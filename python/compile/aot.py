"""AOT compile path: lower the L2 graphs to HLO *text* + manifest.json.

Run once by ``make artifacts``; the rust runtime
(rust/src/runtime/) loads the text with ``HloModuleProto::from_text_file``,
compiles on the PJRT CPU client and executes.  Python never runs on the
request path.

HLO text — NOT ``lowered.compile().serialize()`` and NOT the proto bytes:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32
I8 = jnp.int8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


# Canonical problem shapes baked into artifacts (HLO is shape-specialized).
#   gaussian toy:  Phi in R^{256x512}, s=32  (paper §10)
#   astro r=32:    L=10 antennas -> M = 2*L^2 = 200 stacked-real rows,
#                  N = 32*32 = 1024 pixels, s=16
#   tiny:          fast CI shape
SHAPES = [
    {"name": "tiny_64x128", "m": 64, "n": 128, "s": 8},
    {"name": "gauss_256x512", "m": 256, "n": 512, "s": 32},
    {"name": "astro_200x1024", "m": 200, "n": 1024, "s": 16},
]


def build_entries(m: int, n: int, s: int):
    """(entry_name, lowered, input/output descriptors) for one shape."""
    c1t = spec((n, m), I8)
    c2 = spec((m, n), I8)
    sc = spec((1,))
    y = spec((m,))
    x = spec((n,))
    g = spec((n,))
    mu = spec((1,))
    phi = spec((m, n))

    def io(names, specs):
        return [
            {"name": nm, "dtype": str(sp.dtype), "shape": list(sp.shape)}
            for nm, sp in zip(names, specs)
        ]

    one = spec((1,))
    entries = []

    lowered = jax.jit(
        functools.partial(model.qniht_step, s=s)
    ).lower(c1t, c2, sc, sc, y, x)
    entries.append(
        (
            "qniht_step",
            lowered,
            io(["codes1_t", "codes2", "sc1", "sc2", "y", "x"], [c1t, c2, sc, sc, y, x]),
            io(
                ["x_next", "g", "mu", "dx_nsq", "phi1_dx_nsq", "resid_nsq"],
                [x, g, one, one, one, one],
            ),
        )
    )

    lowered = jax.jit(
        functools.partial(model.apply_step, s=s)
    ).lower(c1t, sc, x, g, mu)
    entries.append(
        (
            "apply_step",
            lowered,
            io(["codes1_t", "sc1", "x", "g", "mu"], [c1t, sc, x, g, mu]),
            io(["x_next", "dx_nsq", "phi1_dx_nsq"], [x, one, one]),
        )
    )

    lowered = jax.jit(model.qgrad).lower(c1t, c2, sc, sc, y, x)
    entries.append(
        (
            "qgrad",
            lowered,
            io(["codes1_t", "codes2", "sc1", "sc2", "y", "x"], [c1t, c2, sc, sc, y, x]),
            io(["g", "resid_nsq"], [g, one]),
        )
    )

    lowered = jax.jit(
        functools.partial(model.niht_step_dense, s=s)
    ).lower(phi, y, x)
    entries.append(
        (
            "niht_step_f32",
            lowered,
            io(["phi", "y", "x"], [phi, y, x]),
            io(
                ["x_next", "g", "mu", "dx_nsq", "phi_dx_nsq", "resid_nsq"],
                [x, g, one, one, one, one],
            ),
        )
    )

    lowered = jax.jit(
        functools.partial(model.apply_step_dense, s=s)
    ).lower(phi, x, g, mu)
    entries.append(
        (
            "apply_step_f32",
            lowered,
            io(["phi", "x", "g", "mu"], [phi, x, g, mu]),
            io(["x_next", "dx_nsq", "phi_dx_nsq"], [x, one, one]),
        )
    )

    return entries


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"format": "hlo-text", "entries": []}
    for shp in SHAPES:
        m, n, s = shp["m"], shp["n"], shp["s"]
        for entry, lowered, inputs, outputs in build_entries(m, n, s):
            fname = f"{entry}_{shp['name']}.hlo.txt"
            path = os.path.join(args.out, fname)
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
            manifest["entries"].append(
                {
                    "name": f"{entry}_{shp['name']}",
                    "entry": entry,
                    "shape_tag": shp["name"],
                    "file": fname,
                    "m": m,
                    "n": n,
                    "s": s,
                    "inputs": inputs,
                    "outputs": outputs,
                }
            )
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}: "
          f"{len(manifest['entries'])} entries")


if __name__ == "__main__":
    main()
