from . import qmatvec, quantize, ref, threshold  # noqa: F401
