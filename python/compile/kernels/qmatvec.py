"""Layer-1 Pallas kernels: fused dequantize + matvec.

This is the paper's compute hot-spot (§9: "the bulk of the computation is
accounted for by two routines: a matrix-vector multiplication ... and a
matrix times a sparse vector").  The measurement matrix lives in memory as
small integer *codes*; the kernel streams code tiles, dequantizes them
in-register (VMEM on a real TPU) and accumulates the product — so the
memory traffic per iteration is ``M*N*b/8`` bytes instead of ``4*M*N``.

Hardware adaptation (paper targets FPGA/AVX2, we target a TPU-shaped
memory hierarchy): the FPGA gradient unit consumes a fixed-rate stream of
packed values; the AVX2 version widens SIMD lanes.  Here the same insight
is expressed as a BlockSpec schedule: int8 code tiles are the HBM→VMEM
traffic, dequantization happens after the copy, and the MXU sees f32
tiles.  Kernels are lowered with ``interpret=True`` (CPU PJRT cannot run
Mosaic custom-calls); on-TPU characteristics are estimated in
DESIGN.md §Perf from the tile footprint.

VMEM budget at the default (128, 256) tile (f32 accumulation):
  codes tile 128*256*1 B = 32 KiB, dequant tile 128*256*4 B = 128 KiB,
  x tile 1 KiB, acc 0.5 KiB -> fits a 16 MiB VMEM with deep double
  buffering; MXU sees (128, 256) @ (256,) fragments.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pick_block(dim: int, cap: int) -> int:
    """Largest divisor of ``dim`` that is <= cap (grid must tile exactly)."""
    for d in range(min(dim, cap), 0, -1):
        if dim % d == 0:
            return d
    return 1


def _mv_kernel(codes_ref, sc_ref, x_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    tile = codes_ref[...].astype(jnp.float32) * sc_ref[0]
    o_ref[...] += tile @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matvec(codes, scale_over_half, x, bm: int = 128, bn: int = 256):
    """y = (codes * scale_over_half) @ x.

    codes: (M, N) int8, scale_over_half: (1,) f32, x: (N,) f32 -> (M,) f32.
    """
    m, n = codes.shape
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    return pl.pallas_call(
        _mv_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(codes, scale_over_half, x)


def _mvt_kernel(codes_ref, sc_ref, v_ref, o_ref):
    i = pl.program_id(1)  # reduction dim (rows) iterates innermost

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    tile = codes_ref[...].astype(jnp.float32) * sc_ref[0]
    o_ref[...] += v_ref[...] @ tile


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matvec_t(codes, scale_over_half, v, bm: int = 128, bn: int = 256):
    """y = (codes * scale_over_half).T @ v.

    codes: (R, C) int8, v: (R,) f32 -> (C,) f32.  The grid iterates the
    reduction (row) dimension innermost so the output tile stays resident.
    """
    r, c = codes.shape
    br = pick_block(r, bm)
    bc = pick_block(c, bn)
    return pl.pallas_call(
        _mvt_kernel,
        grid=(c // bc, r // br),
        in_specs=[
            pl.BlockSpec((br, bc), lambda jc, ir: (ir, jc)),
            pl.BlockSpec((1,), lambda jc, ir: (0,)),
            pl.BlockSpec((br,), lambda jc, ir: (ir,)),
        ],
        out_specs=pl.BlockSpec((bc,), lambda jc, ir: (jc,)),
        out_shape=jax.ShapeDtypeStruct((c,), jnp.float32),
        interpret=True,
    )(codes, scale_over_half, v)
