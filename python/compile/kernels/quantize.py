"""Layer-1 Pallas kernel: stochastic quantizer (paper §3, Algorithm 1 input).

Maps f32 values onto the odd-level b-bit grid (see kernels/ref.py for the
scheme).  Randomness is an explicit input tensor of uniform(0,1) variates —
the rust coordinator owns the RNG (XORShift, as in the paper's CPU
implementation), which keeps the AOT artifact a pure function.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .qmatvec import pick_block


def _quantize_kernel(v_ref, u_ref, inv_ref, half_ref, o_ref):
    half = half_ref[0]
    t = v_ref[...] * inv_ref[0] * half  # v / scale * half
    lo = jnp.floor(t)
    code = lo + (u_ref[...] < (t - lo)).astype(t.dtype)
    o_ref[...] = jnp.clip(code, -half, half).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block",))
def quantize(v, u, inv_scale, half, block: int = 4096):
    """Stochastically quantize flat ``v`` (n,) to int8 codes.

    inv_scale: (1,) f32 = 1/scale; half: (1,) f32 = 2**(bits-2).
    """
    (n,) = v.shape
    b = pick_block(n, block)
    return pl.pallas_call(
        _quantize_kernel,
        grid=(n // b,),
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int8),
        interpret=True,
    )(v, u, inv_scale, half)
