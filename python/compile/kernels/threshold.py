"""Layer-1 Pallas kernel: magnitude thresholding (the H_s apply stage).

The s-th largest magnitude is found with ``lax.top_k`` at the model layer
(a reduction XLA already does well); this kernel performs the bandwidth-
bound apply pass ``v <- v * [|v| >= thr]`` over the full vector.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .qmatvec import pick_block


def _threshold_kernel(v_ref, t_ref, o_ref):
    v = v_ref[...]
    o_ref[...] = jnp.where(jnp.abs(v) >= t_ref[0], v, 0.0)


@functools.partial(jax.jit, static_argnames=("block",))
def threshold_apply(v, thr, block: int = 4096):
    """Zero entries of flat ``v`` (n,) with magnitude below thr (1,)."""
    (n,) = v.shape
    b = pick_block(n, block)
    return pl.pallas_call(
        _threshold_kernel,
        grid=(n // b,),
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(v, thr)
