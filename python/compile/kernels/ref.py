"""Pure-jnp oracles for every Pallas kernel (L1 correctness ground truth).

The quantization scheme mirrors the paper (§3 "Quantization" + Remark 3):
an *odd* number of levels, ``2**(b-1) + 1``, equally spaced on
``[-scale, +scale]``.  Codes are stored as small signed integers
``k in {-half, ..., +half}`` with ``half = 2**(b-2)`` and dequantize as
``value = scale * k / half``.  The level spacing is ``scale / 2**(b-2)`` so
the per-element stochastic-rounding error is at most ``scale / 2**(b-1)`` —
exactly the constant in the paper's Lemma 4.

Stochastic rounding is *externally seeded*: callers pass uniform(0,1)
variates of the same shape, which keeps the kernels pure, makes AOT
artifacts deterministic functions of their inputs, and lets the rust L3
own the RNG (the paper's CPU implementation does the same with XORShift).
"""

import jax
import jax.numpy as jnp


def half_levels(bits: int) -> int:
    """Number of positive levels: codes live in [-half, +half]."""
    if bits < 2:
        raise ValueError(f"need bits >= 2, got {bits}")
    return 2 ** (bits - 2)


def spacing(bits: int) -> float:
    """Level spacing on the normalized [-1, 1] grid."""
    return 1.0 / half_levels(bits)


def quantize_ref(v, u, bits: int, scale):
    """Stochastically round ``v`` onto the b-bit grid. Returns int8 codes.

    ``u`` are iid uniform(0,1) variates, same shape as ``v``.
    ``scale`` must satisfy ``scale >= max|v|`` for the codes to be in range
    (values are clamped otherwise, matching the rust implementation).
    """
    half = half_levels(bits)
    t = v / scale * half  # in [-half, half]
    lo = jnp.floor(t)
    frac = t - lo
    code = lo + (u < frac).astype(t.dtype)
    code = jnp.clip(code, -half, half)
    return code.astype(jnp.int8)


def dequantize_ref(codes, bits: int, scale):
    return codes.astype(jnp.float32) * (scale / half_levels(bits))


def matvec_ref(codes, scale_over_half, x):
    """A @ x with A = codes * scale_over_half (codes: (M, N), x: (N,))."""
    return (codes.astype(jnp.float32) @ x) * scale_over_half


def matvec_t_ref(codes, scale_over_half, v):
    """A.T @ v with A = codes * scale_over_half (codes: (R, C), v: (R,))."""
    return (codes.astype(jnp.float32).T @ v) * scale_over_half


def threshold_apply_ref(v, thr):
    """Zero every entry with |v| < thr (value-threshold form of H_s)."""
    return jnp.where(jnp.abs(v) >= thr, v, 0.0)


def hard_threshold_ref(v, s: int):
    """Exact H_s: keep the s largest-magnitude entries (index-based)."""
    idx = jax.lax.top_k(jnp.abs(v), s)[1]
    mask = jnp.zeros(v.shape, bool).at[idx].set(True)
    return jnp.where(mask, v, 0.0)


def grad_ref(phi1_t_codes, codes2, scale1_over_half, scale2_over_half, y, x):
    """g = Phi1^T (y - Phi2 x), quantized operands.

    ``phi1_t_codes`` is Phi1 stored transposed, (N, M); ``codes2`` is (M, N).
    """
    r = y - matvec_ref(codes2, scale2_over_half, x)
    return matvec_ref(phi1_t_codes, scale1_over_half, r)


def niht_step_dense_ref(phi, y, x, s: int, eps: float = 1e-30):
    """Full-precision NIHT step oracle (the 32-bit baseline semantics)."""
    r = y - phi @ x
    g = phi.T @ r
    mask = x != 0
    any_supp = jnp.any(mask)
    mask = jnp.where(any_supp, mask, hard_threshold_ref(g, s) != 0)
    g_m = jnp.where(mask, g, 0.0)
    num = g_m @ g_m
    pg = phi @ g_m
    den = pg @ pg
    mu = num / jnp.maximum(den, eps)
    x_next = hard_threshold_ref(x + mu * g, s)
    dx = x_next - x
    phi_dx = phi @ dx
    return x_next, g, mu, dx @ dx, phi_dx @ phi_dx, r @ r
