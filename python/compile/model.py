"""Layer-2: the QNIHT update as a JAX compute graph (paper Algorithm 1).

Build-time only — lowered once by aot.py to HLO text and executed from the
rust runtime.  The heavy operands are quantized codes (int8) so the graph's
memory traffic matches the paper's low-precision story; the Pallas kernels
in ``kernels/`` do the fused dequantize-matvec.

Conventions
-----------
* ``codes1_t``: Phi_hat_1 stored TRANSPOSED, shape (N, M) int8.  The
  gradient needs Phi1^T r (a (N,M) matvec — row-major friendly) and the
  line-search needs Phi1 dx (the transposed matvec over the same buffer).
  This mirrors the paper's CPU layout where both routines stream the matrix
  contiguously.
* ``codes2``: Phi_hat_2, shape (M, N) int8 (used for Phi2 x).
* ``sc1`` / ``sc2``: (1,) f32 = scale / half_levels(bits) — the dequant
  multiplier.  Bit width is folded into the multiplier so one artifact
  serves every precision.
* scalars are carried as shape-(1,) f32 so the PJRT boundary stays
  array-only.

Step-size note: Algorithm 1 computes the numerator/denominator of mu with
``Phi_Gamma`` (ambiguous between the full-precision and quantized matrix in
the paper's notation).  At runtime only the quantized matrix exists, so we
use Phi_hat_2 — consistent with the convergence argument, which only needs
``mu <= 1/beta_hat^2``-type bounds on the *quantized* RICs (Remark 2).
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import qmatvec

EPS = 1e-30


def topk_mask(v, s: int):
    """Boolean mask of the s largest |v| entries, lower index wins ties.

    Implemented with sort + cumsum instead of ``lax.top_k``: jax lowers
    top_k to the HLO ``TopK`` op whose text attributes (``largest=true``)
    the xla_extension 0.5.1 parser used by the rust runtime rejects. Sort
    and cumsum are classic HLO and round-trip cleanly. Semantics match
    ``ref.hard_threshold_ref`` exactly (including ties).
    """
    absv = jnp.abs(v)
    sorted_desc = jnp.sort(absv)[::-1]
    thr = sorted_desc[s - 1]
    gt = absv > thr
    eq = absv == thr
    need = s - jnp.sum(gt)
    rank = jnp.cumsum(eq)  # 1-based rank among the tied entries
    return gt | (eq & (rank <= need))


def _support_mask(x, g, s: int):
    """supp(x), or supp(H_s(g)) on the first iteration (x == 0)."""
    mask = x != 0
    return jnp.where(jnp.any(mask), mask, topk_mask(g, s))


def _hs(v, s: int):
    """H_s: keep exactly the s largest-magnitude entries."""
    return jnp.where(topk_mask(v, s), v, 0.0)


def qniht_step(codes1_t, codes2, sc1, sc2, y, x, *, s: int):
    """One quantized NIHT step (gradient + adaptive mu + threshold).

    Returns (x_next, g, mu, dx_nsq, phi1_dx_nsq, resid_nsq) — everything
    the rust coordinator needs to run Algorithm 1's support check and mu
    line search without touching full-precision data.
    """
    r = y - qmatvec.matvec(codes2, sc2, x)
    g = qmatvec.matvec(codes1_t, sc1, r)
    mask = _support_mask(x, g, s)
    g_m = jnp.where(mask, g, 0.0)
    num = g_m @ g_m
    pg = qmatvec.matvec(codes2, sc2, g_m)
    den = pg @ pg
    mu = num / jnp.maximum(den, EPS)
    x_next = _hs(x + mu * g, s)
    dx = x_next - x
    phi1_dx = qmatvec.matvec_t(codes1_t, sc1, dx)
    return (
        x_next,
        g,
        mu[None],
        (dx @ dx)[None],
        (phi1_dx @ phi1_dx)[None],
        (r @ r)[None],
    )


def apply_step(codes1_t, sc1, x, g, mu, *, s: int):
    """Re-apply a (shrunken) step: x+ = H_s(x + mu g), plus the line-search
    norms ||x+ - x||^2 and ||Phi1 (x+ - x)||^2 (Algorithm 1's b^[n])."""
    x_next = _hs(x + mu[0] * g, s)
    dx = x_next - x
    phi1_dx = qmatvec.matvec_t(codes1_t, sc1, dx)
    return x_next, (dx @ dx)[None], (phi1_dx @ phi1_dx)[None]


def qgrad(codes1_t, codes2, sc1, sc2, y, x):
    """Gradient only: g = Phi1^T (y - Phi2 x), plus residual norm."""
    r = y - qmatvec.matvec(codes2, sc2, x)
    g = qmatvec.matvec(codes1_t, sc1, r)
    return g, (r @ r)[None]


def niht_step_dense(phi, y, x, *, s: int):
    """Full-precision (32-bit) NIHT step — the paper's baseline engine.

    Pure jnp (XLA fuses dense matvecs well; the Pallas path is only
    beneficial for quantized operands)."""
    r = y - phi @ x
    g = phi.T @ r
    mask = _support_mask(x, g, s)
    g_m = jnp.where(mask, g, 0.0)
    num = g_m @ g_m
    pg = phi @ g_m
    den = pg @ pg
    mu = num / jnp.maximum(den, EPS)
    x_next = _hs(x + mu * g, s)
    dx = x_next - x
    phi_dx = phi @ dx
    return (
        x_next,
        g,
        mu[None],
        (dx @ dx)[None],
        (phi_dx @ phi_dx)[None],
        (r @ r)[None],
    )


def apply_step_dense(phi, x, g, mu, *, s: int):
    x_next = _hs(x + mu[0] * g, s)
    dx = x_next - x
    phi_dx = phi @ dx
    return x_next, (dx @ dx)[None], (phi_dx @ phi_dx)[None]


# ---------------------------------------------------------------------------
# jit wrappers with static sparsity (top_k needs a static k)


@functools.partial(jax.jit, static_argnames=("s",))
def qniht_step_jit(codes1_t, codes2, sc1, sc2, y, x, s):
    return qniht_step(codes1_t, codes2, sc1, sc2, y, x, s=s)


@functools.partial(jax.jit, static_argnames=("s",))
def apply_step_jit(codes1_t, sc1, x, g, mu, s):
    return apply_step(codes1_t, sc1, x, g, mu, s=s)


@functools.partial(jax.jit, static_argnames=("s",))
def niht_step_dense_jit(phi, y, x, s):
    return niht_step_dense(phi, y, x, s=s)
