"""Pallas threshold-apply kernel vs oracle + H_s semantics."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, threshold


@pytest.mark.parametrize("n", [4, 100, 512])
def test_matches_ref(n):
    rng = np.random.default_rng(n)
    v = jnp.asarray(rng.standard_normal(n), jnp.float32)
    thr = jnp.asarray([0.5], jnp.float32)
    got = threshold.threshold_apply(v, thr)
    want = ref.threshold_apply_ref(v, thr[0])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 300),
    t=st.floats(0.0, 3.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_hypothesis(n, t, seed):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.standard_normal(n), jnp.float32)
    thr = jnp.asarray([t], jnp.float32)
    got = threshold.threshold_apply(v, thr)
    want = ref.threshold_apply_ref(v, thr[0])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hard_threshold_keeps_exactly_s():
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.standard_normal(128), jnp.float32)
    for s in (1, 5, 64, 128):
        out = np.asarray(ref.hard_threshold_ref(v, s))
        assert (out != 0).sum() == s


def test_hard_threshold_keeps_largest():
    v = jnp.asarray([0.1, -5.0, 2.0, 0.01, -3.0], jnp.float32)
    out = np.asarray(ref.hard_threshold_ref(v, 2))
    np.testing.assert_array_equal(out, [0, -5.0, 0, 0, -3.0])


def test_hard_threshold_idempotent():
    rng = np.random.default_rng(1)
    v = jnp.asarray(rng.standard_normal(64), jnp.float32)
    once = ref.hard_threshold_ref(v, 8)
    twice = ref.hard_threshold_ref(once, 8)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))
