"""Pallas stochastic quantizer vs ref.py oracle + statistical properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quantize as qk
from compile.kernels import ref


def _quantize_pallas(v, u, bits, scale):
    inv = jnp.array([1.0 / scale], jnp.float32)
    half = jnp.array([float(ref.half_levels(bits))], jnp.float32)
    return qk.quantize(v, u, inv, half)


@pytest.mark.parametrize("bits", [2, 3, 4, 6, 8])
def test_kernel_matches_ref(bits):
    rng = np.random.default_rng(bits)
    v = jnp.asarray(rng.standard_normal(512), jnp.float32)
    u = jnp.asarray(rng.random(512), jnp.float32)
    scale = float(jnp.max(jnp.abs(v)))
    got = _quantize_pallas(v, u, bits, scale)
    want = ref.quantize_ref(v, u, bits, scale)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 257),
    bits=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(n, bits, seed):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.standard_normal(n) * 3.0, jnp.float32)
    u = jnp.asarray(rng.random(n), jnp.float32)
    scale = float(max(np.max(np.abs(np.asarray(v))), 1e-6))
    got = _quantize_pallas(v, u, bits, scale)
    want = ref.quantize_ref(v, u, bits, scale)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_codes_in_range(bits):
    rng = np.random.default_rng(7)
    v = jnp.asarray(rng.standard_normal(1024), jnp.float32)
    u = jnp.asarray(rng.random(1024), jnp.float32)
    scale = float(jnp.max(jnp.abs(v)))
    codes = np.asarray(_quantize_pallas(v, u, bits, scale))
    half = ref.half_levels(bits)
    assert codes.min() >= -half and codes.max() <= half


def test_unbiased():
    """E[Q(v)] = v: average dequantized value over many rounding draws."""
    rng = np.random.default_rng(11)
    v = jnp.asarray(rng.uniform(-1, 1, 64), jnp.float32)
    scale, bits, reps = 1.0, 2, 4000
    acc = np.zeros(64)
    for i in range(reps):
        u = jnp.asarray(rng.random(64), jnp.float32)
        acc += np.asarray(ref.dequantize_ref(
            ref.quantize_ref(v, u, bits, scale), bits, scale))
    err = np.abs(acc / reps - np.asarray(v))
    # std of the mean ~ spacing/sqrt(reps) ~ 0.016 at b=2
    assert err.max() < 0.08, err.max()


def test_lemma4_error_bound():
    """E||Q(v) - v||_2 <= c sqrt(M) / 2^{b-1} (paper Lemma 4)."""
    rng = np.random.default_rng(3)
    m = 256
    v = jnp.asarray(rng.uniform(-1, 1, m), jnp.float32)
    for bits in (2, 4, 8):
        errs = []
        for i in range(50):
            u = jnp.asarray(rng.random(m), jnp.float32)
            dq = ref.dequantize_ref(ref.quantize_ref(v, u, bits, 1.0), bits, 1.0)
            errs.append(float(jnp.linalg.norm(dq - v)))
        bound = np.sqrt(m) / 2 ** (bits - 1)
        assert np.mean(errs) <= bound, (bits, np.mean(errs), bound)


def test_grid_values_are_fixed_points():
    """Values already on the grid quantize deterministically to themselves."""
    bits = 4
    half = ref.half_levels(bits)
    codes = jnp.arange(-half, half + 1, dtype=jnp.float32)
    v = codes / half
    for uval in (0.0, 0.5, 0.999):
        u = jnp.full_like(v, uval)
        got = ref.quantize_ref(v, u, bits, 1.0)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(codes, np.int8))


def test_clamps_out_of_range():
    bits = 2
    v = jnp.asarray([5.0, -5.0], jnp.float32)
    u = jnp.asarray([0.5, 0.5], jnp.float32)
    got = np.asarray(ref.quantize_ref(v, u, bits, 1.0))
    np.testing.assert_array_equal(got, np.asarray([1, -1], np.int8))
