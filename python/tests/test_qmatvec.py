"""Pallas fused dequant-matvec vs oracle, hypothesis shape/tile sweep."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import qmatvec, ref


def _mk(m, n, seed, bits=4):
    rng = np.random.default_rng(seed)
    half = ref.half_levels(bits)
    codes = jnp.asarray(rng.integers(-half, half + 1, (m, n)), jnp.int8)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    sc = jnp.asarray([0.37 / half], jnp.float32)
    return codes, sc, x


@pytest.mark.parametrize("m,n", [(4, 8), (64, 128), (100, 96), (256, 512)])
def test_matvec_matches_ref(m, n):
    codes, sc, x = _mk(m, n, m * 1000 + n)
    got = qmatvec.matvec(codes, sc, x)
    want = ref.matvec_ref(codes, sc[0], x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("r,c", [(8, 4), (128, 64), (96, 100)])
def test_matvec_t_matches_ref(r, c):
    codes, sc, _ = _mk(r, c, r * 31 + c)
    rng = np.random.default_rng(5)
    v = jnp.asarray(rng.standard_normal(r), jnp.float32)
    got = qmatvec.matvec_t(codes, sc, v)
    want = ref.matvec_t_ref(codes, sc[0], v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 96),
    n=st.integers(1, 96),
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_hypothesis(m, n, bits, seed):
    codes, sc, x = _mk(m, n, seed, bits)
    got = qmatvec.matvec(codes, sc, x)
    want = ref.matvec_ref(codes, sc[0], x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    r=st.integers(1, 96),
    c=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_t_hypothesis(r, c, seed):
    codes, sc, _ = _mk(r, c, seed)
    rng = np.random.default_rng(seed ^ 0xABCD)
    v = jnp.asarray(rng.standard_normal(r), jnp.float32)
    got = qmatvec.matvec_t(codes, sc, v)
    want = ref.matvec_t_ref(codes, sc[0], v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32, 64]),
    bn=st.sampled_from([8, 16, 32, 64]),
)
def test_matvec_tile_invariance(bm, bn):
    """The result must not depend on the BlockSpec tiling."""
    codes, sc, x = _mk(64, 64, 42)
    base = qmatvec.matvec(codes, sc, x, bm=64, bn=64)
    got = qmatvec.matvec(codes, sc, x, bm=bm, bn=bn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), rtol=1e-5, atol=1e-5)


def test_zero_codes_give_zero():
    codes = jnp.zeros((16, 32), jnp.int8)
    sc = jnp.asarray([1.0], jnp.float32)
    x = jnp.ones(32, jnp.float32)
    assert float(jnp.max(jnp.abs(qmatvec.matvec(codes, sc, x)))) == 0.0


def test_scale_linearity():
    codes, sc, x = _mk(32, 48, 9)
    y1 = np.asarray(qmatvec.matvec(codes, sc, x))
    y2 = np.asarray(qmatvec.matvec(codes, 2.0 * sc, x))
    np.testing.assert_allclose(y2, 2.0 * y1, rtol=1e-5, atol=1e-6)


def test_pick_block_divides():
    for dim in (1, 7, 64, 100, 200, 1024):
        for cap in (1, 8, 128, 256):
            b = qmatvec.pick_block(dim, cap)
            assert dim % b == 0 and 1 <= b <= max(cap, 1)
