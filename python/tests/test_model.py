"""L2 model graphs: quantized step vs dense oracle, shape/semantics checks."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _problem(m, n, s, seed, bits=8):
    rng = np.random.default_rng(seed)
    phi = rng.standard_normal((m, n)).astype(np.float32) / np.sqrt(m)
    x_true = np.zeros(n, np.float32)
    supp = rng.choice(n, s, replace=False)
    x_true[supp] = rng.standard_normal(s).astype(np.float32)
    y = phi @ x_true
    half = ref.half_levels(bits)
    scale = np.abs(phi).max()
    u1 = rng.random((n, m)).astype(np.float32)
    u2 = rng.random((m, n)).astype(np.float32)
    c1t = np.asarray(ref.quantize_ref(jnp.asarray(phi.T), jnp.asarray(u1), bits, scale))
    c2 = np.asarray(ref.quantize_ref(jnp.asarray(phi), jnp.asarray(u2), bits, scale))
    sc = np.asarray([scale / half], np.float32)
    return phi, x_true, y.astype(np.float32), c1t, c2, sc


def test_dense_step_matches_oracle():
    m, n, s = 32, 64, 4
    phi, x_true, y, *_ = _problem(m, n, s, 0)
    x0 = jnp.zeros(n, jnp.float32)
    got = model.niht_step_dense_jit(jnp.asarray(phi), jnp.asarray(y), x0, s)
    want = ref.niht_step_dense_ref(jnp.asarray(phi), jnp.asarray(y), x0, s)
    for g, w in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(g).ravel(), np.asarray(w).ravel(), rtol=1e-4, atol=1e-5
        )


def test_qniht_step_close_to_dense_at_8bit():
    """At 8 bits the quantized step should track the dense step closely."""
    m, n, s = 32, 64, 4
    phi, x_true, y, c1t, c2, sc = _problem(m, n, s, 1, bits=8)
    x0 = jnp.zeros(n, jnp.float32)
    xq, gq, *_ = model.qniht_step_jit(
        jnp.asarray(c1t), jnp.asarray(c2), jnp.asarray(sc), jnp.asarray(sc),
        jnp.asarray(y), x0, s,
    )
    xd, gd, *_ = model.niht_step_dense_jit(jnp.asarray(phi), jnp.asarray(y), x0, s)
    # gradients agree to quantization noise
    rel = np.linalg.norm(np.asarray(gq) - np.asarray(gd)) / np.linalg.norm(np.asarray(gd))
    assert rel < 0.1, rel


def test_qniht_step_first_iteration_support():
    """At x=0 the step must select support from H_s(Phi^T y)."""
    m, n, s = 24, 48, 3
    _, _, y, c1t, c2, sc = _problem(m, n, s, 2)
    x0 = jnp.zeros(n, jnp.float32)
    x1, g, mu, *_ = model.qniht_step_jit(
        jnp.asarray(c1t), jnp.asarray(c2), jnp.asarray(sc), jnp.asarray(sc),
        jnp.asarray(y), x0, s,
    )
    x1 = np.asarray(x1)
    g_top = np.asarray(ref.hard_threshold_ref(g, s))
    assert set(np.nonzero(x1)[0]) <= set(np.nonzero(g_top)[0] if (g_top != 0).any() else [])
    assert (x1 != 0).sum() <= s


def test_apply_step_consistent_with_full_step():
    """apply_step with the mu returned by qniht_step reproduces x_next."""
    m, n, s = 32, 64, 4
    _, _, y, c1t, c2, sc = _problem(m, n, s, 3)
    x0 = jnp.zeros(n, jnp.float32)
    args = (jnp.asarray(c1t), jnp.asarray(c2), jnp.asarray(sc), jnp.asarray(sc),
            jnp.asarray(y), x0)
    x1, g, mu, dx_nsq, p1dx_nsq, _ = model.qniht_step_jit(*args, s)
    x1b, dx_nsq_b, p1dx_nsq_b = model.apply_step_jit(
        jnp.asarray(c1t), jnp.asarray(sc), x0, g, mu, s
    )
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x1b), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(dx_nsq[0]), float(dx_nsq_b[0]), rtol=1e-4)
    np.testing.assert_allclose(float(p1dx_nsq[0]), float(p1dx_nsq_b[0]), rtol=1e-4)


def test_iterating_dense_step_recovers_planted_signal():
    """A few dense NIHT steps on a well-conditioned problem reduce error."""
    m, n, s = 64, 128, 4
    phi, x_true, y, *_ = _problem(m, n, s, 4)
    x = jnp.zeros(n, jnp.float32)
    err0 = float(np.linalg.norm(x_true))
    for _ in range(15):
        x = model.niht_step_dense_jit(jnp.asarray(phi), jnp.asarray(y), x, s)[0]
    err = float(np.linalg.norm(np.asarray(x) - x_true))
    assert err < 0.05 * err0, (err, err0)


def test_iterating_qniht_8bit_recovers_planted_signal():
    m, n, s = 64, 128, 4
    phi, x_true, y, c1t, c2, sc = _problem(m, n, s, 5, bits=8)
    x = jnp.zeros(n, jnp.float32)
    for _ in range(15):
        x = model.qniht_step_jit(
            jnp.asarray(c1t), jnp.asarray(c2), jnp.asarray(sc), jnp.asarray(sc),
            jnp.asarray(y), x, s,
        )[0]
    err = float(np.linalg.norm(np.asarray(x) - x_true))
    assert err < 0.15 * float(np.linalg.norm(x_true)), err


@settings(max_examples=8, deadline=None)
@given(
    m=st.integers(16, 48),
    seed=st.integers(0, 2**31 - 1),
    bits=st.sampled_from([4, 8]),
)
def test_qgrad_matches_ref_hypothesis(m, seed, bits):
    n, s = 2 * m, 4
    _, _, y, c1t, c2, sc = _problem(m, n, s, seed, bits)
    rng = np.random.default_rng(seed ^ 0x5555)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32) * 0.1)
    g, rn = model.qgrad(
        jnp.asarray(c1t), jnp.asarray(c2), jnp.asarray(sc), jnp.asarray(sc),
        jnp.asarray(y), x,
    )
    want = ref.grad_ref(
        jnp.asarray(c1t), jnp.asarray(c2), sc[0], sc[0], jnp.asarray(y), x
    )
    np.testing.assert_allclose(np.asarray(g), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_mu_positive_and_finite():
    m, n, s = 32, 64, 4
    _, _, y, c1t, c2, sc = _problem(m, n, s, 6)
    x0 = jnp.zeros(n, jnp.float32)
    _, _, mu, *_ = model.qniht_step_jit(
        jnp.asarray(c1t), jnp.asarray(c2), jnp.asarray(sc), jnp.asarray(sc),
        jnp.asarray(y), x0, s,
    )
    mu = float(mu[0])
    assert np.isfinite(mu) and mu > 0
