//! Offline in-tree shim for the [`anyhow`](https://docs.rs/anyhow) API
//! surface this workspace uses (`Result`, `Error`, `anyhow!`, `bail!`,
//! `ensure!`, `Context`). The build environment has no network registry
//! (DESIGN.md §6 — every dependency is substrate), so this path dependency
//! stands in for the real crate with identical call-site semantics:
//!
//! * `Error` is a flattened message chain: `context` layers prepend
//!   `"ctx: cause"`. Both `{}` and `{:#}` render the full chain (real
//!   anyhow renders only the outermost context for `{}`; call sites here
//!   only use the formats for human-facing diagnostics).
//! * The blanket `From<E: std::error::Error + Send + Sync + 'static>`
//!   enables `?` on std errors, exactly like the real crate (and like it,
//!   `Error` itself deliberately does NOT implement `std::error::Error`,
//!   which is what makes the blanket impl coherent).

use std::fmt;

/// Flattened error: a message with optional context layers folded in.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { msg: m.to_string() }
    }

    fn wrap(self, ctx: impl fmt::Display) -> Self {
        Self { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] as default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and turn `None` into an error).
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(fails_io().is_err());
    }

    #[test]
    fn context_layers_fold_into_message() {
        let e: Result<()> = Err(anyhow!("inner {}", 7));
        let e = e.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer: inner 7");
        assert_eq!(format!("{e:#}"), "outer: inner 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u8).context("missing").unwrap(), 3);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert!(f(-1).is_err());
        assert!(f(101).is_err());
        assert_eq!(f(5).unwrap(), 5);
    }
}
