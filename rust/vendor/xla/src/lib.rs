//! Offline in-tree stub of the `xla` (PJRT) binding surface used by
//! `lpcs::runtime`. The real crate links libpjrt/XLA, which the offline
//! build environment cannot provide; this stub keeps the engine compiling
//! while making every operational entry point return a clear error, so the
//! XLA engines gracefully fail at construction (`PjRtClient::cpu()`), which
//! the runtime benches/tests already gate on (`manifest.json` presence +
//! `Result` plumbing).
//!
//! Swap this path dependency for the real `xla` crate to run the AOT
//! JAX/Pallas artifacts.

use std::fmt;

/// Error type: only ever `{:?}`-formatted by the engine.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error("PJRT/XLA runtime not available in this offline build (xla stub)".to_string()))
}

/// Element types the engine constructs literals with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    S8,
    F32,
}

/// Host literal (stub: carries no data; constructors that must succeed
/// return an empty literal, operations return [`Error`]).
#[derive(Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by an execution (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// PJRT client (stub): construction fails, so every XLA engine errors at
/// the earliest, most diagnosable point.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_stub() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{e:?}").contains("stub"));
    }

    #[test]
    fn literal_roundtrip_paths_fail_cleanly() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.to_vec::<f32>().is_err());
        assert!(l.reshape(&[2]).is_err());
        assert!(l.to_tuple().is_err());
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::S8, &[2], &[0, 1]).is_err());
    }
}
