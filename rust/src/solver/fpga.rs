//! The `"fpga-model"` execution engine: the paper's quantized solve on
//! the native kernels, with wall time charged from the §8 FPGA bandwidth
//! model instead of the host clock.
//!
//! The engine wraps [`NativeQuantEngine`], so the *iterates* are
//! bit-identical to `"native-quant"` for the same request (including the
//! batched quantize+pack amortization); what changes is the cost
//! accounting: every solve accrues `iterations ×`
//! [`FpgaModel::iteration_time`] into the engine's
//! [`EngineMetrics::modeled_time_us`], which [`super::Recovery`] surfaces
//! as [`super::SolveReport::modeled`] and the coordinator aggregates into
//! its service metrics. That makes "what would this job cost on the FPGA
//! at 2/4/8 bits?" a servable query: submit the same job at several
//! precisions and read the modeled times off the reports.

use crate::algorithms::{IterObserver, SolveOptions, SolveResult};
use crate::perfmodel::fpga::FpgaModel;
use anyhow::{anyhow, Result};

use super::registry::{
    BatchObserver, Engine, EngineMetrics, IndexedObserver, NativeQuantEngine, SolveRequest,
};
use super::solvers::SolverKind;

/// Quantized native execution billed at FPGA-model rates.
#[derive(Default)]
pub struct FpgaModelEngine {
    model: FpgaModel,
    inner: NativeQuantEngine,
    /// Modeled device-seconds accrued across every solve (f64 so sub-µs
    /// iterations of small problems are not rounded away per solve).
    modeled_s: f64,
}

impl FpgaModelEngine {
    /// An engine for a specific device (defaults = the paper's platform).
    pub fn with_model(model: FpgaModel) -> Self {
        Self { model, ..Self::default() }
    }

    pub fn model(&self) -> &FpgaModel {
        &self.model
    }

    fn require_qniht(req: &SolveRequest) -> Result<()> {
        match req.solver {
            SolverKind::Qniht { .. } => Ok(()),
            other => Err(anyhow!(
                "engine 'fpga-model' runs solver 'qniht' only, got '{}'",
                other.name()
            )),
        }
    }

    /// Accrue the modeled time of one completed solve: iterations × the
    /// per-iteration streaming time T = size(Φ̂)/P, stretched by the §8.2
    /// resource cap when the device cannot sustain P at this precision.
    fn charge(&mut self, req: &SolveRequest, result: &Result<SolveResult>) {
        let SolverKind::Qniht { bits_phi, bits_y, .. } = req.solver else { return };
        let Ok(res) = result else { return };
        let (m, n) = (req.problem.m(), req.problem.n());
        let mut t = self.model.iteration_time(m, n, bits_phi as u32, bits_y as u32);
        if !self.model.sustains_bandwidth(bits_phi as u32) {
            // Multiplier-bound: the gradient unit needs `values_per_line`
            // parallel MACs to keep up with memory; with fewer, the
            // iteration stretches proportionally.
            t *= self.model.values_per_line(bits_phi as u32) as f64
                / (self.model.multipliers as f64).max(1.0);
        }
        self.modeled_s += t * res.iterations as f64;
    }
}

impl Engine for FpgaModelEngine {
    fn name(&self) -> &'static str {
        "fpga-model"
    }

    fn solve(
        &mut self,
        req: &SolveRequest,
        opts: &SolveOptions,
        observer: &mut dyn IterObserver,
    ) -> Result<SolveResult> {
        Self::require_qniht(req)?;
        let result = self.inner.solve(req, opts, observer);
        self.charge(req, &result);
        result
    }

    /// Batched path: identical to `"native-quant"` (one quantize+pack of
    /// Φ per compatible batch), with each job's modeled time accrued
    /// individually. A batch containing a non-QNIHT request falls back to
    /// the per-job path so the mismatch error names this engine.
    fn solve_batch(
        &mut self,
        reqs: &[SolveRequest],
        opts: &SolveOptions,
        observer: &mut dyn BatchObserver,
    ) -> Vec<Result<SolveResult>> {
        if reqs.iter().any(|r| Self::require_qniht(r).is_err()) {
            return reqs
                .iter()
                .enumerate()
                .map(|(i, r)| {
                    Self::require_qniht(r)?;
                    let mut obs = IndexedObserver { index: i, inner: &mut *observer };
                    let result = self.inner.solve(r, opts, &mut obs);
                    self.charge(r, &result);
                    result
                })
                .collect();
        }
        let results = self.inner.solve_batch(reqs, opts, observer);
        for (req, result) in reqs.iter().zip(&results) {
            self.charge(req, result);
        }
        results
    }

    fn metrics(&self) -> EngineMetrics {
        EngineMetrics {
            modeled_time_us: (self.modeled_s * 1e6).round() as u64,
            ..self.inner.metrics()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::registry::NoopBatchObserver;
    use super::super::Problem;
    use super::*;
    use crate::algorithms::NoopObserver;
    use crate::linalg::Mat;
    use crate::rng::XorShift128Plus;
    use std::sync::Arc;

    fn planted(m: usize, n: usize, s: usize, seed: u64) -> (Arc<Mat>, Vec<f32>) {
        let mut rng = XorShift128Plus::new(seed);
        let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
        let mut x = vec![0.0f32; n];
        for i in rng.choose_k(n, s) {
            x[i] = 2.0 * rng.gaussian_f32().signum();
        }
        let y = phi.matvec(&x);
        (Arc::new(phi), y)
    }

    fn req(phi: &Arc<Mat>, y: &[f32], bits: u8, seed: u64) -> SolveRequest {
        SolveRequest {
            problem: Problem::new(phi.clone(), y.to_vec(), 4),
            solver: SolverKind::qniht_fixed(bits, 8),
            seed,
        }
    }

    #[test]
    fn iterates_match_native_quant_bit_for_bit() {
        let (phi, y) = planted(64, 128, 4, 3);
        let opts = SolveOptions::default();
        let mut fpga = FpgaModelEngine::default();
        let mut native = NativeQuantEngine::default();
        let a = fpga.solve(&req(&phi, &y, 4, 7), &opts, &mut NoopObserver).unwrap();
        let b = native.solve(&req(&phi, &y, 4, 7), &opts, &mut NoopObserver).unwrap();
        assert_eq!(a.x, b.x, "same math, different clock");
        assert_eq!(a.iterations, b.iterations);
    }

    #[test]
    fn charges_iteration_time_per_iteration() {
        let (phi, y) = planted(64, 128, 4, 4);
        let mut e = FpgaModelEngine::default();
        let r = e
            .solve(&req(&phi, &y, 2, 1), &SolveOptions::default(), &mut NoopObserver)
            .unwrap();
        let expect_s =
            FpgaModel::default().iteration_time(64, 128, 2, 8) * r.iterations as f64;
        assert_eq!(e.metrics().modeled_time_us, (expect_s * 1e6).round() as u64);
        assert!(e.metrics().modeled_time_us > 0, "modeled time accrued");
    }

    #[test]
    fn lower_precision_costs_less_modeled_time_per_iteration() {
        let (phi, y) = planted(64, 128, 4, 5);
        let opts = SolveOptions::default();
        let per_iter = |bits: u8| {
            let mut e = FpgaModelEngine::default();
            let r = e.solve(&req(&phi, &y, bits, 1), &opts, &mut NoopObserver).unwrap();
            e.metrics().modeled_time_us as f64 / r.iterations as f64
        };
        let (t2, t8) = (per_iter(2), per_iter(8));
        assert!(t2 < t8, "2-bit per-iteration must be cheaper: {t2} vs {t8}");
    }

    #[test]
    fn batched_path_amortizes_and_charges_every_job() {
        let (phi, y) = planted(64, 128, 4, 6);
        let mut e = FpgaModelEngine::default();
        let reqs = [req(&phi, &y, 8, 1), req(&phi, &y, 8, 2), req(&phi, &y, 8, 3)];
        let results = e.solve_batch(&reqs, &SolveOptions::default(), &mut NoopBatchObserver);
        assert!(results.iter().all(|r| r.is_ok()));
        let m = e.metrics();
        assert_eq!(m.phi_quantizations, 1, "one quantize+pack for the batch");
        assert_eq!(m.solves, 3);
        assert!(m.modeled_time_us > 0);
    }

    #[test]
    fn rejects_dense_solvers() {
        let (phi, y) = planted(16, 32, 2, 7);
        let mut e = FpgaModelEngine::default();
        let bad = SolveRequest {
            problem: Problem::new(phi, y, 2),
            solver: SolverKind::Niht,
            seed: 0,
        };
        let err = e
            .solve(&bad, &SolveOptions::default(), &mut NoopObserver)
            .unwrap_err()
            .to_string();
        assert!(err.contains("fpga-model"), "{err}");
    }
}
