//! The engine registry: name → factory for execution engines, replacing
//! the hardcoded `EngineKind` match that used to live in the coordinator's
//! `run_job`. Each worker thread owns one [`EngineRegistry`]; engines are
//! created lazily on first use and keep their expensive state (the PJRT
//! runtime and its compiled-executable cache, batch quantizations) alive
//! for the thread's lifetime. New engines plug in via
//! [`EngineRegistry::register`] without touching the serving layer.

use crate::algorithms::niht::solve_observed;
use crate::algorithms::qniht::{solve_batch_lockstep, BatchJob, PreparedPhi, RequantMode};
use crate::algorithms::{IterObserver, IterStat, ObserverSignal, SolveOptions, SolveResult};
use crate::config::EngineKind;
use crate::runtime::{Runtime, XlaDenseKernel, XlaQuantKernel};
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::Arc;

use super::problem::Problem;
use super::solvers::SolverKind;

/// One solve, fully described: the problem, the algorithm, and the seed
/// for any stochastic quantization. Which engine executes it is chosen by
/// the caller at dispatch time (by registry name).
#[derive(Clone)]
pub struct SolveRequest {
    pub problem: Problem,
    pub solver: SolverKind,
    pub seed: u64,
}

/// Per-engine counters, exposed so tests (and the service's metrics
/// endpoint) can verify amortization behaviour.
#[derive(Debug, Default, Clone, Copy)]
pub struct EngineMetrics {
    /// Individual solves executed (batched or not).
    pub solves: u64,
    /// `solve_batch` invocations that took the amortized path.
    pub amortized_batches: u64,
    /// Quantization passes over Φ (the quantity batching amortizes).
    pub phi_quantizations: u64,
    /// Modeled device time accrued, µs (performance-model engines such as
    /// `"fpga-model"`; 0 for engines billed on the host clock).
    pub modeled_time_us: u64,
}

/// Observer for a batched solve: `job_index` identifies the request
/// within the batch. The coordinator uses this to stream per-job progress
/// and to cancel individual jobs mid-batch.
pub trait BatchObserver {
    fn on_iteration(&mut self, job_index: usize, stat: &IterStat) -> ObserverSignal;
}

/// Batch observer that never stops anything.
#[derive(Debug, Default, Clone, Copy)]
pub struct NoopBatchObserver;

impl BatchObserver for NoopBatchObserver {
    fn on_iteration(&mut self, _job_index: usize, _stat: &IterStat) -> ObserverSignal {
        ObserverSignal::Continue
    }
}

/// Adapts one slot of a [`BatchObserver`] to the scalar [`IterObserver`]
/// the solver drivers take.
pub(super) struct IndexedObserver<'a> {
    pub(super) index: usize,
    pub(super) inner: &'a mut dyn BatchObserver,
}

impl IterObserver for IndexedObserver<'_> {
    fn on_iteration(&mut self, stat: &IterStat) -> ObserverSignal {
        self.inner.on_iteration(self.index, stat)
    }
}

/// An execution engine: runs [`SolveRequest`]s it supports, owns whatever
/// caches make repeated solves cheap (PJRT executables, shared packed Φ̂).
pub trait Engine {
    /// Registry name (what [`EngineRegistry`] dispatches on).
    fn name(&self) -> &'static str;

    fn solve(
        &mut self,
        req: &SolveRequest,
        opts: &SolveOptions,
        observer: &mut dyn IterObserver,
    ) -> Result<SolveResult>;

    /// Solve a batch of requests that the caller believes are compatible
    /// (same Φ and configuration). Engines with an amortizable setup
    /// override this; the default just loops. One inner `Err` fails that
    /// job only.
    fn solve_batch(
        &mut self,
        reqs: &[SolveRequest],
        opts: &SolveOptions,
        observer: &mut dyn BatchObserver,
    ) -> Vec<Result<SolveResult>> {
        reqs.iter()
            .enumerate()
            .map(|(i, r)| {
                self.solve(r, opts, &mut IndexedObserver { index: i, inner: &mut *observer })
            })
            .collect()
    }

    fn metrics(&self) -> EngineMetrics {
        EngineMetrics::default()
    }
}

/// Context handed to engine factories.
pub struct EngineContext {
    /// Where the AOT artifacts live (XLA engines).
    pub artifact_dir: PathBuf,
}

pub type EngineFactory = Box<dyn Fn(&EngineContext) -> Box<dyn Engine>>;

/// Name → factory table with lazily instantiated engines.
pub struct EngineRegistry {
    ctx: EngineContext,
    factories: Vec<(String, EngineFactory)>,
    live: Vec<(String, Box<dyn Engine>)>,
}

impl EngineRegistry {
    /// An empty registry (register engines yourself).
    pub fn new(artifact_dir: PathBuf) -> Self {
        Self { ctx: EngineContext { artifact_dir }, factories: Vec::new(), live: Vec::new() }
    }

    /// The standard table: the four built-in engines under their
    /// [`EngineKind::name`] names.
    pub fn with_defaults(artifact_dir: PathBuf) -> Self {
        let mut reg = Self::new(artifact_dir);
        reg.register(
            EngineKind::NativeDense.name(),
            Box::new(|_: &EngineContext| Box::new(NativeDenseEngine::default()) as Box<dyn Engine>),
        );
        reg.register(
            EngineKind::NativeQuant.name(),
            Box::new(|_: &EngineContext| Box::new(NativeQuantEngine::default()) as Box<dyn Engine>),
        );
        reg.register(
            EngineKind::XlaQuant.name(),
            Box::new(|ctx: &EngineContext| {
                Box::new(XlaQuantEngine { artifact_dir: ctx.artifact_dir.clone(), rt: None, metrics: EngineMetrics::default() }) as Box<dyn Engine>
            }),
        );
        reg.register(
            EngineKind::XlaDense.name(),
            Box::new(|ctx: &EngineContext| {
                Box::new(XlaDenseEngine { artifact_dir: ctx.artifact_dir.clone(), rt: None, metrics: EngineMetrics::default() }) as Box<dyn Engine>
            }),
        );
        reg.register(
            EngineKind::FpgaModel.name(),
            Box::new(|_: &EngineContext| {
                Box::new(super::fpga::FpgaModelEngine::default()) as Box<dyn Engine>
            }),
        );
        reg
    }

    /// Register (or replace) an engine factory under `name`.
    pub fn register(&mut self, name: &str, factory: EngineFactory) {
        self.factories.retain(|(n, _)| n != name);
        self.live.retain(|(n, _)| n != name);
        self.factories.push((name.to_string(), factory));
    }

    /// Registered engine names, registration order.
    pub fn names(&self) -> Vec<String> {
        self.factories.iter().map(|(n, _)| n.clone()).collect()
    }

    /// The engine registered under `name`, instantiating it on first use.
    pub fn engine_mut(&mut self, name: &str) -> Result<&mut dyn Engine> {
        if let Some(pos) = self.live.iter().position(|(n, _)| n == name) {
            return Ok(self.live[pos].1.as_mut());
        }
        let known = self.names().join(", ");
        let factory = self
            .factories
            .iter()
            .find(|(n, _)| n == name)
            .ok_or_else(|| anyhow!("unknown engine '{name}' (known engines: {known})"))?;
        let engine = (factory.1)(&self.ctx);
        self.live.push((name.to_string(), engine));
        Ok(self.live.last_mut().unwrap().1.as_mut())
    }

    /// Dispatch one solve to the named engine.
    pub fn solve(
        &mut self,
        engine: &str,
        req: &SolveRequest,
        opts: &SolveOptions,
        observer: &mut dyn IterObserver,
    ) -> Result<SolveResult> {
        req.problem.validate()?;
        self.engine_mut(engine)?.solve(req, opts, observer)
    }

    /// Dispatch a compatible batch to the named engine. The outer `Err`
    /// is an unknown engine; inner `Err`s fail individual jobs (including
    /// jobs whose problem fails validation — a malformed job never takes
    /// its batch siblings down with it).
    pub fn solve_batch(
        &mut self,
        engine: &str,
        reqs: &[SolveRequest],
        opts: &SolveOptions,
        observer: &mut dyn BatchObserver,
    ) -> Result<Vec<Result<SolveResult>>> {
        let engine = self.engine_mut(engine)?;
        if reqs.iter().all(|r| r.problem.validate().is_ok()) {
            return Ok(engine.solve_batch(reqs, opts, observer));
        }
        // Mixed validity: fail the malformed jobs individually and solve
        // the rest one by one (the amortized fast path only applies to
        // fully-valid batches).
        Ok(reqs
            .iter()
            .enumerate()
            .map(|(i, r)| {
                r.problem.validate()?;
                engine.solve(r, opts, &mut IndexedObserver { index: i, inner: &mut *observer })
            })
            .collect())
    }

    /// Metrics of the named engine (`None` until its first use).
    pub fn metrics(&self, engine: &str) -> Option<EngineMetrics> {
        self.live.iter().find(|(n, _)| n == engine).map(|(_, e)| e.metrics())
    }
}

/// Dense f32 native engine: runs every [`SolverKind`] except QNIHT.
#[derive(Default)]
pub struct NativeDenseEngine {
    metrics: EngineMetrics,
}

impl Engine for NativeDenseEngine {
    fn name(&self) -> &'static str {
        "native-dense"
    }

    fn solve(
        &mut self,
        req: &SolveRequest,
        opts: &SolveOptions,
        observer: &mut dyn IterObserver,
    ) -> Result<SolveResult> {
        if matches!(req.solver, SolverKind::Qniht { .. }) {
            return Err(anyhow!(
                "solver 'qniht' needs a quantized engine (native-quant or xla-quant), not native-dense"
            ));
        }
        self.metrics.solves += 1;
        req.solver.native_solver(req.seed).solve(&req.problem, opts, observer)
    }

    fn metrics(&self) -> EngineMetrics {
        self.metrics
    }
}

/// Seed for the shared Φ quantization on the batched path. Deliberately
/// NOT taken from any job: per-job results must not depend on which jobs
/// happened to land in the same batch, so the shared Φ̂ is a pure function
/// of (Φ, bits).
fn batch_phi_seed(bits_phi: u8) -> u64 {
    0x9E37_79B9_7F4A_7C15 ^ bits_phi as u64
}

/// Quantized native engine (the paper's low-precision path). Runs QNIHT
/// only; its batched path quantizes+packs Φ once per batch.
#[derive(Default)]
pub struct NativeQuantEngine {
    metrics: EngineMetrics,
}

impl NativeQuantEngine {
    fn quant_config(req: &SolveRequest) -> Result<(u8, u8, RequantMode)> {
        match req.solver {
            SolverKind::Qniht { bits_phi, bits_y, mode } => Ok((bits_phi, bits_y, mode)),
            other => Err(anyhow!(
                "engine 'native-quant' runs solver 'qniht' only, got '{}'",
                other.name()
            )),
        }
    }
}

impl Engine for NativeQuantEngine {
    fn name(&self) -> &'static str {
        "native-quant"
    }

    fn solve(
        &mut self,
        req: &SolveRequest,
        opts: &SolveOptions,
        observer: &mut dyn IterObserver,
    ) -> Result<SolveResult> {
        Self::quant_config(req)?;
        self.metrics.solves += 1;
        self.metrics.phi_quantizations += 1;
        req.solver.native_solver(req.seed).solve(&req.problem, opts, observer)
    }

    /// The amortized path: one quantize+pack of Φ shared by every job in
    /// the batch (jobs differ only in y and seed), then a LOCKSTEP solve
    /// ([`solve_batch_lockstep`]) whose per-iteration gradients stream the
    /// packed Φ̂ᵀ once for the whole batch through the multi-RHS kernels —
    /// each row is decoded once per batch, not once per job. Singleton
    /// batches take it too, and the lockstep driver is bit-identical to
    /// the sequential path per job, so a job's result NEVER depends on
    /// which jobs happened to coalesce with it. Falls back to the per-job
    /// path when the batch is not actually compatible or uses Fresh mode
    /// (which re-quantizes per iteration anyway, so each job's Φ̂ stream is
    /// its own seed's).
    fn solve_batch(
        &mut self,
        reqs: &[SolveRequest],
        opts: &SolveOptions,
        observer: &mut dyn BatchObserver,
    ) -> Vec<Result<SolveResult>> {
        let amortizable = !reqs.is_empty()
            && Self::quant_config(&reqs[0])
                .map(|(_, _, mode)| mode == RequantMode::Fixed)
                .unwrap_or(false)
            && reqs.windows(2).all(|w| {
                w[0].problem.shares_op(&w[1].problem)
                    && w[0].solver == w[1].solver
                    && w[0].problem.s() == w[1].problem.s()
            })
            && reqs[0].problem.as_mat().is_some();
        if !amortizable {
            return reqs
                .iter()
                .enumerate()
                .map(|(i, r)| {
                    self.solve(r, opts, &mut IndexedObserver { index: i, inner: &mut *observer })
                })
                .collect();
        }

        let (bits_phi, bits_y, _) = Self::quant_config(&reqs[0]).expect("checked above");
        let phi = reqs[0].problem.as_mat().expect("checked above");
        let prepared = Arc::new(PreparedPhi::quantize(phi, bits_phi, batch_phi_seed(bits_phi)));
        self.metrics.phi_quantizations += 1;
        self.metrics.amortized_batches += 1;
        self.metrics.solves += reqs.len() as u64;
        let jobs: Vec<BatchJob> = reqs
            .iter()
            .map(|r| BatchJob { y: r.problem.y(), bits_y, seed: r.seed })
            .collect();
        let results = solve_batch_lockstep(
            &prepared,
            &jobs,
            reqs[0].problem.s(),
            opts,
            &mut |j, st| observer.on_iteration(j, st),
        );
        results.into_iter().map(Ok).collect()
    }

    fn metrics(&self) -> EngineMetrics {
        self.metrics
    }
}

/// PJRT quantized engine: executes the `qniht_step`/`apply_step` AOT
/// artifacts. The runtime (and its compiled-executable cache) is created
/// on first use and lives as long as the engine — i.e. as long as the
/// owning worker thread's registry.
pub struct XlaQuantEngine {
    artifact_dir: PathBuf,
    rt: Option<Runtime>,
    metrics: EngineMetrics,
}

impl Engine for XlaQuantEngine {
    fn name(&self) -> &'static str {
        "xla-quant"
    }

    fn solve(
        &mut self,
        req: &SolveRequest,
        opts: &SolveOptions,
        observer: &mut dyn IterObserver,
    ) -> Result<SolveResult> {
        let SolverKind::Qniht { bits_phi, bits_y, mode } = req.solver else {
            return Err(anyhow!(
                "engine 'xla-quant' runs solver 'qniht' only, got '{}'",
                req.solver.name()
            ));
        };
        anyhow::ensure!(
            mode == RequantMode::Fixed,
            "the XLA engine quantizes once (Fixed mode); Fresh re-quantization is native-only"
        );
        let tag = req
            .problem
            .shape_tag()
            .ok_or_else(|| anyhow!("XLA engine requires a shape tag"))?;
        let phi = req
            .problem
            .as_mat()
            .ok_or_else(|| anyhow!("XLA engine requires an explicit measurement matrix"))?;
        let rt = Runtime::ensure(&mut self.rt, &self.artifact_dir)?;
        let mut k =
            XlaQuantKernel::with_runtime(rt, tag, phi, req.problem.y(), bits_phi, bits_y, req.seed)?;
        anyhow::ensure!(
            k.artifact_s() == req.problem.s(),
            "artifact '{tag}' is specialized to s={}, problem has s={}",
            k.artifact_s(),
            req.problem.s()
        );
        self.metrics.solves += 1;
        self.metrics.phi_quantizations += 1;
        Ok(solve_observed(&mut k, req.problem.s(), opts, observer))
    }

    fn metrics(&self) -> EngineMetrics {
        self.metrics
    }
}

/// PJRT dense engine: the 32-bit baseline through the `niht_step_f32`
/// artifacts.
pub struct XlaDenseEngine {
    artifact_dir: PathBuf,
    rt: Option<Runtime>,
    metrics: EngineMetrics,
}

impl Engine for XlaDenseEngine {
    fn name(&self) -> &'static str {
        "xla-dense"
    }

    fn solve(
        &mut self,
        req: &SolveRequest,
        opts: &SolveOptions,
        observer: &mut dyn IterObserver,
    ) -> Result<SolveResult> {
        anyhow::ensure!(
            matches!(req.solver, SolverKind::Niht),
            "engine 'xla-dense' runs solver 'niht' only, got '{}'",
            req.solver.name()
        );
        let tag = req
            .problem
            .shape_tag()
            .ok_or_else(|| anyhow!("XLA engine requires a shape tag"))?;
        let phi = req
            .problem
            .as_mat()
            .ok_or_else(|| anyhow!("XLA engine requires an explicit measurement matrix"))?;
        let rt = Runtime::ensure(&mut self.rt, &self.artifact_dir)?;
        let mut k = XlaDenseKernel::with_runtime(rt, tag, phi, req.problem.y())?;
        anyhow::ensure!(
            k.artifact_s() == req.problem.s(),
            "artifact '{tag}' is specialized to s={}, problem has s={}",
            k.artifact_s(),
            req.problem.s()
        );
        self.metrics.solves += 1;
        Ok(solve_observed(&mut k, req.problem.s(), opts, observer))
    }

    fn metrics(&self) -> EngineMetrics {
        self.metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::NoopObserver;
    use crate::linalg::Mat;

    #[test]
    fn default_registry_knows_all_engine_kinds() {
        let reg = EngineRegistry::with_defaults(PathBuf::from("artifacts"));
        let names = reg.names();
        for kind in [
            EngineKind::NativeDense,
            EngineKind::NativeQuant,
            EngineKind::XlaQuant,
            EngineKind::XlaDense,
            EngineKind::FpgaModel,
        ] {
            assert!(names.iter().any(|n| n == kind.name()), "missing {}", kind.name());
        }
    }

    #[test]
    fn unknown_engine_is_a_clean_error() {
        let mut reg = EngineRegistry::with_defaults(PathBuf::from("artifacts"));
        let err = reg.engine_mut("warp-drive").unwrap_err().to_string();
        assert!(err.contains("unknown engine 'warp-drive'"), "{err}");
        assert!(err.contains("native-dense"), "error lists known engines: {err}");
    }

    #[test]
    fn register_replaces_and_extends() {
        struct Stub;
        impl Engine for Stub {
            fn name(&self) -> &'static str {
                "stub"
            }
            fn solve(
                &mut self,
                _req: &SolveRequest,
                _opts: &SolveOptions,
                _obs: &mut dyn IterObserver,
            ) -> Result<SolveResult> {
                Ok(SolveResult {
                    x: vec![42.0],
                    iterations: 0,
                    converged: true,
                    shrink_events: 0,
                    history: vec![],
                })
            }
        }
        let mut reg = EngineRegistry::new(PathBuf::from("artifacts"));
        reg.register("stub", Box::new(|_: &EngineContext| Box::new(Stub) as Box<dyn Engine>));
        let req = SolveRequest {
            problem: Problem::from_mat(Mat::zeros(1, 1), vec![0.0], 1),
            solver: SolverKind::Niht,
            seed: 0,
        };
        let r = reg
            .solve("stub", &req, &SolveOptions::default(), &mut NoopObserver)
            .unwrap();
        assert_eq!(r.x, vec![42.0]);
    }

    #[test]
    fn engine_rejects_mismatched_solver() {
        let mut reg = EngineRegistry::with_defaults(PathBuf::from("artifacts"));
        let req = SolveRequest {
            problem: Problem::from_mat(Mat::zeros(2, 4), vec![0.0; 2], 1),
            solver: SolverKind::qniht_fixed(8, 8),
            seed: 0,
        };
        let err = reg
            .solve("native-dense", &req, &SolveOptions::default(), &mut NoopObserver)
            .unwrap_err()
            .to_string();
        assert!(err.contains("quantized engine"), "{err}");
    }
}
