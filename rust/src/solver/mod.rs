//! # The unified recovery facade
//!
//! One stable API for every recovery path in the crate: the coordinator,
//! the repro figures, the examples and the benches all route through it,
//! so new solvers and engines plug in without touching any caller.
//!
//! The pieces:
//! * [`Problem`] — Φ (behind a [`MeasurementOp`]) + y + sparsity +
//!   optional AOT shape tag.
//! * [`SolverKind`] / [`SparseSolver`] — the algorithm: NIHT, IHT, QNIHT
//!   (Fixed/Fresh), CoSaMP, FISTA, or a caller-supplied implementation.
//! * [`EngineRegistry`] / [`Engine`] — the execution substrate: dense f32
//!   native, quantized native (with batched quantize+pack amortization),
//!   the PJRT/XLA artifact engines, or [`FpgaModelEngine`]
//!   (`"fpga-model"`: the same quantized solve billed at the paper's §8
//!   FPGA bandwidth-model rates). Name → factory, so custom engines
//!   register without serving-layer changes.
//! * [`Recovery`] — the builder tying it together.
//! * [`SolveReport`] — the unified result (iterate, convergence,
//!   per-iteration history, solver/engine labels, wall time).
//!
//! The 3-line happy path:
//!
//! ```no_run
//! # use lpcs::solver::{Problem, Recovery, SolverKind};
//! # use std::sync::Arc;
//! # let (phi, y, s) = (Arc::new(lpcs::Mat::zeros(4, 8)), vec![0.0f32; 4], 2);
//! let problem = Problem::new(phi, y, s);
//! let report = Recovery::problem(problem).solver(SolverKind::qniht_fixed(2, 8)).run().unwrap();
//! println!("recovered in {} iterations on {}", report.iterations, report.engine);
//! ```
//!
//! Per-iteration streaming and cancellation go through
//! [`crate::algorithms::IterObserver`]:
//!
//! ```no_run
//! # use lpcs::solver::{Problem, Recovery, SolverKind};
//! # use lpcs::algorithms::{IterStat, ObserverSignal};
//! # use std::sync::Arc;
//! # let problem = Problem::new(Arc::new(lpcs::Mat::zeros(4, 8)), vec![0.0f32; 4], 2);
//! let mut stop_when_flat = |st: &IterStat| {
//!     if st.resid_nsq < 1e-9 { ObserverSignal::Stop } else { ObserverSignal::Continue }
//! };
//! let report = Recovery::problem(problem)
//!     .solver(SolverKind::Niht)
//!     .observer(&mut stop_when_flat)
//!     .run()
//!     .unwrap();
//! # let _ = report;
//! ```

pub mod fpga;
pub mod problem;
pub mod registry;
pub mod solvers;

pub use fpga::FpgaModelEngine;
pub use problem::{MeasurementOp, OpKernel, Problem};
pub use registry::{
    BatchObserver, Engine, EngineContext, EngineFactory, EngineMetrics, EngineRegistry,
    NoopBatchObserver, SolveRequest,
};
pub use solvers::{
    CosampSolver, FistaSolver, IhtSolver, NihtSolver, QnihtSolver, SolverKey, SolverKind,
    SparseSolver,
};

use crate::algorithms::{IterObserver, IterStat, ObserverSignal, SolveOptions, SolveResult};
use crate::config::EngineKind;
use anyhow::Result;
use std::path::PathBuf;
use std::time::Duration;

/// The unified result every recovery path returns.
#[derive(Debug, Clone)]
pub struct SolveReport {
    /// The recovered (s-sparse) iterate.
    pub x: Vec<f32>,
    pub iterations: usize,
    pub converged: bool,
    /// True when an observer cancelled the solve early.
    pub stopped_early: bool,
    /// Total μ-shrinkage events (NIHT-family line search; 0 otherwise).
    pub shrink_events: usize,
    /// Per-iteration stats (when `SolveOptions::track_history` is set).
    pub history: Vec<IterStat>,
    /// Solver name ("niht", "qniht", ...).
    pub solver: String,
    /// Engine name the solve executed on ("native-dense", ...).
    pub engine: String,
    /// Wall time of the solve (excluding problem construction).
    pub wall: Duration,
    /// Modeled device time, when the engine bills one (the
    /// `"fpga-model"` engine charges `iterations ×`
    /// [`crate::perfmodel::fpga::FpgaModel::iteration_time`]).
    pub modeled: Option<Duration>,
}

impl SolveReport {
    pub fn from_result(
        result: SolveResult,
        solver: impl Into<String>,
        engine: impl Into<String>,
        stopped_early: bool,
        wall: Duration,
    ) -> Self {
        Self {
            x: result.x,
            iterations: result.iterations,
            converged: result.converged,
            stopped_early,
            shrink_events: result.shrink_events,
            history: result.history,
            solver: solver.into(),
            engine: engine.into(),
            wall,
            modeled: None,
        }
    }
}

/// Wraps the caller's observer so the facade can tell whether the solve
/// was cancelled (the underlying `SolveResult` only records
/// `converged = false`).
struct StopTracker<'a> {
    inner: Option<&'a mut dyn IterObserver>,
    stopped: bool,
}

impl IterObserver for StopTracker<'_> {
    fn on_iteration(&mut self, stat: &IterStat) -> ObserverSignal {
        if let Some(inner) = self.inner.as_mut() {
            if inner.on_iteration(stat) == ObserverSignal::Stop {
                self.stopped = true;
                return ObserverSignal::Stop;
            }
        }
        ObserverSignal::Continue
    }
}

/// Adapts a scalar [`IterObserver`] to the [`BatchObserver`] interface a
/// singleton `solve_batch` dispatch takes (the batch index is always 0).
struct ScalarBatchObserver<'a>(&'a mut dyn IterObserver);

impl BatchObserver for ScalarBatchObserver<'_> {
    fn on_iteration(&mut self, _job_index: usize, stat: &IterStat) -> ObserverSignal {
        self.0.on_iteration(stat)
    }
}

/// Builder for one recovery: problem → solver → engine → observer → run.
///
/// Defaults: solver [`SolverKind::Niht`], the solver's natural engine
/// ([`SolverKind::default_engine`]), default [`SolveOptions`], seed 0,
/// artifact dir `"artifacts"`, no observer, a fresh one-shot registry.
/// Long-lived callers (the coordinator's workers) pass their own registry
/// via [`Recovery::registry`] to reuse engine state across solves.
pub struct Recovery<'a> {
    problem: Problem,
    solver: SolverKind,
    engine: Option<String>,
    opts: SolveOptions,
    seed: u64,
    artifact_dir: PathBuf,
    observer: Option<&'a mut dyn IterObserver>,
    registry: Option<&'a mut EngineRegistry>,
    batched: bool,
}

impl<'a> Recovery<'a> {
    pub fn problem(problem: Problem) -> Self {
        Self {
            problem,
            solver: SolverKind::Niht,
            engine: None,
            opts: SolveOptions::default(),
            seed: 0,
            artifact_dir: PathBuf::from("artifacts"),
            observer: None,
            registry: None,
            batched: false,
        }
    }

    pub fn solver(mut self, solver: SolverKind) -> Self {
        self.solver = solver;
        self
    }

    /// Pick one of the built-in engines.
    pub fn engine(mut self, engine: EngineKind) -> Self {
        self.engine = Some(engine.name().to_string());
        self
    }

    /// Pick an engine by registry name (custom engines).
    pub fn engine_named(mut self, name: impl Into<String>) -> Self {
        self.engine = Some(name.into());
        self
    }

    pub fn options(mut self, opts: SolveOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Seed for stochastic quantization (ignored by dense solvers).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Where the XLA engines find their AOT artifacts.
    pub fn artifact_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifact_dir = dir.into();
        self
    }

    /// Per-iteration observer (progress streaming / early cancellation).
    pub fn observer(mut self, observer: &'a mut dyn IterObserver) -> Self {
        self.observer = Some(observer);
        self
    }

    /// Run against an existing registry (reuses engine caches).
    pub fn registry(mut self, registry: &'a mut EngineRegistry) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Dispatch through the engine's *batched* path (a singleton batch),
    /// exactly as [`crate::coordinator::RecoveryService`] does. For the
    /// quantized engines this takes the amortized quantize+pack path with
    /// its canonical per-(Φ, bits) quantization seed, so the result is
    /// bit-identical to what the service returns for the same spec — and
    /// (deliberately) NOT to the direct `qniht()` kernel call, which
    /// seeds the Φ quantization from the job seed. The conformance matrix
    /// in `tests/service_matrix.rs` pins the two paths together.
    pub fn service_dispatch(mut self) -> Self {
        self.batched = true;
        self
    }

    /// Execute and return the unified report.
    pub fn run(self) -> Result<SolveReport> {
        let engine_name = self
            .engine
            .unwrap_or_else(|| self.solver.default_engine().name().to_string());
        let req = SolveRequest { problem: self.problem, solver: self.solver, seed: self.seed };
        let mut tracker = StopTracker { inner: self.observer, stopped: false };
        let mut owned;
        let registry = match self.registry {
            Some(registry) => registry,
            None => {
                owned = EngineRegistry::with_defaults(self.artifact_dir);
                &mut owned
            }
        };
        let modeled_before =
            registry.metrics(&engine_name).map(|m| m.modeled_time_us).unwrap_or(0);
        let t0 = std::time::Instant::now();
        let result = if self.batched {
            let mut results = registry.solve_batch(
                &engine_name,
                std::slice::from_ref(&req),
                &self.opts,
                &mut ScalarBatchObserver(&mut tracker),
            )?;
            results.pop().expect("one request yields one result")?
        } else {
            registry.solve(&engine_name, &req, &self.opts, &mut tracker)?
        };
        let wall = t0.elapsed();
        let modeled_after =
            registry.metrics(&engine_name).map(|m| m.modeled_time_us).unwrap_or(0);
        let mut report = SolveReport::from_result(
            result,
            self.solver.name(),
            engine_name,
            tracker.stopped,
            wall,
        );
        if modeled_after > modeled_before {
            report.modeled = Some(Duration::from_micros(modeled_after - modeled_before));
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::support::support_of;
    use crate::linalg::Mat;
    use crate::rng::XorShift128Plus;
    use std::sync::Arc;

    fn planted(m: usize, n: usize, s: usize, seed: u64) -> (Problem, Vec<f32>) {
        let mut rng = XorShift128Plus::new(seed);
        let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
        let mut x = vec![0.0f32; n];
        for i in rng.choose_k(n, s) {
            x[i] = 2.0 * rng.gaussian_f32().signum();
        }
        let y = phi.matvec(&x);
        (Problem::new(Arc::new(phi), y, s), x)
    }

    #[test]
    fn builder_happy_path_recovers() {
        let (problem, x_true) = planted(64, 128, 4, 1);
        let report = Recovery::problem(problem).run().unwrap();
        assert_eq!(report.solver, "niht");
        assert_eq!(report.engine, "native-dense");
        assert!(report.converged);
        assert!(!report.stopped_early);
        assert_eq!(support_of(&report.x), support_of(&x_true));
    }

    #[test]
    fn qniht_defaults_to_quant_engine() {
        let (problem, x_true) = planted(96, 192, 5, 2);
        let report = Recovery::problem(problem)
            .solver(SolverKind::qniht_fixed(8, 8))
            .seed(3)
            .run()
            .unwrap();
        assert_eq!(report.engine, "native-quant");
        assert_eq!(support_of(&report.x), support_of(&x_true));
    }

    #[test]
    fn invalid_problem_is_rejected_before_dispatch() {
        let problem = Problem::from_mat(Mat::zeros(4, 8), vec![0.0; 3], 2);
        assert!(Recovery::problem(problem).run().is_err());
    }

    #[test]
    fn fpga_model_engine_reports_modeled_time() {
        let (problem, x_true) = planted(96, 192, 5, 6);
        let report = Recovery::problem(problem)
            .solver(SolverKind::qniht_fixed(8, 8))
            .engine(EngineKind::FpgaModel)
            .seed(1)
            .run()
            .unwrap();
        assert_eq!(report.engine, "fpga-model");
        let modeled = report.modeled.expect("fpga-model bills modeled time");
        assert!(modeled.as_micros() > 0);
        assert_eq!(support_of(&report.x), support_of(&x_true));
    }

    #[test]
    fn report_history_tracks_when_asked() {
        let (problem, _) = planted(64, 128, 4, 4);
        let report = Recovery::problem(problem)
            .options(SolveOptions::default().with_track_history(true))
            .run()
            .unwrap();
        assert_eq!(report.history.len(), report.iterations);
    }
}
