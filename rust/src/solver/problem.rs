//! The problem side of the facade: a [`MeasurementOp`] abstraction over
//! "something that applies Φ", and the [`Problem`] bundle (Φ + y +
//! sparsity + optional artifact shape tag) every solver and engine
//! consumes.

use crate::algorithms::support::{hard_threshold, support_of, top_s_indices};
use crate::algorithms::{NihtKernel, StepOut};
use crate::linalg::{self, Mat};
use anyhow::Result;
use std::sync::Arc;

/// A measurement operator: the three products every recovery algorithm in
/// this crate needs. Implemented by [`Mat`] (the common, explicit-matrix
/// case) and implementable by callers for matrix-free operators (e.g. an
/// FFT-based Φ) — those route through the generic [`OpKernel`] driver.
pub trait MeasurementOp: Send + Sync {
    /// Rows of Φ (observation length).
    fn m(&self) -> usize;

    /// Columns of Φ (signal length).
    fn n(&self) -> usize;

    /// `Φ x`.
    fn apply(&self, x: &[f32]) -> Vec<f32>;

    /// `Φᵀ r`.
    fn apply_t(&self, r: &[f32]) -> Vec<f32>;

    /// `Φ x` for a sparse x given as (indices, values). The default
    /// scatters into a dense vector and calls [`MeasurementOp::apply`];
    /// operators with a cheaper column-restricted product should override.
    fn apply_sparse(&self, idx: &[usize], vals: &[f32]) -> Vec<f32> {
        let mut x = vec![0.0f32; self.n()];
        for (&i, &v) in idx.iter().zip(vals) {
            x[i] = v;
        }
        self.apply(&x)
    }

    /// The explicit matrix behind this operator, when there is one.
    /// Engines that must see entries (quantization, PJRT upload, the
    /// SVD-based baselines) require this; matrix-free operators return
    /// `None` and are served by the dense-f32 NIHT path only.
    fn as_mat(&self) -> Option<&Mat> {
        None
    }
}

impl MeasurementOp for Mat {
    fn m(&self) -> usize {
        self.rows
    }

    fn n(&self) -> usize {
        self.cols
    }

    fn apply(&self, x: &[f32]) -> Vec<f32> {
        self.matvec(x)
    }

    fn apply_t(&self, r: &[f32]) -> Vec<f32> {
        self.matvec_t(r)
    }

    fn apply_sparse(&self, idx: &[usize], vals: &[f32]) -> Vec<f32> {
        self.matvec_sparse(idx, vals)
    }

    fn as_mat(&self) -> Option<&Mat> {
        Some(self)
    }
}

/// One recovery problem: recover an `s`-sparse x from `y ≈ Φx`.
///
/// Φ is held behind an `Arc` so cloning a `Problem` (e.g. for an
/// iteration-budget sweep) never copies the matrix, and so the
/// coordinator can recognize jobs sharing Φ by pointer identity.
#[derive(Clone)]
pub struct Problem {
    op: Arc<dyn MeasurementOp>,
    y: Vec<f32>,
    s: usize,
    shape_tag: Option<String>,
}

impl Problem {
    /// The common case: an explicit measurement matrix.
    pub fn new(phi: Arc<Mat>, y: Vec<f32>, s: usize) -> Self {
        Self { op: phi, y, s, shape_tag: None }
    }

    /// Convenience: wrap an owned matrix.
    pub fn from_mat(phi: Mat, y: Vec<f32>, s: usize) -> Self {
        Self::new(Arc::new(phi), y, s)
    }

    /// A matrix-free (or otherwise custom) measurement operator.
    pub fn with_op(op: Arc<dyn MeasurementOp>, y: Vec<f32>, s: usize) -> Self {
        Self { op, y, s, shape_tag: None }
    }

    /// Tag this problem with an AOT artifact shape (required by the XLA
    /// engines, ignored by the native ones).
    pub fn with_shape_tag(mut self, tag: impl Into<String>) -> Self {
        self.shape_tag = Some(tag.into());
        self
    }

    pub fn op(&self) -> &dyn MeasurementOp {
        &*self.op
    }

    /// The explicit matrix, when the operator has one.
    pub fn as_mat(&self) -> Option<&Mat> {
        self.op.as_mat()
    }

    pub fn y(&self) -> &[f32] {
        &self.y
    }

    pub fn s(&self) -> usize {
        self.s
    }

    pub fn m(&self) -> usize {
        self.op.m()
    }

    pub fn n(&self) -> usize {
        self.op.n()
    }

    pub fn shape_tag(&self) -> Option<&str> {
        self.shape_tag.as_deref()
    }

    /// Whether two problems share the same operator instance (the
    /// coordinator's batch-amortization criterion).
    pub fn shares_op(&self, other: &Problem) -> bool {
        Arc::ptr_eq(&self.op, &other.op)
    }

    /// Cross-field invariants, checked once at the facade boundary.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.y.len() == self.op.m(),
            "y length {} does not match operator rows {}",
            self.y.len(),
            self.op.m()
        );
        anyhow::ensure!(self.s >= 1, "sparsity must be >= 1");
        anyhow::ensure!(
            self.s <= self.op.n(),
            "sparsity {} exceeds signal dimension {}",
            self.s,
            self.op.n()
        );
        Ok(())
    }
}

/// Dense-f32 NIHT step engine over any [`MeasurementOp`] — the same math
/// as `niht::DenseKernel`, reached through the operator trait so
/// matrix-free problems run under the unchanged Algorithm-1 driver.
pub struct OpKernel<'a> {
    op: &'a dyn MeasurementOp,
    y: &'a [f32],
}

impl<'a> OpKernel<'a> {
    pub fn new(op: &'a dyn MeasurementOp, y: &'a [f32]) -> Self {
        assert_eq!(op.m(), y.len());
        Self { op, y }
    }

    fn gradient(&self, x: &[f32]) -> (Vec<f32>, f32) {
        let yx = self.op.apply(x);
        let r: Vec<f32> = self.y.iter().zip(&yx).map(|(a, b)| a - b).collect();
        let g = self.op.apply_t(&r);
        let rn = linalg::norm2_sq(&r);
        (g, rn)
    }
}

impl NihtKernel for OpKernel<'_> {
    fn m(&self) -> usize {
        self.op.m()
    }

    fn n(&self) -> usize {
        self.op.n()
    }

    fn full_step(&mut self, x: &[f32], s: usize) -> StepOut {
        let (g, resid_nsq) = self.gradient(x);
        let supp = if x.iter().any(|&v| v != 0.0) {
            support_of(x)
        } else {
            top_s_indices(&g, s)
        };
        // Masked-vector norm, exactly as `DenseKernel` computes it, so an
        // op backed by a Mat reproduces the dense trajectory bit-for-bit.
        let mut g_m = vec![0.0f32; g.len()];
        for &i in &supp {
            g_m[i] = g[i];
        }
        let num = linalg::norm2_sq(&g_m);
        let vals: Vec<f32> = supp.iter().map(|&i| g[i]).collect();
        let pg = self.op.apply_sparse(&supp, &vals);
        let den = linalg::norm2_sq(&pg);
        let mu = num / den.max(f32::MIN_POSITIVE);
        let (x_next, dx_nsq, phi1_dx_nsq) = self.apply_step(x, &g, mu, s);
        StepOut { x_next, g, mu, dx_nsq, phi1_dx_nsq, resid_nsq }
    }

    fn apply_step(&mut self, x: &[f32], g: &[f32], mu: f32, s: usize) -> (Vec<f32>, f32, f32) {
        let a: Vec<f32> = x.iter().zip(g).map(|(xi, gi)| xi + mu * gi).collect();
        let x_next = hard_threshold(&a, s);
        let dx: Vec<f32> = x_next.iter().zip(x).map(|(a, b)| a - b).collect();
        let dx_nsq = linalg::norm2_sq(&dx);
        let idx = support_of(&dx);
        let vals: Vec<f32> = idx.iter().map(|&i| dx[i]).collect();
        let phi_dx = self.op.apply_sparse(&idx, &vals);
        (x_next, dx_nsq, linalg::norm2_sq(&phi_dx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_is_a_measurement_op() {
        let phi = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f32);
        let x = vec![1.0, 0.0, -1.0, 2.0];
        assert_eq!(phi.apply(&x), phi.matvec(&x));
        assert_eq!(phi.apply_t(&[1.0, 2.0, 3.0]), phi.matvec_t(&[1.0, 2.0, 3.0]));
        assert!(phi.as_mat().is_some());
        assert_eq!((MeasurementOp::m(&phi), MeasurementOp::n(&phi)), (3, 4));
    }

    #[test]
    fn default_apply_sparse_matches_dense_apply() {
        struct Blind(Mat);
        impl MeasurementOp for Blind {
            fn m(&self) -> usize {
                self.0.rows
            }
            fn n(&self) -> usize {
                self.0.cols
            }
            fn apply(&self, x: &[f32]) -> Vec<f32> {
                self.0.matvec(x)
            }
            fn apply_t(&self, r: &[f32]) -> Vec<f32> {
                self.0.matvec_t(r)
            }
        }
        let phi = Mat::from_fn(5, 8, |i, j| ((i + 2 * j) % 5) as f32 - 2.0);
        let op = Blind(phi.clone());
        let got = op.apply_sparse(&[1, 6], &[2.0, -1.0]);
        let mut x = vec![0.0f32; 8];
        x[1] = 2.0;
        x[6] = -1.0;
        assert_eq!(got, phi.matvec(&x));
        assert!(op.as_mat().is_none());
    }

    #[test]
    fn problem_validates() {
        let phi = Arc::new(Mat::zeros(4, 8));
        assert!(Problem::new(phi.clone(), vec![0.0; 4], 2).validate().is_ok());
        assert!(Problem::new(phi.clone(), vec![0.0; 3], 2).validate().is_err());
        assert!(Problem::new(phi.clone(), vec![0.0; 4], 0).validate().is_err());
        assert!(Problem::new(phi, vec![0.0; 4], 9).validate().is_err());
    }

    #[test]
    fn shares_op_is_pointer_identity() {
        let phi = Arc::new(Mat::zeros(4, 8));
        let a = Problem::new(phi.clone(), vec![0.0; 4], 2);
        let b = Problem::new(phi, vec![1.0; 4], 2);
        let c = Problem::new(Arc::new(Mat::zeros(4, 8)), vec![0.0; 4], 2);
        assert!(a.shares_op(&b));
        assert!(a.shares_op(&a.clone()), "clones share the operator");
        assert!(!a.shares_op(&c));
    }
}
