//! The algorithm side of the facade: [`SolverKind`] (the serializable
//! selector the builder and the coordinator use) and the [`SparseSolver`]
//! adapters wrapping the native implementations of NIHT, IHT, QNIHT
//! (Fixed/Fresh), CoSaMP and FISTA behind one interface.

use crate::algorithms::fista::{fista_observed, FistaOptions};
use crate::algorithms::niht::solve_observed;
use crate::algorithms::qniht::{QuantKernel, RequantMode};
use crate::algorithms::{cosamp, iht, IterObserver, SolveOptions, SolveResult};
use crate::config::EngineKind;
use anyhow::{anyhow, Result};

use super::problem::{OpKernel, Problem};

/// Which recovery algorithm to run. `Qniht` carries the full quantization
/// configuration, so a `SolverKind` plus a [`Problem`] is a complete,
/// copyable description of a solve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SolverKind {
    /// Normalized IHT on dense f32 operands (the 32-bit baseline).
    Niht,
    /// Plain IHT with internal rescaling (classical baseline).
    Iht,
    /// The paper's quantized NIHT: Φ at `bits_phi`, y at `bits_y`,
    /// Fixed (systems) or Fresh (theory) re-quantization.
    Qniht { bits_phi: u8, bits_y: u8, mode: RequantMode },
    /// Compressive Sampling Matching Pursuit (greedy baseline).
    Cosamp,
    /// FISTA ℓ₁ baseline. The facade prunes the iterate to the problem's
    /// sparsity and debiases per `debias`, so its report is support-
    /// comparable with the greedy methods.
    Fista { lambda: Option<f32>, debias: bool },
}

impl SolverKind {
    /// Paper-headline QNIHT configuration (Fixed 2&8-bit).
    pub fn qniht_fixed(bits_phi: u8, bits_y: u8) -> Self {
        Self::Qniht { bits_phi, bits_y, mode: RequantMode::Fixed }
    }

    /// Theory-mode QNIHT (fresh stochastic quantizations per iteration).
    pub fn qniht_fresh(bits_phi: u8, bits_y: u8) -> Self {
        Self::Qniht { bits_phi, bits_y, mode: RequantMode::Fresh }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Niht => "niht",
            Self::Iht => "iht",
            Self::Qniht { .. } => "qniht",
            Self::Cosamp => "cosamp",
            Self::Fista { .. } => "fista",
        }
    }

    /// The engine a [`super::Recovery`] uses when the caller names none:
    /// quantized solvers run on the quantized-native engine, everything
    /// else on the dense-native one.
    pub fn default_engine(&self) -> EngineKind {
        match self {
            Self::Qniht { .. } => EngineKind::NativeQuant,
            _ => EngineKind::NativeDense,
        }
    }

    /// The native [`SparseSolver`] adapter for this kind (`seed` feeds the
    /// stochastic quantization; ignored by the deterministic baselines).
    pub fn native_solver(&self, seed: u64) -> Box<dyn SparseSolver> {
        match *self {
            Self::Niht => Box::new(NihtSolver),
            Self::Iht => Box::new(IhtSolver),
            Self::Qniht { bits_phi, bits_y, mode } =>
                Box::new(QnihtSolver { bits_phi, bits_y, mode, seed }),
            Self::Cosamp => Box::new(CosampSolver),
            Self::Fista { lambda, debias } => Box::new(FistaSolver { lambda, debias }),
        }
    }

    /// Hashable fingerprint of this kind (f32 parameters bit-cast) — what
    /// the coordinator folds into its `BatchKey`.
    pub fn key(&self) -> SolverKey {
        match *self {
            Self::Niht => SolverKey::Niht,
            Self::Iht => SolverKey::Iht,
            Self::Qniht { bits_phi, bits_y, mode } => SolverKey::Qniht { bits_phi, bits_y, mode },
            Self::Cosamp => SolverKey::Cosamp,
            Self::Fista { lambda, debias } => {
                SolverKey::Fista { lambda_bits: lambda.map(f32::to_bits), debias }
            }
        }
    }

    /// Serving-layer bit-width gate: the service packs Φ̂/ŷ, so QNIHT is
    /// servable at the packed widths {2, 4, 8} only (the unpacked
    /// kernels accept any width in 2..=8 for direct solves). One shared
    /// check so `JobSpec::validate` and the serve CLI can never drift.
    pub fn check_packed_bits(&self) -> Result<()> {
        if let Self::Qniht { bits_phi, bits_y, .. } = *self {
            for (what, bits) in [("bits_phi", bits_phi), ("bits_y", bits_y)] {
                anyhow::ensure!(
                    matches!(bits, 2 | 4 | 8),
                    "{what} = {bits} is not servable (packed widths: 2, 4, 8)"
                );
            }
        }
        Ok(())
    }

    /// Whether `engine` can execute this solver. Mirrors the engines' own
    /// dispatch-time checks, so a mismatched job fails at submit time
    /// instead of deep inside a batch solve.
    pub fn runs_on(&self, engine: EngineKind) -> bool {
        match engine {
            EngineKind::NativeDense => !matches!(self, Self::Qniht { .. }),
            EngineKind::NativeQuant | EngineKind::FpgaModel => matches!(self, Self::Qniht { .. }),
            // The XLA quant artifacts quantize once: Fixed mode only.
            EngineKind::XlaQuant => {
                matches!(self, Self::Qniht { mode: RequantMode::Fixed, .. })
            }
            EngineKind::XlaDense => matches!(self, Self::Niht),
        }
    }
}

/// Hashable, `Eq` fingerprint of a [`SolverKind`] (`Fista`'s `f32`
/// parameter is bit-cast). Two kinds with equal keys run identical
/// configurations, so the coordinator batches on it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SolverKey {
    Niht,
    Iht,
    Qniht { bits_phi: u8, bits_y: u8, mode: RequantMode },
    Cosamp,
    Fista { lambda_bits: Option<u32>, debias: bool },
}

/// A sparse-recovery algorithm behind the facade: consumes a [`Problem`],
/// produces a [`crate::algorithms::SolveResult`], and reports every outer
/// iteration to the observer. Implement this (or register an engine) to
/// plug a new method into the facade without touching the serving layer.
pub trait SparseSolver {
    fn name(&self) -> &'static str;

    fn solve(
        &mut self,
        problem: &Problem,
        opts: &SolveOptions,
        observer: &mut dyn IterObserver,
    ) -> Result<SolveResult>;
}

fn require_mat<'a>(problem: &'a Problem, who: &str) -> Result<&'a crate::linalg::Mat> {
    problem.as_mat().ok_or_else(|| {
        anyhow!("{who} requires an explicit measurement matrix (matrix-free operators run via SolverKind::Niht)")
    })
}

/// Normalized IHT, dense f32 (the 32-bit baseline), over the generic
/// [`OpKernel`]. For an explicit matrix this computes exactly what
/// `niht::DenseKernel` computes (same products, same reduction order), so
/// facade results stay bit-identical with `niht::niht_dense` — the
/// dispatch-parity test in `tests/solver_facade.rs` pins the two
/// implementations together.
pub struct NihtSolver;

impl SparseSolver for NihtSolver {
    fn name(&self) -> &'static str {
        "niht"
    }

    fn solve(
        &mut self,
        problem: &Problem,
        opts: &SolveOptions,
        observer: &mut dyn IterObserver,
    ) -> Result<SolveResult> {
        let mut k = OpKernel::new(problem.op(), problem.y());
        Ok(solve_observed(&mut k, problem.s(), opts, observer))
    }
}

/// Plain IHT (unit step, internal spectral rescaling).
pub struct IhtSolver;

impl SparseSolver for IhtSolver {
    fn name(&self) -> &'static str {
        "iht"
    }

    fn solve(
        &mut self,
        problem: &Problem,
        opts: &SolveOptions,
        observer: &mut dyn IterObserver,
    ) -> Result<SolveResult> {
        let phi = require_mat(problem, "iht")?;
        Ok(iht::iht_observed(phi, problem.y(), problem.s(), opts, observer))
    }
}

/// The paper's QNIHT on the native quantized kernels.
pub struct QnihtSolver {
    pub bits_phi: u8,
    pub bits_y: u8,
    pub mode: RequantMode,
    pub seed: u64,
}

impl SparseSolver for QnihtSolver {
    fn name(&self) -> &'static str {
        "qniht"
    }

    fn solve(
        &mut self,
        problem: &Problem,
        opts: &SolveOptions,
        observer: &mut dyn IterObserver,
    ) -> Result<SolveResult> {
        let phi = require_mat(problem, "qniht")?;
        let mut k =
            QuantKernel::new(phi, problem.y(), self.bits_phi, self.bits_y, self.mode, self.seed);
        Ok(solve_observed(&mut k, problem.s(), opts, observer))
    }
}

/// CoSaMP greedy baseline.
pub struct CosampSolver;

impl SparseSolver for CosampSolver {
    fn name(&self) -> &'static str {
        "cosamp"
    }

    fn solve(
        &mut self,
        problem: &Problem,
        opts: &SolveOptions,
        observer: &mut dyn IterObserver,
    ) -> Result<SolveResult> {
        let phi = require_mat(problem, "cosamp")?;
        Ok(cosamp::cosamp_observed(phi, problem.y(), problem.s(), opts, observer))
    }
}

/// FISTA ℓ₁ baseline, pruned to the problem sparsity for support metrics.
pub struct FistaSolver {
    pub lambda: Option<f32>,
    pub debias: bool,
}

impl SparseSolver for FistaSolver {
    fn name(&self) -> &'static str {
        "fista"
    }

    fn solve(
        &mut self,
        problem: &Problem,
        opts: &SolveOptions,
        observer: &mut dyn IterObserver,
    ) -> Result<SolveResult> {
        let phi = require_mat(problem, "fista")?;
        let fopts = FistaOptions {
            lambda: self.lambda,
            debias: self.debias,
            prune_to: Some(problem.s()),
        };
        Ok(fista_observed(phi, problem.y(), opts, &fopts, observer))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::support::support_of;
    use crate::algorithms::NoopObserver;
    use crate::linalg::Mat;
    use crate::rng::XorShift128Plus;
    use std::sync::Arc;

    fn planted(m: usize, n: usize, s: usize, seed: u64) -> (Problem, Vec<f32>) {
        let mut rng = XorShift128Plus::new(seed);
        let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
        let mut x = vec![0.0f32; n];
        for i in rng.choose_k(n, s) {
            x[i] = 2.0 * rng.gaussian_f32().signum() + 0.3 * rng.gaussian_f32();
        }
        let y = phi.matvec(&x);
        (Problem::new(Arc::new(phi), y, s), x)
    }

    #[test]
    fn every_adapter_recovers_the_planted_support() {
        let kinds = [
            SolverKind::Niht,
            SolverKind::Iht,
            SolverKind::qniht_fixed(8, 8),
            SolverKind::Cosamp,
            SolverKind::Fista { lambda: None, debias: true },
        ];
        for (i, kind) in kinds.iter().enumerate() {
            let (problem, x_true) = planted(96, 192, 5, 20 + i as u64);
            let opts = SolveOptions::default().with_max_iters(500);
            let mut solver = kind.native_solver(7);
            assert_eq!(solver.name(), kind.name());
            let r = solver.solve(&problem, &opts, &mut NoopObserver).unwrap();
            assert_eq!(
                support_of(&r.x),
                support_of(&x_true),
                "{} must recover the planted support",
                kind.name()
            );
        }
    }

    #[test]
    fn default_engines_match_solver_class() {
        assert_eq!(SolverKind::Niht.default_engine(), EngineKind::NativeDense);
        assert_eq!(SolverKind::qniht_fixed(2, 8).default_engine(), EngineKind::NativeQuant);
        assert_eq!(SolverKind::Cosamp.default_engine(), EngineKind::NativeDense);
    }

    #[test]
    fn solver_keys_fingerprint_configuration() {
        assert_eq!(SolverKind::Niht.key(), SolverKind::Niht.key());
        assert_ne!(SolverKind::qniht_fixed(2, 8).key(), SolverKind::qniht_fixed(4, 8).key());
        assert_ne!(SolverKind::qniht_fixed(2, 8).key(), SolverKind::qniht_fresh(2, 8).key());
        let f = |lambda| SolverKind::Fista { lambda, debias: true };
        assert_eq!(f(Some(0.5)).key(), f(Some(0.5)).key());
        assert_ne!(f(Some(0.5)).key(), f(Some(0.25)).key());
        assert_ne!(f(Some(0.5)).key(), f(None).key());
    }

    #[test]
    fn engine_compatibility_matrix() {
        use EngineKind::*;
        let qniht = SolverKind::qniht_fixed(2, 8);
        assert!(qniht.runs_on(NativeQuant));
        assert!(qniht.runs_on(XlaQuant));
        assert!(qniht.runs_on(FpgaModel));
        assert!(!qniht.runs_on(NativeDense));
        assert!(!SolverKind::qniht_fresh(2, 8).runs_on(XlaQuant), "XLA quantizes once");
        assert!(SolverKind::qniht_fresh(2, 8).runs_on(NativeQuant));
        for dense in [SolverKind::Niht, SolverKind::Iht, SolverKind::Cosamp] {
            assert!(dense.runs_on(NativeDense));
            assert!(!dense.runs_on(NativeQuant));
            assert!(!dense.runs_on(FpgaModel));
        }
        assert!(SolverKind::Niht.runs_on(XlaDense));
        assert!(!SolverKind::Iht.runs_on(XlaDense));
    }
}
