//! Dense linear algebra substrate (S5): row-major f32 matrices, matvecs,
//! norms, and extremal singular values (see [`svd`]). No external BLAS —
//! everything the solvers and the RIP toolkit need is implemented here.

pub mod cg;
pub mod svd;

use crate::par;

/// Dense row-major `f32` matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Self { rows, cols, data }
    }

    /// Build from a generator `f(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    pub fn identity(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// y = A x  (parallel over rows for large matrices).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols, "matvec dim mismatch");
        let mut y = vec![0.0f32; self.rows];
        let cols = self.cols;
        let data = &self.data;
        par::par_chunks_mut(&mut y, 64, |start, chunk| {
            for (k, yi) in chunk.iter_mut().enumerate() {
                let row = &data[(start + k) * cols..(start + k + 1) * cols];
                *yi = dot(row, x);
            }
        });
        y
    }

    /// y = A^T x.
    pub fn matvec_t(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.rows, "matvec_t dim mismatch");
        let cols = self.cols;
        let data = &self.data;
        let mut y = vec![0.0f32; self.cols];
        // Accumulate row-by-row (cache friendly on row-major storage).
        // Parallel over column blocks to avoid write conflicts.
        par::par_chunks_mut(&mut y, 256, |start, chunk| {
            for (i, &xi) in x.iter().enumerate() {
                if xi == 0.0 {
                    continue;
                }
                let row = &data[i * cols + start..i * cols + start + chunk.len()];
                for (c, &r) in chunk.iter_mut().zip(row) {
                    *c += xi * r;
                }
            }
        });
        y
    }

    /// y = A x for sparse x given as (indices, values) — the paper's
    /// "matrix times a sparse vector" routine, cast as column scale-and-add.
    pub fn matvec_sparse(&self, idx: &[usize], vals: &[f32]) -> Vec<f32> {
        assert_eq!(idx.len(), vals.len());
        let mut y = vec![0.0f32; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0f32;
            for (&j, &v) in idx.iter().zip(vals) {
                acc += row[j] * v;
            }
            y[i] = acc;
        }
        y
    }

    /// Extract the submatrix of the given columns (support set Γ).
    pub fn take_cols(&self, cols_idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(self.rows, cols_idx.len());
        for i in 0..self.rows {
            let row = self.row(i);
            for (k, &j) in cols_idx.iter().enumerate() {
                out.data[i * cols_idx.len() + k] = row[j];
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.at(i, j);
            }
        }
        out
    }

    pub fn scale(&mut self, c: f32) {
        for v in &mut self.data {
            *v *= c;
        }
    }

    pub fn frobenius(&self) -> f32 {
        norm2(&self.data)
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &b| a.max(b.abs()))
    }

    /// Size in bytes at full (f32) precision — the paper's traffic metric.
    pub fn bytes_f32(&self) -> usize {
        self.data.len() * 4
    }
}

/// Dot product with 16 contiguous accumulator lanes.
///
/// Perf note (EXPERIMENTS.md §Perf): float reduction loops cannot be
/// reassociated by LLVM, so a scalar `sum += a[i]*b[i]` never vectorizes.
/// A *lane array* `acc[k] += a[16i+k]*b[16i+k]` maps 1:1 onto SIMD
/// registers (one AVX-512 or two AVX2 vectors) and turns the loop into
/// pure FMA streams — 5–6× over the previous 4-way strided unroll.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    const LANES: usize = 16;
    let mut acc = [0.0f32; LANES];
    let chunks = a.len() / LANES;
    for c in 0..chunks {
        let i = c * LANES;
        let (av, bv) = (&a[i..i + LANES], &b[i..i + LANES]);
        for k in 0..LANES {
            acc[k] += av[k] * bv[k];
        }
    }
    let mut s = 0.0f32;
    for k in 0..LANES {
        s += acc[k];
    }
    for i in chunks * LANES..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Euclidean norm.
#[inline]
pub fn norm2(v: &[f32]) -> f32 {
    dot(v, v).sqrt()
}

/// Squared Euclidean norm.
#[inline]
pub fn norm2_sq(v: &[f32]) -> f32 {
    dot(v, v)
}

/// L1 norm.
#[inline]
pub fn norm1(v: &[f32]) -> f32 {
    v.iter().map(|x| x.abs()).sum()
}

/// a - b elementwise.
pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// a + c*b elementwise.
pub fn axpy(a: &[f32], c: f32, b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x + c * y).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Mat {
        Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    }

    #[test]
    fn matvec_known() {
        let a = small();
        assert_eq!(a.matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
    }

    #[test]
    fn matvec_t_known() {
        let a = small();
        assert_eq!(a.matvec_t(&[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn matvec_t_matches_explicit_transpose() {
        let mut rng = crate::rng::XorShift128Plus::new(3);
        let a = Mat::from_fn(17, 29, |_, _| rng.gaussian_f32());
        let x = rng.gaussian_vec(17);
        let got = a.matvec_t(&x);
        let want = a.transpose().matvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4);
        }
    }

    #[test]
    fn matvec_sparse_matches_dense() {
        let mut rng = crate::rng::XorShift128Plus::new(5);
        let a = Mat::from_fn(13, 31, |_, _| rng.gaussian_f32());
        let mut x = vec![0.0f32; 31];
        x[4] = 1.5;
        x[20] = -0.5;
        let dense = a.matvec(&x);
        let sparse = a.matvec_sparse(&[4, 20], &[1.5, -0.5]);
        for (d, s) in dense.iter().zip(&sparse) {
            assert!((d - s).abs() < 1e-5);
        }
    }

    #[test]
    fn take_cols_selects() {
        let a = small();
        let b = a.take_cols(&[2, 0]);
        assert_eq!(b.data, vec![3.0, 1.0, 6.0, 4.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = small();
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn identity_matvec_is_id() {
        let i = Mat::identity(5);
        let x = vec![1.0, -2.0, 3.0, 0.5, 0.0];
        assert_eq!(i.matvec(&x), x);
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = crate::rng::XorShift128Plus::new(7);
        for n in [0, 1, 3, 4, 7, 64, 129] {
            let a = rng.gaussian_vec(n);
            let b = rng.gaussian_vec(n);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-3, "n={n}");
        }
    }

    #[test]
    fn norms() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
        assert!((norm1(&[-3.0, 4.0]) - 7.0).abs() < 1e-6);
        assert!((norm2_sq(&[3.0, 4.0]) - 25.0).abs() < 1e-6);
    }

    #[test]
    fn frobenius_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!((a.frobenius() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn axpy_sub() {
        assert_eq!(axpy(&[1.0, 2.0], 2.0, &[3.0, -1.0]), vec![7.0, 0.0]);
        assert_eq!(sub(&[1.0, 2.0], &[3.0, -1.0]), vec![-2.0, 3.0]);
    }

    #[test]
    #[should_panic]
    fn matvec_dim_mismatch_panics() {
        small().matvec(&[1.0, 2.0]);
    }
}
