//! Extremal singular values via power iteration (no external LAPACK).
//!
//! The RIP toolkit (Figs 3, 7, 8) needs σ_max and σ_min of Φ and of column
//! submatrices Φ_Γ. Both are obtained from power iterations on the Gram
//! operator `v -> A^T (A v)`:
//!   * σ_max² = λ_max(AᵀA): plain power iteration.
//!   * σ_min² = λ_min(AᵀA): power iteration on the spectrally shifted
//!     operator `c·I − AᵀA` with `c ≥ λ_max` (deflation-free, robust for the
//!     well-separated spectra we probe).

use super::Mat;
use crate::rng::XorShift128Plus;

/// Result of an extremal singular-value probe.
#[derive(Debug, Clone, Copy)]
pub struct SingularExtremes {
    pub sigma_max: f32,
    pub sigma_min: f32,
    pub iterations: usize,
}

fn normalize(v: &mut [f32]) -> f32 {
    let n = super::norm2(v);
    if n > 0.0 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
    n
}

/// λ_max of the PSD operator `op` (size n), by power iteration.
fn lambda_max(op: &dyn Fn(&[f32]) -> Vec<f32>, n: usize, tol: f32, max_iter: usize, seed: u64) -> (f32, usize) {
    let mut rng = XorShift128Plus::new(seed);
    let mut v = rng.gaussian_vec(n);
    normalize(&mut v);
    let mut lambda = 0.0f32;
    for it in 0..max_iter {
        let mut w = op(&v);
        let new_lambda = super::dot(&v, &w);
        let growth = normalize(&mut w);
        if growth == 0.0 {
            return (0.0, it);
        }
        v = w;
        if it > 2 && (new_lambda - lambda).abs() <= tol * new_lambda.abs().max(1e-12) {
            return (new_lambda.max(0.0), it);
        }
        lambda = new_lambda;
    }
    (lambda.max(0.0), max_iter)
}

/// Extremal singular values of `a` (tolerance is relative on λ).
pub fn singular_extremes(a: &Mat, tol: f32, max_iter: usize, seed: u64) -> SingularExtremes {
    let n = a.cols;
    let gram = |v: &[f32]| a.matvec_t(&a.matvec(v));
    let (lmax, it1) = lambda_max(&gram, n, tol, max_iter, seed);
    // Shifted operator: c I - AᵀA with c slightly above λ_max.
    let c = lmax * 1.0001 + 1e-12;
    let shifted = |v: &[f32]| {
        let g = gram(v);
        v.iter().zip(&g).map(|(x, y)| c * x - y).collect::<Vec<f32>>()
    };
    let (lshift, it2) = lambda_max(&shifted, n, tol, max_iter, seed ^ 0xDEADBEEF);
    let lmin = (c - lshift).max(0.0);
    SingularExtremes {
        sigma_max: lmax.sqrt(),
        sigma_min: lmin.sqrt(),
        iterations: it1 + it2,
    }
}

/// Spectral norm ‖A‖₂ = σ_max(A).
pub fn spectral_norm(a: &Mat, tol: f32, max_iter: usize, seed: u64) -> f32 {
    singular_extremes(a, tol, max_iter, seed).sigma_max
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_matrix_exact() {
        // diag(3, 2, 1) has σ_max=3, σ_min=1.
        let a = Mat::from_fn(3, 3, |i, j| {
            if i == j {
                (3 - i) as f32
            } else {
                0.0
            }
        });
        let se = singular_extremes(&a, 1e-7, 2000, 1);
        assert!((se.sigma_max - 3.0).abs() < 1e-3, "{se:?}");
        assert!((se.sigma_min - 1.0).abs() < 1e-2, "{se:?}");
    }

    #[test]
    fn identity_all_ones() {
        let a = Mat::identity(8);
        let se = singular_extremes(&a, 1e-7, 2000, 2);
        assert!((se.sigma_max - 1.0).abs() < 1e-3);
        assert!((se.sigma_min - 1.0).abs() < 1e-3);
    }

    #[test]
    fn rank_deficient_sigma_min_zero() {
        // Two identical columns: σ_min = 0.
        let a = Mat::from_vec(2, 2, vec![1.0, 1.0, 2.0, 2.0]);
        let se = singular_extremes(&a, 1e-7, 4000, 3);
        assert!(se.sigma_min < 1e-2, "{se:?}");
    }

    #[test]
    fn scaling_scales_sigma() {
        let mut rng = crate::rng::XorShift128Plus::new(4);
        let a = Mat::from_fn(20, 10, |_, _| rng.gaussian_f32());
        let mut a2 = a.clone();
        a2.scale(3.0);
        let s1 = singular_extremes(&a, 1e-7, 4000, 5);
        let s2 = singular_extremes(&a2, 1e-7, 4000, 5);
        assert!((s2.sigma_max / s1.sigma_max - 3.0).abs() < 0.01);
        assert!((s2.sigma_min / s1.sigma_min - 3.0).abs() < 0.05);
    }

    #[test]
    fn gaussian_tall_matrix_marchenko_pastur_ballpark() {
        // For an m×n Gaussian matrix /sqrt(m), σ ≈ 1 ± sqrt(n/m).
        let (m, n) = (400, 100);
        let mut rng = crate::rng::XorShift128Plus::new(6);
        let a = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
        let se = singular_extremes(&a, 1e-6, 4000, 7);
        let edge = (n as f32 / m as f32).sqrt();
        assert!((se.sigma_max - (1.0 + edge)).abs() < 0.12, "{se:?}");
        assert!((se.sigma_min - (1.0 - edge)).abs() < 0.12, "{se:?}");
    }

    #[test]
    fn spectral_norm_consistent() {
        let a = Mat::from_vec(2, 2, vec![0.0, 2.0, 0.0, 0.0]);
        assert!((spectral_norm(&a, 1e-7, 1000, 8) - 2.0).abs() < 1e-3);
    }
}
