//! Conjugate-gradient least squares (CGNR) — used by the CoSaMP baseline's
//! support-restricted least-squares solve and by diagnostics.
//!
//! Solves `min_z ‖A z − b‖₂` via CG on the normal equations
//! `AᵀA z = Aᵀ b` without forming AᵀA.

use super::{dot, Mat};

/// CGNR result.
#[derive(Debug, Clone)]
pub struct CgResult {
    pub z: Vec<f32>,
    pub iterations: usize,
    pub residual_norm: f32,
}

/// Least-squares solve `min ‖A z − b‖` (A: m×n, b: m). `tol` is relative on
/// the normal residual ‖Aᵀ(b − Az)‖.
pub fn lsqr_cg(a: &Mat, b: &[f32], max_iter: usize, tol: f32) -> CgResult {
    assert_eq!(b.len(), a.rows);
    let n = a.cols;
    let mut z = vec![0.0f32; n];
    // r = Aᵀb − AᵀA z  (z = 0 initially)
    let mut r = a.matvec_t(b);
    let mut p = r.clone();
    let mut rsq = dot(&r, &r);
    let rsq0 = rsq.max(1e-30);
    let mut it = 0;
    while it < max_iter && rsq > tol * tol * rsq0 {
        let ap = a.matvec_t(&a.matvec(&p));
        let pap = dot(&p, &ap);
        if pap <= 0.0 {
            break; // numerical breakdown (A rank-deficient on this support)
        }
        let alpha = rsq / pap;
        for i in 0..n {
            z[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rsq_new = dot(&r, &r);
        let beta = rsq_new / rsq;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rsq = rsq_new;
        it += 1;
    }
    let resid = super::sub(b, &a.matvec(&z));
    CgResult { z, iterations: it, residual_norm: super::norm2(&resid) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::XorShift128Plus;

    #[test]
    fn solves_identity() {
        let a = Mat::identity(5);
        let b = vec![1.0, -2.0, 3.0, 0.0, 0.5];
        let r = lsqr_cg(&a, &b, 100, 1e-7);
        for (zi, bi) in r.z.iter().zip(&b) {
            assert!((zi - bi).abs() < 1e-4);
        }
    }

    #[test]
    fn solves_consistent_overdetermined() {
        let mut rng = XorShift128Plus::new(1);
        let a = Mat::from_fn(40, 10, |_, _| rng.gaussian_f32());
        let z_true = rng.gaussian_vec(10);
        let b = a.matvec(&z_true);
        let r = lsqr_cg(&a, &b, 200, 1e-7);
        for (zi, ti) in r.z.iter().zip(&z_true) {
            assert!((zi - ti).abs() < 1e-3, "{} vs {}", zi, ti);
        }
        assert!(r.residual_norm < 1e-3);
    }

    #[test]
    fn least_squares_residual_orthogonal() {
        // At the LS optimum, Aᵀ(b − Az) ≈ 0 even for inconsistent b.
        let mut rng = XorShift128Plus::new(2);
        let a = Mat::from_fn(30, 8, |_, _| rng.gaussian_f32());
        let b = rng.gaussian_vec(30);
        let r = lsqr_cg(&a, &b, 300, 1e-7);
        let resid = crate::linalg::sub(&b, &a.matvec(&r.z));
        let normal = a.matvec_t(&resid);
        assert!(crate::linalg::norm2(&normal) < 1e-2, "{normal:?}");
    }

    #[test]
    fn zero_rhs_gives_zero() {
        let mut rng = XorShift128Plus::new(3);
        let a = Mat::from_fn(10, 4, |_, _| rng.gaussian_f32());
        let r = lsqr_cg(&a, &vec![0.0; 10], 50, 1e-8);
        assert!(r.z.iter().all(|&v| v.abs() < 1e-6));
    }
}
