//! Low-precision matvec kernels (S4) — the rust analog of the paper's AVX2
//! routines (§9).
//!
//! Two hot routines dominate NIHT (paper §9):
//!   1. the dense matvec `Φᵀr` (gradient), cast as per-row dot products over
//!      the packed matrix, and
//!   2. `Φ · x_sparse` (residual update), cast as a dense scale-and-add over
//!      the columns in the support.
//!
//! Kernels come in three flavours:
//!   * `qmatvec*` — int8 codes (unpacked), f32 accumulate: the general path.
//!   * `packed_matvec` — streams the b-bit packed words and dequantizes
//!     in-register: 4–16× less memory traffic than f32 (the Fig 5 lever).
//!   * `packed_matvec_q8` — both operands quantized: pure integer dots
//!     (the paper's "casts its computation in terms of dot-products").

use crate::par;
use crate::quant::packed::PackedMatrix;

/// y = mult · (codes @ x); codes row-major m×n int8.
pub fn qmatvec(codes: &[i8], m: usize, n: usize, mult: f32, x: &[f32]) -> Vec<f32> {
    assert_eq!(codes.len(), m * n);
    assert_eq!(x.len(), n);
    let mut y = vec![0.0f32; m];
    par::par_chunks_mut(&mut y, 32, |start, chunk| {
        for (k, yi) in chunk.iter_mut().enumerate() {
            let row = &codes[(start + k) * n..(start + k + 1) * n];
            *yi = mult * dot_i8_f32(row, x);
        }
    });
    y
}

/// y = mult · (codesᵀ @ v); codes row-major m×n int8, v length m.
pub fn qmatvec_t(codes: &[i8], m: usize, n: usize, mult: f32, v: &[f32]) -> Vec<f32> {
    assert_eq!(codes.len(), m * n);
    assert_eq!(v.len(), m);
    let mut y = vec![0.0f32; n];
    par::par_chunks_mut(&mut y, 256, |start, chunk| {
        for (i, &vi) in v.iter().enumerate() {
            if vi == 0.0 {
                continue;
            }
            let row = &codes[i * n + start..i * n + start + chunk.len()];
            for (c, &r) in chunk.iter_mut().zip(row) {
                *c += vi * r as f32;
            }
        }
    });
    for c in &mut y {
        *c *= mult;
    }
    y
}

/// y = mult · Φ x for sparse x, using the TRANSPOSED code buffer
/// (`codes_t` is n×m row-major, i.e. columns of Φ are contiguous rows):
/// the paper's dense scale-and-add routine.
pub fn qmatvec_sparse(
    codes_t: &[i8],
    n: usize,
    m: usize,
    mult: f32,
    idx: &[usize],
    vals: &[f32],
) -> Vec<f32> {
    assert_eq!(codes_t.len(), n * m);
    assert_eq!(idx.len(), vals.len());
    let mut y = vec![0.0f32; m];
    for (&j, &xj) in idx.iter().zip(vals) {
        debug_assert!(j < n);
        let col = &codes_t[j * m..(j + 1) * m];
        for (yi, &c) in y.iter_mut().zip(col) {
            *yi += xj * c as f32;
        }
    }
    for yi in &mut y {
        *yi *= mult;
    }
    y
}

/// y = mult · Φ x for sparse x, on ROW-MAJOR codes (m×n): column-restricted
/// accumulation (strided column access — use `qmatvec_sparse` with a
/// transposed buffer when one is available).
pub fn qmatvec_sparse_cols(
    codes: &[i8],
    m: usize,
    n: usize,
    mult: f32,
    idx: &[usize],
    vals: &[f32],
) -> Vec<f32> {
    assert_eq!(codes.len(), m * n);
    assert_eq!(idx.len(), vals.len());
    let mut y = vec![0.0f32; m];
    for i in 0..m {
        let row = &codes[i * n..(i + 1) * n];
        let mut acc = 0.0f32;
        for (&j, &v) in idx.iter().zip(vals) {
            acc += row[j] as f32 * v;
        }
        y[i] = acc * mult;
    }
    y
}

/// Dot of an int8 row with an f32 vector — 16 contiguous accumulator
/// lanes (see `linalg::dot` for the vectorization rationale; the i8→f32
/// widening maps onto VPMOVSXBD + VCVTDQ2PS).
#[inline]
pub fn dot_i8_f32(row: &[i8], x: &[f32]) -> f32 {
    debug_assert_eq!(row.len(), x.len());
    const LANES: usize = 16;
    let mut acc = [0.0f32; LANES];
    let chunks = row.len() / LANES;
    for c in 0..chunks {
        let i = c * LANES;
        let (rv, xv) = (&row[i..i + LANES], &x[i..i + LANES]);
        for k in 0..LANES {
            acc[k] += rv[k] as f32 * xv[k];
        }
    }
    let mut s = 0.0f32;
    for k in 0..LANES {
        s += acc[k];
    }
    for i in chunks * LANES..row.len() {
        s += row[i] as f32 * x[i];
    }
    s
}

/// Pure integer dot: packed row (b-bit fields, biased by half) against an
/// int8 vector. Returns the raw integer accumulator (caller applies scales).
#[inline]
fn packed_dot_q8(words: &[u64], bits: u8, half: i32, n: usize, xq: &[i8]) -> i64 {
    let lanes = 64 / bits as usize;
    let mask = (1u64 << bits) - 1;
    let mut acc: i64 = 0;
    let mut j = 0usize;
    for &w in words {
        let mut ww = w;
        let take = lanes.min(n - j);
        for k in 0..take {
            let code = (ww & mask) as i32 - half;
            acc += (code as i64) * (xq[j + k] as i64);
            ww >>= bits;
        }
        j += take;
        if j >= n {
            break;
        }
    }
    acc
}

/// Byte → 4 signed 2-bit codes, packed little-endian into one u32
/// (field − half, half = 1): one table hit + one unaligned store decodes
/// 4 elements.
fn lut2_u32() -> &'static [u32; 256] {
    static LUT: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [0u32; 256];
        for (b, entry) in t.iter_mut().enumerate() {
            let mut bytes = [0u8; 4];
            for k in 0..4 {
                bytes[k] = ((((b >> (2 * k)) & 0b11) as i8) - 1) as u8;
            }
            *entry = u32::from_le_bytes(bytes);
        }
        t
    })
}

/// Byte → 2 signed 4-bit codes packed into one u16 (field − half, half=4).
fn lut4_u16() -> &'static [u16; 256] {
    static LUT: std::sync::OnceLock<[u16; 256]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [0u16; 256];
        for (b, entry) in t.iter_mut().enumerate() {
            let lo = ((((b >> 0) & 0xF) as i8) - 4) as u8;
            let hi = ((((b >> 4) & 0xF) as i8) - 4) as u8;
            *entry = u16::from_le_bytes([lo, hi]);
        }
        t
    })
}

/// Generic shift/mask decode (tail path + odd widths).
fn decode_generic(words: &[u64], bits: u8, n: usize, scratch: &mut [i8]) {
    let lanes = 64 / bits as usize;
    let mask = (1u64 << bits) - 1;
    let half = crate::quant::Quantizer::new(bits).half();
    let mut j = 0;
    for &w in words {
        let mut ww = w;
        let take = lanes.min(n - j);
        for k in 0..take {
            scratch[j + k] = ((ww & mask) as i32 - half) as i8;
            ww >>= bits;
        }
        j += take;
        if j >= n {
            break;
        }
    }
}

/// Decode one packed row into an i8 scratch buffer (length >= n).
///
/// Perf note (EXPERIMENTS.md §Perf): per-lane shift/mask extraction costs
/// ~4 ops/element and defeats vectorization. The hot path decodes whole
/// words through byte LUTs that emit 4 (2-bit) or 2 (4-bit) codes per
/// single u32/u16 store into an L1-resident scratch row; the vectorized
/// `dot_i8_f32` then consumes the row. Ragged tails fall back to the
/// generic shift/mask loop.
#[inline]
pub fn decode_row(words: &[u64], bits: u8, n: usize, scratch: &mut [i8]) {
    debug_assert!(scratch.len() >= n);
    let lanes = 64 / bits as usize;
    let full_words = n / lanes;
    let out = scratch.as_mut_ptr() as *mut u8;
    match bits {
        2 => {
            let lut = lut2_u32();
            for (wi, &w) in words[..full_words].iter().enumerate() {
                let bytes = w.to_le_bytes();
                let base = wi * 32;
                for (bi, b) in bytes.into_iter().enumerate() {
                    // SAFETY: base+4bi+4 <= full_words*32 <= n <= scratch.len()
                    unsafe {
                        (out.add(base + 4 * bi) as *mut u32)
                            .write_unaligned(lut[b as usize]);
                    }
                }
            }
        }
        4 => {
            let lut = lut4_u16();
            for (wi, &w) in words[..full_words].iter().enumerate() {
                let bytes = w.to_le_bytes();
                let base = wi * 16;
                for (bi, b) in bytes.into_iter().enumerate() {
                    unsafe {
                        (out.add(base + 2 * bi) as *mut u16)
                            .write_unaligned(lut[b as usize]);
                    }
                }
            }
        }
        8 => {
            // field = code + 64: subtract in the byte domain (wrapping sub
            // vectorizes to one psubb over the whole row).
            let src = &words[..full_words];
            for (wi, &w) in src.iter().enumerate() {
                let bytes = w.to_le_bytes();
                let base = wi * 8;
                for (bi, b) in bytes.into_iter().enumerate() {
                    scratch[base + bi] = b.wrapping_sub(64) as i8;
                }
            }
        }
        _ => {
            decode_generic(words, bits, n, scratch);
            return;
        }
    }
    // Ragged tail (n not a multiple of lanes-per-word).
    let done = full_words * lanes;
    if done < n {
        decode_generic(&words[full_words..], bits, n - done, &mut scratch[done..]);
    }
}

/// Dot of a u8 row with an f32 vector (16 accumulator lanes).
#[inline]
fn dot_u8_f32(row: &[u8], x: &[f32]) -> f32 {
    debug_assert_eq!(row.len(), x.len());
    const LANES: usize = 16;
    let mut acc = [0.0f32; LANES];
    let chunks = row.len() / LANES;
    for c in 0..chunks {
        let i = c * LANES;
        let (rv, xv) = (&row[i..i + LANES], &x[i..i + LANES]);
        for k in 0..LANES {
            acc[k] += rv[k] as f32 * xv[k];
        }
    }
    let mut s = 0.0f32;
    for k in 0..LANES {
        s += acc[k];
    }
    for i in chunks * LANES..row.len() {
        s += row[i] as f32 * x[i];
    }
    s
}

/// y = A x streaming the packed representation.
///
/// * 8-bit: no decode at all — the packed bytes ARE `code + 64`, so
///   `dot = Σ byte·x − 64·Σx` with Σx hoisted out of the row loop
///   (one u8·f32 dot straight over the packed storage).
/// * 2/4-bit: LUT-decode each row into an L1 scratch, then the
///   vectorized i8 dot.
pub fn packed_matvec(p: &PackedMatrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), p.n);
    let mult = p.multiplier();
    let mut y = vec![0.0f32; p.m];
    let wpr = p.words_per_row;
    let words = &p.words;
    let (bits, n) = (p.bits, p.n);
    if bits == 8 && n % 8 == 0 {
        let sum_x: f32 = x.iter().sum();
        par::par_chunks_mut(&mut y, 32, |start, chunk| {
            for (k, yi) in chunk.iter_mut().enumerate() {
                let i = start + k;
                let row = &words[i * wpr..(i + 1) * wpr];
                // SAFETY: u64 words reinterpreted as bytes, len = n.
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(row.as_ptr() as *const u8, n)
                };
                *yi = mult * (dot_u8_f32(bytes, x) - 64.0 * sum_x);
            }
        });
        return y;
    }
    par::par_chunks_mut(&mut y, 32, |start, chunk| {
        let mut scratch = vec![0i8; n];
        for (k, yi) in chunk.iter_mut().enumerate() {
            let i = start + k;
            let row = &words[i * wpr..(i + 1) * wpr];
            decode_row(row, bits, n, &mut scratch);
            *yi = mult * dot_i8_f32(&scratch[..n], x);
        }
    });
    y
}

/// y += c · (decoded row) for each (row, c) pair — the packed form of the
/// paper's dense scale-and-add (Φ·x_sparse over a transposed buffer).
pub fn packed_scale_add(p: &PackedMatrix, idx: &[usize], vals: &[f32]) -> Vec<f32> {
    assert_eq!(idx.len(), vals.len());
    let mult = p.multiplier();
    let mut y = vec![0.0f32; p.n];
    let mut scratch = vec![0i8; p.n];
    for (&r, &c) in idx.iter().zip(vals) {
        debug_assert!(r < p.m);
        decode_row(p.row_words(r), p.bits, p.n, &mut scratch);
        let cm = c * mult;
        for (yi, &s) in y.iter_mut().zip(scratch.iter()) {
            *yi += cm * s as f32;
        }
    }
    y
}

/// y = A x with x quantized to int8 (integer dot path). `x_mult` is x's
/// dequantization multiplier; the result is in f32 units.
pub fn packed_matvec_q8(p: &PackedMatrix, xq: &[i8], x_mult: f32) -> Vec<f32> {
    assert_eq!(xq.len(), p.n);
    let half = crate::quant::Quantizer::new(p.bits).half();
    let mult = p.multiplier() * x_mult;
    let mut y = vec![0.0f32; p.m];
    let wpr = p.words_per_row;
    let words = &p.words;
    let (bits, n) = (p.bits, p.n);
    par::par_chunks_mut(&mut y, 32, |start, chunk| {
        for (k, yi) in chunk.iter_mut().enumerate() {
            let i = start + k;
            let row = &words[i * wpr..(i + 1) * wpr];
            *yi = mult * packed_dot_q8(row, bits, half, n, xq) as f32;
        }
    });
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::quant::QuantizedMatrix;
    use crate::rng::XorShift128Plus;

    fn setup(m: usize, n: usize, bits: u8, seed: u64) -> (QuantizedMatrix, Vec<f32>, Vec<f32>) {
        let mut rng = XorShift128Plus::new(seed);
        let a = Mat::from_fn(m, n, |_, _| rng.gaussian_f32());
        let qm = QuantizedMatrix::from_mat(&a, bits, &mut rng);
        let x = rng.gaussian_vec(n);
        let want = qm.to_mat().matvec(&x);
        (qm, x, want)
    }

    #[test]
    fn qmatvec_matches_dense() {
        for bits in [2u8, 4, 8] {
            let (qm, x, want) = setup(23, 57, bits, bits as u64);
            let got = qmatvec(&qm.codes, qm.m, qm.n, qm.multiplier(), &x);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3, "bits={bits}");
            }
        }
    }

    #[test]
    fn qmatvec_t_matches_dense() {
        let (qm, _, _) = setup(23, 57, 4, 10);
        let mut rng = XorShift128Plus::new(99);
        let v = rng.gaussian_vec(23);
        let got = qmatvec_t(&qm.codes, qm.m, qm.n, qm.multiplier(), &v);
        let want = qm.to_mat().matvec_t(&v);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3);
        }
    }

    #[test]
    fn qmatvec_sparse_matches_dense() {
        let (qm, _, _) = setup(23, 57, 4, 11);
        let qt = qm.transposed();
        let idx = vec![3usize, 17, 44];
        let vals = vec![1.5f32, -0.25, 2.0];
        let got = qmatvec_sparse(&qt.codes, qm.n, qm.m, qm.multiplier(), &idx, &vals);
        let mut x = vec![0.0f32; 57];
        for (&j, &v) in idx.iter().zip(&vals) {
            x[j] = v;
        }
        let want = qm.to_mat().matvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3);
        }
    }

    #[test]
    fn packed_matvec_matches_qmatvec() {
        for bits in [2u8, 4, 8] {
            let (qm, x, _) = setup(17, 41, bits, 20 + bits as u64);
            let p = PackedMatrix::pack(&qm);
            let got = packed_matvec(&p, &x);
            let want = qmatvec(&qm.codes, qm.m, qm.n, qm.multiplier(), &x);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3, "bits={bits}");
            }
        }
    }

    #[test]
    fn packed_matvec_q8_integer_path() {
        let (qm, x, _) = setup(17, 41, 2, 30);
        let p = PackedMatrix::pack(&qm);
        // Quantize x to 8 bits.
        let mut rng = XorShift128Plus::new(31);
        let q8 = crate::quant::Quantizer::new(8);
        let (xq, xscale) = q8.quantize_auto(&x, &mut rng);
        let got = packed_matvec_q8(&p, &xq, xscale / q8.half() as f32);
        // Reference: dense product of both dequantized operands.
        let xdq = q8.dequantize_slice(&xq, xscale);
        let want = qm.to_mat().matvec(&xdq);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-2);
        }
    }

    #[test]
    fn empty_support_sparse_is_zero() {
        let (qm, _, _) = setup(5, 9, 4, 40);
        let qt = qm.transposed();
        let y = qmatvec_sparse(&qt.codes, 9, 5, qm.multiplier(), &[], &[]);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn decode_row_matches_unpack() {
        for bits in [2u8, 4, 8] {
            for n in [1usize, 5, 31, 64, 129] {
                let (qm, _, _) = setup(3, n, bits, 60 + n as u64);
                let p = PackedMatrix::pack(&qm);
                let mut scratch = vec![0i8; n];
                for i in 0..3 {
                    decode_row(p.row_words(i), bits, n, &mut scratch);
                    assert_eq!(
                        &scratch[..n],
                        &qm.codes[i * n..(i + 1) * n],
                        "bits={bits} n={n} row={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn packed_scale_add_matches_dense() {
        // Φ = qm (40×24); pt packs Φᵀ so pt rows are Φ's columns.
        let (qm, _, _) = setup(40, 24, 2, 70);
        let qt = qm.transposed();
        let pt = PackedMatrix::pack(&qt);
        let idx = vec![1usize, 7, 20];
        let vals = vec![0.5f32, -1.0, 2.0];
        let got = packed_scale_add(&pt, &idx, &vals);
        // Reference: dense Φ x with sparse x over the columns in idx.
        let mut x = vec![0.0f32; 24];
        for (&j, &v) in idx.iter().zip(&vals) {
            x[j] = v;
        }
        let dense = qm.to_mat().matvec(&x);
        assert_eq!(got.len(), dense.len());
        for (g, w) in got.iter().zip(&dense) {
            assert!((g - w).abs() < 1e-3);
        }
    }

    #[test]
    fn dot_i8_f32_matches_naive() {
        let mut rng = XorShift128Plus::new(50);
        for n in [0usize, 1, 3, 5, 64, 101] {
            let row: Vec<i8> = (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let x = rng.gaussian_vec(n);
            let naive: f32 = row.iter().zip(&x).map(|(&c, &v)| c as f32 * v).sum();
            assert!((dot_i8_f32(&row, &x) - naive).abs() < 1e-2, "n={n}");
        }
    }
}
