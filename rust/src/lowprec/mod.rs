//! Low-precision matvec kernels (S4) — the rust analog of the paper's AVX2
//! routines (§9).
//!
//! Two hot routines dominate NIHT (paper §9):
//!   1. the dense matvec `Φᵀr` (gradient), cast as per-row dot products over
//!      the packed matrix, and
//!   2. `Φ · x_sparse` (residual update), cast as a dense scale-and-add over
//!      the columns in the support.
//!
//! Kernels come in three flavours:
//!   * `qmatvec*` — int8 codes (unpacked), f32 accumulate: the general path.
//!   * `packed_matvec` — streams the b-bit packed words and dequantizes
//!     in-register: 4–16× less memory traffic than f32 (the Fig 5 lever).
//!   * `packed_matvec_q8` — both operands quantized: pure integer dots
//!     (the paper's "casts its computation in terms of dot-products").
//!
//! The batched serving path adds multi-RHS twins (`packed_matvec_multi`,
//! `packed_matvec_q8_multi`): one pass over the packed words serves every
//! right-hand side in the batch, so each row is streamed — and, at 2/4
//! bits, decoded — once per batch instead of once per RHS. Element `r` of
//! a multi result is bit-identical to the corresponding single-RHS call
//! on the same backend (see [`crate::simd`] for the kernel-level
//! contract), which keeps batched solves batch-composition-independent.
//!
//! Since the `simd` layer landed, this module owns the *shape* of each
//! kernel (parallel decomposition, bias bookkeeping, scratch management)
//! while the per-element inner loops dispatch through
//! [`crate::simd::Kernels`] — AVX2 when the CPU has it, the portable scalar
//! reference otherwise. Every public kernel has a `*_with` variant taking an
//! explicit backend so benches and parity tests can pin one.
//!
//! Row loops run on the persistent [`crate::par`] pool. All kernels compute
//! each output element independently or accumulate in fixed input order,
//! so results are identical under any `LPCS_THREADS` setting.

use crate::par;
use crate::quant::packed::PackedMatrix;
use crate::quant::Quantizer;
use crate::simd::{self, Kernels};

/// y = mult · (codes @ x); codes row-major m×n int8.
pub fn qmatvec(codes: &[i8], m: usize, n: usize, mult: f32, x: &[f32]) -> Vec<f32> {
    assert_eq!(codes.len(), m * n);
    assert_eq!(x.len(), n);
    let k = simd::active();
    let mut y = vec![0.0f32; m];
    par::par_chunks_mut(&mut y, 32, |start, chunk| {
        for (r, yi) in chunk.iter_mut().enumerate() {
            let row = &codes[(start + r) * n..(start + r + 1) * n];
            *yi = mult * k.dot_i8_f32(row, x);
        }
    });
    y
}

/// y = mult · (codesᵀ @ v); codes row-major m×n int8, v length m.
pub fn qmatvec_t(codes: &[i8], m: usize, n: usize, mult: f32, v: &[f32]) -> Vec<f32> {
    assert_eq!(codes.len(), m * n);
    assert_eq!(v.len(), m);
    let k = simd::active();
    let mut y = vec![0.0f32; n];
    // Grain-aligned chunks: the backend's scale-add rounds its per-chunk
    // tail differently from its vector/FMA body, so boundaries must fall on
    // the backend's block grid for every thread count (bit-identical
    // outputs under any LPCS_THREADS). `chunk_align` with lanes=1 (unpacked
    // operand) reduces to the f32 grain.
    par::par_chunks_mut_aligned(&mut y, 256, simd::chunk_align(k, 1), |start, chunk| {
        for (i, &vi) in v.iter().enumerate() {
            if vi == 0.0 {
                continue;
            }
            let row = &codes[i * n + start..i * n + start + chunk.len()];
            k.scale_add_i8(chunk, row, vi);
        }
    });
    for c in &mut y {
        *c *= mult;
    }
    y
}

/// y = mult · Φ x for sparse x, using the TRANSPOSED code buffer
/// (`codes_t` is n×m row-major, i.e. columns of Φ are contiguous rows):
/// the paper's dense scale-and-add routine, parallel over output chunks.
/// Each chunk accumulates the support entries in `idx` order, so the result
/// is independent of the thread count.
pub fn qmatvec_sparse(
    codes_t: &[i8],
    n: usize,
    m: usize,
    mult: f32,
    idx: &[usize],
    vals: &[f32],
) -> Vec<f32> {
    assert_eq!(codes_t.len(), n * m);
    assert_eq!(idx.len(), vals.len());
    let k = simd::active();
    let mut y = vec![0.0f32; m];
    // Grain-aligned chunks: see qmatvec_t — keeps the backend's FMA/tail
    // split on a fixed grid so results are identical for any LPCS_THREADS.
    par::par_chunks_mut_aligned(&mut y, 256, simd::chunk_align(k, 1), |start, chunk| {
        for (&j, &xj) in idx.iter().zip(vals) {
            debug_assert!(j < n);
            let col = &codes_t[j * m + start..j * m + start + chunk.len()];
            k.scale_add_i8(chunk, col, xj);
        }
    });
    for yi in &mut y {
        *yi *= mult;
    }
    y
}

/// y = mult · Φ x for sparse x, on ROW-MAJOR codes (m×n): column-restricted
/// accumulation (strided column access — use `qmatvec_sparse` with a
/// transposed buffer when one is available).
pub fn qmatvec_sparse_cols(
    codes: &[i8],
    m: usize,
    n: usize,
    mult: f32,
    idx: &[usize],
    vals: &[f32],
) -> Vec<f32> {
    assert_eq!(codes.len(), m * n);
    assert_eq!(idx.len(), vals.len());
    let mut y = vec![0.0f32; m];
    for i in 0..m {
        let row = &codes[i * n..(i + 1) * n];
        let mut acc = 0.0f32;
        for (&j, &v) in idx.iter().zip(vals) {
            acc += row[j] as f32 * v;
        }
        y[i] = acc * mult;
    }
    y
}

/// Dot of an int8 row with an f32 vector (backend-dispatched).
#[inline]
pub fn dot_i8_f32(row: &[i8], x: &[f32]) -> f32 {
    simd::active().dot_i8_f32(row, x)
}

/// Dot of a u8 row with an f32 vector (backend-dispatched).
#[inline]
pub fn dot_u8_f32(row: &[u8], x: &[f32]) -> f32 {
    simd::active().dot_u8_f32(row, x)
}

/// Decode one packed row into an i8 scratch buffer (length >= n).
///
/// Perf note (EXPERIMENTS.md §Perf): per-lane shift/mask extraction costs
/// ~4 ops/element and defeats vectorization. The scalar backend decodes
/// whole words through byte LUTs (4 codes per u32 store at 2 bits); the
/// AVX2 backend unpacks fields fully in-register. Ragged tails fall back
/// to the generic shift/mask loop inside each backend.
#[inline]
pub fn decode_row(words: &[u64], bits: u8, n: usize, scratch: &mut [i8]) {
    simd::active().decode_row(words, bits, n, scratch)
}

/// View the first `n` packed bytes of an 8-bit row (fields ARE `code + 64`
/// bytes; rows are u64-padded so any `n ≤ 8·words` is in bounds).
#[inline]
fn row_bytes(row: &[u64], n: usize) -> &[u8] {
    debug_assert!(n <= row.len() * 8);
    // SAFETY: u64 words reinterpreted as bytes; length checked above.
    unsafe { std::slice::from_raw_parts(row.as_ptr() as *const u8, n) }
}

/// y = A x streaming the packed representation (auto-selected backend).
pub fn packed_matvec(p: &PackedMatrix, x: &[f32]) -> Vec<f32> {
    packed_matvec_with(simd::active(), p, x)
}

/// [`packed_matvec`] with an explicit kernel backend.
///
/// * 8-bit: no decode at all — the packed bytes ARE `code + 64`, so
///   `dot = Σ byte·x − 64·Σx` with Σx hoisted out of the row loop
///   (one u8·f32 dot straight over the packed storage; works for ANY `n`
///   because rows are word-padded, so ragged tails need no fallback).
/// * 2/4-bit: backend decode of each row into an L1 scratch, then the
///   backend int8 dot.
pub fn packed_matvec_with(k: &dyn Kernels, p: &PackedMatrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), p.n);
    let mult = p.multiplier();
    let mut y = vec![0.0f32; p.m];
    let wpr = p.words_per_row;
    let words = &p.words;
    let (bits, n) = (p.bits, p.n);
    if bits == 8 {
        let sum_x: f32 = x.iter().sum();
        par::par_chunks_mut(&mut y, 32, |start, chunk| {
            for (r, yi) in chunk.iter_mut().enumerate() {
                let i = start + r;
                let row = &words[i * wpr..(i + 1) * wpr];
                *yi = mult * (k.dot_u8_f32(row_bytes(row, n), x) - 64.0 * sum_x);
            }
        });
        return y;
    }
    par::par_chunks_mut(&mut y, 32, |start, chunk| {
        let mut scratch = vec![0i8; n];
        for (r, yi) in chunk.iter_mut().enumerate() {
            let i = start + r;
            let row = &words[i * wpr..(i + 1) * wpr];
            k.decode_row(row, bits, n, &mut scratch);
            *yi = mult * k.dot_i8_f32(&scratch[..n], x);
        }
    });
    y
}

/// Batched `y_r = A x_r` over one packed matrix (auto-selected backend):
/// the multi-RHS twin of [`packed_matvec`]. See [`packed_matvec_multi_with`].
pub fn packed_matvec_multi(p: &PackedMatrix, xs: &[&[f32]]) -> Vec<Vec<f32>> {
    packed_matvec_multi_with(simd::active(), p, xs)
}

/// [`packed_matvec_multi`] with an explicit kernel backend.
///
/// One pass over the packed words serves every right-hand side: each row
/// is loaded (and, at 2/4 bits, decoded) ONCE per batch instead of once
/// per RHS, then fed through the backend's register-blocked multi dot.
/// CONTRACT: `out[r]` is bit-identical to
/// `packed_matvec_with(k, p, xs[r])` — the multi kernels preserve each
/// RHS's accumulation structure, the per-row arithmetic here matches the
/// single-RHS path op for op, and parallel chunks cover whole rows (each
/// output element is computed independently), so results are invariant to
/// batch composition and thread count.
pub fn packed_matvec_multi_with(
    k: &dyn Kernels,
    p: &PackedMatrix,
    xs: &[&[f32]],
) -> Vec<Vec<f32>> {
    let nrhs = xs.len();
    if nrhs == 0 {
        return Vec::new();
    }
    for x in xs {
        assert_eq!(x.len(), p.n);
    }
    if nrhs == 1 {
        return vec![packed_matvec_with(k, p, xs[0])];
    }
    let mult = p.multiplier();
    let wpr = p.words_per_row;
    let words = &p.words;
    let (bits, n, m) = (p.bits, p.n, p.m);
    // Row-major staging [row][rhs]; aligning chunks to nrhs keeps whole
    // rows inside one chunk.
    let mut flat = vec![0.0f32; m * nrhs];
    if bits == 8 {
        let sums: Vec<f32> = xs.iter().map(|x| x.iter().sum()).collect();
        par::par_chunks_mut_aligned(&mut flat, 32 * nrhs, nrhs, |start, chunk| {
            let row0 = start / nrhs;
            let mut tmp = vec![0.0f32; nrhs];
            for (ri, out_row) in chunk.chunks_mut(nrhs).enumerate() {
                let i = row0 + ri;
                let row = &words[i * wpr..(i + 1) * wpr];
                k.dot_u8_f32_multi(row_bytes(row, n), xs, &mut tmp);
                for (o, (&d, &sx)) in out_row.iter_mut().zip(tmp.iter().zip(&sums)) {
                    *o = mult * (d - 64.0 * sx);
                }
            }
        });
    } else {
        par::par_chunks_mut_aligned(&mut flat, 32 * nrhs, nrhs, |start, chunk| {
            let row0 = start / nrhs;
            let mut scratch = vec![0i8; n];
            for (ri, out_row) in chunk.chunks_mut(nrhs).enumerate() {
                let i = row0 + ri;
                let row = &words[i * wpr..(i + 1) * wpr];
                k.decode_row(row, bits, n, &mut scratch);
                k.dot_i8_f32_multi(&scratch[..n], xs, out_row);
                for o in out_row.iter_mut() {
                    *o *= mult;
                }
            }
        });
    }
    unstage(&flat, m, nrhs)
}

/// Batched integer-dot matvec: multi-RHS twin of [`packed_matvec_q8`];
/// `out[r]` is bit-identical to `packed_matvec_q8_with(k, p, xqs[r],
/// x_mults[r])` (all-integer accumulation, bias removed exactly).
pub fn packed_matvec_q8_multi(p: &PackedMatrix, xqs: &[&[i8]], x_mults: &[f32]) -> Vec<Vec<f32>> {
    packed_matvec_q8_multi_with(simd::active(), p, xqs, x_mults)
}

/// [`packed_matvec_q8_multi`] with an explicit kernel backend.
pub fn packed_matvec_q8_multi_with(
    k: &dyn Kernels,
    p: &PackedMatrix,
    xqs: &[&[i8]],
    x_mults: &[f32],
) -> Vec<Vec<f32>> {
    let nrhs = xqs.len();
    assert_eq!(x_mults.len(), nrhs);
    if nrhs == 0 {
        return Vec::new();
    }
    for xq in xqs {
        assert_eq!(xq.len(), p.n);
    }
    let half = Quantizer::new(p.bits).half() as i64;
    let sums: Vec<i64> = xqs
        .iter()
        .map(|xq| xq.iter().map(|&v| v as i64).sum())
        .collect();
    let mults: Vec<f32> = x_mults.iter().map(|&xm| p.multiplier() * xm).collect();
    let wpr = p.words_per_row;
    let words = &p.words;
    let (bits, n, m) = (p.bits, p.n, p.m);
    let mut flat = vec![0.0f32; m * nrhs];
    par::par_chunks_mut_aligned(&mut flat, 32 * nrhs, nrhs, |start, chunk| {
        let row0 = start / nrhs;
        let mut fdots = vec![0i64; nrhs];
        for (ri, out_row) in chunk.chunks_mut(nrhs).enumerate() {
            let i = row0 + ri;
            let row = &words[i * wpr..(i + 1) * wpr];
            k.packed_field_dot_q8_multi(row, bits, n, xqs, &mut fdots);
            for (o, ((&fdot, &sq), &mu)) in out_row
                .iter_mut()
                .zip(fdots.iter().zip(&sums).zip(&mults))
            {
                *o = mu * (fdot - half * sq) as f32;
            }
        }
    });
    unstage(&flat, m, nrhs)
}

/// Split row-major `[row][rhs]` staging into one output vector per RHS.
fn unstage(flat: &[f32], m: usize, nrhs: usize) -> Vec<Vec<f32>> {
    (0..nrhs)
        .map(|r| (0..m).map(|i| flat[i * nrhs + r]).collect())
        .collect()
}

/// y += c · (decoded row) for each (row, c) pair — the packed form of the
/// paper's dense scale-and-add (Φ·x_sparse over a transposed buffer).
pub fn packed_scale_add(p: &PackedMatrix, idx: &[usize], vals: &[f32]) -> Vec<f32> {
    packed_scale_add_with(simd::active(), p, idx, vals)
}

/// [`packed_scale_add`] with an explicit kernel backend.
///
/// Parallel over word-aligned output chunks: each chunk decodes only its
/// segment of every support row (chunk starts are multiples of
/// lanes-per-word, so a segment is a whole-word sub-row) and accumulates
/// the support entries in `idx` order — identical results for any thread
/// count.
pub fn packed_scale_add_with(
    k: &dyn Kernels,
    p: &PackedMatrix,
    idx: &[usize],
    vals: &[f32],
) -> Vec<f32> {
    assert_eq!(idx.len(), vals.len());
    let mult = p.multiplier();
    let mut y = vec![0.0f32; p.n];
    let lanes = PackedMatrix::lanes(p.bits);
    let wpr = p.words_per_row;
    let words = &p.words;
    let bits = p.bits;
    // Chunk starts must sit on word boundaries (lanes) AND the backend's
    // f32 block grid — a true lcm (lanes is not a power of two for
    // hand-built odd widths, e.g. bits=5 ⇒ lanes=12), computed by the one
    // shared grain helper so splits and kernels cannot disagree.
    let align = simd::chunk_align(k, lanes);
    par::par_chunks_mut_aligned(&mut y, 256, align, |start, chunk| {
        debug_assert_eq!(start % lanes, 0);
        let w0 = start / lanes;
        let mut scratch = vec![0i8; chunk.len()];
        for (&r, &c) in idx.iter().zip(vals) {
            debug_assert!(r < p.m);
            let seg = &words[r * wpr + w0..(r + 1) * wpr];
            k.decode_row(seg, bits, chunk.len(), &mut scratch);
            k.scale_add_i8(chunk, &scratch, c * mult);
        }
    });
    y
}

/// y = A x with x quantized to int8 (integer dot path). `x_mult` is x's
/// dequantization multiplier; the result is in f32 units.
pub fn packed_matvec_q8(p: &PackedMatrix, xq: &[i8], x_mult: f32) -> Vec<f32> {
    packed_matvec_q8_with(simd::active(), p, xq, x_mult)
}

/// [`packed_matvec_q8`] with an explicit kernel backend.
///
/// The backend computes the RAW field dot `Σ field·xq` (unsigned fields fit
/// `maddubs`-class instructions directly); the bias is removed here via
/// `Σ code·xq = Σ field·xq − half·Σxq`, exactly, in integers — so all
/// backends are bit-identical on this path.
pub fn packed_matvec_q8_with(
    k: &dyn Kernels,
    p: &PackedMatrix,
    xq: &[i8],
    x_mult: f32,
) -> Vec<f32> {
    assert_eq!(xq.len(), p.n);
    let half = Quantizer::new(p.bits).half() as i64;
    let sum_xq: i64 = xq.iter().map(|&v| v as i64).sum();
    let mult = p.multiplier() * x_mult;
    let mut y = vec![0.0f32; p.m];
    let wpr = p.words_per_row;
    let words = &p.words;
    let (bits, n) = (p.bits, p.n);
    par::par_chunks_mut(&mut y, 32, |start, chunk| {
        for (r, yi) in chunk.iter_mut().enumerate() {
            let i = start + r;
            let row = &words[i * wpr..(i + 1) * wpr];
            let fdot = k.packed_field_dot_q8(row, bits, n, xq);
            *yi = mult * (fdot - half * sum_xq) as f32;
        }
    });
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::quant::QuantizedMatrix;
    use crate::rng::XorShift128Plus;

    fn setup(m: usize, n: usize, bits: u8, seed: u64) -> (QuantizedMatrix, Vec<f32>, Vec<f32>) {
        let mut rng = XorShift128Plus::new(seed);
        let a = Mat::from_fn(m, n, |_, _| rng.gaussian_f32());
        let qm = QuantizedMatrix::from_mat(&a, bits, &mut rng);
        let x = rng.gaussian_vec(n);
        let want = qm.to_mat().matvec(&x);
        (qm, x, want)
    }

    #[test]
    fn qmatvec_matches_dense() {
        for bits in [2u8, 4, 8] {
            let (qm, x, want) = setup(23, 57, bits, bits as u64);
            let got = qmatvec(&qm.codes, qm.m, qm.n, qm.multiplier(), &x);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3, "bits={bits}");
            }
        }
    }

    #[test]
    fn qmatvec_t_matches_dense() {
        let (qm, _, _) = setup(23, 57, 4, 10);
        let mut rng = XorShift128Plus::new(99);
        let v = rng.gaussian_vec(23);
        let got = qmatvec_t(&qm.codes, qm.m, qm.n, qm.multiplier(), &v);
        let want = qm.to_mat().matvec_t(&v);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3);
        }
    }

    #[test]
    fn qmatvec_sparse_matches_dense() {
        let (qm, _, _) = setup(23, 57, 4, 11);
        let qt = qm.transposed();
        let idx = vec![3usize, 17, 44];
        let vals = vec![1.5f32, -0.25, 2.0];
        let got = qmatvec_sparse(&qt.codes, qm.n, qm.m, qm.multiplier(), &idx, &vals);
        let mut x = vec![0.0f32; 57];
        for (&j, &v) in idx.iter().zip(&vals) {
            x[j] = v;
        }
        let want = qm.to_mat().matvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3);
        }
    }

    #[test]
    fn packed_matvec_matches_qmatvec() {
        for bits in [2u8, 4, 8] {
            let (qm, x, _) = setup(17, 41, bits, 20 + bits as u64);
            let p = PackedMatrix::pack(&qm);
            let got = packed_matvec(&p, &x);
            let want = qmatvec(&qm.codes, qm.m, qm.n, qm.multiplier(), &x);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3, "bits={bits}");
            }
        }
    }

    #[test]
    fn packed_matvec_8bit_ragged_n() {
        // Regression: the 8-bit fast path used to be skipped whenever
        // n % 8 != 0 (full-row decode fallback). It now handles any n.
        for n in [1usize, 7, 9, 41, 63, 65, 127] {
            let (qm, x, _) = setup(9, n, 8, 500 + n as u64);
            let p = PackedMatrix::pack(&qm);
            let got = packed_matvec(&p, &x);
            let want = qmatvec(&qm.codes, qm.m, qm.n, qm.multiplier(), &x);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "n={n}");
            }
        }
    }

    #[test]
    fn packed_matvec_q8_integer_path() {
        let (qm, x, _) = setup(17, 41, 2, 30);
        let p = PackedMatrix::pack(&qm);
        // Quantize x to 8 bits.
        let mut rng = XorShift128Plus::new(31);
        let q8 = crate::quant::Quantizer::new(8);
        let (xq, xscale) = q8.quantize_auto(&x, &mut rng);
        let got = packed_matvec_q8(&p, &xq, xscale / q8.half() as f32);
        // Reference: dense product of both dequantized operands.
        let xdq = q8.dequantize_slice(&xq, xscale);
        let want = qm.to_mat().matvec(&xdq);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-2);
        }
    }

    #[test]
    fn empty_support_sparse_is_zero() {
        let (qm, _, _) = setup(5, 9, 4, 40);
        let qt = qm.transposed();
        let y = qmatvec_sparse(&qt.codes, 9, 5, qm.multiplier(), &[], &[]);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn decode_row_matches_unpack() {
        for bits in [2u8, 4, 8] {
            for n in [1usize, 5, 31, 64, 129] {
                let (qm, _, _) = setup(3, n, bits, 60 + n as u64);
                let p = PackedMatrix::pack(&qm);
                let mut scratch = vec![0i8; n];
                for i in 0..3 {
                    decode_row(p.row_words(i), bits, n, &mut scratch);
                    assert_eq!(
                        &scratch[..n],
                        &qm.codes[i * n..(i + 1) * n],
                        "bits={bits} n={n} row={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn packed_scale_add_matches_dense() {
        // Φ = qm (40×24); pt packs Φᵀ so pt rows are Φ's columns.
        let (qm, _, _) = setup(40, 24, 2, 70);
        let qt = qm.transposed();
        let pt = PackedMatrix::pack(&qt);
        let idx = vec![1usize, 7, 20];
        let vals = vec![0.5f32, -1.0, 2.0];
        let got = packed_scale_add(&pt, &idx, &vals);
        // Reference: dense Φ x with sparse x over the columns in idx.
        let mut x = vec![0.0f32; 24];
        for (&j, &v) in idx.iter().zip(&vals) {
            x[j] = v;
        }
        let dense = qm.to_mat().matvec(&x);
        assert_eq!(got.len(), dense.len());
        for (g, w) in got.iter().zip(&dense) {
            assert!((g - w).abs() < 1e-3);
        }
    }

    #[test]
    fn packed_scale_add_wide_output_all_widths() {
        // Output long enough to split across several aligned chunks.
        for bits in [2u8, 4, 8] {
            let (qm, _, _) = setup(6, 700, bits, 80 + bits as u64);
            let p = PackedMatrix::pack(&qm);
            let idx = vec![0usize, 3, 5];
            let vals = vec![1.0f32, -0.5, 0.25];
            let got = packed_scale_add(&p, &idx, &vals);
            let mut want = vec![0.0f32; 700];
            let mult = p.multiplier();
            for (&r, &c) in idx.iter().zip(&vals) {
                for j in 0..700 {
                    want[j] += c * mult * qm.codes[r * 700 + j] as f32;
                }
            }
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "bits={bits}");
            }
        }
    }

    #[test]
    fn packed_matvec_multi_bit_identical_to_single() {
        let mut rng = XorShift128Plus::new(90);
        for bits in [2u8, 4, 8] {
            for n in [17usize, 64, 65, 127, 300] {
                let (qm, _, _) = setup(13, n, bits, 600 + n as u64 + bits as u64);
                let p = PackedMatrix::pack(&qm);
                let xs_own: Vec<Vec<f32>> = (0..5).map(|_| rng.gaussian_vec(n)).collect();
                for r in [1usize, 2, 3, 5] {
                    let xs: Vec<&[f32]> = xs_own[..r].iter().map(|v| v.as_slice()).collect();
                    let got = packed_matvec_multi(&p, &xs);
                    assert_eq!(got.len(), r);
                    for (j, x) in xs.iter().enumerate() {
                        let want = packed_matvec(&p, x);
                        assert_eq!(got[j], want, "bits={bits} n={n} r={r} j={j}");
                    }
                }
            }
        }
    }

    #[test]
    fn packed_matvec_q8_multi_bit_identical_to_single() {
        let mut rng = XorShift128Plus::new(91);
        let q8 = crate::quant::Quantizer::new(8);
        for bits in [2u8, 4, 8] {
            for n in [33usize, 64, 127] {
                let (qm, _, _) = setup(11, n, bits, 700 + n as u64 + bits as u64);
                let p = PackedMatrix::pack(&qm);
                let quantized: Vec<(Vec<i8>, f32)> = (0..4)
                    .map(|_| {
                        let x = rng.gaussian_vec(n);
                        let (xq, xscale) = q8.quantize_auto(&x, &mut rng);
                        (xq, xscale / q8.half() as f32)
                    })
                    .collect();
                let xqs: Vec<&[i8]> = quantized.iter().map(|(xq, _)| xq.as_slice()).collect();
                let mults: Vec<f32> = quantized.iter().map(|&(_, m)| m).collect();
                let got = packed_matvec_q8_multi(&p, &xqs, &mults);
                for (j, ((xq, xm), g)) in quantized.iter().zip(&got).enumerate() {
                    let want = packed_matvec_q8(&p, xq, *xm);
                    assert_eq!(*g, want, "bits={bits} n={n} j={j}");
                }
            }
        }
    }

    #[test]
    fn packed_matvec_multi_empty_and_thread_invariant() {
        let (qm, x, _) = setup(19, 130, 2, 95);
        let p = PackedMatrix::pack(&qm);
        assert!(packed_matvec_multi(&p, &[]).is_empty());
        let mut rng = XorShift128Plus::new(96);
        let x2 = rng.gaussian_vec(130);
        let xs: Vec<&[f32]> = vec![&x, &x2, &x];
        let par_out = packed_matvec_multi(&p, &xs);
        crate::par::set_thread_override(Some(1));
        let one_out = packed_matvec_multi(&p, &xs);
        crate::par::set_thread_override(None);
        assert_eq!(par_out, one_out);
    }

    #[test]
    fn dot_i8_f32_matches_naive() {
        let mut rng = XorShift128Plus::new(50);
        for n in [0usize, 1, 3, 5, 64, 101] {
            let row: Vec<i8> = (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let x = rng.gaussian_vec(n);
            let naive: f32 = row.iter().zip(&x).map(|(&c, &v)| c as f32 * v).sum();
            assert!((dot_i8_f32(&row, &x) - naive).abs() < 1e-2, "n={n}");
        }
    }

    #[test]
    fn dot_u8_f32_matches_naive() {
        let mut rng = XorShift128Plus::new(51);
        for n in [0usize, 1, 3, 5, 64, 101] {
            let row: Vec<u8> = (0..n).map(|_| rng.below(129) as u8).collect();
            let x = rng.gaussian_vec(n);
            let naive: f32 = row.iter().zip(&x).map(|(&c, &v)| c as f32 * v).sum();
            assert!((dot_u8_f32(&row, &x) - naive).abs() < 1e-2, "n={n}");
        }
    }
}
