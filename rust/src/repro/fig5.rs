//! Fig 5 / E4 — CPU speedup of low-precision IHT: per-iteration matvec
//! speedup (measured on the packed kernels) and end-to-end time to 90%
//! support recovery, for 4-bit, 8-bit vs 32-bit.
//!
//! Paper numbers (Haswell AVX2 + MKL): ~2.84× (8-bit) and ~4.19× (4-bit)
//! end-to-end. Our substitution is safe-rust packed kernels (DESIGN.md §6);
//! the *shape* — monotone speedup as precision drops, near the traffic
//! ratio when memory-bound — is the reproduction target.

use crate::algorithms::SolveOptions;
use crate::config::LpcsConfig;
use crate::io::csv::CsvTable;
use crate::perfmodel::cpu;
use crate::repro::iterations_to_sources_resolved;
use crate::solver::{Problem, Recovery, SolverKind};
use crate::telescope::{AstroConfig, AstroProblem};
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

pub fn run(cfg: &LpcsConfig) -> Result<()> {
    // --- per-iteration: packed matvec vs f32 matvec (measured) ---
    // Paper scale (900 × 65,536 = 236 MB at f32): deliberately larger than
    // LLC so the f32 path is DRAM-bound — the regime the speedup lives in.
    let (m, n) = (900usize, 65536usize);
    println!("per-iteration matvec, {m}×{n} (f32 = {} MB):", m * n * 4 / (1 << 20));
    let mut t = CsvTable::new(&[
        "bits",
        "matvec_time_s",
        "f32_time_s",
        "per_iter_speedup",
        "traffic_bound",
        "end_to_end_time_s",
        "end_to_end_speedup",
    ]);

    // --- end-to-end: astro problem, time to 90% sources resolved ---
    // r=128 ⇒ Φ is 1800×16384 (118 MB at f32): big enough that the solve
    // is memory-bound like the per-iteration measurement.
    let astro = AstroConfig {
        resolution: 128,
        sources: cfg.astro.sources.min(16),
        snr_db: 10.0,
        ..cfg.astro.clone()
    };
    let p = AstroProblem::build(&astro, cfg.seed);
    let s = astro.sources;

    // 32-bit baseline end-to-end. Every solve routes through the facade;
    // Problem clones share Φ behind the Arc.
    let opts_k = |k: usize| SolveOptions { max_iters: k, tol: 0.0, ..cfg.solver.clone() };
    let problem = Problem::new(Arc::new(p.phi.clone()), p.y.clone(), s);
    let solve = |kind: SolverKind, k: usize| {
        Recovery::problem(problem.clone())
            .solver(kind)
            .options(opts_k(k))
            .seed(cfg.seed)
            .run()
            .expect("facade solve")
            .x
    };
    let iters32 = iterations_to_sources_resolved(
        |k| solve(SolverKind::Niht, k),
        &p.sky.sources,
        astro.resolution,
        0.9,
        512,
    );
    let t32 = {
        let k = iters32.unwrap_or(512);
        let t0 = Instant::now();
        let _ = solve(SolverKind::Niht, k);
        t0.elapsed().as_secs_f64()
    };

    for bits in [4u8, 8] {
        let mv = cpu::measure_matvec(m, n, bits, 7, cfg.seed);
        let iters_q = iterations_to_sources_resolved(
            |k| solve(SolverKind::qniht_fixed(bits, 8), k),
            &p.sky.sources,
            astro.resolution,
            0.9,
            512,
        );
        let tq = {
            let k = iters_q.unwrap_or(512);
            let t0 = Instant::now();
            let _ = solve(SolverKind::qniht_fixed(bits, 8), k);
            t0.elapsed().as_secs_f64()
        };
        t.row_f64(&[
            bits as f64,
            mv.time_s,
            mv.baseline_f32_s,
            mv.speedup(),
            cpu::traffic_speedup_bound(bits as u32),
            tq,
            t32 / tq,
        ]);
    }
    t.row_f64(&[32.0, 0.0, 0.0, 1.0, 1.0, t32, 1.0]);

    print!("{}", t.pretty());
    t.write_to(&cfg.out_dir.join("fig5.csv"))?;
    println!("wrote fig5.csv to {:?} (paper: 8-bit ≈ 2.84×, 4-bit ≈ 4.19× end-to-end)", cfg.out_dir);
    Ok(())
}
