//! Fig 4 / E3 — recovery error and exact (support) recovery vs iteration
//! count, for: 32-bit NIHT, 2&8-bit QNIHT, 4&8-bit QNIHT, CoSaMP, and the
//! ℓ1 approach (FISTA), on the radio-interferometry problem.

use crate::algorithms::cosamp::cosamp;
use crate::algorithms::fista::{fista, FistaOptions};
use crate::algorithms::niht::niht_dense;
use crate::algorithms::qniht::{qniht, RequantMode};
use crate::algorithms::SolveOptions;
use crate::config::LpcsConfig;
use crate::io::csv::CsvTable;
use crate::metrics;
use crate::telescope::{AstroConfig, AstroProblem};
use anyhow::Result;

pub fn run(cfg: &LpcsConfig) -> Result<()> {
    // Fig 4 scale: keep the harness snappy (r ≤ 32) unless overridden.
    let astro = AstroConfig {
        resolution: cfg.astro.resolution.min(32),
        sources: cfg.astro.sources.min(12),
        ..cfg.astro.clone()
    };
    let p = AstroProblem::build(&astro, cfg.seed);
    let s = astro.sources;
    println!(
        "methods comparison on astro problem: M={} N={} s={} SNR={}dB",
        p.m(), p.n(), s, astro.snr_db
    );

    let iters = [1usize, 2, 4, 8, 16, 32, 64];
    let mut t = CsvTable::new(&["method", "iterations", "recovery_error", "exact_recovery"]);

    let opts_k = |k: usize| SolveOptions { max_iters: k, tol: 0.0, ..cfg.solver.clone() };

    for &k in &iters {
        let x = niht_dense(&p.phi, &p.y, s, &opts_k(k)).x;
        t.row(&row("niht_32bit", k, &x, &p.x_true));
    }
    for (bits, name) in [(2u8, "qniht_2&8bit"), (4u8, "qniht_4&8bit")] {
        for &k in &iters {
            let x = qniht(&p.phi, &p.y, s, bits, 8, RequantMode::Fixed, cfg.seed, &opts_k(k)).x;
            t.row(&row(name, k, &x, &p.x_true));
        }
    }
    for &k in &iters {
        let x = cosamp(&p.phi, &p.y, s, &opts_k(k)).x;
        t.row(&row("cosamp", k, &x, &p.x_true));
    }
    for &k in &iters {
        // FISTA needs more inner iterations per unit progress; scale ×4.
        let x = fista(
            &p.phi,
            &p.y,
            &opts_k(4 * k),
            &FistaOptions { prune_to: Some(s), ..Default::default() },
        )
        .x;
        t.row(&row("l1_fista", k, &x, &p.x_true));
    }

    print!("{}", t.pretty());
    t.write_to(&cfg.out_dir.join("fig4.csv"))?;
    println!("wrote fig4.csv to {:?}", cfg.out_dir);
    Ok(())
}

fn row(name: &str, k: usize, x: &[f32], x_true: &[f32]) -> Vec<String> {
    vec![
        name.to_string(),
        k.to_string(),
        format!("{:.6}", metrics::recovery_error(x, x_true)),
        format!("{:.4}", metrics::exact_recovery_top_s(x, x_true)),
    ]
}
