//! Fig 4 / E3 — recovery error and exact (support) recovery vs iteration
//! count, for: 32-bit NIHT, 2&8-bit QNIHT, 4&8-bit QNIHT, CoSaMP, and the
//! ℓ1 approach (FISTA), on the radio-interferometry problem.

use crate::algorithms::qniht::RequantMode;
use crate::algorithms::SolveOptions;
use crate::config::LpcsConfig;
use crate::io::csv::CsvTable;
use crate::metrics;
use crate::solver::{Problem, Recovery, SolverKind};
use crate::telescope::{AstroConfig, AstroProblem};
use anyhow::Result;
use std::sync::Arc;

pub fn run(cfg: &LpcsConfig) -> Result<()> {
    // Fig 4 scale: keep the harness snappy (r ≤ 32) unless overridden.
    let astro = AstroConfig {
        resolution: cfg.astro.resolution.min(32),
        sources: cfg.astro.sources.min(12),
        ..cfg.astro.clone()
    };
    let p = AstroProblem::build(&astro, cfg.seed);
    let s = astro.sources;
    println!(
        "methods comparison on astro problem: M={} N={} s={} SNR={}dB",
        p.m(), p.n(), s, astro.snr_db
    );

    let iters = [1usize, 2, 4, 8, 16, 32, 64];
    let mut t = CsvTable::new(&["method", "iterations", "recovery_error", "exact_recovery"]);

    let opts_k = |k: usize| SolveOptions { max_iters: k, tol: 0.0, ..cfg.solver.clone() };
    // One Problem, every method: each entry re-runs the facade at a fixed
    // iteration budget (Problem clones share Φ behind the Arc).
    let problem = Problem::new(Arc::new(p.phi.clone()), p.y.clone(), s);
    let solve = |kind: SolverKind, k: usize| {
        Recovery::problem(problem.clone())
            .solver(kind)
            .options(opts_k(k))
            .seed(cfg.seed)
            .run()
            .map(|rep| rep.x)
    };

    for &k in &iters {
        let x = solve(SolverKind::Niht, k)?;
        t.row(&row("niht_32bit", k, &x, &p.x_true));
    }
    for (bits, name) in [(2u8, "qniht_2&8bit"), (4u8, "qniht_4&8bit")] {
        for &k in &iters {
            let x = solve(
                SolverKind::Qniht { bits_phi: bits, bits_y: 8, mode: RequantMode::Fixed },
                k,
            )?;
            t.row(&row(name, k, &x, &p.x_true));
        }
    }
    for &k in &iters {
        let x = solve(SolverKind::Cosamp, k)?;
        t.row(&row("cosamp", k, &x, &p.x_true));
    }
    for &k in &iters {
        // FISTA needs more inner iterations per unit progress; scale ×4.
        // (The facade prunes the ℓ₁ iterate to s for support metrics.)
        let x = solve(SolverKind::Fista { lambda: None, debias: true }, 4 * k)?;
        t.row(&row("l1_fista", k, &x, &p.x_true));
    }

    print!("{}", t.pretty());
    t.write_to(&cfg.out_dir.join("fig4.csv"))?;
    println!("wrote fig4.csv to {:?}", cfg.out_dir);
    Ok(())
}

fn row(name: &str, k: usize, x: &[f32], x_true: &[f32]) -> Vec<String> {
    vec![
        name.to_string(),
        k.to_string(),
        format!("{:.6}", metrics::recovery_error(x, x_true)),
        format!("{:.4}", metrics::exact_recovery_top_s(x, x_true)),
    ]
}
