//! Fig 6 / E5 — FPGA speedup via the bandwidth-bound device model
//! (perfmodel::fpga; the paper's own §8.1 analysis, P = 12.8 GB/s).
//! Per-iteration speedup is exact 32/b; end-to-end combines modeled
//! iteration time with the iteration counts the quantized solver actually
//! needs to reach 90% support recovery. Headline: 2&8-bit ⇒ ~9.19×.

use crate::algorithms::qniht::RequantMode;
use crate::algorithms::SolveOptions;
use crate::config::LpcsConfig;
use crate::io::csv::CsvTable;
use crate::perfmodel::fpga::FpgaModel;
use crate::repro::iterations_to_sources_resolved;
use crate::solver::{Problem, Recovery, SolverKind};
use crate::telescope::{AstroConfig, AstroProblem};
use anyhow::Result;
use std::sync::Arc;

pub fn run(cfg: &LpcsConfig) -> Result<()> {
    let fpga = FpgaModel::default();
    let astro = AstroConfig {
        resolution: cfg.astro.resolution.min(32),
        sources: cfg.astro.sources.min(12),
        ..cfg.astro.clone()
    };
    let p = AstroProblem::build(&astro, cfg.seed);
    let s = astro.sources;
    let (m, n) = (p.m(), p.n());
    println!(
        "FPGA model: P={} GB/s, {}×{} problem; per-iteration T = size(Φ̂)/P",
        fpga.bandwidth / 1e9, m, n
    );

    let opts_k = |k: usize| SolveOptions { max_iters: k, tol: 0.0, ..cfg.solver.clone() };
    let problem = Problem::new(Arc::new(p.phi.clone()), p.y.clone(), s);
    let solve = |kind: SolverKind, k: usize| {
        Recovery::problem(problem.clone())
            .solver(kind)
            .options(opts_k(k))
            .seed(cfg.seed)
            .run()
            .expect("facade solve")
            .x
    };
    let iters32 = iterations_to_sources_resolved(
        |k| solve(SolverKind::Niht, k),
        &p.sky.sources,
        astro.resolution,
        0.9,
        512,
    )
    .unwrap_or(512);
    let t32 = fpga.end_to_end_time(m, n, 32, 32, iters32);

    let mut t = CsvTable::new(&[
        "bits_phi",
        "bits_y",
        "iter_time_us",
        "per_iter_speedup",
        "iters_to_90pct",
        "end_to_end_s",
        "end_to_end_speedup",
        "values_per_line",
    ]);
    t.row_f64(&[
        32.0,
        32.0,
        fpga.iteration_time(m, n, 32, 32) * 1e6,
        1.0,
        iters32 as f64,
        t32,
        1.0,
        fpga.values_per_line(32) as f64,
    ]);

    for (bits, by) in [(16u8, 16u8), (8, 8), (4, 8), (2, 8)] {
        let iters_q = if bits >= 16 {
            // ≥16-bit quantization is numerically indistinguishable here;
            // reuse the 32-bit iteration count (the paper's Fig 6 shows the
            // same plateau).
            iters32
        } else {
            // 2-bit runs use fresh per-iteration quantizations: the FPGA
            // deployment computes Φ on the fly (paper §8.2), so stochastic
            // rounding is re-drawn on every pass over the matrix.
            let mode = if bits <= 2 { RequantMode::Fresh } else { RequantMode::Fixed };
            iterations_to_sources_resolved(
                |k| solve(SolverKind::Qniht { bits_phi: bits, bits_y: by, mode }, k),
                &p.sky.sources,
                astro.resolution,
                0.9,
                512,
            )
            .unwrap_or(512)
        };
        let te = fpga.end_to_end_time(m, n, bits as u32, by as u32, iters_q);
        t.row_f64(&[
            bits as f64,
            by as f64,
            fpga.iteration_time(m, n, bits as u32, by as u32) * 1e6,
            fpga.iteration_speedup(m, n, bits as u32, by as u32),
            iters_q as f64,
            te,
            t32 / te,
            fpga.values_per_line(bits as u32) as f64,
        ]);
    }

    print!("{}", t.pretty());
    t.write_to(&cfg.out_dir.join("fig6.csv"))?;
    println!("wrote fig6.csv to {:?} (paper headline: 2&8-bit ⇒ 9.19×)", cfg.out_dir);
    Ok(())
}
