//! Fig 1 / E1 — sky recoveries: ground truth vs dirty image vs 32-bit NIHT
//! vs 2&8-bit QNIHT, on the LOFAR-like station at 0 dB SNR.
//!
//! Emits `fig1.csv` (recovery error / support recovery / sources resolved
//! per method) and four PGM panels.

use crate::config::LpcsConfig;
use crate::io::{csv::CsvTable, pgm};
use crate::metrics;
use crate::solver::{Problem, Recovery, SolverKind};
use crate::telescope::{dirty, AstroProblem};
use anyhow::Result;
use std::sync::Arc;

pub fn run(cfg: &LpcsConfig) -> Result<()> {
    let p = AstroProblem::build(&cfg.astro, cfg.seed);
    let s = cfg.astro.sources;
    let r = cfg.astro.resolution;
    println!(
        "sky recovery: L={} antennas, {}x{} grid (N={}), {} sources, SNR {} dB, M={} stacked rows",
        cfg.astro.antennas, r, r, p.n(), s, cfg.astro.snr_db, p.m()
    );

    let dirty_img = dirty::dirty_image(&p.phi, &p.y);
    let problem = Problem::new(Arc::new(p.phi.clone()), p.y.clone(), s);
    let x32 = Recovery::problem(problem.clone())
        .solver(SolverKind::Niht)
        .options(cfg.solver.clone())
        .run()?
        .x;
    let xq = Recovery::problem(problem)
        .solver(SolverKind::Qniht {
            bits_phi: cfg.quant.bits_phi,
            bits_y: cfg.quant.bits_y,
            mode: cfg.quant.mode,
        })
        .options(cfg.solver.clone())
        .seed(cfg.seed)
        .run()?
        .x;

    let mut t = CsvTable::new(&[
        "method",
        "recovery_error",
        "support_recovery",
        "sources_resolved",
        "psnr_db",
    ]);
    let sources = &p.sky.sources;
    let mut add = |name: &str, x: &[f32]| {
        t.row_labeled(
            name,
            &[
                metrics::recovery_error(x, &p.x_true),
                metrics::exact_recovery_top_s(x, &p.x_true),
                metrics::sources_resolved(x, sources, r, 1, 0.5) as f64,
                metrics::psnr(x, &p.x_true),
            ],
        );
    };
    add("dirty(least-squares)", &dirty_img);
    add("niht_32bit", &x32);
    add(
        &format!("qniht_{}&{}bit", cfg.quant.bits_phi, cfg.quant.bits_y),
        &xq,
    );

    print!("{}", t.pretty());
    t.write_to(&cfg.out_dir.join("fig1.csv"))?;

    // Panels share the colour scale of the truth.
    let peak = p.x_true.iter().cloned().fold(0.0f32, f32::max);
    let range = Some((0.0, peak));
    pgm::write_pgm(&cfg.out_dir.join("fig1_truth.pgm"), &p.x_true, r, r, range)?;
    pgm::write_pgm(&cfg.out_dir.join("fig1_dirty.pgm"), &dirty_img, r, r, None)?;
    pgm::write_pgm(&cfg.out_dir.join("fig1_niht32.pgm"), &x32, r, r, range)?;
    pgm::write_pgm(&cfg.out_dir.join("fig1_qniht.pgm"), &xq, r, r, range)?;
    println!("wrote fig1.csv + 4 PGM panels to {:?}", cfg.out_dir);
    Ok(())
}
