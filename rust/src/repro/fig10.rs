//! Fig 10 / E-MRI — PSNR of partial-Fourier MRI recovery vs the bit
//! width of the low-precision sampling path: the paper's second
//! application (§10, brain-image recovery from undersampled Fourier
//! measurements), at the harness scale (64×64 phantom by default).
//!
//! The 32-bit row is the f32 matrix-free baseline; 8/4/2-bit rows run
//! [`crate::mri::lowprec_problem`] (observation + per-iteration k-space
//! traffic stochastically quantized with per-readout block scales). The
//! paper's qualitative claim — 8 bits is visually and quantitatively
//! indistinguishable from 32, with graceful degradation below — is what
//! the emitted curve (and the PGM panels) shows.

use crate::config::LpcsConfig;
use crate::io::{csv::CsvTable, pgm};
use crate::metrics;
use crate::mri::{self, MriConfig, MriProblem};
use crate::solver::{Problem, Recovery, SolverKind};
use anyhow::Result;

pub fn run(cfg: &LpcsConfig) -> Result<()> {
    let mri_cfg = MriConfig { resolution: cfg.mri.resolution.min(64), ..cfg.mri };
    let p = MriProblem::build(&mri_cfg, cfg.seed)?;
    println!(
        "MRI PSNR vs bits: {r}x{r} phantom, {kind} mask ({us:.1}% of k-space), s={s}",
        r = p.r,
        kind = p.op.mask().config().kind.name(),
        us = 100.0 * p.op.mask().undersampling(),
        s = p.s,
    );

    let range = Some((0.0f32, p.x_true.iter().cloned().fold(0.0, f32::max)));
    pgm::write_pgm(&cfg.out_dir.join("fig10_truth.pgm"), &p.x_true, p.r, p.r, range)?;

    let mut t = CsvTable::new(&["bits", "psnr_db", "rel_err", "iterations"]);
    let mut solve = |bits: u8| -> Result<()> {
        let problem = if bits == 32 {
            Problem::with_op(p.op.clone(), p.y.clone(), p.s)
        } else {
            mri::lowprec_problem(p.op.clone(), &p.y, p.s, bits, cfg.seed)
        };
        let report = Recovery::problem(problem)
            .solver(SolverKind::Niht)
            .options(cfg.solver.clone())
            .seed(cfg.seed)
            .run()?;
        t.row_f64(&[
            bits as f64,
            metrics::psnr(&report.x, &p.x_true),
            metrics::recovery_error(&report.x, &p.x_true),
            report.iterations as f64,
        ]);
        pgm::write_pgm(
            &cfg.out_dir.join(format!("fig10_recon_b{bits}.pgm")),
            &report.x,
            p.r,
            p.r,
            range,
        )?;
        Ok(())
    };
    for bits in [32u8, 8, 4, 2] {
        solve(bits)?;
    }
    print!("{}", t.pretty());
    t.write_to(&cfg.out_dir.join("fig10.csv"))?;
    println!("wrote fig10.csv and PGM panels to {:?}", cfg.out_dir);
    Ok(())
}
