//! Fig 8 / E7 — γ vs the number of antennas: employing more antennas
//! improves the RIP condition of the measurement matrix, lowering the bit
//! width Lemma 1 requires.

use crate::config::LpcsConfig;
use crate::io::csv::CsvTable;
use crate::rip;
use crate::rng::XorShift128Plus;
use crate::telescope::{steering, AntennaArray, ImageGrid};
use anyhow::Result;

pub fn run(cfg: &LpcsConfig) -> Result<()> {
    let r = cfg.astro.resolution.min(24);
    let grid = ImageGrid::new(r, cfg.astro.fov_half_width);
    let two_s = (2 * cfg.sparsity.min(8)).max(2);
    println!("γ vs antenna count (r={r}, d={}, |Γ|={two_s})", cfg.astro.fov_half_width);

    let mut t = CsvTable::new(&["antennas", "gamma_full", "gamma_probe_2s", "min_bits_lemma1"]);
    for l in [8usize, 12, 16, 20, 24, 28] {
        let mut rng = XorShift128Plus::new(cfg.seed ^ (l as u64));
        let array = AntennaArray::lofar_like(l, cfg.astro.freq_hz, &mut rng);
        let phi = steering::stacked_measurement_matrix_unique(&array, &grid);
        let gamma = rip::gamma_full(&phi, cfg.seed);
        let est = rip::ric_probe(&phi, two_s, 6, cfg.seed ^ (l as u64) << 3);
        let bits = rip::min_bits_for_matrix(est.gamma(), est.alpha as f64, two_s);
        t.row_f64(&[
            l as f64,
            gamma,
            est.gamma(),
            bits.map(|b| b as f64).unwrap_or(f64::NAN),
        ]);
    }
    print!("{}", t.pretty());
    t.write_to(&cfg.out_dir.join("fig8.csv"))?;
    println!("wrote fig8.csv to {:?}", cfg.out_dir);
    Ok(())
}
