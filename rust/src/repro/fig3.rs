//! Fig 3 / E2 — the recovery-bound coefficients √L/β₂ₛ (scaling the noise)
//! and L/β̂₂ₛ (scaling the quantization error ε_sky) over antenna count and
//! sparsity ratio s/M. The paper's conclusion: both coefficients are tiny,
//! so 2-bit quantization adds negligible error for interferometric imaging.

use crate::config::LpcsConfig;
use crate::io::csv::CsvTable;
use crate::quant::QuantizedMatrix;
use crate::rip;
use crate::rng::XorShift128Plus;
use crate::telescope::{steering, AntennaArray, ImageGrid};
use anyhow::Result;

pub fn run(cfg: &LpcsConfig) -> Result<()> {
    let grid = ImageGrid::new(cfg.astro.resolution.min(32), cfg.astro.fov_half_width);
    let antenna_counts = [10usize, 15, 20, 25, 30];
    let sparsity_ratios = [0.02f64, 0.05, 0.1, 0.2];
    let trials = 6;

    let mut t = CsvTable::new(&[
        "antennas",
        "sparsity_ratio",
        "s",
        "beta_2s",
        "beta_hat_2s_2bit",
        "sqrtL_over_beta",
        "L_over_beta_hat",
    ]);

    for &l in &antenna_counts {
        let mut rng = XorShift128Plus::new(cfg.seed ^ (l as u64) << 8);
        let array = AntennaArray::lofar_like(l, cfg.astro.freq_hz, &mut rng);
        let phi = steering::stacked_measurement_matrix_unique(&array, &grid);
        let m_complex = l * (l - 1) / 2;
        let qm = QuantizedMatrix::from_mat(&phi, 2, &mut rng);
        let phi_hat = qm.to_mat();
        for &ratio in &sparsity_ratios {
            let s = ((ratio * m_complex as f64).round() as usize).max(1);
            let two_s = (2 * s).min(phi.cols);
            let est = rip::ric_probe(&phi, two_s, trials, cfg.seed ^ (s as u64));
            let est_hat = rip::ric_probe(&phi_hat, two_s, trials, cfg.seed ^ (s as u64) ^ 0xAA);
            let (c_noise, c_sky) =
                rip::sky_coefficients(l, est.beta as f64, est_hat.beta as f64);
            t.row_f64(&[
                l as f64,
                ratio,
                s as f64,
                est.beta as f64,
                est_hat.beta as f64,
                c_noise,
                c_sky,
            ]);
        }
    }
    print!("{}", t.pretty());
    t.write_to(&cfg.out_dir.join("fig3.csv"))?;
    println!("wrote fig3.csv to {:?}", cfg.out_dir);
    Ok(())
}
