//! Fig 7 / E6 — γ of the measurement matrix vs the grid parameter d (the
//! FoV half width), and the Lemma-1 minimum bit width that keeps
//! γ̂ ≤ 1/16. The paper's point: d is an instrument knob that tunes the
//! RIP constants, and a properly designed Φ admits 2-bit quantization.

use crate::config::LpcsConfig;
use crate::io::csv::CsvTable;
use crate::rip;
use crate::rng::XorShift128Plus;
use crate::telescope::{steering, AntennaArray, ImageGrid};
use anyhow::Result;

pub fn run(cfg: &LpcsConfig) -> Result<()> {
    let l = cfg.astro.antennas.min(20);
    let r = cfg.astro.resolution.min(24);
    let mut rng = XorShift128Plus::new(cfg.seed);
    let array = AntennaArray::lofar_like(l, cfg.astro.freq_hz, &mut rng);
    let two_s = (2 * cfg.sparsity.min(8)).max(2);

    println!("γ vs grid half-width d (L={l}, r={r}, |Γ|={two_s}); γ target ≤ 1/16 = 0.0625");
    let mut t = CsvTable::new(&["d", "gamma_full", "alpha_probe", "gamma_probe_2s", "min_bits_lemma1"]);
    for d in [0.1f64, 0.2, 0.3, 0.4, 0.55, 0.7, 0.85, 0.99] {
        let grid = ImageGrid::new(r, d);
        let phi = steering::stacked_measurement_matrix_unique(&array, &grid);
        let gamma = rip::gamma_full(&phi, cfg.seed);
        // Empirical RIC over supports of size 2s — the quantity the theorem
        // actually needs (the full-matrix γ is an upper bound).
        let est = rip::ric_probe(&phi, two_s, 6, cfg.seed ^ (d * 100.0) as u64);
        let bits = rip::min_bits_for_matrix(est.gamma(), est.alpha as f64, two_s);
        t.row_f64(&[
            d,
            gamma,
            est.alpha as f64,
            est.gamma(),
            bits.map(|b| b as f64).unwrap_or(f64::NAN),
        ]);
    }
    print!("{}", t.pretty());
    t.write_to(&cfg.out_dir.join("fig7.csv"))?;
    println!("wrote fig7.csv to {:?}", cfg.out_dir);
    Ok(())
}
