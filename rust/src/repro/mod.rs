//! Figure-regeneration harness (S15): one driver per table/figure of the
//! paper's evaluation (see DESIGN.md §4 for the experiment index). Each
//! driver prints the series the paper plots and writes a CSV (plus PGMs for
//! the image figures) under the configured output directory.
//!
//! Scaled defaults: the paper's headline grid is 256×256 (N = 65,536) with
//! L = 30 antennas; the default harness scale is r = 32–64 so the full
//! suite runs in minutes on CPU. Every driver takes its scale from
//! [`LpcsConfig`], so paper-scale runs are a config flag away — the result
//! *shapes* are grid-size independent (verified by the r-sweep in fig1).

pub mod fig1;
pub mod fig10;
pub mod fig11;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;

use crate::config::LpcsConfig;
use anyhow::{bail, Result};

pub const ALL: &[&str] =
    &["fig1", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11"];

/// Run one figure driver (or `all`).
pub fn run(which: &str, cfg: &LpcsConfig) -> Result<()> {
    match which {
        "fig1" => fig1::run(cfg),
        "fig3" => fig3::run(cfg),
        "fig4" => fig4::run(cfg),
        "fig5" => fig5::run(cfg),
        "fig6" => fig6::run(cfg),
        "fig7" => fig7::run(cfg),
        "fig8" => fig8::run(cfg),
        "fig9" => fig9::run(cfg),
        "fig10" => fig10::run(cfg),
        "fig11" => fig11::run(cfg),
        "all" => {
            for f in ALL {
                println!("\n=== {f} ===");
                run(f, cfg)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment '{other}' (expected one of {ALL:?} or 'all')"),
    }
}

/// Iterations a solver needed to first reach `target` under an arbitrary
/// quality metric (re-runs with growing budgets + binary-search refine).
pub fn iterations_to_target(
    mut solve_k: impl FnMut(usize) -> Vec<f32>,
    metric: impl Fn(&[f32]) -> f64,
    target: f64,
    max_iters: usize,
) -> Option<usize> {
    let mut k = 1usize;
    while k <= max_iters {
        let x = solve_k(k);
        if metric(&x) >= target {
            // refine: binary search in (k/2, k]
            let mut lo = k / 2;
            let mut hi = k;
            while hi - lo > 1 {
                let mid = (lo + hi) / 2;
                let xm = solve_k(mid);
                if metric(&xm) >= target {
                    hi = mid;
                } else {
                    lo = mid;
                }
            }
            return Some(hi);
        }
        k *= 2;
    }
    None
}

/// Iterations to the given exact-support-recovery ratio (the paper's Fig
/// 5/6 "time to 90% support recovery" metric; appropriate for Gaussian
/// problems).
pub fn iterations_to_support_recovery(
    solve_k: impl FnMut(usize) -> Vec<f32>,
    x_true: &[f32],
    target: f64,
    max_iters: usize,
) -> Option<usize> {
    iterations_to_target(
        solve_k,
        |x| crate::metrics::exact_recovery(x, x_true),
        target,
        max_iters,
    )
}

/// Iterations to resolve the given fraction of sky sources (1-pixel
/// tolerance — adjacent steering columns are nearly coherent, so exact
/// pixel-index support is the wrong metric for interferometric grids; the
/// paper makes the same point about "true celestial sources resolved").
pub fn iterations_to_sources_resolved(
    solve_k: impl FnMut(usize) -> Vec<f32>,
    sources: &[(usize, f32)],
    resolution: usize,
    target: f64,
    max_iters: usize,
) -> Option<usize> {
    let total = sources.len().max(1) as f64;
    iterations_to_target(
        solve_k,
        |x| crate::metrics::sources_resolved(x, sources, resolution, 1, 0.4) as f64 / total,
        target,
        max_iters,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iterations_to_recovery_binary_search() {
        // Fake solver: recovers the support exactly from iteration 7 on.
        let x_true = vec![1.0, 0.0, 1.0];
        let solve_k = |k: usize| {
            if k >= 7 {
                vec![1.0, 0.0, 1.0]
            } else {
                vec![0.0, 1.0, 0.0]
            }
        };
        assert_eq!(iterations_to_support_recovery(solve_k, &x_true, 0.9, 100), Some(7));
    }

    #[test]
    fn iterations_to_recovery_none_when_unreachable() {
        let x_true = vec![1.0, 0.0];
        let solve_k = |_k: usize| vec![0.0, 1.0];
        assert_eq!(iterations_to_support_recovery(solve_k, &x_true, 0.9, 32), None);
    }

    #[test]
    fn unknown_figure_rejected() {
        let cfg = LpcsConfig::default();
        assert!(run("fig99", &cfg).is_err());
    }
}
