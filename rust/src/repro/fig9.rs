//! Fig 9 / E8 — CLEAN vs low-precision IHT at 0 dB SNR: CLEAN latches onto
//! noise artefacts as sources ("an execution of CLEAN corresponds to the
//! first iteration recovery of IHT"), while IHT's global least-squares
//! refinement suppresses them.

use crate::algorithms::clean::{clean, components_to_sky, CleanOptions};
use crate::config::LpcsConfig;
use crate::io::{csv::CsvTable, pgm};
use crate::metrics;
use crate::solver::{Problem, Recovery, SolverKind};
use crate::telescope::{dirty, AstroConfig, AstroProblem};
use anyhow::Result;

pub fn run(cfg: &LpcsConfig) -> Result<()> {
    let astro = AstroConfig {
        resolution: cfg.astro.resolution.min(32),
        sources: cfg.astro.sources.min(10),
        ..cfg.astro.clone()
    };
    let p = AstroProblem::build(&astro, cfg.seed);
    let r = astro.resolution;
    let s = astro.sources;
    println!("CLEAN vs {}&{}-bit IHT at {} dB SNR, {} true sources",
        cfg.quant.bits_phi, cfg.quant.bits_y, astro.snr_db, s);

    // CLEAN on the dirty image.
    let img = dirty::dirty_image(&p.phi, &p.y);
    let beam = dirty::dirty_beam(&p.array, &p.grid);
    let cl = clean(&img, &beam, r, &CleanOptions::default());
    let x_clean = components_to_sky(&cl.components, p.n());

    // Low-precision IHT, via the facade.
    let x_iht = Recovery::problem(Problem::from_mat(p.phi.clone(), p.y.clone(), s))
        .solver(SolverKind::Qniht {
            bits_phi: cfg.quant.bits_phi,
            bits_y: cfg.quant.bits_y,
            mode: cfg.quant.mode,
        })
        .options(cfg.solver.clone())
        .seed(cfg.seed)
        .run()?
        .x;

    let floor = 0.25 * p.sky.sources.iter().map(|&(_, f)| f).fold(f32::MAX, f32::min);
    let mut t = CsvTable::new(&[
        "method",
        "components",
        "sources_resolved",
        "false_positives",
        "recovery_error",
    ]);
    t.row(&[
        "clean".to_string(),
        cl.components.len().to_string(),
        metrics::sources_resolved(&x_clean, &p.sky.sources, r, 1, 0.4).to_string(),
        metrics::false_positives(&x_clean, &p.sky.sources, r, 1, floor).to_string(),
        format!("{:.4}", metrics::recovery_error(&x_clean, &p.x_true)),
    ]);
    let iht_components = x_iht.iter().filter(|&&v| v.abs() > 0.0).count();
    t.row(&[
        "qniht".to_string(),
        iht_components.to_string(),
        metrics::sources_resolved(&x_iht, &p.sky.sources, r, 1, 0.4).to_string(),
        metrics::false_positives(&x_iht, &p.sky.sources, r, 1, floor).to_string(),
        format!("{:.4}", metrics::recovery_error(&x_iht, &p.x_true)),
    ]);

    print!("{}", t.pretty());
    t.write_to(&cfg.out_dir.join("fig9.csv"))?;
    let peak = p.x_true.iter().cloned().fold(0.0f32, f32::max);
    pgm::write_pgm(&cfg.out_dir.join("fig9_clean.pgm"), &x_clean, r, r, Some((0.0, peak)))?;
    pgm::write_pgm(&cfg.out_dir.join("fig9_iht.pgm"), &x_iht, r, r, Some((0.0, peak)))?;
    println!("wrote fig9.csv + 2 PGM panels to {:?}", cfg.out_dir);
    Ok(())
}
