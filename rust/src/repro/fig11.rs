//! Fig 11 / E9 — the Gaussian toy example (paper §10): Φ ∈ ℝ^{256×512}
//! iid N(0,1), observations at a range of SNRs, 100 realizations.
//! Reports mean recovery error ‖x−xˢ‖/‖xˢ‖ and exact support recovery for
//! 32-bit NIHT vs 2&8-bit QNIHT. Expected shape: 2&8-bit slightly worse,
//! equally robust to noise.

use crate::config::LpcsConfig;
use crate::io::csv::CsvTable;
use crate::linalg::Mat;
use crate::metrics;
use crate::rng::XorShift128Plus;
use crate::solver::{Problem, Recovery, SolverKind};
use anyhow::Result;

pub fn run(cfg: &LpcsConfig) -> Result<()> {
    let (m, n, s) = (256usize, 512usize, 16usize);
    let realizations =
        std::env::var("LPCS_FIG11_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(100usize);
    let snrs_db = [-10.0f64, -5.0, 0.0, 5.0, 10.0, 20.0];
    println!("Gaussian toy: Φ∈R^{{{m}x{n}}}, s={s}, {realizations} realizations per SNR");

    let mut t = CsvTable::new(&[
        "snr_db",
        "err_32bit",
        "exact_32bit",
        "err_2_8bit",
        "exact_2_8bit",
    ]);

    for &snr in &snrs_db {
        let mut acc = [0.0f64; 4];
        for rep in 0..realizations {
            let mut rng = XorShift128Plus::new(cfg.seed ^ ((snr as i64 as u64) << 24) ^ rep as u64);
            let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32());
            let mut x = vec![0.0f32; n];
            for i in rng.choose_k(n, s) {
                x[i] = rng.gaussian_f32();
            }
            let clean = phi.matvec(&x);
            let sig_p = crate::linalg::norm2_sq(&clean) as f64;
            let noise_p = sig_p / 10f64.powf(snr / 10.0);
            let sd = (noise_p / m as f64).sqrt() as f32;
            let y: Vec<f32> = clean.iter().map(|v| v + sd * rng.gaussian_f32()).collect();

            let problem = Problem::from_mat(phi, y, s);
            let x32 = Recovery::problem(problem.clone())
                .solver(SolverKind::Niht)
                .options(cfg.solver.clone())
                .run()?
                .x;
            let xq = Recovery::problem(problem)
                .solver(SolverKind::qniht_fresh(2, 8))
                .options(cfg.solver.clone())
                .seed(rep as u64)
                .run()?
                .x;
            acc[0] += metrics::recovery_error(&x32, &x);
            acc[1] += metrics::exact_recovery(&x32, &x);
            acc[2] += metrics::recovery_error(&xq, &x);
            acc[3] += metrics::exact_recovery(&xq, &x);
        }
        let r = realizations as f64;
        t.row_f64(&[snr, acc[0] / r, acc[1] / r, acc[2] / r, acc[3] / r]);
    }

    print!("{}", t.pretty());
    t.write_to(&cfg.out_dir.join("fig11.csv"))?;
    println!("wrote fig11.csv to {:?}", cfg.out_dir);
    Ok(())
}
