//! Configuration system (S14): typed config with JSON file loading and
//! `key=value` CLI overrides. (The offline build vendors no TOML crate, so
//! the on-disk format is JSON via `io::json` — DESIGN.md §6.)

use crate::algorithms::qniht::RequantMode;
use crate::algorithms::SolveOptions;
use crate::io::json::Json;
use crate::mri::{MaskKind, MriConfig};
use crate::solver::SolverKind;
use crate::telescope::AstroConfig;
use anyhow::{anyhow, bail, Result};
use std::path::{Path, PathBuf};

/// Which execution engine runs the NIHT step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// Dense f32 rust kernels (32-bit baseline).
    NativeDense,
    /// int8 quantized rust kernels (the paper's low-precision path).
    NativeQuant,
    /// PJRT executables from the JAX/Pallas AOT artifacts.
    XlaQuant,
    /// PJRT dense f32 artifact.
    XlaDense,
    /// The quantized native kernels with wall time charged from the §8
    /// FPGA bandwidth model ([`crate::perfmodel::fpga::FpgaModel`]):
    /// answers "what would this job cost on the FPGA at 2/4/8 bits?".
    FpgaModel,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "native-dense" | "dense" => Self::NativeDense,
            "native-quant" | "quant" | "native" => Self::NativeQuant,
            "xla-quant" | "xla" => Self::XlaQuant,
            "xla-dense" => Self::XlaDense,
            "fpga-model" | "fpga" => Self::FpgaModel,
            other => bail!(
                "unknown engine '{other}' (native-dense|native-quant|xla-quant|xla-dense|fpga-model)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::NativeDense => "native-dense",
            Self::NativeQuant => "native-quant",
            Self::XlaQuant => "xla-quant",
            Self::XlaDense => "xla-dense",
            Self::FpgaModel => "fpga-model",
        }
    }

    /// Whether this engine executes quantized (low-precision) kernels —
    /// decides whether a job's default solver is QNIHT or dense NIHT.
    pub fn is_quantized(&self) -> bool {
        matches!(self, Self::NativeQuant | Self::XlaQuant | Self::FpgaModel)
    }
}

/// Algorithm selector for the CLI/config (`algorithm` key): picks the
/// facade [`SolverKind`] the `solve`/`serve` commands run. The
/// quantization parameters of QNIHT come from [`QuantConfig`], so this
/// stays a flat name on the command line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlgoKind {
    Niht,
    Iht,
    Qniht,
    Cosamp,
    Fista,
}

impl AlgoKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "niht" => Self::Niht,
            "iht" => Self::Iht,
            "qniht" => Self::Qniht,
            "cosamp" => Self::Cosamp,
            "fista" => Self::Fista,
            // ("auto" is not an AlgoKind: the config layer maps it to
            // `algorithm = None` before calling parse.)
            other => bail!("unknown algorithm '{other}' (niht|iht|qniht|cosamp|fista)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Niht => "niht",
            Self::Iht => "iht",
            Self::Qniht => "qniht",
            Self::Cosamp => "cosamp",
            Self::Fista => "fista",
        }
    }
}

/// Quantization settings.
#[derive(Debug, Clone, Copy)]
pub struct QuantConfig {
    pub bits_phi: u8,
    pub bits_y: u8,
    pub mode: RequantMode,
}

impl Default for QuantConfig {
    fn default() -> Self {
        Self { bits_phi: 2, bits_y: 8, mode: RequantMode::Fixed }
    }
}

/// Recovery-service settings.
#[derive(Debug, Clone, Copy)]
pub struct ServiceConfig {
    pub workers: usize,
    pub queue_capacity: usize,
    pub max_batch: usize,
    pub max_wait_ms: u64,
    /// How many queued jobs a worker snapshots per scheduling decision
    /// (the cost-aware scheduler reorders batches inside this window; the
    /// effective window is never smaller than `max_batch`).
    pub sched_window: usize,
    /// Starvation bound for the scheduler: a batch whose oldest job has
    /// waited at least this long dispatches ahead of every cheaper batch.
    pub starvation_ms: u64,
    /// Let the cost-aware scheduler EWMA-calibrate per-`BatchKey` costs
    /// from the observed setup/execution timings
    /// (`sched::CostModel::observe`). `false` freezes the model at its
    /// static nominal-iteration estimate — what deterministic tests and
    /// reproducible scheduling traces want.
    pub calibrate_cost: bool,
    /// Persist the calibrated cost model across restarts: graceful
    /// shutdown writes the observed per-cost-class EWMAs to
    /// `<artifact_dir>/cost_model.v1` and the next boot warm-starts the
    /// scheduler from it (corrupt file ⇒ counted cold start). Only
    /// meaningful with `calibrate_cost`; off by default so tests and
    /// benches stay hermetic.
    pub persist_cost: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_capacity: 256,
            max_batch: 8,
            max_wait_ms: 5,
            sched_window: 16,
            starvation_ms: 250,
            calibrate_cost: true,
            persist_cost: false,
        }
    }
}

/// Wire-protocol settings (the network face of the recovery service).
#[derive(Debug, Clone)]
pub struct WireConfig {
    /// Listen address for `lpcs serve` (e.g. `127.0.0.1:7070`; port 0
    /// binds an ephemeral port). Empty = stay in-process (the classic
    /// synthetic-stream demo).
    pub listen: String,
    /// Per-subscriber progress-queue depth: stats beyond this are shed
    /// oldest-first rather than ever blocking a worker on a slow client.
    pub sub_depth: usize,
}

impl Default for WireConfig {
    fn default() -> Self {
        Self { listen: String::new(), sub_depth: 64 }
    }
}

/// Router-tier settings (`lpcs route`): the sharded serving front end
/// that consistent-hashes jobs across several `lpcs serve` backends.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Backend `lpcs serve` addresses to shard over. Set with
    /// `router.backends=a:1,b:2` or accumulated one at a time with the
    /// `backend=` alias.
    pub backends: Vec<String>,
    /// Health-probe period in milliseconds.
    pub probe_ms: u64,
    /// Per-probe connect/reply deadline in milliseconds; also bounds
    /// each forwarded submit, so a dead backend fails over quickly
    /// instead of stalling the client behind a kernel TCP timeout.
    pub probe_timeout_ms: u64,
    /// Consecutive probe failures before a backend is marked down and
    /// removed from the hash ring (re-admitted on the next success).
    pub down_after: u32,
    /// Admission bound on the router's in-flight job table: submits
    /// beyond it are rejected with a typed `queue-full` error.
    pub max_inflight: usize,
    /// Reject a submit whose chosen backend last probed at least this
    /// many queued jobs (0 = disabled — backends still enforce their own
    /// capacity, which the router propagates typed).
    pub queue_limit: usize,
    /// Virtual nodes per backend on the consistent-hash ring.
    pub vnodes: usize,
    /// Route by operator/batch-key hash (default) so same-Φ jobs land on
    /// one backend and keep batching; `false` = round-robin (the bench
    /// baseline that destroys batch affinity).
    pub affinity: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            backends: Vec::new(),
            probe_ms: 250,
            probe_timeout_ms: 1000,
            down_after: 2,
            max_inflight: 1024,
            queue_limit: 0,
            vnodes: 64,
            affinity: true,
        }
    }
}

/// Top-level configuration.
#[derive(Debug, Clone)]
pub struct LpcsConfig {
    pub artifact_dir: PathBuf,
    pub out_dir: PathBuf,
    pub seed: u64,
    pub sparsity: usize,
    pub engine: EngineKind,
    /// Explicit algorithm selection; `None` infers from the engine
    /// (quantized engines → QNIHT, dense → NIHT) exactly as the
    /// coordinator's pre-PR-3 default did.
    pub algorithm: Option<AlgoKind>,
    pub quant: QuantConfig,
    pub solver: SolveOptions,
    pub astro: AstroConfig,
    pub mri: MriConfig,
    pub service: ServiceConfig,
    pub wire: WireConfig,
    pub router: RouterConfig,
}

impl Default for LpcsConfig {
    fn default() -> Self {
        Self {
            artifact_dir: PathBuf::from("artifacts"),
            out_dir: PathBuf::from("results"),
            seed: 7,
            sparsity: 30,
            engine: EngineKind::NativeQuant,
            algorithm: None,
            quant: QuantConfig::default(),
            solver: SolveOptions::default(),
            astro: AstroConfig::default(),
            mri: MriConfig::default(),
            service: ServiceConfig::default(),
            wire: WireConfig::default(),
            router: RouterConfig::default(),
        }
    }
}

impl LpcsConfig {
    /// Load from a JSON file; missing keys keep defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading config {path:?}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing config {path:?}: {e}"))?;
        let mut cfg = Self::default();
        let obj = j.as_obj().ok_or_else(|| anyhow!("config root must be an object"))?;
        for (k, v) in obj {
            cfg.apply_json(k, v)?;
        }
        Ok(cfg)
    }

    fn apply_json(&mut self, key: &str, v: &Json) -> Result<()> {
        let sv = v.dump();
        let sv = sv.trim_matches('"');
        self.set(key, sv)
    }

    /// Apply one `key=value` override (dotted keys).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let vf = || -> Result<f64> {
            value.parse::<f64>().map_err(|_| anyhow!("'{key}': expected a number, got '{value}'"))
        };
        match key {
            "artifact_dir" => self.artifact_dir = PathBuf::from(value),
            "out_dir" => self.out_dir = PathBuf::from(value),
            "seed" => self.seed = vf()? as u64,
            "sparsity" | "s" => self.sparsity = vf()? as usize,
            "engine" => self.engine = EngineKind::parse(value)?,
            "algorithm" | "solver.algorithm" => {
                self.algorithm =
                    if value == "auto" { None } else { Some(AlgoKind::parse(value)?) }
            }
            "quant.bits_phi" | "bits_phi" => self.quant.bits_phi = vf()? as u8,
            "quant.bits_y" | "bits_y" => self.quant.bits_y = vf()? as u8,
            "quant.mode" => {
                self.quant.mode = match value {
                    "fixed" => RequantMode::Fixed,
                    "fresh" => RequantMode::Fresh,
                    _ => bail!("quant.mode must be fixed|fresh"),
                }
            }
            "solver.max_iters" | "max_iters" => self.solver.max_iters = vf()? as usize,
            "solver.tol" => self.solver.tol = vf()? as f32,
            "solver.c" => self.solver.c = vf()? as f32,
            "solver.kappa" => self.solver.kappa = vf()? as f32,
            "solver.track_history" => self.solver.track_history = value == "true",
            "solver.max_shrinks_per_iter" => {
                self.solver.max_shrinks_per_iter = vf()? as usize
            }
            "mri.resolution" => self.mri.resolution = vf()? as usize,
            "mri.mask" => self.mri.mask.kind = MaskKind::parse(value)?,
            "mri.fraction" => self.mri.mask.fraction = vf()? as f32,
            "mri.center_band" => self.mri.mask.center_band = vf()? as usize,
            "mri.bits" => self.mri.bits = vf()? as u8,
            "mri.sparsity" => self.mri.sparsity = vf()? as usize,
            "astro.antennas" => self.astro.antennas = vf()? as usize,
            "astro.resolution" => self.astro.resolution = vf()? as usize,
            "astro.fov_half_width" => self.astro.fov_half_width = vf()?,
            "astro.sources" => self.astro.sources = vf()? as usize,
            "astro.snr_db" => self.astro.snr_db = vf()?,
            "astro.freq_hz" => self.astro.freq_hz = vf()?,
            "astro.bits" => self.astro.bits = vf()? as u8,
            "astro.sparsity" => self.astro.sparsity = vf()? as usize,
            "astro.full_baselines" => self.astro.full_baselines = value == "true",
            "service.workers" => self.service.workers = vf()? as usize,
            "service.queue_capacity" => self.service.queue_capacity = vf()? as usize,
            "service.max_batch" => self.service.max_batch = vf()? as usize,
            "service.max_wait_ms" => self.service.max_wait_ms = vf()? as u64,
            "service.sched_window" => self.service.sched_window = vf()? as usize,
            "service.starvation_ms" => self.service.starvation_ms = vf()? as u64,
            "service.calibrate_cost" => self.service.calibrate_cost = value == "true",
            "service.persist_cost" => self.service.persist_cost = value == "true",
            "wire.listen" | "listen" => self.wire.listen = value.to_string(),
            "wire.sub_depth" => self.wire.sub_depth = vf()? as usize,
            "router.backends" => {
                self.router.backends =
                    value.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect()
            }
            // Accumulating alias: `backend=a:1 backend=b:2` appends.
            "backend" | "router.backend" => self.router.backends.push(value.to_string()),
            "router.probe_ms" => self.router.probe_ms = vf()? as u64,
            "router.probe_timeout_ms" => self.router.probe_timeout_ms = vf()? as u64,
            "router.down_after" => self.router.down_after = vf()? as u32,
            "router.max_inflight" => self.router.max_inflight = vf()? as usize,
            "router.queue_limit" => self.router.queue_limit = vf()? as usize,
            "router.vnodes" => self.router.vnodes = vf()? as usize,
            "router.affinity" => self.router.affinity = value == "true",
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// The facade [`SolverKind`] this config selects: the explicit
    /// `algorithm` key when present, otherwise inferred from the engine
    /// (quantized → QNIHT at the configured bits/mode, dense → NIHT).
    pub fn solver_kind(&self) -> SolverKind {
        let algo = self.algorithm.unwrap_or(if self.engine.is_quantized() {
            AlgoKind::Qniht
        } else {
            AlgoKind::Niht
        });
        match algo {
            AlgoKind::Niht => SolverKind::Niht,
            AlgoKind::Iht => SolverKind::Iht,
            AlgoKind::Qniht => SolverKind::Qniht {
                bits_phi: self.quant.bits_phi,
                bits_y: self.quant.bits_y,
                mode: self.quant.mode,
            },
            AlgoKind::Cosamp => SolverKind::Cosamp,
            AlgoKind::Fista => SolverKind::Fista { lambda: None, debias: true },
        }
    }

    /// Validate cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        if !(2..=8).contains(&self.quant.bits_phi) || !(2..=8).contains(&self.quant.bits_y) {
            bail!("bit widths must be in 2..=8");
        }
        if self.sparsity == 0 {
            bail!("sparsity must be >= 1");
        }
        if self.solver.kappa <= 1.0 / (1.0 - self.solver.c) {
            bail!("Algorithm 1 requires kappa > 1/(1-c)");
        }
        if self.service.workers == 0 || self.service.max_batch == 0 {
            bail!("service.workers and service.max_batch must be >= 1");
        }
        if self.service.sched_window == 0 {
            bail!("service.sched_window must be >= 1");
        }
        if self.wire.sub_depth == 0 {
            bail!("wire.sub_depth must be >= 1 (progress queues need room for one stat)");
        }
        if self.router.vnodes == 0 || self.router.max_inflight == 0 || self.router.down_after == 0
        {
            bail!("router.vnodes, router.max_inflight and router.down_after must be >= 1");
        }
        // The MRI mask gate (fraction ∈ (0,1], centre band ≥ 1, packed
        // bit widths) — same check the coordinator re-runs at submit.
        self.mri.validate()?;
        // The telescope gate (station size, grid, packed bit widths) —
        // same check `SkyProblem::build` and the submit face re-run.
        self.astro.validate()?;
        let solver = self.solver_kind();
        if !solver.runs_on(self.engine) {
            bail!(
                "algorithm '{}' cannot run on engine '{}' (quantized engines run qniht; \
                 native-dense runs the full-precision algorithms; xla-dense runs niht)",
                solver.name(),
                self.engine.name()
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        LpcsConfig::default().validate().unwrap();
    }

    #[test]
    fn set_overrides() {
        let mut c = LpcsConfig::default();
        c.set("bits_phi", "4").unwrap();
        c.set("engine", "xla-quant").unwrap();
        c.set("astro.resolution", "128").unwrap();
        c.set("astro.bits", "2").unwrap();
        c.set("astro.sparsity", "12").unwrap();
        c.set("astro.full_baselines", "true").unwrap();
        c.set("quant.mode", "fresh").unwrap();
        c.set("solver.max_shrinks_per_iter", "7").unwrap();
        assert_eq!(c.quant.bits_phi, 4);
        assert_eq!(c.engine, EngineKind::XlaQuant);
        assert_eq!(c.astro.resolution, 128);
        assert_eq!(c.astro.bits, 2);
        assert_eq!(c.astro.sparsity, 12);
        assert!(c.astro.full_baselines);
        assert_eq!(c.quant.mode, RequantMode::Fresh);
        assert_eq!(c.solver.max_shrinks_per_iter, 7);
        // The astro gate rides config-level validate (on a fresh config:
        // `c` above pairs xla-quant with fresh requantization, which the
        // engine gate rejects on its own).
        let mut v = LpcsConfig::default();
        v.set("astro.bits", "2").unwrap();
        v.validate().unwrap();
        v.set("astro.bits", "5").unwrap();
        assert!(v.validate().unwrap_err().to_string().contains("astro.bits"));
    }

    #[test]
    fn quantized_engine_classification() {
        assert!(EngineKind::NativeQuant.is_quantized());
        assert!(EngineKind::XlaQuant.is_quantized());
        assert!(EngineKind::FpgaModel.is_quantized());
        assert!(!EngineKind::NativeDense.is_quantized());
        assert!(!EngineKind::XlaDense.is_quantized());
    }

    #[test]
    fn algorithm_key_selects_solver_kind() {
        let mut c = LpcsConfig::default();
        // Inference preserved: quantized engine → qniht, dense → niht.
        assert_eq!(c.solver_kind().name(), "qniht");
        c.set("engine", "native-dense").unwrap();
        assert_eq!(c.solver_kind().name(), "niht");
        // Explicit selection wins, and carries the quant config for qniht.
        c.set("algorithm", "cosamp").unwrap();
        assert_eq!(c.solver_kind().name(), "cosamp");
        c.validate().unwrap();
        c.set("engine", "fpga-model").unwrap();
        c.set("algorithm", "qniht").unwrap();
        c.set("bits_phi", "4").unwrap();
        assert_eq!(
            c.solver_kind(),
            SolverKind::Qniht { bits_phi: 4, bits_y: 8, mode: RequantMode::Fixed }
        );
        c.validate().unwrap();
        // auto resets to inference.
        c.set("algorithm", "auto").unwrap();
        assert!(c.algorithm.is_none());
        assert!(AlgoKind::parse("warp").is_err());
    }

    #[test]
    fn algorithm_engine_mismatch_rejected() {
        let mut c = LpcsConfig::default();
        c.set("algorithm", "cosamp").unwrap();
        // cosamp on the (default) quantized engine is a config error.
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("cannot run on engine"), "{err}");
        c.set("engine", "native-dense").unwrap();
        c.validate().unwrap();
    }

    #[test]
    fn wire_keys_roundtrip_and_validate() {
        let mut c = LpcsConfig::default();
        assert!(c.wire.listen.is_empty(), "default stays in-process");
        c.set("wire.listen", "127.0.0.1:7070").unwrap();
        c.set("wire.sub_depth", "8").unwrap();
        assert_eq!(c.wire.listen, "127.0.0.1:7070");
        assert_eq!(c.wire.sub_depth, 8);
        c.validate().unwrap();
        // `--listen` is the CLI-facing alias.
        c.set("listen", "0.0.0.0:9000").unwrap();
        assert_eq!(c.wire.listen, "0.0.0.0:9000");
        c.set("wire.sub_depth", "0").unwrap();
        assert!(c.validate().unwrap_err().to_string().contains("sub_depth"));
    }

    #[test]
    fn router_keys_roundtrip_and_validate() {
        let mut c = LpcsConfig::default();
        assert!(c.router.backends.is_empty());
        c.set("router.backends", "127.0.0.1:1, 127.0.0.1:2").unwrap();
        assert_eq!(c.router.backends, vec!["127.0.0.1:1", "127.0.0.1:2"]);
        // The accumulating alias appends (one flag per backend).
        c.set("backend", "127.0.0.1:3").unwrap();
        assert_eq!(c.router.backends.len(), 3);
        c.set("router.probe_ms", "100").unwrap();
        c.set("router.probe_timeout_ms", "500").unwrap();
        c.set("router.down_after", "3").unwrap();
        c.set("router.max_inflight", "16").unwrap();
        c.set("router.queue_limit", "8").unwrap();
        c.set("router.vnodes", "32").unwrap();
        c.set("router.affinity", "false").unwrap();
        assert_eq!(c.router.probe_ms, 100);
        assert_eq!(c.router.probe_timeout_ms, 500);
        assert_eq!(c.router.down_after, 3);
        assert_eq!(c.router.max_inflight, 16);
        assert_eq!(c.router.queue_limit, 8);
        assert_eq!(c.router.vnodes, 32);
        assert!(!c.router.affinity);
        c.validate().unwrap();
        c.set("router.vnodes", "0").unwrap();
        assert!(c.validate().unwrap_err().to_string().contains("router.vnodes"));
    }

    #[test]
    fn scheduler_keys_roundtrip() {
        let mut c = LpcsConfig::default();
        c.set("service.sched_window", "32").unwrap();
        c.set("service.starvation_ms", "100").unwrap();
        assert_eq!(c.service.sched_window, 32);
        assert_eq!(c.service.starvation_ms, 100);
        assert!(c.service.calibrate_cost, "calibration defaults on");
        c.set("service.calibrate_cost", "false").unwrap();
        assert!(!c.service.calibrate_cost);
        assert!(!c.service.persist_cost, "persistence defaults off");
        c.set("service.persist_cost", "true").unwrap();
        assert!(c.service.persist_cost);
        c.set("service.sched_window", "0").unwrap();
        assert!(c.validate().is_err());
    }

    #[test]
    fn mri_keys_roundtrip_and_validate() {
        let mut c = LpcsConfig::default();
        c.set("mri.resolution", "32").unwrap();
        c.set("mri.mask", "radial").unwrap();
        c.set("mri.fraction", "0.3").unwrap();
        c.set("mri.center_band", "2").unwrap();
        c.set("mri.bits", "4").unwrap();
        c.set("mri.sparsity", "64").unwrap();
        assert_eq!(c.mri.resolution, 32);
        assert_eq!(c.mri.mask.kind, MaskKind::Radial);
        assert!((c.mri.mask.fraction - 0.3).abs() < 1e-6);
        assert_eq!(c.mri.mask.center_band, 2);
        assert_eq!(c.mri.bits, 4);
        assert_eq!(c.mri.sparsity, 64);
        c.validate().unwrap();
        assert!(MaskKind::parse("spiral").is_err());

        // Invalid mask parameters are rejected at config validation with
        // a clear message (the same gate the service applies at submit).
        c.set("mri.fraction", "1.5").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("fraction"), "{err}");
        c.set("mri.fraction", "0.4").unwrap();
        c.set("mri.center_band", "0").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("center_band"), "{err}");
        c.set("mri.center_band", "4").unwrap();
        c.set("mri.bits", "3").unwrap();
        assert!(c.validate().is_err());
        c.set("mri.bits", "0").unwrap();
        c.validate().unwrap();
        // Non-power-of-two grids cannot feed the radix-2 FFT.
        c.set("mri.resolution", "48").unwrap();
        assert!(c.validate().is_err());
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(LpcsConfig::default().set("nope", "1").is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        let mut c = LpcsConfig::default();
        assert!(c.set("bits_phi", "abc").is_err());
        c.set("bits_phi", "1").unwrap();
        assert!(c.validate().is_err());
    }

    #[test]
    fn from_file_roundtrip() {
        let dir = std::env::temp_dir().join("lpcs_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"bits_phi": 4, "engine": "native-dense", "seed": 99}"#).unwrap();
        let c = LpcsConfig::from_file(&p).unwrap();
        assert_eq!(c.quant.bits_phi, 4);
        assert_eq!(c.engine, EngineKind::NativeDense);
        assert_eq!(c.seed, 99);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn engine_parse_names() {
        for k in ["native-dense", "native-quant", "xla-quant", "xla-dense", "fpga-model"] {
            assert_eq!(EngineKind::parse(k).unwrap().name(), k);
        }
        assert!(EngineKind::parse("gpu").is_err());
    }
}
