//! Recovery metrics (S9): everything Figs 1, 4, 9, 11 report.

use crate::algorithms::support::{support_intersection, support_of, top_s_indices};

/// Relative recovery error ‖x̂ − x‖₂ / ‖x‖₂ (Fig 11's metric).
pub fn recovery_error(x_hat: &[f32], x_true: &[f32]) -> f64 {
    assert_eq!(x_hat.len(), x_true.len());
    let num: f64 = x_hat
        .iter()
        .zip(x_true)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    let den: f64 = x_true.iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt();
    if den == 0.0 {
        if num == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        num / den
    }
}

/// Exact (support) recovery: |supp(x̂) ∩ supp(x)| / |supp(x)| (Fig 4/11).
pub fn exact_recovery(x_hat: &[f32], x_true: &[f32]) -> f64 {
    let st = support_of(x_true);
    if st.is_empty() {
        return 1.0;
    }
    let sh = support_of(x_hat);
    support_intersection(&sh, &st) as f64 / st.len() as f64
}

/// Support recovery against the top-s entries of the estimate (used when
/// the estimate is not exactly sparse, e.g. FISTA without pruning).
pub fn exact_recovery_top_s(x_hat: &[f32], x_true: &[f32]) -> f64 {
    let st = support_of(x_true);
    if st.is_empty() {
        return 1.0;
    }
    let sh = top_s_indices(x_hat, st.len());
    support_intersection(&sh, &st) as f64 / st.len() as f64
}

/// Source-resolution metric (radio-astronomy tolerance, Fig 4 discussion):
/// a true source at pixel p counts as resolved if the estimate has a
/// component within `tol_pixels` (Chebyshev distance on the r×r grid) whose
/// flux is at least `flux_floor` of the true flux. Returns the
/// true-positive count.
pub fn sources_resolved(
    x_hat: &[f32],
    sources: &[(usize, f32)],
    resolution: usize,
    tol_pixels: usize,
    flux_floor: f32,
) -> usize {
    let mut resolved = 0;
    for &(p, flux) in sources {
        let (pr, pc) = (p / resolution, p % resolution);
        let mut hit = false;
        'search: for dr in -(tol_pixels as isize)..=(tol_pixels as isize) {
            for dc in -(tol_pixels as isize)..=(tol_pixels as isize) {
                let r = pr as isize + dr;
                let c = pc as isize + dc;
                if r < 0 || c < 0 || r >= resolution as isize || c >= resolution as isize {
                    continue;
                }
                let q = r as usize * resolution + c as usize;
                if x_hat[q] >= flux_floor * flux {
                    hit = true;
                    break 'search;
                }
            }
        }
        if hit {
            resolved += 1;
        }
    }
    resolved
}

/// False positives: estimate components not within `tol_pixels` of any true
/// source (counts the CLEAN over-detection of Fig 9).
pub fn false_positives(
    x_hat: &[f32],
    sources: &[(usize, f32)],
    resolution: usize,
    tol_pixels: usize,
    flux_floor_abs: f32,
) -> usize {
    let mut fp = 0;
    for (q, &v) in x_hat.iter().enumerate() {
        if v < flux_floor_abs {
            continue;
        }
        let (qr, qc) = (q / resolution, q % resolution);
        let near_source = sources.iter().any(|&(p, _)| {
            let (pr, pc) = (p / resolution, p % resolution);
            (pr as isize - qr as isize).abs() <= tol_pixels as isize
                && (pc as isize - qc as isize).abs() <= tol_pixels as isize
        });
        if !near_source {
            fp += 1;
        }
    }
    fp
}

/// PSNR (dB) of the reconstruction against the true image.
pub fn psnr(x_hat: &[f32], x_true: &[f32]) -> f64 {
    assert_eq!(x_hat.len(), x_true.len());
    let peak = x_true.iter().fold(0.0f32, |a, &b| a.max(b.abs())) as f64;
    let mse: f64 = x_hat
        .iter()
        .zip(x_true)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / x_true.len() as f64;
    if mse == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (peak * peak / mse).log10()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovery_error_zero_for_exact() {
        let x = vec![1.0, 0.0, -2.0];
        assert_eq!(recovery_error(&x, &x), 0.0);
    }

    #[test]
    fn recovery_error_relative() {
        let xt = vec![3.0, 4.0];
        let xh = vec![3.0, 0.0];
        assert!((recovery_error(&xh, &xt) - 0.8).abs() < 1e-9);
    }

    #[test]
    fn exact_recovery_fractions() {
        let xt = vec![1.0, 0.0, 2.0, 0.0];
        assert_eq!(exact_recovery(&[1.0, 0.0, 2.0, 0.0], &xt), 1.0);
        assert_eq!(exact_recovery(&[1.0, 0.0, 0.0, 5.0], &xt), 0.5);
        assert_eq!(exact_recovery(&[0.0, 1.0, 0.0, 5.0], &xt), 0.0);
    }

    #[test]
    fn exact_recovery_top_s_ignores_small_tail() {
        let xt = vec![1.0, 0.0, 2.0, 0.0];
        // Dense estimate whose top-2 matches the truth.
        let xh = vec![0.9, 0.01, 1.8, -0.02];
        assert_eq!(exact_recovery_top_s(&xh, &xt), 1.0);
    }

    #[test]
    fn sources_resolved_tolerance() {
        // 8×8 grid, source at (2, 2) = pixel 18.
        let sources = vec![(18usize, 1.0f32)];
        let mut xh = vec![0.0f32; 64];
        xh[19] = 0.9; // one pixel off
        assert_eq!(sources_resolved(&xh, &sources, 8, 1, 0.5), 1);
        assert_eq!(sources_resolved(&xh, &sources, 8, 0, 0.5), 0);
        // Too weak:
        xh[19] = 0.3;
        assert_eq!(sources_resolved(&xh, &sources, 8, 1, 0.5), 0);
    }

    #[test]
    fn false_positive_count() {
        let sources = vec![(18usize, 1.0f32)];
        let mut xh = vec![0.0f32; 64];
        xh[18] = 1.0; // true positive
        xh[60] = 0.8; // far away — false positive
        xh[61] = 0.01; // below floor — ignored
        assert_eq!(false_positives(&xh, &sources, 8, 1, 0.1), 1);
    }

    #[test]
    fn psnr_infinite_for_exact() {
        let x = vec![1.0, 2.0];
        assert!(psnr(&x, &x).is_infinite());
    }

    #[test]
    fn psnr_decreases_with_error() {
        let xt = vec![1.0, 0.0, 0.0, 0.0];
        let a = psnr(&[0.9, 0.0, 0.0, 0.0], &xt);
        let b = psnr(&[0.5, 0.0, 0.0, 0.0], &xt);
        assert!(a > b);
    }
}
