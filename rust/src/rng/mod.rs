//! Deterministic pseudo-random numbers (S1).
//!
//! The paper's CPU implementation (§9) uses XORShift to generate the random
//! numbers for stochastic rounding; we use `xorshift128+` — tiny state, fast,
//! and good enough for rounding noise and synthetic-data generation — plus a
//! Box–Muller Gaussian layer. Everything in the repository that needs
//! randomness threads one of these through explicitly, so every experiment is
//! reproducible from a single `u64` seed.

/// `xorshift128+` generator (Vigna 2014).
#[derive(Debug, Clone)]
pub struct XorShift128Plus {
    s0: u64,
    s1: u64,
    /// Cached second Gaussian from Box–Muller.
    spare: Option<f64>,
}

/// SplitMix64 step — used to expand a single seed into the 128-bit state
/// (the construction recommended by the xorshift authors).
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl XorShift128Plus {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm);
        let mut s1 = splitmix64(&mut sm);
        if s0 == 0 && s1 == 0 {
            s1 = 1; // all-zero state is a fixed point
        }
        Self { s0, s1, spare: None }
    }

    /// Derive an independent stream (for parallel workers / fresh
    /// quantizations) without correlating with the parent.
    pub fn fork(&mut self, tag: u64) -> Self {
        let mixed = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        Self::new(mixed)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)` (what the quantizer consumes).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        // u in (0, 1] to avoid ln(0)
        let u = 1.0 - self.uniform();
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    #[inline]
    pub fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Vector of standard normals.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.gaussian_f32()).collect()
    }

    /// Vector of uniform(0,1) f32.
    pub fn uniform_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.uniform_f32()).collect()
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot choose {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = XorShift128Plus::new(42);
        let mut b = XorShift128Plus::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = XorShift128Plus::new(1);
        let mut b = XorShift128Plus::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = XorShift128Plus::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = XorShift128Plus::new(9);
        let mean: f64 = (0..100_000).map(|_| r.uniform()).sum::<f64>() / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = XorShift128Plus::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn choose_k_distinct_and_in_range() {
        let mut r = XorShift128Plus::new(13);
        let picks = r.choose_k(100, 30);
        assert_eq!(picks.len(), 30);
        let set: std::collections::HashSet<_> = picks.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(picks.iter().all(|&i| i < 100));
    }

    #[test]
    fn choose_k_full_is_permutation() {
        let mut r = XorShift128Plus::new(17);
        let mut picks = r.choose_k(20, 20);
        picks.sort_unstable();
        assert_eq!(picks, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_decorrelate() {
        let mut parent = XorShift128Plus::new(21);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_bounds() {
        let mut r = XorShift128Plus::new(23);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
