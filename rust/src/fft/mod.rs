//! Fast Fourier transforms — the substrate of the MRI (partial-Fourier)
//! measurement operator.
//!
//! Scope-matched to what [`crate::mri`] needs: an iterative radix-2
//! Cooley–Tukey complex FFT over split re/im `f32` slices (power-of-two
//! lengths), the 2-D row–column transform, and an O(n²) naive DFT kept as
//! the parity reference the unit tests (and `tests/mri_parity.rs`) check
//! every size against. Twiddle factors are evaluated in `f64` once per
//! [`FftPlan`] (a single `n/2`-entry table serves every stage by stride
//! indexing, conjugated for the inverse), so the `f32` butterflies lose
//! nothing to twiddle error accumulation and the per-iteration hot path
//! ([`crate::mri::PartialFourierOp`]) performs no trigonometry at all.
//!
//! Conventions (match `numpy.fft` / the textbook DFT):
//! * forward: `X_k = Σ_j x_j e^{-2πi jk/n}`, unnormalized;
//! * inverse: `x_j = (1/n) Σ_k X_k e^{+2πi jk/n}`.
//!
//! The unitary scaling the measurement operator wants (`1/√n` both ways)
//! is applied by the caller ([`crate::mri::PartialFourierOp`]), keeping
//! these kernels free of hidden normalization.

/// A prepared transform of one power-of-two length: the bit-reversal
/// size plus a single forward twiddle table `w_n^j = e^{-2πi j/n}`
/// (`j < n/2`) that serves every stage by stride indexing
/// (`w_len^k = w_n^{k·n/len}`) and the inverse by conjugation.
///
/// NIHT calls the transform several times per iteration, so the trig is
/// hoisted here once — [`crate::mri::PartialFourierOp`] holds one plan
/// for its grid; the free functions below build a throwaway plan per
/// call for one-shot use.
#[derive(Debug, Clone)]
pub struct FftPlan {
    n: usize,
    tw_re: Vec<f32>,
    tw_im: Vec<f32>,
}

impl FftPlan {
    /// Panics if `n` is not a power of two.
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two(), "fft length {n} is not a power of two");
        let mut tw_re = Vec::with_capacity(n / 2);
        let mut tw_im = Vec::with_capacity(n / 2);
        for j in 0..n / 2 {
            let ang = -2.0 * std::f64::consts::PI * j as f64 / n as f64;
            tw_re.push(ang.cos() as f32);
            tw_im.push(ang.sin() as f32);
        }
        Self { n, tw_re, tw_im }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// In-place radix-2 FFT over split re/im buffers of length `n`.
    /// `inverse` conjugates the twiddles and applies the `1/n` scaling.
    pub fn run(&self, re: &mut [f32], im: &mut [f32], inverse: bool) {
        let n = self.n;
        assert_eq!(n, re.len(), "buffer length does not match plan size");
        assert_eq!(n, im.len(), "re/im length mismatch");
        if n <= 1 {
            return;
        }

        // Bit-reversal permutation.
        let bits = n.trailing_zeros();
        for i in 0..n {
            let j = i.reverse_bits() >> (usize::BITS - bits);
            if j > i {
                re.swap(i, j);
                im.swap(i, j);
            }
        }

        let conj = if inverse { -1.0f32 } else { 1.0f32 };
        let mut len = 2usize;
        while len <= n {
            let half = len / 2;
            let stride = n / len;
            let mut base = 0usize;
            while base < n {
                for k in 0..half {
                    let wr = self.tw_re[k * stride];
                    let wi = conj * self.tw_im[k * stride];
                    let (ar, ai) = (re[base + k], im[base + k]);
                    let (br, bi) = (re[base + k + half], im[base + k + half]);
                    let tr = wr * br - wi * bi;
                    let ti = wr * bi + wi * br;
                    re[base + k] = ar + tr;
                    im[base + k] = ai + ti;
                    re[base + k + half] = ar - tr;
                    im[base + k + half] = ai - ti;
                }
                base += len;
            }
            len <<= 1;
        }

        if inverse {
            let scale = 1.0 / n as f32;
            for v in re.iter_mut() {
                *v *= scale;
            }
            for v in im.iter_mut() {
                *v *= scale;
            }
        }
    }

    /// In-place 2-D FFT over a square `n × n` row-major split-complex
    /// image (both axes use this plan).
    pub fn run_2d_square(&self, re: &mut [f32], im: &mut [f32], inverse: bool) {
        fft2_with(self, self, re, im, inverse)
    }
}

/// In-place radix-2 FFT over split re/im buffers (one-shot: builds a
/// throwaway [`FftPlan`]; hot paths hold a plan instead). `inverse`
/// selects the exponent sign and applies the `1/n` scaling.
///
/// Panics if the length is not a power of two or the buffers disagree.
pub fn fft_inplace(re: &mut [f32], im: &mut [f32], inverse: bool) {
    assert_eq!(re.len(), im.len(), "re/im length mismatch");
    FftPlan::new(re.len()).run(re, im, inverse)
}

fn fft2_with(
    row_plan: &FftPlan,
    col_plan: &FftPlan,
    re: &mut [f32],
    im: &mut [f32],
    inverse: bool,
) {
    let (rows, cols) = (col_plan.n(), row_plan.n());
    assert_eq!(re.len(), rows * cols, "image shape mismatch");
    assert_eq!(im.len(), rows * cols, "image shape mismatch");
    // Rows are contiguous: transform in place.
    for r in 0..rows {
        let lo = r * cols;
        row_plan.run(&mut re[lo..lo + cols], &mut im[lo..lo + cols], inverse);
    }
    // Columns: gather → transform → scatter through a scratch pair.
    let mut col_re = vec![0.0f32; rows];
    let mut col_im = vec![0.0f32; rows];
    for c in 0..cols {
        for r in 0..rows {
            col_re[r] = re[r * cols + c];
            col_im[r] = im[r * cols + c];
        }
        col_plan.run(&mut col_re, &mut col_im, inverse);
        for r in 0..rows {
            re[r * cols + c] = col_re[r];
            im[r * cols + c] = col_im[r];
        }
    }
}

/// In-place 2-D FFT (row–column decomposition) over a `rows × cols`
/// row-major split-complex image. Both dimensions must be powers of two.
/// One-shot wrapper; hot paths hold an [`FftPlan`] and use
/// [`FftPlan::run_2d_square`].
pub fn fft2_inplace(re: &mut [f32], im: &mut [f32], rows: usize, cols: usize, inverse: bool) {
    let col_plan = FftPlan::new(rows);
    if rows == cols {
        fft2_with(&col_plan, &col_plan, re, im, inverse)
    } else {
        fft2_with(&FftPlan::new(cols), &col_plan, re, im, inverse)
    }
}

/// O(n²) reference DFT with `f64` accumulation (any length). Same
/// conventions as [`fft_inplace`]; returns fresh buffers.
pub fn dft_naive(re: &[f32], im: &[f32], inverse: bool) -> (Vec<f32>, Vec<f32>) {
    let n = re.len();
    assert_eq!(n, im.len());
    let sign = if inverse { 1.0f64 } else { -1.0f64 };
    let mut out_re = vec![0.0f32; n];
    let mut out_im = vec![0.0f32; n];
    let scale = if inverse { 1.0 / n as f64 } else { 1.0 };
    for k in 0..n {
        let mut acc_re = 0.0f64;
        let mut acc_im = 0.0f64;
        for j in 0..n {
            let ang = sign * 2.0 * std::f64::consts::PI * (j * k % n.max(1)) as f64 / n as f64;
            let (c, s) = (ang.cos(), ang.sin());
            let (xr, xi) = (re[j] as f64, im[j] as f64);
            acc_re += xr * c - xi * s;
            acc_im += xr * s + xi * c;
        }
        out_re[k] = (acc_re * scale) as f32;
        out_im[k] = (acc_im * scale) as f32;
    }
    (out_re, out_im)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::XorShift128Plus;

    fn rel_l2(got_re: &[f32], got_im: &[f32], want_re: &[f32], want_im: &[f32]) -> f64 {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for i in 0..got_re.len() {
            num += ((got_re[i] - want_re[i]) as f64).powi(2)
                + ((got_im[i] - want_im[i]) as f64).powi(2);
            den += (want_re[i] as f64).powi(2) + (want_im[i] as f64).powi(2);
        }
        (num / den.max(1e-30)).sqrt()
    }

    #[test]
    fn fft_matches_naive_dft_across_sizes_2_to_1024() {
        let mut rng = XorShift128Plus::new(1);
        let mut n = 2usize;
        while n <= 1024 {
            for inverse in [false, true] {
                let re0 = rng.gaussian_vec(n);
                let im0 = rng.gaussian_vec(n);
                let (want_re, want_im) = dft_naive(&re0, &im0, inverse);
                let mut re = re0.clone();
                let mut im = im0.clone();
                fft_inplace(&mut re, &mut im, inverse);
                let err = rel_l2(&re, &im, &want_re, &want_im);
                assert!(err <= 1e-5, "n={n} inverse={inverse}: rel err {err}");
            }
            n *= 2;
        }
    }

    #[test]
    fn roundtrip_recovers_input() {
        let mut rng = XorShift128Plus::new(2);
        for n in [1usize, 4, 64, 512] {
            let re0 = rng.gaussian_vec(n);
            let im0 = rng.gaussian_vec(n);
            let mut re = re0.clone();
            let mut im = im0.clone();
            fft_inplace(&mut re, &mut im, false);
            fft_inplace(&mut re, &mut im, true);
            for i in 0..n {
                assert!((re[i] - re0[i]).abs() <= 1e-4 * (1.0 + re0[i].abs()), "n={n}");
                assert!((im[i] - im0[i]).abs() <= 1e-4 * (1.0 + im0[i].abs()), "n={n}");
            }
        }
    }

    #[test]
    fn impulse_transforms_to_all_ones() {
        let mut re = vec![0.0f32; 8];
        let mut im = vec![0.0f32; 8];
        re[0] = 1.0;
        fft_inplace(&mut re, &mut im, false);
        for k in 0..8 {
            assert!((re[k] - 1.0).abs() < 1e-6 && im[k].abs() < 1e-6, "k={k}");
        }
    }

    #[test]
    fn real_input_spectrum_is_conjugate_symmetric() {
        let mut rng = XorShift128Plus::new(3);
        let n = 64;
        let mut re = rng.gaussian_vec(n);
        let mut im = vec![0.0f32; n];
        fft_inplace(&mut re, &mut im, false);
        for k in 1..n {
            assert!((re[k] - re[n - k]).abs() <= 1e-4, "k={k}");
            assert!((im[k] + im[n - k]).abs() <= 1e-4, "k={k}");
        }
    }

    #[test]
    fn fft2_matches_row_column_naive() {
        let (rows, cols) = (8usize, 16usize);
        let mut rng = XorShift128Plus::new(4);
        let re0 = rng.gaussian_vec(rows * cols);
        let im0 = rng.gaussian_vec(rows * cols);

        // Naive row–column reference.
        let mut want_re = re0.clone();
        let mut want_im = im0.clone();
        for r in 0..rows {
            let lo = r * cols;
            let (wr, wi) =
                dft_naive(&want_re[lo..lo + cols], &want_im[lo..lo + cols], false);
            want_re[lo..lo + cols].copy_from_slice(&wr);
            want_im[lo..lo + cols].copy_from_slice(&wi);
        }
        for c in 0..cols {
            let col_re: Vec<f32> = (0..rows).map(|r| want_re[r * cols + c]).collect();
            let col_im: Vec<f32> = (0..rows).map(|r| want_im[r * cols + c]).collect();
            let (wr, wi) = dft_naive(&col_re, &col_im, false);
            for r in 0..rows {
                want_re[r * cols + c] = wr[r];
                want_im[r * cols + c] = wi[r];
            }
        }

        let mut re = re0;
        let mut im = im0;
        fft2_inplace(&mut re, &mut im, rows, cols, false);
        let err = rel_l2(&re, &im, &want_re, &want_im);
        assert!(err <= 1e-5, "rel err {err}");
    }

    #[test]
    fn fft2_roundtrip() {
        let (rows, cols) = (16usize, 16usize);
        let mut rng = XorShift128Plus::new(5);
        let re0 = rng.gaussian_vec(rows * cols);
        let mut re = re0.clone();
        let mut im = vec![0.0f32; rows * cols];
        fft2_inplace(&mut re, &mut im, rows, cols, false);
        fft2_inplace(&mut re, &mut im, rows, cols, true);
        for i in 0..re.len() {
            assert!((re[i] - re0[i]).abs() <= 1e-4, "i={i}");
            assert!(im[i].abs() <= 1e-4, "i={i}");
        }
    }

    #[test]
    fn plan_reuse_is_bit_identical_to_one_shot() {
        let mut rng = XorShift128Plus::new(6);
        let plan = FftPlan::new(128);
        assert_eq!(plan.n(), 128);
        for inverse in [false, true] {
            let re0 = rng.gaussian_vec(128);
            let im0 = rng.gaussian_vec(128);
            let (mut re_a, mut im_a) = (re0.clone(), im0.clone());
            fft_inplace(&mut re_a, &mut im_a, inverse);
            let (mut re_b, mut im_b) = (re0.clone(), im0.clone());
            plan.run(&mut re_b, &mut im_b, inverse);
            // Second use of the same plan must also agree (no state).
            let (mut re_c, mut im_c) = (re0, im0);
            plan.run(&mut re_c, &mut im_c, inverse);
            assert_eq!(re_a, re_b);
            assert_eq!(im_a, im_b);
            assert_eq!(re_b, re_c);
            assert_eq!(im_b, im_c);
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_power_of_two_rejected() {
        let mut re = vec![0.0f32; 6];
        let mut im = vec![0.0f32; 6];
        fft_inplace(&mut re, &mut im, false);
    }
}
