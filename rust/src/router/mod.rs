//! # Sharded serving tier (`lpcs route`) — L4
//!
//! A routing front end that fans one wire-protocol listen address out
//! over several `lpcs serve` backends, **preserving batch affinity**:
//! jobs are placed by consistent-hashing [`crate::wire::route_key`] —
//! the operator content hash plus the batch-relevant spec fields — so
//! every job that could share a backend batch (same Φ, same solver/
//! engine/sparsity) lands on the *same* backend and amortizes one
//! quantize+pack exactly as it would against a single server.
//!
//! ```text
//!                         ┌──────────────────┐
//!   WireClient ──Submit──▶│   lpcs route     │──▶ lpcs serve #0 (Φ_a jobs)
//!   WireClient ──Watch───▶│  ring · health   │──▶ lpcs serve #1 (Φ_b jobs)
//!   WireClient ──Cancel──▶│  table · relay   │──▶ lpcs serve #2 (down: ring drops it)
//!                         └──────────────────┘
//! ```
//!
//! Both faces speak the same [`crate::wire`] protocol, so a
//! [`crate::wire::WireClient`] talks to a router or a backend unchanged.
//! Production shape:
//!
//! * [`ring`] — deterministic consistent-hash ring (vnodes, minimal
//!   disruption on membership change).
//! * [`health`] — a prober thread marks backends down after
//!   `down_after` failed `StatsReq` probes (removing them from the
//!   ring) and re-admits them on recovery.
//! * [`relay`] — the data path. Watch streams survive a backend dying
//!   mid-solve: the router resubmits the stored spec to a surviving
//!   backend and *resumes* the stream — deterministic seeded re-solves
//!   replay the same trajectory, replayed iterations are filtered, the
//!   `Progress` epoch increments, and the client still sees one
//!   strictly monotone stream ending in exactly one `Done`.
//! * Admission control — submits are rejected with typed
//!   [`ErrCode::QueueFull`] when the router's in-flight table hits
//!   `max_inflight` or a backend's probed queue depth crosses
//!   `queue_limit`; backend rejections propagate typed. The router
//!   never buffers jobs it cannot place.
//!
//! End-to-end conformance (routed results bit-identical to
//! `Recovery::service_dispatch`, failover resume, typed saturation) is
//! pinned by `tests/router_serving.rs`.

pub mod health;
pub mod relay;
pub mod ring;

pub use health::BackendState;
pub use ring::HashRing;

use crate::config::RouterConfig;
use crate::coordinator::JobId;
use crate::obsv::{BackendCounters, RouterCounters};
use crate::wire::codec::{route_key, ErrCode, WireJobSpec};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Where the router believes one of its jobs lives.
struct RouteEntry {
    backend: usize,
    /// The backend's id for this job (ids are per-service counters, so
    /// the router re-numbers and translates on every relayed frame).
    backend_job: JobId,
    /// The wire spec, kept while the job is live so a watch relay can
    /// resubmit it after a backend loss; dropped at `Done` (a dense Φ
    /// can be tens of MiB — terminal entries must not pin it).
    spec: Option<WireJobSpec>,
    done: bool,
    /// Bumped on every failover. Relays present the generation they
    /// acted on, so two relays watching the same job cannot both
    /// resubmit it for one loss.
    generation: u64,
}

/// A relay's snapshot of a [`RouteEntry`]'s placement.
#[derive(Debug, Clone, Copy)]
pub(crate) struct EntryView {
    pub(crate) backend: usize,
    pub(crate) backend_job: JobId,
    pub(crate) generation: u64,
}

/// Per-backend slice of the router counters.
#[derive(Debug, Default)]
pub struct PerBackendMetrics {
    pub routed: AtomicU64,
    pub resumed: AtomicU64,
    pub down_events: AtomicU64,
}

/// Router counters, mirroring the backend
/// [`crate::coordinator::ServiceMetrics`] discipline: monotone atomics,
/// one-line text snapshot.
#[derive(Debug)]
pub struct RouterMetrics {
    /// Submits successfully placed on a backend.
    pub routed: AtomicU64,
    /// Typed `queue-full` rejections: router table saturation, probed
    /// backend queue limit, or a propagated backend rejection.
    pub rejected_full: AtomicU64,
    /// Submits rejected because no backend was available.
    pub rejected_down: AtomicU64,
    /// Watch streams resumed onto another backend after a loss.
    pub resumed: AtomicU64,
    /// Up→down transitions across all backends.
    pub backend_down_events: AtomicU64,
    per_backend: Vec<PerBackendMetrics>,
}

impl RouterMetrics {
    fn new(backends: usize) -> Self {
        Self {
            routed: AtomicU64::new(0),
            rejected_full: AtomicU64::new(0),
            rejected_down: AtomicU64::new(0),
            resumed: AtomicU64::new(0),
            backend_down_events: AtomicU64::new(0),
            per_backend: (0..backends).map(|_| PerBackendMetrics::default()).collect(),
        }
    }

    pub fn backend(&self, i: usize) -> &PerBackendMetrics {
        &self.per_backend[i]
    }

    /// The counter half of [`RouterCounters`] — just this struct's
    /// atomics; [`RouterState::snapshot_struct`] fills in the health
    /// prober's per-backend view and the in-flight gauge.
    fn counters_only(&self) -> RouterCounters {
        RouterCounters {
            routed: self.routed.load(Ordering::Relaxed),
            rejected_full: self.rejected_full.load(Ordering::Relaxed),
            rejected_down: self.rejected_down.load(Ordering::Relaxed),
            resumed: self.resumed.load(Ordering::Relaxed),
            backend_down: self.backend_down_events.load(Ordering::Relaxed),
            inflight: 0,
            per_backend: self
                .per_backend
                .iter()
                .map(|b| BackendCounters {
                    routed: b.routed.load(Ordering::Relaxed),
                    resumed: b.resumed.load(Ordering::Relaxed),
                    down_events: b.down_events.load(Ordering::Relaxed),
                    ..BackendCounters::default()
                })
                .collect(),
        }
    }

    /// The legacy one-line text form (byte-compatible key order; pinned
    /// by `obsv` tests).
    pub fn snapshot(&self) -> String {
        self.counters_only().render_legacy()
    }
}

/// Everything the router's threads share.
pub struct RouterState {
    pub cfg: RouterConfig,
    pub backends: Vec<BackendState>,
    ring: Mutex<HashRing>,
    table: Mutex<HashMap<JobId, RouteEntry>>,
    next_id: AtomicU64,
    /// Round-robin cursor (`affinity: false` mode — the bench baseline).
    rr: AtomicU64,
    pub metrics: RouterMetrics,
    shutdown: Arc<AtomicBool>,
}

impl RouterState {
    fn new(cfg: RouterConfig, shutdown: Arc<AtomicBool>) -> Self {
        let backends: Vec<BackendState> =
            cfg.backends.iter().cloned().map(BackendState::new).collect();
        let metrics = RouterMetrics::new(backends.len());
        let state = Self {
            backends,
            ring: Mutex::new(HashRing::default()),
            table: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            rr: AtomicU64::new(0),
            metrics,
            cfg,
            shutdown,
        };
        state.rebuild_ring();
        state
    }

    pub(crate) fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Sleep `total`, waking every 20 ms to honor shutdown promptly.
    pub(crate) fn sleep_ticked(&self, total: Duration) {
        let tick = Duration::from_millis(20);
        let mut left = total;
        while !left.is_zero() {
            if self.is_shutdown() {
                return;
            }
            let step = left.min(tick);
            std::thread::sleep(step);
            left -= step;
        }
    }

    /// Deadline for every upstream connect/submit — the probe timeout,
    /// so data-path failover is as fast as health detection.
    pub(crate) fn forward_timeout(&self) -> Duration {
        Duration::from_millis(self.cfg.probe_timeout_ms.max(10))
    }

    /// Rebuild the ring over the currently-up backends (called on every
    /// membership transition; the ring itself is immutable between).
    pub(crate) fn rebuild_ring(&self) {
        let up = self
            .backends
            .iter()
            .enumerate()
            .filter(|(_, b)| b.is_up())
            .map(|(i, b)| (i, b.addr.as_str()));
        *self.ring.lock().unwrap() = HashRing::build(up, self.cfg.vnodes);
    }

    /// Record a down transition once: counters + ring rebuild. Safe to
    /// call from the prober and the data path concurrently.
    pub(crate) fn mark_backend_down(&self, i: usize) {
        if self.backends[i].set_up(false) {
            self.metrics.backend_down_events.fetch_add(1, Ordering::Relaxed);
            self.metrics.backend(i).down_events.fetch_add(1, Ordering::Relaxed);
            self.rebuild_ring();
        }
    }

    pub fn up_count(&self) -> usize {
        self.backends.iter().filter(|b| b.is_up()).count()
    }

    /// Choose a backend for `key`: the ring owner under affinity, a
    /// round-robin pick otherwise. Falls back to a deterministic
    /// key-indexed pick over the live set when the ring briefly lags a
    /// concurrent mark-down.
    pub(crate) fn pick_backend(&self, key: u64) -> Option<usize> {
        if self.cfg.affinity {
            if let Some(i) = self.ring.lock().unwrap().route(key) {
                if self.backends[i].is_up() {
                    return Some(i);
                }
            }
        }
        let ups: Vec<usize> = self
            .backends
            .iter()
            .enumerate()
            .filter(|(_, b)| b.is_up())
            .map(|(i, _)| i)
            .collect();
        if ups.is_empty() {
            return None;
        }
        if self.cfg.affinity {
            Some(ups[(key % ups.len() as u64) as usize])
        } else {
            Some(ups[(self.rr.fetch_add(1, Ordering::Relaxed) as usize) % ups.len()])
        }
    }

    /// Non-terminal entries — the admission measure. Drained when a
    /// watch relays the job's `Done` (the CLI always watches); an
    /// unwatched job pins its slot, which is exactly what `max_inflight`
    /// is there to bound.
    pub fn inflight(&self) -> usize {
        self.table.lock().unwrap().values().filter(|e| !e.done).count()
    }

    /// The structured metrics snapshot for this router: routing counters
    /// plus the health prober's per-backend view (up flag, last probed
    /// queue depth/capacity) and the in-flight table size.
    pub fn snapshot_struct(&self) -> RouterCounters {
        let mut c = self.metrics.counters_only();
        c.inflight = self.inflight() as u64;
        for (b, bc) in self.backends.iter().zip(c.per_backend.iter_mut()) {
            bc.addr = b.addr.clone();
            bc.up = b.is_up();
            bc.queue_depth = b.queue_depth.load(Ordering::Relaxed);
            bc.queue_capacity = b.queue_capacity.load(Ordering::Relaxed);
        }
        c
    }

    /// Prometheus text exposition for the router face (`ScrapeReq` →
    /// `Scrape` on the router listener; `lpcs scrape ADDR` prints it).
    pub fn scrape(&self) -> String {
        crate::obsv::render_router_prometheus(&self.snapshot_struct())
    }

    /// Register a placed job and hand out its router-scoped id.
    pub(crate) fn admit(&self, backend: usize, backend_job: JobId, ws: WireJobSpec) -> JobId {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.table.lock().unwrap().insert(
            id,
            RouteEntry { backend, backend_job, spec: Some(ws), done: false, generation: 0 },
        );
        self.metrics.routed.fetch_add(1, Ordering::Relaxed);
        self.metrics.backend(backend).routed.fetch_add(1, Ordering::Relaxed);
        id
    }

    pub(crate) fn entry_view(&self, id: JobId) -> Option<EntryView> {
        self.table.lock().unwrap().get(&id).map(|e| EntryView {
            backend: e.backend,
            backend_job: e.backend_job,
            generation: e.generation,
        })
    }

    pub(crate) fn mark_done(&self, id: JobId) {
        if let Some(e) = self.table.lock().unwrap().get_mut(&id) {
            e.done = true;
            e.spec = None; // release the operator bytes; outcomes live on the backend
        }
    }

    /// Re-place `id` after its upstream stream was lost: resubmit the
    /// stored spec to a (possibly different) live backend. The
    /// generation guard makes concurrent relays converge on one
    /// resubmission — a loser's duplicate runs out unwatched on its
    /// backend, but never reaches a stream.
    pub(crate) fn failover(
        &self,
        id: JobId,
        seen_generation: u64,
    ) -> Result<EntryView, ErrCode> {
        let spec = {
            let table = self.table.lock().unwrap();
            let e = table.get(&id).ok_or(ErrCode::UnknownJob)?;
            if e.done {
                // Another relay already delivered this job's Done.
                return Err(ErrCode::Internal);
            }
            if e.generation != seen_generation {
                // A concurrent relay already re-placed it; ride along.
                return Ok(EntryView {
                    backend: e.backend,
                    backend_job: e.backend_job,
                    generation: e.generation,
                });
            }
            e.spec.clone().ok_or(ErrCode::Internal)?
        };
        let key = route_key(&spec);
        for _ in 0..self.backends.len() {
            let Some(i) = self.pick_backend(key) else { break };
            match relay::forward_submit(self, i, &spec) {
                Ok(backend_job) => {
                    let mut table = self.table.lock().unwrap();
                    let e = table.get_mut(&id).ok_or(ErrCode::UnknownJob)?;
                    if e.generation != seen_generation {
                        return Ok(EntryView {
                            backend: e.backend,
                            backend_job: e.backend_job,
                            generation: e.generation,
                        });
                    }
                    e.backend = i;
                    e.backend_job = backend_job;
                    e.generation += 1;
                    return Ok(EntryView { backend: i, backend_job, generation: e.generation });
                }
                Err(we) => match we.code {
                    // A live backend refused the resubmit (queue full,
                    // …): surface its verdict to the watcher.
                    Some(code) => return Err(code),
                    None => {
                        self.mark_backend_down(i);
                        continue;
                    }
                },
            }
        }
        Err(ErrCode::BackendDown)
    }
}

/// Handle to a running router. Dropping it only raises the shutdown
/// flag; call [`RouterServer::shutdown`] for the bounded join.
pub struct RouterServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    health: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    state: Arc<RouterState>,
}

impl RouterServer {
    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn state(&self) -> &RouterState {
        &self.state
    }

    pub fn metrics(&self) -> &RouterMetrics {
        &self.state.metrics
    }

    /// Stop accepting, wake every relay and the prober, join them all.
    /// Bounded: every blocking wait in the router ticks and re-checks
    /// the flag.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            h.join().expect("router accept thread panicked");
        }
        if let Some(h) = self.health.take() {
            h.join().expect("router health prober panicked");
        }
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in conns {
            h.join().expect("router connection handler panicked");
        }
    }
}

impl Drop for RouterServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }
}

/// Start routing on `listen` (e.g. `"127.0.0.1:0"`) across
/// `cfg.backends`.
pub fn serve(cfg: RouterConfig, listen: &str) -> Result<RouterServer> {
    if cfg.backends.is_empty() {
        bail!("router needs at least one backend (router.backends=… or backend=…)");
    }
    let listener = TcpListener::bind(listen)
        .with_context(|| format!("binding router listener on {listen}"))?;
    listener.set_nonblocking(true).context("non-blocking router listener")?;
    let addr = listener.local_addr().context("router listener address")?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let state = Arc::new(RouterState::new(cfg, shutdown.clone()));
    let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

    let health = {
        let state = state.clone();
        std::thread::Builder::new()
            .name("lpcs-router-health".into())
            .spawn(move || health::run_prober(state))
            .expect("spawn router health prober")
    };

    let accept = {
        let shutdown = shutdown.clone();
        let conns = conns.clone();
        let state = state.clone();
        std::thread::Builder::new()
            .name("lpcs-router-accept".into())
            .spawn(move || loop {
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let state = state.clone();
                        let handle = std::thread::Builder::new()
                            .name("lpcs-router-conn".into())
                            .spawn(move || relay::handle_conn(stream, state))
                            .expect("spawn router connection handler");
                        // Reap finished handlers so a long-running
                        // router doesn't accumulate joinable threads.
                        let mut conns = conns.lock().unwrap();
                        conns.retain(|h| !h.is_finished());
                        conns.push(handle);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(10)),
                }
            })
            .expect("spawn router accept thread")
    };

    Ok(RouterServer { addr, shutdown, accept: Some(accept), health: Some(health), conns, state })
}
