//! # Sharded serving tier (`lpcs route`) — L4
//!
//! A routing front end that fans one wire-protocol listen address out
//! over several `lpcs serve` backends, **preserving batch affinity**:
//! jobs are placed by consistent-hashing [`crate::wire::route_key`] —
//! the operator content hash plus the batch-relevant spec fields — so
//! every job that could share a backend batch (same Φ, same solver/
//! engine/sparsity) lands on the *same* backend and amortizes one
//! quantize+pack exactly as it would against a single server.
//!
//! ```text
//!                         ┌──────────────────┐
//!   WireClient ──Submit──▶│   lpcs route     │──▶ lpcs serve #0 (Φ_a jobs)
//!   WireClient ──Watch───▶│  ring · health   │──▶ lpcs serve #1 (Φ_b jobs)
//!   WireClient ──Cancel──▶│  table · relay   │──▶ lpcs serve #2 (down: ring drops it)
//!                         └──────────────────┘
//! ```
//!
//! Both faces speak the same [`crate::wire`] protocol, so a
//! [`crate::wire::WireClient`] talks to a router or a backend unchanged.
//! Production shape:
//!
//! * [`ring`] — deterministic consistent-hash ring (vnodes, minimal
//!   disruption on membership change).
//! * [`health`] — a prober thread marks backends down after
//!   `down_after` failed `StatsReq` probes (removing them from the
//!   ring) and re-admits them on recovery.
//! * [`relay`] — the data path. Watch streams survive a backend dying
//!   mid-solve: the router resubmits the stored spec to a surviving
//!   backend and *resumes* the stream — deterministic seeded re-solves
//!   replay the same trajectory, replayed iterations are filtered, the
//!   `Progress` epoch increments, and the client still sees one
//!   strictly monotone stream ending in exactly one `Done`.
//! * Admission control — submits are rejected with typed
//!   [`ErrCode::QueueFull`] when the router's in-flight table hits
//!   `max_inflight` or a backend's probed queue depth crosses
//!   `queue_limit`; backend rejections propagate typed. The router
//!   never buffers jobs it cannot place.
//!
//! End-to-end conformance (routed results bit-identical to
//! `Recovery::service_dispatch`, failover resume, typed saturation) is
//! pinned by `tests/router_serving.rs`.

pub mod health;
pub mod relay;
pub mod ring;

pub use health::BackendState;
pub use ring::HashRing;

use crate::config::RouterConfig;
use crate::coordinator::JobId;
use crate::obsv::{self, BackendCounters, Histogram, RouterCounters};
use crate::wire::codec::{route_key, ErrCode, Message, WireJobSpec};
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Where the router believes one of its jobs lives.
struct RouteEntry {
    backend: usize,
    /// The backend's id for this job (ids are per-service counters, so
    /// the router re-numbers and translates on every relayed frame).
    backend_job: JobId,
    /// The wire spec, kept while the job is live so a watch relay can
    /// resubmit it after a backend loss; dropped at `Done` (a dense Φ
    /// can be tens of MiB — terminal entries must not pin it).
    spec: Option<WireJobSpec>,
    done: bool,
    /// Bumped on every failover. Relays present the generation they
    /// acted on, so two relays watching the same job cannot both
    /// resubmit it for one loss.
    generation: u64,
}

/// A relay's snapshot of a [`RouteEntry`]'s placement.
#[derive(Debug, Clone, Copy)]
pub(crate) struct EntryView {
    pub(crate) backend: usize,
    pub(crate) backend_job: JobId,
    pub(crate) generation: u64,
}

/// Per-backend slice of the router counters.
#[derive(Debug, Default)]
pub struct PerBackendMetrics {
    pub routed: AtomicU64,
    pub resumed: AtomicU64,
    pub down_events: AtomicU64,
}

/// The router's own per-hop latency families, one [`Histogram`] per
/// configured backend so every series carries a `backend="i"` label.
/// These measure what only the router can see — the cost of each hop it
/// adds — and sit next to the *merged backend* families in the
/// federated exposition, so one scrape separates "the fleet is slow"
/// from "the routing tier is slow".
#[derive(Debug)]
pub struct RouterHops {
    /// Submit forward: route decision → backend `Submitted`, including
    /// the upstream connect. Exemplar-tagged with the job's trace id.
    pub submit_forward: Vec<Histogram>,
    /// Subscribe sent upstream → first `Progress` frame received.
    pub first_progress: Vec<Histogram>,
    /// Fan-out delay: upstream `Progress` received → relayed frame
    /// written to the watching client.
    pub fanout_delay: Vec<Histogram>,
    /// Failover resume: upstream loss detected → spec resubmitted and
    /// the stream re-placed (labeled by the backend resumed *onto*).
    pub failover_resume: Vec<Histogram>,
}

impl RouterHops {
    fn new(backends: usize) -> Self {
        let mk = || (0..backends).map(|_| Histogram::new()).collect();
        Self {
            submit_forward: mk(),
            first_progress: mk(),
            fanout_delay: mk(),
            failover_resume: mk(),
        }
    }

    /// Append the four families to `out`. Headers always render (so a
    /// scrape names every hop family even before traffic); zero-sample
    /// series are elided to keep the exposition proportional to use.
    fn render(&self, out: &mut String) {
        for (name, help, hists) in [
            (
                "lpcs_router_submit_forward_us",
                "Router hop: submit forward to backend Submitted, microseconds.",
                &self.submit_forward,
            ),
            (
                "lpcs_router_first_progress_us",
                "Router hop: upstream subscribe to first Progress frame, microseconds.",
                &self.first_progress,
            ),
            (
                "lpcs_router_fanout_delay_us",
                "Router hop: upstream frame received to client write completed, microseconds.",
                &self.fanout_delay,
            ),
            (
                "lpcs_router_failover_resume_us",
                "Router hop: upstream loss to stream resumed on a new backend, microseconds.",
                &self.failover_resume,
            ),
        ] {
            let series: Vec<(String, obsv::HistSnapshot)> = hists
                .iter()
                .enumerate()
                .map(|(i, h)| (format!("backend=\"{i}\""), h.snapshot()))
                .filter(|(_, s)| s.total() > 0)
                .collect();
            obsv::render_labeled_histogram_family(out, name, help, &series);
        }
    }
}

/// Router counters, mirroring the backend
/// [`crate::coordinator::ServiceMetrics`] discipline: monotone atomics,
/// one-line text snapshot.
#[derive(Debug)]
pub struct RouterMetrics {
    /// Submits successfully placed on a backend.
    pub routed: AtomicU64,
    /// Typed `queue-full` rejections: router table saturation, probed
    /// backend queue limit, or a propagated backend rejection.
    pub rejected_full: AtomicU64,
    /// Submits rejected because no backend was available.
    pub rejected_down: AtomicU64,
    /// Watch streams resumed onto another backend after a loss.
    pub resumed: AtomicU64,
    /// Up→down transitions across all backends.
    pub backend_down_events: AtomicU64,
    per_backend: Vec<PerBackendMetrics>,
}

impl RouterMetrics {
    fn new(backends: usize) -> Self {
        Self {
            routed: AtomicU64::new(0),
            rejected_full: AtomicU64::new(0),
            rejected_down: AtomicU64::new(0),
            resumed: AtomicU64::new(0),
            backend_down_events: AtomicU64::new(0),
            per_backend: (0..backends).map(|_| PerBackendMetrics::default()).collect(),
        }
    }

    pub fn backend(&self, i: usize) -> &PerBackendMetrics {
        &self.per_backend[i]
    }

    /// The counter half of [`RouterCounters`] — just this struct's
    /// atomics; [`RouterState::snapshot_struct`] fills in the health
    /// prober's per-backend view and the in-flight gauge.
    fn counters_only(&self) -> RouterCounters {
        RouterCounters {
            routed: self.routed.load(Ordering::Relaxed),
            rejected_full: self.rejected_full.load(Ordering::Relaxed),
            rejected_down: self.rejected_down.load(Ordering::Relaxed),
            resumed: self.resumed.load(Ordering::Relaxed),
            backend_down: self.backend_down_events.load(Ordering::Relaxed),
            inflight: 0,
            per_backend: self
                .per_backend
                .iter()
                .map(|b| BackendCounters {
                    routed: b.routed.load(Ordering::Relaxed),
                    resumed: b.resumed.load(Ordering::Relaxed),
                    down_events: b.down_events.load(Ordering::Relaxed),
                    ..BackendCounters::default()
                })
                .collect(),
        }
    }

    /// The legacy one-line text form (byte-compatible key order; pinned
    /// by `obsv` tests).
    pub fn snapshot(&self) -> String {
        self.counters_only().render_legacy()
    }
}

/// Everything the router's threads share.
pub struct RouterState {
    pub cfg: RouterConfig,
    pub backends: Vec<BackendState>,
    ring: Mutex<HashRing>,
    table: Mutex<HashMap<JobId, RouteEntry>>,
    next_id: AtomicU64,
    /// Round-robin cursor (`affinity: false` mode — the bench baseline).
    rr: AtomicU64,
    pub metrics: RouterMetrics,
    /// Per-hop latency histograms, labeled by backend index.
    pub hops: RouterHops,
    shutdown: Arc<AtomicBool>,
}

impl RouterState {
    fn new(cfg: RouterConfig, shutdown: Arc<AtomicBool>) -> Self {
        let backends: Vec<BackendState> =
            cfg.backends.iter().cloned().map(BackendState::new).collect();
        let metrics = RouterMetrics::new(backends.len());
        let hops = RouterHops::new(backends.len());
        let state = Self {
            backends,
            ring: Mutex::new(HashRing::default()),
            table: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            rr: AtomicU64::new(0),
            metrics,
            hops,
            cfg,
            shutdown,
        };
        state.rebuild_ring();
        state
    }

    pub(crate) fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Sleep `total`, waking every 20 ms to honor shutdown promptly.
    pub(crate) fn sleep_ticked(&self, total: Duration) {
        let tick = Duration::from_millis(20);
        let mut left = total;
        while !left.is_zero() {
            if self.is_shutdown() {
                return;
            }
            let step = left.min(tick);
            std::thread::sleep(step);
            left -= step;
        }
    }

    /// Deadline for every upstream connect/submit — the probe timeout,
    /// so data-path failover is as fast as health detection.
    pub(crate) fn forward_timeout(&self) -> Duration {
        Duration::from_millis(self.cfg.probe_timeout_ms.max(10))
    }

    /// Rebuild the ring over the currently-up backends (called on every
    /// membership transition; the ring itself is immutable between).
    pub(crate) fn rebuild_ring(&self) {
        let up = self
            .backends
            .iter()
            .enumerate()
            .filter(|(_, b)| b.is_up())
            .map(|(i, b)| (i, b.addr.as_str()));
        *self.ring.lock().unwrap() = HashRing::build(up, self.cfg.vnodes);
    }

    /// Record a down transition once: counters + ring rebuild. Safe to
    /// call from the prober and the data path concurrently.
    pub(crate) fn mark_backend_down(&self, i: usize) {
        if self.backends[i].set_up(false) {
            self.metrics.backend_down_events.fetch_add(1, Ordering::Relaxed);
            self.metrics.backend(i).down_events.fetch_add(1, Ordering::Relaxed);
            self.rebuild_ring();
        }
    }

    pub fn up_count(&self) -> usize {
        self.backends.iter().filter(|b| b.is_up()).count()
    }

    /// Choose a backend for `key`: the ring owner under affinity, a
    /// round-robin pick otherwise. Falls back to a deterministic
    /// key-indexed pick over the live set when the ring briefly lags a
    /// concurrent mark-down.
    pub(crate) fn pick_backend(&self, key: u64) -> Option<usize> {
        if self.cfg.affinity {
            if let Some(i) = self.ring.lock().unwrap().route(key) {
                if self.backends[i].is_up() {
                    return Some(i);
                }
            }
        }
        let ups: Vec<usize> = self
            .backends
            .iter()
            .enumerate()
            .filter(|(_, b)| b.is_up())
            .map(|(i, _)| i)
            .collect();
        if ups.is_empty() {
            return None;
        }
        if self.cfg.affinity {
            Some(ups[(key % ups.len() as u64) as usize])
        } else {
            Some(ups[(self.rr.fetch_add(1, Ordering::Relaxed) as usize) % ups.len()])
        }
    }

    /// Non-terminal entries — the admission measure. Drained when a
    /// watch relays the job's `Done` (the CLI always watches); an
    /// unwatched job pins its slot, which is exactly what `max_inflight`
    /// is there to bound.
    pub fn inflight(&self) -> usize {
        self.table.lock().unwrap().values().filter(|e| !e.done).count()
    }

    /// The structured metrics snapshot for this router: routing counters
    /// plus the health prober's per-backend view (up flag, last probed
    /// queue depth/capacity) and the in-flight table size.
    pub fn snapshot_struct(&self) -> RouterCounters {
        let mut c = self.metrics.counters_only();
        c.inflight = self.inflight() as u64;
        for (b, bc) in self.backends.iter().zip(c.per_backend.iter_mut()) {
            bc.addr = b.addr.clone();
            bc.up = b.is_up();
            bc.queue_depth = b.queue_depth.load(Ordering::Relaxed);
            bc.queue_capacity = b.queue_capacity.load(Ordering::Relaxed);
        }
        c
    }

    /// The federated Prometheus exposition for the whole fleet
    /// (`ScrapeReq` → `Scrape` on the router listener; `lpcs scrape
    /// ADDR` prints it). One scrape yields, in order:
    ///
    /// 1. the router's own counters and per-backend health,
    /// 2. the router's per-hop latency families (labeled `backend="i"`),
    /// 3. `lpcs_backend_scrape_errors{backend="i"}` — federation
    ///    failures per backend, bumped this very scrape,
    /// 4. every backend histogram family merged across the fleet
    ///    ([`Histogram::from_cumulative`] + [`Histogram::merge_from`],
    ///    exemplars preserved), `lpcs_jobs_total` summed per label set,
    /// 5. remaining backend scalars re-emitted verbatim under a
    ///    disambiguating `backend="i"` label.
    ///
    /// Each backend is scraped serially under [`Self::forward_timeout`],
    /// so a dead or wedged backend costs one bounded timeout and a
    /// scrape-error increment — never a stalled or poisoned exposition.
    pub fn scrape(&self) -> String {
        let mut out = obsv::render_router_prometheus(&self.snapshot_struct());
        self.hops.render(&mut out);

        let timeout = self.forward_timeout();
        let mut parsed: Vec<(usize, obsv::ParsedExposition)> = Vec::new();
        for (i, b) in self.backends.iter().enumerate() {
            let text = if b.is_up() { scrape_backend(&b.addr, timeout).ok() } else { None };
            match text.and_then(|t| obsv::parse_exposition(&t).ok()) {
                Some(p) => parsed.push((i, p)),
                None => {
                    b.scrape_errors.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        out.push_str(
            "# HELP lpcs_backend_scrape_errors Federated scrape failures per backend.\n\
             # TYPE lpcs_backend_scrape_errors counter\n",
        );
        for (i, b) in self.backends.iter().enumerate() {
            out.push_str(&format!(
                "lpcs_backend_scrape_errors{{backend=\"{i}\"}} {}\n",
                b.scrape_errors.load(Ordering::Relaxed)
            ));
        }

        // Merge the backends' parsed expositions. BTreeMaps keep family
        // and label-set order deterministic, so repeated scrapes of a
        // quiescent fleet render byte-identical text.
        let mut helps: BTreeMap<String, String> = BTreeMap::new();
        let mut kinds: BTreeMap<String, String> = BTreeMap::new();
        let mut merged: BTreeMap<(String, String), Histogram> = BTreeMap::new();
        let mut jobs_total: BTreeMap<String, i64> = BTreeMap::new();
        let mut scalars: BTreeMap<String, Vec<(usize, String, i64)>> = BTreeMap::new();
        for (i, p) in &parsed {
            for (name, h) in &p.helps {
                helps.entry(name.clone()).or_insert_with(|| h.clone());
            }
            for (name, k) in &p.kinds {
                kinds.entry(name.clone()).or_insert_with(|| k.clone());
            }
            for ((fam, labs), ph) in &p.hists {
                // A series with foreign bucket bounds or non-monotone
                // cumulative counts is skipped, not merged: one odd
                // backend cannot poison the fleet view.
                let Some(h) = Histogram::from_cumulative(ph) else { continue };
                match merged.entry((fam.clone(), labs.clone())) {
                    std::collections::btree_map::Entry::Occupied(e) => e.get().merge_from(&h),
                    std::collections::btree_map::Entry::Vacant(v) => {
                        v.insert(h);
                    }
                }
            }
            for ((name, labs), v) in &p.scalars {
                if name == "lpcs_jobs_total" {
                    *jobs_total.entry(labs.clone()).or_default() += v;
                } else {
                    scalars.entry(name.clone()).or_default().push((*i, labs.clone(), *v));
                }
            }
        }

        let mut cur_fam: Option<&str> = None;
        for ((fam, labs), h) in &merged {
            if cur_fam != Some(fam.as_str()) {
                cur_fam = Some(fam.as_str());
                let help =
                    helps.get(fam).map(String::as_str).unwrap_or("Merged backend family.");
                out.push_str(&format!("# HELP {fam} {help}\n# TYPE {fam} histogram\n"));
            }
            obsv::render_histogram_series(&mut out, fam, labs, &h.snapshot());
        }
        if !jobs_total.is_empty() {
            let help = helps
                .get("lpcs_jobs_total")
                .map(String::as_str)
                .unwrap_or("Terminal jobs by solver/engine/bits and outcome.");
            out.push_str(&format!(
                "# HELP lpcs_jobs_total {help}\n# TYPE lpcs_jobs_total counter\n"
            ));
            for (labs, v) in &jobs_total {
                out.push_str(&format!("lpcs_jobs_total{{{labs}}} {v}\n"));
            }
        }
        for (name, rows) in &scalars {
            let kind = kinds.get(name).map(String::as_str).unwrap_or("gauge");
            let help = helps.get(name).map(String::as_str).unwrap_or("Backend series.");
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
            for (i, labs, v) in rows {
                let lab = if labs.is_empty() {
                    format!("backend=\"{i}\"")
                } else {
                    format!("backend=\"{i}\",{labs}")
                };
                out.push_str(&format!("{name}{{{lab}}} {v}\n"));
            }
        }
        out
    }

    /// Register a placed job and hand out its router-scoped id.
    pub(crate) fn admit(&self, backend: usize, backend_job: JobId, ws: WireJobSpec) -> JobId {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.table.lock().unwrap().insert(
            id,
            RouteEntry { backend, backend_job, spec: Some(ws), done: false, generation: 0 },
        );
        self.metrics.routed.fetch_add(1, Ordering::Relaxed);
        self.metrics.backend(backend).routed.fetch_add(1, Ordering::Relaxed);
        id
    }

    pub(crate) fn entry_view(&self, id: JobId) -> Option<EntryView> {
        self.table.lock().unwrap().get(&id).map(|e| EntryView {
            backend: e.backend,
            backend_job: e.backend_job,
            generation: e.generation,
        })
    }

    pub(crate) fn mark_done(&self, id: JobId) {
        if let Some(e) = self.table.lock().unwrap().get_mut(&id) {
            e.done = true;
            e.spec = None; // release the operator bytes; outcomes live on the backend
        }
    }

    /// Re-place `id` after its upstream stream was lost: resubmit the
    /// stored spec to a (possibly different) live backend. The
    /// generation guard makes concurrent relays converge on one
    /// resubmission — a loser's duplicate runs out unwatched on its
    /// backend, but never reaches a stream.
    pub(crate) fn failover(
        &self,
        id: JobId,
        seen_generation: u64,
    ) -> Result<EntryView, ErrCode> {
        let spec = {
            let table = self.table.lock().unwrap();
            let e = table.get(&id).ok_or(ErrCode::UnknownJob)?;
            if e.done {
                // Another relay already delivered this job's Done.
                return Err(ErrCode::Internal);
            }
            if e.generation != seen_generation {
                // A concurrent relay already re-placed it; ride along.
                return Ok(EntryView {
                    backend: e.backend,
                    backend_job: e.backend_job,
                    generation: e.generation,
                });
            }
            e.spec.clone().ok_or(ErrCode::Internal)?
        };
        let key = route_key(&spec);
        for _ in 0..self.backends.len() {
            let Some(i) = self.pick_backend(key) else { break };
            match relay::forward_submit(self, i, &spec) {
                Ok((backend_job, _trace)) => {
                    let mut table = self.table.lock().unwrap();
                    let e = table.get_mut(&id).ok_or(ErrCode::UnknownJob)?;
                    if e.generation != seen_generation {
                        return Ok(EntryView {
                            backend: e.backend,
                            backend_job: e.backend_job,
                            generation: e.generation,
                        });
                    }
                    e.backend = i;
                    e.backend_job = backend_job;
                    e.generation += 1;
                    return Ok(EntryView { backend: i, backend_job, generation: e.generation });
                }
                Err(we) => match we.code {
                    // A live backend refused the resubmit (queue full,
                    // …): surface its verdict to the watcher.
                    Some(code) => return Err(code),
                    None => {
                        self.mark_backend_down(i);
                        continue;
                    }
                },
            }
        }
        Err(ErrCode::BackendDown)
    }
}

/// One backend's `ScrapeReq` → `Scrape` round trip under `timeout` —
/// the federation fan-out leg. Goes through the relay's raw
/// [`relay::Upstream`] (not [`crate::wire::WireClient`]) so the
/// per-backend deadline applies end to end.
fn scrape_backend(addr: &str, timeout: Duration) -> Result<String> {
    let mut up = relay::Upstream::connect(addr, timeout)?;
    up.send(&Message::ScrapeReq)?;
    match up.recv(timeout)? {
        Message::Scrape { text } => Ok(text),
        other => bail!("unexpected scrape reply: {other:?}"),
    }
}

/// Handle to a running router. Dropping it only raises the shutdown
/// flag; call [`RouterServer::shutdown`] for the bounded join.
pub struct RouterServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    health: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    state: Arc<RouterState>,
}

impl RouterServer {
    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn state(&self) -> &RouterState {
        &self.state
    }

    pub fn metrics(&self) -> &RouterMetrics {
        &self.state.metrics
    }

    /// Stop accepting, wake every relay and the prober, join them all.
    /// Bounded: every blocking wait in the router ticks and re-checks
    /// the flag.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            h.join().expect("router accept thread panicked");
        }
        if let Some(h) = self.health.take() {
            h.join().expect("router health prober panicked");
        }
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in conns {
            h.join().expect("router connection handler panicked");
        }
    }
}

impl Drop for RouterServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }
}

/// Start routing on `listen` (e.g. `"127.0.0.1:0"`) across
/// `cfg.backends`.
pub fn serve(cfg: RouterConfig, listen: &str) -> Result<RouterServer> {
    if cfg.backends.is_empty() {
        bail!("router needs at least one backend (router.backends=… or backend=…)");
    }
    let listener = TcpListener::bind(listen)
        .with_context(|| format!("binding router listener on {listen}"))?;
    listener.set_nonblocking(true).context("non-blocking router listener")?;
    let addr = listener.local_addr().context("router listener address")?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let state = Arc::new(RouterState::new(cfg, shutdown.clone()));
    let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

    let health = {
        let state = state.clone();
        std::thread::Builder::new()
            .name("lpcs-router-health".into())
            .spawn(move || health::run_prober(state))
            .expect("spawn router health prober")
    };

    let accept = {
        let shutdown = shutdown.clone();
        let conns = conns.clone();
        let state = state.clone();
        std::thread::Builder::new()
            .name("lpcs-router-accept".into())
            .spawn(move || loop {
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let state = state.clone();
                        let handle = std::thread::Builder::new()
                            .name("lpcs-router-conn".into())
                            .spawn(move || relay::handle_conn(stream, state))
                            .expect("spawn router connection handler");
                        // Reap finished handlers so a long-running
                        // router doesn't accumulate joinable threads.
                        let mut conns = conns.lock().unwrap();
                        conns.retain(|h| !h.is_finished());
                        conns.push(handle);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(10)),
                }
            })
            .expect("spawn router accept thread")
    };

    Ok(RouterServer { addr, shutdown, accept: Some(accept), health: Some(health), conns, state })
}
