//! The router's data path: per-connection request handling, submit
//! routing with failover, and the resume-capable watch relay.
//!
//! A client-facing connection looks exactly like one to `lpcs serve` —
//! same frames, same request/stream discipline — so [`crate::wire::WireClient`]
//! works against either tier unchanged. Underneath, `Submit` is
//! forwarded to the ring-chosen backend, `Subscribe` opens a raw
//! upstream subscription and pumps it through, and when that upstream
//! dies mid-stream the relay resubmits the stored spec to a surviving
//! backend and *resumes*: the re-solve is deterministic (seeded), so the
//! replayed iterations are filtered and the client sees one strictly
//! monotone stream with a bumped epoch and exactly one `Done`.

use super::{EntryView, RouterState};
use crate::coordinator::JobId;
use crate::wire::codec::{
    self, BackendStats, ErrCode, FrameReader, Message, PollError, WireJobSpec,
};
use crate::wire::{WireClient, WireError};
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How often blocked reads wake to check the shutdown flag.
const POLL_TICK: Duration = Duration::from_millis(100);
/// A peer that cannot absorb a frame for this long is declared dead.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);
/// Upstream losses one watch stream tolerates before reporting the job
/// lost — bounds resubmit storms when the whole fleet is flapping.
const MAX_FAILOVERS: usize = 5;

/// A raw connection to a backend. Deliberately *not* a [`WireClient`]:
/// the relay must see every frame kind verbatim (epoched `Progress`,
/// `QueuePos`) and apply its own per-call deadlines, so it stays at the
/// codec layer. The health prober shares it for the same reason.
pub(crate) struct Upstream {
    stream: TcpStream,
    reader: FrameReader,
}

impl Upstream {
    pub(crate) fn connect(addr: &str, timeout: Duration) -> Result<Self> {
        let sa = addr
            .to_socket_addrs()
            .context("resolving backend address")?
            .next()
            .context("backend address resolved to nothing")?;
        let stream = TcpStream::connect_timeout(&sa, timeout)
            .with_context(|| format!("connecting to backend {addr}"))?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(POLL_TICK)).context("setting backend read timeout")?;
        stream.set_write_timeout(Some(WRITE_TIMEOUT)).context("setting backend write timeout")?;
        Ok(Self { stream, reader: FrameReader::new() })
    }

    pub(crate) fn send(&mut self, msg: &Message) -> Result<()> {
        let frame = codec::try_encode(msg).context("encoding backend frame")?;
        self.stream.write_all(&frame).context("writing to backend")
    }

    /// Next frame within `deadline` (checked at `POLL_TICK` granularity).
    pub(crate) fn recv(&mut self, deadline: Duration) -> Result<Message> {
        let until = Instant::now() + deadline;
        loop {
            match self.poll()? {
                Some(msg) => return Ok(msg),
                None => {
                    if Instant::now() >= until {
                        bail!("backend reply timed out after {deadline:?}");
                    }
                }
            }
        }
    }

    /// One read tick: `Ok(None)` = nothing complete yet.
    pub(crate) fn poll(&mut self) -> Result<Option<Message>> {
        match self.reader.poll(&mut self.stream) {
            Ok(m) => Ok(m),
            Err(PollError::Closed) => bail!("backend closed the connection"),
            Err(e) => bail!("reading backend frame: {e}"),
        }
    }
}

/// Submit `ws` to backend `i`, returning the backend's job id and the
/// job's trace id (minted client-side if the submitter sent none). A
/// typed error (`code: Some`) is a live backend's verdict and must be
/// propagated, not failed over; `code: None` is transport loss and the
/// caller should mark the backend down and try the next one.
///
/// Successful forwards record the submit-forward hop latency (connect
/// included — that's part of the hop the router adds) into
/// `lpcs_router_submit_forward_us{backend="i"}`, exemplar-tagged with
/// the trace id.
pub(crate) fn forward_submit(
    state: &RouterState,
    backend: usize,
    ws: &WireJobSpec,
) -> std::result::Result<(JobId, u64), WireError> {
    let addr = &state.backends[backend].addr;
    let t0 = Instant::now();
    let mut client = WireClient::connect_timeout(addr, state.forward_timeout())
        .map_err(|e| WireError { code: None, msg: format!("{e:#}"), retry_after_ms: None })?;
    let res = client.submit_traced(ws);
    if let Ok((_, trace)) = &res {
        let us = t0.elapsed().as_micros() as u64;
        let h = &state.hops.submit_forward[backend];
        h.record(us);
        h.record_exemplar(us, crate::obsv::TraceId(*trace));
    }
    res
}

fn send(conn: &mut TcpStream, msg: &Message) -> std::io::Result<()> {
    let frame = codec::try_encode(msg)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
    conn.write_all(&frame)
}

/// One client-facing connection (mirrors the wire server's handler).
pub(crate) fn handle_conn(mut conn: TcpStream, state: Arc<RouterState>) {
    conn.set_nodelay(true).ok();
    conn.set_read_timeout(Some(POLL_TICK)).ok();
    conn.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
    let mut reader = FrameReader::new();
    loop {
        if state.is_shutdown() {
            return;
        }
        let msg = match reader.poll(&mut conn) {
            Ok(None) => continue, // read tick; re-check shutdown
            Ok(Some(msg)) => msg,
            Err(PollError::Closed) | Err(PollError::Io(_)) => return,
            Err(PollError::Decode(e)) => {
                let code = match e {
                    codec::DecodeError::BadVersion(_) => ErrCode::VersionMismatch,
                    _ => ErrCode::Protocol,
                };
                let _ = send(
                    &mut conn,
                    &Message::Err {
                        code,
                        msg: format!("protocol error: {e}"),
                        retry_after_ms: None,
                    },
                );
                return;
            }
        };
        let ok = match msg {
            Message::Submit(ws) => send(&mut conn, &submit(&state, ws)).is_ok(),
            Message::Subscribe { id } => match relay_watch(&state, id, &mut conn) {
                WatchEnd::Clean => true,
                WatchEnd::Disconnected | WatchEnd::Shutdown => return,
            },
            Message::Cancel { id } => send(&mut conn, &do_cancel(&state, id)).is_ok(),
            Message::MetricsReq => {
                let snapshot =
                    crate::obsv::MetricsSnapshot::Router(state.snapshot_struct()).render_legacy();
                send(&mut conn, &Message::Metrics { snapshot }).is_ok()
            }
            // The router face answers scrapes with the *federated*
            // exposition: its own counters and per-hop histograms plus
            // every live backend's families, merged.
            Message::ScrapeReq => {
                send(&mut conn, &Message::Scrape { text: state.scrape() }).is_ok()
            }
            // The router's own load sample, in the same frame backends
            // answer with: table occupancy against its bound, and how
            // many backends are currently up where a backend reports
            // workers.
            Message::StatsReq => send(
                &mut conn,
                &Message::Stats(BackendStats {
                    queue_depth: state.inflight() as u64,
                    queue_capacity: state.cfg.max_inflight as u64,
                    workers: state.up_count() as u64,
                }),
            )
            .is_ok(),
            _ => send(
                &mut conn,
                &Message::Err {
                    code: ErrCode::Protocol,
                    msg: "unexpected router-bound frame".into(),
                    retry_after_ms: None,
                },
            )
            .is_ok(),
        };
        if !ok {
            return;
        }
    }
}

/// Route one submit: admission checks, ring choice, forward, and
/// failover across backends that prove dead on contact.
fn submit(state: &RouterState, ws: WireJobSpec) -> Message {
    let inflight = state.inflight();
    if inflight >= state.cfg.max_inflight {
        state.metrics.rejected_full.fetch_add(1, Ordering::Relaxed);
        return Message::Err {
            code: ErrCode::QueueFull,
            msg: format!(
                "router in-flight table full ({inflight}/{}); retry later",
                state.cfg.max_inflight
            ),
            retry_after_ms: None,
        };
    }
    let key = codec::route_key(&ws);
    // Each pass either succeeds, returns a typed verdict, or marks a
    // backend down — so the up-set shrinks and this terminates.
    for _ in 0..state.backends.len() {
        let Some(i) = state.pick_backend(key) else { break };
        if state.cfg.queue_limit > 0
            && state.backends[i].queue_depth.load(Ordering::Relaxed)
                >= state.cfg.queue_limit as u64
        {
            state.metrics.rejected_full.fetch_add(1, Ordering::Relaxed);
            return Message::Err {
                code: ErrCode::QueueFull,
                msg: format!(
                    "backend {} at queue limit ({} queued >= {})",
                    state.backends[i].addr,
                    state.backends[i].queue_depth.load(Ordering::Relaxed),
                    state.cfg.queue_limit
                ),
                retry_after_ms: None,
            };
        }
        match forward_submit(state, i, &ws) {
            Ok((backend_job, trace)) => {
                let id = state.admit(i, backend_job, ws);
                return Message::Submitted { id, trace };
            }
            Err(we) => match we.code {
                Some(code) => {
                    // A live backend rejected (queue full, invalid spec,
                    // …): propagate its typed verdict — and its retry
                    // hint — never buffer the job router-side hoping
                    // for capacity.
                    if code == ErrCode::QueueFull {
                        state.metrics.rejected_full.fetch_add(1, Ordering::Relaxed);
                    }
                    return Message::Err {
                        code,
                        msg: we.msg,
                        retry_after_ms: we.retry_after_ms,
                    };
                }
                None => {
                    state.mark_backend_down(i);
                    continue;
                }
            },
        }
    }
    state.metrics.rejected_down.fetch_add(1, Ordering::Relaxed);
    Message::Err {
        code: ErrCode::BackendDown,
        msg: "no backend available".into(),
        retry_after_ms: None,
    }
}

fn do_cancel(state: &RouterState, id: JobId) -> Message {
    let Some(view) = state.entry_view(id) else {
        // Mirrors the wire server: unknown/terminal jobs answer
        // `accepted: false` rather than an error.
        return Message::Cancelled { id, accepted: false };
    };
    let accepted = WireClient::connect_timeout(
        &state.backends[view.backend].addr,
        state.forward_timeout(),
    )
    .ok()
    .and_then(|mut c| c.cancel(view.backend_job).ok())
    .unwrap_or(false);
    Message::Cancelled { id, accepted }
}

enum WatchEnd {
    /// Stream terminated with a frame; connection back in request mode.
    Clean,
    /// The watching client died mid-stream.
    Disconnected,
    Shutdown,
}

enum PumpEnd {
    /// Terminal `Done` relayed (`true`) or the client died taking it.
    Done(bool),
    ClientGone,
    Shutdown,
    /// The upstream stream was lost before its terminal frame.
    /// `backend_dead` distinguishes transport loss (mark the backend
    /// down) from a live backend that no longer knows the job (it
    /// bounced and lost state — resume elsewhere, don't mark it down).
    Lost { backend_dead: bool },
}

/// Relay one watch stream, failing over across backend losses.
fn relay_watch(state: &RouterState, id: JobId, conn: &mut TcpStream) -> WatchEnd {
    let Some(mut view) = state.entry_view(id) else {
        let reply = Message::Err {
            code: ErrCode::UnknownJob,
            msg: format!("unknown job {id}"),
            retry_after_ms: None,
        };
        return if send(conn, &reply).is_ok() { WatchEnd::Clean } else { WatchEnd::Disconnected };
    };
    let mut epoch: u32 = 0;
    let mut last_iter: Option<usize> = None;
    let mut failovers = 0usize;
    loop {
        let backend_dead = match subscribe_upstream(state, &view) {
            Ok(mut up) => {
                match pump(state, id, view.backend, epoch, &mut last_iter, &mut up, conn) {
                    PumpEnd::Done(true) => return WatchEnd::Clean,
                    PumpEnd::Done(false) | PumpEnd::ClientGone => return WatchEnd::Disconnected,
                    PumpEnd::Shutdown => return WatchEnd::Shutdown,
                    PumpEnd::Lost { backend_dead } => backend_dead,
                }
            }
            Err(()) => true,
        };
        failovers += 1;
        if failovers > MAX_FAILOVERS {
            let reply = Message::Err {
                code: ErrCode::BackendDown,
                msg: format!("job {id} lost after {MAX_FAILOVERS} failovers"),
                retry_after_ms: None,
            };
            return if send(conn, &reply).is_ok() {
                WatchEnd::Clean
            } else {
                WatchEnd::Disconnected
            };
        }
        if backend_dead {
            state.mark_backend_down(view.backend);
        }
        let lost_at = Instant::now();
        match state.failover(id, view.generation) {
            Ok(next) => {
                // Resume: new upstream job, next epoch; `last_iter`
                // persists so replayed iterations are swallowed.
                state.metrics.resumed.fetch_add(1, Ordering::Relaxed);
                state.metrics.backend(next.backend).resumed.fetch_add(1, Ordering::Relaxed);
                state.hops.failover_resume[next.backend]
                    .record(lost_at.elapsed().as_micros() as u64);
                view = next;
                epoch += 1;
            }
            Err(code) => {
                let reply = Message::Err {
                    code,
                    msg: format!("job {id}: resume after backend loss failed"),
                    retry_after_ms: None,
                };
                return if send(conn, &reply).is_ok() {
                    WatchEnd::Clean
                } else {
                    WatchEnd::Disconnected
                };
            }
        }
    }
}

/// Open a subscription to the entry's current backend. `Err` is always
/// transport-level (connect or first write failed).
fn subscribe_upstream(state: &RouterState, view: &EntryView) -> Result<Upstream, ()> {
    let mut up = Upstream::connect(&state.backends[view.backend].addr, state.forward_timeout())
        .map_err(|_| ())?;
    up.send(&Message::Subscribe { id: view.backend_job }).map_err(|_| ())?;
    Ok(up)
}

/// Pump one upstream subscription onto the client connection until a
/// terminal frame, a loss, client death, or shutdown. Records the
/// subscribe→first-`Progress` hop latency once per upstream stream and
/// the per-frame fan-out delay (upstream receipt → client write done),
/// both labeled by `backend`.
#[allow(clippy::too_many_arguments)]
fn pump(
    state: &RouterState,
    id: JobId,
    backend: usize,
    epoch: u32,
    last_iter: &mut Option<usize>,
    up: &mut Upstream,
    conn: &mut TcpStream,
) -> PumpEnd {
    let subscribed_at = Instant::now();
    let mut first_progress_seen = false;
    loop {
        match up.poll() {
            Ok(None) => {
                if state.is_shutdown() {
                    return PumpEnd::Shutdown;
                }
            }
            Ok(Some(Message::Progress { stat, trace, .. })) => {
                if !first_progress_seen {
                    first_progress_seen = true;
                    let us = subscribed_at.elapsed().as_micros() as u64;
                    let h = &state.hops.first_progress[backend];
                    h.record(us);
                    h.record_exemplar(us, crate::obsv::TraceId(trace));
                }
                // Replay filter: after a resume the re-solve restarts at
                // iteration 0 and (being seeded) replays the same
                // trajectory; forward only iterations this stream has
                // not already delivered, under the router's epoch.
                if last_iter.is_some_and(|last| stat.iter <= last) {
                    continue;
                }
                *last_iter = Some(stat.iter);
                let received_at = Instant::now();
                if send(conn, &Message::Progress { id, epoch, stat, trace }).is_err() {
                    return PumpEnd::ClientGone;
                }
                state.hops.fanout_delay[backend]
                    .record(received_at.elapsed().as_micros() as u64);
            }
            Ok(Some(Message::QueuePos { position, depth, .. })) => {
                if send(conn, &Message::QueuePos { id, position, depth }).is_err() {
                    return PumpEnd::ClientGone;
                }
            }
            Ok(Some(Message::Done(mut out))) => {
                out.id = id; // the client knows its router-assigned id
                state.mark_done(id);
                return PumpEnd::Done(send(conn, &Message::Done(out)).is_ok());
            }
            // A live backend ended the stream without a Done — after a
            // bounce it answers Subscribe with `unknown job`. The job is
            // recoverable even though the backend is healthy.
            Ok(Some(Message::Err { .. })) => return PumpEnd::Lost { backend_dead: false },
            // Any other frame is a protocol violation from the backend;
            // treat the stream as lost but leave liveness to the prober.
            Ok(Some(_)) => return PumpEnd::Lost { backend_dead: false },
            Err(_) => return PumpEnd::Lost { backend_dead: true },
        }
    }
}
