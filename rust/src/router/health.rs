//! Backend liveness: per-backend health state and the prober thread.
//!
//! The prober walks every configured backend each round, sampling its
//! load with a `StatsReq` under the probe deadline. `down_after`
//! consecutive failures mark a backend down (removed from the hash
//! ring); one success re-admits it immediately and refreshes the cached
//! queue depth the admission check reads. Data-path failures (a forward
//! or relay losing its connection) mark a backend down without waiting
//! for the prober — the prober is how it comes *back*.

use super::relay::Upstream;
use super::RouterState;
use crate::wire::codec::{BackendStats, Message};
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Shared health/load view of one configured backend. Lock-free: the
/// data path reads `up`/`queue_depth` on every submit.
#[derive(Debug)]
pub struct BackendState {
    pub addr: String,
    /// Starts optimistic (`true`) so the router serves immediately; the
    /// first probe round corrects it.
    up: AtomicBool,
    /// Consecutive probe failures (reset on success).
    failures: AtomicU32,
    /// Last probed queue depth/capacity — the admission check's view of
    /// backend load (staleness bounded by the probe period).
    pub queue_depth: AtomicU64,
    pub queue_capacity: AtomicU64,
    /// Federated-scrape failures against this backend (down at scrape
    /// time, or up but unreachable within the per-backend deadline).
    /// Exposed as `lpcs_backend_scrape_errors{backend="i"}` so a dead
    /// backend shows up in the fleet exposition instead of stalling it.
    pub scrape_errors: AtomicU64,
}

impl BackendState {
    pub(crate) fn new(addr: String) -> Self {
        Self {
            addr,
            up: AtomicBool::new(true),
            failures: AtomicU32::new(0),
            queue_depth: AtomicU64::new(0),
            queue_capacity: AtomicU64::new(0),
            scrape_errors: AtomicU64::new(0),
        }
    }

    pub fn is_up(&self) -> bool {
        self.up.load(Ordering::SeqCst)
    }

    /// Flip the up flag, returning the previous value (so callers act
    /// only on actual transitions).
    pub(crate) fn set_up(&self, up: bool) -> bool {
        self.up.swap(up, Ordering::SeqCst)
    }
}

/// One probe: connect + `StatsReq`, both under `timeout`.
/// [`crate::wire::WireClient::stats`] would wait its 120 s reply
/// deadline — far too long for a health check — so this goes through the
/// relay's raw [`Upstream`] with the probe deadline applied end to end.
fn probe(addr: &str, timeout: Duration) -> Result<BackendStats> {
    let mut up = Upstream::connect(addr, timeout)?;
    up.send(&Message::StatsReq)?;
    match up.recv(timeout)? {
        Message::Stats(st) => Ok(st),
        other => bail!("unexpected probe reply: {other:?}"),
    }
}

/// Remaining sleep after a probe round: the configured period minus the
/// time the round itself took, floored at zero. Probes run serially
/// under a per-probe timeout, so k unreachable backends cost up to
/// k×timeout of round time — the cadence must absorb that instead of
/// adding a full period on top (which would stretch down-detection and
/// re-admission linearly in the number of dead backends).
fn cooldown(period: Duration, round_elapsed: Duration) -> Duration {
    period.saturating_sub(round_elapsed)
}

/// The prober loop (one thread per router).
pub(crate) fn run_prober(state: Arc<RouterState>) {
    let period = Duration::from_millis(state.cfg.probe_ms.max(10));
    let timeout = Duration::from_millis(state.cfg.probe_timeout_ms.max(10));
    while !state.is_shutdown() {
        let round = Instant::now();
        for (i, b) in state.backends.iter().enumerate() {
            if state.is_shutdown() {
                return;
            }
            match probe(&b.addr, timeout) {
                Ok(st) => {
                    b.queue_depth.store(st.queue_depth, Ordering::Relaxed);
                    b.queue_capacity.store(st.queue_capacity, Ordering::Relaxed);
                    b.failures.store(0, Ordering::Relaxed);
                    if !b.set_up(true) {
                        // Recovered: rejoin the ring. Keys it owned
                        // before the outage route back to it (the ring
                        // build is deterministic), restoring affinity.
                        state.rebuild_ring();
                    }
                }
                Err(_) => {
                    let failures = b.failures.fetch_add(1, Ordering::Relaxed) + 1;
                    if failures >= state.cfg.down_after && b.is_up() {
                        state.mark_backend_down(i);
                    }
                }
            }
        }
        state.sleep_ticked(cooldown(period, round.elapsed()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RouterConfig;
    use std::net::TcpListener;

    #[test]
    fn cooldown_deducts_round_time_and_floors_at_zero() {
        let p = Duration::from_millis(200);
        assert_eq!(cooldown(p, Duration::from_millis(0)), p);
        assert_eq!(cooldown(p, Duration::from_millis(150)), Duration::from_millis(50));
        assert_eq!(cooldown(p, Duration::from_millis(200)), Duration::ZERO);
        assert_eq!(cooldown(p, Duration::from_millis(900)), Duration::ZERO);
    }

    #[test]
    fn dead_backends_do_not_stretch_round_cadence() {
        // Two backends that accept but never answer: every probe burns
        // the full probe timeout, so a round takes ~2×timeout > period
        // and the cooldown must collapse to zero. The old loop slept a
        // FULL period on top of the round (cadence period + 2×timeout
        // ≈ 500 ms); the fixed loop's cadence is the round time itself
        // (~300 ms). Counting probe attempts over a fixed window
        // separates the two cleanly.
        let mut addrs = Vec::new();
        for _ in 0..2 {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            addrs.push(listener.local_addr().unwrap().to_string());
            std::thread::spawn(move || {
                // Hold accepted sockets open, never reply.
                let mut held = Vec::new();
                while let Ok((s, _)) = listener.accept() {
                    held.push(s);
                }
            });
        }
        let cfg = RouterConfig {
            backends: addrs,
            probe_ms: 200,
            probe_timeout_ms: 150,
            // Never transitions down: this test pins cadence, not
            // membership (and keeps ring rebuilds out of the picture).
            down_after: u32::MAX,
            ..Default::default()
        };
        let shutdown = Arc::new(AtomicBool::new(false));
        let state = Arc::new(RouterState::new(cfg, shutdown.clone()));
        let prober = {
            let state = state.clone();
            std::thread::spawn(move || run_prober(state))
        };
        std::thread::sleep(Duration::from_millis(1300));
        shutdown.store(true, Ordering::SeqCst);
        prober.join().unwrap();
        let attempts: u64 = state
            .backends
            .iter()
            .map(|b| b.failures.load(Ordering::Relaxed) as u64)
            .sum();
        // Fixed cadence: ~4 full rounds in 1.3 s → ≥ 7 attempts (the
        // un-fixed 500 ms cadence manages ~5).
        assert!(attempts >= 7, "prober made only {attempts} probe attempts in 1.3s");
    }
}
