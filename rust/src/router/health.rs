//! Backend liveness: per-backend health state and the prober thread.
//!
//! The prober walks every configured backend each round, sampling its
//! load with a `StatsReq` under the probe deadline. `down_after`
//! consecutive failures mark a backend down (removed from the hash
//! ring); one success re-admits it immediately and refreshes the cached
//! queue depth the admission check reads. Data-path failures (a forward
//! or relay losing its connection) mark a backend down without waiting
//! for the prober — the prober is how it comes *back*.

use super::relay::Upstream;
use super::RouterState;
use crate::wire::codec::{BackendStats, Message};
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Shared health/load view of one configured backend. Lock-free: the
/// data path reads `up`/`queue_depth` on every submit.
#[derive(Debug)]
pub struct BackendState {
    pub addr: String,
    /// Starts optimistic (`true`) so the router serves immediately; the
    /// first probe round corrects it.
    up: AtomicBool,
    /// Consecutive probe failures (reset on success).
    failures: AtomicU32,
    /// Last probed queue depth/capacity — the admission check's view of
    /// backend load (staleness bounded by the probe period).
    pub queue_depth: AtomicU64,
    pub queue_capacity: AtomicU64,
}

impl BackendState {
    pub(crate) fn new(addr: String) -> Self {
        Self {
            addr,
            up: AtomicBool::new(true),
            failures: AtomicU32::new(0),
            queue_depth: AtomicU64::new(0),
            queue_capacity: AtomicU64::new(0),
        }
    }

    pub fn is_up(&self) -> bool {
        self.up.load(Ordering::SeqCst)
    }

    /// Flip the up flag, returning the previous value (so callers act
    /// only on actual transitions).
    pub(crate) fn set_up(&self, up: bool) -> bool {
        self.up.swap(up, Ordering::SeqCst)
    }
}

/// One probe: connect + `StatsReq`, both under `timeout`.
/// [`crate::wire::WireClient::stats`] would wait its 120 s reply
/// deadline — far too long for a health check — so this goes through the
/// relay's raw [`Upstream`] with the probe deadline applied end to end.
fn probe(addr: &str, timeout: Duration) -> Result<BackendStats> {
    let mut up = Upstream::connect(addr, timeout)?;
    up.send(&Message::StatsReq)?;
    match up.recv(timeout)? {
        Message::Stats(st) => Ok(st),
        other => bail!("unexpected probe reply: {other:?}"),
    }
}

/// The prober loop (one thread per router).
pub(crate) fn run_prober(state: Arc<RouterState>) {
    let period = Duration::from_millis(state.cfg.probe_ms.max(10));
    let timeout = Duration::from_millis(state.cfg.probe_timeout_ms.max(10));
    while !state.is_shutdown() {
        for (i, b) in state.backends.iter().enumerate() {
            if state.is_shutdown() {
                return;
            }
            match probe(&b.addr, timeout) {
                Ok(st) => {
                    b.queue_depth.store(st.queue_depth, Ordering::Relaxed);
                    b.queue_capacity.store(st.queue_capacity, Ordering::Relaxed);
                    b.failures.store(0, Ordering::Relaxed);
                    if !b.set_up(true) {
                        // Recovered: rejoin the ring. Keys it owned
                        // before the outage route back to it (the ring
                        // build is deterministic), restoring affinity.
                        state.rebuild_ring();
                    }
                }
                Err(_) => {
                    let failures = b.failures.fetch_add(1, Ordering::Relaxed) + 1;
                    if failures >= state.cfg.down_after && b.is_up() {
                        state.mark_backend_down(i);
                    }
                }
            }
        }
        state.sleep_ticked(period);
    }
}
