//! Consistent-hash ring over backend indices.
//!
//! Each backend contributes `vnodes` points at
//! `fnv64("{addr}#{v}")`; a key routes to the first point clockwise
//! (ties broken by backend index so rebuilds are deterministic). The
//! construction gives the two properties the router leans on:
//!
//! * **Determinism** — same up-set, same vnode count → identical ring,
//!   so every router connection (and a restarted router) routes a given
//!   [`crate::wire::route_key`] identically.
//! * **Minimal disruption** — removing a backend deletes only its own
//!   points; every key that routed to a surviving backend keeps routing
//!   to it, so one crash never reshuffles the whole fleet's batch
//!   affinity.
//!
//! Both are pinned by the in-module tests and the `tests/router_serving.rs`
//! property suite.

use crate::wire::codec::fnv64;

/// An immutable routing snapshot: `(point_hash, backend_index)` sorted
/// by hash. Rebuilt (never mutated) whenever the up-set changes.
#[derive(Debug, Clone, Default)]
pub struct HashRing {
    points: Vec<(u64, usize)>,
}

impl HashRing {
    /// Build from `(backend_index, address)` pairs — typically the
    /// currently-up subset of the configured backends.
    pub fn build<'a, I>(nodes: I, vnodes: usize) -> Self
    where
        I: IntoIterator<Item = (usize, &'a str)>,
    {
        let mut points = Vec::new();
        for (idx, addr) in nodes {
            for v in 0..vnodes.max(1) {
                let h = fnv64(format!("{addr}#{v}").as_bytes());
                points.push((h, idx));
            }
        }
        points.sort_unstable();
        Self { points }
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The backend owning `key`: the first ring point at or after it,
    /// wrapping past the top of the u64 space to the first point.
    pub fn route(&self, key: u64) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        let i = self.points.partition_point(|&(h, _)| h < key);
        let i = if i == self.points.len() { 0 } else { i };
        Some(self.points[i].1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addrs(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("10.0.0.{i}:7070")).collect()
    }

    fn ring_over(addrs: &[String], up: &[usize], vnodes: usize) -> HashRing {
        HashRing::build(up.iter().map(|&i| (i, addrs[i].as_str())), vnodes)
    }

    #[test]
    fn empty_ring_routes_nothing() {
        assert!(HashRing::default().is_empty());
        assert_eq!(HashRing::default().route(42), None);
    }

    #[test]
    fn routing_is_deterministic_and_total() {
        let a = addrs(3);
        let r1 = ring_over(&a, &[0, 1, 2], 64);
        let r2 = ring_over(&a, &[0, 1, 2], 64);
        let mut hit = [false; 3];
        for k in 0..4096u64 {
            let key = k.wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let b = r1.route(key).unwrap();
            assert_eq!(Some(b), r2.route(key), "same build → same routes");
            hit[b] = true;
        }
        assert_eq!(hit, [true; 3], "64 vnodes spread 4096 keys over every backend");
    }

    #[test]
    fn removing_a_backend_only_moves_its_own_keys() {
        let a = addrs(4);
        let full = ring_over(&a, &[0, 1, 2, 3], 64);
        let without_2 = ring_over(&a, &[0, 1, 3], 64);
        for k in 0..4096u64 {
            let key = k.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(17);
            let before = full.route(key).unwrap();
            let after = without_2.route(key).unwrap();
            if before != 2 {
                assert_eq!(before, after, "key {key} moved off a surviving backend");
            } else {
                assert_ne!(after, 2);
            }
        }
    }

    #[test]
    fn wraparound_routes_to_first_point() {
        let a = addrs(2);
        let ring = ring_over(&a, &[0, 1], 4);
        // u64::MAX is ≥ every point with overwhelming likelihood, so it
        // must wrap to whatever backend owns the lowest point — i.e. the
        // same answer as key 0 unless a point sits above u64::MAX - 1.
        assert!(ring.route(u64::MAX).is_some());
        assert!(ring.route(0).is_some());
    }
}
