//! RIP toolkit (S8): non-symmetric restricted-isometry diagnostics.
//!
//! Implements the paper's §3.2 machinery:
//! * `gamma` of the full matrix — `σ_max/σ_min⁺ − 1` with σ_min⁺ the
//!   smallest *nonzero* singular value (for a wide M×N matrix the relevant
//!   Gram operator is the M×M one). By the interlacing argument of §3.2
//!   this upper-bounds γ_{|Γ|} for every support Γ.
//! * Monte-Carlo RIC probes: extremal singular values of Φ_Γ over random
//!   supports of size 2s → empirical α₂ₛ, β₂ₛ (Fig 3's coefficients).
//! * Lemma 1: the minimum bit width guaranteeing γ̂ ≤ 1/16.
//! * Theorem 3 / Corollary 1 error-bound calculators (ε_s, ε_q, the sky
//!   coefficients √L/β₂ₛ and L/β̂₂ₛ).

use crate::linalg::{svd, Mat};
use crate::rng::XorShift128Plus;

/// The paper's γ-threshold for recovery guarantees (Theorem 3).
pub const GAMMA_MAX: f64 = 1.0 / 16.0;

/// Extremal singular values of the full matrix, using the smaller Gram side
/// (σ_min is the smallest nonzero singular value when M < N).
pub fn full_extremes(phi: &Mat, seed: u64) -> svd::SingularExtremes {
    if phi.rows <= phi.cols {
        // Wide: probe Φᵀ (tall), same nonzero spectrum.
        let t = phi.transpose();
        svd::singular_extremes(&t, 1e-6, 4000, seed)
    } else {
        svd::singular_extremes(phi, 1e-6, 4000, seed)
    }
}

/// γ = σ_max/σ_min⁺ − 1 of the full matrix (Fig 7/8 quantity).
pub fn gamma_full(phi: &Mat, seed: u64) -> f64 {
    let se = full_extremes(phi, seed);
    if se.sigma_min <= 0.0 {
        return f64::INFINITY;
    }
    (se.sigma_max / se.sigma_min) as f64 - 1.0
}

/// Empirical RIC probe over random supports.
#[derive(Debug, Clone, Copy)]
pub struct RicEstimate {
    /// min over trials of σ_min(Φ_Γ) — empirical lower bound for α_s.
    pub alpha: f32,
    /// max over trials of σ_max(Φ_Γ) — empirical lower bound for β_s.
    pub beta: f32,
    pub trials: usize,
    pub support_size: usize,
}

impl RicEstimate {
    /// Non-symmetric RIP ratio γ_s = β_s/α_s − 1 (empirical).
    pub fn gamma(&self) -> f64 {
        if self.alpha <= 0.0 {
            f64::INFINITY
        } else {
            (self.beta / self.alpha) as f64 - 1.0
        }
    }
}

/// Monte-Carlo RIC estimate: extremal σ of Φ_Γ over `trials` random
/// supports of the given size.
pub fn ric_probe(phi: &Mat, support_size: usize, trials: usize, seed: u64) -> RicEstimate {
    assert!(support_size >= 1 && support_size <= phi.cols);
    let mut rng = XorShift128Plus::new(seed);
    let mut alpha = f32::MAX;
    let mut beta = 0.0f32;
    for t in 0..trials {
        let supp = rng.choose_k(phi.cols, support_size);
        let sub = phi.take_cols(&supp);
        let se = svd::singular_extremes(&sub, 1e-5, 3000, seed ^ (t as u64) << 17);
        alpha = alpha.min(se.sigma_min);
        beta = beta.max(se.sigma_max);
    }
    RicEstimate { alpha, beta, trials, support_size }
}

/// Lemma 1: minimum bits so that quantization keeps γ̂_{|Γ|} ≤ 1/16, given
/// γ_{|Γ|} ≤ 1/16 − ε with α_{|Γ|} ≥ alpha:
/// `b ≥ log2( 2·√|Γ| / (ε·α) )`.
pub fn lemma1_min_bits(support_size: usize, alpha: f64, eps: f64) -> Option<u32> {
    if eps <= 0.0 || alpha <= 0.0 {
        return None;
    }
    let b = ((2.0 * (support_size as f64).sqrt()) / (eps * alpha)).log2().ceil();
    Some((b.max(2.0)) as u32)
}

/// Lemma 1 combined with a measured γ: returns the bit floor if γ leaves
/// slack below 1/16, else None (the matrix itself violates the condition).
pub fn min_bits_for_matrix(gamma: f64, alpha: f64, support_size: usize) -> Option<u32> {
    let eps = GAMMA_MAX - gamma;
    if eps <= 0.0 {
        return None;
    }
    lemma1_min_bits(support_size, alpha, eps)
}

/// Theorem 3's quantization error term
/// ε_q = √M/β̂₂ₛ · (‖xˢ‖₂/2^{bΦ−1} + 1/2^{bʸ−1}).
pub fn epsilon_q(m: usize, beta_hat_2s: f64, xs_norm: f64, bits_phi: u32, bits_y: u32) -> f64 {
    (m as f64).sqrt() / beta_hat_2s
        * (xs_norm / 2f64.powi(bits_phi as i32 - 1) + 1.0 / 2f64.powi(bits_y as i32 - 1))
}

/// Theorem 2/3's ε_s = ‖x−xˢ‖₂ + ‖x−xˢ‖₁/√s + ‖e‖₂/β₂ₛ.
pub fn epsilon_s(tail_l2: f64, tail_l1: f64, s: usize, noise_l2: f64, beta_2s: f64) -> f64 {
    tail_l2 + tail_l1 / (s as f64).sqrt() + noise_l2 / beta_2s
}

/// Corollary 1's sky error coefficients: (√L/β₂ₛ, L/β̂₂ₛ).
pub fn sky_coefficients(l_antennas: usize, beta_2s: f64, beta_hat_2s: f64) -> (f64, f64) {
    (
        (l_antennas as f64).sqrt() / beta_2s,
        l_antennas as f64 / beta_hat_2s,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantizedMatrix;

    fn gaussian(m: usize, n: usize, seed: u64) -> Mat {
        let mut rng = XorShift128Plus::new(seed);
        Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt())
    }

    #[test]
    fn gamma_of_identity_is_zero() {
        let g = gamma_full(&Mat::identity(10), 1);
        assert!(g.abs() < 1e-3, "γ(I)={g}");
    }

    #[test]
    fn gamma_full_wide_uses_nonzero_spectrum() {
        // Wide Gaussian matrix: finite γ despite N > M.
        let phi = gaussian(30, 120, 2);
        let g = gamma_full(&phi, 2);
        assert!(g.is_finite() && g > 0.0, "γ={g}");
    }

    #[test]
    fn ric_probe_bounds_order() {
        let phi = gaussian(60, 120, 3);
        let e = ric_probe(&phi, 8, 10, 3);
        assert!(e.alpha > 0.0 && e.alpha <= e.beta);
        assert!(e.gamma() > 0.0);
    }

    #[test]
    fn ric_gamma_grows_with_support_size() {
        // Larger supports are worse conditioned (RIP degrades with s).
        let phi = gaussian(60, 120, 4);
        let g4 = ric_probe(&phi, 4, 12, 4).gamma();
        let g24 = ric_probe(&phi, 24, 12, 4).gamma();
        assert!(g24 > g4, "γ(24)={g24} γ(4)={g4}");
    }

    #[test]
    fn ric_probe_submatrix_within_full_extremes() {
        // Interlacing: σ extremes of any submatrix lie inside full extremes.
        let phi = gaussian(40, 60, 5);
        let full = full_extremes(&phi, 5);
        let e = ric_probe(&phi, 6, 8, 5);
        assert!(e.beta <= full.sigma_max * 1.01);
        assert!(e.alpha >= full.sigma_min * 0.99);
    }

    #[test]
    fn lemma1_bits_monotone_in_eps() {
        let tight = lemma1_min_bits(16, 1.0, 0.001).unwrap();
        let loose = lemma1_min_bits(16, 1.0, 0.05).unwrap();
        assert!(tight > loose);
    }

    #[test]
    fn lemma1_bits_two_reachable() {
        // Large α and slack ⇒ the 2-bit floor of Fig 7.
        assert_eq!(lemma1_min_bits(4, 100.0, 0.05).unwrap(), 2);
    }

    #[test]
    fn min_bits_none_when_gamma_violates() {
        assert!(min_bits_for_matrix(0.2, 1.0, 8).is_none());
        assert!(min_bits_for_matrix(0.01, 1.0, 8).is_some());
    }

    #[test]
    fn lemma1_verified_against_quantization() {
        // Quantize at the Lemma-1 floor and check γ̂ ≤ 1/16 empirically.
        // Needs a matrix that satisfies γ ≤ 1/16 − ε: a block of repeated
        // scaled identities has exactly orthogonal equal-norm columns
        // (γ = 0); a small perturbation keeps γ ≪ 1/16.
        let mut rng = XorShift128Plus::new(6);
        let (m, n) = (200, 20);
        let phi = Mat::from_fn(m, n, |i, j| {
            let base = if i % n == j { 1.0 } else { 0.0 };
            base + 0.002 * rng.gaussian_f32()
        });
        let full = full_extremes(&phi, 6);
        let gamma = gamma_full(&phi, 6);
        assert!(gamma < GAMMA_MAX, "test needs a compliant matrix, γ={gamma}");
        let bits = min_bits_for_matrix(gamma, full.sigma_min as f64, 10).unwrap_or(8).min(8);
        let qm = QuantizedMatrix::from_mat(&phi, bits as u8, &mut rng);
        let gh = gamma_full(&qm.to_mat(), 7);
        assert!(gh <= GAMMA_MAX * 1.15, "γ̂={gh} at b={bits}");
    }

    #[test]
    fn epsilon_q_decreases_with_bits() {
        let e2 = epsilon_q(900, 30.0, 5.0, 2, 8);
        let e4 = epsilon_q(900, 30.0, 5.0, 4, 8);
        let e8 = epsilon_q(900, 30.0, 5.0, 8, 8);
        assert!(e2 > e4 && e4 > e8);
    }

    #[test]
    fn epsilon_s_noise_only_for_exactly_sparse() {
        // x = xˢ ⇒ ε_s = ‖e‖/β.
        let e = epsilon_s(0.0, 0.0, 30, 2.0, 40.0);
        assert!((e - 0.05).abs() < 1e-12);
    }

    #[test]
    fn sky_coefficients_scale() {
        let (c1, c2) = sky_coefficients(30, 60.0, 30.0);
        assert!((c1 - 30f64.sqrt() / 60.0).abs() < 1e-12);
        assert!((c2 - 1.0).abs() < 1e-12);
    }
}
