//! Minimal data-parallel helpers over a **persistent worker pool**.
//!
//! The offline build environment has no rayon, so the hot loops use this
//! module instead. The API is deliberately tiny: chunked parallel-for over
//! an output slice (optionally with aligned chunk boundaries) and a
//! parallel map over an index range.
//!
//! Earlier revisions spawned fresh OS threads per call via
//! `std::thread::scope`; NIHT runs hundreds of iterations per recovery and
//! each iteration makes several `par` calls, so thread-creation latency was
//! a fixed tax on every kernel (tens of µs per call — comparable to the
//! 2-bit matvec itself at small sizes). Now a lazily-initialized pool of
//! `available_parallelism` workers is spawned once per process and jobs are
//! pushed onto a shared queue:
//!
//! * the calling thread always executes the first chunk itself, then
//!   **helps** drain the queue while waiting — so progress is guaranteed
//!   even under nested `par` calls or if worker spawn failed;
//! * chunk boundaries depend only on the requested parallelism, and every
//!   kernel built on these helpers computes each output element
//!   independently or in fixed input order, so results are identical for
//!   any `LPCS_THREADS` setting;
//! * worker panics are caught, forwarded, and re-raised on the caller —
//!   never deadlocking the latch.
//!
//! `LPCS_THREADS` is still honored per call (it bounds how many chunks are
//! created; `LPCS_THREADS=1` bypasses the pool entirely).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, TryLockError};

/// Process-wide programmatic thread-count override (0 = none). Preferred
/// over mutating `LPCS_THREADS` at runtime: `std::env::set_var` racing a
/// concurrent `getenv` is UB on glibc, and tests/embedders need a safe knob.
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Override the parallelism decided by [`num_threads`] (`None` clears).
/// Takes precedence over `LPCS_THREADS`; `Some(0)` is clamped to 1, like
/// `LPCS_THREADS=0`.
pub fn set_thread_override(n: Option<usize>) {
    THREAD_OVERRIDE.store(n.map(|v| v.max(1)).unwrap_or(0), Ordering::Relaxed);
}

/// Number of worker threads to use (cores, capped; overridable via
/// [`set_thread_override`] or the `LPCS_THREADS` env var for benchmarking).
pub fn num_threads() -> usize {
    let over = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if over > 0 {
        return over;
    }
    if let Ok(v) = std::env::var("LPCS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

const MAX_WORKERS: usize = 64;

/// Tally of job-queue lock acquisitions that found the lock already held
/// (a `try_lock` miss, then the blocking lock). Monotonic since process
/// start; readers should compare deltas. This is the cheap always-on
/// signal of pool pressure the service metrics expose — if it grows fast
/// relative to job throughput, the single shared queue is the bottleneck
/// and per-worker deques (work stealing) would pay.
static POOL_CONTENTION: AtomicU64 = AtomicU64::new(0);

/// Cumulative count of contended job-queue lock acquisitions (see
/// [`POOL_CONTENTION`]). Exposed through the coordinator's
/// `ServiceMetrics` snapshot as `pool_contention`.
pub fn contention_count() -> u64 {
    POOL_CONTENTION.load(Ordering::Relaxed)
}

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<VecDeque<Job>>,
    ready: Condvar,
}

/// Lock the job queue, tallying contention: a failed `try_lock` costs one
/// counter bump (Relaxed — it's a statistic, not a synchronization edge)
/// before falling back to the ordinary blocking lock.
fn lock_jobs(q: &Queue) -> MutexGuard<'_, VecDeque<Job>> {
    match q.jobs.try_lock() {
        Ok(g) => g,
        Err(TryLockError::Poisoned(e)) => e.into_inner(),
        Err(TryLockError::WouldBlock) => {
            POOL_CONTENTION.fetch_add(1, Ordering::Relaxed);
            q.jobs.lock().unwrap_or_else(|e| e.into_inner())
        }
    }
}

struct Pool {
    queue: Arc<Queue>,
    workers: usize,
}

fn worker_loop(q: Arc<Queue>) {
    loop {
        let job = {
            let mut jobs = lock_jobs(&q);
            loop {
                if let Some(j) = jobs.pop_front() {
                    break j;
                }
                jobs = q.ready.wait(jobs).unwrap_or_else(|e| e.into_inner());
            }
        };
        // Jobs are panic-wrapped at construction; this call cannot unwind.
        job();
    }
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        let queue = Arc::new(Queue { jobs: Mutex::new(VecDeque::new()), ready: Condvar::new() });
        let want = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(MAX_WORKERS);
        let mut workers = 0usize;
        for k in 0..want {
            let q = Arc::clone(&queue);
            // Best effort: if a worker fails to spawn, callers still make
            // progress by helping from the waiting thread.
            if std::thread::Builder::new()
                .name(format!("lpcs-par-{k}"))
                .spawn(move || worker_loop(q))
                .is_ok()
            {
                workers += 1;
            }
        }
        Pool { queue, workers }
    })
}

/// Number of persistent pool workers (spawns the pool on first call).
pub fn pool_size() -> usize {
    pool().workers
}

/// Completion latch: counts outstanding jobs, records whether any panicked.
struct Latch {
    state: Mutex<(usize, bool)>,
    done: Condvar,
}

impl Latch {
    fn new(jobs: usize) -> Self {
        Self { state: Mutex::new((jobs, false)), done: Condvar::new() }
    }

    fn complete(&self, panicked: bool) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.0 -= 1;
        st.1 |= panicked;
        if st.0 == 0 {
            self.done.notify_all();
        }
    }

    /// Wait for all jobs, executing queued jobs (ours or anyone's) while
    /// waiting so nested `par` calls cannot deadlock. Returns the panic flag.
    fn wait_help(&self, q: &Queue) -> bool {
        loop {
            {
                let st = self.state.lock().unwrap_or_else(|e| e.into_inner());
                if st.0 == 0 {
                    return st.1;
                }
            }
            let job = {
                let mut jobs = lock_jobs(q);
                jobs.pop_front()
            };
            match job {
                Some(j) => j(),
                None => {
                    let st = self.state.lock().unwrap_or_else(|e| e.into_inner());
                    if st.0 == 0 {
                        return st.1;
                    }
                    let (st, _) = self
                        .done
                        .wait_timeout(st, std::time::Duration::from_millis(1))
                        .unwrap_or_else(|e| e.into_inner());
                    if st.0 == 0 {
                        return st.1;
                    }
                }
            }
        }
    }
}

/// Erase the borrow lifetime of a job. Sound only because every caller
/// blocks on the latch until the job has run before its borrows expire.
unsafe fn erase_lifetime<'a>(job: Box<dyn FnOnce() + Send + 'a>) -> Job {
    std::mem::transmute(job)
}

/// Split `out` into contiguous chunks and run `f(chunk_start, chunk)` on the
/// pool. `f` must be pure per-chunk (no overlap by construction).
pub fn par_chunks_mut<T: Send, F>(out: &mut [T], min_chunk: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    par_chunks_mut_aligned(out, min_chunk, 1, f)
}

/// [`par_chunks_mut`] with every chunk boundary (except the final tail end)
/// a multiple of `align` — kernels over bit-packed storage use this so each
/// chunk starts on a packed-word boundary.
pub fn par_chunks_mut_aligned<T: Send, F>(out: &mut [T], min_chunk: usize, align: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = out.len();
    if n == 0 {
        return;
    }
    let align = align.max(1);
    let threads = num_threads().min(n.div_ceil(min_chunk.max(1))).max(1);
    let chunk = n.div_ceil(threads).div_ceil(align) * align;
    if threads <= 1 || chunk >= n {
        f(0, out);
        return;
    }
    let nchunks = n.div_ceil(chunk);
    let q = &pool().queue;
    let latch = Latch::new(nchunks - 1);
    let mut chunks = out.chunks_mut(chunk);
    let first = chunks.next().expect("nonempty slice has a first chunk");
    {
        let latch_ref = &latch;
        let fref = &f;
        let mut jobs = lock_jobs(q);
        for (ci, head) in chunks.enumerate() {
            let start = (ci + 1) * chunk;
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let panicked =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| fref(start, head)))
                        .is_err();
                latch_ref.complete(panicked);
            });
            // SAFETY: we block on the latch below until every job has run,
            // so the borrows of `f`, `latch`, and `out` outlive the jobs.
            jobs.push_back(unsafe { erase_lifetime(job) });
        }
    }
    q.ready.notify_all();
    let own = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(0, first)));
    let worker_panicked = latch.wait_help(q);
    if let Err(p) = own {
        std::panic::resume_unwind(p);
    }
    if worker_panicked {
        panic!("par: a parallel chunk panicked");
    }
}

/// Parallel map over `0..n`, collecting results in order.
pub fn par_map<T: Send, F>(n: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Sync,
    T: Default + Clone,
{
    let mut out = vec![T::default(); n];
    par_chunks_mut(&mut out, 1, |start, chunk| {
        for (k, slot) in chunk.iter_mut().enumerate() {
            *slot = f(start + k);
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_chunks_covers_all_indices() {
        let mut v = vec![0usize; 1000];
        par_chunks_mut(&mut v, 16, |start, chunk| {
            for (k, x) in chunk.iter_mut().enumerate() {
                *x = start + k;
            }
        });
        assert!(v.iter().enumerate().all(|(i, &x)| x == i));
    }

    #[test]
    fn par_chunks_empty_ok() {
        let mut v: Vec<u8> = vec![];
        par_chunks_mut(&mut v, 4, |_, _| panic!("must not be called"));
    }

    #[test]
    fn par_map_matches_serial() {
        let got = par_map(257, |i| i * i);
        let want: Vec<usize> = (0..257).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn single_element() {
        let mut v = vec![0i32; 1];
        par_chunks_mut(&mut v, 1024, |s, c| {
            assert_eq!(s, 0);
            c[0] = 7;
        });
        assert_eq!(v[0], 7);
    }

    #[test]
    fn pool_is_reused_across_calls() {
        // Two consecutive calls over the same pool produce correct results
        // (regression for latch reset / queue reuse bugs).
        for round in 0..5u64 {
            let mut v = vec![0u64; 4096];
            par_chunks_mut(&mut v, 8, |start, chunk| {
                for (k, x) in chunk.iter_mut().enumerate() {
                    *x = (start + k) as u64 * round;
                }
            });
            assert!(v.iter().enumerate().all(|(i, &x)| x == i as u64 * round));
        }
        assert!(pool_size() <= MAX_WORKERS);
    }

    #[test]
    fn aligned_chunks_start_on_boundaries() {
        let starts = std::sync::Mutex::new(Vec::new());
        let mut v = vec![0u8; 1000];
        par_chunks_mut_aligned(&mut v, 8, 32, |start, _chunk| {
            starts.lock().unwrap().push(start);
        });
        for s in starts.into_inner().unwrap() {
            assert_eq!(s % 32, 0, "chunk start {s} not 32-aligned");
        }
    }

    #[test]
    fn nested_par_does_not_deadlock() {
        let mut outer = vec![0usize; 64];
        par_chunks_mut(&mut outer, 1, |start, chunk| {
            for (k, slot) in chunk.iter_mut().enumerate() {
                let inner = par_map(50, |i| i + start + k);
                *slot = inner.iter().sum();
            }
        });
        for (i, &x) in outer.iter().enumerate() {
            let want: usize = (0..50).map(|j| j + i).sum();
            assert_eq!(x, want);
        }
    }

    #[test]
    fn contention_counter_is_monotonic_and_cheap() {
        // The counter can only grow; actual contention depends on the
        // machine, so the assertion is monotonicity across a workload
        // that exercises every lock site.
        let before = contention_count();
        for _ in 0..8 {
            let mut v = vec![0u64; 4096];
            par_chunks_mut(&mut v, 1, |start, chunk| {
                for (k, x) in chunk.iter_mut().enumerate() {
                    *x = (start + k) as u64;
                }
            });
        }
        assert!(contention_count() >= before);
    }

    #[test]
    fn caller_chunk_panic_propagates() {
        let r = std::panic::catch_unwind(|| {
            let mut v = vec![0u8; 8];
            par_chunks_mut(&mut v, 1024, |_, _| panic!("boom"));
        });
        assert!(r.is_err());
    }

    #[test]
    fn worker_chunk_panic_propagates() {
        if num_threads() < 2 {
            return; // single-threaded env: nothing runs off-caller
        }
        let r = std::panic::catch_unwind(|| {
            let mut v = vec![0u8; 1024];
            par_chunks_mut(&mut v, 1, |start, _| {
                if start > 0 {
                    panic!("worker boom");
                }
            });
        });
        assert!(r.is_err());
    }
}
