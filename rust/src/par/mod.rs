//! Minimal data-parallel helpers over `std::thread::scope`.
//!
//! The offline build environment has no rayon, so the few hot loops that
//! benefit from threads use this module instead. The API is deliberately
//! tiny: chunked parallel-for over an output slice, and a parallel map over
//! an index range.

/// Number of worker threads to use (cores, capped; overridable via
/// `LPCS_THREADS` for benchmarking).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("LPCS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `out` into contiguous chunks and run `f(chunk_start, chunk)` on a
/// thread per chunk. `f` must be pure per-chunk (no overlap by construction).
pub fn par_chunks_mut<T: Send, F>(out: &mut [T], min_chunk: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = out.len();
    if n == 0 {
        return;
    }
    let threads = num_threads().min(n.div_ceil(min_chunk.max(1))).max(1);
    if threads <= 1 {
        f(0, out);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut start = 0;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let fref = &f;
            s.spawn(move || fref(start, head));
            start += take;
            rest = tail;
        }
    });
}

/// Parallel map over `0..n`, collecting results in order.
pub fn par_map<T: Send, F>(n: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Sync,
    T: Default + Clone,
{
    let mut out = vec![T::default(); n];
    par_chunks_mut(&mut out, 1, |start, chunk| {
        for (k, slot) in chunk.iter_mut().enumerate() {
            *slot = f(start + k);
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_chunks_covers_all_indices() {
        let mut v = vec![0usize; 1000];
        par_chunks_mut(&mut v, 16, |start, chunk| {
            for (k, x) in chunk.iter_mut().enumerate() {
                *x = start + k;
            }
        });
        assert!(v.iter().enumerate().all(|(i, &x)| x == i));
    }

    #[test]
    fn par_chunks_empty_ok() {
        let mut v: Vec<u8> = vec![];
        par_chunks_mut(&mut v, 4, |_, _| panic!("must not be called"));
    }

    #[test]
    fn par_map_matches_serial() {
        let got = par_map(257, |i| i * i);
        let want: Vec<usize> = (0..257).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn single_element() {
        let mut v = vec![0i32; 1];
        par_chunks_mut(&mut v, 1024, |s, c| {
            assert_eq!(s, 0);
            c[0] = 7;
        });
        assert_eq!(v[0], 7);
    }
}
