//! Performance models (S10/S11): the FPGA bandwidth-bound simulator behind
//! Fig 6 and the CPU traffic model behind Fig 5's analytic expectation.

pub mod cpu;
pub mod fpga;
