//! FPGA performance simulator (paper §8).
//!
//! The paper's own analysis: the gradient-computation unit streams Φ̂ and ŷ
//! from main memory at a fixed rate **P = 12.8 GB/s**; the iteration time is
//! `T = size(Φ̂)/P` since `size(ŷ) ≪ size(Φ̂)`, and the unit's internal
//! parallelism is scaled so P is sustained at every precision ("all variants
//! of IHT on FPGA can consume Φ at the same rate"). Quantization therefore
//! yields near-linear speedup in 32/b. This module implements exactly that
//! model (plus the resource-cap refinement of §8.2) — the substitution for
//! real FPGA hardware documented in DESIGN.md §6.
//!
//! The model is servable: the registry's `"fpga-model"` engine
//! ([`crate::solver::FpgaModelEngine`]) runs the real quantized solve and
//! bills `iterations × iteration_time` into its metrics, so FPGA cost
//! queries go through the same facade/service paths as every other solve.

/// Device parameters (defaults = the paper's platform).
#[derive(Debug, Clone, Copy)]
pub struct FpgaModel {
    /// Sustained memory bandwidth in bytes/s (paper: 12.8 GB/s).
    pub bandwidth: f64,
    /// Memory line width in bits (values arriving per transfer).
    pub line_bits: u32,
    /// Multipliers available for the dot-product engine (§8.2: resource
    /// cap that limits on-the-fly parallelism at high precision).
    pub multipliers: u32,
    /// Clock in Hz (for cycle-accurate reporting).
    pub clock_hz: f64,
}

impl Default for FpgaModel {
    fn default() -> Self {
        Self { bandwidth: 12.8e9, line_bits: 512, multipliers: 128, clock_hz: 200e6 }
    }
}

impl FpgaModel {
    /// Bytes streamed per IHT iteration: Φ̂ once for the gradient, Φ̂ once
    /// for the residual matvec (the paper's unit fuses both passes over one
    /// stream, so `passes` is configurable; paper model: 1).
    pub fn bytes_per_iteration(&self, m: usize, n: usize, bits_phi: u32, bits_y: u32) -> f64 {
        let phi_bytes = (m as f64) * (n as f64) * (bits_phi as f64) / 8.0;
        let y_bytes = (m as f64) * (bits_y as f64) / 8.0;
        phi_bytes + y_bytes
    }

    /// Iteration time T = size(Φ̂)/P (seconds).
    pub fn iteration_time(&self, m: usize, n: usize, bits_phi: u32, bits_y: u32) -> f64 {
        self.bytes_per_iteration(m, n, bits_phi, bits_y) / self.bandwidth
    }

    /// Values of Φ̂ arriving per memory line — the internal parallelism the
    /// gradient unit must sustain.
    pub fn values_per_line(&self, bits_phi: u32) -> u32 {
        self.line_bits / bits_phi
    }

    /// Whether the device can sustain rate P at this precision: it needs
    /// `values_per_line` parallel MACs; low precision substitutes LUT adds
    /// for DSP multipliers (§8.2: 2-bit dots need no multipliers at all).
    pub fn sustains_bandwidth(&self, bits_phi: u32) -> bool {
        if bits_phi <= 2 {
            return true; // {-1, 0, 1} codes: adders only
        }
        self.values_per_line(bits_phi) <= self.multipliers
    }

    /// Per-iteration speedup over the 32-bit variant.
    pub fn iteration_speedup(&self, m: usize, n: usize, bits_phi: u32, bits_y: u32) -> f64 {
        self.iteration_time(m, n, 32, 32) / self.iteration_time(m, n, bits_phi, bits_y)
    }

    /// End-to-end time to recovery: iterations (measured by the solver on
    /// this precision) × modeled iteration time.
    pub fn end_to_end_time(
        &self,
        m: usize,
        n: usize,
        bits_phi: u32,
        bits_y: u32,
        iterations: usize,
    ) -> f64 {
        self.iteration_time(m, n, bits_phi, bits_y) * iterations as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iteration_time_linear_in_matrix_size() {
        let f = FpgaModel::default();
        let t1 = f.iteration_time(900, 65536, 32, 32);
        let t2 = f.iteration_time(900, 2 * 65536, 32, 32);
        assert!((t2 / t1 - 2.0).abs() < 0.01);
    }

    #[test]
    fn near_linear_speedup_with_bits() {
        // Paper Fig 6: 2-bit Φ ⇒ ~16× per-iteration speedup over 32-bit.
        let f = FpgaModel::default();
        let s2 = f.iteration_speedup(900, 65536, 2, 8);
        let s4 = f.iteration_speedup(900, 65536, 4, 8);
        let s8 = f.iteration_speedup(900, 65536, 8, 8);
        assert!((s2 - 16.0).abs() < 0.2, "s2={s2}");
        assert!((s4 - 8.0).abs() < 0.1, "s4={s4}");
        assert!((s8 - 4.0).abs() < 0.05, "s8={s8}");
    }

    #[test]
    fn y_term_is_negligible_for_wide_matrices() {
        let f = FpgaModel::default();
        let with_y = f.bytes_per_iteration(900, 65536, 2, 32);
        let phi_only = 900.0 * 65536.0 * 2.0 / 8.0;
        assert!((with_y - phi_only) / phi_only < 0.01);
    }

    #[test]
    fn paper_headline_9x_end_to_end_shape() {
        // Fig 6: 2&8-bit reaches 90% support recovery 9.19× faster than
        // 32-bit even though it needs more iterations. With a 16× cheaper
        // iteration, that implies ~1.74× the iterations — check the model
        // reproduces the relationship.
        let f = FpgaModel::default();
        let t32 = f.end_to_end_time(900, 65536, 32, 32, 100);
        let t2 = f.end_to_end_time(900, 65536, 2, 8, 174);
        let speedup = t32 / t2;
        assert!((speedup - 9.19).abs() < 0.4, "speedup={speedup}");
    }

    #[test]
    fn parallelism_grows_as_precision_drops() {
        let f = FpgaModel::default();
        assert_eq!(f.values_per_line(32), 16);
        assert_eq!(f.values_per_line(8), 64);
        assert_eq!(f.values_per_line(2), 256);
        assert!(f.sustains_bandwidth(2));
        assert!(f.sustains_bandwidth(8));
    }

    #[test]
    fn resource_cap_can_bind_at_high_parallelism() {
        // A small device cannot sustain P for 4-bit (needs 128 MACs > 64).
        let small = FpgaModel { multipliers: 64, ..Default::default() };
        assert!(small.sustains_bandwidth(8));
        assert!(!small.sustains_bandwidth(4));
        assert!(small.sustains_bandwidth(2)); // adder-only path
    }
}
