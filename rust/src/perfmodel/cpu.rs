//! CPU performance model + measurement hooks (paper §9 / Fig 5).
//!
//! The analytic side mirrors the FPGA model: a memory-bound matvec moves
//! `m·n·b/8` bytes, so the *expected* speedup over f32 is `32/b`, capped by
//! the decode/compute throughput of the packed kernels (measured, not
//! assumed — `measure_matvec` times the real kernels in-process).

use crate::benchkit;
use crate::linalg::Mat;
use crate::lowprec;
use crate::quant::packed::PackedMatrix;
use crate::quant::QuantizedMatrix;
use crate::rng::XorShift128Plus;

/// Analytic traffic-ratio speedup bound (the bandwidth roofline).
pub fn traffic_speedup_bound(bits: u32) -> f64 {
    32.0 / bits as f64
}

/// Measured per-iteration matvec time at a precision, plus the f32 baseline.
#[derive(Debug, Clone, Copy)]
pub struct MatvecMeasurement {
    pub bits: u32,
    pub time_s: f64,
    pub baseline_f32_s: f64,
}

impl MatvecMeasurement {
    pub fn speedup(&self) -> f64 {
        self.baseline_f32_s / self.time_s
    }
}

/// Time the packed b-bit matvec against the dense f32 matvec on an m×n
/// Gaussian matrix (median of `iters` runs).
pub fn measure_matvec(m: usize, n: usize, bits: u8, iters: usize, seed: u64) -> MatvecMeasurement {
    let mut rng = XorShift128Plus::new(seed);
    let a = Mat::from_fn(m, n, |_, _| rng.gaussian_f32());
    let qm = QuantizedMatrix::from_mat(&a, bits, &mut rng);
    let p = PackedMatrix::pack(&qm);
    let x = rng.gaussian_vec(n);

    let t_f32 = benchkit::bench(2, iters, || a.matvec(&x)).median_s();
    let t_q = benchkit::bench(2, iters, || lowprec::packed_matvec(&p, &x)).median_s();
    MatvecMeasurement { bits: bits as u32, time_s: t_q, baseline_f32_s: t_f32 }
}

/// Measured single-RHS vs batched multi-RHS matvec time at one precision:
/// `single_s` is one `packed_matvec`, `per_rhs_s` is one multi-RHS sweep
/// over `nrhs` right-hand sides divided by `nrhs`. The gap is the decode
/// work the multi-RHS kernels amortize across the batch.
#[derive(Debug, Clone, Copy)]
pub struct MultiRhsMeasurement {
    pub bits: u32,
    pub nrhs: usize,
    pub single_s: f64,
    pub per_rhs_s: f64,
}

impl MultiRhsMeasurement {
    /// Implied decode share of the single-RHS matvec under the cost
    /// model's `base·(1 − d + d/B)` amortization law, clamped to [0, 1].
    /// Feed this into `CostModel::decode_fraction` to calibrate the
    /// scheduler's batch pricing to the live kernels.
    pub fn decode_fraction(&self) -> f64 {
        if self.nrhs < 2 || self.single_s <= 0.0 {
            return 0.0;
        }
        let b = self.nrhs as f64;
        let d = (1.0 - self.per_rhs_s / self.single_s) * b / (b - 1.0);
        d.clamp(0.0, 1.0)
    }
}

/// Time one single-RHS packed matvec against a multi-RHS sweep over
/// `nrhs` right-hand sides (median of `iters` runs each).
pub fn measure_matvec_multi(
    m: usize,
    n: usize,
    bits: u8,
    nrhs: usize,
    iters: usize,
    seed: u64,
) -> MultiRhsMeasurement {
    assert!(nrhs >= 1);
    let mut rng = XorShift128Plus::new(seed);
    let a = Mat::from_fn(m, n, |_, _| rng.gaussian_f32());
    let qm = QuantizedMatrix::from_mat(&a, bits, &mut rng);
    let p = PackedMatrix::pack(&qm);
    let xs: Vec<Vec<f32>> = (0..nrhs).map(|_| rng.gaussian_vec(n)).collect();
    let refs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();

    let single_s = benchkit::bench(2, iters, || lowprec::packed_matvec(&p, &xs[0])).median_s();
    let multi_s =
        benchkit::bench(2, iters, || lowprec::packed_matvec_multi(&p, &refs)).median_s();
    MultiRhsMeasurement { bits: bits as u32, nrhs, single_s, per_rhs_s: multi_s / nrhs as f64 }
}

/// Calibrate the scheduler's decode fraction from the live kernels at a
/// representative shape: the median implied fraction over the packed
/// widths. Cheap enough to run once at service start.
pub fn measure_decode_fraction(m: usize, n: usize, nrhs: usize, seed: u64) -> f64 {
    let mut fracs: Vec<f64> = [2u8, 4, 8]
        .iter()
        .map(|&bits| measure_matvec_multi(m, n, bits, nrhs, 5, seed).decode_fraction())
        .collect();
    fracs.sort_by(f64::total_cmp);
    fracs[1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traffic_bound_values() {
        assert_eq!(traffic_speedup_bound(2), 16.0);
        assert_eq!(traffic_speedup_bound(4), 8.0);
        assert_eq!(traffic_speedup_bound(8), 4.0);
        assert_eq!(traffic_speedup_bound(32), 1.0);
    }

    #[test]
    fn measurement_runs_and_is_positive() {
        let m = measure_matvec(64, 256, 4, 5, 1);
        assert!(m.time_s > 0.0 && m.baseline_f32_s > 0.0);
        assert!(m.speedup() > 0.0);
    }

    #[test]
    fn multi_rhs_measurement_runs_and_fraction_in_range() {
        let m = measure_matvec_multi(64, 256, 4, 4, 3, 2);
        assert!(m.single_s > 0.0 && m.per_rhs_s > 0.0);
        let d = m.decode_fraction();
        assert!((0.0..=1.0).contains(&d), "decode fraction {d} out of range");
    }

    #[test]
    fn decode_fraction_inverts_the_amortization_law() {
        // per_rhs = single·(1 − d + d/B) must invert back to d exactly.
        let m = MultiRhsMeasurement {
            bits: 4,
            nrhs: 4,
            single_s: 1.0,
            per_rhs_s: 1.0 - 0.4 + 0.4 / 4.0,
        };
        assert!((m.decode_fraction() - 0.4).abs() < 1e-9);
        // Degenerate cases clamp instead of exploding.
        let solo = MultiRhsMeasurement { bits: 4, nrhs: 1, single_s: 1.0, per_rhs_s: 1.0 };
        assert_eq!(solo.decode_fraction(), 0.0);
    }
}
