//! CPU performance model + measurement hooks (paper §9 / Fig 5).
//!
//! The analytic side mirrors the FPGA model: a memory-bound matvec moves
//! `m·n·b/8` bytes, so the *expected* speedup over f32 is `32/b`, capped by
//! the decode/compute throughput of the packed kernels (measured, not
//! assumed — `measure_matvec` times the real kernels in-process).

use crate::benchkit;
use crate::linalg::Mat;
use crate::lowprec;
use crate::quant::packed::PackedMatrix;
use crate::quant::QuantizedMatrix;
use crate::rng::XorShift128Plus;

/// Analytic traffic-ratio speedup bound (the bandwidth roofline).
pub fn traffic_speedup_bound(bits: u32) -> f64 {
    32.0 / bits as f64
}

/// Measured per-iteration matvec time at a precision, plus the f32 baseline.
#[derive(Debug, Clone, Copy)]
pub struct MatvecMeasurement {
    pub bits: u32,
    pub time_s: f64,
    pub baseline_f32_s: f64,
}

impl MatvecMeasurement {
    pub fn speedup(&self) -> f64 {
        self.baseline_f32_s / self.time_s
    }
}

/// Time the packed b-bit matvec against the dense f32 matvec on an m×n
/// Gaussian matrix (median of `iters` runs).
pub fn measure_matvec(m: usize, n: usize, bits: u8, iters: usize, seed: u64) -> MatvecMeasurement {
    let mut rng = XorShift128Plus::new(seed);
    let a = Mat::from_fn(m, n, |_, _| rng.gaussian_f32());
    let qm = QuantizedMatrix::from_mat(&a, bits, &mut rng);
    let p = PackedMatrix::pack(&qm);
    let x = rng.gaussian_vec(n);

    let t_f32 = benchkit::bench(2, iters, || a.matvec(&x)).median_s();
    let t_q = benchkit::bench(2, iters, || lowprec::packed_matvec(&p, &x)).median_s();
    MatvecMeasurement { bits: bits as u32, time_s: t_q, baseline_f32_s: t_f32 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traffic_bound_values() {
        assert_eq!(traffic_speedup_bound(2), 16.0);
        assert_eq!(traffic_speedup_bound(4), 8.0);
        assert_eq!(traffic_speedup_bound(8), 4.0);
        assert_eq!(traffic_speedup_bound(32), 1.0);
    }

    #[test]
    fn measurement_runs_and_is_positive() {
        let m = measure_matvec(64, 256, 4, 5, 1);
        assert!(m.time_s > 0.0 && m.baseline_f32_s > 0.0);
        assert!(m.speedup() > 0.0);
    }
}
