//! # LPCS — Low-Precision Compressive Sensing
//!
//! Production-grade reproduction of *"Compressive Sensing with Low Precision
//! Data Representation: Theory and Applications"* (Gürel et al.).
//!
//! The crate implements the paper's quantized Normalized Iterative Hard
//! Thresholding (QNIHT) solver together with every substrate the paper's
//! evaluation depends on: stochastic quantization with bit-packed storage,
//! low-precision matvec kernels, a radio-interferometry simulator (LOFAR-like
//! station, measurement-matrix formation, visibility synthesis), an MRI
//! workload (radix-2 FFT substrate, Shepp–Logan phantom, Cartesian/radial
//! undersampling masks, a matrix-free partial-Fourier operator with a
//! low-precision sampling path), the full baseline suite (NIHT, IHT, CoSaMP,
//! FISTA, CLEAN), an RIP toolkit, an FPGA bandwidth-model simulator, a PJRT
//! runtime that executes the JAX/Pallas AOT artifacts, and an async recovery
//! service.
//!
//! ## Layers
//!
//! Every recovery path enters through the **[`solver`] facade** and flows
//! down:
//!
//! * **Facade** ([`solver`]): [`solver::Problem`] (Φ as a
//!   [`solver::MeasurementOp`] + y + sparsity), [`solver::SolverKind`] /
//!   [`solver::SparseSolver`] adapters for every algorithm,
//!   [`solver::Recovery`] builder, and the [`solver::EngineRegistry`]
//!   (name → engine factory) that owns execution dispatch, XLA runtime
//!   caching and batched quantize+pack amortization. This is the only API
//!   the serving layer, examples, repro figures and benches use.
//! * **Serving** ([`coordinator`]): every [`solver::SolverKind`] is
//!   servable — `JobSpec` carries an explicit solver selector (validated
//!   at submit time) that is part of the batching key — and so are
//!   **matrix-free operators**: `coordinator::OperatorSpec` describes
//!   an explicit dense Φ, a shared [`mri::PartialFourierOp`], or a
//!   shared [`telescope::VisibilityOp`] (each matrix-free variant with
//!   an optional low-precision sampling bit width), folded into
//!   `BatchKey` by operator identity and gated at
//!   submit (mask/station parameters, the NIHT/native-dense matrix-free
//!   surface). A telescope station is the motivating serving workload:
//!   a stream of visibility snapshots shares ONE `VisibilityOp` (the
//!   geometry is fixed while the pointing is), so jobs batch by
//!   operator identity locally and by operator *content* over the
//!   wire, and the low-precision path quantizes the observation and
//!   each iteration's visibility-domain residual at 2/4/8 bits with
//!   per-baseline-block scales — the paper's sampling model on the
//!   measurement traffic, while the image-domain state stays f32.
//!   Jobs flow through
//!   a bounded queue with backpressure into worker-local snapshot
//!   windows that the **cost-aware scheduler** ([`coordinator::sched`])
//!   partitions into key-homogeneous batches and orders cheapest-first
//!   (amortized quantize+pack setup + per-iteration stream cost − age
//!   credit) under an urgency bound (submit priority + starvation
//!   limit), with within-key FIFO fairness — a pure, property-tested
//!   policy. Workers (one registry each) execute the head batch via
//!   `solve_batch` and return the rest of the window to the queue;
//!   per-job progress streaming and
//!   cancellation ride on [`algorithms::IterObserver`]. The
//!   [`solver::FpgaModelEngine`] (`"fpga-model"`) serves "what would
//!   this job cost on the FPGA at 2/4/8 bits?" by billing modeled time
//!   from [`perfmodel::fpga::FpgaModel`].
//! * **Wire** ([`wire`]): the network face of the service — std-only
//!   TCP with length-prefixed, checksummed frames (`Submit` /
//!   `Subscribe` / `Cancel` / `Progress` / `Done` / `Metrics` / `Err`;
//!   see [`wire::codec`] for the frame table). `lpcs serve
//!   --listen 127.0.0.1:7070` serves it; `lpcs watch <addr> <job>` (or
//!   [`wire::WireClient`]) streams per-iteration residuals live, with
//!   bounded drop-oldest subscriber queues so a slow client never
//!   stalls a worker. Wire-served results are bit-identical to
//!   in-process ones, and operators ship by content so wire jobs batch
//!   too:
//!
//!   ```no_run
//!   # use lpcs::coordinator::{JobSpec, ProblemHandle};
//!   # use std::sync::Arc;
//!   # let spec = JobSpec::builder(
//!   #     ProblemHandle::new(Arc::new(lpcs::Mat::zeros(4, 8))), vec![0.0; 4], 2,
//!   # ).build();
//!   let mut client = lpcs::wire::WireClient::connect("127.0.0.1:7070").unwrap();
//!   let id = client.submit(&spec).unwrap();
//!   for event in client.watch(id).unwrap() {
//!       match event.unwrap() {
//!           lpcs::wire::WatchEvent::Queued { position, depth } => {
//!               println!("queued at {position}/{depth}")
//!           }
//!           lpcs::wire::WatchEvent::Progress(st) => println!("iter {}: {:.3e}", st.iter, st.resid_nsq),
//!           lpcs::wire::WatchEvent::Done(out) => println!("done: {:?}", out.state),
//!       }
//!   }
//!   ```
//! * **Router** ([`router`]): the fleet tier. `lpcs route --listen A
//!   backend=B backend=C` speaks the same wire protocol on both faces
//!   and shards jobs across several `lpcs serve` backends by
//!   consistent-hashing [`wire::route_key`] (operator content +
//!   batch-relevant spec fields), so same-Φ jobs keep landing on one
//!   backend and keep batching:
//!
//!   ```text
//!                        ┌──────────────┐
//!   WireClient ──wire──▶ │  lpcs route  │ ──wire──▶ lpcs serve #0 (Φ_a)
//!   WireClient ──wire──▶ │ ring·health  │ ──wire──▶ lpcs serve #1 (Φ_b)
//!                        └──────────────┘     ✗───▶ lpcs serve #2 (down)
//!   ```
//!
//!   Backends are health-probed (down after consecutive failures,
//!   removed from the ring, re-admitted on recovery); watch streams
//!   *resume* across a backend dying mid-solve (deterministic re-solve
//!   elsewhere, replayed iterations filtered, epoch bumped — the client
//!   sees one monotone stream with exactly one `Done`); and admission
//!   control answers saturation with typed `queue-full` errors instead
//!   of buffering.
//! * **Observability** ([`obsv`]): the fleet view. Lock-light
//!   log-bucket latency histograms (queue-wait, quantize+pack setup,
//!   execution, end-to-end) labeled `SolverKind` × engine × bits with
//!   outcome-labeled terminal counters
//!   (`ok`/`failed`/`cancelled`/`rejected_full`), worker-pool
//!   saturation and in-flight gauges, and a structured
//!   [`obsv::MetricsSnapshot`] behind the legacy `metrics=` text line.
//!   Three fleet-wide pieces ride on top:
//!
//!   - **Trace ids end to end.** Every job gets an
//!     [`obsv::TraceId`] minted at its first submit face (client,
//!     server or router — a content hash of the measurement vector
//!     plus a process-local counter, stable with no wall clock) and
//!     carried on every wire-v4 `Submit`/`Submitted`/`Progress`/`Done`
//!     frame, through `JobSpec` into the job table, and out again as
//!     an exemplar on the end-to-end histogram
//!     (`lpcs_job_e2e_us_bucket{...} # {trace_id="..."}`):
//!
//!     ```text
//!     submit ──▶ router ──▶ backend ──▶ queue ──▶ solve ──▶ Done
//!       mint      carry       carry      stamp     stamp     exemplar
//!     trace_id ────────────────────────────────────────────▶ scrape
//!     ```
//!
//!     `lpcs watch` prints the id on the terminal frame and
//!     `lpcs trace ADDR JOB` turns it into a per-stage breakdown
//!     (queued / ran / e2e), so one grep connects a client-side solve
//!     to its series in any exposition.
//!   - **Per-hop router histograms.** The relay records its own
//!     families, labeled `backend="i"`: `lpcs_router_submit_forward_us`
//!     (submit → backend ack), `lpcs_router_first_progress_us`
//!     (subscribe → first relayed iteration),
//!     `lpcs_router_fanout_delay_us` (backend frame → client write)
//!     and `lpcs_router_failover_resume_us` (stream lost → resumed
//!     elsewhere) — separating routing cost from solve cost per hop.
//!   - **Federated scrape.** A `ScrapeReq` at the router fans out to
//!     every healthy backend under a bounded per-backend timeout and
//!     merges the parsed expositions ([`obsv::Histogram::merge_from`]
//!     on identical bucket bounds; counters summed per label set;
//!     per-backend scalars re-labeled `backend="i"`), so one
//!     `lpcs scrape ROUTER` shows the whole fleet. A dead or garbled
//!     backend never stalls the scrape — it shows up as an
//!     `lpcs_backend_scrape_errors{backend="i"}` increment instead.
//!
//!   The recorded per-`BatchKey` setup/execution times feed back into
//!   the scheduler: `sched::CostModel::observe` EWMA-calibrates batch
//!   pricing from measurements instead of the static nominal-iteration
//!   estimate (freezable via `service.calibrate_cost=false` for
//!   deterministic tests), and the calibrated state persists across
//!   restarts via `service.persist_cost`.
//! * **Algorithms** ([`algorithms`]): the Algorithm-1 NIHT driver (generic
//!   over [`algorithms::NihtKernel`]), the quantized kernels, and the
//!   baselines — all observable per iteration.
//! * **Substrate**: [`quant`] (stochastic quantization + bit-packing),
//!   [`lowprec`] (packed kernels over the runtime-dispatched [`simd`]
//!   backends on the persistent [`par`] pool), [`linalg`], [`fft`]
//!   (radix-2 transforms behind the matrix-free Fourier operator),
//!   [`rng`]. The kernel layer dispatches over a runtime ladder —
//!   scalar < NEON < AVX2 < AVX-512 VNNI, forceable via `LPCS_SIMD` —
//!   and exposes a multi-RHS surface (`*_multi`) that serves several
//!   right-hand sides from ONE decode pass over the packed Φ words;
//!   batched QNIHT solves route through it
//!   ([`algorithms::qniht::solve_batch_lockstep`]), decoding each row
//!   once per batch instead of once per job, bit-identically to the
//!   sequential path.
//! * **Artifacts** ([`runtime`]): PJRT client + compiled-executable cache
//!   executing the L2/L1 JAX/Pallas AOT graphs (`artifacts/*.hlo.txt`);
//!   reached through the registry's `xla-*` engines.
//! * **Evaluation**: [`telescope`] and [`mri`] (the paper's two
//!   application workloads), [`rip`], [`perfmodel`], [`metrics`],
//!   [`repro`] (figure harness, incl. the MRI PSNR-vs-bits fig10),
//!   [`benchkit`].
//!
//! ```no_run
//! use lpcs::solver::{Problem, Recovery, SolverKind};
//! # let (phi, y) = (std::sync::Arc::new(lpcs::Mat::zeros(4, 8)), vec![0.0f32; 4]);
//! let report = Recovery::problem(Problem::new(phi, y, 2))
//!     .solver(SolverKind::qniht_fixed(2, 8))
//!     .run()
//!     .unwrap();
//! ```

pub mod algorithms;
pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod fft;
pub mod io;
pub mod linalg;
pub mod lowprec;
pub mod metrics;
pub mod mri;
pub mod obsv;
pub mod par;
pub mod perfmodel;
pub mod quant;
pub mod repro;
pub mod rip;
pub mod rng;
pub mod router;
pub mod runtime;
pub mod simd;
pub mod solver;
pub mod telescope;
pub mod testkit;
pub mod wire;

pub use linalg::Mat;
pub use quant::{QuantizedMatrix, Quantizer};
pub use solver::{Problem, Recovery, SolveReport, SolverKind};
