//! # LPCS — Low-Precision Compressive Sensing
//!
//! Production-grade reproduction of *"Compressive Sensing with Low Precision
//! Data Representation: Theory and Applications"* (Gürel et al.).
//!
//! The crate implements the paper's quantized Normalized Iterative Hard
//! Thresholding (QNIHT) solver together with every substrate the paper's
//! evaluation depends on: stochastic quantization with bit-packed storage,
//! low-precision matvec kernels, a radio-interferometry simulator (LOFAR-like
//! station, measurement-matrix formation, visibility synthesis), the full
//! baseline suite (NIHT, IHT, CoSaMP, FISTA, CLEAN), an RIP toolkit, an FPGA
//! bandwidth-model simulator, a PJRT runtime that executes the JAX/Pallas
//! AOT artifacts, and an async recovery service.
//!
//! Layers (see DESIGN.md):
//! * L3 (this crate): coordination, control flow of Algorithm 1, serving.
//! * L2/L1 (python/compile): JAX step graphs + Pallas kernels, AOT-lowered
//!   to `artifacts/*.hlo.txt`, loaded by [`runtime`].

pub mod algorithms;
pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod io;
pub mod linalg;
pub mod lowprec;
pub mod metrics;
pub mod par;
pub mod perfmodel;
pub mod quant;
pub mod repro;
pub mod rip;
pub mod rng;
pub mod runtime;
pub mod simd;
pub mod telescope;
pub mod testkit;

pub use linalg::Mat;
pub use quant::{QuantizedMatrix, Quantizer};
