//! Job model: specs, the state machine, and the store clients wait on.

use crate::algorithms::qniht::RequantMode;
use crate::algorithms::{IterStat, SolveResult};
use crate::config::{EngineKind, QuantConfig};
use crate::linalg::Mat;
use crate::solver::{Problem, SolveRequest, SolverKey, SolverKind};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

pub type JobId = u64;

/// The measurement matrix a job recovers against. Jobs sharing the same
/// `Arc` are batchable (one quantization pass amortized over the batch).
#[derive(Debug, Clone)]
pub struct ProblemHandle {
    pub phi: Arc<Mat>,
    /// Artifact shape tag if this Φ matches an AOT shape (XLA engines).
    pub shape_tag: Option<String>,
}

impl ProblemHandle {
    pub fn new(phi: Arc<Mat>) -> Self {
        Self { phi, shape_tag: None }
    }

    pub fn with_shape_tag(phi: Arc<Mat>, tag: &str) -> Self {
        Self { phi, shape_tag: Some(tag.to_string()) }
    }
}

/// A recovery request: problem + an explicit algorithm ([`SolverKind`],
/// which carries the full quantization configuration for QNIHT) + the
/// engine that executes it. Construct via [`JobSpec::builder`] — the
/// builder infers the solver from the engine exactly as the pre-PR-3
/// service did, so existing callers keep their behavior.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub problem: ProblemHandle,
    pub y: Vec<f32>,
    pub s: usize,
    pub solver: SolverKind,
    pub engine: EngineKind,
    pub seed: u64,
}

impl JobSpec {
    /// Start building a spec. Defaults: engine `native-quant` with the
    /// default bit widths ([`QuantConfig::default`]), solver inferred
    /// from the engine, seed 0.
    pub fn builder(problem: ProblemHandle, y: Vec<f32>, s: usize) -> JobSpecBuilder {
        let q = QuantConfig::default();
        JobSpecBuilder {
            problem,
            y,
            s,
            engine: EngineKind::NativeQuant,
            bits_phi: q.bits_phi,
            bits_y: q.bits_y,
            solver: None,
            seed: 0,
        }
    }

    /// Batching key: jobs are batchable iff they share Φ (by identity) and
    /// the full execution configuration — including the solver, so e.g.
    /// a CoSaMP job never coalesces with an NIHT job.
    pub fn batch_key(&self) -> BatchKey {
        BatchKey {
            phi_ptr: Arc::as_ptr(&self.problem.phi) as usize,
            s: self.s,
            solver: self.solver.key(),
            engine: self.engine,
        }
    }

    /// Submit-time validation: shape/sparsity sanity, solver ↔ engine
    /// compatibility, and packed bit widths for the quantized engines.
    /// Without this a malformed spec only fails deep inside the batch
    /// solve, after it has been queued, scheduled and batched.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.y.len() == self.problem.phi.rows,
            "y length {} does not match Φ rows {}",
            self.y.len(),
            self.problem.phi.rows
        );
        anyhow::ensure!(self.s >= 1, "sparsity must be >= 1");
        anyhow::ensure!(
            self.s <= self.problem.phi.cols,
            "sparsity {} exceeds signal dimension {}",
            self.s,
            self.problem.phi.cols
        );
        anyhow::ensure!(
            self.solver.runs_on(self.engine),
            "solver '{}' cannot run on engine '{}'",
            self.solver.name(),
            self.engine.name()
        );
        if self.engine.is_quantized() {
            self.solver.check_packed_bits()?;
        }
        Ok(())
    }

    /// Lower this job into the facade's [`SolveRequest`]. Jobs sharing a
    /// `ProblemHandle` produce requests whose problems share Φ by pointer
    /// identity, which is what the engine's batched path amortizes over.
    pub fn into_request(self) -> SolveRequest {
        let solver = self.solver;
        let mut problem = Problem::new(self.problem.phi, self.y, self.s);
        if let Some(tag) = self.problem.shape_tag {
            problem = problem.with_shape_tag(tag);
        }
        SolveRequest { problem, solver, seed: self.seed }
    }
}

/// Builder for [`JobSpec`]. Unless [`JobSpecBuilder::solver`] is called,
/// the solver is inferred from the engine exactly as the old
/// `solver_kind()` did: QNIHT (Fixed, at the builder's bit widths) on
/// quantized engines, dense NIHT otherwise.
#[derive(Debug, Clone)]
pub struct JobSpecBuilder {
    problem: ProblemHandle,
    y: Vec<f32>,
    s: usize,
    engine: EngineKind,
    bits_phi: u8,
    bits_y: u8,
    solver: Option<SolverKind>,
    seed: u64,
}

impl JobSpecBuilder {
    pub fn engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    /// Bit widths the inferred QNIHT solver uses (ignored when an
    /// explicit solver is set).
    pub fn bits(mut self, bits_phi: u8, bits_y: u8) -> Self {
        self.bits_phi = bits_phi;
        self.bits_y = bits_y;
        self
    }

    /// Explicit algorithm selection (any [`SolverKind`], including the
    /// CoSaMP/FISTA/IHT baselines and Fresh-mode QNIHT).
    pub fn solver(mut self, solver: SolverKind) -> Self {
        self.solver = Some(solver);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn build(self) -> JobSpec {
        let solver = self.solver.unwrap_or(if self.engine.is_quantized() {
            SolverKind::Qniht {
                bits_phi: self.bits_phi,
                bits_y: self.bits_y,
                mode: RequantMode::Fixed,
            }
        } else {
            SolverKind::Niht
        });
        JobSpec {
            problem: self.problem,
            y: self.y,
            s: self.s,
            solver,
            engine: self.engine,
            seed: self.seed,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BatchKey {
    pub phi_ptr: usize,
    pub s: usize,
    pub solver: SolverKey,
    pub engine: EngineKind,
}

/// Lifecycle states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
}

impl JobState {
    /// Legal transitions of the state machine.
    pub fn can_transition(self, next: JobState) -> bool {
        matches!(
            (self, next),
            (JobState::Queued, JobState::Running)
                | (JobState::Queued, JobState::Failed) // rejected before start
                | (JobState::Running, JobState::Done)
                | (JobState::Running, JobState::Failed)
        )
    }
}

/// Completed-job payload.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub id: JobId,
    pub state: JobState,
    pub result: Option<SolveResult>,
    pub error: Option<String>,
    pub queued_for: Duration,
    pub ran_for: Duration,
}

#[derive(Debug)]
struct Record {
    state: JobState,
    result: Option<SolveResult>,
    error: Option<String>,
    submitted: Instant,
    started: Option<Instant>,
    finished: Option<Instant>,
    /// Latest per-iteration stat the worker's observer streamed in.
    progress: Option<IterStat>,
    /// Cancellation requested: the worker's observer stops the solve at
    /// the next iteration boundary; the job completes with its partial
    /// iterate.
    cancel: bool,
}

/// Shared job table with completion signalling.
#[derive(Debug, Default)]
pub struct JobStore {
    inner: Mutex<HashMap<JobId, Record>>,
    done: Condvar,
}

impl JobStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert_queued(&self, id: JobId) {
        let mut g = self.inner.lock().unwrap();
        let prev = g.insert(
            id,
            Record {
                state: JobState::Queued,
                result: None,
                error: None,
                submitted: Instant::now(),
                started: None,
                finished: None,
                progress: None,
                cancel: false,
            },
        );
        assert!(prev.is_none(), "job id {id} reused");
    }

    /// Stream the latest iteration stat for a running job (worker-side).
    pub fn record_progress(&self, id: JobId, stat: IterStat) {
        if let Some(r) = self.inner.lock().unwrap().get_mut(&id) {
            r.progress = Some(stat);
        }
    }

    /// Latest streamed iteration stat, if the job has run any iterations.
    pub fn progress(&self, id: JobId) -> Option<IterStat> {
        self.inner.lock().unwrap().get(&id).and_then(|r| r.progress)
    }

    /// Microseconds since the job was submitted (0 for unknown ids) —
    /// the age the cost-aware scheduler feeds its starvation bound.
    pub fn queued_age_us(&self, id: JobId) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .get(&id)
            .map(|r| r.submitted.elapsed().as_micros() as u64)
            .unwrap_or(0)
    }

    /// Ask a job to stop at its next iteration boundary. Returns false if
    /// the job is unknown or already terminal.
    pub fn request_cancel(&self, id: JobId) -> bool {
        let mut g = self.inner.lock().unwrap();
        match g.get_mut(&id) {
            Some(r) if !matches!(r.state, JobState::Done | JobState::Failed) => {
                r.cancel = true;
                true
            }
            _ => false,
        }
    }

    /// Whether cancellation was requested (worker-side poll).
    pub fn cancel_requested(&self, id: JobId) -> bool {
        self.inner.lock().unwrap().get(&id).map(|r| r.cancel).unwrap_or(false)
    }

    /// Transition enforcing state-machine legality.
    pub fn transition(&self, id: JobId, next: JobState) {
        let mut g = self.inner.lock().unwrap();
        let r = g.get_mut(&id).unwrap_or_else(|| panic!("unknown job {id}"));
        assert!(
            r.state.can_transition(next),
            "illegal transition {:?} -> {next:?} for job {id}",
            r.state
        );
        r.state = next;
        match next {
            JobState::Running => r.started = Some(Instant::now()),
            JobState::Done | JobState::Failed => {
                r.finished = Some(Instant::now());
            }
            JobState::Queued => unreachable!(),
        }
        if matches!(next, JobState::Done | JobState::Failed) {
            drop(g);
            self.done.notify_all();
        }
    }

    pub fn complete(&self, id: JobId, result: SolveResult) {
        {
            let mut g = self.inner.lock().unwrap();
            let r = g.get_mut(&id).unwrap();
            r.result = Some(result);
        }
        self.transition(id, JobState::Done);
    }

    pub fn fail(&self, id: JobId, error: String) {
        {
            let mut g = self.inner.lock().unwrap();
            let r = g.get_mut(&id).unwrap();
            r.error = Some(error);
        }
        self.transition(id, JobState::Failed);
    }

    pub fn state(&self, id: JobId) -> Option<JobState> {
        self.inner.lock().unwrap().get(&id).map(|r| r.state)
    }

    /// Block until the job reaches a terminal state (or timeout).
    pub fn wait(&self, id: JobId, timeout: Duration) -> Option<JobOutcome> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            match g.get(&id) {
                None => return None,
                Some(r) if matches!(r.state, JobState::Done | JobState::Failed) => {
                    let queued_for = r
                        .started
                        .unwrap_or_else(|| r.finished.unwrap())
                        .duration_since(r.submitted);
                    let ran_for = match (r.started, r.finished) {
                        (Some(s), Some(f)) => f.duration_since(s),
                        _ => Duration::ZERO,
                    };
                    return Some(JobOutcome {
                        id,
                        state: r.state,
                        result: r.result.clone(),
                        error: r.error.clone(),
                        queued_for,
                        ran_for,
                    });
                }
                Some(_) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return None;
                    }
                    let (gg, _) = self.done.wait_timeout(g, deadline - now).unwrap();
                    g = gg;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_result() -> SolveResult {
        SolveResult { x: vec![], iterations: 1, converged: true, shrink_events: 0, history: vec![] }
    }

    #[test]
    fn legal_lifecycle() {
        let s = JobStore::new();
        s.insert_queued(1);
        assert_eq!(s.state(1), Some(JobState::Queued));
        s.transition(1, JobState::Running);
        s.complete(1, dummy_result());
        assert_eq!(s.state(1), Some(JobState::Done));
    }

    #[test]
    #[should_panic(expected = "illegal transition")]
    fn illegal_transition_panics() {
        let s = JobStore::new();
        s.insert_queued(1);
        s.transition(1, JobState::Done); // must pass through Running
    }

    #[test]
    #[should_panic(expected = "reused")]
    fn duplicate_id_panics() {
        let s = JobStore::new();
        s.insert_queued(1);
        s.insert_queued(1);
    }

    #[test]
    fn wait_returns_outcome() {
        let s = Arc::new(JobStore::new());
        s.insert_queued(5);
        let s2 = s.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            s2.transition(5, JobState::Running);
            s2.complete(5, dummy_result());
        });
        let out = s.wait(5, Duration::from_secs(2)).expect("job must finish");
        assert_eq!(out.state, JobState::Done);
        assert!(out.result.is_some());
        h.join().unwrap();
    }

    #[test]
    fn wait_times_out() {
        let s = JobStore::new();
        s.insert_queued(9);
        assert!(s.wait(9, Duration::from_millis(20)).is_none());
    }

    #[test]
    fn failed_jobs_carry_error() {
        let s = JobStore::new();
        s.insert_queued(2);
        s.transition(2, JobState::Running);
        s.fail(2, "boom".into());
        let out = s.wait(2, Duration::from_millis(10)).unwrap();
        assert_eq!(out.state, JobState::Failed);
        assert_eq!(out.error.as_deref(), Some("boom"));
    }

    #[test]
    fn progress_and_cancel_roundtrip() {
        let s = JobStore::new();
        s.insert_queued(3);
        assert!(s.progress(3).is_none());
        assert!(!s.cancel_requested(3));
        let stat = IterStat {
            iter: 4,
            resid_nsq: 0.5,
            mu: 1.0,
            support_changed: false,
            shrink_count: 0,
        };
        s.record_progress(3, stat);
        assert_eq!(s.progress(3).unwrap().iter, 4);
        assert!(s.request_cancel(3));
        assert!(s.cancel_requested(3));
        // Terminal jobs can no longer be cancelled.
        s.transition(3, JobState::Running);
        s.complete(3, dummy_result());
        assert!(!s.request_cancel(3));
        assert!(!s.request_cancel(99), "unknown job");
    }

    #[test]
    fn spec_lowers_to_facade_request() {
        let phi = Arc::new(Mat::zeros(2, 3));
        let spec = JobSpec::builder(ProblemHandle::with_shape_tag(phi.clone(), "tiny"), vec![0.0; 2], 1)
            .bits(2, 8)
            .seed(9)
            .build();
        assert_eq!(spec.solver.name(), "qniht");
        let dense =
            JobSpec { engine: EngineKind::NativeDense, solver: SolverKind::Niht, ..spec.clone() };
        assert_eq!(dense.solver.name(), "niht");
        let req = spec.into_request();
        assert_eq!(req.seed, 9);
        assert_eq!(req.problem.shape_tag(), Some("tiny"));
        assert_eq!((req.problem.m(), req.problem.n(), req.problem.s()), (2, 3, 1));
        // The request's problem shares the handle's Φ by identity.
        let req2 = dense.into_request();
        assert!(req.problem.shares_op(&req2.problem));
    }

    #[test]
    fn builder_infers_solver_from_engine_and_explicit_wins() {
        let phi = Arc::new(Mat::zeros(4, 8));
        let b = || JobSpec::builder(ProblemHandle::new(phi.clone()), vec![0.0; 4], 2);
        // Quantized engines → QNIHT Fixed at the builder's bit widths.
        let quant = b().engine(EngineKind::NativeQuant).bits(4, 8).build();
        assert_eq!(
            quant.solver,
            SolverKind::Qniht { bits_phi: 4, bits_y: 8, mode: RequantMode::Fixed }
        );
        let fpga = b().engine(EngineKind::FpgaModel).bits(2, 8).build();
        assert_eq!(fpga.solver.name(), "qniht");
        // Dense engines → NIHT.
        assert_eq!(b().engine(EngineKind::NativeDense).build().solver, SolverKind::Niht);
        // Explicit selection wins over inference.
        let explicit = b().engine(EngineKind::NativeDense).solver(SolverKind::Cosamp).build();
        assert_eq!(explicit.solver, SolverKind::Cosamp);
    }

    #[test]
    fn batch_key_identity() {
        let phi = Arc::new(Mat::zeros(2, 3));
        let spec = |phi: &Arc<Mat>| {
            JobSpec::builder(ProblemHandle::new(phi.clone()), vec![0.0; 2], 1).bits(2, 8).build()
        };
        let a = spec(&phi);
        let b = spec(&phi);
        assert_eq!(a.batch_key(), b.batch_key());
        let other = Arc::new(Mat::zeros(2, 3));
        let c = spec(&other);
        assert_ne!(a.batch_key(), c.batch_key());
        // Bit widths live in the solver key now.
        let mut d = spec(&phi);
        d.solver = SolverKind::qniht_fixed(4, 8);
        assert_ne!(a.batch_key(), d.batch_key());
        // Same everything but a different algorithm never batches.
        let e = JobSpec::builder(ProblemHandle::new(phi.clone()), vec![0.0; 2], 1)
            .engine(EngineKind::NativeDense)
            .build();
        let mut f = e.clone();
        f.solver = SolverKind::Cosamp;
        assert_ne!(e.batch_key(), f.batch_key());
        // Engine is still part of the key.
        let mut g = spec(&phi);
        g.engine = EngineKind::FpgaModel;
        assert_ne!(a.batch_key(), g.batch_key());
    }

    #[test]
    fn validate_rejects_malformed_specs() {
        let phi = Arc::new(Mat::zeros(4, 8));
        let ok = JobSpec::builder(ProblemHandle::new(phi.clone()), vec![0.0; 4], 2)
            .bits(2, 8)
            .build();
        ok.validate().unwrap();

        let mut wrong_y = ok.clone();
        wrong_y.y = vec![0.0; 3];
        assert!(wrong_y.validate().unwrap_err().to_string().contains("y length"));

        let mut zero_s = ok.clone();
        zero_s.s = 0;
        assert!(zero_s.validate().is_err());
        let mut fat_s = ok.clone();
        fat_s.s = 9;
        assert!(fat_s.validate().is_err());

        // Non-packed widths are rejected for quantized engines.
        for bad_bits in [0u8, 1, 3, 5, 6, 7, 16] {
            let spec = JobSpec::builder(ProblemHandle::new(phi.clone()), vec![0.0; 4], 2)
                .bits(bad_bits, 8)
                .build();
            let err = spec.validate().unwrap_err().to_string();
            assert!(err.contains("bits_phi"), "{bad_bits}: {err}");
        }
        let bad_y_bits = JobSpec::builder(ProblemHandle::new(phi.clone()), vec![0.0; 4], 2)
            .bits(2, 5)
            .build();
        assert!(bad_y_bits.validate().unwrap_err().to_string().contains("bits_y"));

        // Solver ↔ engine mismatches fail at submit, not inside the solve.
        let mismatch = JobSpec::builder(ProblemHandle::new(phi.clone()), vec![0.0; 4], 2)
            .engine(EngineKind::NativeQuant)
            .solver(SolverKind::Cosamp)
            .build();
        assert!(mismatch.validate().unwrap_err().to_string().contains("cannot run"));
        let fresh_on_xla = JobSpec::builder(ProblemHandle::new(phi.clone()), vec![0.0; 4], 2)
            .engine(EngineKind::XlaQuant)
            .solver(SolverKind::qniht_fresh(2, 8))
            .build();
        assert!(fresh_on_xla.validate().is_err());
    }
}
