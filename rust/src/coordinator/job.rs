//! Job model: specs, the state machine, and the store clients wait on.

use crate::algorithms::qniht::RequantMode;
use crate::algorithms::{IterStat, SolveResult};
use crate::config::EngineKind;
use crate::linalg::Mat;
use crate::solver::{Problem, SolveRequest, SolverKind};
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

pub type JobId = u64;

/// The measurement matrix a job recovers against. Jobs sharing the same
/// `Arc` are batchable (one quantization pass amortized over the batch).
#[derive(Debug, Clone)]
pub struct ProblemHandle {
    pub phi: Arc<Mat>,
    /// Artifact shape tag if this Φ matches an AOT shape (XLA engines).
    pub shape_tag: Option<String>,
}

impl ProblemHandle {
    pub fn new(phi: Arc<Mat>) -> Self {
        Self { phi, shape_tag: None }
    }

    pub fn with_shape_tag(phi: Arc<Mat>, tag: &str) -> Self {
        Self { phi, shape_tag: Some(tag.to_string()) }
    }
}

/// A recovery request.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub problem: ProblemHandle,
    pub y: Vec<f32>,
    pub s: usize,
    pub bits_phi: u8,
    pub bits_y: u8,
    pub engine: EngineKind,
    pub seed: u64,
}

impl JobSpec {
    /// Batching key: jobs are batchable iff they share Φ (by identity) and
    /// the full execution configuration.
    pub fn batch_key(&self) -> BatchKey {
        BatchKey {
            phi_ptr: Arc::as_ptr(&self.problem.phi) as usize,
            s: self.s,
            bits_phi: self.bits_phi,
            bits_y: self.bits_y,
            engine: self.engine,
        }
    }

    /// The facade [`SolverKind`] this job runs: QNIHT (Fixed — the
    /// serving setting) on the quantized engines, dense NIHT otherwise.
    pub fn solver_kind(&self) -> SolverKind {
        if self.engine.is_quantized() {
            SolverKind::Qniht {
                bits_phi: self.bits_phi,
                bits_y: self.bits_y,
                mode: RequantMode::Fixed,
            }
        } else {
            SolverKind::Niht
        }
    }

    /// Lower this job into the facade's [`SolveRequest`]. Jobs sharing a
    /// `ProblemHandle` produce requests whose problems share Φ by pointer
    /// identity, which is what the engine's batched path amortizes over.
    pub fn into_request(self) -> SolveRequest {
        let solver = self.solver_kind();
        let mut problem = Problem::new(self.problem.phi, self.y, self.s);
        if let Some(tag) = self.problem.shape_tag {
            problem = problem.with_shape_tag(tag);
        }
        SolveRequest { problem, solver, seed: self.seed }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BatchKey {
    pub phi_ptr: usize,
    pub s: usize,
    pub bits_phi: u8,
    pub bits_y: u8,
    pub engine: EngineKind,
}

/// Lifecycle states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
}

impl JobState {
    /// Legal transitions of the state machine.
    pub fn can_transition(self, next: JobState) -> bool {
        matches!(
            (self, next),
            (JobState::Queued, JobState::Running)
                | (JobState::Queued, JobState::Failed) // rejected before start
                | (JobState::Running, JobState::Done)
                | (JobState::Running, JobState::Failed)
        )
    }
}

/// Completed-job payload.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub id: JobId,
    pub state: JobState,
    pub result: Option<SolveResult>,
    pub error: Option<String>,
    pub queued_for: Duration,
    pub ran_for: Duration,
}

#[derive(Debug)]
struct Record {
    state: JobState,
    result: Option<SolveResult>,
    error: Option<String>,
    submitted: Instant,
    started: Option<Instant>,
    finished: Option<Instant>,
    /// Latest per-iteration stat the worker's observer streamed in.
    progress: Option<IterStat>,
    /// Cancellation requested: the worker's observer stops the solve at
    /// the next iteration boundary; the job completes with its partial
    /// iterate.
    cancel: bool,
}

/// Shared job table with completion signalling.
#[derive(Debug, Default)]
pub struct JobStore {
    inner: Mutex<HashMap<JobId, Record>>,
    done: Condvar,
}

impl JobStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert_queued(&self, id: JobId) {
        let mut g = self.inner.lock().unwrap();
        let prev = g.insert(
            id,
            Record {
                state: JobState::Queued,
                result: None,
                error: None,
                submitted: Instant::now(),
                started: None,
                finished: None,
                progress: None,
                cancel: false,
            },
        );
        assert!(prev.is_none(), "job id {id} reused");
    }

    /// Stream the latest iteration stat for a running job (worker-side).
    pub fn record_progress(&self, id: JobId, stat: IterStat) {
        if let Some(r) = self.inner.lock().unwrap().get_mut(&id) {
            r.progress = Some(stat);
        }
    }

    /// Latest streamed iteration stat, if the job has run any iterations.
    pub fn progress(&self, id: JobId) -> Option<IterStat> {
        self.inner.lock().unwrap().get(&id).and_then(|r| r.progress)
    }

    /// Ask a job to stop at its next iteration boundary. Returns false if
    /// the job is unknown or already terminal.
    pub fn request_cancel(&self, id: JobId) -> bool {
        let mut g = self.inner.lock().unwrap();
        match g.get_mut(&id) {
            Some(r) if !matches!(r.state, JobState::Done | JobState::Failed) => {
                r.cancel = true;
                true
            }
            _ => false,
        }
    }

    /// Whether cancellation was requested (worker-side poll).
    pub fn cancel_requested(&self, id: JobId) -> bool {
        self.inner.lock().unwrap().get(&id).map(|r| r.cancel).unwrap_or(false)
    }

    /// Transition enforcing state-machine legality.
    pub fn transition(&self, id: JobId, next: JobState) {
        let mut g = self.inner.lock().unwrap();
        let r = g.get_mut(&id).unwrap_or_else(|| panic!("unknown job {id}"));
        assert!(
            r.state.can_transition(next),
            "illegal transition {:?} -> {next:?} for job {id}",
            r.state
        );
        r.state = next;
        match next {
            JobState::Running => r.started = Some(Instant::now()),
            JobState::Done | JobState::Failed => {
                r.finished = Some(Instant::now());
            }
            JobState::Queued => unreachable!(),
        }
        if matches!(next, JobState::Done | JobState::Failed) {
            drop(g);
            self.done.notify_all();
        }
    }

    pub fn complete(&self, id: JobId, result: SolveResult) {
        {
            let mut g = self.inner.lock().unwrap();
            let r = g.get_mut(&id).unwrap();
            r.result = Some(result);
        }
        self.transition(id, JobState::Done);
    }

    pub fn fail(&self, id: JobId, error: String) {
        {
            let mut g = self.inner.lock().unwrap();
            let r = g.get_mut(&id).unwrap();
            r.error = Some(error);
        }
        self.transition(id, JobState::Failed);
    }

    pub fn state(&self, id: JobId) -> Option<JobState> {
        self.inner.lock().unwrap().get(&id).map(|r| r.state)
    }

    /// Block until the job reaches a terminal state (or timeout).
    pub fn wait(&self, id: JobId, timeout: Duration) -> Option<JobOutcome> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            match g.get(&id) {
                None => return None,
                Some(r) if matches!(r.state, JobState::Done | JobState::Failed) => {
                    let queued_for = r
                        .started
                        .unwrap_or_else(|| r.finished.unwrap())
                        .duration_since(r.submitted);
                    let ran_for = match (r.started, r.finished) {
                        (Some(s), Some(f)) => f.duration_since(s),
                        _ => Duration::ZERO,
                    };
                    return Some(JobOutcome {
                        id,
                        state: r.state,
                        result: r.result.clone(),
                        error: r.error.clone(),
                        queued_for,
                        ran_for,
                    });
                }
                Some(_) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return None;
                    }
                    let (gg, _) = self.done.wait_timeout(g, deadline - now).unwrap();
                    g = gg;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_result() -> SolveResult {
        SolveResult { x: vec![], iterations: 1, converged: true, shrink_events: 0, history: vec![] }
    }

    #[test]
    fn legal_lifecycle() {
        let s = JobStore::new();
        s.insert_queued(1);
        assert_eq!(s.state(1), Some(JobState::Queued));
        s.transition(1, JobState::Running);
        s.complete(1, dummy_result());
        assert_eq!(s.state(1), Some(JobState::Done));
    }

    #[test]
    #[should_panic(expected = "illegal transition")]
    fn illegal_transition_panics() {
        let s = JobStore::new();
        s.insert_queued(1);
        s.transition(1, JobState::Done); // must pass through Running
    }

    #[test]
    #[should_panic(expected = "reused")]
    fn duplicate_id_panics() {
        let s = JobStore::new();
        s.insert_queued(1);
        s.insert_queued(1);
    }

    #[test]
    fn wait_returns_outcome() {
        let s = Arc::new(JobStore::new());
        s.insert_queued(5);
        let s2 = s.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            s2.transition(5, JobState::Running);
            s2.complete(5, dummy_result());
        });
        let out = s.wait(5, Duration::from_secs(2)).expect("job must finish");
        assert_eq!(out.state, JobState::Done);
        assert!(out.result.is_some());
        h.join().unwrap();
    }

    #[test]
    fn wait_times_out() {
        let s = JobStore::new();
        s.insert_queued(9);
        assert!(s.wait(9, Duration::from_millis(20)).is_none());
    }

    #[test]
    fn failed_jobs_carry_error() {
        let s = JobStore::new();
        s.insert_queued(2);
        s.transition(2, JobState::Running);
        s.fail(2, "boom".into());
        let out = s.wait(2, Duration::from_millis(10)).unwrap();
        assert_eq!(out.state, JobState::Failed);
        assert_eq!(out.error.as_deref(), Some("boom"));
    }

    #[test]
    fn progress_and_cancel_roundtrip() {
        let s = JobStore::new();
        s.insert_queued(3);
        assert!(s.progress(3).is_none());
        assert!(!s.cancel_requested(3));
        let stat = IterStat {
            iter: 4,
            resid_nsq: 0.5,
            mu: 1.0,
            support_changed: false,
            shrink_count: 0,
        };
        s.record_progress(3, stat);
        assert_eq!(s.progress(3).unwrap().iter, 4);
        assert!(s.request_cancel(3));
        assert!(s.cancel_requested(3));
        // Terminal jobs can no longer be cancelled.
        s.transition(3, JobState::Running);
        s.complete(3, dummy_result());
        assert!(!s.request_cancel(3));
        assert!(!s.request_cancel(99), "unknown job");
    }

    #[test]
    fn spec_lowers_to_facade_request() {
        let phi = Arc::new(Mat::zeros(2, 3));
        let spec = JobSpec {
            problem: ProblemHandle::with_shape_tag(phi.clone(), "tiny"),
            y: vec![0.0; 2],
            s: 1,
            bits_phi: 2,
            bits_y: 8,
            engine: EngineKind::NativeQuant,
            seed: 9,
        };
        assert_eq!(spec.solver_kind().name(), "qniht");
        let dense = JobSpec { engine: EngineKind::NativeDense, ..spec.clone() };
        assert_eq!(dense.solver_kind().name(), "niht");
        let req = spec.into_request();
        assert_eq!(req.seed, 9);
        assert_eq!(req.problem.shape_tag(), Some("tiny"));
        assert_eq!((req.problem.m(), req.problem.n(), req.problem.s()), (2, 3, 1));
        // The request's problem shares the handle's Φ by identity.
        let req2 = dense.into_request();
        assert!(req.problem.shares_op(&req2.problem));
    }

    #[test]
    fn batch_key_identity() {
        let phi = Arc::new(Mat::zeros(2, 3));
        let spec = |phi: &Arc<Mat>| JobSpec {
            problem: ProblemHandle::new(phi.clone()),
            y: vec![0.0; 2],
            s: 1,
            bits_phi: 2,
            bits_y: 8,
            engine: EngineKind::NativeQuant,
            seed: 0,
        };
        let a = spec(&phi);
        let b = spec(&phi);
        assert_eq!(a.batch_key(), b.batch_key());
        let other = Arc::new(Mat::zeros(2, 3));
        let c = spec(&other);
        assert_ne!(a.batch_key(), c.batch_key());
        let mut d = spec(&phi);
        d.bits_phi = 4;
        assert_ne!(a.batch_key(), d.batch_key());
    }
}
