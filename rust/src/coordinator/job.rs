//! Job model: specs, the state machine, and the store clients wait on.

use crate::algorithms::qniht::RequantMode;
use crate::algorithms::{IterStat, SolveResult};
use crate::config::{EngineKind, QuantConfig};
use crate::linalg::Mat;
use crate::mri::{self, PartialFourierOp};
use crate::solver::{MeasurementOp, Problem, SolveRequest, SolverKey, SolverKind};
use crate::telescope::{op as astro_op, VisibilityOp};
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

pub type JobId = u64;

/// The measurement operator a job recovers against — either an explicit
/// dense Φ or a matrix-free structured operator. Jobs sharing the same
/// `Arc` (and configuration) are batchable: for dense quantized jobs the
/// engine amortizes one quantize+pack pass over the batch; for
/// matrix-free jobs the shared operator is the batch identity.
#[derive(Debug, Clone)]
pub enum OperatorSpec {
    /// Explicit dense measurement matrix (every engine; all solvers).
    Dense(Arc<Mat>),
    /// Matrix-free partial-Fourier MRI operator. `bits = None` runs the
    /// f32 path; `Some(b)` the low-precision sampling path (observation
    /// and per-iteration k-space traffic quantized to b ∈ {2, 4, 8} —
    /// see [`crate::mri::op`]). Servable under `SolverKind::Niht` on the
    /// dense native engine (the facade's generic `OpKernel` driver).
    PartialFourier { op: Arc<PartialFourierOp>, bits: Option<u8> },
    /// Matrix-free radio-interferometry visibility operator
    /// ([`crate::telescope::op`]). `bits = None` runs the f32 path;
    /// `Some(b)` the low-precision sampling path (observation and
    /// per-iteration visibility traffic quantized to b ∈ {2, 4, 8} with
    /// per-baseline-block scales). Same matrix-free serving surface as
    /// partial-Fourier: `SolverKind::Niht` on the dense native engine.
    /// Serving defaults to unique-baseline operators; the full L² set
    /// (rank-deficient stacked-real) is for paper-parity figures.
    Visibility { op: Arc<VisibilityOp>, bits: Option<u8> },
}

impl OperatorSpec {
    /// Observation length (operator rows).
    pub fn m(&self) -> usize {
        match self {
            Self::Dense(phi) => phi.rows,
            Self::PartialFourier { op, .. } => MeasurementOp::m(&**op),
            Self::Visibility { op, .. } => MeasurementOp::m(&**op),
        }
    }

    /// Signal length (operator columns).
    pub fn n(&self) -> usize {
        match self {
            Self::Dense(phi) => phi.cols,
            Self::PartialFourier { op, .. } => MeasurementOp::n(&**op),
            Self::Visibility { op, .. } => MeasurementOp::n(&**op),
        }
    }

    /// The explicit matrix, when this spec holds one.
    pub fn as_dense(&self) -> Option<&Arc<Mat>> {
        match self {
            Self::Dense(phi) => Some(phi),
            Self::PartialFourier { .. } | Self::Visibility { .. } => None,
        }
    }

    /// Hashable identity for batching: operator `Arc` pointer plus the
    /// configuration that changes the executed math.
    pub fn key(&self) -> OpKey {
        match self {
            Self::Dense(phi) => OpKey::Dense { phi: Arc::as_ptr(phi) as usize },
            Self::PartialFourier { op, bits } => {
                OpKey::PartialFourier { op: Arc::as_ptr(op) as usize, bits: *bits }
            }
            Self::Visibility { op, bits } => {
                OpKey::Visibility { op: Arc::as_ptr(op) as usize, bits: *bits }
            }
        }
    }
}

/// Hashable fingerprint of an [`OperatorSpec`] (part of [`BatchKey`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKey {
    Dense { phi: usize },
    PartialFourier { op: usize, bits: Option<u8> },
    Visibility { op: usize, bits: Option<u8> },
}

/// The operator a job recovers against plus its artifact shape tag. Jobs
/// sharing the operator `Arc` are batchable.
#[derive(Debug, Clone)]
pub struct ProblemHandle {
    pub op: OperatorSpec,
    /// Artifact shape tag if this Φ matches an AOT shape (XLA engines).
    pub shape_tag: Option<String>,
}

impl ProblemHandle {
    /// Explicit dense Φ (the common case).
    pub fn new(phi: Arc<Mat>) -> Self {
        Self { op: OperatorSpec::Dense(phi), shape_tag: None }
    }

    pub fn with_shape_tag(phi: Arc<Mat>, tag: &str) -> Self {
        Self { op: OperatorSpec::Dense(phi), shape_tag: Some(tag.to_string()) }
    }

    /// Matrix-free partial-Fourier operator, f32 path.
    pub fn partial_fourier(op: Arc<PartialFourierOp>) -> Self {
        Self { op: OperatorSpec::PartialFourier { op, bits: None }, shape_tag: None }
    }

    /// Matrix-free partial-Fourier operator on the low-precision sampling
    /// path at `bits` ∈ {2, 4, 8}.
    pub fn low_prec_fourier(op: Arc<PartialFourierOp>, bits: u8) -> Self {
        Self { op: OperatorSpec::PartialFourier { op, bits: Some(bits) }, shape_tag: None }
    }

    /// Matrix-free visibility operator, f32 path.
    pub fn visibility(op: Arc<VisibilityOp>) -> Self {
        Self { op: OperatorSpec::Visibility { op, bits: None }, shape_tag: None }
    }

    /// Matrix-free visibility operator on the low-precision sampling path
    /// at `bits` ∈ {2, 4, 8}.
    pub fn low_prec_visibility(op: Arc<VisibilityOp>, bits: u8) -> Self {
        Self { op: OperatorSpec::Visibility { op, bits: Some(bits) }, shape_tag: None }
    }

    pub fn m(&self) -> usize {
        self.op.m()
    }

    pub fn n(&self) -> usize {
        self.op.n()
    }

    pub fn as_dense(&self) -> Option<&Arc<Mat>> {
        self.op.as_dense()
    }
}

/// A recovery request: problem + an explicit algorithm ([`SolverKind`],
/// which carries the full quantization configuration for QNIHT) + the
/// engine that executes it. Construct via [`JobSpec::builder`] — the
/// builder infers the solver from the engine exactly as the pre-PR-3
/// service did, so existing callers keep their behavior.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub problem: ProblemHandle,
    pub y: Vec<f32>,
    pub s: usize,
    pub solver: SolverKind,
    pub engine: EngineKind,
    pub seed: u64,
    /// Fleet trace id (see [`crate::obsv::TraceId`]); 0 = untraced.
    /// Deliberately excluded from [`JobSpec::batch_key`] and the wire
    /// `route_key` — tracing must never change batching or placement.
    pub trace: u64,
}

impl JobSpec {
    /// Start building a spec. Defaults: engine `native-quant` with the
    /// default bit widths ([`QuantConfig::default`]), solver inferred
    /// from the engine, seed 0.
    pub fn builder(problem: ProblemHandle, y: Vec<f32>, s: usize) -> JobSpecBuilder {
        let q = QuantConfig::default();
        JobSpecBuilder {
            problem,
            y,
            s,
            engine: EngineKind::NativeQuant,
            bits_phi: q.bits_phi,
            bits_y: q.bits_y,
            solver: None,
            seed: 0,
            trace: 0,
        }
    }

    /// Batching key: jobs are batchable iff they share the operator (by
    /// identity, plus its math-changing configuration — the MRI bit
    /// width) and the full execution configuration — including the
    /// solver, so e.g. a CoSaMP job never coalesces with an NIHT job.
    pub fn batch_key(&self) -> BatchKey {
        BatchKey {
            op: self.problem.op.key(),
            s: self.s,
            solver: self.solver.key(),
            engine: self.engine,
        }
    }

    /// Submit-time validation: shape/sparsity sanity, solver ↔ engine
    /// compatibility, packed bit widths for the quantized engines, and —
    /// for matrix-free operators — the operator's own parameter gate
    /// (mask fraction/centre band) plus the matrix-free serving surface
    /// (`SolverKind::Niht` on the dense native engine). Without this a
    /// malformed spec only fails deep inside the batch solve, after it
    /// has been queued, scheduled and batched.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.y.len() == self.problem.m(),
            "y length {} does not match Φ rows {}",
            self.y.len(),
            self.problem.m()
        );
        anyhow::ensure!(self.s >= 1, "sparsity must be >= 1");
        anyhow::ensure!(
            self.s <= self.problem.n(),
            "sparsity {} exceeds signal dimension {}",
            self.s,
            self.problem.n()
        );
        if let OperatorSpec::PartialFourier { op, bits } = &self.problem.op {
            op.validate()?;
            anyhow::ensure!(
                self.solver == SolverKind::Niht,
                "matrix-free partial-Fourier jobs run solver 'niht' (the generic \
                 OpKernel driver); solver '{}' needs an explicit measurement matrix",
                self.solver.name()
            );
            anyhow::ensure!(
                self.engine == EngineKind::NativeDense,
                "matrix-free partial-Fourier jobs are servable on engine \
                 'native-dense' only (engine '{}' needs an explicit matrix)",
                self.engine.name()
            );
            if let Some(b) = bits {
                anyhow::ensure!(
                    matches!(b, 2 | 4 | 8),
                    "mri bits = {b} is not servable (packed widths: 2, 4, 8)"
                );
            }
        }
        if let OperatorSpec::Visibility { op, bits } = &self.problem.op {
            op.validate()?;
            anyhow::ensure!(
                self.solver == SolverKind::Niht,
                "matrix-free visibility jobs run solver 'niht' (the generic \
                 OpKernel driver); solver '{}' needs an explicit measurement matrix",
                self.solver.name()
            );
            anyhow::ensure!(
                self.engine == EngineKind::NativeDense,
                "matrix-free visibility jobs are servable on engine \
                 'native-dense' only (engine '{}' needs an explicit matrix)",
                self.engine.name()
            );
            if let Some(b) = bits {
                anyhow::ensure!(
                    matches!(b, 2 | 4 | 8),
                    "astro bits = {b} is not servable (packed widths: 2, 4, 8)"
                );
            }
        }
        anyhow::ensure!(
            self.solver.runs_on(self.engine),
            "solver '{}' cannot run on engine '{}'",
            self.solver.name(),
            self.engine.name()
        );
        if self.engine.is_quantized() {
            self.solver.check_packed_bits()?;
        }
        Ok(())
    }

    /// Lower this job into the facade's [`SolveRequest`]. Jobs sharing a
    /// `ProblemHandle` produce requests whose problems share Φ by pointer
    /// identity, which is what the engine's batched path amortizes over.
    /// Low-precision MRI jobs lower through [`mri::lowprec_problem`] —
    /// the same lowering direct facade callers use, so served results are
    /// bit-identical to local ones (the `seed` drives the stochastic
    /// quantization of ŷ and the per-iteration traffic).
    pub fn into_request(self) -> SolveRequest {
        let solver = self.solver;
        let mut problem = match self.problem.op {
            OperatorSpec::Dense(phi) => Problem::new(phi, self.y, self.s),
            OperatorSpec::PartialFourier { op, bits: None } => {
                Problem::with_op(op, self.y, self.s)
            }
            OperatorSpec::PartialFourier { op, bits: Some(b) } => {
                mri::lowprec_problem(op, &self.y, self.s, b, self.seed)
            }
            OperatorSpec::Visibility { op, bits: None } => {
                Problem::with_op(op, self.y, self.s)
            }
            OperatorSpec::Visibility { op, bits: Some(b) } => {
                astro_op::lowprec_problem(op, &self.y, self.s, b, self.seed)
            }
        };
        if let Some(tag) = self.problem.shape_tag {
            problem = problem.with_shape_tag(tag);
        }
        SolveRequest { problem, solver, seed: self.seed }
    }
}

/// Builder for [`JobSpec`]. Unless [`JobSpecBuilder::solver`] is called,
/// the solver is inferred from the engine exactly as the old
/// `solver_kind()` did: QNIHT (Fixed, at the builder's bit widths) on
/// quantized engines, dense NIHT otherwise.
#[derive(Debug, Clone)]
pub struct JobSpecBuilder {
    problem: ProblemHandle,
    y: Vec<f32>,
    s: usize,
    engine: EngineKind,
    bits_phi: u8,
    bits_y: u8,
    solver: Option<SolverKind>,
    seed: u64,
    trace: u64,
}

impl JobSpecBuilder {
    pub fn engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    /// Bit widths the inferred QNIHT solver uses (ignored when an
    /// explicit solver is set).
    pub fn bits(mut self, bits_phi: u8, bits_y: u8) -> Self {
        self.bits_phi = bits_phi;
        self.bits_y = bits_y;
        self
    }

    /// Explicit algorithm selection (any [`SolverKind`], including the
    /// CoSaMP/FISTA/IHT baselines and Fresh-mode QNIHT).
    pub fn solver(mut self, solver: SolverKind) -> Self {
        self.solver = Some(solver);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Attach a fleet trace id (0 = untraced; see
    /// [`crate::obsv::TraceId`]).
    pub fn trace(mut self, trace: u64) -> Self {
        self.trace = trace;
        self
    }

    pub fn build(self) -> JobSpec {
        let solver = self.solver.unwrap_or(if self.engine.is_quantized() {
            SolverKind::Qniht {
                bits_phi: self.bits_phi,
                bits_y: self.bits_y,
                mode: RequantMode::Fixed,
            }
        } else {
            SolverKind::Niht
        });
        JobSpec {
            problem: self.problem,
            y: self.y,
            s: self.s,
            solver,
            engine: self.engine,
            seed: self.seed,
            trace: self.trace,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BatchKey {
    pub op: OpKey,
    pub s: usize,
    pub solver: SolverKey,
    pub engine: EngineKind,
}

/// Lifecycle states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
}

impl JobState {
    /// Legal transitions of the state machine.
    pub fn can_transition(self, next: JobState) -> bool {
        matches!(
            (self, next),
            (JobState::Queued, JobState::Running)
                | (JobState::Queued, JobState::Failed) // rejected before start
                | (JobState::Running, JobState::Done)
                | (JobState::Running, JobState::Failed)
        )
    }
}

/// Completed-job payload.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub id: JobId,
    pub state: JobState,
    pub result: Option<SolveResult>,
    pub error: Option<String>,
    pub queued_for: Duration,
    pub ran_for: Duration,
    /// Fleet trace id the job carried (0 = untraced).
    pub trace: u64,
}

/// One event delivered to a progress subscriber: a per-iteration stat,
/// then exactly one terminal outcome.
#[derive(Debug, Clone)]
pub enum ProgressEvent {
    Stat(IterStat),
    Terminal(JobOutcome),
}

#[derive(Debug)]
struct SubInner {
    /// Bounded stat buffer (drop-oldest on overflow).
    buf: VecDeque<IterStat>,
    /// Set once, delivered after every buffered stat.
    terminal: Option<JobOutcome>,
    terminal_taken: bool,
    dropped: u64,
    detached: bool,
}

/// A push-based progress subscription on one job: a bounded stat queue
/// with **drop-oldest** overflow, so the producing worker NEVER blocks on
/// a slow consumer — the consumer just sees gaps in the iteration stream
/// (always keeping the freshest stats) and still receives exactly one
/// [`ProgressEvent::Terminal`]. This is what the wire server bridges a
/// `Subscribe` frame onto.
#[derive(Debug)]
pub struct ProgressSub {
    depth: usize,
    inner: Mutex<SubInner>,
    ready: Condvar,
}

impl ProgressSub {
    fn new(depth: usize) -> Arc<Self> {
        Arc::new(Self {
            depth: depth.max(1),
            inner: Mutex::new(SubInner {
                buf: VecDeque::new(),
                terminal: None,
                terminal_taken: false,
                dropped: 0,
                detached: false,
            }),
            ready: Condvar::new(),
        })
    }

    /// Producer-side push; O(1), never blocks beyond the short buffer
    /// lock. Returns how many stats were dropped to make room (0 or 1).
    fn push_stat(&self, stat: IterStat) -> u64 {
        let dropped = {
            let mut g = self.inner.lock().unwrap();
            if g.detached || g.terminal.is_some() {
                return 0;
            }
            let mut dropped = 0;
            if g.buf.len() >= self.depth {
                g.buf.pop_front();
                g.dropped += 1;
                dropped = 1;
            }
            g.buf.push_back(stat);
            dropped
        };
        self.ready.notify_all();
        dropped
    }

    fn push_terminal(&self, outcome: JobOutcome) {
        {
            let mut g = self.inner.lock().unwrap();
            if g.detached || g.terminal.is_some() {
                return;
            }
            g.terminal = Some(outcome);
        }
        self.ready.notify_all();
    }

    /// Consumer-side pull: buffered stats in order, then the terminal
    /// outcome once. `None` means timeout — or, after the terminal event
    /// has been taken, that the stream is over.
    pub fn recv(&self, timeout: Duration) -> Option<ProgressEvent> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(stat) = g.buf.pop_front() {
                return Some(ProgressEvent::Stat(stat));
            }
            if g.terminal_taken {
                return None;
            }
            if let Some(out) = g.terminal.clone() {
                g.terminal_taken = true;
                return Some(ProgressEvent::Terminal(out));
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (gg, _) = self.ready.wait_timeout(g, deadline - now).unwrap();
            g = gg;
        }
    }

    /// Total stats discarded by drop-oldest overflow.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Whether the terminal event has been consumed (the stream is over).
    pub fn finished(&self) -> bool {
        let g = self.inner.lock().unwrap();
        g.terminal_taken && g.buf.is_empty()
    }

    /// Mark the subscriber dead (client disconnected): the store prunes
    /// detached subs on the next progress push, and further pushes are
    /// no-ops.
    pub fn detach(&self) {
        self.inner.lock().unwrap().detached = true;
    }

    fn is_detached(&self) -> bool {
        self.inner.lock().unwrap().detached
    }
}

#[derive(Debug)]
struct Record {
    state: JobState,
    result: Option<SolveResult>,
    error: Option<String>,
    submitted: Instant,
    started: Option<Instant>,
    finished: Option<Instant>,
    /// Latest per-iteration stat the worker's observer streamed in.
    progress: Option<IterStat>,
    /// Cancellation requested: the worker's observer stops the solve at
    /// the next iteration boundary; the job completes with its partial
    /// iterate.
    cancel: bool,
    /// Push-based progress subscribers (wire clients); every stat fans
    /// out here, and the terminal transition delivers the outcome.
    subs: Vec<Arc<ProgressSub>>,
    /// Fleet trace id carried from the submit face (0 = untraced).
    trace: u64,
}

impl Record {
    /// Terminal payload; callers ensure `state` is Done/Failed.
    fn outcome(&self, id: JobId) -> JobOutcome {
        let queued_for = self
            .started
            .unwrap_or_else(|| self.finished.unwrap())
            .duration_since(self.submitted);
        let ran_for = match (self.started, self.finished) {
            (Some(s), Some(f)) => f.duration_since(s),
            _ => Duration::ZERO,
        };
        JobOutcome {
            id,
            state: self.state,
            result: self.result.clone(),
            error: self.error.clone(),
            queued_for,
            ran_for,
            trace: self.trace,
        }
    }
}

/// Shared job table with completion signalling.
#[derive(Debug, Default)]
pub struct JobStore {
    inner: Mutex<HashMap<JobId, Record>>,
    done: Condvar,
}

impl JobStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert_queued(&self, id: JobId, trace: u64) {
        let mut g = self.inner.lock().unwrap();
        let prev = g.insert(
            id,
            Record {
                state: JobState::Queued,
                result: None,
                error: None,
                submitted: Instant::now(),
                started: None,
                finished: None,
                progress: None,
                cancel: false,
                subs: Vec::new(),
                trace,
            },
        );
        assert!(prev.is_none(), "job id {id} reused");
    }

    /// The fleet trace id a job carries (0 for untraced or unknown ids).
    pub fn trace_of(&self, id: JobId) -> u64 {
        self.inner.lock().unwrap().get(&id).map(|r| r.trace).unwrap_or(0)
    }

    /// Stream the latest iteration stat for a running job (worker-side)
    /// and fan it out to every live subscriber. Bounded subscriber queues
    /// drop their oldest stat instead of blocking, so this never stalls
    /// the worker; the return value is how many stats were dropped that
    /// way (for the service's `progress_dropped` counter). Detached
    /// (disconnected) subscribers are pruned here.
    pub fn record_progress(&self, id: JobId, stat: IterStat) -> u64 {
        let mut g = self.inner.lock().unwrap();
        let Some(r) = g.get_mut(&id) else { return 0 };
        r.progress = Some(stat);
        r.subs.retain(|s| !s.is_detached());
        r.subs.iter().map(|s| s.push_stat(stat)).sum()
    }

    /// Register a push-based progress subscriber on a job: a bounded
    /// queue of `depth` stats with drop-oldest overflow (see
    /// [`ProgressSub`]). Subscribing to an already-terminal job yields
    /// just the terminal event; unknown ids yield `None`. The latest
    /// recorded stat (if any) is pre-buffered so late subscribers see
    /// where the solve currently stands.
    pub fn subscribe(&self, id: JobId, depth: usize) -> Option<Arc<ProgressSub>> {
        let mut g = self.inner.lock().unwrap();
        let r = g.get_mut(&id)?;
        let sub = ProgressSub::new(depth);
        if matches!(r.state, JobState::Done | JobState::Failed) {
            sub.push_terminal(r.outcome(id));
            return Some(sub);
        }
        if let Some(stat) = r.progress {
            sub.push_stat(stat);
        }
        r.subs.push(sub.clone());
        Some(sub)
    }

    /// Latest streamed iteration stat, if the job has run any iterations.
    pub fn progress(&self, id: JobId) -> Option<IterStat> {
        self.inner.lock().unwrap().get(&id).and_then(|r| r.progress)
    }

    /// Microseconds a still-**Queued** job has waited since submit (0 for
    /// unknown ids *and* for jobs already Running or terminal) — the age
    /// the cost-aware scheduler feeds its starvation bound. Dispatched
    /// jobs must not report a growing "age": their true queue wait is
    /// frozen at the Queued→Running transition (see
    /// [`JobStore::transition`]) and that is what the queue-wait
    /// histogram records.
    pub fn queued_age_us(&self, id: JobId) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .get(&id)
            .filter(|r| r.state == JobState::Queued)
            .map(|r| r.submitted.elapsed().as_micros() as u64)
            .unwrap_or(0)
    }

    /// Submit/start instants for a job (`None` for unknown ids; the
    /// second slot is `None` until the job starts running). The worker
    /// derives execution and end-to-end durations from these *before*
    /// marking the job terminal, so observability recording is complete
    /// by the time `wait` callers unblock.
    pub fn stamps(&self, id: JobId) -> Option<(Instant, Option<Instant>)> {
        self.inner.lock().unwrap().get(&id).map(|r| (r.submitted, r.started))
    }

    /// Ask a job to stop at its next iteration boundary. Returns false if
    /// the job is unknown or already terminal.
    pub fn request_cancel(&self, id: JobId) -> bool {
        let mut g = self.inner.lock().unwrap();
        match g.get_mut(&id) {
            Some(r) if !matches!(r.state, JobState::Done | JobState::Failed) => {
                r.cancel = true;
                true
            }
            _ => false,
        }
    }

    /// Whether cancellation was requested (worker-side poll).
    pub fn cancel_requested(&self, id: JobId) -> bool {
        self.inner.lock().unwrap().get(&id).map(|r| r.cancel).unwrap_or(false)
    }

    /// Transition enforcing state-machine legality. Entering `Running`
    /// returns the job's true queue wait (started − submitted), measured
    /// under the store lock at the instant it is frozen — the sample the
    /// queue-wait histogram records.
    pub fn transition(&self, id: JobId, next: JobState) -> Option<Duration> {
        let mut queue_wait = None;
        let mut g = self.inner.lock().unwrap();
        let r = g.get_mut(&id).unwrap_or_else(|| panic!("unknown job {id}"));
        assert!(
            r.state.can_transition(next),
            "illegal transition {:?} -> {next:?} for job {id}",
            r.state
        );
        r.state = next;
        match next {
            JobState::Running => {
                let now = Instant::now();
                r.started = Some(now);
                queue_wait = Some(now.duration_since(r.submitted));
            }
            JobState::Done | JobState::Failed => {
                r.finished = Some(Instant::now());
            }
            JobState::Queued => unreachable!(),
        }
        if matches!(next, JobState::Done | JobState::Failed) {
            // Deliver the terminal event to every subscriber (after any
            // still-buffered stats) and drop the registry — the stream is
            // over, nothing further will be pushed.
            let outcome = r.outcome(id);
            for sub in r.subs.drain(..) {
                sub.push_terminal(outcome.clone());
            }
            drop(g);
            self.done.notify_all();
        }
        queue_wait
    }

    pub fn complete(&self, id: JobId, result: SolveResult) {
        {
            let mut g = self.inner.lock().unwrap();
            let r = g.get_mut(&id).unwrap();
            r.result = Some(result);
        }
        self.transition(id, JobState::Done);
    }

    pub fn fail(&self, id: JobId, error: String) {
        {
            let mut g = self.inner.lock().unwrap();
            let r = g.get_mut(&id).unwrap();
            r.error = Some(error);
        }
        self.transition(id, JobState::Failed);
    }

    pub fn state(&self, id: JobId) -> Option<JobState> {
        self.inner.lock().unwrap().get(&id).map(|r| r.state)
    }

    /// Block until the job reaches a terminal state (or timeout).
    pub fn wait(&self, id: JobId, timeout: Duration) -> Option<JobOutcome> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            match g.get(&id) {
                None => return None,
                Some(r) if matches!(r.state, JobState::Done | JobState::Failed) => {
                    return Some(r.outcome(id));
                }
                Some(_) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return None;
                    }
                    let (gg, _) = self.done.wait_timeout(g, deadline - now).unwrap();
                    g = gg;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_result() -> SolveResult {
        SolveResult { x: vec![], iterations: 1, converged: true, shrink_events: 0, history: vec![] }
    }

    #[test]
    fn legal_lifecycle() {
        let s = JobStore::new();
        s.insert_queued(1, 0);
        assert_eq!(s.state(1), Some(JobState::Queued));
        s.transition(1, JobState::Running);
        s.complete(1, dummy_result());
        assert_eq!(s.state(1), Some(JobState::Done));
    }

    #[test]
    fn queued_age_is_zero_once_dispatched_and_wait_is_frozen_at_running() {
        let s = JobStore::new();
        s.insert_queued(1, 0);
        std::thread::sleep(Duration::from_millis(5));
        assert!(s.queued_age_us(1) > 0, "a queued job ages");
        let wait = s.transition(1, JobState::Running).expect("Running returns the queue wait");
        assert!(wait >= Duration::from_millis(4));
        // Dispatched: age must stop growing (the old behavior returned
        // elapsed-since-submit forever).
        assert_eq!(s.queued_age_us(1), 0);
        let (submitted, started) = s.stamps(1).unwrap();
        assert_eq!(started.unwrap().duration_since(submitted), wait);
        s.complete(1, dummy_result());
        assert_eq!(s.queued_age_us(1), 0);
        assert_eq!(s.queued_age_us(999), 0);
        // The outcome's queued_for is the same frozen wait.
        let out = s.wait(1, Duration::from_millis(10)).unwrap();
        assert_eq!(out.queued_for, wait);
    }

    #[test]
    #[should_panic(expected = "illegal transition")]
    fn illegal_transition_panics() {
        let s = JobStore::new();
        s.insert_queued(1, 0);
        s.transition(1, JobState::Done); // must pass through Running
    }

    #[test]
    #[should_panic(expected = "reused")]
    fn duplicate_id_panics() {
        let s = JobStore::new();
        s.insert_queued(1, 0);
        s.insert_queued(1, 0);
    }

    #[test]
    fn wait_returns_outcome() {
        let s = Arc::new(JobStore::new());
        s.insert_queued(5, 0);
        let s2 = s.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            s2.transition(5, JobState::Running);
            s2.complete(5, dummy_result());
        });
        let out = s.wait(5, Duration::from_secs(2)).expect("job must finish");
        assert_eq!(out.state, JobState::Done);
        assert!(out.result.is_some());
        h.join().unwrap();
    }

    #[test]
    fn wait_times_out() {
        let s = JobStore::new();
        s.insert_queued(9, 0);
        assert!(s.wait(9, Duration::from_millis(20)).is_none());
    }

    #[test]
    fn failed_jobs_carry_error() {
        let s = JobStore::new();
        s.insert_queued(2, 0);
        s.transition(2, JobState::Running);
        s.fail(2, "boom".into());
        let out = s.wait(2, Duration::from_millis(10)).unwrap();
        assert_eq!(out.state, JobState::Failed);
        assert_eq!(out.error.as_deref(), Some("boom"));
    }

    #[test]
    fn progress_and_cancel_roundtrip() {
        let s = JobStore::new();
        s.insert_queued(3, 0);
        assert!(s.progress(3).is_none());
        assert!(!s.cancel_requested(3));
        let stat = IterStat {
            iter: 4,
            resid_nsq: 0.5,
            mu: 1.0,
            support_changed: false,
            shrink_count: 0,
        };
        s.record_progress(3, stat);
        assert_eq!(s.progress(3).unwrap().iter, 4);
        assert!(s.request_cancel(3));
        assert!(s.cancel_requested(3));
        // Terminal jobs can no longer be cancelled.
        s.transition(3, JobState::Running);
        s.complete(3, dummy_result());
        assert!(!s.request_cancel(3));
        assert!(!s.request_cancel(99), "unknown job");
    }

    fn stat(iter: usize) -> IterStat {
        IterStat { iter, resid_nsq: 1.0 / (iter + 1) as f32, mu: 1.0, support_changed: false, shrink_count: 0 }
    }

    #[test]
    fn subscriber_drop_oldest_keeps_latest_and_never_blocks() {
        let s = JobStore::new();
        s.insert_queued(1, 0);
        s.transition(1, JobState::Running);
        let sub = s.subscribe(1, 3).expect("known job");
        // Push 10 stats into a depth-3 queue: 7 drop (oldest first), the
        // producer side never waits on the consumer.
        let mut dropped = 0;
        for i in 0..10 {
            dropped += s.record_progress(1, stat(i));
        }
        assert_eq!(dropped, 7);
        assert_eq!(sub.dropped(), 7);
        s.complete(1, dummy_result());
        // The consumer sees exactly the 3 freshest stats, in order, then
        // the terminal event, then end-of-stream.
        let mut iters = Vec::new();
        loop {
            match sub.recv(Duration::from_secs(5)) {
                Some(ProgressEvent::Stat(st)) => iters.push(st.iter),
                Some(ProgressEvent::Terminal(out)) => {
                    assert_eq!(out.state, JobState::Done);
                    break;
                }
                None => panic!("terminal must arrive"),
            }
        }
        assert_eq!(iters, vec![7, 8, 9]);
        assert!(sub.finished());
        assert!(sub.recv(Duration::from_millis(1)).is_none(), "stream is over");
    }

    #[test]
    fn subscribe_after_terminal_yields_outcome_and_unknown_is_none() {
        let s = JobStore::new();
        assert!(s.subscribe(42, 4).is_none(), "unknown job");
        s.insert_queued(1, 0);
        s.transition(1, JobState::Running);
        s.fail(1, "boom".into());
        let sub = s.subscribe(1, 4).expect("terminal jobs still subscribe");
        match sub.recv(Duration::from_secs(1)) {
            Some(ProgressEvent::Terminal(out)) => {
                assert_eq!(out.state, JobState::Failed);
                assert_eq!(out.error.as_deref(), Some("boom"));
            }
            other => panic!("expected terminal, got {other:?}"),
        }
    }

    #[test]
    fn late_subscriber_sees_latest_stat_and_detached_subs_are_pruned() {
        let s = JobStore::new();
        s.insert_queued(1, 0);
        s.transition(1, JobState::Running);
        s.record_progress(1, stat(5));
        // A late subscriber is seeded with where the solve stands now.
        let sub = s.subscribe(1, 4).unwrap();
        match sub.recv(Duration::from_secs(1)) {
            Some(ProgressEvent::Stat(st)) => assert_eq!(st.iter, 5),
            other => panic!("expected the seeded stat, got {other:?}"),
        }
        // Detached (disconnected) subscribers stop accumulating.
        sub.detach();
        assert_eq!(s.record_progress(1, stat(6)), 0, "detached subs never drop");
        assert!(sub.recv(Duration::from_millis(1)).is_none());
        s.complete(1, dummy_result());
    }

    #[test]
    fn spec_lowers_to_facade_request() {
        let phi = Arc::new(Mat::zeros(2, 3));
        let spec = JobSpec::builder(ProblemHandle::with_shape_tag(phi.clone(), "tiny"), vec![0.0; 2], 1)
            .bits(2, 8)
            .seed(9)
            .build();
        assert_eq!(spec.solver.name(), "qniht");
        let dense =
            JobSpec { engine: EngineKind::NativeDense, solver: SolverKind::Niht, ..spec.clone() };
        assert_eq!(dense.solver.name(), "niht");
        let req = spec.into_request();
        assert_eq!(req.seed, 9);
        assert_eq!(req.problem.shape_tag(), Some("tiny"));
        assert_eq!((req.problem.m(), req.problem.n(), req.problem.s()), (2, 3, 1));
        // The request's problem shares the handle's Φ by identity.
        let req2 = dense.into_request();
        assert!(req.problem.shares_op(&req2.problem));
    }

    #[test]
    fn builder_infers_solver_from_engine_and_explicit_wins() {
        let phi = Arc::new(Mat::zeros(4, 8));
        let b = || JobSpec::builder(ProblemHandle::new(phi.clone()), vec![0.0; 4], 2);
        // Quantized engines → QNIHT Fixed at the builder's bit widths.
        let quant = b().engine(EngineKind::NativeQuant).bits(4, 8).build();
        assert_eq!(
            quant.solver,
            SolverKind::Qniht { bits_phi: 4, bits_y: 8, mode: RequantMode::Fixed }
        );
        let fpga = b().engine(EngineKind::FpgaModel).bits(2, 8).build();
        assert_eq!(fpga.solver.name(), "qniht");
        // Dense engines → NIHT.
        assert_eq!(b().engine(EngineKind::NativeDense).build().solver, SolverKind::Niht);
        // Explicit selection wins over inference.
        let explicit = b().engine(EngineKind::NativeDense).solver(SolverKind::Cosamp).build();
        assert_eq!(explicit.solver, SolverKind::Cosamp);
    }

    #[test]
    fn batch_key_identity() {
        let phi = Arc::new(Mat::zeros(2, 3));
        let spec = |phi: &Arc<Mat>| {
            JobSpec::builder(ProblemHandle::new(phi.clone()), vec![0.0; 2], 1).bits(2, 8).build()
        };
        let a = spec(&phi);
        let b = spec(&phi);
        assert_eq!(a.batch_key(), b.batch_key());
        let other = Arc::new(Mat::zeros(2, 3));
        let c = spec(&other);
        assert_ne!(a.batch_key(), c.batch_key());
        // Bit widths live in the solver key now.
        let mut d = spec(&phi);
        d.solver = SolverKind::qniht_fixed(4, 8);
        assert_ne!(a.batch_key(), d.batch_key());
        // Same everything but a different algorithm never batches.
        let e = JobSpec::builder(ProblemHandle::new(phi.clone()), vec![0.0; 2], 1)
            .engine(EngineKind::NativeDense)
            .build();
        let mut f = e.clone();
        f.solver = SolverKind::Cosamp;
        assert_ne!(e.batch_key(), f.batch_key());
        // Engine is still part of the key.
        let mut g = spec(&phi);
        g.engine = EngineKind::FpgaModel;
        assert_ne!(a.batch_key(), g.batch_key());
    }

    fn mri_op(r: usize) -> Arc<PartialFourierOp> {
        let mask = crate::mri::SamplingMask::generate(
            &crate::mri::MaskConfig::default(),
            r,
            1,
        )
        .unwrap();
        Arc::new(PartialFourierOp::new(mask))
    }

    #[test]
    fn partial_fourier_specs_validate_and_batch_by_op_and_bits() {
        let op = mri_op(16);
        let m = ProblemHandle::partial_fourier(op.clone()).m();
        let spec = |h: ProblemHandle| {
            JobSpec::builder(h, vec![0.0; m], 4)
                .engine(EngineKind::NativeDense)
                .solver(SolverKind::Niht)
                .build()
        };
        let f32_a = spec(ProblemHandle::partial_fourier(op.clone()));
        f32_a.validate().unwrap();
        let f32_b = spec(ProblemHandle::partial_fourier(op.clone()));
        assert_eq!(f32_a.batch_key(), f32_b.batch_key(), "shared op Arc batches");
        let q8 = spec(ProblemHandle::low_prec_fourier(op.clone(), 8));
        q8.validate().unwrap();
        assert_ne!(f32_a.batch_key(), q8.batch_key(), "bit width splits the key");
        let q2 = spec(ProblemHandle::low_prec_fourier(op.clone(), 2));
        assert_ne!(q8.batch_key(), q2.batch_key());
        // A different op instance (same parameters) never batches.
        let other = spec(ProblemHandle::partial_fourier(mri_op(16)));
        assert_ne!(f32_a.batch_key(), other.batch_key());
        // And a dense job never shares a key with a matrix-free one.
        let dense = JobSpec::builder(
            ProblemHandle::new(Arc::new(Mat::zeros(m, 256))),
            vec![0.0; m],
            4,
        )
        .engine(EngineKind::NativeDense)
        .build();
        assert_ne!(dense.batch_key(), f32_a.batch_key());
    }

    #[test]
    fn partial_fourier_validation_rejects_wrong_surface() {
        let op = mri_op(16);
        let m = ProblemHandle::partial_fourier(op.clone()).m();
        let base = |h: ProblemHandle| JobSpec::builder(h, vec![0.0; m], 4);
        // Wrong solver: matrix-free runs NIHT only.
        let err = base(ProblemHandle::partial_fourier(op.clone()))
            .engine(EngineKind::NativeDense)
            .solver(SolverKind::Cosamp)
            .build()
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("matrix-free"), "{err}");
        // Wrong engine: quantized/XLA engines need an explicit matrix.
        let err = base(ProblemHandle::partial_fourier(op.clone()))
            .engine(EngineKind::NativeQuant)
            .solver(SolverKind::Niht)
            .build()
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("native-dense"), "{err}");
        // Non-packed MRI bit width.
        let mut bad_bits = base(ProblemHandle::low_prec_fourier(op.clone(), 8))
            .engine(EngineKind::NativeDense)
            .solver(SolverKind::Niht)
            .build();
        if let OperatorSpec::PartialFourier { bits, .. } = &mut bad_bits.problem.op {
            *bits = Some(3);
        }
        assert!(bad_bits.validate().unwrap_err().to_string().contains("packed widths"));
        // Observation length mismatch against the operator's m.
        let short = JobSpec::builder(
            ProblemHandle::partial_fourier(op.clone()),
            vec![0.0; m - 1],
            4,
        )
        .engine(EngineKind::NativeDense)
        .solver(SolverKind::Niht)
        .build();
        assert!(short.validate().unwrap_err().to_string().contains("y length"));
        // Invalid mask parameters surface at submit with a clear error.
        let bad_mask = crate::mri::SamplingMask::generate(
            &crate::mri::MaskConfig { fraction: 0.0, ..Default::default() },
            16,
            0,
        )
        .unwrap();
        let bad_op = Arc::new(PartialFourierOp::new(bad_mask));
        let bad_m = ProblemHandle::partial_fourier(bad_op.clone()).m();
        let err = JobSpec::builder(ProblemHandle::partial_fourier(bad_op), vec![0.0; bad_m], 4)
            .engine(EngineKind::NativeDense)
            .solver(SolverKind::Niht)
            .build()
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("fraction"), "{err}");
    }

    #[test]
    fn partial_fourier_spec_lowers_to_matrix_free_request() {
        let op = mri_op(16);
        let m = ProblemHandle::partial_fourier(op.clone()).m();
        let f32_spec = JobSpec::builder(ProblemHandle::partial_fourier(op.clone()), vec![0.5; m], 4)
            .engine(EngineKind::NativeDense)
            .solver(SolverKind::Niht)
            .seed(9)
            .build();
        let req = f32_spec.into_request();
        assert!(req.problem.as_mat().is_none(), "matrix-free problems expose no Mat");
        assert_eq!((req.problem.m(), req.problem.n()), (m, 256));
        // The quantized lowering perturbs y (stochastic Q_b) but keeps shape.
        let q_spec = JobSpec::builder(ProblemHandle::low_prec_fourier(op, 8), vec![0.5; m], 4)
            .engine(EngineKind::NativeDense)
            .solver(SolverKind::Niht)
            .seed(9)
            .build();
        let q_req = q_spec.into_request();
        assert_eq!(q_req.problem.m(), m);
        assert!(q_req.problem.as_mat().is_none());
    }

    fn vis_op(l: usize, r: usize) -> Arc<VisibilityOp> {
        let mut rng = crate::rng::XorShift128Plus::new(1);
        let a = crate::telescope::AntennaArray::lofar_like(l, 50e6, &mut rng);
        Arc::new(VisibilityOp::new(a, crate::telescope::ImageGrid::new(r, 0.4)))
    }

    #[test]
    fn visibility_specs_validate_and_batch_by_op_and_bits() {
        let op = vis_op(5, 8);
        let m = ProblemHandle::visibility(op.clone()).m();
        let spec = |h: ProblemHandle| {
            JobSpec::builder(h, vec![0.0; m], 4)
                .engine(EngineKind::NativeDense)
                .solver(SolverKind::Niht)
                .build()
        };
        let f32_a = spec(ProblemHandle::visibility(op.clone()));
        f32_a.validate().unwrap();
        let f32_b = spec(ProblemHandle::visibility(op.clone()));
        assert_eq!(f32_a.batch_key(), f32_b.batch_key(), "shared op Arc batches");
        let q8 = spec(ProblemHandle::low_prec_visibility(op.clone(), 8));
        q8.validate().unwrap();
        assert_ne!(f32_a.batch_key(), q8.batch_key(), "bit width splits the key");
        let q2 = spec(ProblemHandle::low_prec_visibility(op.clone(), 2));
        assert_ne!(q8.batch_key(), q2.batch_key());
        // A different op instance (same parameters) never batches.
        let other = spec(ProblemHandle::visibility(vis_op(5, 8)));
        assert_ne!(f32_a.batch_key(), other.batch_key());
        // Visibility keys never collide with partial-Fourier or dense ones.
        let mri = JobSpec::builder(
            ProblemHandle::partial_fourier(mri_op(16)),
            vec![0.0; ProblemHandle::partial_fourier(mri_op(16)).m()],
            4,
        )
        .engine(EngineKind::NativeDense)
        .solver(SolverKind::Niht)
        .build();
        assert_ne!(f32_a.batch_key(), mri.batch_key());
    }

    #[test]
    fn visibility_validation_rejects_wrong_surface() {
        let op = vis_op(5, 8);
        let m = ProblemHandle::visibility(op.clone()).m();
        let base = |h: ProblemHandle| JobSpec::builder(h, vec![0.0; m], 4);
        // Wrong solver: matrix-free runs NIHT only.
        let err = base(ProblemHandle::visibility(op.clone()))
            .engine(EngineKind::NativeDense)
            .solver(SolverKind::Cosamp)
            .build()
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("matrix-free visibility"), "{err}");
        // Wrong engine: quantized/XLA engines need an explicit matrix.
        let err = base(ProblemHandle::visibility(op.clone()))
            .engine(EngineKind::NativeQuant)
            .solver(SolverKind::Niht)
            .build()
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("native-dense"), "{err}");
        // Non-packed astro bit width.
        let mut bad_bits = base(ProblemHandle::low_prec_visibility(op.clone(), 8))
            .engine(EngineKind::NativeDense)
            .solver(SolverKind::Niht)
            .build();
        if let OperatorSpec::Visibility { bits, .. } = &mut bad_bits.problem.op {
            *bits = Some(3);
        }
        assert!(bad_bits.validate().unwrap_err().to_string().contains("packed widths"));
        // Observation length mismatch against the operator's m.
        let short = JobSpec::builder(ProblemHandle::visibility(op.clone()), vec![0.0; m - 1], 4)
            .engine(EngineKind::NativeDense)
            .solver(SolverKind::Niht)
            .build();
        assert!(short.validate().unwrap_err().to_string().contains("y length"));
        // An ill-formed station surfaces at submit with a clear error.
        let one = crate::telescope::AntennaArray { positions: vec![[0.0, 0.0]], freq_hz: 50e6 };
        let bad_op = Arc::new(VisibilityOp::new(one, crate::telescope::ImageGrid::new(8, 0.4)));
        let bad_m = ProblemHandle::visibility(bad_op.clone()).m();
        let err = JobSpec::builder(ProblemHandle::visibility(bad_op), vec![0.0; bad_m], 1)
            .engine(EngineKind::NativeDense)
            .solver(SolverKind::Niht)
            .build()
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("antennas"), "{err}");
    }

    #[test]
    fn visibility_spec_lowers_to_matrix_free_request() {
        let op = vis_op(5, 8);
        let m = ProblemHandle::visibility(op.clone()).m();
        let f32_spec = JobSpec::builder(ProblemHandle::visibility(op.clone()), vec![0.5; m], 4)
            .engine(EngineKind::NativeDense)
            .solver(SolverKind::Niht)
            .seed(9)
            .build();
        let req = f32_spec.into_request();
        assert!(req.problem.as_mat().is_none(), "matrix-free problems expose no Mat");
        assert_eq!((req.problem.m(), req.problem.n()), (m, 64));
        // The quantized lowering perturbs y (stochastic Q_b) but keeps shape.
        let q_spec = JobSpec::builder(ProblemHandle::low_prec_visibility(op, 8), vec![0.5; m], 4)
            .engine(EngineKind::NativeDense)
            .solver(SolverKind::Niht)
            .seed(9)
            .build();
        let q_req = q_spec.into_request();
        assert_eq!(q_req.problem.m(), m);
        assert!(q_req.problem.as_mat().is_none());
    }

    #[test]
    fn validate_rejects_malformed_specs() {
        let phi = Arc::new(Mat::zeros(4, 8));
        let ok = JobSpec::builder(ProblemHandle::new(phi.clone()), vec![0.0; 4], 2)
            .bits(2, 8)
            .build();
        ok.validate().unwrap();

        let mut wrong_y = ok.clone();
        wrong_y.y = vec![0.0; 3];
        assert!(wrong_y.validate().unwrap_err().to_string().contains("y length"));

        let mut zero_s = ok.clone();
        zero_s.s = 0;
        assert!(zero_s.validate().is_err());
        let mut fat_s = ok.clone();
        fat_s.s = 9;
        assert!(fat_s.validate().is_err());

        // Non-packed widths are rejected for quantized engines.
        for bad_bits in [0u8, 1, 3, 5, 6, 7, 16] {
            let spec = JobSpec::builder(ProblemHandle::new(phi.clone()), vec![0.0; 4], 2)
                .bits(bad_bits, 8)
                .build();
            let err = spec.validate().unwrap_err().to_string();
            assert!(err.contains("bits_phi"), "{bad_bits}: {err}");
        }
        let bad_y_bits = JobSpec::builder(ProblemHandle::new(phi.clone()), vec![0.0; 4], 2)
            .bits(2, 5)
            .build();
        assert!(bad_y_bits.validate().unwrap_err().to_string().contains("bits_y"));

        // Solver ↔ engine mismatches fail at submit, not inside the solve.
        let mismatch = JobSpec::builder(ProblemHandle::new(phi.clone()), vec![0.0; 4], 2)
            .engine(EngineKind::NativeQuant)
            .solver(SolverKind::Cosamp)
            .build();
        assert!(mismatch.validate().unwrap_err().to_string().contains("cannot run"));
        let fresh_on_xla = JobSpec::builder(ProblemHandle::new(phi.clone()), vec![0.0; 4], 2)
            .engine(EngineKind::XlaQuant)
            .solver(SolverKind::qniht_fresh(2, 8))
            .build();
        assert!(fresh_on_xla.validate().is_err());
    }
}
