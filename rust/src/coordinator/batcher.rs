//! Batching policy — a pure function from a job stream to batches, so the
//! invariants are property-testable without threads.
//!
//! Policy: a batch is a maximal run of consecutive jobs (FIFO order) that
//! share a [`BatchKey`], capped at `max_batch`. Consecutive-run batching
//! (rather than global grouping) preserves fairness: a job never overtakes
//! an earlier job with a different key.
//!
//! Since PR 3 the worker loop dispatches through the cost-aware scheduler
//! in [`super::sched`] instead; `form_batches` remains the strict-FIFO
//! reference policy (and the definition of the [`Batch`] unit both
//! policies emit).
//!
//! A formed batch is executed in one `EngineRegistry::solve_batch` call
//! (see [`crate::solver::registry`]): because every job in it shares Φ and
//! the quantization configuration, the quantized engine performs ONE
//! quantize+pack of Φ for the whole batch — that amortization is the
//! reason batches exist.

use super::job::{BatchKey, JobId, JobSpec};

/// A formed batch: the shared key + (id, spec) pairs.
#[derive(Debug)]
pub struct Batch {
    pub key: BatchKey,
    pub jobs: Vec<(JobId, JobSpec)>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }
}

/// Split a FIFO job list into batches (used by tests and by the worker loop
/// when it drains the queue).
pub fn form_batches(jobs: Vec<(JobId, JobSpec)>, max_batch: usize) -> Vec<Batch> {
    assert!(max_batch >= 1);
    let mut out: Vec<Batch> = Vec::new();
    for (id, spec) in jobs {
        let key = spec.batch_key();
        match out.last_mut() {
            Some(b) if b.key == key && b.len() < max_batch => b.jobs.push((id, spec)),
            _ => out.push(Batch { key, jobs: vec![(id, spec)] }),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;
    use crate::coordinator::job::ProblemHandle;
    use crate::linalg::Mat;
    use std::sync::Arc;

    fn spec(phi: &Arc<Mat>, bits: u8) -> JobSpec {
        JobSpec::builder(ProblemHandle::new(phi.clone()), vec![0.0; phi.rows], 2)
            .bits(bits, 8)
            .engine(EngineKind::NativeQuant)
            .build()
    }

    #[test]
    fn groups_consecutive_same_key() {
        let phi = Arc::new(Mat::zeros(4, 8));
        let jobs = vec![(1, spec(&phi, 2)), (2, spec(&phi, 2)), (3, spec(&phi, 2))];
        let b = form_batches(jobs, 8);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].len(), 3);
    }

    #[test]
    fn splits_on_key_change_and_preserves_order() {
        let phi = Arc::new(Mat::zeros(4, 8));
        let jobs = vec![(1, spec(&phi, 2)), (2, spec(&phi, 4)), (3, spec(&phi, 2))];
        let b = form_batches(jobs, 8);
        // 3 batches: key changes break runs even if an earlier key recurs.
        assert_eq!(b.len(), 3);
        let ids: Vec<JobId> = b.iter().flat_map(|b| b.jobs.iter().map(|(i, _)| *i)).collect();
        assert_eq!(ids, vec![1, 2, 3], "FIFO order preserved");
    }

    #[test]
    fn respects_max_batch() {
        let phi = Arc::new(Mat::zeros(4, 8));
        let jobs: Vec<_> = (0..10).map(|i| (i, spec(&phi, 2))).collect();
        let b = form_batches(jobs, 4);
        assert_eq!(b.iter().map(Batch::len).collect::<Vec<_>>(), vec![4, 4, 2]);
    }

    #[test]
    fn empty_input_empty_output() {
        assert!(form_batches(vec![], 4).is_empty());
    }
}
