//! Bounded MPMC queue with backpressure (Mutex + Condvar; no external
//! crates). FIFO per priority class, two classes (High ahead of Normal).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Priority {
    Normal,
    High,
}

#[derive(Debug)]
struct Inner<T> {
    high: VecDeque<T>,
    normal: VecDeque<T>,
    closed: bool,
}

impl<T> Inner<T> {
    fn len(&self) -> usize {
        self.high.len() + self.normal.len()
    }

    fn pop(&mut self) -> Option<T> {
        self.high.pop_front().or_else(|| self.normal.pop_front())
    }
}

/// Bounded two-priority FIFO queue.
#[derive(Debug)]
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    Full(T),
    Closed(T),
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        Self {
            inner: Mutex::new(Inner { high: VecDeque::new(), normal: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Non-blocking push; `Err(Full)` is the backpressure signal.
    pub fn try_push(&self, item: T, prio: Priority) -> Result<(), PushError<T>> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed(item));
        }
        if g.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        match prio {
            Priority::High => g.high.push_back(item),
            Priority::Normal => g.normal.push_back(item),
        }
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking push with timeout.
    pub fn push_timeout(&self, item: T, prio: Priority, timeout: Duration) -> Result<(), PushError<T>> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(PushError::Closed(item));
            }
            if g.len() < self.capacity {
                match prio {
                    Priority::High => g.high.push_back(item),
                    Priority::Normal => g.normal.push_back(item),
                }
                drop(g);
                self.not_empty.notify_one();
                return Ok(());
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(PushError::Full(item));
            }
            let (gg, _) = self.not_full.wait_timeout(g, deadline - now).unwrap();
            g = gg;
        }
    }

    /// Blocking pop with timeout; `None` on timeout or when closed+drained.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<T> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.pop() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (gg, _) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = gg;
        }
    }

    /// Drain up to `limit` additional items matching `pred` (the
    /// pre-PR-3 compatible-batch drain: caller already holds the batch
    /// leader). The service now snapshots windows via
    /// [`BoundedQueue::drain_upto`] and lets the scheduler group them;
    /// this remains the strict-FIFO reference drain, pinned by the queue
    /// unit and property tests.
    pub fn drain_matching(&self, limit: usize, pred: impl Fn(&T) -> bool) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let mut out = Vec::new();
        // Only take from the FRONT while it matches — preserves FIFO order
        // for non-matching jobs. High-priority queue first.
        while out.len() < limit && g.high.front().map(&pred).unwrap_or(false) {
            out.push(g.high.pop_front().unwrap());
        }
        while out.len() < limit && g.normal.front().map(&pred).unwrap_or(false) {
            out.push(g.normal.pop_front().unwrap());
        }
        if !out.is_empty() {
            drop(g);
            self.not_full.notify_all();
        }
        out
    }

    /// Pop up to `limit` items from the front regardless of contents
    /// (High before Normal, FIFO within each class) — the cost-aware
    /// scheduler's snapshot window.
    pub fn drain_upto(&self, limit: usize) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let mut out = Vec::new();
        while out.len() < limit {
            match g.pop() {
                Some(v) => out.push(v),
                None => break,
            }
        }
        if !out.is_empty() {
            drop(g);
            self.not_full.notify_all();
        }
        out
    }

    /// Return items to the FRONT of their priority class, preserving the
    /// given order (the scheduler's window give-back: a worker snapshots
    /// several jobs, executes one batch, and returns the rest so other
    /// workers can take them). Deliberately ignores capacity — the items
    /// came out of this queue moments ago, so the transient overshoot is
    /// bounded by the scheduling window, and refusing them would lose
    /// accepted jobs. Works after `close()` too: closed queues still
    /// drain.
    pub fn unpop(&self, items: Vec<T>, class: impl Fn(&T) -> Priority) {
        if items.is_empty() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        for item in items.into_iter().rev() {
            match class(&item) {
                Priority::High => g.high.push_front(item),
                Priority::Normal => g.normal.push_front(item),
            }
        }
        drop(g);
        self.not_empty.notify_all();
    }

    /// 0-based position of the first item matching `pred` in pop order
    /// (High class ahead of Normal), i.e. how many items a worker will
    /// take before it — the queue-position a subscribed client sees.
    /// `None` if no queued item matches (popped into a worker window or
    /// never queued).
    pub fn position_where(&self, pred: impl Fn(&T) -> bool) -> Option<usize> {
        let g = self.inner.lock().unwrap();
        g.high.iter().chain(g.normal.iter()).position(pred)
    }

    /// [`BoundedQueue::position_where`] plus the queue depth, read under
    /// ONE lock acquisition. Reading them in two calls lets a concurrent
    /// dispatch drain the queue in between, producing an impossible
    /// `position ≥ depth` pair; this snapshot guarantees
    /// `position < depth` whenever it returns `Some`.
    pub fn position_and_depth(&self, pred: impl Fn(&T) -> bool) -> Option<(usize, usize)> {
        let g = self.inner.lock().unwrap();
        g.high.iter().chain(g.normal.iter()).position(pred).map(|p| (p, g.len()))
    }

    /// Close: pushes fail, pops drain the remainder then return None.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_per_priority() {
        let q = BoundedQueue::new(10);
        q.try_push(1, Priority::Normal).unwrap();
        q.try_push(2, Priority::Normal).unwrap();
        q.try_push(99, Priority::High).unwrap();
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(99));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(1));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(2));
    }

    #[test]
    fn capacity_enforced() {
        let q = BoundedQueue::new(2);
        q.try_push(1, Priority::Normal).unwrap();
        q.try_push(2, Priority::Normal).unwrap();
        assert!(matches!(q.try_push(3, Priority::Normal), Err(PushError::Full(3))));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn pop_timeout_on_empty() {
        let q: BoundedQueue<i32> = BoundedQueue::new(1);
        let t0 = Instant::now();
        assert_eq!(q.pop_timeout(Duration::from_millis(20)), None);
        assert!(t0.elapsed() >= Duration::from_millis(18));
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(4);
        q.try_push(1, Priority::Normal).unwrap();
        q.close();
        assert!(matches!(q.try_push(2, Priority::Normal), Err(PushError::Closed(2))));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(1));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), None);
    }

    #[test]
    fn drain_matching_takes_prefix_only() {
        let q = BoundedQueue::new(10);
        for v in [2, 4, 5, 6] {
            q.try_push(v, Priority::Normal).unwrap();
        }
        // Front run of evens is [2, 4]; 5 blocks the drain even though 6
        // matches (FIFO preservation).
        let got = q.drain_matching(10, |v| v % 2 == 0);
        assert_eq!(got, vec![2, 4]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn drain_upto_pops_front_in_priority_order() {
        let q = BoundedQueue::new(10);
        q.try_push(1, Priority::Normal).unwrap();
        q.try_push(2, Priority::Normal).unwrap();
        q.try_push(99, Priority::High).unwrap();
        assert_eq!(q.drain_upto(2), vec![99, 1]);
        assert_eq!(q.drain_upto(5), vec![2]);
        assert!(q.drain_upto(5).is_empty());
    }

    #[test]
    fn unpop_returns_items_to_the_front_in_order() {
        let q = BoundedQueue::new(4);
        q.try_push(3, Priority::Normal).unwrap();
        q.try_push(90, Priority::High).unwrap();
        // Give back [91 (high), 1, 2 (normal)]: highs land ahead of 90?
        // No — unpop pushes to the FRONT of each class, so returned items
        // precede what is still queued, in their given order.
        q.unpop(vec![91, 1, 2], |v| if *v >= 90 { Priority::High } else { Priority::Normal });
        let mut got = vec![];
        while let Some(v) = q.pop_timeout(Duration::from_millis(1)) {
            got.push(v);
        }
        assert_eq!(got, vec![91, 90, 1, 2, 3]);
        // Unpop works on a closed queue (jobs must not be lost).
        q.close();
        q.unpop(vec![7], |_| Priority::Normal);
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(7));
    }

    #[test]
    fn drain_matching_respects_limit() {
        let q = BoundedQueue::new(10);
        for v in 0..6 {
            q.try_push(v, Priority::Normal).unwrap();
        }
        let got = q.drain_matching(3, |_| true);
        assert_eq!(got.len(), 3);
    }

    #[test]
    fn position_where_counts_across_priority_classes() {
        let q = BoundedQueue::new(10);
        q.try_push(1, Priority::Normal).unwrap();
        q.try_push(2, Priority::Normal).unwrap();
        q.try_push(99, Priority::High).unwrap();
        // Pop order is [99, 1, 2].
        assert_eq!(q.position_where(|v| *v == 99), Some(0));
        assert_eq!(q.position_where(|v| *v == 1), Some(1));
        assert_eq!(q.position_where(|v| *v == 2), Some(2));
        assert_eq!(q.position_where(|v| *v == 7), None);
        q.pop_timeout(Duration::from_millis(1)).unwrap();
        assert_eq!(q.position_where(|v| *v == 2), Some(1));
    }

    #[test]
    fn position_and_depth_snapshot_is_internally_consistent() {
        let q = BoundedQueue::new(10);
        q.try_push(1, Priority::Normal).unwrap();
        q.try_push(2, Priority::Normal).unwrap();
        assert_eq!(q.position_and_depth(|v| *v == 2), Some((1, 2)));
        assert_eq!(q.position_and_depth(|v| *v == 7), None);
        q.pop_timeout(Duration::from_millis(1)).unwrap();
        assert_eq!(q.position_and_depth(|v| *v == 2), Some((0, 1)));
    }

    /// Regression for the wire server's `QueuePos` race: hammer
    /// submit/drain from two threads while a watcher snapshots a tracked
    /// item's position — the one-lock snapshot must never report
    /// `position >= depth` (the two-call read could, whenever a drain
    /// landed between the calls).
    #[test]
    fn position_and_depth_invariant_holds_under_concurrent_submit_drain() {
        let q = Arc::new(BoundedQueue::new(64));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));

        let producer = {
            let (q, stop) = (q.clone(), stop.clone());
            std::thread::spawn(move || {
                let mut next = 1i64;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    // Item 0 is the tracked one; keep re-adding it among chaff.
                    let _ = q.try_push(0, Priority::Normal);
                    for _ in 0..8 {
                        let _ = q.try_push(next, Priority::Normal);
                        next += 1;
                    }
                }
            })
        };
        let drainer = {
            let (q, stop) = (q.clone(), stop.clone());
            std::thread::spawn(move || {
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let _ = q.drain_upto(5);
                }
            })
        };

        let t0 = Instant::now();
        let mut observed = 0u64;
        while t0.elapsed() < Duration::from_millis(200) {
            if let Some((pos, depth)) = q.position_and_depth(|v| *v == 0) {
                assert!(
                    pos < depth,
                    "snapshot reported position {pos} >= depth {depth}"
                );
                observed += 1;
            }
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        producer.join().unwrap();
        drainer.join().unwrap();
        assert!(observed > 0, "the watcher never saw the tracked item queued");
    }

    #[test]
    fn blocking_push_unblocks_on_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.try_push(1, Priority::Normal).unwrap();
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            q2.push_timeout(2, Priority::Normal, Duration::from_secs(2))
        });
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop_timeout(Duration::from_millis(100)), Some(1));
        assert!(h.join().unwrap().is_ok());
        assert_eq!(q.pop_timeout(Duration::from_millis(100)), Some(2));
    }

    #[test]
    fn concurrent_producers_consumers_conserve_items() {
        let q = Arc::new(BoundedQueue::new(8));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..50 {
                        q.push_timeout(p * 1000 + i, Priority::Normal, Duration::from_secs(5))
                            .unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = vec![];
                    while let Some(v) = q.pop_timeout(Duration::from_millis(300)) {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<i32> = consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort_unstable();
        let mut want: Vec<i32> =
            (0..4).flat_map(|p| (0..50).map(move |i| p * 1000 + i)).collect();
        want.sort_unstable();
        assert_eq!(all, want);
    }
}
