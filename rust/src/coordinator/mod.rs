//! Recovery service (S13) — the L3 coordination layer.
//!
//! A telescope station produces a stream of visibility snapshots that share
//! one measurement matrix Φ (the geometry is fixed while the grid/pointing
//! is). The service accepts recovery jobs (y, s, precision, engine), routes
//! them through a bounded queue with backpressure, groups jobs that share Φ
//! and configuration into batches (one quantization pass amortized over the
//! batch), and executes them on a worker pool. PJRT handles are not `Send`,
//! so each worker owns its own [`runtime::Runtime`]; compiled executables
//! are cached per worker.
//!
//! Components:
//! * [`queue`] — bounded MPMC queue (Mutex + Condvar) with try/timeout
//!   semantics and snapshot-window draining.
//! * [`job`] — job specs (with an explicit [`crate::solver::SolverKind`]
//!   selector, so every algorithm the facade wraps is servable), the
//!   state machine (Queued → Running → Done|Failed), submit-time
//!   validation, the store clients wait on, and per-job
//!   progress/cancellation flags.
//! * [`batcher`] — the strict-FIFO reference batching policy (and the
//!   [`batcher::Batch`] unit).
//! * [`sched`] — the cost-aware scheduler the workers dispatch through:
//!   a pure queue-snapshot → dispatch-order policy scoring batches by
//!   amortized setup + stream cost − age credit, under a starvation
//!   bound and a within-key fairness guarantee.
//! * [`service`] — worker pool wiring and metrics. Execution dispatch
//!   lives in the [`crate::solver`] engine registry (one per worker);
//!   batches go through `solve_batch`, which amortizes one quantize+pack
//!   of Φ across the batch.

pub mod batcher;
pub mod job;
pub mod queue;
pub mod sched;
pub mod service;

pub use job::{
    BatchKey, JobId, JobOutcome, JobSpec, JobSpecBuilder, JobState, OpKey, OperatorSpec,
    ProblemHandle, ProgressEvent, ProgressSub,
};
pub use queue::Priority;
pub use service::{RecoveryService, ServiceMetrics, SubmitError};
