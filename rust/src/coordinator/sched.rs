//! Cost-aware batch scheduling (PR 3) — a PURE function from a queue
//! snapshot to a dispatch order, replacing the worker's FIFO-run drain.
//! Like [`super::batcher`], the policy touches no clocks, threads or
//! queues, so every invariant is property-testable
//! (`tests/coordinator_props.rs` drives it through `testkit::forall`).
//!
//! Policy, in order:
//! 1. **Group** the snapshot by [`BatchKey`], preserving snapshot order
//!    within each key, and chunk each group into batches of at most
//!    `max_batch`. Unlike the consecutive-run reference policy, grouping
//!    is global over the window: interleaved key streams still amortize
//!    one quantize+pack per batch.
//! 2. **Score** each batch with the [`CostModel`]: one-time setup
//!    (quantize+pack of Φ) amortized over the batch size, plus the
//!    per-job iteration streaming cost, minus an age credit. Cheapest
//!    per-job score dispatches first.
//! 3. **Urgency**: a batch is urgent when it contains a High-priority
//!    job (the submit-level priority must never lose to a cheaper
//!    Normal batch) or a job that has waited at least `starvation_us`.
//!    Urgent batches — and, for fairness, every earlier batch of the
//!    same key — dispatch before all others, in snapshot order.
//! 4. **Fairness**: within a `BatchKey`, batches always dispatch in
//!    snapshot order, whatever the scores say — a job is never overtaken
//!    by a later job with its key.

use std::collections::{HashMap, VecDeque};
use std::path::Path;

use super::batcher::Batch;
use super::job::{BatchKey, JobId, JobSpec, OperatorSpec};
use crate::solver::SolverKind;

/// One queued job as the scheduler sees it. `age_us` is the time since
/// submission; the caller snapshots the clock once for the whole window,
/// keeping `schedule` itself clock-free. `high` carries the submit-level
/// [`super::queue::Priority`] so the cost order cannot invert it.
#[derive(Debug, Clone)]
pub struct QueuedJob {
    pub id: JobId,
    pub spec: JobSpec,
    pub age_us: u64,
    pub high: bool,
}

/// Measured per-key batch cost, EWMA-smoothed (microseconds). Also the
/// unit the warm-start cost file persists across restarts (see
/// [`save_cost_file`]).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ObservedCost {
    pub setup_us: f64,
    pub job_exec_us: f64,
    pub samples: u64,
}

impl ObservedCost {
    /// Fold one sample into the EWMA (first sample seeds the estimate).
    fn fold(&mut self, alpha: f64, setup_us: f64, job_exec_us: f64) {
        self.samples += 1;
        if self.samples == 1 {
            self.setup_us = setup_us;
            self.job_exec_us = job_exec_us;
        } else {
            let a = alpha.clamp(f64::EPSILON, 1.0);
            self.setup_us += a * (setup_us - self.setup_us);
            self.job_exec_us += a * (job_exec_us - self.job_exec_us);
        }
    }
}

/// Restart-survivable identity of a job's cost class. [`BatchKey`] keys
/// the live EWMA but embeds `Arc` pointers, which change every process;
/// this hashes what those pointers stand for — operator shape and kind
/// (dense vs partial-Fourier and its sampling bit width, plus any AOT
/// shape tag), sparsity, engine, and the full solver configuration — so
/// a calibration persisted at shutdown can warm-start the next boot.
pub fn stable_cost_key(spec: &JobSpec) -> u64 {
    let op = match &spec.problem.op {
        OperatorSpec::Dense(_) => "dense".to_string(),
        OperatorSpec::PartialFourier { bits, .. } => format!("pf:{bits:?}"),
    };
    let line = format!(
        "{}x{} {} tag={} s={} {} {:?}",
        spec.problem.m(),
        spec.problem.n(),
        op,
        spec.problem.shape_tag.as_deref().unwrap_or("-"),
        spec.s,
        spec.engine.name(),
        spec.solver,
    );
    crate::wire::fnv64(line.as_bytes())
}

/// Pure cost model in abstract work units (bytes of operand traffic).
/// Only relative magnitudes matter: the scheduler compares scores, it
/// never converts them to seconds.
///
/// With `calibrate` on, the model additionally learns from the service's
/// recorded timings: [`CostModel::observe`] feeds each executed batch's
/// measured quantize+pack setup and per-job execution time (the same
/// samples the [`crate::obsv`] histograms record) into a per-[`BatchKey`]
/// EWMA, and `setup_cost`/`job_cost` answer from the calibrated estimate
/// — in real microseconds — once a key has samples, falling back to the
/// static nominal-iteration estimate for keys never seen. `calibrate`
/// defaults off (`Default` is the frozen, deterministic static model;
/// the service enables it per `ServiceConfig::calibrate_cost`).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Work to quantize+pack one entry of Φ (batch setup; dense engines
    /// pay none). Charged once per batch, amortized over its size.
    pub setup_per_entry: f64,
    /// Iterations a typical job runs — scales the per-iteration stream
    /// cost into a per-job cost.
    pub nominal_iters: f64,
    /// Work-unit credit per microsecond of age: aging jobs pull their
    /// batch forward even before the starvation bound trips.
    pub age_credit_per_us: f64,
    /// Fraction of a quantized dense job's per-iteration cost that is the
    /// 2/4/8-bit field unpack of packed Φ words (vs the arithmetic against
    /// the right-hand side). The engine's lockstep batched path decodes
    /// each row ONCE per batch through the multi-RHS kernels, so this
    /// share is paid per batch, not per job — bigger batches get cheaper
    /// per job beyond the setup amortization. 0 disables the effect;
    /// [`crate::perfmodel::cpu::measure_decode_fraction`] calibrates it
    /// from the live kernels.
    pub decode_fraction: f64,
    /// Learn per-key costs from [`CostModel::observe`] samples. Off =
    /// the model is frozen: observations are discarded and every
    /// estimate is the static one (what deterministic tests want).
    pub calibrate: bool,
    /// EWMA smoothing factor for observations in `(0, 1]`: weight of the
    /// newest sample. 1.0 = always trust the latest measurement.
    pub ewma_alpha: f64,
    observed: HashMap<BatchKey, ObservedCost>,
    /// Warm-start ledger keyed by [`stable_cost_key`]: seeded from the
    /// persisted cost file on boot, updated alongside `observed` by
    /// [`CostModel::observe_job`], consulted when a key has no live
    /// samples yet. Empty unless the service persists calibration.
    warm: HashMap<u64, ObservedCost>,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            setup_per_entry: 2.0,
            nominal_iters: 64.0,
            age_credit_per_us: 1.0,
            decode_fraction: 0.3,
            calibrate: false,
            ewma_alpha: 0.3,
            observed: HashMap::new(),
            warm: HashMap::new(),
        }
    }
}

impl CostModel {
    /// The calibrating variant of the default model (what the service
    /// workers run unless `service.calibrate_cost=false`).
    pub fn calibrating() -> Self {
        Self { calibrate: true, ..Self::default() }
    }

    /// Feed one executed batch's measured costs: `setup_us` is the batch
    /// quantize+pack setup (solve start → first iteration), `job_exec_us`
    /// the mean per-job execution time inside that batch. EWMA-smoothed
    /// per key; a no-op when the model is frozen. Non-finite or negative
    /// samples are discarded (a clock hiccup must not poison the model).
    pub fn observe(&mut self, key: &BatchKey, setup_us: f64, job_exec_us: f64) {
        if !self.calibrate
            || !setup_us.is_finite()
            || !job_exec_us.is_finite()
            || setup_us < 0.0
            || job_exec_us < 0.0
        {
            return;
        }
        let a = self.ewma_alpha;
        self.observed.entry(*key).or_default().fold(a, setup_us, job_exec_us);
    }

    /// [`CostModel::observe`] plus the restart-survivable ledger: the
    /// same sample also folds into the warm entry under
    /// [`stable_cost_key`], which [`CostModel::export_warm`] /
    /// [`save_cost_file`] persist across restarts. This is what the
    /// service workers call per executed batch.
    pub fn observe_job(&mut self, spec: &JobSpec, setup_us: f64, job_exec_us: f64) {
        self.observe_keyed(&spec.batch_key(), stable_cost_key(spec), setup_us, job_exec_us);
    }

    /// [`CostModel::observe_job`] with both keys precomputed (callers
    /// that consumed the spec before the timings were final).
    pub fn observe_keyed(&mut self, key: &BatchKey, stable: u64, setup_us: f64, job_exec_us: f64) {
        self.observe(key, setup_us, job_exec_us);
        if !self.calibrate
            || !setup_us.is_finite()
            || !job_exec_us.is_finite()
            || setup_us < 0.0
            || job_exec_us < 0.0
        {
            return;
        }
        let a = self.ewma_alpha;
        self.warm.entry(stable).or_default().fold(a, setup_us, job_exec_us);
    }

    /// Warm-start the model from a persisted calibration (see
    /// [`load_cost_file`]). Warm entries answer `setup_cost`/`job_cost`
    /// for cost classes with no live observations yet; the live EWMA
    /// takes over per [`BatchKey`] as batches execute.
    pub fn seed_warm(&mut self, warm: HashMap<u64, ObservedCost>) {
        self.warm = warm;
    }

    /// The restart-survivable ledger accumulated by
    /// [`CostModel::observe_job`] (plus whatever seeded it).
    pub fn export_warm(&self) -> &HashMap<u64, ObservedCost> {
        &self.warm
    }

    /// The warm estimate for a spec's cost class, if the persisted
    /// ledger holds one.
    fn warm_cost(&self, spec: &JobSpec) -> Option<(f64, f64)> {
        self.warm
            .get(&stable_cost_key(spec))
            .filter(|o| o.samples > 0)
            .map(|o| (o.setup_us, o.job_exec_us))
    }

    /// The calibrated `(setup_us, job_exec_us)` estimate for a key, if
    /// any observations have been folded in.
    pub fn observed_cost(&self, key: &BatchKey) -> Option<(f64, f64)> {
        self.observed
            .get(key)
            .filter(|o| o.samples > 0)
            .map(|o| (o.setup_us, o.job_exec_us))
    }
    /// Bits of Φ streamed per entry per iteration: the quantized width
    /// for QNIHT jobs, f32 for the dense algorithms.
    fn stream_bits(spec: &JobSpec) -> f64 {
        match spec.solver {
            SolverKind::Qniht { bits_phi, .. } => bits_phi as f64,
            _ => 32.0,
        }
    }

    /// One-time batch setup: the quantize+pack pass over Φ that the
    /// batched engine path amortizes (see `NativeQuantEngine::solve_batch`).
    /// Matrix-free operators have no entries to quantize — zero setup
    /// (they are also only servable on the dense engine).
    pub fn setup_cost(&self, spec: &JobSpec) -> f64 {
        if self.calibrate {
            if let Some((setup_us, _)) = self.observed_cost(&spec.batch_key()) {
                return setup_us;
            }
            if let Some((setup_us, _)) = self.warm_cost(spec) {
                return setup_us;
            }
        }
        match spec.problem.as_dense() {
            Some(phi) if spec.engine.is_quantized() => {
                self.setup_per_entry * (phi.rows * phi.cols) as f64
            }
            _ => 0.0,
        }
    }

    /// Per-job cost: operand bytes streamed per iteration × nominal
    /// iteration count. Dense operators stream the full `m × n` matrix at
    /// the solver's bit width; matrix-free partial-Fourier jobs stream
    /// `O(n log n)` butterfly traffic plus the `m` measurements in f32 —
    /// that asymptotic gap is exactly why the scheduler must not price
    /// them like dense jobs of the same shape.
    pub fn job_cost(&self, spec: &JobSpec) -> f64 {
        if self.calibrate {
            if let Some((_, job_exec_us)) = self.observed_cost(&spec.batch_key()) {
                return job_exec_us;
            }
            if let Some((_, job_exec_us)) = self.warm_cost(spec) {
                return job_exec_us;
            }
        }
        let (m, n) = (spec.problem.m() as f64, spec.problem.n() as f64);
        match spec.problem.as_dense() {
            Some(_) => m * n * Self::stream_bits(spec) / 8.0 * self.nominal_iters,
            // ~2 transforms per iteration, 4-byte complex-split lanes.
            None => (2.0 * n * n.log2().max(1.0) + m) * 4.0 * self.nominal_iters,
        }
    }

    /// [`Self::job_cost`] as seen from inside a batch of `len` jobs:
    /// quantized dense jobs pay the packed-Φ decode share once per batch
    /// (the engine's multi-RHS lockstep path), so their effective per-job
    /// iteration cost shrinks with batch size. Dense-engine and
    /// matrix-free jobs have no packed decode and price batch-size
    /// independent.
    pub fn job_cost_in_batch(&self, spec: &JobSpec, len: usize) -> f64 {
        let base = self.job_cost(spec);
        let amortizes = spec.engine.is_quantized() && spec.problem.as_dense().is_some();
        // len <= 1: a singleton pays the full decode — return `base`
        // itself so the exact-equality invariant (`c1 == job_cost`) holds
        // by construction, not by float rounding of (1−d)+d/1.
        if !amortizes || len <= 1 {
            return base;
        }
        let d = self.decode_fraction.clamp(0.0, 1.0);
        base * (1.0 - d + d / len as f64)
    }

    /// Amortized per-job score of a (key-homogeneous) batch; lower
    /// dispatches first. Bigger batches amortize setup AND the packed
    /// decode better, lower precision streams fewer bytes, older jobs
    /// earn credit.
    pub fn batch_score(&self, jobs: &[&QueuedJob]) -> f64 {
        let lead = &jobs[0].spec;
        let max_age = jobs.iter().map(|j| j.age_us).max().unwrap_or(0);
        self.setup_cost(lead) / jobs.len() as f64 + self.job_cost_in_batch(lead, jobs.len())
            - self.age_credit_per_us * max_age as f64
    }
}

/// First line of the persisted cost file; anything else is a corrupt
/// (or future-versioned) file and loads as a cold start.
pub const COST_FILE_HEADER: &str = "lpcs-cost-model v1";

/// Merge `from` into `into`, weighting each cost class by its sample
/// count — how workers fold their private ledgers into the service
/// vault at shutdown without one idle worker diluting a busy one.
pub fn merge_warm(into: &mut HashMap<u64, ObservedCost>, from: &HashMap<u64, ObservedCost>) {
    for (k, f) in from {
        if f.samples == 0 {
            continue;
        }
        let e = into.entry(*k).or_default();
        let total = e.samples + f.samples;
        let wf = f.samples as f64 / total as f64;
        e.setup_us += wf * (f.setup_us - e.setup_us);
        e.job_exec_us += wf * (f.job_exec_us - e.job_exec_us);
        e.samples = total;
    }
}

/// Write the warm ledger as the small versioned text file the service
/// reloads on boot: the header line, then one
/// `<key_hex16> <setup_us> <exec_us> <samples>` row per cost class,
/// key-sorted so the file is deterministic.
pub fn save_cost_file(path: &Path, warm: &HashMap<u64, ObservedCost>) -> std::io::Result<()> {
    let mut rows: Vec<(&u64, &ObservedCost)> =
        warm.iter().filter(|(_, o)| o.samples > 0).collect();
    rows.sort_by_key(|(k, _)| **k);
    let mut out = String::from(COST_FILE_HEADER);
    out.push('\n');
    for (k, o) in rows {
        out.push_str(&format!("{k:016x} {} {} {}\n", o.setup_us, o.job_exec_us, o.samples));
    }
    std::fs::write(path, out)
}

/// Corrupt-tolerant loader: any structural problem — unreadable file,
/// wrong header, short row, unparsable or non-finite field — is an
/// `Err` the service maps to a cold start (counted in its metrics,
/// never a panic).
pub fn load_cost_file(path: &Path) -> Result<HashMap<u64, ObservedCost>, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    let mut lines = text.lines();
    match lines.next() {
        Some(h) if h == COST_FILE_HEADER => {}
        other => return Err(format!("bad cost-file header: {other:?}")),
    }
    let mut warm = HashMap::new();
    for (i, line) in lines.enumerate() {
        let row = i + 2; // 1-based, after the header
        if line.trim().is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split_whitespace().collect();
        if f.len() != 4 {
            return Err(format!("row {row}: expected 4 fields, got {}", f.len()));
        }
        let key =
            u64::from_str_radix(f[0], 16).map_err(|e| format!("row {row}: key: {e}"))?;
        let setup_us: f64 = f[1].parse().map_err(|e| format!("row {row}: setup: {e}"))?;
        let job_exec_us: f64 = f[2].parse().map_err(|e| format!("row {row}: exec: {e}"))?;
        let samples: u64 = f[3].parse().map_err(|e| format!("row {row}: samples: {e}"))?;
        if !setup_us.is_finite() || !job_exec_us.is_finite() || setup_us < 0.0 || job_exec_us < 0.0
        {
            return Err(format!("row {row}: non-finite or negative cost"));
        }
        warm.insert(key, ObservedCost { setup_us, job_exec_us, samples });
    }
    Ok(warm)
}

/// Scheduler knobs (the service derives them from
/// [`crate::config::ServiceConfig`]).
#[derive(Debug, Clone, Copy)]
pub struct SchedConfig {
    pub max_batch: usize,
    /// Age (µs) at which a job's batch becomes overdue and jumps the
    /// cost order.
    pub starvation_us: u64,
}

impl Default for SchedConfig {
    fn default() -> Self {
        Self { max_batch: 8, starvation_us: 250_000 }
    }
}

/// A scored batch candidate during scheduling.
struct Chunk {
    key: BatchKey,
    /// Snapshot index of the chunk's first (oldest-position) job.
    min_index: usize,
    jobs: Vec<(usize, QueuedJob)>,
    score: f64,
    /// High-priority member or starvation bound tripped: jumps the cost
    /// order.
    urgent: bool,
}

/// The policy: snapshot → batches in dispatch order. Every job appears
/// in exactly one batch; batches are key-homogeneous and at most
/// `max_batch` long; the ordering invariants are documented above and
/// pinned by `tests/coordinator_props.rs`.
pub fn schedule(snapshot: Vec<QueuedJob>, cfg: &SchedConfig, cost: &CostModel) -> Vec<Batch> {
    assert!(cfg.max_batch >= 1);

    // 1. Group by key (first-seen order), preserving snapshot order
    //    within each group.
    let mut groups: Vec<(BatchKey, Vec<(usize, QueuedJob)>)> = Vec::new();
    for (idx, job) in snapshot.into_iter().enumerate() {
        let key = job.spec.batch_key();
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, g)) => g.push((idx, job)),
            None => groups.push((key, vec![(idx, job)])),
        }
    }

    // 2. Chunk + score. Urgency is promoted backwards within a key: if
    //    chunk k is urgent, every earlier chunk of that key must
    //    dispatch before it anyway (fairness), so they are urgent too —
    //    keeping each key's urgent set a prefix of its chunks.
    let mut urgent: Vec<Chunk> = Vec::new();
    let mut rest: Vec<Chunk> = Vec::new();
    for (key, mut jobs) in groups {
        let mut key_chunks: Vec<Chunk> = Vec::new();
        while !jobs.is_empty() {
            let tail = jobs.split_off(jobs.len().min(cfg.max_batch));
            let chunk_jobs = std::mem::replace(&mut jobs, tail);
            let refs: Vec<&QueuedJob> = chunk_jobs.iter().map(|(_, j)| j).collect();
            key_chunks.push(Chunk {
                key,
                min_index: chunk_jobs[0].0,
                score: cost.batch_score(&refs),
                urgent: chunk_jobs
                    .iter()
                    .any(|(_, j)| j.high || j.age_us >= cfg.starvation_us),
                jobs: chunk_jobs,
            });
        }
        if let Some(last) = key_chunks.iter().rposition(|c| c.urgent) {
            for c in &mut key_chunks[..last] {
                c.urgent = true;
            }
        }
        for c in key_chunks {
            if c.urgent {
                urgent.push(c);
            } else {
                rest.push(c);
            }
        }
    }

    // 3. Urgent batches first, in snapshot order (within a key this IS
    //    chunk order, so no fairness fix-up is needed here; High jobs
    //    occupy the snapshot prefix because the queue pops them first,
    //    so this order also respects submit priority).
    urgent.sort_by_key(|c| c.min_index);

    // 4. The remainder dispatches cheapest-first (ties broken by snapshot
    //    position — fully deterministic)...
    rest.sort_by(|a, b| a.score.total_cmp(&b.score).then(a.min_index.cmp(&b.min_index)));
    // ...with a fairness fix-up: same-key chunks keep snapshot order by
    // reassigning each key's chunks, oldest-first, to the positions the
    // cost order gave that key.
    let key_seq: Vec<BatchKey> = rest.iter().map(|c| c.key).collect();
    let mut queues: Vec<(BatchKey, VecDeque<Chunk>)> = Vec::new();
    for c in rest {
        match queues.iter_mut().find(|(k, _)| *k == c.key) {
            Some((_, q)) => q.push_back(c),
            None => queues.push((c.key, VecDeque::from([c]))),
        }
    }
    for (_, q) in &mut queues {
        q.make_contiguous().sort_by_key(|c| c.min_index);
    }

    let ordered = urgent.into_iter().chain(key_seq.into_iter().map(|key| {
        let (_, q) = queues.iter_mut().find(|(k, _)| *k == key).expect("key was enqueued");
        q.pop_front().expect("one chunk per key occurrence")
    }));
    ordered
        .map(|c| Batch {
            key: c.key,
            jobs: c.jobs.into_iter().map(|(_, j)| (j.id, j.spec)).collect(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;
    use crate::coordinator::job::ProblemHandle;
    use crate::linalg::Mat;
    use std::sync::Arc;

    fn job(id: JobId, phi: &Arc<Mat>, bits: u8, age_us: u64) -> QueuedJob {
        let spec = JobSpec::builder(ProblemHandle::new(phi.clone()), vec![0.0; phi.rows], 2)
            .bits(bits, 8)
            .engine(EngineKind::NativeQuant)
            .seed(id)
            .build();
        QueuedJob { id, spec, age_us, high: false }
    }

    fn ids(batches: &[Batch]) -> Vec<Vec<JobId>> {
        batches.iter().map(|b| b.jobs.iter().map(|(i, _)| *i).collect()).collect()
    }

    #[test]
    fn groups_interleaved_keys_globally() {
        let phi = Arc::new(Mat::zeros(4, 8));
        // 2-bit and 8-bit jobs interleaved: the FIFO-run policy would form
        // four singleton batches; global grouping forms two pairs.
        let snapshot =
            vec![job(0, &phi, 2, 0), job(1, &phi, 8, 0), job(2, &phi, 2, 0), job(3, &phi, 8, 0)];
        let batches = schedule(snapshot, &SchedConfig::default(), &CostModel::default());
        assert_eq!(batches.len(), 2);
        // 2-bit streams fewer bytes per iteration → cheaper → first.
        assert_eq!(ids(&batches), vec![vec![0, 2], vec![1, 3]]);
    }

    #[test]
    fn bigger_batches_amortize_and_dispatch_first() {
        let phi_a = Arc::new(Mat::zeros(4, 8));
        let phi_b = Arc::new(Mat::zeros(4, 8));
        let cm = CostModel { age_credit_per_us: 0.0, ..CostModel::default() };
        // Same precision and ages; the keys differ only by Φ identity.
        // The pair amortizes its quantize+pack over two jobs, so it
        // scores cheaper than the singleton that arrived first.
        let snapshot = vec![job(0, &phi_b, 4, 0), job(1, &phi_a, 4, 0), job(2, &phi_a, 4, 0)];
        let batches = schedule(snapshot, &SchedConfig::default(), &cm);
        assert_eq!(ids(&batches), vec![vec![1, 2], vec![0]]);
    }

    #[test]
    fn starvation_bound_jumps_the_cost_order() {
        let phi = Arc::new(Mat::zeros(4, 8));
        let cfg = SchedConfig { max_batch: 8, starvation_us: 1_000_000 };
        // The 8-bit job is ancient; the cheap young 2-bit jobs must wait.
        let snapshot =
            vec![job(0, &phi, 8, 2_000_000), job(1, &phi, 2, 0), job(2, &phi, 2, 0)];
        let batches = schedule(snapshot, &cfg, &CostModel::default());
        assert_eq!(ids(&batches), vec![vec![0], vec![1, 2]]);
    }

    #[test]
    fn high_priority_jumps_the_cost_order() {
        let phi = Arc::new(Mat::zeros(4, 8));
        // An expensive young 8-bit HIGH job must not lose to the cheaper
        // Normal 2-bit job behind it in the snapshot.
        let mut snapshot = vec![job(0, &phi, 8, 0), job(1, &phi, 2, 0)];
        snapshot[0].high = true;
        let batches = schedule(snapshot, &SchedConfig::default(), &CostModel::default());
        assert_eq!(ids(&batches), vec![vec![0], vec![1]]);
    }

    #[test]
    fn within_key_snapshot_order_is_never_inverted() {
        let phi = Arc::new(Mat::zeros(4, 8));
        let cfg = SchedConfig { max_batch: 2, starvation_us: u64::MAX };
        // Adversarial ages: the LATER chunk of the key holds the oldest
        // job, so raw scores would dispatch it first. Fairness wins.
        let snapshot = vec![
            job(0, &phi, 2, 0),
            job(1, &phi, 2, 0),
            job(2, &phi, 2, 900_000),
            job(3, &phi, 2, 900_000),
        ];
        let batches = schedule(snapshot, &cfg, &CostModel::default());
        assert_eq!(ids(&batches), vec![vec![0, 1], vec![2, 3]]);
    }

    #[test]
    fn matrix_free_jobs_price_fft_traffic_not_dense_shape() {
        use crate::mri::{MaskConfig, PartialFourierOp, SamplingMask};
        use crate::solver::SolverKind;
        let cm = CostModel::default();
        let mask = SamplingMask::generate(&MaskConfig::default(), 32, 1).unwrap();
        let op = Arc::new(PartialFourierOp::new(mask));
        let h = ProblemHandle::partial_fourier(op);
        let m = h.m();
        let pf = JobSpec::builder(h, vec![0.0; m], 2)
            .engine(EngineKind::NativeDense)
            .solver(SolverKind::Niht)
            .build();
        let dense = JobSpec::builder(
            ProblemHandle::new(Arc::new(Mat::zeros(m, 1024))),
            vec![0.0; m],
            2,
        )
        .engine(EngineKind::NativeDense)
        .build();
        assert_eq!(cm.setup_cost(&pf), 0.0, "nothing to quantize+pack");
        assert!(
            cm.job_cost(&pf) < cm.job_cost(&dense) / 10.0,
            "FFT traffic must undercut the same-shape dense matvec: {} vs {}",
            cm.job_cost(&pf),
            cm.job_cost(&dense)
        );
    }

    #[test]
    fn multi_rhs_decode_amortizes_quantized_batches_only() {
        let phi = Arc::new(Mat::zeros(4, 8));
        let cm = CostModel::default();
        let quant = job(0, &phi, 4, 0).spec;
        // Quantized dense jobs get cheaper per job as the batch grows
        // (decode once per batch), converging to the non-decode share.
        let c1 = cm.job_cost_in_batch(&quant, 1);
        let c4 = cm.job_cost_in_batch(&quant, 4);
        let c8 = cm.job_cost_in_batch(&quant, 8);
        assert_eq!(c1, cm.job_cost(&quant), "singleton pays the full decode");
        assert!(c4 < c1 && c8 < c4, "decode amortizes with batch size: {c1} {c4} {c8}");
        assert!(c8 > cm.job_cost(&quant) * (1.0 - cm.decode_fraction));
        // Dense-engine jobs have no packed decode: batch-size independent.
        let dense = JobSpec::builder(
            ProblemHandle::new(phi.clone()),
            vec![0.0; phi.rows],
            2,
        )
        .engine(EngineKind::NativeDense)
        .solver(crate::solver::SolverKind::Niht)
        .build();
        assert_eq!(cm.job_cost_in_batch(&dense, 8), cm.job_cost(&dense));
        // Zeroing the fraction disables the effect entirely.
        let flat = CostModel { decode_fraction: 0.0, ..CostModel::default() };
        assert_eq!(flat.job_cost_in_batch(&quant, 8), flat.job_cost(&quant));
    }

    #[test]
    fn empty_snapshot_schedules_nothing() {
        assert!(schedule(vec![], &SchedConfig::default(), &CostModel::default()).is_empty());
    }

    /// Property: over many randomized noisy timing streams, the
    /// calibrated estimate converges to the measured mean — within the
    /// noise band — and always stays inside the observed sample range.
    #[test]
    fn calibrated_costs_converge_to_measured_timings() {
        use crate::rng::XorShift128Plus;
        let phi = Arc::new(Mat::zeros(4, 8));
        let spec = job(0, &phi, 4, 0).spec;
        let key = spec.batch_key();
        for case in 0..50u64 {
            let mut rng = XorShift128Plus::new(0xC0_57 ^ case);
            let true_setup = 500.0 + (rng.next_u64() % 20_000) as f64;
            let true_exec = 100.0 + (rng.next_u64() % 5_000) as f64;
            let mut cm = CostModel::calibrating();
            let (mut lo_s, mut hi_s) = (f64::MAX, f64::MIN);
            for _ in 0..200 {
                // ±10% multiplicative noise around the true cost.
                let mut noise = || 0.9 + 0.2 * (rng.next_u64() % 1000) as f64 / 1000.0;
                let s = true_setup * noise();
                let e = true_exec * noise();
                lo_s = lo_s.min(s);
                hi_s = hi_s.max(s);
                cm.observe(&key, s, e);
            }
            let got_setup = cm.setup_cost(&spec);
            let got_exec = cm.job_cost(&spec);
            assert!(
                (got_setup - true_setup).abs() <= 0.15 * true_setup,
                "case {case}: setup {got_setup} vs true {true_setup}"
            );
            assert!(
                (got_exec - true_exec).abs() <= 0.15 * true_exec,
                "case {case}: exec {got_exec} vs true {true_exec}"
            );
            // EWMA of samples can never leave the samples' convex hull.
            assert!(got_setup >= lo_s && got_setup <= hi_s);
        }
    }

    #[test]
    fn frozen_model_ignores_observations_and_matches_static_estimates() {
        let phi = Arc::new(Mat::zeros(4, 8));
        let spec = job(0, &phi, 4, 0).spec;
        let key = spec.batch_key();
        let static_model = CostModel::default();
        let mut frozen = CostModel::default();
        assert!(!frozen.calibrate, "Default must be the frozen static model");
        frozen.observe(&key, 1.0, 1.0);
        assert_eq!(frozen.observed_cost(&key), None, "frozen: observations discarded");
        assert_eq!(frozen.setup_cost(&spec), static_model.setup_cost(&spec));
        assert_eq!(frozen.job_cost(&spec), static_model.job_cost(&spec));
    }

    #[test]
    fn calibration_is_per_key_and_falls_back_statically_for_unseen_keys() {
        let phi = Arc::new(Mat::zeros(4, 8));
        let seen = job(0, &phi, 4, 0).spec;
        let unseen = job(1, &phi, 2, 0).spec; // different bits → different key
        let mut cm = CostModel::calibrating();
        cm.observe(&seen.batch_key(), 777.0, 333.0);
        assert_eq!(cm.setup_cost(&seen), 777.0);
        assert_eq!(cm.job_cost(&seen), 333.0);
        // The 2-bit key has no samples: static estimate, as if frozen.
        let static_model = CostModel::default();
        assert_eq!(cm.job_cost(&unseen), static_model.job_cost(&unseen));
        assert_eq!(cm.setup_cost(&unseen), static_model.setup_cost(&unseen));
        // Garbage samples are discarded.
        cm.observe(&seen.batch_key(), f64::NAN, 1.0);
        cm.observe(&seen.batch_key(), -5.0, 1.0);
        assert_eq!(cm.setup_cost(&seen), 777.0);
        // The batch amortization law still applies on the calibrated base.
        assert!(cm.job_cost_in_batch(&seen, 8) < cm.job_cost(&seen));
    }

    #[test]
    fn stable_cost_key_survives_operator_identity_but_not_configuration() {
        // Same shape/config, different Arc: the BatchKeys differ (pointer
        // identity) but the stable keys — what the persisted file uses —
        // must match, or a restart could never warm-start anything.
        let phi_a = Arc::new(Mat::zeros(4, 8));
        let phi_b = Arc::new(Mat::zeros(4, 8));
        let a = job(0, &phi_a, 4, 0).spec;
        let b = job(1, &phi_b, 4, 0).spec;
        assert_ne!(a.batch_key(), b.batch_key());
        assert_eq!(stable_cost_key(&a), stable_cost_key(&b));
        // Anything that changes the executed math changes the key.
        let other_bits = job(2, &phi_a, 2, 0).spec;
        assert_ne!(stable_cost_key(&a), stable_cost_key(&other_bits));
        let other_shape =
            job(3, &Arc::new(Mat::zeros(8, 8)), 4, 0).spec;
        assert_ne!(stable_cost_key(&a), stable_cost_key(&other_shape));
    }

    #[test]
    fn warm_ledger_round_trips_through_the_cost_file() {
        let phi = Arc::new(Mat::zeros(4, 8));
        let spec = job(0, &phi, 4, 0).spec;
        let mut cm = CostModel::calibrating();
        cm.observe_job(&spec, 900.0, 450.0);
        cm.observe_job(&spec, 900.0, 450.0);

        let path = std::env::temp_dir()
            .join(format!("lpcs-cost-roundtrip-{}.v1", std::process::id()));
        save_cost_file(&path, cm.export_warm()).unwrap();
        let loaded = load_cost_file(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(&loaded, cm.export_warm());

        // A fresh process: new Arc (new BatchKey), no live samples — the
        // warm ledger answers, exactly.
        let phi2 = Arc::new(Mat::zeros(4, 8));
        let rebooted = job(1, &phi2, 4, 0).spec;
        let mut next = CostModel::calibrating();
        next.seed_warm(loaded);
        assert_eq!(next.setup_cost(&rebooted), 900.0);
        assert_eq!(next.job_cost(&rebooted), 450.0);
        // Live observations take over per key once batches execute.
        next.observe(&rebooted.batch_key(), 100.0, 50.0);
        assert_eq!(next.setup_cost(&rebooted), 100.0);
        // A frozen model ignores the warm ledger like everything else.
        let mut frozen = CostModel::default();
        frozen.seed_warm(next.export_warm().clone());
        assert_eq!(frozen.setup_cost(&rebooted), CostModel::default().setup_cost(&rebooted));
    }

    #[test]
    fn corrupt_cost_files_load_as_errors_never_panics() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("lpcs-cost-corrupt-{}.v1", std::process::id()));
        for (case, text) in [
            ("empty", ""),
            ("wrong header", "lpcs-cost-model v9\n"),
            ("short row", "lpcs-cost-model v1\ndeadbeef 1.0 2.0\n"),
            ("non-hex key", "lpcs-cost-model v1\nzz 1.0 2.0 3\n"),
            ("nan cost", "lpcs-cost-model v1\n00000000000000aa NaN 2.0 3\n"),
            ("negative cost", "lpcs-cost-model v1\n00000000000000aa -1.0 2.0 3\n"),
            ("binary junk", "\u{0}\u{1}\u{2}\n"),
        ] {
            std::fs::write(&path, text).unwrap();
            assert!(load_cost_file(&path).is_err(), "case {case:?} must be rejected");
        }
        std::fs::remove_file(&path).ok();
        assert!(load_cost_file(&path).is_err(), "missing file is an error, not a panic");
    }

    #[test]
    fn merge_warm_weights_by_sample_count() {
        let mut into = HashMap::from([(
            7u64,
            ObservedCost { setup_us: 100.0, job_exec_us: 10.0, samples: 3 },
        )]);
        let from = HashMap::from([
            (7u64, ObservedCost { setup_us: 200.0, job_exec_us: 30.0, samples: 1 }),
            (9u64, ObservedCost { setup_us: 50.0, job_exec_us: 5.0, samples: 2 }),
            (11u64, ObservedCost::default()), // zero samples: ignored
        ]);
        merge_warm(&mut into, &from);
        let e = into[&7];
        assert_eq!(e.samples, 4);
        assert!((e.setup_us - 125.0).abs() < 1e-9, "3:1 weighting: {}", e.setup_us);
        assert!((e.job_exec_us - 15.0).abs() < 1e-9);
        assert_eq!(into[&9].samples, 2, "new classes copy over");
        assert!(!into.contains_key(&11));
    }
}
