//! The recovery service: router + worker pool + metrics.
//!
//! Specs are validated at submit time ([`JobSpec::validate`]); accepted
//! jobs flow through the bounded queue to the workers. Each worker
//! snapshots a window of queued jobs and hands it to the pure cost-aware
//! scheduler ([`super::sched::schedule`]), which partitions it into
//! key-homogeneous batches and orders them cheapest-first under an
//! urgency bound (submit priority and the starvation limit). The worker
//! executes only the head batch and returns the rest to the queue front,
//! so heterogeneous windows spread across the pool instead of
//! serializing behind one worker.
//!
//! Execution dispatch lives in the [`crate::solver`] engine registry —
//! each worker thread owns an [`EngineRegistry`] (so XLA runtime caches
//! and batch quantizations persist per worker) and submits whole batches
//! through [`EngineRegistry::solve_batch`], which amortizes one
//! quantize+pack of Φ over every batch-key-equal job. A per-batch
//! [`BatchObserver`] streams iteration progress into the [`JobStore`] and
//! polls for cancellation, so clients can watch and stop running jobs.

use super::job::{JobId, JobOutcome, JobSpec, JobState, JobStore};
use super::queue::{BoundedQueue, Priority, PushError};
use super::sched::{self, CostModel, ObservedCost, QueuedJob, SchedConfig};
use crate::algorithms::{IterStat, ObserverSignal, SolveOptions};
use crate::config::ServiceConfig;
use crate::obsv::{JobLabels, Outcome, ServiceCounters, ServiceObsv, TraceId};
use crate::solver::{BatchObserver, EngineRegistry, SolveRequest, SolverKind};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Atomic counters exported by the service.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    /// Specs that failed [`JobSpec::validate`] at submit time (no job id
    /// is allocated; not counted in `submitted`/`rejected`).
    pub invalid: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    /// Jobs that finished after a cancellation request (their partial
    /// iterate is still delivered; counted in `completed` too).
    pub cancelled: AtomicU64,
    pub batches: AtomicU64,
    /// Sum of batch sizes (mean batch size = batched_jobs / batches).
    pub batched_jobs: AtomicU64,
    /// Total solve wall time, microseconds.
    pub solve_us: AtomicU64,
    /// Modeled device time accrued by performance-model engines
    /// (`fpga-model`), microseconds.
    pub modeled_us: AtomicU64,
    /// Progress stats discarded by drop-oldest overflow on bounded
    /// subscriber queues (slow consumers shed load here instead of
    /// stalling workers).
    pub progress_dropped: AtomicU64,
    /// Wire subscribers whose connection died mid-stream (the server
    /// dropped the subscription; the job itself kept running).
    pub disconnects: AtomicU64,
    /// EWMA of per-job execution time (µs), fed by every executed batch.
    /// This is what [`RecoveryService::retry_after_ms`] scales by queue
    /// depth to derive the backpressure retry hint; 0 = no samples yet.
    pub exec_ewma_us: AtomicU64,
    /// Persisted cost-model files that failed to load at boot (corrupt
    /// or unreadable ⇒ cold start, counted here, never a panic).
    pub cost_load_errors: AtomicU64,
}

impl ServiceMetrics {
    /// The counters at one instant, as the structured snapshot every
    /// face plumbs ([`crate::obsv::MetricsSnapshot`]). `queue_depth` is
    /// left `None`; the wire server fills it in from its atomic
    /// queue-lock snapshot.
    pub fn snapshot_struct(&self) -> ServiceCounters {
        ServiceCounters {
            submitted: self.submitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            invalid: self.invalid.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_jobs: self.batched_jobs.load(Ordering::Relaxed),
            solve_us: self.solve_us.load(Ordering::Relaxed),
            modeled_us: self.modeled_us.load(Ordering::Relaxed),
            progress_dropped: self.progress_dropped.load(Ordering::Relaxed),
            disconnects: self.disconnects.load(Ordering::Relaxed),
            // Process-wide kernel-pool lock contention (crate::par), not a
            // per-service counter: the worker pool is shared.
            pool_contention: crate::par::contention_count(),
            queue_depth: None,
        }
    }

    /// The legacy one-line text form (byte-compatible with the
    /// pre-structured renderer; see [`ServiceCounters::render_legacy`]).
    pub fn snapshot(&self) -> String {
        self.snapshot_struct().render_legacy()
    }
}

/// Histogram labels for a job: solver × engine × Φ's stored bit width
/// (32 for the full-precision baselines).
fn labels_of(spec: &JobSpec) -> JobLabels {
    let bits = match spec.solver {
        SolverKind::Qniht { bits_phi, .. } => bits_phi,
        _ => 32,
    };
    JobLabels { solver: spec.solver.name(), engine: spec.engine.name(), bits }
}

/// Why a submission was refused, as a typed value — the wire server
/// maps these onto the protocol's [`crate::wire::ErrCode`]s so routers
/// and clients can react by category instead of parsing strings.
#[derive(Debug)]
pub enum SubmitError {
    /// The spec failed [`JobSpec::validate`]; no job id was allocated.
    Invalid(anyhow::Error),
    /// Backpressure: the bounded queue is full (a job id was allocated
    /// and immediately failed in the store so `wait` still resolves).
    QueueFull,
    /// The service is shutting down.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Invalid(e) => write!(f, "invalid job spec: {e:#}"),
            Self::QueueFull => write!(f, "queue full"),
            Self::Closed => write!(f, "service closed"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// What flows through the queue: the job plus its submit priority (the
/// scheduler must see the priority so the cost order cannot invert it).
type QueueItem = (JobId, JobSpec, Priority);

/// Handle to a running service.
pub struct RecoveryService {
    queue: Arc<BoundedQueue<QueueItem>>,
    store: Arc<JobStore>,
    metrics: Arc<ServiceMetrics>,
    obsv: Arc<ServiceObsv>,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
    solver: SolveOptions,
    /// Where graceful shutdown persists the calibrated cost model
    /// (`None` unless `service.persist_cost` is on).
    cost_path: Option<PathBuf>,
    /// Shared warm-cost vault: seeded from the persisted file at boot,
    /// workers merge their private ledgers in as they exit, shutdown
    /// writes it back out.
    cost_vault: Arc<Mutex<HashMap<u64, ObservedCost>>>,
}

impl RecoveryService {
    /// Start the worker pool.
    pub fn start(cfg: ServiceConfig, solver: SolveOptions, artifact_dir: PathBuf) -> Self {
        let queue = Arc::new(BoundedQueue::new(cfg.queue_capacity));
        let store = Arc::new(JobStore::new());
        let metrics = Arc::new(ServiceMetrics::default());
        let obsv = Arc::new(ServiceObsv::new());
        obsv.workers_total.set(cfg.workers as i64);
        let cost_path = cfg.persist_cost.then(|| artifact_dir.join("cost_model.v1"));
        let warm: HashMap<u64, ObservedCost> = match &cost_path {
            Some(p) if p.exists() => match sched::load_cost_file(p) {
                Ok(m) => m,
                Err(_) => {
                    // Corrupt file ⇒ cold start, counted, never a panic.
                    metrics.cost_load_errors.fetch_add(1, Ordering::Relaxed);
                    HashMap::new()
                }
            },
            _ => HashMap::new(),
        };
        let cost_vault = Arc::new(Mutex::new(warm.clone()));
        let workers = (0..cfg.workers)
            .map(|w| {
                let queue = queue.clone();
                let store = store.clone();
                let metrics = metrics.clone();
                let obsv = obsv.clone();
                let solver = solver.clone();
                let artifact_dir = artifact_dir.clone();
                let warm = warm.clone();
                let vault = cost_vault.clone();
                std::thread::Builder::new()
                    .name(format!("lpcs-worker-{w}"))
                    .spawn(move || {
                        worker_loop(
                            cfg, queue, store, metrics, obsv, solver, artifact_dir, warm, vault,
                        )
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            queue,
            store,
            metrics,
            obsv,
            workers,
            next_id: AtomicU64::new(1),
            solver,
            cost_path,
            cost_vault,
        }
    }

    pub fn solver_options(&self) -> &SolveOptions {
        &self.solver
    }

    /// Submit a job; `Err` is either an invalid spec (rejected before a
    /// job id is allocated) or the backpressure signal (queue full).
    pub fn submit(&self, spec: JobSpec) -> Result<JobId> {
        self.submit_prio(spec, Priority::Normal)
    }

    pub fn submit_prio(&self, spec: JobSpec, prio: Priority) -> Result<JobId> {
        self.try_submit(spec, prio).map_err(|e| anyhow!("{e}"))
    }

    /// [`RecoveryService::submit_prio`] with the refusal category kept
    /// typed (validation vs. backpressure vs. shutdown).
    pub fn try_submit(
        &self,
        mut spec: JobSpec,
        prio: Priority,
    ) -> std::result::Result<JobId, SubmitError> {
        if let Err(e) = spec.validate() {
            self.metrics.invalid.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::Invalid(e));
        }
        // This is the first submit face for in-process callers: untraced
        // specs get their fleet trace id here (wire submits arrive with
        // one already minted by the client or server face).
        if spec.trace == 0 {
            spec.trace = TraceId::mint_submit(&spec.y, spec.s).0;
        }
        let labels = labels_of(&spec);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.store.insert_queued(id, spec.trace);
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        // Admitted from the store's point of view; terminal recording
        // (worker side or the rejection below) balances the gauge.
        self.obsv.inflight.add(1);
        match self.queue.try_push((id, spec, prio), prio) {
            Ok(()) => Ok(id),
            Err(PushError::Full((_, spec, _))) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                self.obsv.on_terminal(
                    labels,
                    Outcome::RejectedFull,
                    None,
                    0,
                    TraceId(spec.trace),
                );
                self.store.fail(id, "rejected: queue full (backpressure)".into());
                Err(SubmitError::QueueFull)
            }
            Err(PushError::Closed(_)) => {
                self.obsv.inflight.add(-1);
                self.store.fail(id, "rejected: service shutting down".into());
                Err(SubmitError::Closed)
            }
        }
    }

    /// The fleet trace id minted (or carried) for a submitted job, 0 for
    /// unknown ids — what `lpcs watch`/`trace` correlate against the
    /// e2e histogram exemplars.
    pub fn trace_of(&self, id: JobId) -> u64 {
        self.store.trace_of(id)
    }

    /// Backpressure retry hint: observed per-job execution EWMA scaled
    /// by the current queue depth and divided across workers. `None`
    /// until the first batch has executed. The wire server attaches this
    /// to `QueueFull` `Err` frames so clients can back off intelligently
    /// instead of hammering a saturated node.
    pub fn retry_after_ms(&self) -> Option<u64> {
        let ewma = self.metrics.exec_ewma_us.load(Ordering::Relaxed);
        if ewma == 0 {
            return None;
        }
        let depth = self.queue_depth() as u64;
        let workers = self.workers.len().max(1) as u64;
        Some((ewma.saturating_mul(depth + 1) / workers / 1000).max(1))
    }

    /// Block until a job finishes.
    pub fn wait(&self, id: JobId, timeout: Duration) -> Option<JobOutcome> {
        self.store.wait(id, timeout)
    }

    /// Latest per-iteration stat streamed by the job's solve (None until
    /// the first iteration completes).
    pub fn progress(&self, id: JobId) -> Option<IterStat> {
        self.store.progress(id)
    }

    /// Push-based progress stream for a job: a bounded queue of `depth`
    /// stats with drop-oldest overflow, ending in exactly one terminal
    /// event (see [`super::job::ProgressSub`]). A slow consumer can never
    /// stall the worker — it just observes gaps. `None` for unknown ids.
    /// This is what the wire server bridges `Subscribe` frames onto.
    pub fn subscribe(&self, id: JobId, depth: usize) -> Option<Arc<super::job::ProgressSub>> {
        self.store.subscribe(id, depth)
    }

    /// Ask a job to stop at its next iteration boundary. The job still
    /// completes (with its partial iterate); returns false if it is
    /// unknown or already terminal.
    pub fn cancel(&self, id: JobId) -> bool {
        self.store.request_cancel(id)
    }

    /// Current lifecycle state of a job (`None` for unknown ids).
    pub fn state_of(&self, id: JobId) -> Option<JobState> {
        self.store.state(id)
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn queue_capacity(&self) -> usize {
        self.queue.capacity()
    }

    /// Worker threads serving the queue.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// 0-based position of a still-queued job in pop order (how many
    /// jobs a worker will take before it), `None` once a worker has
    /// pulled it into a scheduling window or for unknown ids. This is
    /// what the wire server pushes as `QueuePos` to subscribers.
    pub fn queue_position(&self, id: JobId) -> Option<usize> {
        self.queue.position_where(|(qid, _, _)| *qid == id)
    }

    /// Atomic `(position, depth)` snapshot for a queued job, taken under
    /// ONE queue lock so `position < depth` always holds — the invariant
    /// the wire `QueuePos` frame promises its subscribers.
    pub fn queue_position_and_depth(&self, id: JobId) -> Option<(usize, usize)> {
        self.queue.position_and_depth(|(qid, _, _)| *qid == id)
    }

    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }

    /// The observability registry: latency histograms and saturation
    /// gauges (see [`crate::obsv`]).
    pub fn obsv(&self) -> &ServiceObsv {
        &self.obsv
    }

    /// Prometheus text exposition for this service — what the wire
    /// `ScrapeReq` frame returns and `lpcs scrape ADDR` prints.
    pub fn scrape(&self) -> String {
        self.obsv.render_prometheus(
            &self.metrics.snapshot_struct(),
            self.queue_depth() as u64,
            self.queue_capacity() as u64,
        )
    }

    /// Drain and stop; joins all workers, then persists the calibrated
    /// cost model (when `service.persist_cost` is on) so the next boot
    /// schedules from observed costs instead of the static estimate.
    pub fn shutdown(self) {
        self.queue.close();
        for w in self.workers {
            w.join().expect("worker panicked");
        }
        if let Some(path) = &self.cost_path {
            let vault = self.cost_vault.lock().expect("cost vault poisoned");
            // Persistence is best-effort: an unwritable artifact dir must
            // not turn a clean shutdown into a failure.
            let _ = sched::save_cost_file(path, &vault);
        }
    }
}

/// Streams per-job progress into the store and relays cancellation
/// requests back into the running solves. Also owns the Queued → Running
/// transition: a batch executes its jobs sequentially, so each job is
/// marked Running when ITS solve first reports an iteration — not when
/// the batch starts — keeping queued_for/ran_for honest for trailing
/// batch members.
struct ServiceObserver<'a> {
    store: &'a JobStore,
    metrics: &'a ServiceMetrics,
    obsv: &'a ServiceObsv,
    /// Batches are key-homogeneous, so one label set covers every job.
    labels: JobLabels,
    ids: &'a [JobId],
    started: Vec<bool>,
    /// When the worker called `solve_batch` — the first observed
    /// iteration stamps the quantize+pack setup latency against it.
    solve_start: Instant,
    setup_us: Option<u64>,
}

impl BatchObserver for ServiceObserver<'_> {
    fn on_iteration(&mut self, job_index: usize, stat: &IterStat) -> ObserverSignal {
        let id = self.ids[job_index];
        if self.setup_us.is_none() {
            let us = self.solve_start.elapsed().as_micros() as u64;
            self.setup_us = Some(us);
            self.obsv.on_setup(self.labels, us);
        }
        if !self.started[job_index] {
            if let Some(wait) = self.store.transition(id, JobState::Running) {
                self.obsv.on_running(self.labels, wait.as_micros() as u64);
            }
            self.started[job_index] = true;
        }
        let dropped = self.store.record_progress(id, *stat);
        if dropped > 0 {
            self.metrics.progress_dropped.fetch_add(dropped, Ordering::Relaxed);
        }
        if self.store.cancel_requested(id) {
            ObserverSignal::Stop
        } else {
            ObserverSignal::Continue
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    cfg: ServiceConfig,
    queue: Arc<BoundedQueue<QueueItem>>,
    store: Arc<JobStore>,
    metrics: Arc<ServiceMetrics>,
    obsv: Arc<ServiceObsv>,
    solver: SolveOptions,
    artifact_dir: PathBuf,
    warm: HashMap<u64, ObservedCost>,
    vault: Arc<Mutex<HashMap<u64, ObservedCost>>>,
) {
    // All execution dispatch lives behind the engine registry. It is
    // per-worker because PJRT handles are not Send: each worker's XLA
    // engines own their runtime + compiled-executable cache.
    let mut registry = EngineRegistry::with_defaults(artifact_dir);
    // Per-worker cost model: when calibration is on, each executed batch
    // feeds its measured setup/exec timings back in (EWMA per BatchKey),
    // so scheduling decisions track this worker's real hardware instead
    // of the static nominal-iteration estimate. The warm ledger seeds it
    // with the previous boot's calibration (empty unless persisting).
    let seeded = warm.clone();
    let mut cost = CostModel::default();
    cost.calibrate = cfg.calibrate_cost;
    cost.seed_warm(warm);
    let sched_cfg = SchedConfig {
        // Clamp: callers constructing ServiceConfig literally (benches,
        // tests) may pass 0; the old loop tolerated it as "singletons".
        max_batch: cfg.max_batch.max(1),
        starvation_us: cfg.starvation_ms.saturating_mul(1000),
    };
    loop {
        let Some(lead) = queue.pop_timeout(Duration::from_millis(50)) else {
            if queue.is_closed() {
                // Fold this worker's live observations into the shared
                // vault for shutdown to persist (skip the no-op merge:
                // an idle worker has nothing beyond its seed).
                if cost.export_warm() != &seeded {
                    let mut v = vault.lock().expect("cost vault poisoned");
                    sched::merge_warm(&mut v, cost.export_warm());
                }
                return;
            }
            continue;
        };
        // Small wait lets closely-spaced submissions coalesce.
        if cfg.max_batch > 1 && queue.is_empty() && cfg.max_wait_ms > 0 {
            std::thread::sleep(Duration::from_millis(cfg.max_wait_ms));
        }
        // Snapshot a scheduling window and hand it to the pure policy:
        // batches come back key-homogeneous, cheapest-first under the
        // urgency (priority/starvation) bound, FIFO within each key.
        let window = cfg.sched_window.max(sched_cfg.max_batch);
        let mut items = vec![lead];
        items.extend(queue.drain_upto(window - 1));
        let index_of: std::collections::HashMap<JobId, usize> =
            items.iter().enumerate().map(|(i, (id, _, _))| (*id, i)).collect();
        let prio_of: std::collections::HashMap<JobId, Priority> =
            items.iter().map(|(id, _, p)| (*id, *p)).collect();
        let snapshot: Vec<QueuedJob> = items
            .into_iter()
            .map(|(id, spec, prio)| QueuedJob {
                id,
                spec,
                age_us: store.queued_age_us(id),
                high: prio == Priority::High,
            })
            .collect();
        let mut batches = sched::schedule(snapshot, &sched_cfg, &cost);
        if batches.is_empty() {
            continue;
        }
        // Execute only the HEAD of the dispatch order and give the rest
        // back to the queue front (original order, original classes):
        // other workers pick them up instead of idling behind this one,
        // and the next snapshot re-scores them with their grown ages.
        let head = batches.remove(0);
        let mut rest: Vec<(JobId, JobSpec)> =
            batches.into_iter().flat_map(|b| b.jobs).collect();
        rest.sort_by_key(|(id, _)| index_of[id]);
        let give_back: Vec<QueueItem> =
            rest.into_iter().map(|(id, spec)| (id, spec, prio_of[&id])).collect();
        queue.unpop(give_back, |(_, _, p)| *p);
        obsv.workers_busy.add(1);
        run_batch(head, &mut registry, &store, &metrics, &obsv, &mut cost, &solver);
        obsv.workers_busy.add(-1);
    }
}

/// Execution/end-to-end latencies for a job about to go terminal, read
/// from the store's stamps so they are final BEFORE `complete`/`fail`
/// unblocks waiters (a waiter that immediately scrapes sees its job).
fn job_times(store: &JobStore, id: JobId) -> (Option<u64>, u64) {
    let now = Instant::now();
    match store.stamps(id) {
        Some((submitted, started)) => (
            started.map(|s| now.duration_since(s).as_micros() as u64),
            now.duration_since(submitted).as_micros() as u64,
        ),
        None => (None, 0),
    }
}

/// Execute one scheduled batch on this worker's registry, stream results
/// into the store and keep the counters honest.
fn run_batch(
    batch: super::batcher::Batch,
    registry: &mut EngineRegistry,
    store: &JobStore,
    metrics: &ServiceMetrics,
    obsv: &ServiceObsv,
    cost: &mut CostModel,
    solver: &SolveOptions,
) {
    let key = batch.key;
    let engine_name = key.engine.name();
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.batched_jobs.fetch_add(batch.len() as u64, Ordering::Relaxed);

    let t0 = Instant::now();
    let modeled_before = registry.metrics(engine_name).map(|m| m.modeled_time_us).unwrap_or(0);
    let ids: Vec<JobId> = batch.jobs.iter().map(|(id, _)| *id).collect();
    let (labels, stable_key) = match batch.jobs.first() {
        Some((_, spec)) => (labels_of(spec), sched::stable_cost_key(spec)),
        None => return,
    };
    let reqs: Vec<SolveRequest> =
        batch.jobs.into_iter().map(|(_, spec)| spec.into_request()).collect();
    let mut observer = ServiceObserver {
        store,
        metrics,
        obsv,
        labels,
        ids: &ids,
        started: vec![false; ids.len()],
        solve_start: t0,
        setup_us: None,
    };
    match registry.solve_batch(engine_name, &reqs, solver, &mut observer) {
        Ok(results) => {
            for (&id, result) in ids.iter().zip(results) {
                // Jobs that terminated before their first observer
                // callback (validation errors, engine rejections,
                // max_iters = 0) are still Queued; the state machine
                // requires passing through Running.
                if store.state(id) == Some(JobState::Queued) {
                    if let Some(wait) = store.transition(id, JobState::Running) {
                        obsv.on_running(labels, wait.as_micros() as u64);
                    }
                }
                // Count before completing: `wait` returns as soon as
                // the store transitions, so the counter — and the
                // histogram samples — must already be visible then.
                let (exec_us, e2e_us) = job_times(store, id);
                let trace = TraceId(store.trace_of(id));
                match result {
                    Ok(res) => {
                        let outcome = if store.cancel_requested(id) {
                            metrics.cancelled.fetch_add(1, Ordering::Relaxed);
                            Outcome::Cancelled
                        } else {
                            Outcome::Ok
                        };
                        metrics.completed.fetch_add(1, Ordering::Relaxed);
                        obsv.on_terminal(labels, outcome, exec_us, e2e_us, trace);
                        store.complete(id, res);
                    }
                    Err(e) => {
                        metrics.failed.fetch_add(1, Ordering::Relaxed);
                        obsv.on_terminal(labels, Outcome::Failed, exec_us, e2e_us, trace);
                        store.fail(id, format!("{e:#}"));
                    }
                }
            }
        }
        Err(e) => {
            // Unknown engine: fail the whole batch.
            for &id in &ids {
                if store.state(id) == Some(JobState::Queued) {
                    if let Some(wait) = store.transition(id, JobState::Running) {
                        obsv.on_running(labels, wait.as_micros() as u64);
                    }
                }
                let (exec_us, e2e_us) = job_times(store, id);
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                obsv.on_terminal(
                    labels,
                    Outcome::Failed,
                    exec_us,
                    e2e_us,
                    TraceId(store.trace_of(id)),
                );
                store.fail(id, format!("{e:#}"));
            }
        }
    }
    let modeled_after = registry.metrics(engine_name).map(|m| m.modeled_time_us).unwrap_or(0);
    metrics
        .modeled_us
        .fetch_add(modeled_after.saturating_sub(modeled_before), Ordering::Relaxed);
    let wall_us = t0.elapsed().as_micros() as u64;
    metrics.solve_us.fetch_add(wall_us, Ordering::Relaxed);
    // Close the loop into the scheduler: feed the measured quantize+pack
    // setup and per-job execution time back into the cost model — both
    // the live per-BatchKey EWMA and the restart-survivable warm ledger
    // (no-op when calibration is frozen).
    let setup_us = observer.setup_us.unwrap_or(0);
    let per_job_us = wall_us.saturating_sub(setup_us) / ids.len().max(1) as u64;
    cost.observe_keyed(&key, stable_key, setup_us as f64, per_job_us as f64);
    // And into the backpressure hint: a coarse service-wide exec EWMA
    // (weight 1/8 on the newest sample) that retry_after_ms scales by
    // queue depth.
    let old = metrics.exec_ewma_us.load(Ordering::Relaxed);
    let new = if old == 0 { per_job_us } else { old - old / 8 + per_job_us / 8 };
    metrics.exec_ewma_us.store(new.max(1), Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;
    use crate::coordinator::job::ProblemHandle;
    use crate::linalg::Mat;
    use crate::rng::XorShift128Plus;
    use crate::solver::SolverKind;

    fn planted(m: usize, n: usize, s: usize, seed: u64) -> (Arc<Mat>, Vec<f32>, Vec<f32>) {
        let mut rng = XorShift128Plus::new(seed);
        let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
        let mut x = vec![0.0f32; n];
        for i in rng.choose_k(n, s) {
            x[i] = 2.0 * rng.gaussian_f32().signum();
        }
        let y = phi.matvec(&x);
        (Arc::new(phi), y, x)
    }

    fn svc(workers: usize) -> RecoveryService {
        RecoveryService::start(
            ServiceConfig {
                workers,
                queue_capacity: 64,
                max_batch: 4,
                max_wait_ms: 0,
                ..Default::default()
            },
            SolveOptions::default(),
            PathBuf::from("artifacts"),
        )
    }

    #[test]
    fn end_to_end_single_job() {
        let service = svc(1);
        let (phi, y, x_true) = planted(64, 128, 4, 1);
        let id = service
            .submit(JobSpec::builder(ProblemHandle::new(phi), y, 4).bits(8, 8).seed(1).build())
            .unwrap();
        let out = service.wait(id, Duration::from_secs(30)).expect("finishes");
        assert_eq!(out.state, JobState::Done);
        let x = out.result.unwrap().x;
        let err = crate::metrics::recovery_error(&x, &x_true);
        assert!(err < 0.05, "err={err}");
        service.shutdown();
    }

    #[test]
    fn many_jobs_share_matrix_and_batch() {
        let service = svc(2);
        let (phi, _, _) = planted(48, 96, 3, 2);
        let mut rng = XorShift128Plus::new(9);
        let ids: Vec<_> = (0..12)
            .map(|k| {
                let mut x = vec![0.0f32; 96];
                for i in rng.choose_k(96, 3) {
                    x[i] = 1.5;
                }
                let y = phi.matvec(&x);
                service
                    .submit(
                        JobSpec::builder(ProblemHandle::new(phi.clone()), y, 3)
                            .bits(8, 8)
                            .seed(k)
                            .build(),
                    )
                    .unwrap()
            })
            .collect();
        for id in ids {
            let out = service.wait(id, Duration::from_secs(60)).expect("finishes");
            assert_eq!(out.state, JobState::Done, "{:?}", out.error);
        }
        let m = service.metrics();
        assert_eq!(m.completed.load(Ordering::Relaxed), 12);
        service.shutdown();
    }

    #[test]
    fn mixed_solver_and_engine_stream_completes() {
        // A heterogeneous window: the scheduler must partition by key
        // (solver × engine × bits), dispatch every batch, and every job
        // must finish — including baselines and the fpga-model engine.
        let service = svc(2);
        let (phi, y, _) = planted(64, 128, 4, 8);
        let specs = [
            JobSpec::builder(ProblemHandle::new(phi.clone()), y.clone(), 4).bits(2, 8).build(),
            JobSpec::builder(ProblemHandle::new(phi.clone()), y.clone(), 4)
                .engine(EngineKind::NativeDense)
                .solver(SolverKind::Cosamp)
                .build(),
            JobSpec::builder(ProblemHandle::new(phi.clone()), y.clone(), 4)
                .engine(EngineKind::FpgaModel)
                .bits(4, 8)
                .build(),
            JobSpec::builder(ProblemHandle::new(phi.clone()), y.clone(), 4)
                .engine(EngineKind::NativeDense)
                .solver(SolverKind::Iht)
                .build(),
            JobSpec::builder(ProblemHandle::new(phi.clone()), y.clone(), 4).bits(2, 8).build(),
        ];
        let ids: Vec<_> = specs.into_iter().map(|s| service.submit(s).unwrap()).collect();
        for id in ids {
            let out = service.wait(id, Duration::from_secs(60)).expect("finishes");
            assert_eq!(out.state, JobState::Done, "{:?}", out.error);
        }
        assert!(
            service.metrics().modeled_us.load(Ordering::Relaxed) > 0,
            "the fpga-model job accrued modeled time into the service metrics"
        );
        service.shutdown();
    }

    #[test]
    fn invalid_specs_rejected_at_submit() {
        let service = svc(1);
        let (phi, y, _) = planted(16, 32, 2, 9);
        let ok = |phi: &Arc<crate::linalg::Mat>, y: &[f32]| {
            JobSpec::builder(ProblemHandle::new(phi.clone()), y.to_vec(), 2).bits(2, 8)
        };
        // Non-packed bit width on a quantized engine.
        let err = service.submit(ok(&phi, &y).bits(3, 8).build()).unwrap_err().to_string();
        assert!(err.contains("invalid job spec"), "{err}");
        // Zero sparsity.
        assert!(service
            .submit(JobSpec::builder(ProblemHandle::new(phi.clone()), y.clone(), 0).build())
            .is_err());
        // Observation length mismatch.
        assert!(service.submit(ok(&phi, &y[..15]).build()).is_err());
        // Solver incompatible with the engine.
        assert!(service
            .submit(ok(&phi, &y).solver(SolverKind::Cosamp).build())
            .is_err());
        let m = service.metrics();
        assert_eq!(m.invalid.load(Ordering::Relaxed), 4);
        assert_eq!(m.submitted.load(Ordering::Relaxed), 0, "no id was allocated");
        service.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // Tiny queue + zero workers processing slowly: fill it up.
        let service = RecoveryService::start(
            ServiceConfig {
                workers: 1,
                queue_capacity: 2,
                max_batch: 1,
                max_wait_ms: 0,
                ..Default::default()
            },
            SolveOptions { max_iters: 2000, ..Default::default() },
            PathBuf::from("artifacts"),
        );
        let (phi, y, _) = planted(128, 512, 8, 3);
        let spec = JobSpec::builder(ProblemHandle::new(phi), y, 8)
            .engine(EngineKind::NativeDense)
            .build();
        let mut rejected = 0;
        let mut ids = vec![];
        for _ in 0..40 {
            match service.submit(spec.clone()) {
                Ok(id) => ids.push(id),
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "queue of capacity 2 must reject a 40-job burst");
        for id in ids {
            service.wait(id, Duration::from_secs(120)).expect("accepted jobs finish");
        }
        let rej: u64 = service
            .obsv()
            .outcome_totals()
            .iter()
            .filter(|(_, o, _)| *o == Outcome::RejectedFull)
            .map(|(_, _, n)| *n)
            .sum();
        assert_eq!(rej, rejected as u64, "every backpressure reject is an outcome-labeled sample");
        service.shutdown();
    }

    #[test]
    fn observability_records_job_lifecycle() {
        let service = svc(1);
        let (phi, y, _) = planted(64, 128, 4, 21);
        let ids: Vec<_> = (0..3)
            .map(|k| {
                service
                    .submit(
                        JobSpec::builder(ProblemHandle::new(phi.clone()), y.clone(), 4)
                            .bits(8, 8)
                            .seed(k)
                            .build(),
                    )
                    .unwrap()
            })
            .collect();
        for id in ids {
            let out = service.wait(id, Duration::from_secs(60)).expect("finishes");
            assert_eq!(out.state, JobState::Done, "{:?}", out.error);
        }
        let obsv = service.obsv();
        let labels = JobLabels { solver: "qniht", engine: "native-quant", bits: 8 };
        let ok: u64 = obsv
            .outcome_totals()
            .iter()
            .filter(|(l, o, _)| *l == labels && *o == Outcome::Ok)
            .map(|(_, _, n)| *n)
            .sum();
        assert_eq!(ok, 3, "every completion is an ok-labeled e2e sample");
        assert_eq!(obsv.inflight.get(), 0, "terminal recording balanced the gauge");
        assert_eq!(obsv.queue_wait.get(labels, None).snapshot().count, 3);
        assert_eq!(obsv.exec.get(labels, None).snapshot().count, 3);
        let setup = obsv.setup.get(labels, None).snapshot();
        assert!(setup.count >= 1, "at least one batch recorded its setup");
        let e2e = obsv.e2e.get(labels, Some(Outcome::Ok)).snapshot();
        assert!(e2e.sum_us >= obsv.exec.get(labels, None).snapshot().sum_us,
            "end-to-end dominates execution");
        let text = service.scrape();
        for needle in [
            "# TYPE lpcs_job_e2e_us histogram",
            "lpcs_job_e2e_us_bucket{solver=\"qniht\",engine=\"native-quant\",bits=\"8\",outcome=\"ok\",le=\"+Inf\"} 3",
            "lpcs_jobs_total{solver=\"qniht\",engine=\"native-quant\",bits=\"8\",outcome=\"ok\"} 3",
            "lpcs_workers_total 1",
            "lpcs_inflight_jobs 0",
        ] {
            assert!(text.contains(needle), "scrape missing {needle:?}:\n{text}");
        }
        service.shutdown();
    }

    #[test]
    fn dense_engine_works() {
        let service = svc(1);
        let (phi, y, x_true) = planted(64, 128, 4, 4);
        let id = service
            .submit(
                JobSpec::builder(ProblemHandle::new(phi), y, 4)
                    .engine(EngineKind::NativeDense)
                    .build(),
            )
            .unwrap();
        let out = service.wait(id, Duration::from_secs(30)).unwrap();
        let err = crate::metrics::recovery_error(&out.result.unwrap().x, &x_true);
        assert!(err < 1e-2);
        service.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let service = svc(3);
        service.shutdown();
    }

    #[test]
    fn cancel_stops_long_jobs_and_delivers_partial_results() {
        let service = RecoveryService::start(
            ServiceConfig {
                workers: 1,
                queue_capacity: 8,
                max_batch: 1,
                max_wait_ms: 0,
                ..Default::default()
            },
            // tol = 0 + huge budget: without cancellation these jobs would
            // grind through 200k iterations each.
            SolveOptions::default().with_tol(0.0).with_max_iters(200_000),
            PathBuf::from("artifacts"),
        );
        // Big dense problem so one iteration costs two full matvecs —
        // cancelling right after submit always lands within the first
        // couple of iterations.
        let (phi, y, _) = planted(512, 4096, 8, 11);
        let spec = JobSpec::builder(ProblemHandle::new(phi), y, 8)
            .engine(EngineKind::NativeDense)
            .seed(1)
            .build();
        let a = service.submit(spec.clone()).unwrap();
        let b = service.submit(spec).unwrap();
        assert!(service.cancel(a), "queued/running job accepts cancellation");
        assert!(service.cancel(b));
        for id in [a, b] {
            let out = service.wait(id, Duration::from_secs(120)).expect("cancelled job completes");
            assert_eq!(out.state, JobState::Done);
            let res = out.result.unwrap();
            assert!(!res.converged, "cancelled solve reports non-convergence");
            assert!(res.iterations <= 4, "stopped almost immediately, ran {}", res.iterations);
            assert!(service.progress(id).is_some(), "progress was streamed");
        }
        assert_eq!(
            service.metrics().cancelled.load(Ordering::Relaxed),
            2,
            "cancellations are counted"
        );
        service.shutdown();
    }

    #[test]
    fn submits_mint_nonzero_distinct_trace_ids() {
        let service = svc(1);
        let (phi, y, _) = planted(64, 128, 4, 31);
        let spec = JobSpec::builder(ProblemHandle::new(phi), y, 4).bits(8, 8).build();
        let a = service.submit(spec.clone()).unwrap();
        let b = service.submit(spec).unwrap();
        let (ta, tb) = (service.trace_of(a), service.trace_of(b));
        assert_ne!(ta, 0, "every admitted job carries a trace id");
        assert_ne!(ta, tb, "identical submit bytes still mint distinct ids");
        for id in [a, b] {
            service.wait(id, Duration::from_secs(30)).expect("finishes");
        }
        // The e2e histogram carries one of them as its exemplar.
        let labels = JobLabels { solver: "qniht", engine: "native-quant", bits: 8 };
        let snap = service.obsv().e2e.get(labels, Some(Outcome::Ok)).snapshot();
        let (trace, _) = snap.exemplar.expect("a terminal job tagged the e2e exemplar");
        assert!(trace == ta || trace == tb, "exemplar {trace:#x} vs {ta:#x}/{tb:#x}");
        service.shutdown();
    }

    #[test]
    fn retry_hint_appears_after_first_batch_and_scales_sanely() {
        let service = svc(1);
        assert_eq!(service.retry_after_ms(), None, "no samples yet, no hint");
        let (phi, y, _) = planted(64, 128, 4, 5);
        let id = service
            .submit(JobSpec::builder(ProblemHandle::new(phi), y, 4).bits(8, 8).build())
            .unwrap();
        service.wait(id, Duration::from_secs(30)).expect("finishes");
        let hint = service.retry_after_ms().expect("one executed batch seeds the EWMA");
        assert!(hint >= 1, "hint is a positive millisecond estimate");
        assert!(service.metrics().exec_ewma_us.load(Ordering::Relaxed) > 0);
        service.shutdown();
    }

    #[test]
    fn cost_model_persists_across_restarts_and_tolerates_corruption() {
        let dir = std::env::temp_dir().join(format!("lpcs-svc-cost-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = ServiceConfig {
            workers: 1,
            queue_capacity: 64,
            max_batch: 4,
            max_wait_ms: 0,
            calibrate_cost: true,
            persist_cost: true,
            ..Default::default()
        };
        let boot = || RecoveryService::start(cfg, SolveOptions::default(), dir.clone());

        let service = boot();
        let (phi, y, _) = planted(64, 128, 4, 7);
        let id = service
            .submit(JobSpec::builder(ProblemHandle::new(phi), y, 4).bits(8, 8).build())
            .unwrap();
        service.wait(id, Duration::from_secs(30)).expect("finishes");
        service.shutdown();

        let path = dir.join("cost_model.v1");
        let warm = crate::coordinator::sched::load_cost_file(&path)
            .expect("graceful shutdown wrote a loadable cost file");
        assert!(
            warm.values().any(|o| o.samples > 0),
            "the executed batch was persisted: {warm:?}"
        );

        // A clean reboot loads it without errors.
        let service = boot();
        assert_eq!(service.metrics().cost_load_errors.load(Ordering::Relaxed), 0);
        service.shutdown();

        // Corruption ⇒ counted cold start, never a panic; the next
        // graceful shutdown rewrites a valid file.
        std::fs::write(&path, "not a cost file\n\u{0}\u{1}").unwrap();
        let service = boot();
        assert_eq!(service.metrics().cost_load_errors.load(Ordering::Relaxed), 1);
        service.shutdown();
        crate::coordinator::sched::load_cost_file(&path)
            .expect("shutdown replaced the corrupt file");
        std::fs::remove_dir_all(&dir).ok();
    }
}
