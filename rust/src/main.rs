//! `lpcs` — launcher CLI for the low-precision compressive-sensing stack.
//!
//! Subcommands:
//!   solve    one recovery on a synthetic problem (gaussian | astro)
//!   mri      matrix-free partial-Fourier MRI recovery (phantom → PGMs)
//!   astro    matrix-free visibility recovery on a synthetic sky — local
//!            (sky → unique-baseline visibilities → NIHT → PGMs), or
//!            (with --addr ADDR) submitted to a serve/route listener as
//!            an `OperatorSpec::Visibility` wire job
//!   serve    run the recovery service — on a stream of synthetic jobs,
//!            or (with --listen ADDR) as a network service speaking the
//!            wire protocol (submit/subscribe/cancel/metrics frames)
//!   route    shard jobs across several serve backends: same wire
//!            protocol on both faces, consistent-hash batch affinity,
//!            health-checked backends, watch streams that resume across
//!            a backend dying mid-solve
//!   watch    stream a served job's per-iteration progress over the wire
//!   trace    follow one job to its terminal frame and print its fleet
//!            trace id with the per-stage timing breakdown
//!   scrape   print a server's Prometheus text exposition — against a
//!            router, the federated fleet-wide exposition
//!   repro    regenerate a paper figure (fig1..fig11 | all)
//!   info     list AOT artifacts and environment
//!
//! Options are `--key value` / `key=value` pairs applied onto the config
//! (see `config::LpcsConfig::set` for the full key list); `--config FILE`
//! loads a JSON config first. (No clap offline — hand-rolled parsing,
//! DESIGN.md §6.)

use anyhow::{bail, Context, Result};
use lpcs::config::LpcsConfig;
use lpcs::coordinator::{JobSpec, ProblemHandle, RecoveryService};
use lpcs::io::pgm;
use lpcs::linalg::Mat;
use lpcs::metrics;
use lpcs::mri::MriProblem;
use lpcs::rng::XorShift128Plus;
use lpcs::runtime::Runtime;
use lpcs::solver::{Problem, Recovery};
use lpcs::telescope::AstroProblem;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: lpcs <solve|serve|route|watch|trace|scrape|repro|info> [args] [--key value ...]\n\
         \n\
         lpcs solve [gaussian|astro] [--engine native-quant|native-dense|xla-quant|xla-dense|fpga-model]\n\
         \x20          [--algorithm niht|iht|qniht|cosamp|fista|auto]\n\
         lpcs mri   [--mri.resolution N] [--mri.mask cartesian|radial] [--mri.fraction F]\n\
         \x20          [--mri.center_band B] [--mri.bits 0|2|4|8] [--mri.sparsity S]\n\
         lpcs astro [--astro.antennas L] [--astro.resolution N] [--astro.sources K]\n\
         \x20          [--astro.snr_db DB] [--astro.bits 0|2|4|8] [--astro.sparsity S]\n\
         \x20          [--astro.full_baselines true|false] [--addr ADDR]\n\
         lpcs serve [--service.workers N] [--engine ...] [--algorithm ...]\n\
         \x20          [--listen ADDR] [--wire.sub_depth N]   (ADDR e.g. 127.0.0.1:7070)\n\
         lpcs route --listen ADDR backend=ADDR [backend=ADDR ...]\n\
         \x20          [--router.probe_ms N] [--router.max_inflight N] [--router.queue_limit N]\n\
         \x20          [--router.vnodes N] [--router.affinity true|false]\n\
         lpcs watch <addr> <job-id>\n\
         lpcs trace <addr> <job-id>            (trace id + per-stage timing breakdown)\n\
         lpcs scrape <addr>                    (Prometheus text exposition; federated on a router)\n\
         lpcs repro <fig1|fig3|fig4|fig5|fig6|fig7|fig8|fig9|fig10|fig11|all> [--out_dir DIR]\n\
         lpcs info"
    );
    std::process::exit(2);
}

/// Parse trailing `--key value` / `key=value` pairs onto the config;
/// returns positional arguments.
fn parse_args(cfg: &mut LpcsConfig, args: &[String]) -> Result<Vec<String>> {
    let mut positional = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if key == "config" {
                let path = args.get(i + 1).context("--config needs a file")?;
                *cfg = LpcsConfig::from_file(std::path::Path::new(path))?;
                i += 2;
                continue;
            }
            if let Some((k, v)) = key.split_once('=') {
                cfg.set(k, v)?;
                i += 1;
            } else {
                let v = args.get(i + 1).with_context(|| format!("--{key} needs a value"))?;
                cfg.set(key, v)?;
                i += 2;
            }
        } else if let Some((k, v)) = a.split_once('=') {
            cfg.set(k, v)?;
            i += 1;
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Ok(positional)
}

fn real_main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let mut cfg = LpcsConfig::default();
    // `--addr` targets a wire listener, not a config key — peel it off
    // before the config parser sees it.
    let mut tail = args[1..].to_vec();
    let mut addr = None;
    if let Some(i) = tail.iter().position(|a| a == "--addr") {
        addr = Some(tail.get(i + 1).context("--addr needs a value")?.clone());
        tail.drain(i..=i + 1);
    }
    let rest = parse_args(&mut cfg, &tail)?;
    cfg.validate()?;

    match cmd.as_str() {
        "solve" => cmd_solve(&cfg, rest.first().map(|s| s.as_str()).unwrap_or("gaussian")),
        "mri" => cmd_mri(&cfg),
        "astro" => cmd_astro(&cfg, addr.as_deref()),
        "serve" => cmd_serve(&cfg),
        "route" => cmd_route(&cfg),
        "watch" => match (rest.first(), rest.get(1)) {
            (Some(addr), Some(job)) => cmd_watch(addr, job),
            _ => usage(),
        },
        "trace" => match (rest.first(), rest.get(1)) {
            (Some(addr), Some(job)) => cmd_trace(addr, job),
            _ => usage(),
        },
        "scrape" => match rest.first() {
            Some(addr) => cmd_scrape(addr),
            None => usage(),
        },
        "repro" => {
            let which = rest.first().map(|s| s.as_str()).unwrap_or("all");
            lpcs::repro::run(which, &cfg)
        }
        "info" => cmd_info(&cfg),
        _ => usage(),
    }
}

/// Build a synthetic problem. Gaussian problems use the artifact shape
/// (256×512, s=32) so every engine can run them.
fn gaussian_problem(seed: u64) -> (Mat, Vec<f32>, Vec<f32>, usize, &'static str) {
    let (m, n, s) = (256usize, 512usize, 32usize);
    let mut rng = XorShift128Plus::new(seed);
    let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
    let mut x = vec![0.0f32; n];
    for i in rng.choose_k(n, s) {
        x[i] = rng.gaussian_f32() + 1.5 * rng.gaussian_f32().signum();
    }
    let y = phi.matvec(&x);
    (phi, y, x, s, "gauss_256x512")
}

fn cmd_solve(cfg: &LpcsConfig, kind: &str) -> Result<()> {
    let t_total = Instant::now();
    let (phi, y, x_true, s, tag) = match kind {
        "gaussian" => gaussian_problem(cfg.seed),
        "astro" => {
            let p = AstroProblem::build(&cfg.astro, cfg.seed);
            let s = cfg.sparsity.min(cfg.astro.sources);
            let AstroProblem { phi, y, x_true, .. } = p;
            (phi, y, x_true, s, "astro")
        }
        other => bail!("unknown problem kind '{other}' (gaussian|astro)"),
    };
    println!(
        "problem={kind} M={} N={} s={s} engine={} bits={}&{}",
        phi.rows, phi.cols, cfg.engine.name(), cfg.quant.bits_phi, cfg.quant.bits_y
    );

    // One facade call covers every engine: the registry owns dispatch,
    // and the config resolves the algorithm (`--algorithm`, or inferred
    // from the engine).
    let solver = cfg.solver_kind();
    let problem = Problem::from_mat(phi, y, s).with_shape_tag(tag);
    let report = Recovery::problem(problem)
        .solver(solver)
        .engine(cfg.engine)
        .options(cfg.solver.clone())
        .seed(cfg.seed)
        .artifact_dir(cfg.artifact_dir.clone())
        .run()?;

    println!(
        "solver={} engine={} iterations={} converged={} shrink_events={} solve_time={:.3?} total={:.3?}",
        report.solver, report.engine, report.iterations, report.converged,
        report.shrink_events, report.wall, t_total.elapsed()
    );
    if let Some(modeled) = report.modeled {
        println!(
            "fpga_modeled_time={modeled:.3?} ({} iterations at the §8 bandwidth-model rate)",
            report.iterations
        );
    }
    println!(
        "recovery_error={:.6} support_recovery={:.4}",
        metrics::recovery_error(&report.x, &x_true),
        metrics::exact_recovery_top_s(&report.x, &x_true)
    );
    Ok(())
}

/// The MRI workload end to end: sparse Shepp–Logan phantom →
/// undersampled k-space → matrix-free NIHT recovery (f32 and, when
/// `mri.bits` > 0, the low-precision sampling path) → PSNR + PGM panels.
fn cmd_mri(cfg: &LpcsConfig) -> Result<()> {
    let t0 = Instant::now();
    let p = MriProblem::build(&cfg.mri, cfg.seed)?;
    let mask = p.op.mask();
    println!(
        "mri: {r}x{r} phantom, {kind} mask fraction={frac} band={band} -> {k} samples \
         ({us:.1}% of k-space), M={m} stacked-real rows, s={s}  [built in {dt:.2?}]",
        r = p.r,
        kind = mask.config().kind.name(),
        frac = mask.config().fraction,
        band = mask.config().center_band,
        k = mask.len(),
        us = 100.0 * mask.undersampling(),
        m = p.m(),
        s = p.s,
        dt = t0.elapsed(),
    );
    let range = Some((0.0f32, p.x_true.iter().cloned().fold(0.0, f32::max)));
    let out = &cfg.out_dir;
    pgm::write_pgm(&out.join("mri_truth.pgm"), &p.x_true, p.r, p.r, range)?;
    let zf = p.op.zero_filled(&p.y);
    pgm::write_pgm(&out.join("mri_zero_filled.pgm"), &zf, p.r, p.r, range)?;
    println!(
        "zero-filled Φᵀy baseline: psnr={:.2} dB (the aliased classical estimate)",
        metrics::psnr(&zf, &p.x_true)
    );

    let report = Recovery::problem(Problem::with_op(p.op.clone(), p.y.clone(), p.s))
        .solver(lpcs::solver::SolverKind::Niht)
        .options(cfg.solver.clone())
        .run()?;
    let psnr32 = metrics::psnr(&report.x, &p.x_true);
    println!(
        "f32 matrix-free NIHT: {} iters in {:.3?}  psnr={psnr32:.2} dB  err={:.4}",
        report.iterations,
        report.wall,
        metrics::recovery_error(&report.x, &p.x_true)
    );
    pgm::write_pgm(&out.join("mri_recon_f32.pgm"), &report.x, p.r, p.r, range)?;

    if cfg.mri.bits != 0 {
        let b = cfg.mri.bits;
        let problem = lpcs::mri::lowprec_problem(p.op.clone(), &p.y, p.s, b, cfg.seed);
        let q = Recovery::problem(problem)
            .solver(lpcs::solver::SolverKind::Niht)
            .options(cfg.solver.clone())
            .seed(cfg.seed)
            .run()?;
        let psnrq = metrics::psnr(&q.x, &p.x_true);
        println!(
            "{b}-bit sampling path:  {} iters in {:.3?}  psnr={psnrq:.2} dB  (Δ vs f32 {:+.2} dB)",
            q.iterations,
            q.wall,
            psnrq - psnr32
        );
        pgm::write_pgm(&out.join(format!("mri_recon_q{b}.pgm")), &q.x, p.r, p.r, range)?;
    }
    println!("wrote PGM panels to {out:?}");
    Ok(())
}

/// The telescope workload end to end: synthetic sky → unique-baseline
/// visibilities with conjugate-structured noise → matrix-free NIHT
/// recovery (f32 and, when `astro.bits` > 0, the low-precision sampling
/// path). Locally this writes PGM panels; with `--addr` the same problem
/// ships to a serve/route listener as an `OperatorSpec::Visibility` job
/// and this process streams its progress.
fn cmd_astro(cfg: &LpcsConfig, addr: Option<&str>) -> Result<()> {
    let t0 = Instant::now();
    let p = lpcs::telescope::SkyProblem::build(&cfg.astro, cfg.seed)?;
    let r = cfg.astro.resolution;
    println!(
        "astro: L={l} antennas -> {mb} {set} baselines, {r}x{r} sky, {src} sources, \
         M={m} stacked-real rows, s={s}, snr={snr} dB  [built in {dt:.2?}]",
        l = cfg.astro.antennas,
        mb = p.op.baseline_count(),
        set = if p.op.full_baselines() { "full-set" } else { "unique" },
        src = cfg.astro.sources,
        m = p.m(),
        s = p.s,
        snr = cfg.astro.snr_db,
        dt = t0.elapsed(),
    );
    match addr {
        Some(addr) => cmd_astro_wire(cfg, &p, addr),
        None => cmd_astro_local(cfg, &p),
    }
}

fn cmd_astro_local(cfg: &LpcsConfig, p: &lpcs::telescope::SkyProblem) -> Result<()> {
    let r = cfg.astro.resolution;
    let range = Some((0.0f32, p.x_true.iter().cloned().fold(0.0, f32::max)));
    let out = &cfg.out_dir;
    pgm::write_pgm(&out.join("astro_truth.pgm"), &p.x_true, r, r, range)?;
    let dirty = p.op.dirty_image(&p.y);
    pgm::write_pgm(&out.join("astro_dirty.pgm"), &dirty, r, r, None)?;
    println!(
        "dirty-image Φᵀy baseline: err={:.4} (the classical estimate CLEAN deconvolves)",
        metrics::recovery_error(&dirty, &p.x_true)
    );

    let report = Recovery::problem(Problem::with_op(p.op.clone(), p.y.clone(), p.s))
        .solver(lpcs::solver::SolverKind::Niht)
        .options(cfg.solver.clone())
        .run()?;
    let psnr32 = metrics::psnr(&report.x, &p.x_true);
    println!(
        "f32 matrix-free NIHT: {} iters in {:.3?}  psnr={psnr32:.2} dB  err={:.4}",
        report.iterations,
        report.wall,
        metrics::recovery_error(&report.x, &p.x_true)
    );
    pgm::write_pgm(&out.join("astro_recon_f32.pgm"), &report.x, r, r, range)?;

    if cfg.astro.bits != 0 {
        let b = cfg.astro.bits;
        let problem = lpcs::telescope::op::lowprec_problem(
            p.op.clone(),
            &p.y,
            p.s,
            b,
            cfg.seed,
        );
        let q = Recovery::problem(problem)
            .solver(lpcs::solver::SolverKind::Niht)
            .options(cfg.solver.clone())
            .seed(cfg.seed)
            .run()?;
        let psnrq = metrics::psnr(&q.x, &p.x_true);
        println!(
            "{b}-bit sampling path:  {} iters in {:.3?}  psnr={psnrq:.2} dB  (Δ vs f32 {:+.2} dB)",
            q.iterations,
            q.wall,
            psnrq - psnr32
        );
        pgm::write_pgm(&out.join(format!("astro_recon_q{b}.pgm")), &q.x, r, r, range)?;
    }
    println!("wrote PGM panels to {out:?}");
    Ok(())
}

/// Ship the sky problem to a wire listener and stream its progress.
/// Visibility jobs are servable on NIHT × native-dense only, so those
/// are forced regardless of the configured engine.
fn cmd_astro_wire(cfg: &LpcsConfig, p: &lpcs::telescope::SkyProblem, addr: &str) -> Result<()> {
    let handle = match cfg.astro.bits {
        0 => ProblemHandle::visibility(p.op.clone()),
        b => ProblemHandle::low_prec_visibility(p.op.clone(), b),
    };
    let spec = JobSpec::builder(handle, p.y.clone(), p.s)
        .engine(lpcs::config::EngineKind::NativeDense)
        .solver(lpcs::solver::SolverKind::Niht)
        .seed(cfg.seed)
        .build();
    let mut client = lpcs::wire::WireClient::connect(addr)
        .with_context(|| format!("connecting to {addr}"))?;
    let id = client.submit(&spec).context("submitting visibility job")?;
    println!("submitted visibility job {id} to {addr} (bits={})", cfg.astro.bits);
    for event in client.watch(id)? {
        match event? {
            lpcs::wire::WatchEvent::Queued { position, depth } => {
                println!("queued: position {position} of {depth}")
            }
            lpcs::wire::WatchEvent::Progress(st) => println!(
                "iter {:>6}  resid_nsq={:.6e}  mu={:.3e}",
                st.iter, st.resid_nsq, st.mu
            ),
            lpcs::wire::WatchEvent::Done(out) => {
                if out.trace != 0 {
                    println!("trace {:016x}", out.trace);
                }
                println!(
                    "job {} {:?}  queued_for={:.3?}  ran_for={:.3?}",
                    out.id, out.state, out.queued_for, out.ran_for
                );
                if let Some(res) = out.result {
                    println!(
                        "result: {} iterations, converged={}, recovery_error={:.6}",
                        res.iterations,
                        res.converged,
                        metrics::recovery_error(&res.x, &p.x_true)
                    );
                }
                if let Some(err) = out.error {
                    println!("error: {err}");
                }
            }
        }
    }
    Ok(())
}

fn cmd_serve(cfg: &LpcsConfig) -> Result<()> {
    // Fail fast with a config-level error: without this check every
    // submission below would be rejected individually by
    // `JobSpec::validate` (same shared bit-width gate).
    cfg.solver_kind().check_packed_bits().context("serve")?;
    if !cfg.wire.listen.is_empty() {
        return cmd_serve_wire(cfg);
    }
    let jobs: usize =
        std::env::var("LPCS_SERVE_JOBS").ok().and_then(|v| v.parse().ok()).unwrap_or(32);
    println!(
        "recovery service: workers={} queue={} max_batch={} — submitting {jobs} jobs",
        cfg.service.workers, cfg.service.queue_capacity, cfg.service.max_batch
    );
    let service =
        RecoveryService::start(cfg.service, cfg.solver.clone(), cfg.artifact_dir.clone());

    // A snapshot stream: many observations share one Φ.
    let (phi, _, _, s, _) = gaussian_problem(cfg.seed);
    let phi = Arc::new(phi);
    let mut rng = XorShift128Plus::new(cfg.seed ^ 0x5EEE);
    let t0 = Instant::now();
    let mut ids = Vec::new();
    let mut x_true_by_id = std::collections::HashMap::new();
    for j in 0..jobs {
        let mut x = vec![0.0f32; phi.cols];
        for i in rng.choose_k(phi.cols, s) {
            x[i] = 1.0 + rng.uniform_f32();
        }
        let y = phi.matvec(&x);
        let spec = JobSpec::builder(ProblemHandle::new(phi.clone()), y, s)
            .engine(cfg.engine)
            .solver(cfg.solver_kind())
            .seed(j as u64)
            .build();
        match service.submit(spec) {
            Ok(id) => {
                ids.push(id);
                x_true_by_id.insert(id, x);
            }
            Err(e) => println!("job {j} rejected (backpressure): {e}"),
        }
    }
    let mut errs = Vec::new();
    let mut lat = Vec::new();
    for id in &ids {
        let out = service.wait(*id, Duration::from_secs(600)).context("job timed out")?;
        if let Some(res) = out.result {
            errs.push(metrics::recovery_error(&res.x, &x_true_by_id[id]));
        }
        lat.push(out.queued_for + out.ran_for);
    }
    let wall = t0.elapsed();
    lat.sort();
    println!(
        "completed {}/{} in {:.3?}  throughput={:.1} jobs/s  p50={:.3?} p95={:.3?}",
        errs.len(),
        jobs,
        wall,
        errs.len() as f64 / wall.as_secs_f64(),
        lat[lat.len() / 2],
        lat[(lat.len() * 95) / 100],
    );
    println!(
        "mean recovery error = {:.6}",
        errs.iter().sum::<f64>() / errs.len().max(1) as f64
    );
    println!("metrics: {}", service.metrics().snapshot());
    service.shutdown();
    Ok(())
}

/// `lpcs serve --listen ADDR`: the recovery service as a network
/// service. Clients speak the wire protocol ([`lpcs::wire`]): submit
/// jobs, stream per-iteration progress, cancel, read metrics. Runs until
/// the process is killed.
fn cmd_serve_wire(cfg: &LpcsConfig) -> Result<()> {
    let service = Arc::new(RecoveryService::start(
        cfg.service,
        cfg.solver.clone(),
        cfg.artifact_dir.clone(),
    ));
    let server = lpcs::wire::serve(service.clone(), &cfg.wire.listen, cfg.wire.sub_depth)?;
    println!(
        "wire server listening on {} (frames v{}; workers={} queue={} sub_depth={})",
        server.addr(),
        lpcs::wire::WIRE_VERSION,
        cfg.service.workers,
        cfg.service.queue_capacity,
        cfg.wire.sub_depth
    );
    println!("watch a job with: lpcs watch {} <job-id>   (Ctrl-C stops the server)", server.addr());
    // Optional self-traffic: with LPCS_SERVE_JOBS set, run that many
    // synthetic jobs through the service before settling into the serve
    // loop, so a following `lpcs scrape` sees populated series (used by
    // the CI smoke test).
    if let Some(jobs) = std::env::var("LPCS_SERVE_JOBS").ok().and_then(|v| v.parse::<usize>().ok())
    {
        let (phi, _, _, s, _) = gaussian_problem(cfg.seed);
        let phi = Arc::new(phi);
        let mut rng = XorShift128Plus::new(cfg.seed ^ 0x5EEE);
        let mut ids = Vec::new();
        for j in 0..jobs {
            let mut x = vec![0.0f32; phi.cols];
            for i in rng.choose_k(phi.cols, s) {
                x[i] = 1.0 + rng.uniform_f32();
            }
            let y = phi.matvec(&x);
            let spec = JobSpec::builder(ProblemHandle::new(phi.clone()), y, s)
                .engine(cfg.engine)
                .solver(cfg.solver_kind())
                .seed(j as u64)
                .build();
            match service.submit(spec) {
                Ok(id) => ids.push(id),
                Err(e) => println!("self-traffic job {j} rejected: {e}"),
            }
        }
        for id in ids {
            let _ = service.wait(id, Duration::from_secs(600));
        }
        println!("self-traffic: {jobs} jobs done");
    }
    // `server` must outlive the loop — dropping it would stop accepting.
    loop {
        std::thread::sleep(Duration::from_secs(60));
        println!("metrics: {}", service.metrics().snapshot());
    }
}

/// `lpcs route --listen ADDR backend=B1 backend=B2 …`: the sharded
/// serving tier. Clients speak to it exactly as to `lpcs serve`; jobs
/// shard across the backends by batch-affine consistent hashing, with
/// health-checked membership and resume-on-failover watch streams.
fn cmd_route(cfg: &LpcsConfig) -> Result<()> {
    if cfg.wire.listen.is_empty() {
        bail!("route needs --listen ADDR");
    }
    let router = lpcs::router::serve(cfg.router.clone(), &cfg.wire.listen)?;
    println!(
        "router listening on {} (frames v{}; {} backends, vnodes={} affinity={} \
         max_inflight={} queue_limit={})",
        router.addr(),
        lpcs::wire::WIRE_VERSION,
        cfg.router.backends.len(),
        cfg.router.vnodes,
        cfg.router.affinity,
        cfg.router.max_inflight,
        cfg.router.queue_limit,
    );
    for b in &cfg.router.backends {
        println!("  backend {b}");
    }
    // Optional self-traffic mirroring LPCS_SERVE_JOBS: with
    // LPCS_ROUTE_JOBS set, drive that many synthetic jobs through the
    // router's own wire face (one Φ per job, so consistent hashing
    // spreads the keys over the ring) and drain their watch streams.
    // A following `lpcs scrape` then sees populated per-hop router
    // histograms plus merged backend families — the CI federation smoke.
    if let Some(jobs) = std::env::var("LPCS_ROUTE_JOBS").ok().and_then(|v| v.parse::<usize>().ok())
    {
        let mut rng = XorShift128Plus::new(cfg.seed ^ 0x0907E);
        for j in 0..jobs {
            let (phi, _, _, s, _) = gaussian_problem(cfg.seed + 1 + j as u64);
            let phi = Arc::new(phi);
            let mut x = vec![0.0f32; phi.cols];
            for i in rng.choose_k(phi.cols, s) {
                x[i] = 1.0 + rng.uniform_f32();
            }
            let y = phi.matvec(&x);
            let spec = JobSpec::builder(ProblemHandle::new(phi), y, s)
                .engine(cfg.engine)
                .solver(cfg.solver_kind())
                .seed(j as u64)
                .build();
            let mut client = lpcs::wire::WireClient::connect(router.addr())
                .context("self-traffic connect")?;
            let id = client.submit(&spec).context("self-traffic submit")?;
            for event in client.watch(id)? {
                if let lpcs::wire::WatchEvent::Done(out) = event? {
                    println!("self-traffic job {j}: {:?} trace {:016x}", out.state, out.trace);
                }
            }
        }
        println!("self-traffic: {jobs} jobs done");
    }
    // `router` must outlive the loop — dropping it would stop accepting.
    loop {
        std::thread::sleep(Duration::from_secs(60));
        println!("metrics: {}", router.metrics().snapshot());
    }
}

/// `lpcs watch ADDR JOB`: stream a served job's convergence live.
fn cmd_watch(addr: &str, job: &str) -> Result<()> {
    let id: u64 = job.parse().with_context(|| format!("job id '{job}' is not a number"))?;
    let mut client = lpcs::wire::WireClient::connect(addr)
        .with_context(|| format!("connecting to {addr}"))?;
    for event in client.watch(id)? {
        match event? {
            lpcs::wire::WatchEvent::Queued { position, depth } => {
                println!("queued: position {position} of {depth}")
            }
            lpcs::wire::WatchEvent::Progress(st) => println!(
                "iter {:>6}  resid_nsq={:.6e}  mu={:.3e}  support_changed={}  shrinks={}",
                st.iter, st.resid_nsq, st.mu, st.support_changed, st.shrink_count
            ),
            lpcs::wire::WatchEvent::Done(out) => {
                if out.trace != 0 {
                    println!("trace {:016x}", out.trace);
                }
                println!(
                    "job {} {:?}  queued_for={:.3?}  ran_for={:.3?}",
                    out.id, out.state, out.queued_for, out.ran_for
                );
                if let Some(res) = out.result {
                    println!(
                        "result: {} iterations, converged={}, |x|_0={}",
                        res.iterations,
                        res.converged,
                        res.x.iter().filter(|v| **v != 0.0).count()
                    );
                }
                if let Some(err) = out.error {
                    println!("error: {err}");
                }
            }
        }
    }
    Ok(())
}

/// `lpcs trace ADDR JOB`: follow one served job to its terminal frame
/// and print its fleet trace id with the per-stage timing breakdown —
/// the same id the end-to-end histogram exemplar carries, so a scrape's
/// exemplar points straight back at what this prints.
fn cmd_trace(addr: &str, job: &str) -> Result<()> {
    let id: u64 = job.parse().with_context(|| format!("job id '{job}' is not a number"))?;
    let mut client = lpcs::wire::WireClient::connect(addr)
        .with_context(|| format!("connecting to {addr}"))?;
    let mut progress = 0usize;
    for event in client.watch(id)? {
        match event? {
            lpcs::wire::WatchEvent::Queued { .. } => {}
            lpcs::wire::WatchEvent::Progress(_) => progress += 1,
            lpcs::wire::WatchEvent::Done(out) => {
                println!("job {}  state {:?}", out.id, out.state);
                if out.trace != 0 {
                    println!("trace {:016x}", out.trace);
                } else {
                    println!("trace - (pre-v4 server; no trace id on the stream)");
                }
                println!("  queued  {:.3?}", out.queued_for);
                println!("  ran     {:.3?}  ({progress} progress frames)", out.ran_for);
                println!("  e2e     {:.3?}", out.queued_for + out.ran_for);
                if let Some(res) = out.result {
                    println!(
                        "  result  {} iterations, converged={}",
                        res.iterations, res.converged
                    );
                }
                if let Some(err) = out.error {
                    println!("  error   {err}");
                }
            }
        }
    }
    Ok(())
}

/// `lpcs scrape ADDR`: fetch one Prometheus text exposition from a
/// serve or route listener and print it. A server answers with the full
/// solver histograms; a router answers with the *federated* fleet view —
/// its own per-hop histograms plus every backend's families, merged.
fn cmd_scrape(addr: &str) -> Result<()> {
    let mut client = lpcs::wire::WireClient::connect(addr)
        .with_context(|| format!("connecting to {addr}"))?;
    print!("{}", client.scrape()?);
    Ok(())
}

fn cmd_info(cfg: &LpcsConfig) -> Result<()> {
    println!("lpcs {} — low-precision compressive sensing", env!("CARGO_PKG_VERSION"));
    println!("artifact dir: {:?}", cfg.artifact_dir);
    match Runtime::new(&cfg.artifact_dir) {
        Ok(rt) => {
            println!("PJRT CPU client OK; {} artifacts:", rt.manifest().entries.len());
            for e in &rt.manifest().entries {
                println!(
                    "  {:<36} {}x{} s={} ({} inputs, {} outputs)",
                    e.name, e.m, e.n, e.s, e.inputs.len(), e.outputs.len()
                );
            }
        }
        Err(e) => println!("runtime unavailable: {e:#}"),
    }
    Ok(())
}
