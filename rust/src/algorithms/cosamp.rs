//! CoSaMP (Needell & Tropp 2008) — greedy baseline of Fig 4.
//!
//! Per iteration: proxy `g = Φᵀr`, identify the 2s largest proxy entries,
//! merge with the current support (≤ 3s columns), least-squares solve on
//! the merged support (CGNR, `linalg::cg`), prune to the s largest, update
//! the residual. The paper notes CoSaMP degrades when Φ has similar-
//! magnitude entries / fails RIP — Fig 4 and our fig4 bench reproduce that.

use super::support::{support_of, support_union, supports_equal, top_s_indices};
use super::{IterObserver, IterStat, NoopObserver, ObserverSignal, SolveOptions, SolveResult};
use crate::linalg::{self, cg, Mat};

/// Deprecated shim: new code should route through the
/// [`crate::solver::Recovery`] facade (`SolverKind::Cosamp`).
pub fn cosamp(phi: &Mat, y: &[f32], s: usize, opts: &SolveOptions) -> SolveResult {
    cosamp_observed(phi, y, s, opts, &mut NoopObserver)
}

/// [`cosamp`] with a per-iteration [`IterObserver`] (progress streaming /
/// cancellation). `mu` is reported as 0 — CoSaMP has no step size.
pub fn cosamp_observed(
    phi: &Mat,
    y: &[f32],
    s: usize,
    opts: &SolveOptions,
    observer: &mut dyn IterObserver,
) -> SolveResult {
    assert_eq!(phi.rows, y.len());
    assert!(s >= 1);
    let n = phi.cols;
    let mut x = vec![0.0f32; n];
    let mut r = y.to_vec();
    let mut converged = false;
    let mut iters = 0;
    let mut history = Vec::new();

    for it in 0..opts.max_iters {
        let g = phi.matvec_t(&r);
        let omega = top_s_indices(&g, (2 * s).min(n));
        let merged = support_union(&omega, &support_of(&x));
        // LS solve restricted to the merged support.
        let sub = phi.take_cols(&merged);
        let ls = cg::lsqr_cg(&sub, y, 4 * merged.len().max(8), 1e-6);
        // Embed and prune to s.
        let mut b = vec![0.0f32; n];
        for (k, &j) in merged.iter().enumerate() {
            b[j] = ls.z[k];
        }
        let keep = top_s_indices(&b, s);
        let mut x_next = vec![0.0f32; n];
        for &j in &keep {
            x_next[j] = b[j];
        }
        let dx_nsq = linalg::norm2_sq(&linalg::sub(&x_next, &x));
        let x_nsq = linalg::norm2_sq(&x);
        let support_changed = !supports_equal(&support_of(&x), &support_of(&x_next));
        x = x_next;
        // Residual update uses the sparse x.
        let idx = support_of(&x);
        let vals: Vec<f32> = idx.iter().map(|&i| x[i]).collect();
        r = linalg::sub(y, &phi.matvec_sparse(&idx, &vals));
        iters = it + 1;
        let stat = IterStat {
            iter: it,
            resid_nsq: linalg::norm2_sq(&r),
            mu: 0.0,
            support_changed,
            shrink_count: 0,
        };
        if opts.track_history {
            history.push(stat);
        }
        if observer.on_iteration(&stat) == ObserverSignal::Stop {
            break;
        }
        if it > 0 && dx_nsq <= opts.tol * opts.tol * x_nsq.max(1e-12) {
            converged = true;
            break;
        }
    }
    SolveResult { x, iterations: iters, converged, shrink_events: 0, history }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::XorShift128Plus;

    fn planted(m: usize, n: usize, s: usize, seed: u64) -> (Mat, Vec<f32>, Vec<f32>) {
        let mut rng = XorShift128Plus::new(seed);
        let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
        let mut x = vec![0.0f32; n];
        for i in rng.choose_k(n, s) {
            x[i] = 2.0 * rng.gaussian_f32().signum() + 0.3 * rng.gaussian_f32();
        }
        let y = phi.matvec(&x);
        (phi, y, x)
    }

    #[test]
    fn recovers_planted_noiseless() {
        let (phi, y, x_true) = planted(80, 160, 5, 1);
        let r = cosamp(&phi, &y, 5, &SolveOptions::default());
        assert_eq!(support_of(&r.x), support_of(&x_true));
        let rel = linalg::norm2(&linalg::sub(&r.x, &x_true)) / linalg::norm2(&x_true);
        assert!(rel < 1e-2, "rel={rel}");
    }

    #[test]
    fn converges_fast_on_good_rip() {
        let (phi, y, _) = planted(128, 256, 4, 2);
        let r = cosamp(&phi, &y, 4, &SolveOptions::default());
        assert!(r.converged);
        assert!(r.iterations < 25, "iters={}", r.iterations);
    }

    #[test]
    fn output_is_s_sparse() {
        let (phi, y, _) = planted(60, 120, 6, 3);
        let r = cosamp(&phi, &y, 6, &SolveOptions::default());
        assert!(support_of(&r.x).len() <= 6);
    }

    #[test]
    fn noisy_recovery_reasonable() {
        let (phi, y0, x_true) = planted(96, 192, 5, 4);
        let mut rng = XorShift128Plus::new(40);
        let y: Vec<f32> = y0.iter().map(|v| v + 0.02 * rng.gaussian_f32()).collect();
        let r = cosamp(&phi, &y, 5, &SolveOptions::default());
        let rel = linalg::norm2(&linalg::sub(&r.x, &x_true)) / linalg::norm2(&x_true);
        assert!(rel < 0.1, "rel={rel}");
    }
}
