//! FISTA — the ℓ₁-minimization baseline ("the ℓ1-based approach" of Fig 4).
//!
//! Solves `min_x ½‖y − Φx‖² + λ‖x‖₁` with Beck–Teboulle accelerated
//! proximal gradient: step 1/L with L = σ_max(Φ)², soft-thresholding prox,
//! Nesterov momentum. λ defaults to `0.05·‖Φᵀy‖_∞` (a standard
//! regularization-path heuristic; the paper "optimized each algorithm
//! independently", and our fig4 harness sweeps λ). An optional debias pass
//! re-fits the values on the recovered support by least squares.

use super::support::{support_of, supports_equal, top_s_indices};
use super::{IterObserver, IterStat, NoopObserver, ObserverSignal, SolveOptions, SolveResult};
use crate::linalg::{self, cg, svd, Mat};

/// Soft-thresholding operator.
#[inline]
pub fn soft_threshold(v: f32, t: f32) -> f32 {
    if v > t {
        v - t
    } else if v < -t {
        v + t
    } else {
        0.0
    }
}

#[derive(Debug, Clone)]
pub struct FistaOptions {
    /// ℓ₁ weight; `None` → 0.05·‖Φᵀy‖_∞.
    pub lambda: Option<f32>,
    /// Re-fit values on the final support by LS.
    pub debias: bool,
    /// Prune the final iterate to the s largest entries (for support
    /// metrics comparable with the greedy methods); `None` keeps all.
    pub prune_to: Option<usize>,
}

impl Default for FistaOptions {
    fn default() -> Self {
        Self { lambda: None, debias: true, prune_to: None }
    }
}

/// Deprecated shim: new code should route through the
/// [`crate::solver::Recovery`] facade (`SolverKind::Fista`).
pub fn fista(
    phi: &Mat,
    y: &[f32],
    opts: &SolveOptions,
    fopts: &FistaOptions,
) -> SolveResult {
    fista_observed(phi, y, opts, fopts, &mut NoopObserver)
}

/// [`fista`] with a per-iteration [`IterObserver`] (progress streaming /
/// cancellation). `mu` in the reported stats is the proximal step 1/L.
pub fn fista_observed(
    phi: &Mat,
    y: &[f32],
    opts: &SolveOptions,
    fopts: &FistaOptions,
    observer: &mut dyn IterObserver,
) -> SolveResult {
    assert_eq!(phi.rows, y.len());
    let n = phi.cols;
    let lip = {
        let sigma = svd::spectral_norm(phi, 1e-5, 2000, 0xF157A);
        (sigma * sigma).max(f32::MIN_POSITIVE)
    };
    let step = 1.0 / lip;
    let aty = phi.matvec_t(y);
    let lambda = fopts
        .lambda
        .unwrap_or_else(|| 0.05 * aty.iter().fold(0.0f32, |a, &b| a.max(b.abs())));
    let thr = lambda * step;

    let mut x = vec![0.0f32; n];
    let mut z = x.clone();
    let mut t = 1.0f32;
    let mut converged = false;
    let mut iters = 0;
    let mut history = Vec::new();

    for it in 0..opts.max_iters {
        let r = linalg::sub(y, &phi.matvec(&z));
        let g = phi.matvec_t(&r);
        let x_next: Vec<f32> = z
            .iter()
            .zip(&g)
            .map(|(zi, gi)| soft_threshold(zi + step * gi, thr))
            .collect();
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
        let beta = (t - 1.0) / t_next;
        z = x_next
            .iter()
            .zip(&x)
            .map(|(xn, xo)| xn + beta * (xn - xo))
            .collect();
        let dx_nsq = linalg::norm2_sq(&linalg::sub(&x_next, &x));
        let x_nsq = linalg::norm2_sq(&x);
        let stat = IterStat {
            iter: it,
            resid_nsq: linalg::norm2_sq(&r),
            mu: step,
            support_changed: !supports_equal(&support_of(&x), &support_of(&x_next)),
            shrink_count: 0,
        };
        if opts.track_history {
            history.push(stat);
        }
        x = x_next;
        t = t_next;
        iters = it + 1;
        if observer.on_iteration(&stat) == ObserverSignal::Stop {
            break;
        }
        if it > 0 && dx_nsq <= opts.tol * opts.tol * x_nsq.max(1e-12) {
            converged = true;
            break;
        }
    }

    if let Some(s) = fopts.prune_to {
        let keep = top_s_indices(&x, s);
        let mut pruned = vec![0.0f32; n];
        for &i in &keep {
            pruned[i] = x[i];
        }
        x = pruned;
    }

    if fopts.debias {
        let supp = support_of(&x);
        if !supp.is_empty() {
            let sub = phi.take_cols(&supp);
            let ls = cg::lsqr_cg(&sub, y, 4 * supp.len().max(8), 1e-6);
            for (k, &j) in supp.iter().enumerate() {
                x[j] = ls.z[k];
            }
        }
    }

    SolveResult { x, iterations: iters, converged, shrink_events: 0, history }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::XorShift128Plus;

    fn planted(m: usize, n: usize, s: usize, seed: u64) -> (Mat, Vec<f32>, Vec<f32>) {
        let mut rng = XorShift128Plus::new(seed);
        let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
        let mut x = vec![0.0f32; n];
        for i in rng.choose_k(n, s) {
            x[i] = 2.0 * rng.gaussian_f32().signum() + 0.3 * rng.gaussian_f32();
        }
        let y = phi.matvec(&x);
        (phi, y, x)
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
    }

    #[test]
    fn recovers_planted_support_with_prune() {
        let (phi, y, x_true) = planted(80, 160, 5, 1);
        let fopts = FistaOptions { prune_to: Some(5), ..Default::default() };
        let opts = SolveOptions { max_iters: 400, ..Default::default() };
        let r = fista(&phi, &y, &opts, &fopts);
        assert_eq!(support_of(&r.x), support_of(&x_true));
    }

    #[test]
    fn debias_reduces_error() {
        let (phi, y, x_true) = planted(80, 160, 5, 2);
        let opts = SolveOptions { max_iters: 300, ..Default::default() };
        let no_db = fista(&phi, &y, &opts,
            &FistaOptions { debias: false, prune_to: Some(5), ..Default::default() });
        let db = fista(&phi, &y, &opts,
            &FistaOptions { debias: true, prune_to: Some(5), ..Default::default() });
        let e0 = linalg::norm2(&linalg::sub(&no_db.x, &x_true));
        let e1 = linalg::norm2(&linalg::sub(&db.x, &x_true));
        assert!(e1 <= e0 + 1e-5, "debias must not hurt: {e1} vs {e0}");
    }

    #[test]
    fn larger_lambda_sparser_solution() {
        let (phi, y, _) = planted(60, 120, 5, 3);
        let opts = SolveOptions { max_iters: 300, ..Default::default() };
        let small = fista(&phi, &y, &opts,
            &FistaOptions { lambda: Some(0.001), debias: false, prune_to: None });
        let large = fista(&phi, &y, &opts,
            &FistaOptions { lambda: Some(0.5), debias: false, prune_to: None });
        assert!(support_of(&large.x).len() <= support_of(&small.x).len());
    }

    #[test]
    fn zero_observation_gives_zero() {
        let (phi, _, _) = planted(30, 60, 3, 4);
        let r = fista(&phi, &vec![0.0; 30], &SolveOptions::default(), &FistaOptions::default());
        assert!(r.x.iter().all(|&v| v == 0.0));
    }
}
