//! Sparse-recovery solver suite (S7): the paper's QNIHT plus every baseline
//! its evaluation compares against.
//!
//! * [`niht`] — Normalized IHT with the full Algorithm-1 control flow
//!   (adaptive step, support check, μ line search), generic over a
//!   [`NihtKernel`] so the same driver runs the dense f32, quantized-native,
//!   packed and PJRT/XLA execution engines.
//! * [`qniht`] — quantized operand kernels (the paper's contribution).
//! * [`iht`] — plain IHT (μ = 1, ‖Φ‖₂ < 1), the classical baseline.
//! * [`cosamp`] — Compressive Sampling Matching Pursuit.
//! * [`fista`] — ℓ₁ baseline (FISTA), "the ℓ1-based approach" of Fig 4.
//! * [`clean`] — the CLEAN deconvolution baseline (Algorithm 2, Fig 9).
//! * [`support`] — H_s, top-s selection, support-set utilities.
//!
//! Every iterative solver also has a `*_observed` entry point that accepts
//! an [`IterObserver`] — a per-iteration callback that can stream progress
//! and request early cancellation. Callers normally reach these through
//! the [`crate::solver`] facade rather than calling them directly.

pub mod clean;
pub mod cosamp;
pub mod fista;
pub mod iht;
pub mod niht;
pub mod qniht;
pub mod support;

/// Everything one NIHT step produces (mirrors the AOT artifact outputs).
#[derive(Debug, Clone)]
pub struct StepOut {
    pub x_next: Vec<f32>,
    pub g: Vec<f32>,
    pub mu: f32,
    pub dx_nsq: f32,
    pub phi1_dx_nsq: f32,
    pub resid_nsq: f32,
}

/// A NIHT step engine: the only interface the Algorithm-1 driver needs.
/// Implementations: dense f32, quantized int8, bit-packed, PJRT executable.
pub trait NihtKernel {
    fn m(&self) -> usize;
    fn n(&self) -> usize;

    /// One full step at the adaptive μ (gradient + μ + threshold + norms).
    fn full_step(&mut self, x: &[f32], s: usize) -> StepOut;

    /// Re-apply `x⁺ = H_s(x + μ g)` at a caller-chosen μ, returning
    /// `(x_next, ‖dx‖², ‖Φ̂₁dx‖²)` — the line-search inner call.
    fn apply_step(&mut self, x: &[f32], g: &[f32], mu: f32, s: usize)
        -> (Vec<f32>, f32, f32);

    /// Called at the start of each outer iteration — lets quantized kernels
    /// draw fresh quantizations (Algorithm 1's {Φ̂₁ … Φ̂₂ₙ*}).
    fn begin_iteration(&mut self, _iter: usize) {}
}

/// Solver options shared by the iterative methods.
#[derive(Debug, Clone)]
pub struct SolveOptions {
    pub max_iters: usize,
    /// Convergence: stop when ‖x⁺ − x‖² ≤ tol² · ‖x‖².
    pub tol: f32,
    /// Algorithm-1 line-search constant c ∈ (0, 1).
    pub c: f32,
    /// Algorithm-1 shrinkage κ > 1/(1−c).
    pub kappa: f32,
    /// Record per-iteration statistics.
    pub track_history: bool,
    /// Line-search safety valve: give up shrinking μ after this many
    /// shrink steps in one outer iteration (μ is ~0 by then, so the
    /// support can no longer move and the iteration is accepted as-is).
    pub max_shrinks_per_iter: usize,
}

impl Default for SolveOptions {
    fn default() -> Self {
        Self {
            max_iters: 200,
            tol: 1e-5,
            c: 0.1,
            kappa: 1.2,
            track_history: false,
            max_shrinks_per_iter: 100,
        }
    }
}

impl SolveOptions {
    /// Builder-style setters (used by the [`crate::solver`] facade).
    pub fn with_max_iters(mut self, max_iters: usize) -> Self {
        self.max_iters = max_iters;
        self
    }

    pub fn with_tol(mut self, tol: f32) -> Self {
        self.tol = tol;
        self
    }

    pub fn with_c(mut self, c: f32) -> Self {
        self.c = c;
        self
    }

    pub fn with_kappa(mut self, kappa: f32) -> Self {
        self.kappa = kappa;
        self
    }

    pub fn with_track_history(mut self, track: bool) -> Self {
        self.track_history = track;
        self
    }

    pub fn with_max_shrinks_per_iter(mut self, max_shrinks: usize) -> Self {
        self.max_shrinks_per_iter = max_shrinks;
        self
    }
}

/// Per-iteration statistics (history entry / observer payload).
/// `PartialEq` is the derived field-wise comparison with IEEE `f32`
/// semantics (NaN ≠ NaN) — it exists for the wire codec's round-trip
/// tests, which never carry NaN stats.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IterStat {
    pub iter: usize,
    pub resid_nsq: f32,
    pub mu: f32,
    pub support_changed: bool,
    pub shrink_count: usize,
}

/// Decision an [`IterObserver`] returns after each outer iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObserverSignal {
    /// Keep iterating.
    Continue,
    /// Stop now and return the current iterate (early cancellation; the
    /// result is reported as not converged).
    Stop,
}

/// Per-iteration callback threaded through every iterative solver: the
/// serving layer uses it to stream progress and to cancel running jobs,
/// and the [`crate::solver`] facade exposes it to callers.
///
/// Observers see every outer iteration (independently of
/// `SolveOptions::track_history`) and are invoked *after* the iterate has
/// been updated, so returning [`ObserverSignal::Stop`] keeps the work of
/// the iteration that triggered the stop.
pub trait IterObserver {
    fn on_iteration(&mut self, stat: &IterStat) -> ObserverSignal;
}

/// The do-nothing observer every non-observed entry point uses.
#[derive(Debug, Default, Clone, Copy)]
pub struct NoopObserver;

impl IterObserver for NoopObserver {
    fn on_iteration(&mut self, _stat: &IterStat) -> ObserverSignal {
        ObserverSignal::Continue
    }
}

/// Any `FnMut(&IterStat) -> ObserverSignal` closure is an observer.
impl<F: FnMut(&IterStat) -> ObserverSignal> IterObserver for F {
    fn on_iteration(&mut self, stat: &IterStat) -> ObserverSignal {
        self(stat)
    }
}

/// Solver output.
#[derive(Debug, Clone)]
pub struct SolveResult {
    pub x: Vec<f32>,
    pub iterations: usize,
    pub converged: bool,
    /// Total μ-shrinkage events across the run (Algorithm-1 line search).
    pub shrink_events: usize,
    pub history: Vec<IterStat>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_options_satisfy_alg1_constraint() {
        // Algorithm 1 requires κ > 1/(1−c).
        let o = SolveOptions::default();
        assert!(o.kappa > 1.0 / (1.0 - o.c));
    }

    #[test]
    fn builder_setters_compose() {
        let o = SolveOptions::default()
            .with_max_iters(17)
            .with_tol(1e-3)
            .with_track_history(true)
            .with_max_shrinks_per_iter(5);
        assert_eq!(o.max_iters, 17);
        assert_eq!(o.tol, 1e-3);
        assert!(o.track_history);
        assert_eq!(o.max_shrinks_per_iter, 5);
        // Untouched fields keep their defaults.
        assert_eq!(o.c, SolveOptions::default().c);
    }

    #[test]
    fn closures_are_observers() {
        let mut calls = 0usize;
        let mut obs = |st: &IterStat| {
            calls += 1;
            if st.iter >= 1 { ObserverSignal::Stop } else { ObserverSignal::Continue }
        };
        let stat = |iter| IterStat {
            iter,
            resid_nsq: 0.0,
            mu: 1.0,
            support_changed: false,
            shrink_count: 0,
        };
        {
            let dyn_obs: &mut dyn IterObserver = &mut obs;
            assert_eq!(dyn_obs.on_iteration(&stat(0)), ObserverSignal::Continue);
            assert_eq!(dyn_obs.on_iteration(&stat(1)), ObserverSignal::Stop);
        }
        assert_eq!(calls, 2);
        assert_eq!(NoopObserver.on_iteration(&stat(9)), ObserverSignal::Continue);
    }
}
