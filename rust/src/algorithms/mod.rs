//! Sparse-recovery solver suite (S7): the paper's QNIHT plus every baseline
//! its evaluation compares against.
//!
//! * [`niht`] — Normalized IHT with the full Algorithm-1 control flow
//!   (adaptive step, support check, μ line search), generic over a
//!   [`NihtKernel`] so the same driver runs the dense f32, quantized-native,
//!   packed and PJRT/XLA execution engines.
//! * [`qniht`] — quantized operand kernels (the paper's contribution).
//! * [`iht`] — plain IHT (μ = 1, ‖Φ‖₂ < 1), the classical baseline.
//! * [`cosamp`] — Compressive Sampling Matching Pursuit.
//! * [`fista`] — ℓ₁ baseline (FISTA), "the ℓ1-based approach" of Fig 4.
//! * [`clean`] — the CLEAN deconvolution baseline (Algorithm 2, Fig 9).
//! * [`support`] — H_s, top-s selection, support-set utilities.

pub mod clean;
pub mod cosamp;
pub mod fista;
pub mod iht;
pub mod niht;
pub mod qniht;
pub mod support;

/// Everything one NIHT step produces (mirrors the AOT artifact outputs).
#[derive(Debug, Clone)]
pub struct StepOut {
    pub x_next: Vec<f32>,
    pub g: Vec<f32>,
    pub mu: f32,
    pub dx_nsq: f32,
    pub phi1_dx_nsq: f32,
    pub resid_nsq: f32,
}

/// A NIHT step engine: the only interface the Algorithm-1 driver needs.
/// Implementations: dense f32, quantized int8, bit-packed, PJRT executable.
pub trait NihtKernel {
    fn m(&self) -> usize;
    fn n(&self) -> usize;

    /// One full step at the adaptive μ (gradient + μ + threshold + norms).
    fn full_step(&mut self, x: &[f32], s: usize) -> StepOut;

    /// Re-apply `x⁺ = H_s(x + μ g)` at a caller-chosen μ, returning
    /// `(x_next, ‖dx‖², ‖Φ̂₁dx‖²)` — the line-search inner call.
    fn apply_step(&mut self, x: &[f32], g: &[f32], mu: f32, s: usize)
        -> (Vec<f32>, f32, f32);

    /// Called at the start of each outer iteration — lets quantized kernels
    /// draw fresh quantizations (Algorithm 1's {Φ̂₁ … Φ̂₂ₙ*}).
    fn begin_iteration(&mut self, _iter: usize) {}
}

/// Solver options shared by the iterative methods.
#[derive(Debug, Clone)]
pub struct SolveOptions {
    pub max_iters: usize,
    /// Convergence: stop when ‖x⁺ − x‖² ≤ tol² · ‖x‖².
    pub tol: f32,
    /// Algorithm-1 line-search constant c ∈ (0, 1).
    pub c: f32,
    /// Algorithm-1 shrinkage κ > 1/(1−c).
    pub kappa: f32,
    /// Record per-iteration statistics.
    pub track_history: bool,
}

impl Default for SolveOptions {
    fn default() -> Self {
        Self { max_iters: 200, tol: 1e-5, c: 0.1, kappa: 1.2, track_history: false }
    }
}

/// Per-iteration statistics (history entry).
#[derive(Debug, Clone, Copy)]
pub struct IterStat {
    pub iter: usize,
    pub resid_nsq: f32,
    pub mu: f32,
    pub support_changed: bool,
    pub shrink_count: usize,
}

/// Solver output.
#[derive(Debug, Clone)]
pub struct SolveResult {
    pub x: Vec<f32>,
    pub iterations: usize,
    pub converged: bool,
    /// Total μ-shrinkage events across the run (Algorithm-1 line search).
    pub shrink_events: usize,
    pub history: Vec<IterStat>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_options_satisfy_alg1_constraint() {
        // Algorithm 1 requires κ > 1/(1−c).
        let o = SolveOptions::default();
        assert!(o.kappa > 1.0 / (1.0 - o.c));
    }
}
