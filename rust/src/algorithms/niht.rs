//! Normalized IHT — the Algorithm-1 driver and the dense f32 kernel.
//!
//! The driver implements the paper's Algorithm 1 control flow:
//!
//! 1. `g = Φ̂₁ᵀ(ŷ − Φ̂₂x)`, adaptive `μ = ‖g_Γ‖²/‖Φ̂ g_Γ‖²`;
//! 2. proposal `x⁺ = H_s(x + μ g)`;
//! 3. if the support changed, require `μ ≤ (1−c)·b` with
//!    `b = ‖x⁺−x‖²/‖Φ̂₁(x⁺−x)‖²`; otherwise shrink `μ ← μ/(κ(1−c))` and
//!    re-propose until the condition holds (guaranteed to terminate since
//!    μ → 0 keeps the support fixed).
//!
//! Note: the paper's Algorithm-1 box contains two obvious typos (it assigns
//! `x[n+1] = x[n]` on *accept* paths, which would freeze the iterate); we
//! implement the underlying normalized-IHT rule from Blumensath & Davies
//! (2010), which the text describes (Eqns. 6–7) and which the convergence
//! theory (Theorem 2/3) actually analyzes.
//!
//! The dense f32 kernel here deliberately does NOT dispatch through
//! [`crate::simd`]: it is the paper's 32-bit *baseline*, and keeping it on
//! the portable autovectorized loops keeps the Fig 5/6 comparison honest
//! and its trajectories bit-reproducible across machines. The quantized
//! kernel ([`super::qniht`]) is where the SIMD backend layer applies.

use super::support::{hard_threshold, support_of, supports_equal, top_s_indices};
use super::{
    IterObserver, IterStat, NihtKernel, NoopObserver, ObserverSignal, SolveOptions, SolveResult,
    StepOut,
};
use crate::linalg::{self, Mat};

/// Run Algorithm 1 with any [`NihtKernel`].
pub fn solve<K: NihtKernel>(kernel: &mut K, s: usize, opts: &SolveOptions) -> SolveResult {
    solve_observed(kernel, s, opts, &mut NoopObserver)
}

/// [`solve`] with a per-iteration [`IterObserver`]: the observer sees every
/// outer iteration's [`IterStat`] after the iterate is updated and may
/// return [`ObserverSignal::Stop`] to cancel the solve, which returns the
/// current iterate with `converged = false`.
pub fn solve_observed<K: NihtKernel>(
    kernel: &mut K,
    s: usize,
    opts: &SolveOptions,
    observer: &mut dyn IterObserver,
) -> SolveResult {
    assert!(s >= 1, "sparsity must be >= 1");
    assert!(s <= kernel.n(), "sparsity exceeds dimension");
    let mut driver = IterDriver::new(kernel.n());
    for it in 0..opts.max_iters {
        kernel.begin_iteration(it);
        driver.advance(kernel, it, s, opts, observer);
        if driver.done {
            break;
        }
    }
    driver.finish()
}

/// Per-solve state of the Algorithm-1 driver, factored out so the
/// sequential path ([`solve_observed`]) and the batched lockstep path
/// ([`super::qniht::solve_batch_lockstep`]) share ONE iteration body:
/// trajectories are bit-identical by construction rather than by parallel
/// maintenance of two copies of the control flow.
pub(crate) struct IterDriver {
    /// The current iterate (read by the lockstep driver to compute the
    /// batched residuals/gradients before each [`Self::advance`]).
    pub(crate) x: Vec<f32>,
    supp: Vec<usize>, // empty support at x = 0
    shrink_events: usize,
    history: Vec<IterStat>,
    converged: bool,
    iters: usize,
    /// Set when the solve finished (converged or observer-stopped); callers
    /// must not `advance` a done driver.
    pub(crate) done: bool,
}

impl IterDriver {
    pub(crate) fn new(n: usize) -> Self {
        Self {
            x: vec![0.0f32; n],
            supp: Vec::new(),
            shrink_events: 0,
            history: Vec::new(),
            converged: false,
            iters: 0,
            done: false,
        }
    }

    /// One outer iteration of Algorithm 1: full step, support-change line
    /// search, bookkeeping, observer, convergence check. `it` must be the
    /// number of previous `advance` calls (callers that skip iterations
    /// would corrupt the warm-start convergence guard).
    pub(crate) fn advance<K: NihtKernel + ?Sized>(
        &mut self,
        kernel: &mut K,
        it: usize,
        s: usize,
        opts: &SolveOptions,
        observer: &mut dyn IterObserver,
    ) {
        let st = kernel.full_step(&self.x, s);
        let mut mu = st.mu;
        let mut x_next = st.x_next;
        let mut dx_nsq = st.dx_nsq;
        let mut phi1_dx_nsq = st.phi1_dx_nsq;
        let mut supp_next = support_of(&x_next);
        let changed = !supports_equal(&self.supp, &supp_next);
        let mut shrinks_this_iter = 0usize;

        if changed && it > 0 {
            // Line search: μ must satisfy μ ≤ (1−c)·‖dx‖²/‖Φ̂₁dx‖².
            loop {
                if dx_nsq == 0.0 {
                    break; // proposal collapsed onto x — accept
                }
                let b = dx_nsq / phi1_dx_nsq.max(f32::MIN_POSITIVE);
                if mu <= (1.0 - opts.c) * b {
                    break;
                }
                mu /= opts.kappa * (1.0 - opts.c);
                let (xn, dn, pn) = kernel.apply_step(&self.x, &st.g, mu, s);
                x_next = xn;
                dx_nsq = dn;
                phi1_dx_nsq = pn;
                shrinks_this_iter += 1;
                self.shrink_events += 1;
                supp_next = support_of(&x_next);
                if supports_equal(&self.supp, &supp_next) {
                    // Support stabilized: Algorithm 1 only requires the
                    // μ ≤ (1−c)·b guard when the support *moves*, and a
                    // small-enough μ can no longer move it — shrinking
                    // further would just drive μ → 0 and stall the solve.
                    break;
                }
                if shrinks_this_iter > opts.max_shrinks_per_iter {
                    break; // safety valve; μ is ~0 by now
                }
            }
        }

        let stat = IterStat {
            iter: it,
            resid_nsq: st.resid_nsq,
            mu,
            support_changed: changed,
            shrink_count: shrinks_this_iter,
        };
        if opts.track_history {
            self.history.push(stat);
        }

        let x_nsq = linalg::norm2_sq(&self.x);
        self.iters = it + 1;
        self.x = x_next;
        self.supp = supp_next;
        if observer.on_iteration(&stat) == ObserverSignal::Stop {
            self.done = true;
            return;
        }
        if it > 0 && dx_nsq <= opts.tol * opts.tol * x_nsq.max(1e-12) {
            self.converged = true;
            self.done = true;
        }
    }

    pub(crate) fn finish(self) -> SolveResult {
        SolveResult {
            x: self.x,
            iterations: self.iters,
            converged: self.converged,
            shrink_events: self.shrink_events,
            history: self.history,
        }
    }
}

/// Dense full-precision kernel (the 32-bit baseline): Φ̂₁ = Φ̂₂ = Φ.
pub struct DenseKernel<'a> {
    pub phi: &'a Mat,
    pub y: &'a [f32],
}

impl<'a> DenseKernel<'a> {
    pub fn new(phi: &'a Mat, y: &'a [f32]) -> Self {
        assert_eq!(phi.rows, y.len());
        Self { phi, y }
    }

    fn gradient(&self, x: &[f32]) -> (Vec<f32>, f32) {
        let yx = self.phi.matvec(x);
        let r: Vec<f32> = self.y.iter().zip(&yx).map(|(a, b)| a - b).collect();
        let g = self.phi.matvec_t(&r);
        let rn = linalg::norm2_sq(&r);
        (g, rn)
    }
}

impl NihtKernel for DenseKernel<'_> {
    fn m(&self) -> usize {
        self.phi.rows
    }

    fn n(&self) -> usize {
        self.phi.cols
    }

    fn full_step(&mut self, x: &[f32], s: usize) -> StepOut {
        let (g, resid_nsq) = self.gradient(x);
        // Support mask: supp(x), or supp(H_s(g)) on the first iteration.
        let supp = if x.iter().any(|&v| v != 0.0) {
            support_of(x)
        } else {
            top_s_indices(&g, s)
        };
        let mut g_m = vec![0.0f32; g.len()];
        for &i in &supp {
            g_m[i] = g[i];
        }
        let num = linalg::norm2_sq(&g_m);
        let pg = self.phi.matvec_sparse(&supp, &supp.iter().map(|&i| g[i]).collect::<Vec<_>>());
        let den = linalg::norm2_sq(&pg);
        let mu = num / den.max(f32::MIN_POSITIVE);
        let (x_next, dx_nsq, phi1_dx_nsq) = self.apply_step(x, &g, mu, s);
        StepOut { x_next, g, mu, dx_nsq, phi1_dx_nsq, resid_nsq }
    }

    fn apply_step(&mut self, x: &[f32], g: &[f32], mu: f32, s: usize) -> (Vec<f32>, f32, f32) {
        let a: Vec<f32> = x.iter().zip(g).map(|(xi, gi)| xi + mu * gi).collect();
        let x_next = hard_threshold(&a, s);
        let dx: Vec<f32> = x_next.iter().zip(x).map(|(a, b)| a - b).collect();
        let dx_nsq = linalg::norm2_sq(&dx);
        let idx = support_of(&dx);
        let vals: Vec<f32> = idx.iter().map(|&i| dx[i]).collect();
        let phi_dx = self.phi.matvec_sparse(&idx, &vals);
        (x_next, dx_nsq, linalg::norm2_sq(&phi_dx))
    }
}

/// Convenience: full-precision NIHT solve.
///
/// Deprecated shim: new code should route through the
/// [`crate::solver::Recovery`] facade (`SolverKind::Niht`); this free
/// function remains for one release so existing callers keep working.
pub fn niht_dense(phi: &Mat, y: &[f32], s: usize, opts: &SolveOptions) -> SolveResult {
    let mut k = DenseKernel::new(phi, y);
    solve(&mut k, s, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::XorShift128Plus;

    /// Planted sparse problem with a well-conditioned Gaussian matrix.
    fn planted(m: usize, n: usize, s: usize, seed: u64) -> (Mat, Vec<f32>, Vec<f32>) {
        let mut rng = XorShift128Plus::new(seed);
        let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
        let mut x = vec![0.0f32; n];
        for i in rng.choose_k(n, s) {
            x[i] = rng.gaussian_f32() + if rng.uniform() > 0.5 { 1.5 } else { -1.5 };
        }
        let y = phi.matvec(&x);
        (phi, y, x)
    }

    #[test]
    fn recovers_planted_noiseless() {
        let (phi, y, x_true) = planted(64, 128, 5, 1);
        let r = niht_dense(&phi, &y, 5, &SolveOptions::default());
        let err = linalg::norm2(&linalg::sub(&r.x, &x_true)) / linalg::norm2(&x_true);
        assert!(err < 1e-3, "relative error {err}");
        assert!(r.converged);
    }

    #[test]
    fn recovers_support_exactly() {
        let (phi, y, x_true) = planted(80, 160, 8, 2);
        let r = niht_dense(&phi, &y, 8, &SolveOptions::default());
        assert_eq!(support_of(&r.x), support_of(&x_true));
    }

    #[test]
    fn noisy_recovery_error_bounded_by_noise() {
        let (phi, y0, x_true) = planted(96, 192, 6, 3);
        let mut rng = XorShift128Plus::new(30);
        let noise_scale = 0.01;
        let y: Vec<f32> = y0.iter().map(|v| v + noise_scale * rng.gaussian_f32()).collect();
        let r = niht_dense(&phi, &y, 6, &SolveOptions::default());
        let err = linalg::norm2(&linalg::sub(&r.x, &x_true));
        // Theorem 2: error ≈ O(‖e‖/β); allow a generous constant.
        let noise_norm = noise_scale * (96f32).sqrt();
        assert!(err < 10.0 * noise_norm, "err={err} noise={noise_norm}");
    }

    #[test]
    fn result_is_s_sparse() {
        let (phi, y, _) = planted(48, 96, 4, 4);
        let r = niht_dense(&phi, &y, 4, &SolveOptions::default());
        assert!(support_of(&r.x).len() <= 4);
    }

    #[test]
    fn residual_monotone_under_history() {
        let (phi, y, _) = planted(64, 128, 5, 5);
        let opts = SolveOptions { track_history: true, ..Default::default() };
        let r = niht_dense(&phi, &y, 5, &opts);
        let resids: Vec<f32> = r.history.iter().map(|h| h.resid_nsq).collect();
        for w in resids.windows(2) {
            assert!(w[1] <= w[0] * 1.001, "residual must not increase: {resids:?}");
        }
    }

    #[test]
    fn more_iterations_never_hurt() {
        let (phi, y, x_true) = planted(64, 128, 5, 6);
        let r5 = niht_dense(&phi, &y, 5, &SolveOptions { max_iters: 5, ..Default::default() });
        let r50 = niht_dense(&phi, &y, 5, &SolveOptions { max_iters: 50, ..Default::default() });
        let e5 = linalg::norm2(&linalg::sub(&r5.x, &x_true));
        let e50 = linalg::norm2(&linalg::sub(&r50.x, &x_true));
        assert!(e50 <= e5 + 1e-6);
    }

    #[test]
    fn handles_s_equal_one() {
        let (phi, y, x_true) = planted(32, 64, 1, 7);
        let r = niht_dense(&phi, &y, 1, &SolveOptions::default());
        assert_eq!(support_of(&r.x), support_of(&x_true));
    }

    #[test]
    #[should_panic]
    fn rejects_zero_sparsity() {
        let (phi, y, _) = planted(16, 32, 2, 8);
        niht_dense(&phi, &y, 0, &SolveOptions::default());
    }

    #[test]
    fn observer_sees_every_iteration_and_noop_matches_plain_solve() {
        let (phi, y, _) = planted(64, 128, 5, 9);
        let opts = SolveOptions::default();
        let plain = niht_dense(&phi, &y, 5, &opts);
        let mut seen = Vec::new();
        let mut obs = |st: &super::super::IterStat| {
            seen.push(st.iter);
            super::super::ObserverSignal::Continue
        };
        let mut k = DenseKernel::new(&phi, &y);
        let observed = solve_observed(&mut k, 5, &opts, &mut obs);
        assert_eq!(observed.x, plain.x, "noop observer must not change the trajectory");
        assert_eq!(observed.iterations, plain.iterations);
        assert_eq!(seen, (0..plain.iterations).collect::<Vec<_>>());
    }

    #[test]
    fn observer_stop_cancels_early() {
        let (phi, y, _) = planted(64, 128, 5, 10);
        // tol = 0 so the solver cannot converge on its own.
        let opts = SolveOptions::default().with_tol(0.0).with_max_iters(50);
        let mut obs = |st: &super::super::IterStat| {
            if st.iter >= 3 {
                super::super::ObserverSignal::Stop
            } else {
                super::super::ObserverSignal::Continue
            }
        };
        let mut k = DenseKernel::new(&phi, &y);
        let r = solve_observed(&mut k, 5, &opts, &mut obs);
        assert_eq!(r.iterations, 4, "stopped at the end of iteration 3");
        assert!(!r.converged);
        assert!(support_of(&r.x).len() <= 5, "partial iterate is still s-sparse");
    }

    #[test]
    fn max_shrinks_valve_is_configurable() {
        // A tiny valve must not break recovery on a well-conditioned
        // problem (it only caps the pathological-μ loop), and the shrink
        // totals it produces must be no larger than the default's.
        let (phi, y, x_true) = planted(64, 128, 5, 11);
        let tight =
            niht_dense(&phi, &y, 5, &SolveOptions::default().with_max_shrinks_per_iter(1));
        let loose = niht_dense(&phi, &y, 5, &SolveOptions::default());
        assert_eq!(support_of(&tight.x), support_of(&x_true));
        assert!(tight.shrink_events <= loose.shrink_events.max(tight.iterations * 2));
    }
}
