//! Hard thresholding and support-set utilities.
//!
//! `H_s(x)` keeps the `s` largest-magnitude entries. Selection uses an
//! O(n + s·log s) partial quickselect rather than a full sort — this runs
//! once per iteration on a length-N vector, so it matters at sky scale.
//! Ties are broken by lower index (deterministic, matches the canonical
//! top-k semantics used on the JAX side).

/// Indices of the `s` largest |x| entries, ascending index order.
pub fn top_s_indices(x: &[f32], s: usize) -> Vec<usize> {
    let n = x.len();
    if s >= n {
        return (0..n).collect();
    }
    if s == 0 {
        return vec![];
    }
    // Quickselect on (|x|, reverse index) keys to find the s-th largest.
    let mut idx: Vec<usize> = (0..n).collect();
    let key = |i: usize| (x[i].abs(), std::cmp::Reverse(i));
    let (mut lo, mut hi) = (0usize, n);
    let target = s; // want the top `s` in idx[..s]
    while hi - lo > 1 {
        // median-of-three pivot
        let mid = lo + (hi - lo) / 2;
        let mut trio = [idx[lo], idx[mid], idx[hi - 1]];
        trio.sort_by(|&a, &b| key(b).partial_cmp(&key(a)).unwrap());
        let pivot = key(trio[1]);
        // partition: larger-than-pivot first
        let mut i = lo;
        let mut j = hi;
        let mut k = lo;
        while k < j {
            let c = key(idx[k]).partial_cmp(&pivot).unwrap();
            match c {
                std::cmp::Ordering::Greater => {
                    idx.swap(i, k);
                    i += 1;
                    k += 1;
                }
                std::cmp::Ordering::Less => {
                    j -= 1;
                    idx.swap(k, j);
                }
                std::cmp::Ordering::Equal => k += 1,
            }
        }
        // idx[lo..i] > pivot, idx[i..j] == pivot, idx[j..hi] < pivot
        if target <= i {
            hi = i;
        } else if target >= j {
            lo = j;
        } else {
            break; // target falls inside the equal block — done
        }
    }
    let mut out = idx[..s].to_vec();
    out.sort_unstable();
    out
}

/// H_s: zero all but the s largest-magnitude entries.
pub fn hard_threshold(x: &[f32], s: usize) -> Vec<f32> {
    let keep = top_s_indices(x, s);
    let mut out = vec![0.0f32; x.len()];
    for i in keep {
        out[i] = x[i];
    }
    out
}

/// In-place variant writing into `out` (hot-path, no allocation).
pub fn hard_threshold_into(x: &[f32], s: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    out.fill(0.0);
    for i in top_s_indices(x, s) {
        out[i] = x[i];
    }
}

/// Support (indices of nonzeros), ascending.
pub fn support_of(x: &[f32]) -> Vec<usize> {
    x.iter().enumerate().filter(|(_, &v)| v != 0.0).map(|(i, _)| i).collect()
}

/// Set equality of two ascending index lists.
pub fn supports_equal(a: &[usize], b: &[usize]) -> bool {
    a == b
}

/// |a ∩ b| for ascending index lists (merge scan).
pub fn support_intersection(a: &[usize], b: &[usize]) -> usize {
    let (mut i, mut j, mut count) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                count += 1;
                i += 1;
                j += 1;
            }
        }
    }
    count
}

/// Union of two ascending index lists.
pub fn support_union(a: &[usize], b: &[usize]) -> Vec<usize> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() || j < b.len() {
        if j >= b.len() || (i < a.len() && a[i] < b[j]) {
            out.push(a[i]);
            i += 1;
        } else if i >= a.len() || b[j] < a[i] {
            out.push(b[j]);
            j += 1;
        } else {
            out.push(a[i]);
            i += 1;
            j += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::XorShift128Plus;

    fn naive_top_s(x: &[f32], s: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..x.len()).collect();
        idx.sort_by(|&a, &b| {
            (x[b].abs(), std::cmp::Reverse(b))
                .partial_cmp(&(x[a].abs(), std::cmp::Reverse(a)))
                .unwrap()
        });
        let mut out = idx[..s.min(x.len())].to_vec();
        out.sort_unstable();
        out
    }

    #[test]
    fn top_s_matches_naive_random() {
        let mut rng = XorShift128Plus::new(1);
        for trial in 0..50 {
            let n = 1 + rng.below(200);
            let x = rng.gaussian_vec(n);
            let s = rng.below(n + 1);
            assert_eq!(top_s_indices(&x, s), naive_top_s(&x, s), "trial {trial} n={n} s={s}");
        }
    }

    #[test]
    fn top_s_with_ties() {
        let x = vec![1.0, -1.0, 1.0, 1.0];
        // Ties break toward lower index.
        assert_eq!(top_s_indices(&x, 2), vec![0, 1]);
        assert_eq!(top_s_indices(&x, 3), vec![0, 1, 2]);
    }

    #[test]
    fn top_s_zero_and_full() {
        let x = vec![3.0, 1.0, 2.0];
        assert_eq!(top_s_indices(&x, 0), Vec::<usize>::new());
        assert_eq!(top_s_indices(&x, 3), vec![0, 1, 2]);
        assert_eq!(top_s_indices(&x, 10), vec![0, 1, 2]);
    }

    #[test]
    fn hard_threshold_keeps_exactly_s() {
        let mut rng = XorShift128Plus::new(2);
        let x = rng.gaussian_vec(100);
        for s in [1usize, 7, 50, 100] {
            let h = hard_threshold(&x, s);
            assert_eq!(support_of(&h).len(), s);
        }
    }

    #[test]
    fn hard_threshold_values_preserved() {
        let x = vec![0.1, -5.0, 2.0, 0.01, -3.0];
        assert_eq!(hard_threshold(&x, 2), vec![0.0, -5.0, 0.0, 0.0, -3.0]);
    }

    #[test]
    fn hard_threshold_idempotent() {
        let mut rng = XorShift128Plus::new(3);
        let x = rng.gaussian_vec(64);
        let once = hard_threshold(&x, 8);
        let twice = hard_threshold(&once, 8);
        assert_eq!(once, twice);
    }

    #[test]
    fn hard_threshold_into_matches() {
        let mut rng = XorShift128Plus::new(4);
        let x = rng.gaussian_vec(64);
        let mut out = vec![9.0f32; 64];
        hard_threshold_into(&x, 5, &mut out);
        assert_eq!(out, hard_threshold(&x, 5));
    }

    #[test]
    fn set_ops() {
        let a = vec![1, 3, 5, 7];
        let b = vec![3, 4, 7, 9];
        assert_eq!(support_intersection(&a, &b), 2);
        assert_eq!(support_union(&a, &b), vec![1, 3, 4, 5, 7, 9]);
        assert!(supports_equal(&a, &a.clone()));
        assert!(!supports_equal(&a, &b));
    }
}
