//! CLEAN (Högbom 1974) — Algorithm 2 of the paper's supplementary.
//!
//! Deconvolution baseline for Fig 9: start from the dirty image, iteratively
//! find the peak of the residual map, subtract `loop_gain · peak` times the
//! dirty beam centered at the peak, and record the component. At 0 dB SNR
//! CLEAN picks up noise artefacts as sources (the paper's point — "an
//! execution of CLEAN corresponds to the first iteration recovery of IHT").

use crate::linalg::Mat;

#[derive(Debug, Clone)]
pub struct CleanOptions {
    /// Loop gain λ ≤ 0.3 (paper footnote 2).
    pub loop_gain: f32,
    /// Stop when the residual peak falls below this threshold.
    pub threshold: f32,
    pub max_components: usize,
}

impl Default for CleanOptions {
    fn default() -> Self {
        Self { loop_gain: 0.2, threshold: 0.05, max_components: 1000 }
    }
}

/// One CLEAN component: (pixel index, flux).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CleanComponent {
    pub pixel: usize,
    pub flux: f32,
}

#[derive(Debug, Clone)]
pub struct CleanResult {
    pub components: Vec<CleanComponent>,
    /// Residual map after the loop.
    pub residual: Vec<f32>,
    pub iterations: usize,
}

/// Run CLEAN on a dirty image (r×r, row-major) with a (2r−1)×(2r−1) dirty
/// beam patch normalized to beam(center) = 1.
pub fn clean(dirty: &[f32], beam: &Mat, resolution: usize, opts: &CleanOptions) -> CleanResult {
    let r = resolution;
    assert_eq!(dirty.len(), r * r);
    assert_eq!(beam.rows, 2 * r - 1);
    assert_eq!(beam.cols, 2 * r - 1);
    let mut residual = dirty.to_vec();
    let mut components: Vec<CleanComponent> = Vec::new();
    let mut iterations = 0;

    for _ in 0..opts.max_components {
        // Peak of the residual map (positive peaks: sky intensities ≥ 0).
        let (p, &peak) = residual
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        if peak < opts.threshold {
            break;
        }
        iterations += 1;
        let flux = opts.loop_gain * peak;
        let (pr, pc) = (p / r, p % r);
        // Subtract flux · beam(Δ) over the whole map.
        for row in 0..r {
            let dr = row as isize - pr as isize + (r as isize - 1);
            for col in 0..r {
                let dc = col as isize - pc as isize + (r as isize - 1);
                residual[row * r + col] -= flux * beam.at(dr as usize, dc as usize);
            }
        }
        // Merge repeated components at the same pixel.
        if let Some(c) = components.iter_mut().find(|c| c.pixel == p) {
            c.flux += flux;
        } else {
            components.push(CleanComponent { pixel: p, flux });
        }
    }

    CleanResult { components, residual, iterations }
}

/// Render the component list as a sky vector.
pub fn components_to_sky(components: &[CleanComponent], n: usize) -> Vec<f32> {
    let mut x = vec![0.0f32; n];
    for c in components {
        x[c.pixel] += c.flux;
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::XorShift128Plus;
    use crate::telescope::{dirty, steering, visibility, AntennaArray, ImageGrid};

    fn setup(seed: u64) -> (AntennaArray, ImageGrid, Mat) {
        let mut rng = XorShift128Plus::new(seed);
        let a = AntennaArray::lofar_like(10, 50e6, &mut rng);
        let g = ImageGrid::new(16, 0.4);
        let phi = steering::stacked_measurement_matrix(&a, &g);
        (a, g, phi)
    }

    #[test]
    fn finds_single_bright_source() {
        let (a, g, phi) = setup(1);
        let mut x = vec![0.0f32; g.pixels()];
        let src = g.index(5, 9);
        x[src] = 1.0;
        let y = visibility::observe_clean(&phi, &x);
        let img = dirty::dirty_image(&phi, &y);
        let beam = dirty::dirty_beam(&a, &g);
        let res = clean(&img, &beam, 16, &CleanOptions::default());
        assert!(!res.components.is_empty());
        // The strongest component must be at the source pixel.
        let strongest = res
            .components
            .iter()
            .max_by(|u, v| u.flux.partial_cmp(&v.flux).unwrap())
            .unwrap();
        assert_eq!(strongest.pixel, src);
    }

    #[test]
    fn recovered_flux_approaches_truth() {
        let (a, g, phi) = setup(2);
        let mut x = vec![0.0f32; g.pixels()];
        let src = g.index(8, 8);
        x[src] = 1.0;
        let y = visibility::observe_clean(&phi, &x);
        let img = dirty::dirty_image(&phi, &y);
        let beam = dirty::dirty_beam(&a, &g);
        let opts = CleanOptions { threshold: 0.02, max_components: 5000, ..Default::default() };
        let res = clean(&img, &beam, 16, &opts);
        let sky = components_to_sky(&res.components, g.pixels());
        assert!((sky[src] - 1.0).abs() < 0.25, "flux={}", sky[src]);
    }

    #[test]
    fn residual_peak_below_threshold_at_exit() {
        let (a, g, phi) = setup(3);
        let mut x = vec![0.0f32; g.pixels()];
        x[g.index(3, 12)] = 0.8;
        let y = visibility::observe_clean(&phi, &x);
        let img = dirty::dirty_image(&phi, &y);
        let beam = dirty::dirty_beam(&a, &g);
        let opts = CleanOptions { threshold: 0.05, max_components: 5000, ..Default::default() };
        let res = clean(&img, &beam, 16, &opts);
        let peak = res.residual.iter().cloned().fold(f32::MIN, f32::max);
        assert!(peak < 0.05, "peak={peak}");
    }

    #[test]
    fn noise_generates_spurious_components() {
        // The Fig 9 phenomenon: at 0 dB, CLEAN reports far more components
        // than true sources.
        let (a, g, phi) = setup(4);
        let mut rng = XorShift128Plus::new(44);
        let mut x = vec![0.0f32; g.pixels()];
        for i in rng.choose_k(g.pixels(), 3) {
            x[i] = 1.0;
        }
        let (y, _) = visibility::observe(&phi, &x, 0.0, &mut rng, 10);
        let img = dirty::dirty_image(&phi, &y);
        let beam = dirty::dirty_beam(&a, &g);
        let res = clean(&img, &beam, 16, &CleanOptions::default());
        assert!(res.components.len() > 3, "CLEAN at 0 dB should over-detect");
    }

    #[test]
    fn empty_sky_no_components() {
        let (a, g, phi) = setup(5);
        let y = vec![0.0f32; phi.rows];
        let img = dirty::dirty_image(&phi, &y);
        let beam = dirty::dirty_beam(&a, &g);
        let res = clean(&img, &beam, 16, &CleanOptions::default());
        assert!(res.components.is_empty());
    }
}
