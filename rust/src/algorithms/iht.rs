//! Plain IHT (Blumensath & Davies 2008): `x ← H_s(x + Φᵀ(y − Φx))` with
//! unit step. Convergence requires ‖Φ‖₂ < 1, so the solver rescales the
//! problem internally (`Φ/η, y/η` with `η = 1.01·σ_max` — the "re-scaling
//! of the measurement matrix" the paper's Remark 1 says NIHT makes
//! unnecessary) and un-scales the result. Kept as the classical baseline.

use super::support::{hard_threshold, support_of, supports_equal};
use super::{IterObserver, IterStat, NoopObserver, ObserverSignal, SolveOptions, SolveResult};
use crate::linalg::{self, svd, Mat};

/// Deprecated shim: new code should route through the
/// [`crate::solver::Recovery`] facade (`SolverKind::Iht`).
pub fn iht(phi: &Mat, y: &[f32], s: usize, opts: &SolveOptions) -> SolveResult {
    iht_observed(phi, y, s, opts, &mut NoopObserver)
}

/// [`iht`] with a per-iteration [`IterObserver`] (progress streaming /
/// cancellation). `resid_nsq` in the reported stats is measured on the
/// internally rescaled problem (Φ/η, y/η); `mu` is the unit step.
pub fn iht_observed(
    phi: &Mat,
    y: &[f32],
    s: usize,
    opts: &SolveOptions,
    observer: &mut dyn IterObserver,
) -> SolveResult {
    assert_eq!(phi.rows, y.len());
    let sigma = svd::spectral_norm(phi, 1e-5, 2000, 0x1417);
    let eta = 1.01 * sigma.max(f32::MIN_POSITIVE);
    let mut phi_s = phi.clone();
    phi_s.scale(1.0 / eta);
    let y_s: Vec<f32> = y.iter().map(|v| v / eta).collect();

    let n = phi.cols;
    let mut x = vec![0.0f32; n];
    let mut converged = false;
    let mut iters = 0;
    let mut history = Vec::new();
    for it in 0..opts.max_iters {
        let r = linalg::sub(&y_s, &phi_s.matvec(&x));
        let g = phi_s.matvec_t(&r);
        let a: Vec<f32> = x.iter().zip(&g).map(|(xi, gi)| xi + gi).collect();
        let x_next = hard_threshold(&a, s);
        let dx_nsq = linalg::norm2_sq(&linalg::sub(&x_next, &x));
        let x_nsq = linalg::norm2_sq(&x);
        let stat = IterStat {
            iter: it,
            resid_nsq: linalg::norm2_sq(&r),
            mu: 1.0,
            support_changed: !supports_equal(&support_of(&x), &support_of(&x_next)),
            shrink_count: 0,
        };
        if opts.track_history {
            history.push(stat);
        }
        x = x_next;
        iters = it + 1;
        if observer.on_iteration(&stat) == ObserverSignal::Stop {
            break;
        }
        if it > 0 && dx_nsq <= opts.tol * opts.tol * x_nsq.max(1e-12) {
            converged = true;
            break;
        }
    }
    SolveResult { x, iterations: iters, converged, shrink_events: 0, history }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::support::support_of;
    use crate::rng::XorShift128Plus;

    fn planted(m: usize, n: usize, s: usize, seed: u64) -> (Mat, Vec<f32>, Vec<f32>) {
        let mut rng = XorShift128Plus::new(seed);
        let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
        let mut x = vec![0.0f32; n];
        for i in rng.choose_k(n, s) {
            x[i] = 2.0 * rng.gaussian_f32().signum() + rng.gaussian_f32() * 0.2;
        }
        let y = phi.matvec(&x);
        (phi, y, x)
    }

    #[test]
    fn recovers_planted_noiseless() {
        let (phi, y, x_true) = planted(80, 160, 5, 1);
        let opts = SolveOptions { max_iters: 500, ..Default::default() };
        let r = iht(&phi, &y, 5, &opts);
        assert_eq!(support_of(&r.x), support_of(&x_true));
        let rel = linalg::norm2(&linalg::sub(&r.x, &x_true)) / linalg::norm2(&x_true);
        assert!(rel < 0.05, "rel={rel}");
    }

    #[test]
    fn rescaling_makes_it_converge_on_unscaled_matrix() {
        // Entries O(1): ‖Φ‖ ≫ 1 — plain IHT without rescaling would diverge.
        let mut rng = XorShift128Plus::new(2);
        let phi = Mat::from_fn(40, 80, |_, _| rng.gaussian_f32());
        let mut x_true = vec![0.0f32; 80];
        x_true[3] = 1.0;
        x_true[50] = -2.0;
        let y = phi.matvec(&x_true);
        let r = iht(&phi, &y, 2, &SolveOptions { max_iters: 500, ..Default::default() });
        assert!(r.x.iter().all(|v| v.is_finite()));
        assert_eq!(support_of(&r.x), vec![3, 50]);
    }

    #[test]
    fn output_is_s_sparse() {
        let (phi, y, _) = planted(40, 80, 3, 3);
        let r = iht(&phi, &y, 3, &SolveOptions::default());
        assert!(support_of(&r.x).len() <= 3);
    }
}
