//! QNIHT — the paper's contribution: NIHT over quantized operands.
//!
//! The kernel holds Φ̂ in two orientations (codes2 = Φ̂₂ row-major for
//! `Φ̂x`; codes1_t = Φ̂₁ᵀ row-major for the gradient `Φ̂₁ᵀr` *and* the
//! line-search norm `Φ̂₁dx` via the sparse scale-and-add) plus the
//! quantized observation ŷ — exactly the two routines + data layout of the
//! paper's CPU implementation (§9).
//!
//! Quantization modes:
//! * [`RequantMode::Fixed`] — quantize once, reuse every iteration. This is
//!   what the CPU/FPGA systems do: the full-precision matrix is never
//!   touched after setup, so the bandwidth saving is real.
//! * [`RequantMode::Fresh`] — draw independent Φ̂₂ₙ₋₁, Φ̂₂ₙ each iteration
//!   from the retained full-precision Φ (Algorithm 1's
//!   `{Φ̂₁ … Φ̂₂ₙ*}`) — the theory-faithful mode used to validate
//!   Theorem 3's expectation bound.
//!
//! Every packed kernel this module drives (`packed_matvec`,
//! `packed_scale_add`, `packed_matvec_q8`) dispatches through the runtime
//! SIMD backend layer ([`crate::simd`]) and runs its row loops on the
//! persistent [`crate::par`] pool, so per-iteration cost is kernel time,
//! not thread-spawn or dispatch overhead. [`QuantKernel::simd_backend`]
//! reports which backend this process selected.
//!
//! The quantize+pack product itself is factored out as [`PreparedPhi`]:
//! the engine registry's batched path builds it once per batch of
//! batch-key-equal jobs and binds per-job kernels to the shared `Arc`
//! via [`QuantKernel::with_prepared`].

use super::niht::{solve, IterDriver};
use super::support::{hard_threshold, support_of, top_s_indices};
use super::{IterStat, NihtKernel, ObserverSignal, SolveOptions, SolveResult, StepOut};
use crate::linalg::{self, Mat};
use crate::lowprec;
use crate::quant::packed::PackedMatrix;
use crate::quant::{QuantizedMatrix, Quantizer};
use crate::rng::XorShift128Plus;
use std::sync::Arc;

/// How Φ̂ is refreshed across iterations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RequantMode {
    /// One quantization, reused (systems mode — default).
    Fixed,
    /// Fresh independent quantizations each iteration (theory mode).
    Fresh,
}

/// The immutable product of one QNIHT setup pass over Φ: quantized codes
/// in both orientations plus the bit-packed buffers (when the width has a
/// packed kernel). This is the expensive part of building a
/// [`QuantKernel`], so the coordinator shares one `Arc<PreparedPhi>`
/// across every batch-key-equal job (one quantize+pack amortized over the
/// batch); `Fresh` mode builds an unpacked one per iteration.
pub struct PreparedPhi {
    /// Φ̂₂ codes, m×n row-major.
    codes2: QuantizedMatrix,
    /// Φ̂₁ᵀ codes, n×m row-major.
    codes1_t: QuantizedMatrix,
    /// Packed Φ̂₂ (Fixed mode only).
    packed2: Option<PackedMatrix>,
    /// Packed Φ̂₁ᵀ = Φ̂ᵀ (Fixed mode only: Φ̂₁ = Φ̂₂).
    packed1_t: Option<PackedMatrix>,
}

impl PreparedPhi {
    /// Fixed-mode quantization: ONE stored quantized matrix (Φ̂₁ = Φ̂₂ =
    /// Φ̂), bit-packed when `bits_phi ∈ {2, 4, 8}`. One stored matrix is
    /// the systems setting (one packed buffer in memory) and it makes g
    /// the exact gradient of ‖ŷ − Φ̂x‖², so NIHT's descent guarantees
    /// apply to the quantized problem. Independent Φ̂₁ ≠ Φ̂₂ only makes
    /// sense with FRESH draws every iteration (Theorem 3's expectation);
    /// a *fixed* mismatched pair is a biased cross-gradient and can
    /// oscillate at 2 bits.
    pub fn quantize(phi: &Mat, bits_phi: u8, seed: u64) -> Self {
        Self::fixed_with_rng(phi, bits_phi, &mut XorShift128Plus::new(seed))
    }

    fn fixed_with_rng(phi: &Mat, bits_phi: u8, rng: &mut XorShift128Plus) -> Self {
        let codes2 = QuantizedMatrix::from_mat(phi, bits_phi, rng);
        let codes1_t = codes2.transposed();
        let (packed2, packed1_t) = if matches!(bits_phi, 2 | 4 | 8) {
            (Some(PackedMatrix::pack(&codes2)), Some(PackedMatrix::pack(&codes1_t)))
        } else {
            (None, None)
        };
        Self { codes2, codes1_t, packed2, packed1_t }
    }

    /// Fresh-mode draw: independent Φ̂₂ / Φ̂₁ᵀ at a shared scale, unpacked
    /// (the fresh path re-quantizes every iteration, so packing would cost
    /// more than it saves).
    fn fresh_with_rng(phi: &Mat, bits_phi: u8, scale: Option<f32>, rng: &mut XorShift128Plus) -> Self {
        let codes2 = match scale {
            None => QuantizedMatrix::from_mat(phi, bits_phi, rng),
            Some(sc) => QuantizedMatrix::from_mat_with_scale(phi, bits_phi, sc, rng),
        };
        let phi_t = phi.transpose();
        let codes1_t =
            QuantizedMatrix::from_mat_with_scale(&phi_t, bits_phi, codes2.scale, rng);
        Self { codes2, codes1_t, packed2: None, packed1_t: None }
    }

    pub fn m(&self) -> usize {
        self.codes2.m
    }

    pub fn n(&self) -> usize {
        self.codes2.n
    }

    pub fn bits(&self) -> u8 {
        self.codes2.bits
    }

    /// Bytes of Φ̂ traffic per full step at the ideal packed width.
    pub fn bytes_ideal(&self) -> usize {
        self.codes2.bytes_ideal() + self.codes1_t.bytes_ideal()
    }

    /// Batched gradient matvecs: Φ̂₁ᵀ rⱼ for every residual in `rs`. On
    /// the packed path this is ONE multi-RHS sweep
    /// ([`lowprec::packed_matvec_multi`]) that decodes each packed Φ̂ᵀ row
    /// once for the whole batch instead of once per job; the unpacked
    /// fallback loops the single-RHS matvec. Either way each returned
    /// gradient is bit-identical to the sequential kernel's
    /// `phi1t_v(rs[j])` — the multi-RHS kernel contract.
    pub(crate) fn gradients_multi(&self, rs: &[&[f32]]) -> Vec<Vec<f32>> {
        if let Some(p1t) = &self.packed1_t {
            return lowprec::packed_matvec_multi(p1t, rs);
        }
        rs.iter()
            .map(|r| {
                lowprec::qmatvec(
                    &self.codes1_t.codes,
                    self.n(),
                    self.m(),
                    self.codes1_t.multiplier(),
                    r,
                )
            })
            .collect()
    }
}

/// Quantized NIHT kernel (native execution engine).
///
/// In `Fixed` mode the matrix is stored BIT-PACKED (b bits per code) and
/// every matvec streams the packed words through `lowprec::packed_matvec`
/// / `packed_scale_add` — the traffic per iteration is genuinely
/// `m·n·b/8` bytes, which is where the Fig 5 speedup comes from. `Fresh`
/// mode re-quantizes each iteration (theory mode) and uses the unpacked
/// int8 path.
pub struct QuantKernel {
    /// Quantized (and, in Fixed mode, packed) Φ̂ — shareable across
    /// kernels recovering different observations against the same Φ.
    phi_hat: Arc<PreparedPhi>,
    /// Dequantized observation ŷ (f32 image of Q(y)).
    y_hat: Vec<f32>,
    mode: RequantMode,
    /// Full-precision Φ retained only in `Fresh` mode.
    full: Option<Mat>,
    rng: XorShift128Plus,
    m: usize,
    n: usize,
}

impl QuantKernel {
    /// Quantize a problem: Φ at `bits_phi`, y at `bits_y`.
    pub fn new(
        phi: &Mat,
        y: &[f32],
        bits_phi: u8,
        bits_y: u8,
        mode: RequantMode,
        seed: u64,
    ) -> Self {
        assert_eq!(phi.rows, y.len());
        let mut rng = XorShift128Plus::new(seed);
        let phi_hat = Arc::new(match mode {
            RequantMode::Fixed => PreparedPhi::fixed_with_rng(phi, bits_phi, &mut rng),
            RequantMode::Fresh => PreparedPhi::fresh_with_rng(phi, bits_phi, None, &mut rng),
        });
        let qy = Quantizer::new(bits_y);
        let (y_codes, y_scale) = qy.quantize_auto(y, &mut rng);
        let y_hat = qy.dequantize_slice(&y_codes, y_scale);
        let full = match mode {
            RequantMode::Fixed => None,
            RequantMode::Fresh => Some(phi.clone()),
        };
        Self { phi_hat, y_hat, mode, full, rng, m: phi.rows, n: phi.cols }
    }

    /// Bind an already-quantized Φ̂ to a new observation — the batched
    /// entry point: the coordinator quantizes/packs Φ once per batch and
    /// builds one kernel per job from the shared `Arc`. Always Fixed mode
    /// (a shared Φ̂ is by definition not redrawn); `seed` drives the
    /// stochastic y quantization only.
    pub fn with_prepared(phi_hat: Arc<PreparedPhi>, y: &[f32], bits_y: u8, seed: u64) -> Self {
        assert_eq!(phi_hat.m(), y.len());
        let mut rng = XorShift128Plus::new(seed);
        let qy = Quantizer::new(bits_y);
        let (y_codes, y_scale) = qy.quantize_auto(y, &mut rng);
        let y_hat = qy.dequantize_slice(&y_codes, y_scale);
        let (m, n) = (phi_hat.m(), phi_hat.n());
        Self { phi_hat, y_hat, mode: RequantMode::Fixed, full: None, rng, m, n }
    }

    /// Bytes of Φ̂ traffic per full step at the ideal packed width
    /// (gradient streams Φ̂₁ᵀ once, the residual matvec streams Φ̂₂ once).
    pub fn bytes_per_iteration(&self) -> usize {
        self.phi_hat.bytes_ideal()
    }

    pub fn bits_phi(&self) -> u8 {
        self.phi_hat.bits()
    }

    /// Name of the SIMD kernel backend executing this kernel's matvecs
    /// ("vnni", "avx2", "neon", or "scalar") — diagnostics / bench labels.
    pub fn simd_backend(&self) -> &'static str {
        crate::simd::backend_name()
    }

    /// Φ̂₂ x (sparse x → the paper's dense scale-and-add over columns).
    fn phi2_x(&self, x: &[f32]) -> Vec<f32> {
        let ph = &*self.phi_hat;
        let supp = support_of(x);
        if !supp.is_empty() && supp.len() * 8 < self.n {
            let vals: Vec<f32> = supp.iter().map(|&i| x[i]).collect();
            // Fixed mode: columns of Φ̂₂ are the rows of packed1_t.
            if let Some(p1t) = &ph.packed1_t {
                return lowprec::packed_scale_add(p1t, &supp, &vals);
            }
            return lowprec::qmatvec_sparse_cols(
                &ph.codes2.codes,
                self.m,
                self.n,
                ph.codes2.multiplier(),
                &supp,
                &vals,
            );
        }
        if let Some(p2) = &ph.packed2 {
            return lowprec::packed_matvec(p2, x);
        }
        lowprec::qmatvec(&ph.codes2.codes, self.m, self.n, ph.codes2.multiplier(), x)
    }

    /// Φ̂₁ᵀ v — the gradient matvec (streams the packed Φ̂ᵀ in Fixed mode).
    fn phi1t_v(&self, v: &[f32]) -> Vec<f32> {
        let ph = &*self.phi_hat;
        if let Some(p1t) = &ph.packed1_t {
            return lowprec::packed_matvec(p1t, v);
        }
        lowprec::qmatvec(&ph.codes1_t.codes, self.n, self.m, ph.codes1_t.multiplier(), v)
    }

    /// Φ̂₁ applied to a sparse vector (line-search norm).
    fn phi1_sparse(&self, idx: &[usize], vals: &[f32]) -> Vec<f32> {
        let ph = &*self.phi_hat;
        if let Some(p1t) = &ph.packed1_t {
            return lowprec::packed_scale_add(p1t, idx, vals);
        }
        lowprec::qmatvec_sparse(
            &ph.codes1_t.codes,
            self.n,
            self.m,
            ph.codes1_t.multiplier(),
            idx,
            vals,
        )
    }

    pub(crate) fn residual(&self, x: &[f32]) -> Vec<f32> {
        let yx = self.phi2_x(x);
        self.y_hat.iter().zip(&yx).map(|(a, b)| a - b).collect()
    }

    /// The tail of [`NihtKernel::full_step`] once the gradient is in hand:
    /// support selection, adaptive μ, proposed iterate. Factored out so the
    /// lockstep batch driver ([`solve_batch_lockstep`]) can substitute a
    /// gradient computed by the batched multi-RHS matvec while reusing the
    /// exact per-job arithmetic of the sequential path — the two stay
    /// bit-identical by sharing this one body.
    pub(crate) fn step_from_gradient(
        &mut self,
        x: &[f32],
        s: usize,
        g: Vec<f32>,
        resid_nsq: f32,
    ) -> StepOut {
        let supp = if x.iter().any(|&v| v != 0.0) {
            support_of(x)
        } else {
            top_s_indices(&g, s)
        };
        let vals: Vec<f32> = supp.iter().map(|&i| g[i]).collect();
        let num: f32 = vals.iter().map(|v| v * v).sum();
        // Φ̂₂ g_Γ restricted to the support (packed scale-and-add in
        // Fixed mode, dense column-restricted matvec otherwise).
        let ph = &*self.phi_hat;
        let pg = if let Some(p1t) = &ph.packed1_t {
            lowprec::packed_scale_add(p1t, &supp, &vals)
        } else {
            lowprec::qmatvec_sparse_cols(
                &ph.codes2.codes,
                self.m,
                self.n,
                ph.codes2.multiplier(),
                &supp,
                &vals,
            )
        };
        let den = linalg::norm2_sq(&pg);
        let mu = num / den.max(f32::MIN_POSITIVE);
        let (x_next, dx_nsq, phi1_dx_nsq) = self.apply_step(x, &g, mu, s);
        StepOut { x_next, g, mu, dx_nsq, phi1_dx_nsq, resid_nsq }
    }
}

impl NihtKernel for QuantKernel {
    fn m(&self) -> usize {
        self.m
    }

    fn n(&self) -> usize {
        self.n
    }

    fn begin_iteration(&mut self, _iter: usize) {
        if self.mode == RequantMode::Fresh {
            let phi = self.full.take().expect("Fresh mode retains Φ");
            let bits = self.phi_hat.bits();
            let scale = self.phi_hat.codes2.scale;
            self.phi_hat =
                Arc::new(PreparedPhi::fresh_with_rng(&phi, bits, Some(scale), &mut self.rng));
            self.full = Some(phi);
        }
    }

    fn full_step(&mut self, x: &[f32], s: usize) -> StepOut {
        let r = self.residual(x);
        let resid_nsq = linalg::norm2_sq(&r);
        // g = Φ̂₁ᵀ r — a row-major matvec over the transposed buffer.
        let g = self.phi1t_v(&r);
        self.step_from_gradient(x, s, g, resid_nsq)
    }

    fn apply_step(&mut self, x: &[f32], g: &[f32], mu: f32, s: usize) -> (Vec<f32>, f32, f32) {
        let a: Vec<f32> = x.iter().zip(g).map(|(xi, gi)| xi + mu * gi).collect();
        let x_next = hard_threshold(&a, s);
        let dx: Vec<f32> = x_next.iter().zip(x).map(|(a, b)| a - b).collect();
        let dx_nsq = linalg::norm2_sq(&dx);
        // ‖Φ̂₁ dx‖²: columns of Φ̂₁ are rows of codes1_t — sparse scale-and-add.
        let idx = support_of(&dx);
        let vals: Vec<f32> = idx.iter().map(|&i| dx[i]).collect();
        let p1dx = self.phi1_sparse(&idx, &vals);
        (x_next, dx_nsq, linalg::norm2_sq(&p1dx))
    }
}

/// One observation in a lockstep batch — what [`solve_batch_lockstep`]
/// needs to bind a [`QuantKernel`] to the shared Φ̂. Φ̂'s own seed lives in
/// the prepared matrix; `seed` drives only the stochastic y quantization.
pub struct BatchJob<'a> {
    pub y: &'a [f32],
    pub bits_y: u8,
    pub seed: u64,
}

/// [`NihtKernel`] adapter the lockstep driver wraps around a
/// [`QuantKernel`] for one `advance` call: `full_step` consumes a gradient
/// already produced by the batched multi-RHS matvec instead of issuing its
/// own, so the per-row unpack of Φ̂ᵀ is amortized across the batch while
/// [`IterDriver::advance`] sees the ordinary kernel interface (line-search
/// `apply_step` calls pass straight through).
struct PrecomputedStep<'a> {
    inner: &'a mut QuantKernel,
    g: Option<Vec<f32>>,
    resid_nsq: f32,
}

impl NihtKernel for PrecomputedStep<'_> {
    fn m(&self) -> usize {
        self.inner.m
    }

    fn n(&self) -> usize {
        self.inner.n
    }

    fn full_step(&mut self, x: &[f32], s: usize) -> StepOut {
        let g = self.g.take().expect("one full_step per lockstep advance");
        self.inner.step_from_gradient(x, s, g, self.resid_nsq)
    }

    fn apply_step(&mut self, x: &[f32], g: &[f32], mu: f32, s: usize) -> (Vec<f32>, f32, f32) {
        self.inner.apply_step(x, g, mu, s)
    }
}

/// Solve a batch of observations against one shared Φ̂ in LOCKSTEP: all
/// still-running jobs advance through global iteration `it` together, and
/// their gradients Φ̂₁ᵀrⱼ come from ONE batched multi-RHS matvec
/// ([`PreparedPhi::gradients_multi`]) that decodes each packed Φ̂ᵀ row once
/// for the whole batch instead of once per job — the bandwidth win the
/// multi-RHS kernels exist for.
///
/// Every job's trajectory is bit-identical to a sequential
/// [`QuantKernel::with_prepared`] + [`super::niht::solve_observed`] run
/// with the same seeds, independent of batch composition: the iteration
/// body is the shared [`IterDriver`], the multi-RHS kernels are
/// bit-identical per RHS to the single-RHS kernels (their contract), and a
/// job that finishes early simply drops out of the batched matvec without
/// perturbing the others (active jobs never pause, so each job's local
/// iteration count equals the global `it`).
///
/// `observe(j, stat)` fires once per active job per iteration, after job
/// `j`'s iterate updates; returning [`ObserverSignal::Stop`] cancels job
/// `j` alone.
pub fn solve_batch_lockstep(
    prepared: &Arc<PreparedPhi>,
    jobs: &[BatchJob<'_>],
    s: usize,
    opts: &SolveOptions,
    observe: &mut dyn FnMut(usize, &IterStat) -> ObserverSignal,
) -> Vec<SolveResult> {
    if jobs.is_empty() {
        return Vec::new();
    }
    let n = prepared.n();
    assert!(s >= 1, "sparsity must be >= 1");
    assert!(s <= n, "sparsity exceeds dimension");
    let mut kernels: Vec<QuantKernel> = jobs
        .iter()
        .map(|j| QuantKernel::with_prepared(prepared.clone(), j.y, j.bits_y, j.seed))
        .collect();
    let mut drivers: Vec<IterDriver> = (0..jobs.len()).map(|_| IterDriver::new(n)).collect();
    let mut active: Vec<usize> = (0..jobs.len()).collect();
    for it in 0..opts.max_iters {
        if active.is_empty() {
            break;
        }
        for &j in &active {
            kernels[j].begin_iteration(it);
        }
        // Per-job residuals (sparse-x phase, cheap), then one batched
        // gradient sweep over the shared packed Φ̂ᵀ for every RHS.
        let rs: Vec<Vec<f32>> = active
            .iter()
            .map(|&j| kernels[j].residual(&drivers[j].x))
            .collect();
        let resid_nsqs: Vec<f32> = rs.iter().map(|r| linalg::norm2_sq(r)).collect();
        let r_refs: Vec<&[f32]> = rs.iter().map(|r| r.as_slice()).collect();
        let gs = prepared.gradients_multi(&r_refs);
        for ((&j, g), &resid_nsq) in active.iter().zip(gs).zip(&resid_nsqs) {
            let mut pk = PrecomputedStep { inner: &mut kernels[j], g: Some(g), resid_nsq };
            let mut obs = |st: &IterStat| observe(j, st);
            drivers[j].advance(&mut pk, it, s, opts, &mut obs);
        }
        active.retain(|&j| !drivers[j].done);
    }
    drivers.into_iter().map(IterDriver::finish).collect()
}

/// Convenience: quantized NIHT solve (the paper's `b_Φ & b_y` variants).
///
/// Deprecated shim: new code should route through the
/// [`crate::solver::Recovery`] facade (`SolverKind::Qniht`); this free
/// function remains for one release so existing callers keep working.
pub fn qniht(
    phi: &Mat,
    y: &[f32],
    s: usize,
    bits_phi: u8,
    bits_y: u8,
    mode: RequantMode,
    seed: u64,
    opts: &SolveOptions,
) -> SolveResult {
    let mut k = QuantKernel::new(phi, y, bits_phi, bits_y, mode, seed);
    solve(&mut k, s, opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn planted(m: usize, n: usize, s: usize, seed: u64) -> (Mat, Vec<f32>, Vec<f32>) {
        let mut rng = XorShift128Plus::new(seed);
        let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
        let mut x = vec![0.0f32; n];
        for i in rng.choose_k(n, s) {
            x[i] = rng.gaussian_f32() + if rng.uniform() > 0.5 { 2.0 } else { -2.0 };
        }
        let y = phi.matvec(&x);
        (phi, y, x)
    }

    #[test]
    fn qniht_8bit_recovers_support() {
        let (phi, y, x_true) = planted(96, 192, 6, 1);
        let r = qniht(&phi, &y, 6, 8, 8, RequantMode::Fixed, 42, &SolveOptions::default());
        assert_eq!(support_of(&r.x), support_of(&x_true));
    }

    #[test]
    fn qniht_8bit_error_small() {
        let (phi, y, x_true) = planted(96, 192, 6, 2);
        let r = qniht(&phi, &y, 6, 8, 8, RequantMode::Fixed, 43, &SolveOptions::default());
        let rel = linalg::norm2(&linalg::sub(&r.x, &x_true)) / linalg::norm2(&x_true);
        assert!(rel < 0.05, "rel={rel}");
    }

    #[test]
    fn qniht_2bit_fresh_recovers_support() {
        // 2-bit Φ & 8-bit y on a Gaussian problem (paper §10: "performs
        // slightly worse ... robust to noise as good as 32 bit"). Fresh
        // quantizations per iteration (Algorithm 1's setting) average the
        // rounding noise out and recover nearly the full support.
        let (phi, y, x_true) = planted(192, 256, 5, 3);
        let r = qniht(&phi, &y, 5, 2, 8, RequantMode::Fresh, 44, &SolveOptions::default());
        let st = support_of(&x_true);
        let sr = support_of(&r.x);
        let inter = super::super::support::support_intersection(&st, &sr);
        assert!(inter >= 4, "recovered {inter}/5");
    }

    #[test]
    fn qniht_2bit_fresh_beats_fixed_on_gaussian() {
        // Algorithm 1's fresh quantizations are what make 2-bit viable on a
        // Gaussian matrix (the expectation in Theorem 3 is over Q draws).
        let mut fresh_hits = 0usize;
        let mut fixed_hits = 0usize;
        for seed in 0..4u64 {
            let (phi, y, x_true) = planted(192, 256, 5, 100 + seed);
            let st = support_of(&x_true);
            let rf = qniht(&phi, &y, 5, 2, 8, RequantMode::Fresh, seed, &SolveOptions::default());
            let rx = qniht(&phi, &y, 5, 2, 8, RequantMode::Fixed, seed, &SolveOptions::default());
            fresh_hits +=
                super::super::support::support_intersection(&st, &support_of(&rf.x));
            fixed_hits +=
                super::super::support::support_intersection(&st, &support_of(&rx.x));
        }
        assert!(fresh_hits >= fixed_hits, "fresh {fresh_hits} vs fixed {fixed_hits}");
        assert!(fresh_hits >= 16, "fresh should recover most of 20: {fresh_hits}");
    }

    #[test]
    fn error_decreases_with_bits() {
        let (phi, y, x_true) = planted(96, 192, 5, 4);
        let mut errs = vec![];
        for bits in [2u8, 4, 8] {
            let r = qniht(&phi, &y, 5, bits, 8, RequantMode::Fresh, 45, &SolveOptions::default());
            errs.push(linalg::norm2(&linalg::sub(&r.x, &x_true)));
        }
        assert!(errs[2] < errs[0], "8-bit must beat 2-bit: {errs:?}");
    }

    #[test]
    fn fresh_mode_differs_from_fixed() {
        let (phi, y, _) = planted(64, 128, 4, 5);
        let rf = qniht(&phi, &y, 4, 4, 8, RequantMode::Fixed, 46, &SolveOptions::default());
        let rr = qniht(&phi, &y, 4, 4, 8, RequantMode::Fresh, 46, &SolveOptions::default());
        assert_ne!(rf.x, rr.x);
    }

    #[test]
    fn bytes_per_iteration_scales_with_bits() {
        let (phi, y, _) = planted(32, 64, 3, 6);
        let k2 = QuantKernel::new(&phi, &y, 2, 8, RequantMode::Fixed, 1);
        let k8 = QuantKernel::new(&phi, &y, 8, 8, RequantMode::Fixed, 1);
        assert_eq!(k8.bytes_per_iteration(), 4 * k2.bytes_per_iteration());
    }

    #[test]
    fn result_is_s_sparse() {
        let (phi, y, _) = planted(48, 96, 4, 7);
        let r = qniht(&phi, &y, 4, 4, 8, RequantMode::Fixed, 47, &SolveOptions::default());
        assert!(support_of(&r.x).len() <= 4);
    }

    #[test]
    fn with_prepared_shares_one_quantization_and_recovers() {
        // Batch amortization building block: one quantize+pack of Φ,
        // several kernels bound to different observations.
        let (phi, _, _) = planted(96, 192, 6, 8);
        let prepared = Arc::new(PreparedPhi::quantize(&phi, 8, 99));
        assert_eq!((prepared.m(), prepared.n(), prepared.bits()), (96, 192, 8));
        let mut rng = XorShift128Plus::new(77);
        for job in 0..3u64 {
            let mut x_true = vec![0.0f32; 192];
            for i in rng.choose_k(192, 6) {
                x_true[i] = 2.0 * rng.gaussian_f32().signum();
            }
            let y = phi.matvec(&x_true);
            let mut k = QuantKernel::with_prepared(prepared.clone(), &y, 8, job);
            let r = solve(&mut k, 6, &SolveOptions::default());
            assert_eq!(support_of(&r.x), support_of(&x_true), "job {job}");
        }
    }

    #[test]
    fn with_prepared_is_deterministic_in_its_seeds() {
        let (phi, y, _) = planted(64, 128, 4, 9);
        let a = {
            let p = Arc::new(PreparedPhi::quantize(&phi, 4, 5));
            let mut k = QuantKernel::with_prepared(p, &y, 8, 11);
            solve(&mut k, 4, &SolveOptions::default())
        };
        let b = {
            let p = Arc::new(PreparedPhi::quantize(&phi, 4, 5));
            let mut k = QuantKernel::with_prepared(p, &y, 8, 11);
            solve(&mut k, 4, &SolveOptions::default())
        };
        assert_eq!(a.x, b.x, "same (phi seed, y seed) must reproduce bit-identically");
    }

    fn batch_problem(
        phi: &Mat,
        njobs: usize,
        seed: u64,
    ) -> (Vec<Vec<f32>>, Vec<Vec<usize>>) {
        let n = phi.cols;
        let mut rng = XorShift128Plus::new(seed);
        let (mut ys, mut supports) = (vec![], vec![]);
        for _ in 0..njobs {
            let mut x_true = vec![0.0f32; n];
            for i in rng.choose_k(n, 6) {
                x_true[i] = 2.0 * rng.gaussian_f32().signum();
            }
            ys.push(phi.matvec(&x_true));
            supports.push(support_of(&x_true));
        }
        (ys, supports)
    }

    #[test]
    fn lockstep_batch_matches_sequential_bit_for_bit() {
        // The core contract of the batched path: for every packed width,
        // each job in a lockstep batch reproduces its sequential
        // with_prepared solve EXACTLY — same iterate bits, same iteration
        // count — so batching is invisible to results.
        let (phi, _, _) = planted(96, 192, 6, 10);
        let opts = SolveOptions::default();
        for bits in [2u8, 4, 8] {
            let prepared = Arc::new(PreparedPhi::quantize(&phi, bits, 7));
            let (ys, _) = batch_problem(&phi, 3, 123);
            let jobs: Vec<BatchJob> = ys
                .iter()
                .enumerate()
                .map(|(i, y)| BatchJob { y, bits_y: 8, seed: 50 + i as u64 })
                .collect();
            let batch = solve_batch_lockstep(&prepared, &jobs, 6, &opts, &mut |_, _| {
                ObserverSignal::Continue
            });
            assert_eq!(batch.len(), 3);
            for (i, y) in ys.iter().enumerate() {
                let mut k = QuantKernel::with_prepared(prepared.clone(), y, 8, 50 + i as u64);
                let seq = solve(&mut k, 6, &opts);
                assert_eq!(batch[i].x, seq.x, "bits={bits} job={i}");
                assert_eq!(batch[i].iterations, seq.iterations, "bits={bits} job={i}");
                assert_eq!(batch[i].converged, seq.converged, "bits={bits} job={i}");
            }
        }
    }

    #[test]
    fn lockstep_batch_recovers_supports() {
        let (phi, _, _) = planted(96, 192, 6, 11);
        let prepared = Arc::new(PreparedPhi::quantize(&phi, 8, 21));
        let (ys, supports) = batch_problem(&phi, 3, 321);
        let jobs: Vec<BatchJob> = ys
            .iter()
            .enumerate()
            .map(|(i, y)| BatchJob { y, bits_y: 8, seed: i as u64 })
            .collect();
        let res = solve_batch_lockstep(
            &prepared,
            &jobs,
            6,
            &SolveOptions::default(),
            &mut |_, _| ObserverSignal::Continue,
        );
        for (r, want) in res.iter().zip(&supports) {
            assert_eq!(&support_of(&r.x), want);
        }
    }

    #[test]
    fn lockstep_observer_stops_one_job_only() {
        // Stopping one job must not perturb the rest of the batch: the
        // stopped job drops out of the shared gradient sweep and the others
        // keep their exact trajectories.
        let (phi, _, _) = planted(96, 192, 6, 12);
        let prepared = Arc::new(PreparedPhi::quantize(&phi, 4, 33));
        let (ys, _) = batch_problem(&phi, 3, 213);
        let jobs: Vec<BatchJob> = ys
            .iter()
            .enumerate()
            .map(|(i, y)| BatchJob { y, bits_y: 8, seed: i as u64 })
            .collect();
        let opts = SolveOptions::default();
        let full = solve_batch_lockstep(&prepared, &jobs, 6, &opts, &mut |_, _| {
            ObserverSignal::Continue
        });
        let stopped = solve_batch_lockstep(&prepared, &jobs, 6, &opts, &mut |j, st| {
            if j == 1 && st.iter == 0 {
                ObserverSignal::Stop
            } else {
                ObserverSignal::Continue
            }
        });
        assert_eq!(stopped[1].iterations, 1);
        assert!(!stopped[1].converged);
        assert_eq!(stopped[0].x, full[0].x);
        assert_eq!(stopped[2].x, full[2].x);
    }

    #[test]
    fn lockstep_empty_batch_is_empty() {
        let (phi, _, _) = planted(32, 64, 3, 13);
        let prepared = Arc::new(PreparedPhi::quantize(&phi, 8, 1));
        let res = solve_batch_lockstep(
            &prepared,
            &[],
            3,
            &SolveOptions::default(),
            &mut |_, _| ObserverSignal::Continue,
        );
        assert!(res.is_empty());
    }

    #[test]
    fn reports_simd_backend() {
        let (phi, y, _) = planted(16, 32, 2, 9);
        let k = QuantKernel::new(&phi, &y, 4, 8, RequantMode::Fixed, 1);
        assert!(["scalar", "avx2", "neon", "vnni"].contains(&k.simd_backend()));
    }
}
