//! Visibility synthesis: y = Φx + e with SNR-calibrated complex AWGN.
//!
//! The paper's noise model (§7.1): antenna thermal noise is
//! `CN(0, σ_n² I_L)`, and the SNR at antenna level is
//! `10·log10(‖Φx‖² / ‖e‖²)` — 0 dB in the headline experiments.  In the
//! stacked-real embedding a complex `CN(0, σ²)` sample becomes two real
//! `N(0, σ²/2)` components, which is exactly how we draw them.

use crate::linalg::{norm2_sq, Mat};
use crate::rng::XorShift128Plus;

/// Observe a sky `x` through `phi` (stacked-real) at the target SNR (dB).
/// Returns (y, sigma_n) where sigma_n is the equivalent per-component
/// complex noise std.
pub fn observe(phi: &Mat, x: &[f32], snr_db: f64, rng: &mut XorShift128Plus) -> (Vec<f32>, f32) {
    let clean = phi.matvec(x);
    let signal_power = norm2_sq(&clean) as f64;
    let m2 = clean.len(); // 2·L² stacked-real components
    // Target: signal_power / noise_power = 10^(snr/10); noise_power =
    // E‖e‖² = m2 · (σ²/2) per real component with complex std σ.
    let noise_power = signal_power / 10f64.powf(snr_db / 10.0);
    let sigma_complex = (2.0 * noise_power / m2 as f64).sqrt();
    let per_component = (noise_power / m2 as f64).sqrt() as f32;
    let y: Vec<f32> = clean
        .iter()
        .map(|&c| c + per_component * rng.gaussian_f32())
        .collect();
    (y, sigma_complex as f32)
}

/// Noise-free visibilities (for ground-truth pipelines).
pub fn observe_clean(phi: &Mat, x: &[f32]) -> Vec<f32> {
    phi.matvec(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telescope::{steering, AntennaArray, ImageGrid};

    fn setup() -> (Mat, Vec<f32>) {
        let mut rng = XorShift128Plus::new(1);
        let a = AntennaArray::lofar_like(6, 50e6, &mut rng);
        let g = ImageGrid::new(12, 0.4);
        let phi = steering::stacked_measurement_matrix(&a, &g);
        let mut x = vec![0.0f32; g.pixels()];
        x[10] = 1.0;
        x[77] = 0.8;
        (phi, x)
    }

    #[test]
    fn zero_db_snr_calibration() {
        let (phi, x) = setup();
        let mut rng = XorShift128Plus::new(2);
        let clean = observe_clean(&phi, &x);
        // Average over draws: achieved SNR ≈ requested.
        let mut ratios = vec![];
        for seed in 0..20 {
            let mut r = rng.fork(seed);
            let (y, _) = observe(&phi, &x, 0.0, &mut r);
            let noise: Vec<f32> = y.iter().zip(&clean).map(|(a, b)| a - b).collect();
            ratios.push((norm2_sq(&clean) / norm2_sq(&noise)) as f64);
        }
        let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!((10.0 * mean.log10()).abs() < 1.0, "snr={}", 10.0 * mean.log10());
    }

    #[test]
    fn high_snr_nearly_clean() {
        let (phi, x) = setup();
        let mut rng = XorShift128Plus::new(3);
        let clean = observe_clean(&phi, &x);
        let (y, _) = observe(&phi, &x, 60.0, &mut rng);
        let noise: Vec<f32> = y.iter().zip(&clean).map(|(a, b)| a - b).collect();
        assert!(norm2_sq(&noise) < 1e-5 * norm2_sq(&clean));
    }

    #[test]
    fn sigma_scales_with_snr() {
        let (phi, x) = setup();
        let mut r1 = XorShift128Plus::new(4);
        let mut r2 = XorShift128Plus::new(4);
        let (_, s_low) = observe(&phi, &x, -10.0, &mut r1);
        let (_, s_high) = observe(&phi, &x, 10.0, &mut r2);
        assert!(s_low > s_high, "more noise at lower SNR");
        assert!((s_low / s_high - 10.0).abs() < 0.5);
    }
}
