//! Visibility synthesis: y = Φx + e with SNR-calibrated complex AWGN.
//!
//! The paper's noise model (§7.1): antenna thermal noise is
//! `CN(0, σ_n² I_L)`, and the SNR at antenna level is
//! `10·log10(‖Φx‖² / ‖e‖²)` — 0 dB in the headline experiments.  In the
//! stacked-real embedding a complex `CN(0, σ²)` sample becomes two real
//! `N(0, σ²/2)` components, which is exactly how we draw them.
//!
//! **Physical structure.** The instrument only measures L(L−1)/2
//! distinct complex visibilities plus L real autocorrelations; the full
//! ordered-pair set is their conjugate completion (`V(k,i) =
//! conj(V(i,k))`, `Im V(i,i) = 0`). Noise inherits that structure:
//! independent draws happen **only** on the unique baselines and the
//! autocorrelation real parts, and the conjugate components mirror them
//! (`e(k,i) = conj(e(i,k))`, autocorrelation Im components stay exactly
//! 0). Drawing i.i.d. noise on all 2·L² stacked-real components — the
//! pre-fix behavior — acts like ~2× more physical measurements than the
//! instrument has and silently inflates recovery quality.

use crate::linalg::{norm2_sq, Mat};
use crate::rng::XorShift128Plus;

/// Baseline structure of a stacked-real visibility vector, deciding
/// which components carry independent noise draws.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NoiseShape {
    /// Full ordered-pair set (row z = i·L + k): draws on i < k pairs and
    /// autocorrelation real parts, conjugates mirrored.
    Full { antennas: usize },
    /// Unique-baseline set (i < k only): every complex visibility is
    /// distinct, so all components are independently noisy.
    Unique,
}

/// Add SNR-calibrated, physically structured noise to clean stacked-real
/// visibilities. Returns `(y, sigma_n)` where `sigma_n` is the
/// per-visibility complex noise std actually applied; the calibration
/// target is `E‖e‖² = ‖Φx‖² / 10^(snr/10)` over the whole stacked
/// vector, mirrored components included.
pub fn add_noise(
    clean: &[f32],
    snr_db: f64,
    rng: &mut XorShift128Plus,
    shape: NoiseShape,
) -> (Vec<f32>, f32) {
    assert!(clean.len() % 2 == 0, "stacked-real vector has even length");
    let mb = clean.len() / 2; // complex visibility count
    let signal_power = norm2_sq(clean) as f64;
    let noise_power = signal_power / 10f64.powf(snr_db / 10.0);
    match shape {
        NoiseShape::Full { antennas: l } => {
            assert_eq!(
                clean.len(),
                2 * l * l,
                "full-set vector must hold 2·L² components for L = {l}"
            );
            // Each unique pair's complex draw lands in two mirrored
            // slots, each autocorrelation draw in one:
            // E‖e‖² = L(L−1)·σ² + L·σ² = L²·σ².
            let sigma_sq = noise_power / (l * l) as f64;
            let s_half = (sigma_sq / 2.0).sqrt() as f32;
            let s_auto = sigma_sq.sqrt() as f32;
            let mut e = vec![0.0f32; clean.len()];
            for i in 0..l {
                // Autocorrelation: real power fluctuation, Im stays 0.
                e[i * l + i] = s_auto * rng.gaussian_f32();
                for k in (i + 1)..l {
                    let g_re = s_half * rng.gaussian_f32();
                    let g_im = s_half * rng.gaussian_f32();
                    let (z1, z2) = (i * l + k, k * l + i);
                    e[z1] = g_re;
                    e[z2] = g_re;
                    e[mb + z1] = g_im;
                    e[mb + z2] = -g_im;
                }
            }
            let y = clean.iter().zip(&e).map(|(c, n)| c + n).collect();
            (y, sigma_sq.sqrt() as f32)
        }
        NoiseShape::Unique => {
            // Every visibility distinct: E‖e‖² = 2M·(σ²/2) = M·σ².
            let sigma_sq = noise_power / mb as f64;
            let s_half = (sigma_sq / 2.0).sqrt() as f32;
            let y = clean.iter().map(|&c| c + s_half * rng.gaussian_f32()).collect();
            (y, sigma_sq.sqrt() as f32)
        }
    }
}

/// Observe a sky `x` through `phi` (stacked-real) at the target SNR (dB).
/// `antennas` tells the noise synthesis the baseline structure: a matrix
/// with `2·L²` rows is the full ordered-pair set (conjugate components
/// mirrored), anything else is treated as a unique-baseline stack.
/// Returns (y, sigma_n) with sigma_n the per-visibility complex noise
/// std.
pub fn observe(
    phi: &Mat,
    x: &[f32],
    snr_db: f64,
    rng: &mut XorShift128Plus,
    antennas: usize,
) -> (Vec<f32>, f32) {
    let clean = phi.matvec(x);
    let shape = if phi.rows == 2 * antennas * antennas {
        NoiseShape::Full { antennas }
    } else {
        NoiseShape::Unique
    };
    add_noise(&clean, snr_db, rng, shape)
}

/// Noise-free visibilities (for ground-truth pipelines).
pub fn observe_clean(phi: &Mat, x: &[f32]) -> Vec<f32> {
    phi.matvec(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telescope::{steering, AntennaArray, ImageGrid};

    const L: usize = 6;

    fn setup() -> (Mat, Vec<f32>) {
        let mut rng = XorShift128Plus::new(1);
        let a = AntennaArray::lofar_like(L, 50e6, &mut rng);
        let g = ImageGrid::new(12, 0.4);
        let phi = steering::stacked_measurement_matrix(&a, &g);
        let mut x = vec![0.0f32; g.pixels()];
        x[10] = 1.0;
        x[77] = 0.8;
        (phi, x)
    }

    #[test]
    fn zero_db_snr_calibration() {
        let (phi, x) = setup();
        let mut rng = XorShift128Plus::new(2);
        let clean = observe_clean(&phi, &x);
        // Average over draws: achieved SNR ≈ requested.
        let mut ratios = vec![];
        for seed in 0..20 {
            let mut r = rng.fork(seed);
            let (y, _) = observe(&phi, &x, 0.0, &mut r, L);
            let noise: Vec<f32> = y.iter().zip(&clean).map(|(a, b)| a - b).collect();
            ratios.push((norm2_sq(&clean) / norm2_sq(&noise)) as f64);
        }
        let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!((10.0 * mean.log10()).abs() < 1.0, "snr={}", 10.0 * mean.log10());
    }

    #[test]
    fn high_snr_nearly_clean() {
        let (phi, x) = setup();
        let mut rng = XorShift128Plus::new(3);
        let clean = observe_clean(&phi, &x);
        let (y, _) = observe(&phi, &x, 60.0, &mut rng, L);
        let noise: Vec<f32> = y.iter().zip(&clean).map(|(a, b)| a - b).collect();
        assert!(norm2_sq(&noise) < 1e-5 * norm2_sq(&clean));
    }

    #[test]
    fn sigma_scales_with_snr() {
        let (phi, x) = setup();
        let mut r1 = XorShift128Plus::new(4);
        let mut r2 = XorShift128Plus::new(4);
        let (_, s_low) = observe(&phi, &x, -10.0, &mut r1, L);
        let (_, s_high) = observe(&phi, &x, 10.0, &mut r2, L);
        assert!(s_low > s_high, "more noise at lower SNR");
        assert!((s_low / s_high - 10.0).abs() < 0.5);
    }

    #[test]
    fn full_set_noise_is_conjugate_symmetric() {
        // On a zero sky the observation IS the noise: pin the structure.
        let l = 5;
        let clean = vec![0.0f32; 2 * l * l];
        let mb = l * l;
        let mut rng = XorShift128Plus::new(9);
        let (e, sigma) = add_noise(&clean, 0.0, &mut rng, NoiseShape::Full { antennas: l });
        assert!(sigma == 0.0 || sigma.is_finite());
        let mut any_nonzero = false;
        for i in 0..l {
            assert_eq!(e[mb + i * l + i], 0.0, "autocorrelation Im stays 0");
            for k in (i + 1)..l {
                let (z1, z2) = (i * l + k, k * l + i);
                assert_eq!(e[z1], e[z2], "Re mirrored");
                assert_eq!(e[mb + z1], -e[mb + z2], "Im conjugated");
                any_nonzero |= e[z1] != 0.0 || e[mb + z1] != 0.0;
            }
        }
        // signal_power = 0 ⇒ noise_power = 0 here; re-draw at fixed power
        // via a nonzero clean vector to confirm draws actually happen.
        assert!(!any_nonzero, "zero signal ⇒ zero calibrated noise");
        let clean = vec![1.0f32; 2 * l * l];
        let (y, _) = add_noise(&clean, 0.0, &mut rng, NoiseShape::Full { antennas: l });
        let mut distinct = 0;
        for i in 0..l {
            assert_eq!(y[mb + i * l + i], clean[mb + i * l + i], "Im(auto) untouched");
            for k in (i + 1)..l {
                let (z1, z2) = (i * l + k, k * l + i);
                assert_eq!(y[z1], y[z2]);
                // y_im(z1) − c = −(y_im(z2) − c)
                let (n1, n2) = (y[mb + z1] - 1.0, y[mb + z2] - 1.0);
                assert!((n1 + n2).abs() < 1e-6);
                distinct += (n1 != 0.0) as usize;
            }
        }
        assert!(distinct > 0, "noise was actually drawn");
    }

    #[test]
    fn unique_set_components_all_independent() {
        // Unique-baseline stack: no two components share a draw.
        let clean = vec![1.0f32; 30]; // M = 15 unique visibilities
        let mut rng = XorShift128Plus::new(11);
        let (y, _) = add_noise(&clean, 0.0, &mut rng, NoiseShape::Unique);
        let noise: Vec<f32> = y.iter().map(|v| v - 1.0).collect();
        let nonzero = noise.iter().filter(|&&v| v != 0.0).count();
        assert!(nonzero >= 28, "essentially every component drawn: {nonzero}");
        for i in 0..noise.len() {
            for j in (i + 1)..noise.len() {
                assert!(
                    noise[i] != noise[j] || noise[i] == 0.0,
                    "components {i} and {j} share a draw"
                );
            }
        }
    }

    #[test]
    fn full_set_calibration_counts_mirrored_energy() {
        // The mirrored components carry real energy: achieved SNR on the
        // WHOLE stacked vector must still hit the target.
        let (phi, x) = setup();
        let clean = observe_clean(&phi, &x);
        let mut rng = XorShift128Plus::new(12);
        let mut ratios = vec![];
        for seed in 0..20 {
            let mut r = rng.fork(seed);
            let (y, _) = add_noise(&clean, 3.0, &mut r, NoiseShape::Full { antennas: L });
            let noise: Vec<f32> = y.iter().zip(&clean).map(|(a, b)| a - b).collect();
            ratios.push((norm2_sq(&clean) / norm2_sq(&noise)) as f64);
        }
        let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!((10.0 * mean.log10() - 3.0).abs() < 1.0, "snr={}", 10.0 * mean.log10());
    }
}
