//! The matrix-free visibility measurement operator and its low-precision
//! sampling variant — the telescope workload's analogue of
//! [`crate::mri::PartialFourierOp`].
//!
//! [`VisibilityOp`] applies the paper's Eqn. 75 steering matrix without
//! materializing it: `Φ_{z,w} = exp(-j 2π ⟨p_z, r_w⟩)` over baselines
//! `p_z` (wavelengths) and pixel directions `r_w`, embedded stacked-real
//! (`y = [Re Φ; Im Φ]·x`, Re rows first). `apply` and the *exact* adjoint
//! `apply_t` evaluate the steering phases on the fly from the
//! [`AntennaArray`] positions and the [`ImageGrid`] — **zero** operator
//! storage at `O(M·N)` trig work — or from an optional cached-row mode
//! ([`VisibilityOp::cached`]) that materializes the rows once (in
//! parallel row chunks) and replays them trig-free, bit-identically to
//! the on-the-fly path. [`VisibilityOp::to_mat`] materializes the same
//! operator through [`super::steering`]'s closed form — the dense-parity
//! reference and the dense-baseline operand of `benches/astro.rs`.
//!
//! By default the operator covers the **unique baselines** (ordered
//! pairs i < k): the full L² set's stacked-real embedding is
//! rank-deficient (identical autocorrelation rows, conjugate-duplicate
//! pairs — see [`super::geometry`]), so serving defaults to the
//! L(L−1)/2 distinct visibilities an interferometer actually measures.
//! The full set stays available behind
//! [`VisibilityOp::with_full_baselines`] for paper-parity figures.
//!
//! ## What is quantized when Φ is implicit
//!
//! Exactly the MRI convention ([`crate::mri::op`]): the operator has no
//! entries worth storing, so the paper's low-precision representation
//! maps onto the **measurement-domain data streams**
//! ([`LowPrecVisibilityOp`]):
//!
//! * the observation ŷ = Q_b(y), quantized once at acquisition
//!   ([`lowprec_problem`]) — the correlator output at `b` bits;
//! * the per-iteration visibility-domain residual entering the adjoint,
//!   re-quantized stochastically every gradient step.
//!
//! Both use the shared [`crate::mri::quantize_blocked`] with one scale
//! per [`crate::mri::QUANT_BLOCK`]-sample **baseline block**: short
//! baselines sit on the bright low-spatial-frequency flux while long
//! baselines measure faint fine structure, so visibility amplitudes span
//! orders of magnitude and a single global scale would round the long
//! baselines — the resolution information — to zero at any practical
//! bit width. Dequantization streams the int8 codes through the
//! runtime-dispatched SIMD backend, the same mixed-precision kernel the
//! packed dense path uses. Image-domain iterates stay f32 — solver
//! state, not operator traffic.

use super::visibility::{self, NoiseShape};
use super::{steering, AntennaArray, AstroConfig, ImageGrid, SkyModel};
use crate::linalg::Mat;
use crate::mri::quantize_blocked;
use crate::par;
use crate::rng::XorShift128Plus;
use crate::solver::{MeasurementOp, Problem};
use anyhow::Result;
use std::sync::{Arc, Mutex};

/// Matrix-free stacked-real visibility operator (see module docs).
#[derive(Clone)]
pub struct VisibilityOp {
    array: AntennaArray,
    grid: ImageGrid,
    /// Full L² baseline set (paper parity) instead of the unique default.
    full: bool,
    /// Baselines in wavelengths, one complex visibility each.
    baselines: Vec<[f64; 2]>,
    /// Pixel direction cosines, precomputed once.
    dirs: Vec<[f64; 2]>,
    n: usize,
    /// Cached-row mode: the materialized rows (`to_mat` layout), so the
    /// transforms replay trig-free. `2·M·N` f32 of memory when enabled.
    cache: Option<Arc<Vec<f32>>>,
}

impl std::fmt::Debug for VisibilityOp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("VisibilityOp")
            .field("antennas", &self.array.len())
            .field("resolution", &self.grid.resolution)
            .field("full", &self.full)
            .field("m", &MeasurementOp::m(self))
            .field("cached", &self.cache.is_some())
            .finish()
    }
}

impl VisibilityOp {
    /// Unique-baseline operator (i < k pairs): M = L(L−1)/2 complex
    /// visibilities, the serving default.
    pub fn new(array: AntennaArray, grid: ImageGrid) -> Self {
        Self::build(array, grid, false)
    }

    /// Full ordered-pair operator (M = L², includes autocorrelations and
    /// conjugate duplicates) for paper-parity figures. Its stacked-real
    /// embedding is rank-deficient — keep recovery on the unique set.
    pub fn with_full_baselines(array: AntennaArray, grid: ImageGrid) -> Self {
        Self::build(array, grid, true)
    }

    fn build(array: AntennaArray, grid: ImageGrid, full: bool) -> Self {
        let baselines = if full {
            array.baselines_wavelengths()
        } else {
            array.unique_baselines_wavelengths()
        };
        let dirs: Vec<[f64; 2]> = (0..grid.pixels()).map(|w| grid.direction_of(w)).collect();
        let n = grid.pixels();
        Self { array, grid, full, baselines, dirs, n, cache: None }
    }

    /// Enable cached-row mode: materialize the rows once (parallel row
    /// chunks via [`Self::to_mat`]) and replay them trig-free. The cached
    /// transforms are bit-identical to the on-the-fly ones — same f32
    /// entries, same accumulation order.
    pub fn cached(mut self) -> Self {
        if self.cache.is_none() {
            self.cache = Some(Arc::new(self.to_mat().data));
        }
        self
    }

    pub fn array(&self) -> &AntennaArray {
        &self.array
    }

    pub fn grid(&self) -> ImageGrid {
        self.grid
    }

    /// Whether this operator covers the full L² ordered-pair set.
    pub fn full_baselines(&self) -> bool {
        self.full
    }

    /// Number of complex visibilities M (half the stacked-real rows).
    pub fn baseline_count(&self) -> usize {
        self.baselines.len()
    }

    pub fn is_cached(&self) -> bool {
        self.cache.is_some()
    }

    /// Submit-time gate (the coordinator calls this from
    /// `JobSpec::validate`): station and grid parameters re-checked so an
    /// ill-formed operator fails at submission, not inside a worker.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.array.len() >= 2,
            "visibility operator needs >= 2 antennas, got {}",
            self.array.len()
        );
        anyhow::ensure!(
            self.array.positions.iter().all(|p| p[0].is_finite() && p[1].is_finite()),
            "antenna positions must be finite"
        );
        anyhow::ensure!(
            self.array.freq_hz.is_finite() && self.array.freq_hz > 0.0,
            "observing frequency {} Hz must be finite and positive",
            self.array.freq_hz
        );
        anyhow::ensure!(
            (2..=1024).contains(&self.grid.resolution),
            "image resolution {} out of the servable 2..=1024 range",
            self.grid.resolution
        );
        Ok(())
    }

    /// Materialize the operator as an explicit dense [`Mat`] through the
    /// closed-form steering matrix (independent of the matrix-free code
    /// path — the parity reference and the dense bench baseline).
    pub fn to_mat(&self) -> Mat {
        if self.full {
            steering::stacked_measurement_matrix(&self.array, &self.grid)
        } else {
            steering::stacked_measurement_matrix_unique(&self.array, &self.grid)
        }
    }

    /// The classical dirty-image reconstruction `Φᵀ y` (the zero-order
    /// baseline next to the recovered sky).
    pub fn dirty_image(&self, y: &[f32]) -> Vec<f32> {
        self.apply_t(y)
    }

    #[inline]
    fn phase(&self, z: usize, w: usize) -> f64 {
        let b = self.baselines[z];
        let d = self.dirs[w];
        -2.0 * std::f64::consts::PI * (b[0] * d[0] + b[1] * d[1])
    }
}

impl MeasurementOp for VisibilityOp {
    fn m(&self) -> usize {
        2 * self.baselines.len()
    }

    fn n(&self) -> usize {
        self.n
    }

    fn apply(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n);
        let mb = self.baselines.len();
        let n = self.n;
        let mut out = vec![0.0f32; 2 * mb];
        // One output component per chunk element: each costs an n-length
        // trig'd dot, plenty of grain for the pool.
        par::par_chunks_mut(&mut out, 1, |start, chunk| {
            for (j, cell) in chunk.iter_mut().enumerate() {
                let row = start + j;
                let (z, imag) = if row < mb { (row, false) } else { (row - mb, true) };
                let mut acc = 0.0f32;
                if let Some(cache) = &self.cache {
                    let r = &cache[row * n..(row + 1) * n];
                    for (e, &xv) in r.iter().zip(x) {
                        acc += e * xv;
                    }
                } else {
                    for (w, &xv) in x.iter().enumerate() {
                        let phase = self.phase(z, w);
                        let e = if imag { phase.sin() } else { phase.cos() } as f32;
                        acc += e * xv;
                    }
                }
                *cell = acc;
            }
        });
        out
    }

    fn apply_t(&self, v: &[f32]) -> Vec<f32> {
        let mb = self.baselines.len();
        let n = self.n;
        assert_eq!(v.len(), 2 * mb);
        let mut out = vec![0.0f32; n];
        par::par_chunks_mut(&mut out, 16, |start, chunk| {
            for (j, cell) in chunk.iter_mut().enumerate() {
                let w = start + j;
                let mut acc = 0.0f32;
                if let Some(cache) = &self.cache {
                    for z in 0..mb {
                        acc += cache[z * n + w] * v[z] + cache[(mb + z) * n + w] * v[mb + z];
                    }
                } else {
                    for z in 0..mb {
                        let phase = self.phase(z, w);
                        acc += (phase.cos() as f32) * v[z] + (phase.sin() as f32) * v[mb + z];
                    }
                }
                *cell = acc;
            }
        });
        out
    }
}

/// Low-precision sampling variant of [`VisibilityOp`]: the same
/// transforms, with the per-iteration visibility-domain traffic (the
/// residual entering the adjoint) stochastically quantized to `bits` per
/// [`crate::mri::QUANT_BLOCK`]-sample baseline block. See the module
/// docs for what is (and is not) quantized when Φ is implicit.
///
/// The RNG driving the stochastic rounding lives behind a `Mutex`: calls
/// consume draws in sequence, so two solves issuing the same call
/// sequence from the same seed are bit-identical — which is how
/// `tests/astro_serving.rs` pins the served path against the facade.
pub struct LowPrecVisibilityOp {
    inner: Arc<VisibilityOp>,
    bits: u8,
    rng: Mutex<XorShift128Plus>,
}

impl std::fmt::Debug for LowPrecVisibilityOp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LowPrecVisibilityOp")
            .field("bits", &self.bits)
            .field("inner", &self.inner)
            .finish()
    }
}

impl LowPrecVisibilityOp {
    pub fn new(inner: Arc<VisibilityOp>, bits: u8, rng: XorShift128Plus) -> Self {
        assert!(matches!(bits, 2 | 4 | 8), "packed widths only, got {bits}");
        Self { inner, bits, rng: Mutex::new(rng) }
    }

    pub fn bits(&self) -> u8 {
        self.bits
    }
}

impl MeasurementOp for LowPrecVisibilityOp {
    fn m(&self) -> usize {
        self.inner.m()
    }

    fn n(&self) -> usize {
        self.inner.n()
    }

    fn apply(&self, x: &[f32]) -> Vec<f32> {
        // Image-domain input: solver state, streamed at full precision.
        self.inner.apply(x)
    }

    fn apply_t(&self, v: &[f32]) -> Vec<f32> {
        let vq = quantize_blocked(v, self.bits, &mut self.rng.lock().unwrap());
        self.inner.apply_t(&vq)
    }
}

/// Lower a sky problem onto the low-precision sampling path: quantize
/// the observation to `bits` (per-baseline-block stochastic rounding
/// seeded by `seed`) and wrap the operator so per-iteration visibility
/// traffic is quantized with the same RNG stream.
///
/// This is the single lowering both
/// [`crate::coordinator::JobSpec::into_request`] and direct facade
/// callers use, so a served job and a local `Recovery` run of the same
/// spec produce bit-identical iterates.
pub fn lowprec_problem(
    op: Arc<VisibilityOp>,
    y: &[f32],
    s: usize,
    bits: u8,
    seed: u64,
) -> Problem {
    let mut rng = XorShift128Plus::new(seed ^ 0x4C50_5653); // "LPVS"
    let y_hat = quantize_blocked(y, bits, &mut rng);
    Problem::with_op(Arc::new(LowPrecVisibilityOp::new(op, bits, rng)), y_hat, s)
}

/// A fully synthesized sky-recovery problem over the matrix-free
/// operator — the served/CLI/bench counterpart of
/// [`super::AstroProblem`] (which materializes Φ and keeps the full L²
/// set for paper-parity figures).
#[derive(Debug, Clone)]
pub struct SkyProblem {
    /// The matrix-free operator, shareable across jobs (batch identity).
    pub op: Arc<VisibilityOp>,
    /// f32 observations with the physical conjugate-symmetric noise
    /// (quantize via [`lowprec_problem`]).
    pub y: Vec<f32>,
    /// Ground-truth sky vector.
    pub x_true: Vec<f32>,
    /// Per-visibility complex noise std actually applied.
    pub sigma_n: f32,
    pub s: usize,
}

impl SkyProblem {
    /// Build from validated configuration; `seed` drives the station
    /// layout, the sky draw and the noise. Defaults to the unique
    /// baseline set; `cfg.full_baselines` opts into the full L² set.
    pub fn build(cfg: &AstroConfig, seed: u64) -> Result<Self> {
        cfg.validate()?;
        let mut rng = XorShift128Plus::new(seed);
        let array = AntennaArray::lofar_like(cfg.antennas, cfg.freq_hz, &mut rng);
        let grid = ImageGrid::new(cfg.resolution, cfg.fov_half_width);
        let op = if cfg.full_baselines {
            VisibilityOp::with_full_baselines(array, grid)
        } else {
            VisibilityOp::new(array, grid)
        };
        let sky = SkyModel::random_points(&grid, cfg.sources, &mut rng);
        let x_true = sky.to_vector(grid.pixels());
        let clean = op.apply(&x_true);
        let shape = if cfg.full_baselines {
            NoiseShape::Full { antennas: cfg.antennas }
        } else {
            NoiseShape::Unique
        };
        let (y, sigma_n) = visibility::add_noise(&clean, cfg.snr_db, &mut rng, shape);
        Ok(Self { op: Arc::new(op), y, x_true, sigma_n, s: cfg.effective_sparsity() })
    }

    pub fn n(&self) -> usize {
        MeasurementOp::n(&*self.op)
    }

    pub fn m(&self) -> usize {
        self.y.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg;

    fn tiny(l: usize, r: usize) -> VisibilityOp {
        let mut rng = XorShift128Plus::new(1);
        let a = AntennaArray::lofar_like(l, 50e6, &mut rng);
        VisibilityOp::new(a, ImageGrid::new(r, 0.4))
    }

    #[test]
    fn shapes_unique_and_full() {
        let op = tiny(5, 8);
        assert_eq!(MeasurementOp::m(&op), 5 * 4); // 2 · L(L−1)/2
        assert_eq!(MeasurementOp::n(&op), 64);
        assert!(!op.full_baselines());
        let mut rng = XorShift128Plus::new(1);
        let a = AntennaArray::lofar_like(5, 50e6, &mut rng);
        let full = VisibilityOp::with_full_baselines(a, ImageGrid::new(8, 0.4));
        assert_eq!(MeasurementOp::m(&full), 2 * 25);
        assert!(full.full_baselines());
    }

    #[test]
    fn dense_parity_against_to_mat() {
        for full in [false, true] {
            let mut rng = XorShift128Plus::new(2);
            let a = AntennaArray::lofar_like(4, 50e6, &mut rng);
            let g = ImageGrid::new(8, 0.4);
            let op = if full {
                VisibilityOp::with_full_baselines(a, g)
            } else {
                VisibilityOp::new(a, g)
            };
            let dense = op.to_mat();
            assert_eq!((dense.rows, dense.cols), (MeasurementOp::m(&op), MeasurementOp::n(&op)));
            let x = rng.gaussian_vec(MeasurementOp::n(&op));
            let y_free = op.apply(&x);
            let y_dense = dense.matvec(&x);
            for (a, b) in y_free.iter().zip(&y_dense) {
                assert!((a - b).abs() <= 1e-3 * (1.0 + b.abs()), "full={full}: {a} vs {b}");
            }
            let v = rng.gaussian_vec(MeasurementOp::m(&op));
            let bt_free = op.apply_t(&v);
            let bt_dense = dense.matvec_t(&v);
            for (a, b) in bt_free.iter().zip(&bt_dense) {
                assert!((a - b).abs() <= 1e-3 * (1.0 + b.abs()), "full={full}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn adjoint_inner_product_property() {
        let op = tiny(6, 8);
        let mut rng = XorShift128Plus::new(3);
        let x = rng.gaussian_vec(MeasurementOp::n(&op));
        let v = rng.gaussian_vec(MeasurementOp::m(&op));
        let lhs = linalg::dot(&op.apply(&x), &v);
        let rhs = linalg::dot(&x, &op.apply_t(&v));
        assert!((lhs - rhs).abs() <= 1e-3 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
    }

    #[test]
    fn cached_mode_is_bit_identical() {
        let op = tiny(5, 8);
        let cached = op.clone().cached();
        assert!(cached.is_cached() && !op.is_cached());
        let mut rng = XorShift128Plus::new(4);
        let x = rng.gaussian_vec(MeasurementOp::n(&op));
        let v = rng.gaussian_vec(MeasurementOp::m(&op));
        assert_eq!(op.apply(&x), cached.apply(&x));
        assert_eq!(op.apply_t(&v), cached.apply_t(&v));
    }

    #[test]
    fn validate_gates_station_parameters() {
        let op = tiny(4, 8);
        op.validate().unwrap();
        let mut rng = XorShift128Plus::new(5);
        let mut a = AntennaArray::lofar_like(4, 50e6, &mut rng);
        a.freq_hz = 0.0;
        let bad = VisibilityOp::new(a, ImageGrid::new(8, 0.4));
        assert!(bad.validate().unwrap_err().to_string().contains("frequency"));
        let one = AntennaArray { positions: vec![[0.0, 0.0]], freq_hz: 50e6 };
        assert!(VisibilityOp::new(one, ImageGrid::new(8, 0.4))
            .validate()
            .unwrap_err()
            .to_string()
            .contains("antennas"));
    }

    #[test]
    fn lowprec_op_quantizes_adjoint_traffic_only() {
        let inner = Arc::new(tiny(6, 8));
        let lp = LowPrecVisibilityOp::new(inner.clone(), 8, XorShift128Plus::new(1));
        let mut rng = XorShift128Plus::new(6);
        let x = rng.gaussian_vec(MeasurementOp::n(&*inner));
        assert_eq!(lp.apply(&x), inner.apply(&x), "forward path is exact");
        let v = rng.gaussian_vec(MeasurementOp::m(&*inner));
        let exact = inner.apply_t(&v);
        let noisy = lp.apply_t(&v);
        assert_ne!(noisy, exact, "adjoint input is quantized");
        let rel = linalg::norm2(&linalg::sub(&noisy, &exact)) / linalg::norm2(&exact);
        assert!(rel < 0.05, "8-bit noise is small: rel={rel}");
    }

    #[test]
    fn lowprec_problem_is_deterministic_in_seed() {
        let inner = Arc::new(tiny(5, 8));
        let mut rng = XorShift128Plus::new(7);
        let x = rng.gaussian_vec(MeasurementOp::n(&*inner));
        let y = inner.apply(&x);
        let run = |seed: u64| {
            let p = lowprec_problem(inner.clone(), &y, 4, 8, seed);
            let a = p.op().apply_t(p.y());
            (p.y().to_vec(), a)
        };
        assert_eq!(run(3), run(3), "same seed reproduces");
        assert_ne!(run(3), run(4), "seed matters");
    }

    #[test]
    fn sky_problem_builds_on_unique_set_by_default() {
        let cfg = AstroConfig {
            antennas: 6,
            resolution: 12,
            sources: 4,
            ..Default::default()
        };
        let p = SkyProblem::build(&cfg, 1).unwrap();
        assert_eq!(p.m(), 6 * 5); // 2 · L(L−1)/2
        assert_eq!(p.n(), 144);
        assert!(!p.op.full_baselines());
        assert_eq!(p.s, 4, "sparsity defaults to the source count");
        let q = SkyProblem::build(&cfg, 1).unwrap();
        assert_eq!(p.y, q.y, "deterministic in seed");
        let full = SkyProblem::build(
            &AstroConfig { full_baselines: true, ..cfg.clone() },
            1,
        )
        .unwrap();
        assert_eq!(full.m(), 2 * 36);
        assert!(full.op.full_baselines());
    }

    #[test]
    fn sky_problem_rejects_invalid_config() {
        let cfg = AstroConfig { bits: 3, ..Default::default() };
        assert!(SkyProblem::build(&cfg, 0).is_err());
        let cfg = AstroConfig { antennas: 1, ..Default::default() };
        assert!(SkyProblem::build(&cfg, 0).is_err());
    }
}
