//! Measurement-matrix formation (paper Eqn. 75).
//!
//! `Φ_{z,w} = exp(-j 2π ⟨p_{i,k}, r_{l,m}⟩)` with `z = i + L(k-1)` over
//! antenna pairs and `w` over pixels; `p_{i,k}` is the baseline in
//! wavelengths, `r_{l,m}` the pixel direction cosines. The complex system
//! is embedded into stacked real form
//!
//! ```text
//!   [Re y]   [Re Φ]
//!   [Im y] = [Im Φ] · x + e_stacked        (exact for real sky x)
//! ```
//!
//! so the entire solver stack stays in real f32 arithmetic. The embedding
//! preserves inner products: ‖Φ_stacked x‖₂ = ‖Φ_complex x‖₂, so RIP
//! constants carry over verbatim.

use super::{AntennaArray, ImageGrid};
use crate::linalg::Mat;
use crate::par;

/// Complex Φ as a pair (Re, Im), each L²×r².
pub fn complex_measurement_matrix(array: &AntennaArray, grid: &ImageGrid) -> (Mat, Mat) {
    let baselines = array.baselines_wavelengths();
    complex_from_baselines(&baselines, grid)
}

/// Complex Φ over the UNIQUE baselines (i < k): L(L−1)/2 rows.
pub fn complex_measurement_matrix_unique(array: &AntennaArray, grid: &ImageGrid) -> (Mat, Mat) {
    let baselines = array.unique_baselines_wavelengths();
    complex_from_baselines(&baselines, grid)
}

fn complex_from_baselines(baselines: &[[f64; 2]], grid: &ImageGrid) -> (Mat, Mat) {
    let m = baselines.len();
    let n = grid.pixels();
    let mut re = Mat::zeros(m, n);
    let mut im = Mat::zeros(m, n);
    // Precompute pixel directions once.
    let dirs: Vec<[f64; 2]> = (0..n).map(|w| grid.direction_of(w)).collect();
    let two_pi = 2.0 * std::f64::consts::PI;
    par::par_chunks_mut(&mut re.data, n, |start, chunk| {
        // chunks are whole rows because we pass min_chunk = n
        let row0 = start / n;
        for (kr, row) in chunk.chunks_mut(n).enumerate() {
            let b = baselines[row0 + kr];
            for (w, cell) in row.iter_mut().enumerate() {
                let phase = -two_pi * (b[0] * dirs[w][0] + b[1] * dirs[w][1]);
                *cell = phase.cos() as f32;
            }
        }
    });
    par::par_chunks_mut(&mut im.data, n, |start, chunk| {
        let row0 = start / n;
        for (kr, row) in chunk.chunks_mut(n).enumerate() {
            let b = baselines[row0 + kr];
            for (w, cell) in row.iter_mut().enumerate() {
                let phase = -two_pi * (b[0] * dirs[w][0] + b[1] * dirs[w][1]);
                *cell = phase.sin() as f32;
            }
        }
    });
    (re, im)
}

/// Stacked-real Φ: (2·L²) × r², rows = [Re Φ; Im Φ].
pub fn stacked_measurement_matrix(array: &AntennaArray, grid: &ImageGrid) -> Mat {
    let (re, im) = complex_measurement_matrix(array, grid);
    stack(re, im)
}

/// Stacked-real Φ over unique baselines: (L·(L−1)) × r².
pub fn stacked_measurement_matrix_unique(array: &AntennaArray, grid: &ImageGrid) -> Mat {
    let (re, im) = complex_measurement_matrix_unique(array, grid);
    stack(re, im)
}

fn stack(re: Mat, im: Mat) -> Mat {
    let m = re.rows;
    let n = re.cols;
    let mut data = re.data;
    data.extend_from_slice(&im.data);
    Mat { rows: 2 * m, cols: n, data }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::XorShift128Plus;

    fn tiny() -> (AntennaArray, ImageGrid) {
        let mut rng = XorShift128Plus::new(1);
        let a = AntennaArray::lofar_like(4, 50e6, &mut rng);
        let g = ImageGrid::new(8, 0.4);
        (a, g)
    }

    #[test]
    fn dimensions() {
        let (a, g) = tiny();
        let (re, im) = complex_measurement_matrix(&a, &g);
        assert_eq!((re.rows, re.cols), (16, 64));
        assert_eq!((im.rows, im.cols), (16, 64));
        let s = stacked_measurement_matrix(&a, &g);
        assert_eq!((s.rows, s.cols), (32, 64));
    }

    #[test]
    fn unit_modulus_entries() {
        let (a, g) = tiny();
        let (re, im) = complex_measurement_matrix(&a, &g);
        for (r, i) in re.data.iter().zip(&im.data) {
            let mag = (r * r + i * i).sqrt();
            assert!((mag - 1.0).abs() < 1e-5, "entry modulus {mag}");
        }
    }

    #[test]
    fn autocorrelation_rows_are_all_ones() {
        // Baseline (i, i) has u = v = 0 ⇒ phase 0 ⇒ Re = 1, Im = 0.
        let (a, g) = tiny();
        let (re, im) = complex_measurement_matrix(&a, &g);
        let l = a.len();
        for i in 0..l {
            let z = i * l + i;
            assert!(re.row(z).iter().all(|&v| (v - 1.0).abs() < 1e-6));
            assert!(im.row(z).iter().all(|&v| v.abs() < 1e-6));
        }
    }

    #[test]
    fn conjugate_symmetry_of_reversed_baselines() {
        // Φ[(i,k)] = conj(Φ[(k,i)]) since baselines are antisymmetric.
        let (a, g) = tiny();
        let (re, im) = complex_measurement_matrix(&a, &g);
        let l = a.len();
        for i in 0..l {
            for k in 0..l {
                let z1 = i * l + k;
                let z2 = k * l + i;
                for w in 0..g.pixels() {
                    assert!((re.at(z1, w) - re.at(z2, w)).abs() < 1e-5);
                    assert!((im.at(z1, w) + im.at(z2, w)).abs() < 1e-5);
                }
            }
        }
    }

    #[test]
    fn stacking_preserves_norm() {
        // ‖Φ_stacked x‖₂² = ‖Re Φ x‖² + ‖Im Φ x‖² = ‖Φ_complex x‖².
        let (a, g) = tiny();
        let (re, im) = complex_measurement_matrix(&a, &g);
        let s = stacked_measurement_matrix(&a, &g);
        let mut rng = XorShift128Plus::new(2);
        let x = rng.gaussian_vec(g.pixels());
        let yr = re.matvec(&x);
        let yi = im.matvec(&x);
        let ys = s.matvec(&x);
        let complex_nsq = crate::linalg::norm2_sq(&yr) + crate::linalg::norm2_sq(&yi);
        let stacked_nsq = crate::linalg::norm2_sq(&ys);
        assert!((complex_nsq - stacked_nsq).abs() / complex_nsq < 1e-5);
    }

    #[test]
    fn wider_fov_changes_matrix() {
        let (a, _) = tiny();
        let g1 = ImageGrid::new(8, 0.1);
        let g2 = ImageGrid::new(8, 0.8);
        let m1 = stacked_measurement_matrix(&a, &g1);
        let m2 = stacked_measurement_matrix(&a, &g2);
        assert_ne!(m1.data, m2.data);
    }
}
