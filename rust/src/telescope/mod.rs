//! Radio-interferometry substrate (S6) — the paper's application domain.
//!
//! Implements the pipeline of the paper's §7 (supplementary): antenna
//! geometry → baselines → measurement matrix Φ (Eqn. 75) → point-source sky
//! → visibilities `y = Φx + e` at a target SNR → dirty image / dirty beam.
//!
//! **Substitution note (DESIGN.md §6):** we do not have the LOFAR CS302
//! measurement set; given the station geometry and the image grid, Φ is
//! fully determined by Eqn. 75, so a geometry-faithful simulator exercises
//! the identical code path. The complex system is embedded into stacked
//! real form (`[[Re Φ];[Im Φ]]`, exact for a real-valued sky), which keeps
//! every solver and kernel in f32 real arithmetic.
//!
//! Two problem constructions coexist:
//!
//! * [`AstroProblem`] materializes Φ over the **full L² ordered-pair
//!   set** — the paper-parity figure path.
//! * [`op::SkyProblem`] builds on the matrix-free [`op::VisibilityOp`]
//!   over the **unique baselines** (the full set's stacked-real embedding
//!   is rank-deficient; see [`geometry`]) — the served/CLI/bench path,
//!   with the low-precision sampling variant ([`op::LowPrecVisibilityOp`]
//!   + [`op::lowprec_problem`]) behind
//!   `coordinator::OperatorSpec::Visibility`.
//!
//! Noise in both is physically structured ([`visibility::add_noise`]):
//! independent draws only on unique baselines + autocorrelations, with
//! conjugate components mirrored.

pub mod dirty;
pub mod geometry;
pub mod grid;
pub mod op;
pub mod sky;
pub mod steering;
pub mod visibility;

pub use geometry::AntennaArray;
pub use grid::ImageGrid;
pub use op::{LowPrecVisibilityOp, SkyProblem, VisibilityOp};
pub use sky::SkyModel;

use crate::linalg::Mat;
use crate::rng::XorShift128Plus;

/// A fully materialized interferometric recovery problem.
#[derive(Debug, Clone)]
pub struct AstroProblem {
    /// Stacked-real measurement matrix, (2·L²) × r².
    pub phi: Mat,
    /// Stacked-real visibilities (2·L²).
    pub y: Vec<f32>,
    /// Ground-truth sky vector (r²) — known because we synthesize it.
    pub x_true: Vec<f32>,
    /// Per-antenna noise std σ_n actually applied.
    pub sigma_n: f32,
    pub array: AntennaArray,
    pub grid: ImageGrid,
    pub sky: SkyModel,
}

/// Problem-construction parameters (paper §4 defaults).
#[derive(Debug, Clone)]
pub struct AstroConfig {
    /// Number of antennas L (paper: 30 low-band antennas).
    pub antennas: usize,
    /// Image resolution r (pixels per axis; paper: 256, scaled default 64).
    pub resolution: usize,
    /// Field-of-view half width `d` in direction cosines (Fig 7 knob).
    pub fov_half_width: f64,
    /// Number of point sources (paper: 30 strong sources).
    pub sources: usize,
    /// SNR at antenna level in dB (paper: 0 dB).
    pub snr_db: f64,
    /// Observation frequency in Hz (LOFAR low band: 15–80 MHz).
    pub freq_hz: f64,
    /// Bit width of the low-precision sampling path (2 | 4 | 8), or 0 to
    /// run the f32 path only.
    pub bits: u8,
    /// Recovery sparsity s, or 0 to default to the source count.
    pub sparsity: usize,
    /// Build [`op::SkyProblem`] on the full L² ordered-pair set instead
    /// of the unique-baseline default (paper-parity figures only — the
    /// full set's stacked-real embedding is rank-deficient).
    pub full_baselines: bool,
}

impl Default for AstroConfig {
    fn default() -> Self {
        Self {
            antennas: 30,
            resolution: 64,
            fov_half_width: 0.4,
            sources: 30,
            snr_db: 0.0,
            freq_hz: 50e6,
            bits: 8,
            sparsity: 0,
            full_baselines: false,
        }
    }
}

impl AstroConfig {
    /// The resolved sparsity target (0 ⇒ the synthesized source count).
    pub fn effective_sparsity(&self) -> usize {
        if self.sparsity == 0 {
            self.sources
        } else {
            self.sparsity
        }
    }

    /// Cross-field gate (config file / CLI parse, and
    /// [`op::SkyProblem::build`]).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            (2..=512).contains(&self.antennas),
            "astro.antennas {} must be in 2..=512",
            self.antennas
        );
        anyhow::ensure!(
            (2..=1024).contains(&self.resolution),
            "astro.resolution {} must be in 2..=1024",
            self.resolution
        );
        anyhow::ensure!(
            self.fov_half_width > 0.0 && self.fov_half_width <= 1.0,
            "astro.fov_half_width {} needs 0 < d <= 1 (direction cosines)",
            self.fov_half_width
        );
        anyhow::ensure!(
            self.sources >= 1 && self.sources <= self.resolution * self.resolution,
            "astro.sources {} must be in 1..=r²",
            self.sources
        );
        anyhow::ensure!(self.snr_db.is_finite(), "astro.snr_db must be finite");
        anyhow::ensure!(
            self.freq_hz.is_finite() && self.freq_hz > 0.0,
            "astro.freq_hz {} must be finite and positive",
            self.freq_hz
        );
        anyhow::ensure!(
            matches!(self.bits, 0 | 2 | 4 | 8),
            "astro.bits {} must be 0 (f32) or a packed width (2|4|8)",
            self.bits
        );
        anyhow::ensure!(
            self.effective_sparsity() <= self.resolution * self.resolution,
            "astro.sparsity {} exceeds the image dimension",
            self.sparsity
        );
        Ok(())
    }
}

impl AstroProblem {
    /// Synthesize a complete problem from configuration + seed.
    pub fn build(cfg: &AstroConfig, seed: u64) -> Self {
        let mut rng = XorShift128Plus::new(seed);
        let array = AntennaArray::lofar_like(cfg.antennas, cfg.freq_hz, &mut rng);
        let grid = ImageGrid::new(cfg.resolution, cfg.fov_half_width);
        let phi = steering::stacked_measurement_matrix(&array, &grid);
        let sky = SkyModel::random_points(&grid, cfg.sources, &mut rng);
        let x_true = sky.to_vector(grid.pixels());
        let (y, sigma_n) =
            visibility::observe(&phi, &x_true, cfg.snr_db, &mut rng, cfg.antennas);
        Self { phi, y, x_true, sigma_n, array, grid, sky }
    }

    /// Number of stacked-real measurement rows (2·L²).
    pub fn m(&self) -> usize {
        self.phi.rows
    }

    /// Number of pixels (r²).
    pub fn n(&self) -> usize {
        self.phi.cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_dimensions_consistent() {
        let cfg = AstroConfig { antennas: 6, resolution: 16, sources: 5, ..Default::default() };
        let p = AstroProblem::build(&cfg, 1);
        assert_eq!(p.m(), 2 * 6 * 6);
        assert_eq!(p.n(), 16 * 16);
        assert_eq!(p.y.len(), p.m());
        assert_eq!(p.x_true.len(), p.n());
        assert_eq!(p.x_true.iter().filter(|&&v| v != 0.0).count(), 5);
    }

    #[test]
    fn build_deterministic_in_seed() {
        let cfg = AstroConfig { antennas: 4, resolution: 8, sources: 3, ..Default::default() };
        let a = AstroProblem::build(&cfg, 7);
        let b = AstroProblem::build(&cfg, 7);
        assert_eq!(a.y, b.y);
        assert_eq!(a.x_true, b.x_true);
        let c = AstroProblem::build(&cfg, 8);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn config_validates_and_resolves_sparsity() {
        let cfg = AstroConfig::default();
        cfg.validate().unwrap();
        assert_eq!(cfg.effective_sparsity(), 30, "defaults to source count");
        assert_eq!(AstroConfig { sparsity: 12, ..cfg.clone() }.effective_sparsity(), 12);
        assert!(AstroConfig { antennas: 1, ..cfg.clone() }.validate().is_err());
        assert!(AstroConfig { resolution: 1, ..cfg.clone() }.validate().is_err());
        assert!(AstroConfig { bits: 16, ..cfg.clone() }.validate().is_err());
        assert!(AstroConfig { fov_half_width: 1.5, ..cfg.clone() }.validate().is_err());
        assert!(AstroConfig { sources: 0, ..cfg.clone() }.validate().is_err());
        AstroConfig { bits: 0, ..cfg }.validate().unwrap();
    }

    #[test]
    fn snr_is_calibrated() {
        let cfg = AstroConfig {
            antennas: 8,
            resolution: 16,
            sources: 6,
            snr_db: 0.0,
            ..Default::default()
        };
        let p = AstroProblem::build(&cfg, 3);
        // Reconstruct the clean visibilities and check achieved SNR ≈ 0 dB.
        let clean = p.phi.matvec(&p.x_true);
        let noise: Vec<f32> = p.y.iter().zip(&clean).map(|(y, c)| y - c).collect();
        let snr = 10.0
            * (crate::linalg::norm2_sq(&clean) / crate::linalg::norm2_sq(&noise)).log10();
        assert!(snr.abs() < 1.5, "snr={snr}");
    }
}
