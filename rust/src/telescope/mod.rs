//! Radio-interferometry substrate (S6) — the paper's application domain.
//!
//! Implements the pipeline of the paper's §7 (supplementary): antenna
//! geometry → baselines → measurement matrix Φ (Eqn. 75) → point-source sky
//! → visibilities `y = Φx + e` at a target SNR → dirty image / dirty beam.
//!
//! **Substitution note (DESIGN.md §6):** we do not have the LOFAR CS302
//! measurement set; given the station geometry and the image grid, Φ is
//! fully determined by Eqn. 75, so a geometry-faithful simulator exercises
//! the identical code path. The complex system is embedded into stacked
//! real form (`[[Re Φ];[Im Φ]]`, exact for a real-valued sky), which keeps
//! every solver and kernel in f32 real arithmetic.

pub mod dirty;
pub mod geometry;
pub mod grid;
pub mod sky;
pub mod steering;
pub mod visibility;

pub use geometry::AntennaArray;
pub use grid::ImageGrid;
pub use sky::SkyModel;

use crate::linalg::Mat;
use crate::rng::XorShift128Plus;

/// A fully materialized interferometric recovery problem.
#[derive(Debug, Clone)]
pub struct AstroProblem {
    /// Stacked-real measurement matrix, (2·L²) × r².
    pub phi: Mat,
    /// Stacked-real visibilities (2·L²).
    pub y: Vec<f32>,
    /// Ground-truth sky vector (r²) — known because we synthesize it.
    pub x_true: Vec<f32>,
    /// Per-antenna noise std σ_n actually applied.
    pub sigma_n: f32,
    pub array: AntennaArray,
    pub grid: ImageGrid,
    pub sky: SkyModel,
}

/// Problem-construction parameters (paper §4 defaults).
#[derive(Debug, Clone)]
pub struct AstroConfig {
    /// Number of antennas L (paper: 30 low-band antennas).
    pub antennas: usize,
    /// Image resolution r (pixels per axis; paper: 256, scaled default 64).
    pub resolution: usize,
    /// Field-of-view half width `d` in direction cosines (Fig 7 knob).
    pub fov_half_width: f64,
    /// Number of point sources (paper: 30 strong sources).
    pub sources: usize,
    /// SNR at antenna level in dB (paper: 0 dB).
    pub snr_db: f64,
    /// Observation frequency in Hz (LOFAR low band: 15–80 MHz).
    pub freq_hz: f64,
}

impl Default for AstroConfig {
    fn default() -> Self {
        Self {
            antennas: 30,
            resolution: 64,
            fov_half_width: 0.4,
            sources: 30,
            snr_db: 0.0,
            freq_hz: 50e6,
        }
    }
}

impl AstroProblem {
    /// Synthesize a complete problem from configuration + seed.
    pub fn build(cfg: &AstroConfig, seed: u64) -> Self {
        let mut rng = XorShift128Plus::new(seed);
        let array = AntennaArray::lofar_like(cfg.antennas, cfg.freq_hz, &mut rng);
        let grid = ImageGrid::new(cfg.resolution, cfg.fov_half_width);
        let phi = steering::stacked_measurement_matrix(&array, &grid);
        let sky = SkyModel::random_points(&grid, cfg.sources, &mut rng);
        let x_true = sky.to_vector(grid.pixels());
        let (y, sigma_n) = visibility::observe(&phi, &x_true, cfg.snr_db, &mut rng);
        Self { phi, y, x_true, sigma_n, array, grid, sky }
    }

    /// Number of stacked-real measurement rows (2·L²).
    pub fn m(&self) -> usize {
        self.phi.rows
    }

    /// Number of pixels (r²).
    pub fn n(&self) -> usize {
        self.phi.cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_dimensions_consistent() {
        let cfg = AstroConfig { antennas: 6, resolution: 16, sources: 5, ..Default::default() };
        let p = AstroProblem::build(&cfg, 1);
        assert_eq!(p.m(), 2 * 6 * 6);
        assert_eq!(p.n(), 16 * 16);
        assert_eq!(p.y.len(), p.m());
        assert_eq!(p.x_true.len(), p.n());
        assert_eq!(p.x_true.iter().filter(|&&v| v != 0.0).count(), 5);
    }

    #[test]
    fn build_deterministic_in_seed() {
        let cfg = AstroConfig { antennas: 4, resolution: 8, sources: 3, ..Default::default() };
        let a = AstroProblem::build(&cfg, 7);
        let b = AstroProblem::build(&cfg, 7);
        assert_eq!(a.y, b.y);
        assert_eq!(a.x_true, b.x_true);
        let c = AstroProblem::build(&cfg, 8);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn snr_is_calibrated() {
        let cfg = AstroConfig {
            antennas: 8,
            resolution: 16,
            sources: 6,
            snr_db: 0.0,
            ..Default::default()
        };
        let p = AstroProblem::build(&cfg, 3);
        // Reconstruct the clean visibilities and check achieved SNR ≈ 0 dB.
        let clean = p.phi.matvec(&p.x_true);
        let noise: Vec<f32> = p.y.iter().zip(&clean).map(|(y, c)| y - c).collect();
        let snr = 10.0
            * (crate::linalg::norm2_sq(&clean) / crate::linalg::norm2_sq(&noise)).log10();
        assert!(snr.abs() < 1.5, "snr={snr}");
    }
}
