//! Dirty image and dirty beam (paper §7, Eqns. 62–64).
//!
//! The *dirty image* is the adjoint (matched-filter / least-squares)
//! estimate `I_d = Φᴴ y`, i.e. the inverse Fourier transform of the
//! non-uniformly sampled visibilities — in the stacked-real embedding it is
//! exactly `Φ_stackedᵀ y_stacked`. The *dirty beam* is the point-spread
//! function `I_db(Δl, Δm) = Σ_baselines cos(2π(u·Δl + v·Δm))`, needed by
//! the CLEAN baseline (Algorithm 2).

use super::{AntennaArray, ImageGrid};
use crate::linalg::Mat;

/// Dirty image (length-N sky vector) from stacked-real Φ and y,
/// normalized by the number of complex baselines M = L².
pub fn dirty_image(phi_stacked: &Mat, y_stacked: &[f32]) -> Vec<f32> {
    let m_complex = phi_stacked.rows / 2;
    let mut img = phi_stacked.matvec_t(y_stacked);
    let inv = 1.0 / m_complex as f32;
    for v in &mut img {
        *v *= inv;
    }
    img
}

/// Dirty beam patch on a (2r-1)×(2r-1) grid of pixel offsets, normalized
/// to beam(0,0) = 1. Entry [dr + r-1][dc + r-1] is the response at an
/// offset of (dr, dc) pixels.
pub fn dirty_beam(array: &AntennaArray, grid: &ImageGrid) -> Mat {
    let r = grid.resolution;
    let size = 2 * r - 1;
    let cell = grid.cell();
    let baselines = array.baselines_wavelengths();
    let m = baselines.len() as f64;
    let two_pi = 2.0 * std::f64::consts::PI;
    let mut beam = Mat::zeros(size, size);
    for dr in 0..size {
        let dm = (dr as isize - (r as isize - 1)) as f64 * cell;
        for dc in 0..size {
            let dl = (dc as isize - (r as isize - 1)) as f64 * cell;
            let mut acc = 0.0f64;
            for b in &baselines {
                acc += (two_pi * (b[0] * dl + b[1] * dm)).cos();
            }
            *beam.at_mut(dr, dc) = (acc / m) as f32;
        }
    }
    beam
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::XorShift128Plus;
    use crate::telescope::{steering, visibility};

    fn setup() -> (AntennaArray, ImageGrid, Mat) {
        let mut rng = XorShift128Plus::new(1);
        let a = AntennaArray::lofar_like(8, 50e6, &mut rng);
        let g = ImageGrid::new(12, 0.4);
        let phi = steering::stacked_measurement_matrix(&a, &g);
        (a, g, phi)
    }

    #[test]
    fn dirty_beam_peak_at_center_is_one() {
        let (a, g, _) = setup();
        let beam = dirty_beam(&a, &g);
        let c = g.resolution - 1;
        assert!((beam.at(c, c) - 1.0).abs() < 1e-6);
        // Center is the global max.
        for v in &beam.data {
            assert!(*v <= 1.0 + 1e-6);
        }
    }

    #[test]
    fn dirty_image_peaks_at_source() {
        // A single noiseless point source: the dirty image peaks there.
        let (_, g, phi) = setup();
        let mut x = vec![0.0f32; g.pixels()];
        let src = 5 * g.resolution + 7;
        x[src] = 1.0;
        let y = visibility::observe_clean(&phi, &x);
        let img = dirty_image(&phi, &y);
        let argmax = img
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax, src);
    }

    #[test]
    fn dirty_image_of_single_source_matches_beam_cut() {
        // I_d = I * I_db for a unit point source ⇒ the dirty image row
        // through the source equals the beam row (up to fp error).
        let (a, g, phi) = setup();
        let r = g.resolution;
        let src_row = 6;
        let src_col = 6;
        let mut x = vec![0.0f32; g.pixels()];
        x[g.index(src_row, src_col)] = 1.0;
        let y = visibility::observe_clean(&phi, &x);
        let img = dirty_image(&phi, &y);
        let beam = dirty_beam(&a, &g);
        for col in 0..r {
            let img_v = img[g.index(src_row, col)];
            let beam_v = beam.at(r - 1, (col as isize - src_col as isize + r as isize - 1) as usize);
            assert!((img_v - beam_v).abs() < 1e-3, "col={col}: {img_v} vs {beam_v}");
        }
    }

    #[test]
    fn dirty_beam_symmetric() {
        let (a, g, _) = setup();
        let beam = dirty_beam(&a, &g);
        let size = 2 * g.resolution - 1;
        for i in 0..size {
            for j in 0..size {
                let v1 = beam.at(i, j);
                let v2 = beam.at(size - 1 - i, size - 1 - j);
                assert!((v1 - v2).abs() < 1e-5, "beam must be centro-symmetric");
            }
        }
    }
}
