//! Antenna array geometry: positions, baselines, layout generators.
//!
//! The paper uses one LOFAR station (CS302, 30 low-band antennas in the
//! 15–80 MHz band). LOFAR LBA stations place dipoles in a dense
//! pseudo-random cluster with a handful of outliers — we generate layouts
//! with the same character (`lofar_like`): a core with sunflower-spiral
//! pseudo-random packing plus ~20% scattered outer antennas. Uniform-grid
//! and uniform-random layouts are provided for ablations.

use crate::rng::XorShift128Plus;

pub const SPEED_OF_LIGHT: f64 = 299_792_458.0;

/// An antenna station: 2-D positions (meters) and observing frequency.
#[derive(Debug, Clone)]
pub struct AntennaArray {
    /// Antenna positions in meters (x, y), projected station plane.
    pub positions: Vec<[f64; 2]>,
    /// Observing frequency in Hz.
    pub freq_hz: f64,
}

impl AntennaArray {
    pub fn len(&self) -> usize {
        self.positions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.positions.is_empty()
    }

    /// Observation wavelength λ = c / f (meters).
    pub fn wavelength(&self) -> f64 {
        SPEED_OF_LIGHT / self.freq_hz
    }

    /// LOFAR-LBA-like station: dense sunflower-spiral core (80%) with
    /// jitter + scattered outliers (20%), ~87 m aperture like CS302's LBA
    /// field.
    pub fn lofar_like(l: usize, freq_hz: f64, rng: &mut XorShift128Plus) -> Self {
        assert!(l >= 2, "need at least 2 antennas");
        let golden = std::f64::consts::PI * (3.0 - 5.0f64.sqrt());
        let core = (l as f64 * 0.8).ceil() as usize;
        let core_radius = 30.0;
        let outer_radius = 43.5; // CS302 LBA field is ~87 m across
        let mut positions = Vec::with_capacity(l);
        for k in 0..core {
            // Sunflower packing: r ∝ sqrt(k), θ = k·golden-angle, + jitter.
            let r = core_radius * ((k as f64 + 0.5) / core as f64).sqrt();
            let theta = k as f64 * golden;
            let jx = rng.uniform_in(-1.5, 1.5);
            let jy = rng.uniform_in(-1.5, 1.5);
            positions.push([r * theta.cos() + jx, r * theta.sin() + jy]);
        }
        for _ in core..l {
            let r = rng.uniform_in(core_radius, outer_radius);
            let theta = rng.uniform_in(0.0, 2.0 * std::f64::consts::PI);
            positions.push([r * theta.cos(), r * theta.sin()]);
        }
        Self { positions, freq_hz }
    }

    /// Regular square grid (side ≈ √L), for ablations.
    pub fn uniform_grid(l: usize, spacing_m: f64, freq_hz: f64) -> Self {
        let side = (l as f64).sqrt().ceil() as usize;
        let mut positions = Vec::with_capacity(l);
        'outer: for i in 0..side {
            for j in 0..side {
                if positions.len() >= l {
                    break 'outer;
                }
                positions.push([i as f64 * spacing_m, j as f64 * spacing_m]);
            }
        }
        Self { positions, freq_hz }
    }

    /// Uniform random positions in a disc of the given radius.
    pub fn random_disc(l: usize, radius_m: f64, freq_hz: f64, rng: &mut XorShift128Plus) -> Self {
        let positions = (0..l)
            .map(|_| {
                let r = radius_m * rng.uniform().sqrt();
                let t = rng.uniform_in(0.0, 2.0 * std::f64::consts::PI);
                [r * t.cos(), r * t.sin()]
            })
            .collect();
        Self { positions, freq_hz }
    }

    /// All ordered antenna pairs (i, k) — M = L² visibilities including
    /// autocorrelations, matching the paper's M = L².
    pub fn baselines_wavelengths(&self) -> Vec<[f64; 2]> {
        let lambda = self.wavelength();
        let l = self.len();
        let mut out = Vec::with_capacity(l * l);
        for i in 0..l {
            for k in 0..l {
                let u = (self.positions[i][0] - self.positions[k][0]) / lambda;
                let v = (self.positions[i][1] - self.positions[k][1]) / lambda;
                out.push([u, v]);
            }
        }
        out
    }

    /// Unique baselines only: ordered pairs i < k (drops autocorrelations
    /// and conjugate duplicates). M = L(L−1)/2. The stacked-real embedding
    /// of the FULL L² set is rank-deficient (autocorrelation rows are
    /// identical, conjugate pairs are linearly dependent), so RIP
    /// diagnostics (Figs 3/7/8) use this set — physically, the distinct
    /// visibilities an interferometer actually measures.
    pub fn unique_baselines_wavelengths(&self) -> Vec<[f64; 2]> {
        let lambda = self.wavelength();
        let l = self.len();
        let mut out = Vec::with_capacity(l * (l - 1) / 2);
        for i in 0..l {
            for k in (i + 1)..l {
                let u = (self.positions[i][0] - self.positions[k][0]) / lambda;
                let v = (self.positions[i][1] - self.positions[k][1]) / lambda;
                out.push([u, v]);
            }
        }
        out
    }

    /// Maximum baseline length in wavelengths (sets angular resolution).
    pub fn max_baseline_wl(&self) -> f64 {
        self.baselines_wavelengths()
            .iter()
            .map(|b| (b[0] * b[0] + b[1] * b[1]).sqrt())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lofar_like_count_and_extent() {
        let mut rng = XorShift128Plus::new(1);
        let a = AntennaArray::lofar_like(30, 50e6, &mut rng);
        assert_eq!(a.len(), 30);
        for p in &a.positions {
            let r = (p[0] * p[0] + p[1] * p[1]).sqrt();
            assert!(r <= 50.0, "antenna outside field: r={r}");
        }
    }

    #[test]
    fn baselines_count_is_l_squared() {
        let mut rng = XorShift128Plus::new(2);
        let a = AntennaArray::lofar_like(7, 50e6, &mut rng);
        assert_eq!(a.baselines_wavelengths().len(), 49);
    }

    #[test]
    fn baselines_antisymmetric_with_zero_diagonal() {
        let mut rng = XorShift128Plus::new(3);
        let a = AntennaArray::lofar_like(5, 50e6, &mut rng);
        let b = a.baselines_wavelengths();
        let l = 5;
        for i in 0..l {
            assert_eq!(b[i * l + i], [0.0, 0.0]);
            for k in 0..l {
                assert!((b[i * l + k][0] + b[k * l + i][0]).abs() < 1e-12);
                assert!((b[i * l + k][1] + b[k * l + i][1]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn wavelength_lofar_band() {
        let a = AntennaArray::uniform_grid(4, 5.0, 50e6);
        assert!((a.wavelength() - 5.9958).abs() < 0.01);
    }

    #[test]
    fn uniform_grid_positions() {
        let a = AntennaArray::uniform_grid(4, 2.0, 50e6);
        assert_eq!(a.len(), 4);
        assert_eq!(a.positions[0], [0.0, 0.0]);
        assert_eq!(a.positions[3], [2.0, 2.0]);
    }

    #[test]
    fn random_disc_within_radius() {
        let mut rng = XorShift128Plus::new(4);
        let a = AntennaArray::random_disc(50, 10.0, 50e6, &mut rng);
        for p in &a.positions {
            assert!((p[0] * p[0] + p[1] * p[1]).sqrt() <= 10.0 + 1e-9);
        }
    }

    #[test]
    fn max_baseline_positive() {
        let mut rng = XorShift128Plus::new(5);
        let a = AntennaArray::lofar_like(10, 50e6, &mut rng);
        assert!(a.max_baseline_wl() > 1.0);
    }
}
