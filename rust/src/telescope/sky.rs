//! Point-source sky models (paper §7: "we assume a point source model for
//! the sky ... the sky is populated with 30 strong sources").

use super::ImageGrid;
use crate::rng::XorShift128Plus;

/// A sparse sky: point sources at pixel indices with positive fluxes.
#[derive(Debug, Clone)]
pub struct SkyModel {
    /// (pixel index, flux) pairs; indices are distinct.
    pub sources: Vec<(usize, f32)>,
}

impl SkyModel {
    /// `count` sources at distinct random pixels, fluxes uniform in
    /// [0.5, 1.5] (strong sources of comparable magnitude, the regime in
    /// which IHT is known to do well — paper §4).
    pub fn random_points(grid: &ImageGrid, count: usize, rng: &mut XorShift128Plus) -> Self {
        let n = grid.pixels();
        assert!(count <= n);
        let pixels = rng.choose_k(n, count);
        let sources = pixels
            .into_iter()
            .map(|p| (p, rng.uniform_in(0.5, 1.5) as f32))
            .collect();
        Self { sources }
    }

    /// Dense sky vector x ∈ R^n.
    pub fn to_vector(&self, n: usize) -> Vec<f32> {
        let mut x = vec![0.0f32; n];
        for &(p, f) in &self.sources {
            x[p] = f;
        }
        x
    }

    /// Support set (sorted pixel indices).
    pub fn support(&self) -> Vec<usize> {
        let mut s: Vec<usize> = self.sources.iter().map(|&(p, _)| p).collect();
        s.sort_unstable();
        s
    }

    pub fn sparsity(&self) -> usize {
        self.sources.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn source_count_and_distinct() {
        let g = ImageGrid::new(16, 0.4);
        let mut rng = XorShift128Plus::new(1);
        let sky = SkyModel::random_points(&g, 30, &mut rng);
        assert_eq!(sky.sparsity(), 30);
        let sup = sky.support();
        let mut dedup = sup.clone();
        dedup.dedup();
        assert_eq!(sup, dedup, "pixels must be distinct");
    }

    #[test]
    fn flux_range() {
        let g = ImageGrid::new(16, 0.4);
        let mut rng = XorShift128Plus::new(2);
        let sky = SkyModel::random_points(&g, 50, &mut rng);
        for &(_, f) in &sky.sources {
            assert!((0.5..=1.5).contains(&f));
        }
    }

    #[test]
    fn to_vector_places_sources() {
        let sky = SkyModel { sources: vec![(3, 1.0), (7, 0.5)] };
        let x = sky.to_vector(10);
        assert_eq!(x[3], 1.0);
        assert_eq!(x[7], 0.5);
        assert_eq!(x.iter().filter(|&&v| v != 0.0).count(), 2);
    }

    #[test]
    fn full_sky_allowed() {
        let g = ImageGrid::new(4, 0.4);
        let mut rng = XorShift128Plus::new(3);
        let sky = SkyModel::random_points(&g, 16, &mut rng);
        assert_eq!(sky.sparsity(), 16);
    }
}
