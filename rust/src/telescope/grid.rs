//! Sky image grid: pixel ↔ direction-cosine mapping.
//!
//! The sky patch around the pointing direction is parameterized by
//! direction cosines `(l, m) ∈ [-d, d]²` (paper §7.3: the half-width `d`
//! is the instrument-dependent knob that tunes the RIP constants — Fig 7).
//! Pixels are cell centers of an r×r grid, vectorized row-major
//! (`w = row * r + col`, matching `vec(I)` of Definition 1).

/// An r×r image grid over `[-d, d]²` in direction cosines.
#[derive(Debug, Clone, Copy)]
pub struct ImageGrid {
    /// Pixels per axis.
    pub resolution: usize,
    /// Field-of-view half width in direction cosines (0 < d ≤ 1).
    pub half_width: f64,
}

impl ImageGrid {
    pub fn new(resolution: usize, half_width: f64) -> Self {
        assert!(resolution >= 1);
        assert!(
            half_width > 0.0 && half_width <= 1.0,
            "direction cosines need 0 < d <= 1, got {half_width}"
        );
        Self { resolution, half_width }
    }

    /// Total number of pixels N = r².
    pub fn pixels(&self) -> usize {
        self.resolution * self.resolution
    }

    /// Direction cosines (l, m) of the center of pixel (row, col).
    pub fn direction(&self, row: usize, col: usize) -> [f64; 2] {
        let r = self.resolution as f64;
        let d = self.half_width;
        let l = -d + 2.0 * d * (col as f64 + 0.5) / r;
        let m = -d + 2.0 * d * (row as f64 + 0.5) / r;
        [l, m]
    }

    /// Direction cosines of linear pixel index `w` (row-major).
    pub fn direction_of(&self, w: usize) -> [f64; 2] {
        self.direction(w / self.resolution, w % self.resolution)
    }

    /// Linear pixel index from (row, col).
    pub fn index(&self, row: usize, col: usize) -> usize {
        row * self.resolution + col
    }

    /// Pixel size in direction cosines.
    pub fn cell(&self) -> f64 {
        2.0 * self.half_width / self.resolution as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pixel_count() {
        assert_eq!(ImageGrid::new(16, 0.5).pixels(), 256);
    }

    #[test]
    fn directions_span_symmetric_range() {
        let g = ImageGrid::new(8, 0.4);
        let first = g.direction(0, 0);
        let last = g.direction(7, 7);
        assert!((first[0] + last[0]).abs() < 1e-12, "symmetric about 0");
        assert!((first[1] + last[1]).abs() < 1e-12);
        assert!(first[0] > -0.4 && last[0] < 0.4);
    }

    #[test]
    fn center_pixels_near_origin() {
        let g = ImageGrid::new(2, 1.0);
        // centers at ±0.5
        assert_eq!(g.direction(0, 0), [-0.5, -0.5]);
        assert_eq!(g.direction(1, 1), [0.5, 0.5]);
    }

    #[test]
    fn index_roundtrip() {
        let g = ImageGrid::new(5, 0.3);
        for row in 0..5 {
            for col in 0..5 {
                let w = g.index(row, col);
                assert_eq!(g.direction_of(w), g.direction(row, col));
            }
        }
    }

    #[test]
    fn cell_size() {
        let g = ImageGrid::new(10, 0.5);
        assert!((g.cell() - 0.1).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_half_width() {
        ImageGrid::new(4, 1.5);
    }
}
