//! In-tree micro-benchmark harness (no criterion offline; DESIGN.md §6).
//!
//! Deliberately small: warmup, fixed iteration count, robust statistics
//! (median / mean / p10 / p90), and a black-box sink to defeat dead-code
//! elimination. All `cargo bench` targets (harness = false) use this.

use std::hint::black_box;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub p10: Duration,
    pub p90: Duration,
}

impl BenchStats {
    pub fn median_s(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Time `f` with `warmup` + `iters` repetitions; returns robust stats.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    assert!(iters >= 1);
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    BenchStats {
        iters,
        median: samples[iters / 2],
        mean,
        p10: samples[iters / 10],
        p90: samples[(iters * 9) / 10],
    }
}

/// Print one result line in a fixed parseable format.
pub fn report(name: &str, stats: &BenchStats) {
    println!(
        "bench {name:<44} median {:>12.3?}  mean {:>12.3?}  p10 {:>12.3?}  p90 {:>12.3?}  (n={})",
        stats.median, stats.mean, stats.p10, stats.p90, stats.iters
    );
}

/// Convenience wrapper: run + report + return stats.
pub fn run<T>(name: &str, warmup: usize, iters: usize, f: impl FnMut() -> T) -> BenchStats {
    let stats = bench(warmup, iters, f);
    report(name, &stats);
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = bench(2, 50, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.p10 <= s.median && s.median <= s.p90);
        assert!(s.mean.as_nanos() > 0);
    }

    #[test]
    fn measures_sleep_roughly() {
        let s = bench(0, 3, || std::thread::sleep(Duration::from_millis(2)));
        assert!(s.median >= Duration::from_millis(2));
        assert!(s.median < Duration::from_millis(50));
    }
}
