//! In-tree micro-benchmark harness (no criterion offline; DESIGN.md §6).
//!
//! Deliberately small: warmup, fixed iteration count, robust statistics
//! (median / mean / p10 / p90), and a black-box sink to defeat dead-code
//! elimination. All `cargo bench` targets (harness = false) use this.
//!
//! [`JsonReporter`] additionally collects results into a machine-readable
//! `BENCH_<name>.json` file (median/p10/p90 seconds per kernel) so bench
//! runs leave a perf trajectory that later PRs can diff against.

use crate::io::json::Json;
use std::collections::BTreeMap;
use std::hint::black_box;
use std::path::Path;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub p10: Duration,
    pub p90: Duration,
}

impl BenchStats {
    pub fn median_s(&self) -> f64 {
        self.median.as_secs_f64()
    }

    pub fn mean_s(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    pub fn p10_s(&self) -> f64 {
        self.p10.as_secs_f64()
    }

    pub fn p90_s(&self) -> f64 {
        self.p90.as_secs_f64()
    }
}

/// Time `f` with `warmup` + `iters` repetitions; returns robust stats.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    assert!(iters >= 1);
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    BenchStats {
        iters,
        median: samples[iters / 2],
        mean,
        p10: samples[iters / 10],
        p90: samples[(iters * 9) / 10],
    }
}

/// Print one result line in a fixed parseable format.
pub fn report(name: &str, stats: &BenchStats) {
    println!(
        "bench {name:<44} median {:>12.3?}  mean {:>12.3?}  p10 {:>12.3?}  p90 {:>12.3?}  (n={})",
        stats.median, stats.mean, stats.p10, stats.p90, stats.iters
    );
}

/// Convenience wrapper: run + report + return stats.
pub fn run<T>(name: &str, warmup: usize, iters: usize, f: impl FnMut() -> T) -> BenchStats {
    let stats = bench(warmup, iters, f);
    report(name, &stats);
    stats
}

/// Collects bench results and serializes them as JSON via [`crate::io::json`]
/// (no external crates offline). One reporter per bench target; `write_file`
/// emits `BENCH_<bench>.json` next to the working directory of `cargo bench`.
pub struct JsonReporter {
    bench: String,
    entries: Vec<(String, BenchStats)>,
}

impl JsonReporter {
    pub fn new(bench: &str) -> Self {
        Self { bench: bench.to_string(), entries: Vec::new() }
    }

    /// Record an already-measured result under `name`.
    pub fn record(&mut self, name: &str, stats: &BenchStats) {
        self.entries.push((name.to_string(), *stats));
    }

    /// Run + print + record in one step (the usual bench-target call).
    pub fn run<T>(
        &mut self,
        name: &str,
        warmup: usize,
        iters: usize,
        f: impl FnMut() -> T,
    ) -> BenchStats {
        let stats = run(name, warmup, iters, f);
        self.record(name, &stats);
        stats
    }

    /// The collected results as a JSON value.
    pub fn to_json(&self) -> Json {
        let results: Vec<Json> = self
            .entries
            .iter()
            .map(|(name, s)| {
                let mut o = BTreeMap::new();
                o.insert("name".to_string(), Json::Str(name.clone()));
                o.insert("iters".to_string(), Json::Num(s.iters as f64));
                o.insert("median_s".to_string(), Json::Num(s.median_s()));
                o.insert("mean_s".to_string(), Json::Num(s.mean_s()));
                o.insert("p10_s".to_string(), Json::Num(s.p10_s()));
                o.insert("p90_s".to_string(), Json::Num(s.p90_s()));
                Json::Obj(o)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("bench".to_string(), Json::Str(self.bench.clone()));
        top.insert("results".to_string(), Json::Arr(results));
        Json::Obj(top)
    }

    /// Write `BENCH_<bench>.json` into `dir`; returns the path written.
    pub fn write_file(&self, dir: impl AsRef<Path>) -> std::io::Result<std::path::PathBuf> {
        let path = dir.as_ref().join(format!("BENCH_{}.json", self.bench));
        std::fs::write(&path, self.to_json().dump())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = bench(2, 50, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.p10 <= s.median && s.median <= s.p90);
        assert!(s.mean.as_nanos() > 0);
    }

    #[test]
    fn measures_sleep_roughly() {
        let s = bench(0, 3, || std::thread::sleep(Duration::from_millis(2)));
        assert!(s.median >= Duration::from_millis(2));
        assert!(s.median < Duration::from_millis(50));
    }

    #[test]
    fn json_reporter_roundtrips() {
        let mut rep = JsonReporter::new("unit");
        let s = bench(0, 5, || black_box(3u64.pow(7)));
        rep.record("pow/scalar/2bit", &s);
        rep.record("pow/avx2/2bit", &s);
        let j = Json::parse(&rep.to_json().dump()).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str(), Some("unit"));
        let rs = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].get("name").unwrap().as_str(), Some("pow/scalar/2bit"));
        assert_eq!(rs[0].get("iters").unwrap().as_usize(), Some(5));
        for key in ["median_s", "mean_s", "p10_s", "p90_s"] {
            assert!(rs[0].get(key).unwrap().as_f64().is_some(), "{key}");
        }
    }

    #[test]
    fn json_reporter_writes_file() {
        let dir = std::env::temp_dir();
        let mut rep = JsonReporter::new("filetest");
        let s = bench(0, 3, || black_box(1 + 1));
        rep.record("noop", &s);
        let path = rep.write_file(&dir).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        let _ = std::fs::remove_file(path);
    }
}
