//! Portable scalar backend — the guaranteed-correct reference.
//!
//! These are the original `lowprec` loops: 16 contiguous accumulator lanes
//! for the mixed int·f32 dots (the lane array maps 1:1 onto SIMD registers,
//! so LLVM's autovectorizer turns them into FMA streams on any target), and
//! whole-word LUT decode for the 2/4-bit unpack (one table hit emits 4 or 2
//! codes per single u32/u16 store). Every other backend is tested
//! bit-for-bit (integer kernels) or to tolerance (f32 reductions) against
//! this module.

use super::{Backend, Kernels};
use crate::quant::Quantizer;

/// The portable backend (unit struct; stateless).
pub struct Scalar;

impl Kernels for Scalar {
    fn backend(&self) -> Backend {
        Backend::Scalar
    }

    fn name(&self) -> &'static str {
        "scalar"
    }

    fn dot_i8_f32(&self, row: &[i8], x: &[f32]) -> f32 {
        dot_i8_f32(row, x)
    }

    fn dot_u8_f32(&self, row: &[u8], x: &[f32]) -> f32 {
        dot_u8_f32(row, x)
    }

    fn decode_row(&self, words: &[u64], bits: u8, n: usize, out: &mut [i8]) {
        decode_row(words, bits, n, out)
    }

    fn packed_field_dot_q8(&self, words: &[u64], bits: u8, n: usize, xq: &[i8]) -> i64 {
        packed_field_dot_q8(words, bits, n, xq)
    }

    fn scale_add_i8(&self, y: &mut [f32], row: &[i8], c: f32) {
        scale_add_i8(y, row, c)
    }
}

/// Dot of an int8 row with an f32 vector — 16 contiguous accumulator lanes
/// (the i8→f32 widening maps onto VPMOVSXBD + VCVTDQ2PS).
pub(crate) fn dot_i8_f32(row: &[i8], x: &[f32]) -> f32 {
    debug_assert_eq!(row.len(), x.len());
    const LANES: usize = 16;
    let mut acc = [0.0f32; LANES];
    let chunks = row.len() / LANES;
    for c in 0..chunks {
        let i = c * LANES;
        let (rv, xv) = (&row[i..i + LANES], &x[i..i + LANES]);
        for k in 0..LANES {
            acc[k] += rv[k] as f32 * xv[k];
        }
    }
    let mut s = 0.0f32;
    for a in acc {
        s += a;
    }
    for i in chunks * LANES..row.len() {
        s += row[i] as f32 * x[i];
    }
    s
}

/// Dot of a u8 row with an f32 vector (16 accumulator lanes).
pub(crate) fn dot_u8_f32(row: &[u8], x: &[f32]) -> f32 {
    debug_assert_eq!(row.len(), x.len());
    const LANES: usize = 16;
    let mut acc = [0.0f32; LANES];
    let chunks = row.len() / LANES;
    for c in 0..chunks {
        let i = c * LANES;
        let (rv, xv) = (&row[i..i + LANES], &x[i..i + LANES]);
        for k in 0..LANES {
            acc[k] += rv[k] as f32 * xv[k];
        }
    }
    let mut s = 0.0f32;
    for a in acc {
        s += a;
    }
    for i in chunks * LANES..row.len() {
        s += row[i] as f32 * x[i];
    }
    s
}

/// `y[j] += c · row[j]` — no reduction, so the plain zip loop vectorizes.
pub(crate) fn scale_add_i8(y: &mut [f32], row: &[i8], c: f32) {
    debug_assert_eq!(y.len(), row.len());
    for (yi, &r) in y.iter_mut().zip(row) {
        *yi += c * r as f32;
    }
}

/// Byte → 4 signed 2-bit codes, packed little-endian into one u32
/// (field − half, half = 1): one table hit + one unaligned store decodes
/// 4 elements.
fn lut2_u32() -> &'static [u32; 256] {
    static LUT: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [0u32; 256];
        for (b, entry) in t.iter_mut().enumerate() {
            let mut bytes = [0u8; 4];
            for k in 0..4 {
                bytes[k] = ((((b >> (2 * k)) & 0b11) as i8) - 1) as u8;
            }
            *entry = u32::from_le_bytes(bytes);
        }
        t
    })
}

/// Byte → 2 signed 4-bit codes packed into one u16 (field − half, half=4).
fn lut4_u16() -> &'static [u16; 256] {
    static LUT: std::sync::OnceLock<[u16; 256]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [0u16; 256];
        for (b, entry) in t.iter_mut().enumerate() {
            let lo = ((((b >> 0) & 0xF) as i8) - 4) as u8;
            let hi = ((((b >> 4) & 0xF) as i8) - 4) as u8;
            *entry = u16::from_le_bytes([lo, hi]);
        }
        t
    })
}

/// Generic shift/mask decode (tail path + odd widths).
pub(crate) fn decode_generic(words: &[u64], bits: u8, n: usize, out: &mut [i8]) {
    let lanes = 64 / bits as usize;
    let mask = (1u64 << bits) - 1;
    let half = Quantizer::new(bits).half();
    let mut j = 0;
    for &w in words {
        let mut ww = w;
        let take = lanes.min(n - j);
        for k in 0..take {
            out[j + k] = ((ww & mask) as i32 - half) as i8;
            ww >>= bits;
        }
        j += take;
        if j >= n {
            break;
        }
    }
}

/// Decode one packed row into signed codes (LUT fast path, shift/mask tail).
pub(crate) fn decode_row(words: &[u64], bits: u8, n: usize, out: &mut [i8]) {
    debug_assert!(out.len() >= n);
    let lanes = 64 / bits as usize;
    let full_words = n / lanes;
    let dst = out.as_mut_ptr() as *mut u8;
    match bits {
        2 => {
            let lut = lut2_u32();
            for (wi, &w) in words[..full_words].iter().enumerate() {
                let bytes = w.to_le_bytes();
                let base = wi * 32;
                for (bi, b) in bytes.into_iter().enumerate() {
                    // SAFETY: base+4bi+4 <= full_words*32 <= n <= out.len()
                    unsafe {
                        (dst.add(base + 4 * bi) as *mut u32).write_unaligned(lut[b as usize]);
                    }
                }
            }
        }
        4 => {
            let lut = lut4_u16();
            for (wi, &w) in words[..full_words].iter().enumerate() {
                let bytes = w.to_le_bytes();
                let base = wi * 16;
                for (bi, b) in bytes.into_iter().enumerate() {
                    // SAFETY: base+2bi+2 <= full_words*16 <= n <= out.len()
                    unsafe {
                        (dst.add(base + 2 * bi) as *mut u16).write_unaligned(lut[b as usize]);
                    }
                }
            }
        }
        8 => {
            // field = code + 64: subtract in the byte domain (wrapping sub
            // vectorizes to one psubb over the whole row).
            for (wi, &w) in words[..full_words].iter().enumerate() {
                let bytes = w.to_le_bytes();
                let base = wi * 8;
                for (bi, b) in bytes.into_iter().enumerate() {
                    out[base + bi] = b.wrapping_sub(64) as i8;
                }
            }
        }
        _ => {
            decode_generic(words, bits, n, out);
            return;
        }
    }
    // Ragged tail (n not a multiple of lanes-per-word).
    let done = full_words * lanes;
    if done < n {
        decode_generic(&words[full_words..], bits, n - done, &mut out[done..]);
    }
}

/// `Σ field_j · xq_j` over the raw (biased, unsigned) packed fields.
pub(crate) fn packed_field_dot_q8(words: &[u64], bits: u8, n: usize, xq: &[i8]) -> i64 {
    debug_assert!(xq.len() >= n);
    let lanes = 64 / bits as usize;
    let mask = (1u64 << bits) - 1;
    let mut acc: i64 = 0;
    let mut j = 0usize;
    for &w in words {
        if j >= n {
            break;
        }
        let mut ww = w;
        let take = lanes.min(n - j);
        for k in 0..take {
            acc += ((ww & mask) as i64) * (xq[j + k] as i64);
            ww >>= bits;
        }
        j += take;
    }
    acc
}
