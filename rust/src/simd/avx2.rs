//! AVX2 backend — runtime-detected, the rust analog of the paper's §9
//! hand-vectorized CPU routines.
//!
//! Kernel strategy:
//! * mixed int·f32 dots: widen 32 codes per iteration with
//!   `VPMOVSXBD`/`VPMOVZXBD` (`_mm256_cvtepi8_epi32` / `_mm256_cvtepu8_epi32`)
//!   and accumulate through four independent `_mm256_fmadd_ps` chains;
//! * 2/4-bit decode: in-register field unpack — shift/mask into per-position
//!   byte vectors, then a 4-way (2-bit) or 2-way (4-bit) `PUNPCKLBW`
//!   interleave tree restores element order, `PSUBB` removes the bias, one
//!   store per 16 codes;
//! * pure integer dots: `_mm256_maddubs_epi16` on the RAW unsigned fields
//!   against the signed int8 vector (fields ≤ 128 and |xq| ≤ 127, so the
//!   pairwise i16 sums cannot saturate), widened via `_mm256_madd_epi16`
//!   and flushed from i32 lanes to an i64 scalar every block — exact for
//!   any row length.
//!
//! Every function is `#[target_feature(enable = "avx2", enable = "fma")]`;
//! the [`Avx2`] kernel set is only reachable through [`supported`]
//! (`is_x86_feature_detected!`), so the safe trait wrappers are sound.

use super::{Backend, Kernels};

#[cfg(target_arch = "x86")]
use core::arch::x86::*;
#[cfg(target_arch = "x86_64")]
use core::arch::x86_64::*;

/// Runtime check for the features this backend requires.
pub(crate) fn supported() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

/// The AVX2 backend (unit struct; stateless).
pub struct Avx2;

impl Kernels for Avx2 {
    fn backend(&self) -> Backend {
        Backend::Avx2
    }

    fn name(&self) -> &'static str {
        "avx2"
    }

    fn dot_i8_f32(&self, row: &[i8], x: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), x.len());
        // SAFETY: Avx2 is only constructed behind `supported()`.
        unsafe { dot_i8_f32(row, x) }
    }

    fn dot_u8_f32(&self, row: &[u8], x: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), x.len());
        // SAFETY: as above.
        unsafe { dot_u8_f32(row, x) }
    }

    fn decode_row(&self, words: &[u64], bits: u8, n: usize, out: &mut [i8]) {
        debug_assert!(out.len() >= n);
        // SAFETY: as above.
        unsafe { decode_row(words, bits, n, out) }
    }

    fn packed_field_dot_q8(&self, words: &[u64], bits: u8, n: usize, xq: &[i8]) -> i64 {
        debug_assert!(xq.len() >= n);
        // SAFETY: as above.
        unsafe {
            match bits {
                2 => field_dot2(words, n, xq),
                4 => field_dot4(words, n, xq),
                8 => field_dot8(words, n, xq),
                _ => super::scalar::packed_field_dot_q8(words, bits, n, xq),
            }
        }
    }

    fn scale_add_i8(&self, y: &mut [f32], row: &[i8], c: f32) {
        debug_assert_eq!(y.len(), row.len());
        // SAFETY: as above.
        unsafe { scale_add_i8(y, row, c) }
    }

    fn f32_grain(&self) -> usize {
        8 // _mm256_fmadd_ps over 8 converted codes per block
    }

    fn dot_i8_f32_multi(&self, row: &[i8], xs: &[&[f32]], out: &mut [f32]) {
        debug_assert_eq!(xs.len(), out.len());
        // SAFETY: as above.
        unsafe { dot_i8_f32_multi(row, xs, out) }
    }

    fn dot_u8_f32_multi(&self, row: &[u8], xs: &[&[f32]], out: &mut [f32]) {
        debug_assert_eq!(xs.len(), out.len());
        // SAFETY: as above.
        unsafe { dot_u8_f32_multi(row, xs, out) }
    }

    fn packed_field_dot_q8_multi(
        &self,
        words: &[u64],
        bits: u8,
        n: usize,
        xqs: &[&[i8]],
        out: &mut [i64],
    ) {
        debug_assert_eq!(xqs.len(), out.len());
        match bits {
            // SAFETY: as above.
            2 => unsafe { field_dot2_multi(words, n, xqs, out) },
            4 => unsafe { field_dot4_multi(words, n, xqs, out) },
            8 => unsafe { field_dot8_multi(words, n, xqs, out) },
            _ => {
                for (o, xq) in out.iter_mut().zip(xqs) {
                    *o = super::scalar::packed_field_dot_q8(words, bits, n, xq);
                }
            }
        }
    }
}

/// Horizontal sum of 8 f32 lanes.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum_ps(v: __m256) -> f32 {
    let s = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps::<1>(v));
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_shuffle_ps::<1>(s, s));
    _mm_cvtss_f32(s)
}

/// Horizontal sum of 8 i32 lanes into an i64 (final add in 64-bit, so the
/// caller's per-block bound only needs each lane < 2^31/4).
#[inline]
#[target_feature(enable = "avx2")]
pub(super) unsafe fn hsum_epi32_i64(v: __m256i) -> i64 {
    let s = _mm_add_epi32(_mm256_castsi256_si128(v), _mm256_extracti128_si256::<1>(v));
    let s = _mm_add_epi32(s, _mm_srli_si128::<8>(s));
    _mm_cvtsi128_si32(s) as i64 + _mm_extract_epi32::<1>(s) as i64
}

#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn dot_i8_f32(row: &[i8], x: &[f32]) -> f32 {
    let n = row.len();
    let rp = row.as_ptr();
    let xp = x.as_ptr();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 32 <= n {
        let b = _mm256_loadu_si256(rp.add(i) as *const __m256i);
        let lo = _mm256_castsi256_si128(b);
        let hi = _mm256_extracti128_si256::<1>(b);
        let v0 = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(lo));
        let v1 = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(_mm_srli_si128::<8>(lo)));
        let v2 = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(hi));
        let v3 = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(_mm_srli_si128::<8>(hi)));
        acc0 = _mm256_fmadd_ps(v0, _mm256_loadu_ps(xp.add(i)), acc0);
        acc1 = _mm256_fmadd_ps(v1, _mm256_loadu_ps(xp.add(i + 8)), acc1);
        acc2 = _mm256_fmadd_ps(v2, _mm256_loadu_ps(xp.add(i + 16)), acc2);
        acc3 = _mm256_fmadd_ps(v3, _mm256_loadu_ps(xp.add(i + 24)), acc3);
        i += 32;
    }
    let mut s = hsum_ps(_mm256_add_ps(
        _mm256_add_ps(acc0, acc1),
        _mm256_add_ps(acc2, acc3),
    ));
    while i < n {
        s += *rp.add(i) as f32 * *xp.add(i);
        i += 1;
    }
    s
}

#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn dot_u8_f32(row: &[u8], x: &[f32]) -> f32 {
    let n = row.len();
    let rp = row.as_ptr();
    let xp = x.as_ptr();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 32 <= n {
        let b = _mm256_loadu_si256(rp.add(i) as *const __m256i);
        let lo = _mm256_castsi256_si128(b);
        let hi = _mm256_extracti128_si256::<1>(b);
        let v0 = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(lo));
        let v1 = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(_mm_srli_si128::<8>(lo)));
        let v2 = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(hi));
        let v3 = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(_mm_srli_si128::<8>(hi)));
        acc0 = _mm256_fmadd_ps(v0, _mm256_loadu_ps(xp.add(i)), acc0);
        acc1 = _mm256_fmadd_ps(v1, _mm256_loadu_ps(xp.add(i + 8)), acc1);
        acc2 = _mm256_fmadd_ps(v2, _mm256_loadu_ps(xp.add(i + 16)), acc2);
        acc3 = _mm256_fmadd_ps(v3, _mm256_loadu_ps(xp.add(i + 24)), acc3);
        i += 32;
    }
    let mut s = hsum_ps(_mm256_add_ps(
        _mm256_add_ps(acc0, acc1),
        _mm256_add_ps(acc2, acc3),
    ));
    while i < n {
        s += *rp.add(i) as f32 * *xp.add(i);
        i += 1;
    }
    s
}

#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn scale_add_i8(y: &mut [f32], row: &[i8], c: f32) {
    let n = y.len();
    let rp = row.as_ptr();
    let yp = y.as_mut_ptr();
    let vc = _mm256_set1_ps(c);
    let mut i = 0usize;
    while i + 8 <= n {
        let v = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(_mm_loadl_epi64(
            rp.add(i) as *const __m128i
        )));
        let yv = _mm256_loadu_ps(yp.add(i));
        _mm256_storeu_ps(yp.add(i), _mm256_fmadd_ps(v, vc, yv));
        i += 8;
    }
    while i < n {
        *yp.add(i) += c * *rp.add(i) as f32;
        i += 1;
    }
}

/// 16 packed bytes → 64 raw 2-bit fields, element order restored by a
/// 4-way byte-interleave tree.
#[inline]
#[target_feature(enable = "avx2")]
pub(super) unsafe fn unpack2_fields(b: __m128i) -> (__m128i, __m128i, __m128i, __m128i) {
    let mask = _mm_set1_epi8(0x03);
    let q0 = _mm_and_si128(b, mask);
    let q1 = _mm_and_si128(_mm_srli_epi16::<2>(b), mask);
    let q2 = _mm_and_si128(_mm_srli_epi16::<4>(b), mask);
    let q3 = _mm_and_si128(_mm_srli_epi16::<6>(b), mask);
    let t0 = _mm_unpacklo_epi8(q0, q2);
    let t1 = _mm_unpacklo_epi8(q1, q3);
    let u0 = _mm_unpackhi_epi8(q0, q2);
    let u1 = _mm_unpackhi_epi8(q1, q3);
    (
        _mm_unpacklo_epi8(t0, t1),
        _mm_unpackhi_epi8(t0, t1),
        _mm_unpacklo_epi8(u0, u1),
        _mm_unpackhi_epi8(u0, u1),
    )
}

/// 16 packed bytes → 32 raw 4-bit fields (low nibble first).
#[inline]
#[target_feature(enable = "avx2")]
pub(super) unsafe fn unpack4_fields(b: __m128i) -> (__m128i, __m128i) {
    let mask = _mm_set1_epi8(0x0F);
    let lo = _mm_and_si128(b, mask);
    let hi = _mm_and_si128(_mm_srli_epi16::<4>(b), mask);
    (_mm_unpacklo_epi8(lo, hi), _mm_unpackhi_epi8(lo, hi))
}

#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn decode_row(words: &[u64], bits: u8, n: usize, out: &mut [i8]) {
    match bits {
        2 => decode2(words, n, out),
        4 => decode4(words, n, out),
        8 => decode8(words, n, out),
        _ => super::scalar::decode_row(words, bits, n, out),
    }
}

#[target_feature(enable = "avx2")]
unsafe fn decode2(words: &[u64], n: usize, out: &mut [i8]) {
    let src = words.as_ptr() as *const u8;
    let dst = out.as_mut_ptr();
    let half = _mm_set1_epi8(1);
    // 16 packed bytes (2 words) → 64 codes per iteration.
    let groups = n / 64;
    for g in 0..groups {
        let b = _mm_loadu_si128(src.add(g * 16) as *const __m128i);
        let (o0, o1, o2, o3) = unpack2_fields(b);
        let o = dst.add(g * 64);
        _mm_storeu_si128(o as *mut __m128i, _mm_sub_epi8(o0, half));
        _mm_storeu_si128(o.add(16) as *mut __m128i, _mm_sub_epi8(o1, half));
        _mm_storeu_si128(o.add(32) as *mut __m128i, _mm_sub_epi8(o2, half));
        _mm_storeu_si128(o.add(48) as *mut __m128i, _mm_sub_epi8(o3, half));
    }
    let done = groups * 64;
    if done < n {
        super::scalar::decode_row(&words[groups * 2..], 2, n - done, &mut out[done..]);
    }
}

#[target_feature(enable = "avx2")]
unsafe fn decode4(words: &[u64], n: usize, out: &mut [i8]) {
    let src = words.as_ptr() as *const u8;
    let dst = out.as_mut_ptr();
    let half = _mm_set1_epi8(4);
    // 16 packed bytes (2 words) → 32 codes per iteration.
    let groups = n / 32;
    for g in 0..groups {
        let b = _mm_loadu_si128(src.add(g * 16) as *const __m128i);
        let (o0, o1) = unpack4_fields(b);
        let o = dst.add(g * 32);
        _mm_storeu_si128(o as *mut __m128i, _mm_sub_epi8(o0, half));
        _mm_storeu_si128(o.add(16) as *mut __m128i, _mm_sub_epi8(o1, half));
    }
    let done = groups * 32;
    if done < n {
        super::scalar::decode_row(&words[groups * 2..], 4, n - done, &mut out[done..]);
    }
}

#[target_feature(enable = "avx2")]
unsafe fn decode8(words: &[u64], n: usize, out: &mut [i8]) {
    let src = words.as_ptr() as *const u8;
    let dst = out.as_mut_ptr() as *mut u8;
    let half = _mm256_set1_epi8(64);
    // 32 packed bytes (4 words) → 32 codes per iteration.
    let groups = n / 32;
    for g in 0..groups {
        let v = _mm256_loadu_si256(src.add(g * 32) as *const __m256i);
        _mm256_storeu_si256(dst.add(g * 32) as *mut __m256i, _mm256_sub_epi8(v, half));
    }
    let done = groups * 32;
    if done < n {
        super::scalar::decode_row(&words[groups * 4..], 8, n - done, &mut out[done..]);
    }
}

/// Number of inner iterations between i32→i64 accumulator flushes. Worst
/// case growth per iteration is 2·2·128·127 < 2^16 per lane (8-bit fields),
/// so 2^12 iterations stay below 2^28 per lane — far from i32 overflow.
pub(super) const FLUSH: usize = 1 << 12;

#[target_feature(enable = "avx2")]
unsafe fn field_dot8(words: &[u64], n: usize, xq: &[i8]) -> i64 {
    let src = words.as_ptr() as *const u8;
    let xp = xq.as_ptr();
    let ones = _mm256_set1_epi16(1);
    let mut total: i64 = 0;
    let mut i = 0usize;
    while i + 32 <= n {
        let mut acc = _mm256_setzero_si256();
        let mut iters = 0usize;
        while i + 32 <= n && iters < FLUSH {
            let f = _mm256_loadu_si256(src.add(i) as *const __m256i);
            let xv = _mm256_loadu_si256(xp.add(i) as *const __m256i);
            // fields ≤ 128, |xq| ≤ 127 ⇒ pairwise i16 sums ≤ 32512: no
            // maddubs saturation.
            let prod = _mm256_maddubs_epi16(f, xv);
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(prod, ones));
            i += 32;
            iters += 1;
        }
        total += hsum_epi32_i64(acc);
    }
    while i < n {
        total += *src.add(i) as i64 * *xp.add(i) as i64;
        i += 1;
    }
    total
}

#[target_feature(enable = "avx2")]
unsafe fn field_dot2(words: &[u64], n: usize, xq: &[i8]) -> i64 {
    let src = words.as_ptr() as *const u8;
    let xp = xq.as_ptr();
    let ones = _mm256_set1_epi16(1);
    let mut total: i64 = 0;
    let groups = n / 64;
    let mut g = 0usize;
    while g < groups {
        let mut acc = _mm256_setzero_si256();
        let stop = groups.min(g + FLUSH);
        while g < stop {
            let b = _mm_loadu_si128(src.add(g * 16) as *const __m128i);
            let (o0, o1, o2, o3) = unpack2_fields(b);
            let f01 = _mm256_set_m128i(o1, o0);
            let f23 = _mm256_set_m128i(o3, o2);
            let x01 = _mm256_loadu_si256(xp.add(g * 64) as *const __m256i);
            let x23 = _mm256_loadu_si256(xp.add(g * 64 + 32) as *const __m256i);
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(_mm256_maddubs_epi16(f01, x01), ones));
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(_mm256_maddubs_epi16(f23, x23), ones));
            g += 1;
        }
        total += hsum_epi32_i64(acc);
    }
    let done = groups * 64;
    if done < n {
        total +=
            super::scalar::packed_field_dot_q8(&words[groups * 2..], 2, n - done, &xq[done..]);
    }
    total
}

#[target_feature(enable = "avx2")]
unsafe fn field_dot4(words: &[u64], n: usize, xq: &[i8]) -> i64 {
    let src = words.as_ptr() as *const u8;
    let xp = xq.as_ptr();
    let ones = _mm256_set1_epi16(1);
    let mut total: i64 = 0;
    let groups = n / 32;
    let mut g = 0usize;
    while g < groups {
        let mut acc = _mm256_setzero_si256();
        let stop = groups.min(g + FLUSH);
        while g < stop {
            let b = _mm_loadu_si128(src.add(g * 16) as *const __m128i);
            let (o0, o1) = unpack4_fields(b);
            let f = _mm256_set_m128i(o1, o0);
            let xv = _mm256_loadu_si256(xp.add(g * 32) as *const __m256i);
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(_mm256_maddubs_epi16(f, xv), ones));
            g += 1;
        }
        total += hsum_epi32_i64(acc);
    }
    let done = groups * 32;
    if done < n {
        total +=
            super::scalar::packed_field_dot_q8(&words[groups * 2..], 4, n - done, &xq[done..]);
    }
    total
}

// ---------------------------------------------------------------------------
// Register-blocked multi-RHS kernels.
//
// The f32 dots pair right-hand sides two at a time: 2 RHS × 4 FMA chains =
// 8 YMM accumulators plus the 4 widened value vectors, which fits the
// 16-register file with room for the streamed x loads. Each RHS keeps
// EXACTLY the single-RHS op sequence (same four chains, same horizontal
// sum, same scalar tail), so out[r] is bit-identical to the single-RHS
// kernel — only the row load/widen is shared. The pure integer field dots
// block up to four RHS per pass; their accumulation is exact in integers,
// so bit-identity is automatic and only the unpack amortization matters.
// ---------------------------------------------------------------------------

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_i8_f32_x2(row: &[i8], x0: &[f32], x1: &[f32]) -> (f32, f32) {
    let n = row.len();
    let rp = row.as_ptr();
    let xp0 = x0.as_ptr();
    let xp1 = x1.as_ptr();
    let mut a0 = _mm256_setzero_ps();
    let mut a1 = _mm256_setzero_ps();
    let mut a2 = _mm256_setzero_ps();
    let mut a3 = _mm256_setzero_ps();
    let mut b0 = _mm256_setzero_ps();
    let mut b1 = _mm256_setzero_ps();
    let mut b2 = _mm256_setzero_ps();
    let mut b3 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 32 <= n {
        let b = _mm256_loadu_si256(rp.add(i) as *const __m256i);
        let lo = _mm256_castsi256_si128(b);
        let hi = _mm256_extracti128_si256::<1>(b);
        let v0 = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(lo));
        let v1 = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(_mm_srli_si128::<8>(lo)));
        let v2 = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(hi));
        let v3 = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(_mm_srli_si128::<8>(hi)));
        a0 = _mm256_fmadd_ps(v0, _mm256_loadu_ps(xp0.add(i)), a0);
        a1 = _mm256_fmadd_ps(v1, _mm256_loadu_ps(xp0.add(i + 8)), a1);
        a2 = _mm256_fmadd_ps(v2, _mm256_loadu_ps(xp0.add(i + 16)), a2);
        a3 = _mm256_fmadd_ps(v3, _mm256_loadu_ps(xp0.add(i + 24)), a3);
        b0 = _mm256_fmadd_ps(v0, _mm256_loadu_ps(xp1.add(i)), b0);
        b1 = _mm256_fmadd_ps(v1, _mm256_loadu_ps(xp1.add(i + 8)), b1);
        b2 = _mm256_fmadd_ps(v2, _mm256_loadu_ps(xp1.add(i + 16)), b2);
        b3 = _mm256_fmadd_ps(v3, _mm256_loadu_ps(xp1.add(i + 24)), b3);
        i += 32;
    }
    let mut s0 = hsum_ps(_mm256_add_ps(_mm256_add_ps(a0, a1), _mm256_add_ps(a2, a3)));
    let mut s1 = hsum_ps(_mm256_add_ps(_mm256_add_ps(b0, b1), _mm256_add_ps(b2, b3)));
    while i < n {
        let c = *rp.add(i) as f32;
        s0 += c * *xp0.add(i);
        s1 += c * *xp1.add(i);
        i += 1;
    }
    (s0, s1)
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_u8_f32_x2(row: &[u8], x0: &[f32], x1: &[f32]) -> (f32, f32) {
    let n = row.len();
    let rp = row.as_ptr();
    let xp0 = x0.as_ptr();
    let xp1 = x1.as_ptr();
    let mut a0 = _mm256_setzero_ps();
    let mut a1 = _mm256_setzero_ps();
    let mut a2 = _mm256_setzero_ps();
    let mut a3 = _mm256_setzero_ps();
    let mut b0 = _mm256_setzero_ps();
    let mut b1 = _mm256_setzero_ps();
    let mut b2 = _mm256_setzero_ps();
    let mut b3 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 32 <= n {
        let b = _mm256_loadu_si256(rp.add(i) as *const __m256i);
        let lo = _mm256_castsi256_si128(b);
        let hi = _mm256_extracti128_si256::<1>(b);
        let v0 = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(lo));
        let v1 = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(_mm_srli_si128::<8>(lo)));
        let v2 = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(hi));
        let v3 = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(_mm_srli_si128::<8>(hi)));
        a0 = _mm256_fmadd_ps(v0, _mm256_loadu_ps(xp0.add(i)), a0);
        a1 = _mm256_fmadd_ps(v1, _mm256_loadu_ps(xp0.add(i + 8)), a1);
        a2 = _mm256_fmadd_ps(v2, _mm256_loadu_ps(xp0.add(i + 16)), a2);
        a3 = _mm256_fmadd_ps(v3, _mm256_loadu_ps(xp0.add(i + 24)), a3);
        b0 = _mm256_fmadd_ps(v0, _mm256_loadu_ps(xp1.add(i)), b0);
        b1 = _mm256_fmadd_ps(v1, _mm256_loadu_ps(xp1.add(i + 8)), b1);
        b2 = _mm256_fmadd_ps(v2, _mm256_loadu_ps(xp1.add(i + 16)), b2);
        b3 = _mm256_fmadd_ps(v3, _mm256_loadu_ps(xp1.add(i + 24)), b3);
        i += 32;
    }
    let mut s0 = hsum_ps(_mm256_add_ps(_mm256_add_ps(a0, a1), _mm256_add_ps(a2, a3)));
    let mut s1 = hsum_ps(_mm256_add_ps(_mm256_add_ps(b0, b1), _mm256_add_ps(b2, b3)));
    while i < n {
        let c = *rp.add(i) as f32;
        s0 += c * *xp0.add(i);
        s1 += c * *xp1.add(i);
        i += 1;
    }
    (s0, s1)
}

#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn dot_i8_f32_multi(row: &[i8], xs: &[&[f32]], out: &mut [f32]) {
    let mut r = 0usize;
    while r + 2 <= xs.len() {
        let (s0, s1) = dot_i8_f32_x2(row, xs[r], xs[r + 1]);
        out[r] = s0;
        out[r + 1] = s1;
        r += 2;
    }
    if r < xs.len() {
        out[r] = dot_i8_f32(row, xs[r]);
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn dot_u8_f32_multi(row: &[u8], xs: &[&[f32]], out: &mut [f32]) {
    let mut r = 0usize;
    while r + 2 <= xs.len() {
        let (s0, s1) = dot_u8_f32_x2(row, xs[r], xs[r + 1]);
        out[r] = s0;
        out[r + 1] = s1;
        r += 2;
    }
    if r < xs.len() {
        out[r] = dot_u8_f32(row, xs[r]);
    }
}

/// Max RHS per integer-dot register block: 4 i32x8 accumulators + the
/// shared unpacked field vectors stay inside the 16-register file.
pub(super) const IDOT_BLOCK: usize = 4;

#[target_feature(enable = "avx2")]
unsafe fn field_dot8_block(words: &[u64], n: usize, xqs: &[&[i8]], out: &mut [i64]) {
    let k = xqs.len();
    debug_assert!(k <= IDOT_BLOCK);
    let src = words.as_ptr() as *const u8;
    let ones = _mm256_set1_epi16(1);
    let mut totals = [0i64; IDOT_BLOCK];
    let mut i = 0usize;
    while i + 32 <= n {
        let mut acc = [_mm256_setzero_si256(); IDOT_BLOCK];
        let mut iters = 0usize;
        while i + 32 <= n && iters < FLUSH {
            let f = _mm256_loadu_si256(src.add(i) as *const __m256i);
            for r in 0..k {
                let xv = _mm256_loadu_si256(xqs[r].as_ptr().add(i) as *const __m256i);
                let prod = _mm256_maddubs_epi16(f, xv);
                acc[r] = _mm256_add_epi32(acc[r], _mm256_madd_epi16(prod, ones));
            }
            i += 32;
            iters += 1;
        }
        for r in 0..k {
            totals[r] += hsum_epi32_i64(acc[r]);
        }
    }
    while i < n {
        let f = *src.add(i) as i64;
        for r in 0..k {
            totals[r] += f * *xqs[r].as_ptr().add(i) as i64;
        }
        i += 1;
    }
    out[..k].copy_from_slice(&totals[..k]);
}

#[target_feature(enable = "avx2")]
unsafe fn field_dot2_block(words: &[u64], n: usize, xqs: &[&[i8]], out: &mut [i64]) {
    let k = xqs.len();
    debug_assert!(k <= IDOT_BLOCK);
    let src = words.as_ptr() as *const u8;
    let ones = _mm256_set1_epi16(1);
    let mut totals = [0i64; IDOT_BLOCK];
    let groups = n / 64;
    let mut g = 0usize;
    while g < groups {
        let mut acc = [_mm256_setzero_si256(); IDOT_BLOCK];
        let stop = groups.min(g + FLUSH);
        while g < stop {
            let b = _mm_loadu_si128(src.add(g * 16) as *const __m128i);
            let (o0, o1, o2, o3) = unpack2_fields(b);
            let f01 = _mm256_set_m128i(o1, o0);
            let f23 = _mm256_set_m128i(o3, o2);
            for r in 0..k {
                let xp = xqs[r].as_ptr();
                let x01 = _mm256_loadu_si256(xp.add(g * 64) as *const __m256i);
                let x23 = _mm256_loadu_si256(xp.add(g * 64 + 32) as *const __m256i);
                acc[r] =
                    _mm256_add_epi32(acc[r], _mm256_madd_epi16(_mm256_maddubs_epi16(f01, x01), ones));
                acc[r] =
                    _mm256_add_epi32(acc[r], _mm256_madd_epi16(_mm256_maddubs_epi16(f23, x23), ones));
            }
            g += 1;
        }
        for r in 0..k {
            totals[r] += hsum_epi32_i64(acc[r]);
        }
    }
    let done = groups * 64;
    if done < n {
        for r in 0..k {
            totals[r] += super::scalar::packed_field_dot_q8(
                &words[groups * 2..],
                2,
                n - done,
                &xqs[r][done..],
            );
        }
    }
    out[..k].copy_from_slice(&totals[..k]);
}

#[target_feature(enable = "avx2")]
unsafe fn field_dot4_block(words: &[u64], n: usize, xqs: &[&[i8]], out: &mut [i64]) {
    let k = xqs.len();
    debug_assert!(k <= IDOT_BLOCK);
    let src = words.as_ptr() as *const u8;
    let ones = _mm256_set1_epi16(1);
    let mut totals = [0i64; IDOT_BLOCK];
    let groups = n / 32;
    let mut g = 0usize;
    while g < groups {
        let mut acc = [_mm256_setzero_si256(); IDOT_BLOCK];
        let stop = groups.min(g + FLUSH);
        while g < stop {
            let b = _mm_loadu_si128(src.add(g * 16) as *const __m128i);
            let (o0, o1) = unpack4_fields(b);
            let f = _mm256_set_m128i(o1, o0);
            for r in 0..k {
                let xv = _mm256_loadu_si256(xqs[r].as_ptr().add(g * 32) as *const __m256i);
                acc[r] =
                    _mm256_add_epi32(acc[r], _mm256_madd_epi16(_mm256_maddubs_epi16(f, xv), ones));
            }
            g += 1;
        }
        for r in 0..k {
            totals[r] += hsum_epi32_i64(acc[r]);
        }
    }
    let done = groups * 32;
    if done < n {
        for r in 0..k {
            totals[r] += super::scalar::packed_field_dot_q8(
                &words[groups * 2..],
                4,
                n - done,
                &xqs[r][done..],
            );
        }
    }
    out[..k].copy_from_slice(&totals[..k]);
}

#[target_feature(enable = "avx2")]
unsafe fn field_dot8_multi(words: &[u64], n: usize, xqs: &[&[i8]], out: &mut [i64]) {
    for (xg, og) in xqs.chunks(IDOT_BLOCK).zip(out.chunks_mut(IDOT_BLOCK)) {
        field_dot8_block(words, n, xg, og);
    }
}

#[target_feature(enable = "avx2")]
unsafe fn field_dot2_multi(words: &[u64], n: usize, xqs: &[&[i8]], out: &mut [i64]) {
    for (xg, og) in xqs.chunks(IDOT_BLOCK).zip(out.chunks_mut(IDOT_BLOCK)) {
        field_dot2_block(words, n, xg, og);
    }
}

#[target_feature(enable = "avx2")]
unsafe fn field_dot4_multi(words: &[u64], n: usize, xqs: &[&[i8]], out: &mut [i64]) {
    for (xg, og) in xqs.chunks(IDOT_BLOCK).zip(out.chunks_mut(IDOT_BLOCK)) {
        field_dot4_block(words, n, xg, og);
    }
}
