//! Runtime-dispatched SIMD kernel backends for the packed low-precision
//! hot path (the paper's §9 AVX2 routines, generalized).
//!
//! NIHT runs hundreds of iterations per recovery and every iteration is two
//! streamed kernels over the packed matrix — `Φ̂ᵀr` (gradient) and `Φ̂x`
//! (residual). This module gives those kernels explicit SIMD backends in the
//! shape of ggblas's `Cpu` abstraction, adapted to packed b-bit operands:
//!
//! The dispatch ladder, weakest to strongest (auto-detection picks the
//! strongest available; each rung is only reachable after its runtime
//! feature check):
//!
//! * [`scalar::Scalar`] — the portable lane-hint loops that previously lived
//!   in `lowprec`. Guaranteed correct everywhere; the reference every other
//!   backend is tested against.
//! * [`neon::Neon`] (aarch64 only) — `vmovl` widening + `vcvtq_f32_s32` +
//!   four `vfmaq_f32` chains for the mixed int·f32 kernels, `vand`/`vshr` +
//!   `vzip` in-register 2/4-bit field unpack, and `vmlal_s16` widening
//!   integer dots for `packed_field_dot_q8` (baseline NEON — no second
//!   feature tier; `vdotq_s32` needs the optional `dotprod` extension).
//! * [`avx2::Avx2`] (x86/x86_64 only) — `_mm256_maddubs_epi16`-class integer
//!   dots, in-register 2/4-bit field unpack, and `_mm256_fmadd_ps` mixed
//!   int→f32 dots, selected at runtime via `is_x86_feature_detected!`.
//! * [`vnni::Vnni`] (x86_64 only) — AVX-512 VNNI tier above AVX2:
//!   `vpdpbusd` fuses the `maddubs`+`madd` pair of every pure integer
//!   field dot into one u8×i8→i32 multiply-accumulate (the f32 kernels
//!   and the decode are shared with AVX2, so iterates are bit-identical
//!   between the two tiers). Requires `avx512vnni` + `avx512vl`.
//!
//! ## Multi-RHS (register-blocked) surface
//!
//! The serving stack batches many right-hand sides against one packed Φ̂;
//! the single-row kernels would re-load (and, at 2/4 bits, re-unpack)
//! every packed word once per RHS. The `*_multi` trait methods amortize
//! that: one pass over the row serves a whole block of right-hand sides
//! ([`Kernels::dot_i8_f32_multi`], [`Kernels::dot_u8_f32_multi`],
//! [`Kernels::packed_field_dot_q8_multi`]). CONTRACT: element `r` of the
//! multi output is **bit-identical** to the same backend's single-RHS
//! kernel on `xs[r]` — backends hoist loads/unpacks across the block but
//! keep each RHS's accumulation structure unchanged, so batched solves
//! stay batch-composition-independent. The trait defaults (= the scalar
//! reference) just loop the single-RHS kernels; AVX2/VNNI override them
//! with register-blocked versions.
//!
//! Dispatch is **per call-site, not per element**: `active()` resolves once
//! (cached) to a `&'static dyn Kernels`, callers hoist it out of their row
//! loops, and the inner loops are statically compiled for each backend.
//! `LPCS_SIMD=scalar|avx2|neon|vnni` forces a backend (benchmarks use this
//! to measure the dispatched-vs-scalar win); an unavailable forced backend
//! falls back to scalar rather than failing.
//!
//! Deliberately **not** dispatched: the dense f32 baseline (`linalg::dot`).
//! The paper's speedup claim is packed-traffic vs f32-traffic under the same
//! compiler regime; keeping the f32 baseline as the portable autovectorized
//! loop keeps that comparison honest and keeps solver trajectories
//! bit-reproducible across machines.

pub mod scalar;

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
pub mod avx2;

#[cfg(target_arch = "aarch64")]
pub mod neon;

#[cfg(target_arch = "x86_64")]
pub mod vnni;

use std::sync::OnceLock;

/// Identifies one kernel backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Scalar,
    Avx2,
    Neon,
    Vnni,
}

/// The kernel set every backend provides — ggblas's `Cpu` trait shape,
/// adapted to packed low-precision operands. All methods are safe wrappers;
/// backends that use feature-gated intrinsics are only reachable after a
/// successful runtime feature check.
pub trait Kernels: Sync {
    fn backend(&self) -> Backend;
    fn name(&self) -> &'static str;

    /// Dot of an int8 code row with an f32 vector.
    fn dot_i8_f32(&self, row: &[i8], x: &[f32]) -> f32;

    /// Dot of a u8 (biased-field) row with an f32 vector.
    fn dot_u8_f32(&self, row: &[u8], x: &[f32]) -> f32;

    /// Decode one packed row (b-bit fields, little-endian in `u64` words)
    /// into signed codes `field − half`. `out[..n]` is written.
    fn decode_row(&self, words: &[u64], bits: u8, n: usize, out: &mut [i8]);

    /// Pure integer dot of the RAW (unsigned, biased) packed fields against
    /// an int8 vector: returns `Σ_j field_j · xq_j`. The caller removes the
    /// bias via `Σ code·xq = Σ field·xq − half·Σ xq` (exact in integers).
    fn packed_field_dot_q8(&self, words: &[u64], bits: u8, n: usize, xq: &[i8]) -> i64;

    /// `y[j] += c · row[j]` — the scale-and-add inner kernel.
    fn scale_add_i8(&self, y: &mut [f32], row: &[i8], c: f32);

    /// Block width of this backend's f32 accumulation in [`Self::scale_add_i8`]
    /// (power of two). Elements inside a block round through the vector/FMA
    /// path, the tail through scalar ops — callers that split work across
    /// threads must align chunk boundaries to this grain so the block grid
    /// (and thus every element's rounding) is independent of the chunking.
    /// Callers should derive their alignment via [`chunk_align`] rather than
    /// combining this with packed-lane widths by hand.
    fn f32_grain(&self) -> usize {
        1
    }

    /// Multi-RHS variant of [`Self::dot_i8_f32`]: one decoded row against a
    /// block of right-hand sides. CONTRACT: `out[r]` is bit-identical to
    /// `self.dot_i8_f32(row, xs[r])` — overriding backends amortize the row
    /// load/widening across the block but keep each RHS's accumulation
    /// structure (chain count, op order, tail) unchanged. The default is the
    /// scalar reference: loop the single-RHS kernel.
    fn dot_i8_f32_multi(&self, row: &[i8], xs: &[&[f32]], out: &mut [f32]) {
        debug_assert_eq!(xs.len(), out.len());
        for (o, x) in out.iter_mut().zip(xs) {
            *o = self.dot_i8_f32(row, x);
        }
    }

    /// Multi-RHS variant of [`Self::dot_u8_f32`]; same bit-identity contract
    /// as [`Self::dot_i8_f32_multi`].
    fn dot_u8_f32_multi(&self, row: &[u8], xs: &[&[f32]], out: &mut [f32]) {
        debug_assert_eq!(xs.len(), out.len());
        for (o, x) in out.iter_mut().zip(xs) {
            *o = self.dot_u8_f32(row, x);
        }
    }

    /// Multi-RHS variant of [`Self::packed_field_dot_q8`]: unpack each packed
    /// word once per batch instead of once per RHS. All-integer accumulation,
    /// so `out[r] == self.packed_field_dot_q8(words, bits, n, xqs[r])` holds
    /// exactly for every backend by construction.
    fn packed_field_dot_q8_multi(
        &self,
        words: &[u64],
        bits: u8,
        n: usize,
        xqs: &[&[i8]],
        out: &mut [i64],
    ) {
        debug_assert_eq!(xqs.len(), out.len());
        for (o, xq) in out.iter_mut().zip(xqs) {
            *o = self.packed_field_dot_q8(words, bits, n, xq);
        }
    }
}

fn gcd(a: usize, b: usize) -> usize {
    let (mut a, mut b) = (a, b);
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

fn lcm(a: usize, b: usize) -> usize {
    a / gcd(a, b) * b
}

/// The one place grain/tail alignment is computed. Parallel chunk
/// boundaries over packed or decoded rows must sit on BOTH the packed-word
/// grid (`lanes` fields per `u64`; pass 1 for unpacked operands) and the
/// backend's f32 accumulation grid ([`Kernels::f32_grain`]), so the
/// vector/tail split — and thus every element's rounding — is identical
/// for every thread count and for the blocked multi-RHS kernels. Callers
/// (`lowprec` splits, blocked kernels) all route through this helper so
/// they cannot disagree on remainder ordering.
pub fn chunk_align(k: &dyn Kernels, lanes: usize) -> usize {
    lcm(lanes.max(1), k.f32_grain().max(1))
}

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
fn avx2_impl() -> Option<&'static dyn Kernels> {
    if avx2::supported() {
        Some(&avx2::Avx2)
    } else {
        None
    }
}

#[cfg(not(any(target_arch = "x86", target_arch = "x86_64")))]
fn avx2_impl() -> Option<&'static dyn Kernels> {
    None
}

#[cfg(target_arch = "aarch64")]
fn neon_impl() -> Option<&'static dyn Kernels> {
    Some(&neon::Neon)
}

#[cfg(not(target_arch = "aarch64"))]
fn neon_impl() -> Option<&'static dyn Kernels> {
    None
}

#[cfg(target_arch = "x86_64")]
fn vnni_impl() -> Option<&'static dyn Kernels> {
    if vnni::supported() {
        Some(&vnni::Vnni)
    } else {
        None
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn vnni_impl() -> Option<&'static dyn Kernels> {
    None
}

fn detect() -> &'static dyn Kernels {
    match std::env::var("LPCS_SIMD").as_deref() {
        Ok("scalar") => return &scalar::Scalar,
        Ok("avx2") => return avx2_impl().unwrap_or(&scalar::Scalar),
        Ok("neon") => return neon_impl().unwrap_or(&scalar::Scalar),
        Ok("vnni") => return vnni_impl().unwrap_or(&scalar::Scalar),
        Ok(other) => {
            // A forced-but-unrecognized backend must not silently
            // auto-detect (it would corrupt scalar-vs-dispatched bench
            // comparisons); degrade to the guaranteed-correct reference.
            eprintln!("LPCS_SIMD={other:?} not recognized (scalar|avx2|neon|vnni): using scalar");
            return &scalar::Scalar;
        }
        Err(_) => {}
    }
    vnni_impl()
        .or_else(avx2_impl)
        .or_else(neon_impl)
        .unwrap_or(&scalar::Scalar)
}

/// The auto-selected backend for this machine (cached after first call).
pub fn active() -> &'static dyn Kernels {
    static ACTIVE: OnceLock<&'static dyn Kernels> = OnceLock::new();
    *ACTIVE.get_or_init(detect)
}

/// Resolve a specific backend; unavailable backends (wrong arch, feature
/// not detected) degrade to the scalar reference so callers never fail.
pub fn by_backend(b: Backend) -> &'static dyn Kernels {
    match b {
        Backend::Scalar => &scalar::Scalar,
        Backend::Avx2 => avx2_impl().unwrap_or(&scalar::Scalar),
        Backend::Neon => neon_impl().unwrap_or(&scalar::Scalar),
        Backend::Vnni => vnni_impl().unwrap_or(&scalar::Scalar),
    }
}

/// Name of the auto-selected backend (diagnostics / bench labels).
pub fn backend_name() -> &'static str {
    active().name()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::packed::PackedMatrix;
    use crate::quant::{QuantizedMatrix, Quantizer};
    use crate::rng::XorShift128Plus;

    fn packed(m: usize, n: usize, bits: u8, seed: u64) -> (QuantizedMatrix, PackedMatrix) {
        let mut rng = XorShift128Plus::new(seed);
        let a = crate::linalg::Mat::from_fn(m, n, |_, _| rng.gaussian_f32());
        let qm = QuantizedMatrix::from_mat(&a, bits, &mut rng);
        let p = PackedMatrix::pack(&qm);
        (qm, p)
    }

    #[test]
    fn active_is_cached_and_named() {
        let a = active();
        let b = active();
        assert_eq!(a.backend(), b.backend());
        assert!(["scalar", "avx2", "neon", "vnni"].contains(&a.name()));
    }

    #[test]
    fn by_backend_never_fails() {
        for b in [Backend::Scalar, Backend::Avx2, Backend::Neon, Backend::Vnni] {
            let k = by_backend(b);
            assert!(!k.name().is_empty());
        }
        assert_eq!(by_backend(Backend::Scalar).backend(), Backend::Scalar);
    }

    #[test]
    fn dot_i8_f32_matches_scalar_all_backends() {
        let mut rng = XorShift128Plus::new(11);
        for n in [0usize, 1, 7, 31, 32, 33, 100, 257] {
            let row: Vec<i8> =
                (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let x = rng.gaussian_vec(n);
            let want = scalar::Scalar.dot_i8_f32(&row, &x);
            for b in [Backend::Avx2, Backend::Neon, Backend::Vnni] {
                let got = by_backend(b).dot_i8_f32(&row, &x);
                let tol = 1e-3 * (1.0 + want.abs());
                assert!((got - want).abs() <= tol, "{b:?} n={n}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn dot_u8_f32_matches_scalar_all_backends() {
        let mut rng = XorShift128Plus::new(12);
        for n in [0usize, 1, 8, 15, 64, 129] {
            let row: Vec<u8> = (0..n).map(|_| rng.below(129) as u8).collect();
            let x = rng.gaussian_vec(n);
            let want = scalar::Scalar.dot_u8_f32(&row, &x);
            for b in [Backend::Avx2, Backend::Neon, Backend::Vnni] {
                let got = by_backend(b).dot_u8_f32(&row, &x);
                let tol = 1e-3 * (1.0 + want.abs());
                assert!((got - want).abs() <= tol, "{b:?} n={n}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn decode_row_bit_identical_across_backends() {
        for bits in [2u8, 4, 8] {
            for n in [1usize, 5, 31, 63, 64, 65, 128, 300] {
                let (qm, p) = packed(2, n, bits, 77 + n as u64);
                let mut want = vec![0i8; n];
                let mut got = vec![0i8; n];
                for row in 0..2 {
                    scalar::Scalar.decode_row(p.row_words(row), bits, n, &mut want);
                    assert_eq!(&want[..], &qm.codes[row * n..(row + 1) * n]);
                    for b in [Backend::Avx2, Backend::Neon, Backend::Vnni] {
                        by_backend(b).decode_row(p.row_words(row), bits, n, &mut got);
                        assert_eq!(got, want, "{b:?} bits={bits} n={n} row={row}");
                    }
                }
            }
        }
    }

    #[test]
    fn packed_field_dot_q8_exact_across_backends() {
        let mut rng = XorShift128Plus::new(13);
        for bits in [2u8, 4, 8] {
            for n in [1usize, 17, 64, 65, 127, 256, 301] {
                let (qm, p) = packed(1, n, bits, 900 + n as u64 + bits as u64);
                let xq: Vec<i8> =
                    (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
                let want = scalar::Scalar.packed_field_dot_q8(p.row_words(0), bits, n, &xq);
                // Cross-check the scalar reference itself against the codes.
                let half = Quantizer::new(bits).half() as i64;
                let naive: i64 = qm.codes[..n]
                    .iter()
                    .zip(&xq)
                    .map(|(&c, &v)| (c as i64 + half) * v as i64)
                    .sum();
                assert_eq!(want, naive, "scalar field dot bits={bits} n={n}");
                for b in [Backend::Avx2, Backend::Neon, Backend::Vnni] {
                    let got = by_backend(b).packed_field_dot_q8(p.row_words(0), bits, n, &xq);
                    assert_eq!(got, want, "{b:?} bits={bits} n={n}");
                }
            }
        }
    }

    #[test]
    fn scale_add_i8_matches_scalar_all_backends() {
        let mut rng = XorShift128Plus::new(14);
        for n in [0usize, 1, 9, 64, 200] {
            let row: Vec<i8> =
                (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let base = rng.gaussian_vec(n);
            let mut want = base.clone();
            scalar::Scalar.scale_add_i8(&mut want, &row, 0.37);
            for b in [Backend::Avx2, Backend::Neon, Backend::Vnni] {
                let mut got = base.clone();
                by_backend(b).scale_add_i8(&mut got, &row, 0.37);
                for (g, w) in got.iter().zip(&want) {
                    assert!((g - w).abs() <= 1e-4 * (1.0 + w.abs()), "{b:?} n={n}");
                }
            }
        }
    }

    #[test]
    fn multi_rhs_dots_bit_identical_to_single() {
        // The core multi-RHS contract: out[r] of every `_multi` kernel must
        // equal the same backend's single-RHS result bit-for-bit, for every
        // block width (including widths past the register-blocked factor,
        // which exercise the odd-remainder path) and ragged n.
        let mut rng = XorShift128Plus::new(31);
        for b in [Backend::Scalar, Backend::Avx2, Backend::Neon, Backend::Vnni] {
            let k = by_backend(b);
            for n in [0usize, 1, 17, 32, 33, 64, 100, 257] {
                let irow: Vec<i8> =
                    (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
                let urow: Vec<u8> = (0..n).map(|_| rng.below(129) as u8).collect();
                let xs_own: Vec<Vec<f32>> = (0..9).map(|_| rng.gaussian_vec(n)).collect();
                for r in [1usize, 2, 3, 4, 5, 8, 9] {
                    let xs: Vec<&[f32]> = xs_own[..r].iter().map(|v| v.as_slice()).collect();
                    let mut got = vec![0.0f32; r];
                    k.dot_i8_f32_multi(&irow, &xs, &mut got);
                    for (j, x) in xs.iter().enumerate() {
                        assert_eq!(got[j], k.dot_i8_f32(&irow, x), "{b:?} i8 n={n} r={r} j={j}");
                    }
                    k.dot_u8_f32_multi(&urow, &xs, &mut got);
                    for (j, x) in xs.iter().enumerate() {
                        assert_eq!(got[j], k.dot_u8_f32(&urow, x), "{b:?} u8 n={n} r={r} j={j}");
                    }
                }
            }
        }
    }

    #[test]
    fn multi_rhs_packed_field_dot_exact() {
        let mut rng = XorShift128Plus::new(32);
        for bits in [2u8, 4, 8] {
            for n in [1usize, 63, 64, 65, 127, 256, 301] {
                let (_, p) = packed(1, n, bits, 1500 + n as u64 + bits as u64);
                let xq_own: Vec<Vec<i8>> = (0..9)
                    .map(|_| (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect())
                    .collect();
                for b in [Backend::Scalar, Backend::Avx2, Backend::Neon, Backend::Vnni] {
                    let k = by_backend(b);
                    for r in [1usize, 3, 5, 9] {
                        let xqs: Vec<&[i8]> =
                            xq_own[..r].iter().map(|v| v.as_slice()).collect();
                        let mut got = vec![0i64; r];
                        k.packed_field_dot_q8_multi(p.row_words(0), bits, n, &xqs, &mut got);
                        for (j, xq) in xqs.iter().enumerate() {
                            let want = scalar::Scalar.packed_field_dot_q8(
                                p.row_words(0),
                                bits,
                                n,
                                xq,
                            );
                            assert_eq!(got[j], want, "{b:?} bits={bits} n={n} r={r} j={j}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn chunk_align_covers_both_grids() {
        for b in [Backend::Scalar, Backend::Avx2, Backend::Neon, Backend::Vnni] {
            let k = by_backend(b);
            for lanes in [1usize, 8, 16, 32] {
                let a = chunk_align(k, lanes);
                assert_eq!(a % lanes, 0, "{b:?} lanes={lanes}");
                assert_eq!(a % k.f32_grain(), 0, "{b:?} lanes={lanes}");
            }
        }
        assert_eq!(chunk_align(&scalar::Scalar, 32), 32);
    }
}
