//! NEON backend stub (aarch64).
//!
//! The dispatch seam, trait plumbing, and parity test matrix already cover
//! this backend; the kernels currently delegate to the scalar reference,
//! which LLVM autovectorizes reasonably well on aarch64. Real NEON kernels
//! still need (see ROADMAP "Open items"):
//! * `vdotq_s32`/`smull`-based integer dots for `packed_field_dot_q8`;
//! * `vtbl`-free 2/4-bit field unpack via `vand`/`vshr` + `vzip`;
//! * `vcvtq_f32_s32` + `vfmaq_f32` chains for the mixed int·f32 dots.

use super::{Backend, Kernels};

/// The NEON backend (currently a correct-by-delegation stub).
pub struct Neon;

impl Kernels for Neon {
    fn backend(&self) -> Backend {
        Backend::Neon
    }

    fn name(&self) -> &'static str {
        "neon"
    }

    fn dot_i8_f32(&self, row: &[i8], x: &[f32]) -> f32 {
        super::scalar::dot_i8_f32(row, x)
    }

    fn dot_u8_f32(&self, row: &[u8], x: &[f32]) -> f32 {
        super::scalar::dot_u8_f32(row, x)
    }

    fn decode_row(&self, words: &[u64], bits: u8, n: usize, out: &mut [i8]) {
        super::scalar::decode_row(words, bits, n, out)
    }

    fn packed_field_dot_q8(&self, words: &[u64], bits: u8, n: usize, xq: &[i8]) -> i64 {
        super::scalar::packed_field_dot_q8(words, bits, n, xq)
    }

    fn scale_add_i8(&self, y: &mut [f32], row: &[i8], c: f32) {
        super::scalar::scale_add_i8(y, row, c)
    }
}
