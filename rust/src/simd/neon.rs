//! NEON backend (aarch64).
//!
//! The mixed int·f32 kernels are real NEON now: `vmovl`-chain widening
//! (i8 → i16 → i32), `vcvtq_f32_s32`/`vcvtq_f32_u32` conversion and four
//! independent `vfmaq_f32` accumulator chains — the aarch64 twin of the
//! AVX2 `VPMOVSXBD` + `VFMADD` path, covering [`Kernels::dot_i8_f32`],
//! [`Kernels::dot_u8_f32`] and [`Kernels::scale_add_i8`]. NEON is a
//! baseline feature of every aarch64 target rustc supports, so there is
//! no runtime feature check to fail.
//!
//! Still delegating to the scalar reference (see ROADMAP "Open items"):
//! * `vdotq_s32`/`smull`-based integer dots for `packed_field_dot_q8`;
//! * `vtbl`-free 2/4-bit field unpack via `vand`/`vshr` + `vzip`.
//!
//! The parity matrix (`tests/simd_parity.rs` + the unit tests in
//! [`super`]) exercises every kernel here against the scalar reference on
//! any aarch64 host.

use super::{Backend, Kernels};
use core::arch::aarch64::*;

/// The NEON backend (unit struct; stateless).
pub struct Neon;

impl Kernels for Neon {
    fn backend(&self) -> Backend {
        Backend::Neon
    }

    fn name(&self) -> &'static str {
        "neon"
    }

    fn dot_i8_f32(&self, row: &[i8], x: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), x.len());
        // SAFETY: NEON is unconditionally available on aarch64.
        unsafe { dot_i8_f32(row, x) }
    }

    fn dot_u8_f32(&self, row: &[u8], x: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), x.len());
        // SAFETY: as above.
        unsafe { dot_u8_f32(row, x) }
    }

    fn decode_row(&self, words: &[u64], bits: u8, n: usize, out: &mut [i8]) {
        super::scalar::decode_row(words, bits, n, out)
    }

    fn packed_field_dot_q8(&self, words: &[u64], bits: u8, n: usize, xq: &[i8]) -> i64 {
        super::scalar::packed_field_dot_q8(words, bits, n, xq)
    }

    fn scale_add_i8(&self, y: &mut [f32], row: &[i8], c: f32) {
        debug_assert_eq!(y.len(), row.len());
        // SAFETY: as above.
        unsafe { scale_add_i8(y, row, c) }
    }

    fn f32_grain(&self) -> usize {
        8 // the inner loops step 8/16 codes; 4-lane FMAs start at multiples of 8
    }
}

/// Widen 16 i8 codes to four f32x4 vectors (sign-extended).
#[inline]
#[target_feature(enable = "neon")]
unsafe fn widen_i8x16(b: int8x16_t) -> (float32x4_t, float32x4_t, float32x4_t, float32x4_t) {
    let lo = vmovl_s8(vget_low_s8(b));
    let hi = vmovl_s8(vget_high_s8(b));
    (
        vcvtq_f32_s32(vmovl_s16(vget_low_s16(lo))),
        vcvtq_f32_s32(vmovl_s16(vget_high_s16(lo))),
        vcvtq_f32_s32(vmovl_s16(vget_low_s16(hi))),
        vcvtq_f32_s32(vmovl_s16(vget_high_s16(hi))),
    )
}

#[target_feature(enable = "neon")]
unsafe fn dot_i8_f32(row: &[i8], x: &[f32]) -> f32 {
    let n = row.len();
    let rp = row.as_ptr();
    let xp = x.as_ptr();
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut acc2 = vdupq_n_f32(0.0);
    let mut acc3 = vdupq_n_f32(0.0);
    let mut i = 0usize;
    while i + 16 <= n {
        let (v0, v1, v2, v3) = widen_i8x16(vld1q_s8(rp.add(i)));
        acc0 = vfmaq_f32(acc0, v0, vld1q_f32(xp.add(i)));
        acc1 = vfmaq_f32(acc1, v1, vld1q_f32(xp.add(i + 4)));
        acc2 = vfmaq_f32(acc2, v2, vld1q_f32(xp.add(i + 8)));
        acc3 = vfmaq_f32(acc3, v3, vld1q_f32(xp.add(i + 12)));
        i += 16;
    }
    let mut s = vaddvq_f32(vaddq_f32(vaddq_f32(acc0, acc1), vaddq_f32(acc2, acc3)));
    while i < n {
        s += *rp.add(i) as f32 * *xp.add(i);
        i += 1;
    }
    s
}

#[target_feature(enable = "neon")]
unsafe fn dot_u8_f32(row: &[u8], x: &[f32]) -> f32 {
    let n = row.len();
    let rp = row.as_ptr();
    let xp = x.as_ptr();
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut acc2 = vdupq_n_f32(0.0);
    let mut acc3 = vdupq_n_f32(0.0);
    let mut i = 0usize;
    while i + 16 <= n {
        let b = vld1q_u8(rp.add(i));
        let lo = vmovl_u8(vget_low_u8(b));
        let hi = vmovl_u8(vget_high_u8(b));
        let v0 = vcvtq_f32_u32(vmovl_u16(vget_low_u16(lo)));
        let v1 = vcvtq_f32_u32(vmovl_u16(vget_high_u16(lo)));
        let v2 = vcvtq_f32_u32(vmovl_u16(vget_low_u16(hi)));
        let v3 = vcvtq_f32_u32(vmovl_u16(vget_high_u16(hi)));
        acc0 = vfmaq_f32(acc0, v0, vld1q_f32(xp.add(i)));
        acc1 = vfmaq_f32(acc1, v1, vld1q_f32(xp.add(i + 4)));
        acc2 = vfmaq_f32(acc2, v2, vld1q_f32(xp.add(i + 8)));
        acc3 = vfmaq_f32(acc3, v3, vld1q_f32(xp.add(i + 12)));
        i += 16;
    }
    let mut s = vaddvq_f32(vaddq_f32(vaddq_f32(acc0, acc1), vaddq_f32(acc2, acc3)));
    while i < n {
        s += *rp.add(i) as f32 * *xp.add(i);
        i += 1;
    }
    s
}

#[target_feature(enable = "neon")]
unsafe fn scale_add_i8(y: &mut [f32], row: &[i8], c: f32) {
    let n = y.len();
    let rp = row.as_ptr();
    let yp = y.as_mut_ptr();
    let vc = vdupq_n_f32(c);
    let mut i = 0usize;
    while i + 8 <= n {
        let w = vmovl_s8(vld1_s8(rp.add(i)));
        let v0 = vcvtq_f32_s32(vmovl_s16(vget_low_s16(w)));
        let v1 = vcvtq_f32_s32(vmovl_s16(vget_high_s16(w)));
        vst1q_f32(yp.add(i), vfmaq_f32(vld1q_f32(yp.add(i)), v0, vc));
        vst1q_f32(yp.add(i + 4), vfmaq_f32(vld1q_f32(yp.add(i + 4)), v1, vc));
        i += 8;
    }
    while i < n {
        *yp.add(i) += c * *rp.add(i) as f32;
        i += 1;
    }
}
