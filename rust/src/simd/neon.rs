//! NEON backend (aarch64).
//!
//! The mixed int·f32 kernels: `vmovl`-chain widening (i8 → i16 → i32),
//! `vcvtq_f32_s32`/`vcvtq_f32_u32` conversion and four independent
//! `vfmaq_f32` accumulator chains — the aarch64 twin of the AVX2
//! `VPMOVSXBD` + `VFMADD` path, covering [`Kernels::dot_i8_f32`],
//! [`Kernels::dot_u8_f32`] and [`Kernels::scale_add_i8`]. NEON is a
//! baseline feature of every aarch64 target rustc supports, so there is
//! no runtime feature check to fail.
//!
//! The packed integer kernels are native too:
//! * 2/4-bit decode — per-byte `vand`/`vshr` into per-position field
//!   vectors, then a `vzip1q`/`vzip2q` interleave tree (the NEON twin of
//!   the AVX2 `PUNPCKLBW` tree) restores element order; `vsubq_s8`
//!   removes the bias;
//! * `packed_field_dot_q8` — unpacked u8 fields widened with `vmovl_u8`
//!   (fields ≤ 128 fit i16), int8 vector widened with `vmovl_s8`, four
//!   `vmlal_s16` i32x4 accumulator chains flushed to i64 via
//!   `vaddlvq_s32` every block — exact for any row length. This is
//!   baseline NEON by design: `vdotq_s32` would need the optional
//!   `dotprod` extension and a second runtime dispatch tier for an
//!   instruction-count win the widening chains mostly capture.
//!
//! The multi-RHS methods use the trait defaults (loop the single-RHS
//! kernel); on aarch64 the decode-once amortization happens one level up
//! in `lowprec::packed_matvec_multi`, which decodes each row once and
//! loops the dot.
//!
//! The parity matrix (`tests/simd_parity.rs` + the unit tests in
//! [`super`]) exercises every kernel here against the scalar reference on
//! any aarch64 host.

use super::{Backend, Kernels};
use core::arch::aarch64::*;

/// The NEON backend (unit struct; stateless).
pub struct Neon;

impl Kernels for Neon {
    fn backend(&self) -> Backend {
        Backend::Neon
    }

    fn name(&self) -> &'static str {
        "neon"
    }

    fn dot_i8_f32(&self, row: &[i8], x: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), x.len());
        // SAFETY: NEON is unconditionally available on aarch64.
        unsafe { dot_i8_f32(row, x) }
    }

    fn dot_u8_f32(&self, row: &[u8], x: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), x.len());
        // SAFETY: as above.
        unsafe { dot_u8_f32(row, x) }
    }

    fn decode_row(&self, words: &[u64], bits: u8, n: usize, out: &mut [i8]) {
        debug_assert!(out.len() >= n);
        // SAFETY: NEON is unconditionally available on aarch64.
        unsafe {
            match bits {
                2 => decode2(words, n, out),
                4 => decode4(words, n, out),
                8 => decode8(words, n, out),
                _ => super::scalar::decode_row(words, bits, n, out),
            }
        }
    }

    fn packed_field_dot_q8(&self, words: &[u64], bits: u8, n: usize, xq: &[i8]) -> i64 {
        debug_assert!(xq.len() >= n);
        // SAFETY: as above.
        unsafe {
            match bits {
                2 => field_dot2(words, n, xq),
                4 => field_dot4(words, n, xq),
                8 => field_dot8(words, n, xq),
                _ => super::scalar::packed_field_dot_q8(words, bits, n, xq),
            }
        }
    }

    fn scale_add_i8(&self, y: &mut [f32], row: &[i8], c: f32) {
        debug_assert_eq!(y.len(), row.len());
        // SAFETY: as above.
        unsafe { scale_add_i8(y, row, c) }
    }

    fn f32_grain(&self) -> usize {
        8 // the inner loops step 8/16 codes; 4-lane FMAs start at multiples of 8
    }
}

/// Widen 16 i8 codes to four f32x4 vectors (sign-extended).
#[inline]
#[target_feature(enable = "neon")]
unsafe fn widen_i8x16(b: int8x16_t) -> (float32x4_t, float32x4_t, float32x4_t, float32x4_t) {
    let lo = vmovl_s8(vget_low_s8(b));
    let hi = vmovl_s8(vget_high_s8(b));
    (
        vcvtq_f32_s32(vmovl_s16(vget_low_s16(lo))),
        vcvtq_f32_s32(vmovl_s16(vget_high_s16(lo))),
        vcvtq_f32_s32(vmovl_s16(vget_low_s16(hi))),
        vcvtq_f32_s32(vmovl_s16(vget_high_s16(hi))),
    )
}

#[target_feature(enable = "neon")]
unsafe fn dot_i8_f32(row: &[i8], x: &[f32]) -> f32 {
    let n = row.len();
    let rp = row.as_ptr();
    let xp = x.as_ptr();
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut acc2 = vdupq_n_f32(0.0);
    let mut acc3 = vdupq_n_f32(0.0);
    let mut i = 0usize;
    while i + 16 <= n {
        let (v0, v1, v2, v3) = widen_i8x16(vld1q_s8(rp.add(i)));
        acc0 = vfmaq_f32(acc0, v0, vld1q_f32(xp.add(i)));
        acc1 = vfmaq_f32(acc1, v1, vld1q_f32(xp.add(i + 4)));
        acc2 = vfmaq_f32(acc2, v2, vld1q_f32(xp.add(i + 8)));
        acc3 = vfmaq_f32(acc3, v3, vld1q_f32(xp.add(i + 12)));
        i += 16;
    }
    let mut s = vaddvq_f32(vaddq_f32(vaddq_f32(acc0, acc1), vaddq_f32(acc2, acc3)));
    while i < n {
        s += *rp.add(i) as f32 * *xp.add(i);
        i += 1;
    }
    s
}

#[target_feature(enable = "neon")]
unsafe fn dot_u8_f32(row: &[u8], x: &[f32]) -> f32 {
    let n = row.len();
    let rp = row.as_ptr();
    let xp = x.as_ptr();
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut acc2 = vdupq_n_f32(0.0);
    let mut acc3 = vdupq_n_f32(0.0);
    let mut i = 0usize;
    while i + 16 <= n {
        let b = vld1q_u8(rp.add(i));
        let lo = vmovl_u8(vget_low_u8(b));
        let hi = vmovl_u8(vget_high_u8(b));
        let v0 = vcvtq_f32_u32(vmovl_u16(vget_low_u16(lo)));
        let v1 = vcvtq_f32_u32(vmovl_u16(vget_high_u16(lo)));
        let v2 = vcvtq_f32_u32(vmovl_u16(vget_low_u16(hi)));
        let v3 = vcvtq_f32_u32(vmovl_u16(vget_high_u16(hi)));
        acc0 = vfmaq_f32(acc0, v0, vld1q_f32(xp.add(i)));
        acc1 = vfmaq_f32(acc1, v1, vld1q_f32(xp.add(i + 4)));
        acc2 = vfmaq_f32(acc2, v2, vld1q_f32(xp.add(i + 8)));
        acc3 = vfmaq_f32(acc3, v3, vld1q_f32(xp.add(i + 12)));
        i += 16;
    }
    let mut s = vaddvq_f32(vaddq_f32(vaddq_f32(acc0, acc1), vaddq_f32(acc2, acc3)));
    while i < n {
        s += *rp.add(i) as f32 * *xp.add(i);
        i += 1;
    }
    s
}

#[target_feature(enable = "neon")]
unsafe fn scale_add_i8(y: &mut [f32], row: &[i8], c: f32) {
    let n = y.len();
    let rp = row.as_ptr();
    let yp = y.as_mut_ptr();
    let vc = vdupq_n_f32(c);
    let mut i = 0usize;
    while i + 8 <= n {
        let w = vmovl_s8(vld1_s8(rp.add(i)));
        let v0 = vcvtq_f32_s32(vmovl_s16(vget_low_s16(w)));
        let v1 = vcvtq_f32_s32(vmovl_s16(vget_high_s16(w)));
        vst1q_f32(yp.add(i), vfmaq_f32(vld1q_f32(yp.add(i)), v0, vc));
        vst1q_f32(yp.add(i + 4), vfmaq_f32(vld1q_f32(yp.add(i + 4)), v1, vc));
        i += 8;
    }
    while i < n {
        *yp.add(i) += c * *rp.add(i) as f32;
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// Packed 2/4/8-bit decode: per-byte shift/mask into per-position field
// vectors, then a vzip interleave tree (the NEON unpacklo/unpackhi twin of
// the AVX2 tree in `avx2::unpack2_fields`) restores element order. Output
// codes are exact, so bit-identity with the scalar reference is automatic;
// ragged tails delegate to the scalar decoder on the remaining words.
// ---------------------------------------------------------------------------

/// 16 packed bytes → 64 raw 2-bit fields in element order (four u8x16).
#[inline]
#[target_feature(enable = "neon")]
unsafe fn unpack2_fields(b: uint8x16_t) -> (uint8x16_t, uint8x16_t, uint8x16_t, uint8x16_t) {
    let mask = vdupq_n_u8(0x03);
    let q0 = vandq_u8(b, mask);
    let q1 = vandq_u8(vshrq_n_u8::<2>(b), mask);
    let q2 = vandq_u8(vshrq_n_u8::<4>(b), mask);
    let q3 = vandq_u8(vshrq_n_u8::<6>(b), mask);
    // out[4k + j] = qj[k]: interleave (q0,q2) and (q1,q3), then each other.
    let t0l = vzip1q_u8(q0, q2);
    let t0h = vzip2q_u8(q0, q2);
    let t1l = vzip1q_u8(q1, q3);
    let t1h = vzip2q_u8(q1, q3);
    (
        vzip1q_u8(t0l, t1l),
        vzip2q_u8(t0l, t1l),
        vzip1q_u8(t0h, t1h),
        vzip2q_u8(t0h, t1h),
    )
}

/// 16 packed bytes → 32 raw 4-bit fields in element order (low nibble first).
#[inline]
#[target_feature(enable = "neon")]
unsafe fn unpack4_fields(b: uint8x16_t) -> (uint8x16_t, uint8x16_t) {
    let lo = vandq_u8(b, vdupq_n_u8(0x0F));
    let hi = vshrq_n_u8::<4>(b); // per-byte shift: zero-filled, no mask needed
    (vzip1q_u8(lo, hi), vzip2q_u8(lo, hi))
}

#[target_feature(enable = "neon")]
unsafe fn decode2(words: &[u64], n: usize, out: &mut [i8]) {
    let src = words.as_ptr() as *const u8;
    let dst = out.as_mut_ptr();
    let half = vdupq_n_s8(1);
    // 16 packed bytes (2 words) → 64 codes per iteration.
    let groups = n / 64;
    for g in 0..groups {
        let b = vld1q_u8(src.add(g * 16));
        let (o0, o1, o2, o3) = unpack2_fields(b);
        let o = dst.add(g * 64);
        vst1q_s8(o, vsubq_s8(vreinterpretq_s8_u8(o0), half));
        vst1q_s8(o.add(16), vsubq_s8(vreinterpretq_s8_u8(o1), half));
        vst1q_s8(o.add(32), vsubq_s8(vreinterpretq_s8_u8(o2), half));
        vst1q_s8(o.add(48), vsubq_s8(vreinterpretq_s8_u8(o3), half));
    }
    let done = groups * 64;
    if done < n {
        super::scalar::decode_row(&words[groups * 2..], 2, n - done, &mut out[done..]);
    }
}

#[target_feature(enable = "neon")]
unsafe fn decode4(words: &[u64], n: usize, out: &mut [i8]) {
    let src = words.as_ptr() as *const u8;
    let dst = out.as_mut_ptr();
    let half = vdupq_n_s8(4);
    // 16 packed bytes (2 words) → 32 codes per iteration.
    let groups = n / 32;
    for g in 0..groups {
        let b = vld1q_u8(src.add(g * 16));
        let (o0, o1) = unpack4_fields(b);
        let o = dst.add(g * 32);
        vst1q_s8(o, vsubq_s8(vreinterpretq_s8_u8(o0), half));
        vst1q_s8(o.add(16), vsubq_s8(vreinterpretq_s8_u8(o1), half));
    }
    let done = groups * 32;
    if done < n {
        super::scalar::decode_row(&words[groups * 2..], 4, n - done, &mut out[done..]);
    }
}

#[target_feature(enable = "neon")]
unsafe fn decode8(words: &[u64], n: usize, out: &mut [i8]) {
    let src = words.as_ptr() as *const u8;
    let dst = out.as_mut_ptr();
    let half = vdupq_n_s8(64);
    // 16 packed bytes (2 words) → 16 codes per iteration; vsubq_s8 wraps,
    // matching the scalar `wrapping_sub` (field 128 → code 64).
    let groups = n / 16;
    for g in 0..groups {
        let b = vld1q_u8(src.add(g * 16));
        vst1q_s8(dst.add(g * 16), vsubq_s8(vreinterpretq_s8_u8(b), half));
    }
    let done = groups * 16;
    if done < n {
        super::scalar::decode_row(&words[groups * 2..], 8, n - done, &mut out[done..]);
    }
}

// ---------------------------------------------------------------------------
// Pure integer field dots: widen the raw u8 fields and the i8 vector to
// i16 halves, accumulate through four vmlal_s16 i32x4 chains, flush to an
// i64 scalar every FLUSH 16-element blocks. Exact in integers.
// ---------------------------------------------------------------------------

/// i32→i64 flush cadence. Each 16-element block adds ≤ 128·127·4 < 2^17
/// per i32 lane across the four chains (≤ 2·16256 < 2^16 per lane per
/// chain), so 2^12 blocks stay far below i32 overflow.
const FLUSH: usize = 1 << 12;

/// Accumulate 16 raw u8 fields against 16 i8 values into four i32x4 chains.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn mlal_fields(acc: &mut [int32x4_t; 4], fields: uint8x16_t, xv: int8x16_t) {
    // fields ≤ 255 fit i16 after zero-extension; reinterpret is exact.
    let flo = vreinterpretq_s16_u16(vmovl_u8(vget_low_u8(fields)));
    let fhi = vreinterpretq_s16_u16(vmovl_u8(vget_high_u8(fields)));
    let xlo = vmovl_s8(vget_low_s8(xv));
    let xhi = vmovl_s8(vget_high_s8(xv));
    acc[0] = vmlal_s16(acc[0], vget_low_s16(flo), vget_low_s16(xlo));
    acc[1] = vmlal_s16(acc[1], vget_high_s16(flo), vget_high_s16(xlo));
    acc[2] = vmlal_s16(acc[2], vget_low_s16(fhi), vget_low_s16(xhi));
    acc[3] = vmlal_s16(acc[3], vget_high_s16(fhi), vget_high_s16(xhi));
}

#[inline]
#[target_feature(enable = "neon")]
unsafe fn flush_acc(acc: &[int32x4_t; 4]) -> i64 {
    vaddlvq_s32(acc[0]) + vaddlvq_s32(acc[1]) + vaddlvq_s32(acc[2]) + vaddlvq_s32(acc[3])
}

#[target_feature(enable = "neon")]
unsafe fn field_dot8(words: &[u64], n: usize, xq: &[i8]) -> i64 {
    let src = words.as_ptr() as *const u8;
    let xp = xq.as_ptr();
    let mut total: i64 = 0;
    let mut i = 0usize;
    while i + 16 <= n {
        let mut acc = [vdupq_n_s32(0); 4];
        let mut iters = 0usize;
        while i + 16 <= n && iters < FLUSH {
            mlal_fields(&mut acc, vld1q_u8(src.add(i)), vld1q_s8(xp.add(i)));
            i += 16;
            iters += 1;
        }
        total += flush_acc(&acc);
    }
    while i < n {
        total += *src.add(i) as i64 * *xp.add(i) as i64;
        i += 1;
    }
    total
}

#[target_feature(enable = "neon")]
unsafe fn field_dot2(words: &[u64], n: usize, xq: &[i8]) -> i64 {
    let src = words.as_ptr() as *const u8;
    let xp = xq.as_ptr();
    let mut total: i64 = 0;
    // 16 packed bytes → 64 fields per group.
    let groups = n / 64;
    let mut g = 0usize;
    while g < groups {
        let mut acc = [vdupq_n_s32(0); 4];
        let stop = groups.min(g + FLUSH / 4);
        while g < stop {
            let (o0, o1, o2, o3) = unpack2_fields(vld1q_u8(src.add(g * 16)));
            let x = xp.add(g * 64);
            mlal_fields(&mut acc, o0, vld1q_s8(x));
            mlal_fields(&mut acc, o1, vld1q_s8(x.add(16)));
            mlal_fields(&mut acc, o2, vld1q_s8(x.add(32)));
            mlal_fields(&mut acc, o3, vld1q_s8(x.add(48)));
            g += 1;
        }
        total += flush_acc(&acc);
    }
    let done = groups * 64;
    if done < n {
        total +=
            super::scalar::packed_field_dot_q8(&words[groups * 2..], 2, n - done, &xq[done..]);
    }
    total
}

#[target_feature(enable = "neon")]
unsafe fn field_dot4(words: &[u64], n: usize, xq: &[i8]) -> i64 {
    let src = words.as_ptr() as *const u8;
    let xp = xq.as_ptr();
    let mut total: i64 = 0;
    // 16 packed bytes → 32 fields per group.
    let groups = n / 32;
    let mut g = 0usize;
    while g < groups {
        let mut acc = [vdupq_n_s32(0); 4];
        let stop = groups.min(g + FLUSH / 2);
        while g < stop {
            let (o0, o1) = unpack4_fields(vld1q_u8(src.add(g * 16)));
            let x = xp.add(g * 32);
            mlal_fields(&mut acc, o0, vld1q_s8(x));
            mlal_fields(&mut acc, o1, vld1q_s8(x.add(16)));
            g += 1;
        }
        total += flush_acc(&acc);
    }
    let done = groups * 32;
    if done < n {
        total +=
            super::scalar::packed_field_dot_q8(&words[groups * 2..], 4, n - done, &xq[done..]);
    }
    total
}
