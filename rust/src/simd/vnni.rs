//! AVX-512 VNNI backend tier (x86_64 only) — one rung above AVX2 in the
//! dispatch ladder.
//!
//! The only thing this tier changes is the pure integer field dot:
//! `vpdpbusd` (u8 × i8 → i32 multiply-accumulate over groups of four)
//! replaces the AVX2 `_mm256_maddubs_epi16` + `_mm256_madd_epi16` pair,
//! halving the instruction count of the packed hot loop and removing the
//! i16 saturation concern entirely (the accumulate widens straight to
//! i32, which is exact for b ≤ 8 fields against int8). We use the 256-bit
//! EVEX form via inline `asm!` rather than the `_mm256_dpbusd_epi32`
//! intrinsic so the backend builds on any stable toolchain; the register
//! operands keep the loop structure identical to the AVX2 field dots.
//! Requires `avx512vnni` + `avx512vl` (the ymm EVEX encoding) at runtime.
//!
//! Everything else — the f32 dots, the 2/4/8-bit decode, scale-and-add —
//! delegates to the AVX2 implementations, so iterates produced under the
//! `vnni` backend are **bit-identical** to the `avx2` backend: the tier
//! only buys integer-dot throughput, it cannot change results.

use super::{avx2, Backend, Kernels};

use core::arch::x86_64::*;

/// Runtime check: the AVX2 base this tier delegates to, plus the VNNI
/// extension and the AVX512VL ymm encodings it needs.
pub(crate) fn supported() -> bool {
    avx2::supported()
        && is_x86_feature_detected!("avx512vnni")
        && is_x86_feature_detected!("avx512vl")
}

/// The VNNI backend (unit struct; stateless).
pub struct Vnni;

impl Kernels for Vnni {
    fn backend(&self) -> Backend {
        Backend::Vnni
    }

    fn name(&self) -> &'static str {
        "vnni"
    }

    fn dot_i8_f32(&self, row: &[i8], x: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), x.len());
        // SAFETY: Vnni is only constructed behind `supported()`, which
        // implies the AVX2+FMA features of the delegated kernel.
        unsafe { avx2::dot_i8_f32(row, x) }
    }

    fn dot_u8_f32(&self, row: &[u8], x: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), x.len());
        // SAFETY: as above.
        unsafe { avx2::dot_u8_f32(row, x) }
    }

    fn decode_row(&self, words: &[u64], bits: u8, n: usize, out: &mut [i8]) {
        debug_assert!(out.len() >= n);
        // SAFETY: as above.
        unsafe { avx2::decode_row(words, bits, n, out) }
    }

    fn packed_field_dot_q8(&self, words: &[u64], bits: u8, n: usize, xq: &[i8]) -> i64 {
        debug_assert!(xq.len() >= n);
        // SAFETY: as above, plus avx512vnni+avx512vl for `vpdpbusd`.
        unsafe {
            match bits {
                2 => field_dot2(words, n, xq),
                4 => field_dot4(words, n, xq),
                8 => field_dot8(words, n, xq),
                _ => super::scalar::packed_field_dot_q8(words, bits, n, xq),
            }
        }
    }

    fn scale_add_i8(&self, y: &mut [f32], row: &[i8], c: f32) {
        debug_assert_eq!(y.len(), row.len());
        // SAFETY: as above.
        unsafe { avx2::scale_add_i8(y, row, c) }
    }

    fn f32_grain(&self) -> usize {
        8 // same FMA grid as the delegated AVX2 f32 kernels
    }

    fn dot_i8_f32_multi(&self, row: &[i8], xs: &[&[f32]], out: &mut [f32]) {
        debug_assert_eq!(xs.len(), out.len());
        // SAFETY: as above.
        unsafe { avx2::dot_i8_f32_multi(row, xs, out) }
    }

    fn dot_u8_f32_multi(&self, row: &[u8], xs: &[&[f32]], out: &mut [f32]) {
        debug_assert_eq!(xs.len(), out.len());
        // SAFETY: as above.
        unsafe { avx2::dot_u8_f32_multi(row, xs, out) }
    }

    fn packed_field_dot_q8_multi(
        &self,
        words: &[u64],
        bits: u8,
        n: usize,
        xqs: &[&[i8]],
        out: &mut [i64],
    ) {
        debug_assert_eq!(xqs.len(), out.len());
        match bits {
            // SAFETY: as above.
            2 => unsafe { field_dot2_multi(words, n, xqs, out) },
            4 => unsafe { field_dot4_multi(words, n, xqs, out) },
            8 => unsafe { field_dot8_multi(words, n, xqs, out) },
            _ => {
                for (o, xq) in out.iter_mut().zip(xqs) {
                    *o = super::scalar::packed_field_dot_q8(words, bits, n, xq);
                }
            }
        }
    }
}

/// `acc += Σ_groups-of-4 (u8 field · i8 x)` per i32 lane — the EVEX ymm
/// form of `vpdpbusd`. Emitted as inline asm so the crate builds on
/// toolchains without the AVX-512 intrinsics stabilized; callers must have
/// verified `avx512vnni` + `avx512vl` at runtime.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn dpbusd(acc: __m256i, f: __m256i, x: __m256i) -> __m256i {
    let mut out = acc;
    core::arch::asm!(
        "vpdpbusd {acc}, {f}, {x}",
        acc = inout(ymm_reg) out,
        f = in(ymm_reg) f,
        x = in(ymm_reg) x,
        options(pure, nomem, nostack)
    );
    out
}

#[target_feature(enable = "avx2")]
unsafe fn field_dot8_block(words: &[u64], n: usize, xqs: &[&[i8]], out: &mut [i64]) {
    let k = xqs.len();
    debug_assert!(k <= avx2::IDOT_BLOCK);
    let src = words.as_ptr() as *const u8;
    let mut totals = [0i64; avx2::IDOT_BLOCK];
    let mut i = 0usize;
    while i + 32 <= n {
        let mut acc = [_mm256_setzero_si256(); avx2::IDOT_BLOCK];
        let mut iters = 0usize;
        // Per iteration each i32 lane grows by ≤ 4·128·127 < 2^17, so
        // FLUSH=2^12 iterations stay below 2^29 — no i32 overflow.
        while i + 32 <= n && iters < avx2::FLUSH {
            let f = _mm256_loadu_si256(src.add(i) as *const __m256i);
            for r in 0..k {
                let xv = _mm256_loadu_si256(xqs[r].as_ptr().add(i) as *const __m256i);
                acc[r] = dpbusd(acc[r], f, xv);
            }
            i += 32;
            iters += 1;
        }
        for r in 0..k {
            totals[r] += avx2::hsum_epi32_i64(acc[r]);
        }
    }
    while i < n {
        let f = *src.add(i) as i64;
        for r in 0..k {
            totals[r] += f * *xqs[r].as_ptr().add(i) as i64;
        }
        i += 1;
    }
    out[..k].copy_from_slice(&totals[..k]);
}

#[target_feature(enable = "avx2")]
unsafe fn field_dot2_block(words: &[u64], n: usize, xqs: &[&[i8]], out: &mut [i64]) {
    let k = xqs.len();
    debug_assert!(k <= avx2::IDOT_BLOCK);
    let src = words.as_ptr() as *const u8;
    let mut totals = [0i64; avx2::IDOT_BLOCK];
    let groups = n / 64;
    let mut g = 0usize;
    while g < groups {
        let mut acc = [_mm256_setzero_si256(); avx2::IDOT_BLOCK];
        let stop = groups.min(g + avx2::FLUSH);
        while g < stop {
            let b = _mm_loadu_si128(src.add(g * 16) as *const __m128i);
            let (o0, o1, o2, o3) = avx2::unpack2_fields(b);
            let f01 = _mm256_set_m128i(o1, o0);
            let f23 = _mm256_set_m128i(o3, o2);
            for r in 0..k {
                let xp = xqs[r].as_ptr();
                let x01 = _mm256_loadu_si256(xp.add(g * 64) as *const __m256i);
                let x23 = _mm256_loadu_si256(xp.add(g * 64 + 32) as *const __m256i);
                acc[r] = dpbusd(acc[r], f01, x01);
                acc[r] = dpbusd(acc[r], f23, x23);
            }
            g += 1;
        }
        for r in 0..k {
            totals[r] += avx2::hsum_epi32_i64(acc[r]);
        }
    }
    let done = groups * 64;
    if done < n {
        for r in 0..k {
            totals[r] += super::scalar::packed_field_dot_q8(
                &words[groups * 2..],
                2,
                n - done,
                &xqs[r][done..],
            );
        }
    }
    out[..k].copy_from_slice(&totals[..k]);
}

#[target_feature(enable = "avx2")]
unsafe fn field_dot4_block(words: &[u64], n: usize, xqs: &[&[i8]], out: &mut [i64]) {
    let k = xqs.len();
    debug_assert!(k <= avx2::IDOT_BLOCK);
    let src = words.as_ptr() as *const u8;
    let mut totals = [0i64; avx2::IDOT_BLOCK];
    let groups = n / 32;
    let mut g = 0usize;
    while g < groups {
        let mut acc = [_mm256_setzero_si256(); avx2::IDOT_BLOCK];
        let stop = groups.min(g + avx2::FLUSH);
        while g < stop {
            let b = _mm_loadu_si128(src.add(g * 16) as *const __m128i);
            let (o0, o1) = avx2::unpack4_fields(b);
            let f = _mm256_set_m128i(o1, o0);
            for r in 0..k {
                let xv = _mm256_loadu_si256(xqs[r].as_ptr().add(g * 32) as *const __m256i);
                acc[r] = dpbusd(acc[r], f, xv);
            }
            g += 1;
        }
        for r in 0..k {
            totals[r] += avx2::hsum_epi32_i64(acc[r]);
        }
    }
    let done = groups * 32;
    if done < n {
        for r in 0..k {
            totals[r] += super::scalar::packed_field_dot_q8(
                &words[groups * 2..],
                4,
                n - done,
                &xqs[r][done..],
            );
        }
    }
    out[..k].copy_from_slice(&totals[..k]);
}

#[target_feature(enable = "avx2")]
unsafe fn field_dot8(words: &[u64], n: usize, xq: &[i8]) -> i64 {
    let mut out = [0i64; 1];
    field_dot8_block(words, n, &[xq], &mut out);
    out[0]
}

#[target_feature(enable = "avx2")]
unsafe fn field_dot2(words: &[u64], n: usize, xq: &[i8]) -> i64 {
    let mut out = [0i64; 1];
    field_dot2_block(words, n, &[xq], &mut out);
    out[0]
}

#[target_feature(enable = "avx2")]
unsafe fn field_dot4(words: &[u64], n: usize, xq: &[i8]) -> i64 {
    let mut out = [0i64; 1];
    field_dot4_block(words, n, &[xq], &mut out);
    out[0]
}

#[target_feature(enable = "avx2")]
unsafe fn field_dot8_multi(words: &[u64], n: usize, xqs: &[&[i8]], out: &mut [i64]) {
    for (xg, og) in xqs.chunks(avx2::IDOT_BLOCK).zip(out.chunks_mut(avx2::IDOT_BLOCK)) {
        field_dot8_block(words, n, xg, og);
    }
}

#[target_feature(enable = "avx2")]
unsafe fn field_dot2_multi(words: &[u64], n: usize, xqs: &[&[i8]], out: &mut [i64]) {
    for (xg, og) in xqs.chunks(avx2::IDOT_BLOCK).zip(out.chunks_mut(avx2::IDOT_BLOCK)) {
        field_dot2_block(words, n, xg, og);
    }
}

#[target_feature(enable = "avx2")]
unsafe fn field_dot4_multi(words: &[u64], n: usize, xqs: &[&[i8]], out: &mut [i64]) {
    for (xg, og) in xqs.chunks(avx2::IDOT_BLOCK).zip(out.chunks_mut(avx2::IDOT_BLOCK)) {
        field_dot4_block(words, n, xg, og);
    }
}
