//! End-to-end service harness: what makes a *networked* recovery
//! service testable at all.
//!
//! [`ServiceHarness`] boots a real [`RecoveryService`] plus a wire
//! server on an ephemeral port (`127.0.0.1:0` — parallel test binaries
//! never collide), hands out connected [`WireClient`]s, and tears the
//! whole stack down deterministically: wire server first (every
//! connection handler joins, bounded by the server's poll tick), then
//! the service (workers join). Teardown *asserts* nothing leaked — if a
//! handler thread were still holding the service, the final unwrap of
//! the service `Arc` would fail loudly instead of leaking a thread past
//! the test.

use crate::algorithms::SolveOptions;
use crate::config::{RouterConfig, ServiceConfig};
use crate::coordinator::RecoveryService;
use crate::router::{self, RouterServer};
use crate::wire::{self, WireClient, WireServer};
use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::Arc;

/// A live service + wire server, torn down on [`ServiceHarness::shutdown`]
/// or drop.
pub struct ServiceHarness {
    service: Option<Arc<RecoveryService>>,
    server: Option<WireServer>,
    addr: SocketAddr,
}

impl ServiceHarness {
    /// Boot with the default subscriber-queue depth (64).
    pub fn start(cfg: ServiceConfig, opts: SolveOptions) -> Self {
        Self::start_with_depth(cfg, opts, 64)
    }

    /// Boot with an explicit per-subscriber progress-queue depth (small
    /// depths make drop-oldest shedding observable in tests).
    pub fn start_with_depth(cfg: ServiceConfig, opts: SolveOptions, sub_depth: usize) -> Self {
        let service =
            Arc::new(RecoveryService::start(cfg, opts, PathBuf::from("artifacts")));
        let server = wire::serve(service.clone(), "127.0.0.1:0", sub_depth)
            .expect("bind wire server on an ephemeral port");
        let addr = server.addr();
        Self { service: Some(service), server: Some(server), addr }
    }

    /// The ephemeral address the wire server actually bound.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// A fresh connected client (open several for concurrent streams).
    pub fn client(&self) -> WireClient {
        WireClient::connect(self.addr).expect("connect to harness wire server")
    }

    /// Direct access to the in-process service (for white-box asserts:
    /// metrics, `wait`, `subscribe`, `cancel`).
    pub fn service(&self) -> &RecoveryService {
        self.service.as_ref().expect("harness is live")
    }

    /// Deterministic teardown; also asserts no connection handler leaked
    /// (each handler holds a service `Arc` — all must be gone once the
    /// server has joined).
    pub fn shutdown(mut self) {
        self.teardown(true);
    }

    fn teardown(&mut self, strict: bool) {
        if let Some(server) = self.server.take() {
            server.shutdown();
        }
        if let Some(service) = self.service.take() {
            match Arc::try_unwrap(service) {
                Ok(service) => service.shutdown(),
                Err(_leaked) => {
                    if strict {
                        panic!(
                            "service Arc still referenced after wire-server shutdown \
                             (a connection handler thread leaked)"
                        );
                    }
                }
            }
        }
    }
}

impl Drop for ServiceHarness {
    fn drop(&mut self) {
        // Non-strict on drop: a panicking test must not double-panic in
        // teardown; explicit `shutdown()` is the asserting path.
        self.teardown(false);
    }
}

/// One backend of a [`RouterHarness`]: its service and its (killable)
/// network face, held separately so a test can crash the wire server
/// while the service — and any in-flight solve — keeps running.
struct Backend {
    service: Option<Arc<RecoveryService>>,
    server: Option<WireServer>,
    addr: SocketAddr,
}

/// A full routed fleet: `n` real backends (each a [`RecoveryService`] +
/// wire server on an ephemeral port) behind a [`RouterServer`]. Probe
/// cadence defaults fast (50 ms / 250 ms timeout) so
/// kill-detect-failover sequences fit a test budget; override via the
/// `tweak` hook of [`RouterHarness::start_with`].
pub struct RouterHarness {
    backends: Vec<Backend>,
    router: Option<RouterServer>,
    addr: SocketAddr,
}

impl RouterHarness {
    /// Boot `n` backends and a router over them.
    pub fn start(n: usize, cfg: ServiceConfig, opts: SolveOptions) -> Self {
        Self::start_with(n, cfg, opts, |_| {})
    }

    /// [`RouterHarness::start`] with a hook that edits the router config
    /// after the harness fills in backend addresses and test cadence.
    pub fn start_with(
        n: usize,
        cfg: ServiceConfig,
        opts: SolveOptions,
        tweak: impl FnOnce(&mut RouterConfig),
    ) -> Self {
        assert!(n >= 1, "a router needs at least one backend");
        let backends: Vec<Backend> = (0..n)
            .map(|_| {
                let service = Arc::new(RecoveryService::start(
                    cfg,
                    opts.clone(),
                    PathBuf::from("artifacts"),
                ));
                let server = wire::serve(service.clone(), "127.0.0.1:0", 64)
                    .expect("bind backend wire server on an ephemeral port");
                let addr = server.addr();
                Backend { service: Some(service), server: Some(server), addr }
            })
            .collect();
        let mut rcfg = RouterConfig::default();
        rcfg.backends = backends.iter().map(|b| b.addr.to_string()).collect();
        rcfg.probe_ms = 50;
        rcfg.probe_timeout_ms = 250;
        tweak(&mut rcfg);
        let router =
            router::serve(rcfg, "127.0.0.1:0").expect("bind router on an ephemeral port");
        let addr = router.addr();
        Self { backends, router: Some(router), addr }
    }

    /// The router's listen address — what clients connect to.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// A fresh client connected *through the router*.
    pub fn client(&self) -> WireClient {
        WireClient::connect(self.addr).expect("connect to harness router")
    }

    /// A client connected directly to backend `i` (bypassing the
    /// router) — the conformance baseline.
    pub fn backend_client(&self, i: usize) -> WireClient {
        WireClient::connect(self.backends[i].addr).expect("connect to harness backend")
    }

    pub fn backend_addr(&self, i: usize) -> SocketAddr {
        self.backends[i].addr
    }

    /// White-box access to backend `i`'s in-process service (metrics,
    /// cancel — e.g. to reap a ghost job after a failover test).
    pub fn backend_service(&self, i: usize) -> &RecoveryService {
        self.backends[i].service.as_ref().expect("backend service is live")
    }

    /// White-box access to the router (metrics, backend up/down state).
    pub fn router(&self) -> &RouterServer {
        self.router.as_ref().expect("harness is live")
    }

    /// Crash backend `i` as the router sees it: shut down its wire
    /// server (connections drop, further connects are refused) while its
    /// service keeps running — so a mid-solve job behaves exactly like
    /// one lost to a machine partition, without blocking teardown.
    pub fn kill_backend_server(&mut self, i: usize) {
        if let Some(server) = self.backends[i].server.take() {
            server.shutdown();
        }
    }

    /// Deterministic teardown: router first (relays join), then each
    /// backend's wire server, then its service; asserts nothing leaked.
    pub fn shutdown(mut self) {
        self.teardown(true);
    }

    fn teardown(&mut self, strict: bool) {
        if let Some(router) = self.router.take() {
            router.shutdown();
        }
        for b in &mut self.backends {
            if let Some(server) = b.server.take() {
                server.shutdown();
            }
            if let Some(service) = b.service.take() {
                match Arc::try_unwrap(service) {
                    Ok(service) => service.shutdown(),
                    Err(_leaked) => {
                        if strict {
                            panic!(
                                "backend service Arc still referenced after shutdown \
                                 (a handler thread leaked)"
                            );
                        }
                    }
                }
            }
        }
    }
}

impl Drop for RouterHarness {
    fn drop(&mut self) {
        self.teardown(false);
    }
}
