//! End-to-end service harness: what makes a *networked* recovery
//! service testable at all.
//!
//! [`ServiceHarness`] boots a real [`RecoveryService`] plus a wire
//! server on an ephemeral port (`127.0.0.1:0` — parallel test binaries
//! never collide), hands out connected [`WireClient`]s, and tears the
//! whole stack down deterministically: wire server first (every
//! connection handler joins, bounded by the server's poll tick), then
//! the service (workers join). Teardown *asserts* nothing leaked — if a
//! handler thread were still holding the service, the final unwrap of
//! the service `Arc` would fail loudly instead of leaking a thread past
//! the test.

use crate::algorithms::SolveOptions;
use crate::config::ServiceConfig;
use crate::coordinator::RecoveryService;
use crate::wire::{self, WireClient, WireServer};
use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::Arc;

/// A live service + wire server, torn down on [`ServiceHarness::shutdown`]
/// or drop.
pub struct ServiceHarness {
    service: Option<Arc<RecoveryService>>,
    server: Option<WireServer>,
    addr: SocketAddr,
}

impl ServiceHarness {
    /// Boot with the default subscriber-queue depth (64).
    pub fn start(cfg: ServiceConfig, opts: SolveOptions) -> Self {
        Self::start_with_depth(cfg, opts, 64)
    }

    /// Boot with an explicit per-subscriber progress-queue depth (small
    /// depths make drop-oldest shedding observable in tests).
    pub fn start_with_depth(cfg: ServiceConfig, opts: SolveOptions, sub_depth: usize) -> Self {
        let service =
            Arc::new(RecoveryService::start(cfg, opts, PathBuf::from("artifacts")));
        let server = wire::serve(service.clone(), "127.0.0.1:0", sub_depth)
            .expect("bind wire server on an ephemeral port");
        let addr = server.addr();
        Self { service: Some(service), server: Some(server), addr }
    }

    /// The ephemeral address the wire server actually bound.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// A fresh connected client (open several for concurrent streams).
    pub fn client(&self) -> WireClient {
        WireClient::connect(self.addr).expect("connect to harness wire server")
    }

    /// Direct access to the in-process service (for white-box asserts:
    /// metrics, `wait`, `subscribe`, `cancel`).
    pub fn service(&self) -> &RecoveryService {
        self.service.as_ref().expect("harness is live")
    }

    /// Deterministic teardown; also asserts no connection handler leaked
    /// (each handler holds a service `Arc` — all must be gone once the
    /// server has joined).
    pub fn shutdown(mut self) {
        self.teardown(true);
    }

    fn teardown(&mut self, strict: bool) {
        if let Some(server) = self.server.take() {
            server.shutdown();
        }
        if let Some(service) = self.service.take() {
            match Arc::try_unwrap(service) {
                Ok(service) => service.shutdown(),
                Err(_leaked) => {
                    if strict {
                        panic!(
                            "service Arc still referenced after wire-server shutdown \
                             (a connection handler thread leaked)"
                        );
                    }
                }
            }
        }
    }
}

impl Drop for ServiceHarness {
    fn drop(&mut self) {
        // Non-strict on drop: a panicking test must not double-panic in
        // teardown; explicit `shutdown()` is the asserting path.
        self.teardown(false);
    }
}
