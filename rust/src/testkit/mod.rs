//! In-tree property-testing driver (no proptest offline; DESIGN.md §6)
//! plus the end-to-end service harness ([`harness::ServiceHarness`]).
//!
//! `forall` runs a property over `cases` pseudo-random inputs derived from a
//! base seed; on failure it reports the exact case seed so the case can be
//! replayed deterministically (`LPCS_PROP_SEED=<seed>` re-runs just that
//! case). The property-test suites in `rust/tests/` are built on this.

pub mod harness;

pub use harness::{RouterHarness, ServiceHarness};

use crate::rng::XorShift128Plus;

/// Run `prop(rng, case_index)` for `cases` independently seeded cases.
/// Panics with the failing case seed on the first failure.
pub fn forall(name: &str, base_seed: u64, cases: usize, prop: impl Fn(&mut XorShift128Plus, usize)) {
    // Replay mode: run only the requested case seed.
    if let Ok(v) = std::env::var("LPCS_PROP_SEED") {
        if let Ok(seed) = v.parse::<u64>() {
            let mut rng = XorShift128Plus::new(seed);
            prop(&mut rng, 0);
            return;
        }
    }
    for case in 0..cases {
        let case_seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = XorShift128Plus::new(case_seed);
            prop(&mut rng, case);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (replay with \
                 LPCS_PROP_SEED={case_seed}): {msg}"
            );
        }
    }
}

/// Random vector helpers for property bodies.
pub fn vec_f32(rng: &mut XorShift128Plus, max_len: usize) -> Vec<f32> {
    let n = 1 + rng.below(max_len);
    rng.gaussian_vec(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall("abs-nonneg", 1, 50, |rng, _| {
            let v = rng.gaussian_f32();
            assert!(v.abs() >= 0.0);
        });
    }

    #[test]
    fn reports_failing_seed() {
        let r = std::panic::catch_unwind(|| {
            forall("always-fails", 2, 3, |_, _| panic!("boom"));
        });
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("LPCS_PROP_SEED="), "{msg}");
    }

    #[test]
    fn vec_f32_length_bounds() {
        let mut rng = XorShift128Plus::new(3);
        for _ in 0..100 {
            let v = vec_f32(&mut rng, 17);
            assert!(!v.is_empty() && v.len() <= 17);
        }
    }
}
