//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the rust runtime: one entry per compiled HLO module with its shape
//! and I/O signature.

use crate::io::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Tensor descriptor in an artifact signature.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// Full name, e.g. `qniht_step_gauss_256x512`.
    pub name: String,
    /// Entry kind, e.g. `qniht_step`, `apply_step`, `niht_step_f32`.
    pub entry: String,
    /// Shape tag, e.g. `gauss_256x512`.
    pub shape_tag: String,
    pub file: PathBuf,
    pub m: usize,
    pub n: usize,
    pub s: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

fn parse_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("signature must be an array"))?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("tensor missing name"))?
                    .to_string(),
                dtype: t
                    .get("dtype")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("tensor missing dtype"))?
                    .to_string(),
                shape: t
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("tensor missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<Vec<_>>>()?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let entries = j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing entries"))?
            .iter()
            .map(|e| -> Result<ArtifactEntry> {
                let field = |k: &str| -> Result<&Json> {
                    e.get(k).ok_or_else(|| anyhow!("entry missing '{k}'"))
                };
                Ok(ArtifactEntry {
                    name: field("name")?.as_str().unwrap_or_default().to_string(),
                    entry: field("entry")?.as_str().unwrap_or_default().to_string(),
                    shape_tag: field("shape_tag")?.as_str().unwrap_or_default().to_string(),
                    file: dir.join(field("file")?.as_str().unwrap_or_default()),
                    m: field("m")?.as_usize().ok_or_else(|| anyhow!("bad m"))?,
                    n: field("n")?.as_usize().ok_or_else(|| anyhow!("bad n"))?,
                    s: field("s")?.as_usize().ok_or_else(|| anyhow!("bad s"))?,
                    inputs: parse_specs(field("inputs")?)?,
                    outputs: parse_specs(field("outputs")?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { dir: dir.to_path_buf(), entries })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Find by (entry kind, shape tag), e.g. ("qniht_step", "tiny_64x128").
    pub fn find_kind(&self, entry: &str, shape_tag: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.entry == entry && e.shape_tag == shape_tag)
    }

    /// All shape tags present.
    pub fn shape_tags(&self) -> Vec<String> {
        let mut tags: Vec<String> = self.entries.iter().map(|e| e.shape_tag.clone()).collect();
        tags.sort();
        tags.dedup();
        tags
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        let text = r#"{
          "format": "hlo-text",
          "entries": [
            {"name": "qniht_step_tiny", "entry": "qniht_step", "shape_tag": "tiny",
             "file": "qniht_step_tiny.hlo.txt", "m": 64, "n": 128, "s": 8,
             "inputs": [{"name": "x", "dtype": "float32", "shape": [128]}],
             "outputs": [{"name": "x_next", "dtype": "float32", "shape": [128]}]}
          ]
        }"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn loads_and_finds() {
        let dir = std::env::temp_dir().join("lpcs_manifest_test");
        write_fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.find("qniht_step_tiny").unwrap();
        assert_eq!((e.m, e.n, e.s), (64, 128, 8));
        assert_eq!(e.inputs[0].elements(), 128);
        assert!(m.find_kind("qniht_step", "tiny").is_some());
        assert!(m.find_kind("qniht_step", "absent").is_none());
        assert_eq!(m.shape_tags(), vec!["tiny".to_string()]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_helpful_error() {
        let err = Manifest::load(Path::new("/nonexistent_lpcs")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // Soft check against the actual artifacts dir when built.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.find_kind("qniht_step", "tiny_64x128").is_some());
            assert!(m.find_kind("niht_step_f32", "gauss_256x512").is_some());
        }
    }
}
