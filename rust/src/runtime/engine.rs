//! PJRT execution engine: compiled-artifact cache + NihtKernel adapters.

use super::manifest::Manifest;
use crate::algorithms::{NihtKernel, StepOut};
use crate::linalg::Mat;
use crate::quant::{QuantizedMatrix, Quantizer};
use crate::rng::XorShift128Plus;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// PJRT CPU client + compiled-executable cache over the artifact manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Lazily materialize a per-thread runtime. PJRT handles are not
    /// `Send`, so owners (engine-registry XLA engines, one per worker
    /// thread) hold `Option<Runtime>` and initialize on first use; the
    /// compiled-executable cache then lives for the thread's lifetime.
    pub fn ensure<'a>(slot: &'a mut Option<Runtime>, artifact_dir: &Path) -> Result<&'a mut Runtime> {
        if slot.is_none() {
            *slot = Some(Runtime::new(artifact_dir)?);
        }
        Ok(slot.as_mut().expect("just initialized"))
    }

    /// Compile (or fetch from cache) the named artifact.
    pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let entry = self
                .manifest
                .find(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
                .clone();
            let proto = xla::HloModuleProto::from_text_file(
                entry.file.to_str().context("artifact path not utf-8")?,
            )
            .map_err(|e| anyhow!("parsing HLO text {:?}: {e:?}", entry.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling '{name}': {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute an artifact; unwraps the 1-tuple-of-tuple convention
    /// (return_tuple=True on the jax side) into a flat Vec<Literal>.
    pub fn execute(&mut self, name: &str, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.load(name)?;
        let result = exe
            .execute::<&xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing '{name}': {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of '{name}': {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untupling result of '{name}': {e:?}"))
    }
}

/// f32 literal with the given dims.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    assert_eq!(data.len(), n);
    if dims.len() == 1 {
        return Ok(xla::Literal::vec1(data));
    }
    let dims64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims64)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// int8 literal with the given dims.
pub fn lit_i8(data: &[i8], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    assert_eq!(data.len(), n);
    let bytes: &[u8] = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, n) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S8, dims, bytes)
        .map_err(|e| anyhow!("i8 literal: {e:?}"))
}

pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal to f32 vec: {e:?}"))
}

fn scalar1(lits: &xla::Literal) -> Result<f32> {
    Ok(to_vec_f32(lits)?[0])
}

/// [`NihtKernel`] over the `qniht_step_*` / `apply_step_*` artifacts —
/// the quantized solve running entirely through PJRT.
///
/// Generic over how the [`Runtime`] is held: `XlaQuantKernel<Runtime>` owns
/// one (simple, recompiles per instance), `XlaQuantKernel<&mut Runtime>`
/// borrows a per-thread runtime so the compiled-executable cache is shared
/// across jobs (what the coordinator workers do — PJRT handles are not
/// `Send`, so each worker thread owns its runtime).
pub struct XlaQuantKernel<R: std::borrow::BorrowMut<Runtime> = Runtime> {
    rt: R,
    step_name: String,
    apply_name: String,
    m: usize,
    n: usize,
    s: usize,
    codes1_t: xla::Literal,
    codes2: xla::Literal,
    sc1: xla::Literal,
    sc2: xla::Literal,
    y: xla::Literal,
}

impl XlaQuantKernel<Runtime> {
    /// Quantize (Φ, y) at (bits_phi, bits_y) and bind to the artifacts for
    /// `shape_tag`. The problem shape must match the artifact shape.
    pub fn new(
        artifact_dir: &Path,
        shape_tag: &str,
        phi: &Mat,
        y: &[f32],
        bits_phi: u8,
        bits_y: u8,
        seed: u64,
    ) -> Result<Self> {
        let rt = Runtime::new(artifact_dir)?;
        Self::with_runtime(rt, shape_tag, phi, y, bits_phi, bits_y, seed)
    }
}

impl<R: std::borrow::BorrowMut<Runtime>> XlaQuantKernel<R> {
    /// Bind to an existing runtime (shared executable cache).
    pub fn with_runtime(
        mut rt: R,
        shape_tag: &str,
        phi: &Mat,
        y: &[f32],
        bits_phi: u8,
        bits_y: u8,
        seed: u64,
    ) -> Result<Self> {
        let rt_ref = rt.borrow_mut();
        let step = rt_ref
            .manifest()
            .find_kind("qniht_step", shape_tag)
            .ok_or_else(|| anyhow!("no qniht_step artifact for '{shape_tag}'"))?
            .clone();
        let apply = rt_ref
            .manifest()
            .find_kind("apply_step", shape_tag)
            .ok_or_else(|| anyhow!("no apply_step artifact for '{shape_tag}'"))?
            .clone();
        anyhow::ensure!(
            phi.rows == step.m && phi.cols == step.n,
            "problem {}×{} does not match artifact {}×{}",
            phi.rows,
            phi.cols,
            step.m,
            step.n
        );
        let mut rng = XorShift128Plus::new(seed);
        let q2 = QuantizedMatrix::from_mat(phi, bits_phi, &mut rng);
        // One stored quantization (Φ̂₁ = Φ̂₂): see qniht::QuantKernel — a
        // fixed mismatched pair yields a biased cross-gradient.
        let q1t = q2.transposed();
        let qy = Quantizer::new(bits_y);
        let (yc, ysc) = qy.quantize_auto(y, &mut rng);
        let y_hat = qy.dequantize_slice(&yc, ysc);

        Ok(Self {
            m: step.m,
            n: step.n,
            s: step.s,
            codes1_t: lit_i8(&q1t.codes, &[step.n, step.m])?,
            codes2: lit_i8(&q2.codes, &[step.m, step.n])?,
            sc1: lit_f32(&[q1t.multiplier()], &[1])?,
            sc2: lit_f32(&[q2.multiplier()], &[1])?,
            y: lit_f32(&y_hat, &[step.m])?,
            step_name: step.name,
            apply_name: apply.name,
            rt,
        })
    }

    /// The artifact's baked sparsity (top-k is shape-specialized).
    pub fn artifact_s(&self) -> usize {
        self.s
    }

    fn run_step(&mut self, x: &[f32]) -> Result<StepOut> {
        let xl = lit_f32(x, &[self.n])?;
        let outs = self.rt.borrow_mut().execute(
            &self.step_name.clone(),
            &[&self.codes1_t, &self.codes2, &self.sc1, &self.sc2, &self.y, &xl],
        )?;
        anyhow::ensure!(outs.len() == 6, "qniht_step must return 6 outputs");
        Ok(StepOut {
            x_next: to_vec_f32(&outs[0])?,
            g: to_vec_f32(&outs[1])?,
            mu: scalar1(&outs[2])?,
            dx_nsq: scalar1(&outs[3])?,
            phi1_dx_nsq: scalar1(&outs[4])?,
            resid_nsq: scalar1(&outs[5])?,
        })
    }

    fn run_apply(&mut self, x: &[f32], g: &[f32], mu: f32) -> Result<(Vec<f32>, f32, f32)> {
        let xl = lit_f32(x, &[self.n])?;
        let gl = lit_f32(g, &[self.n])?;
        let mul = lit_f32(&[mu], &[1])?;
        let outs = self.rt.borrow_mut().execute(
            &self.apply_name.clone(),
            &[&self.codes1_t, &self.sc1, &xl, &gl, &mul],
        )?;
        anyhow::ensure!(outs.len() == 3, "apply_step must return 3 outputs");
        Ok((to_vec_f32(&outs[0])?, scalar1(&outs[1])?, scalar1(&outs[2])?))
    }
}

impl<R: std::borrow::BorrowMut<Runtime>> NihtKernel for XlaQuantKernel<R> {
    fn m(&self) -> usize {
        self.m
    }

    fn n(&self) -> usize {
        self.n
    }

    fn full_step(&mut self, x: &[f32], s: usize) -> StepOut {
        assert_eq!(s, self.s, "artifact is specialized to s={}", self.s);
        self.run_step(x).expect("PJRT qniht_step failed")
    }

    fn apply_step(&mut self, x: &[f32], g: &[f32], mu: f32, s: usize) -> (Vec<f32>, f32, f32) {
        assert_eq!(s, self.s, "artifact is specialized to s={}", self.s);
        self.run_apply(x, g, mu).expect("PJRT apply_step failed")
    }
}

/// [`NihtKernel`] over the dense `niht_step_f32_*` artifacts (the 32-bit
/// baseline executing through PJRT).
pub struct XlaDenseKernel<R: std::borrow::BorrowMut<Runtime> = Runtime> {
    rt: R,
    step_name: String,
    apply_name: String,
    m: usize,
    n: usize,
    s: usize,
    phi: xla::Literal,
    y: xla::Literal,
}

impl XlaDenseKernel<Runtime> {
    pub fn new(artifact_dir: &Path, shape_tag: &str, phi: &Mat, y: &[f32]) -> Result<Self> {
        let rt = Runtime::new(artifact_dir)?;
        Self::with_runtime(rt, shape_tag, phi, y)
    }
}

impl<R: std::borrow::BorrowMut<Runtime>> XlaDenseKernel<R> {
    pub fn with_runtime(mut rt: R, shape_tag: &str, phi: &Mat, y: &[f32]) -> Result<Self> {
        let rt_ref = rt.borrow_mut();
        let step = rt_ref
            .manifest()
            .find_kind("niht_step_f32", shape_tag)
            .ok_or_else(|| anyhow!("no niht_step_f32 artifact for '{shape_tag}'"))?
            .clone();
        let apply = rt_ref
            .manifest()
            .find_kind("apply_step_f32", shape_tag)
            .ok_or_else(|| anyhow!("no apply_step_f32 artifact for '{shape_tag}'"))?
            .clone();
        anyhow::ensure!(phi.rows == step.m && phi.cols == step.n, "shape mismatch");
        Ok(Self {
            m: step.m,
            n: step.n,
            s: step.s,
            phi: lit_f32(&phi.data, &[step.m, step.n])?,
            y: lit_f32(y, &[step.m])?,
            step_name: step.name,
            apply_name: apply.name,
            rt,
        })
    }

    pub fn artifact_s(&self) -> usize {
        self.s
    }
}

impl<R: std::borrow::BorrowMut<Runtime>> NihtKernel for XlaDenseKernel<R> {
    fn m(&self) -> usize {
        self.m
    }

    fn n(&self) -> usize {
        self.n
    }

    fn full_step(&mut self, x: &[f32], s: usize) -> StepOut {
        assert_eq!(s, self.s, "artifact is specialized to s={}", self.s);
        let xl = lit_f32(x, &[self.n]).expect("literal");
        let outs = self
            .rt
            .borrow_mut()
            .execute(&self.step_name.clone(), &[&self.phi, &self.y, &xl])
            .expect("PJRT niht_step_f32 failed");
        StepOut {
            x_next: to_vec_f32(&outs[0]).unwrap(),
            g: to_vec_f32(&outs[1]).unwrap(),
            mu: scalar1(&outs[2]).unwrap(),
            dx_nsq: scalar1(&outs[3]).unwrap(),
            phi1_dx_nsq: scalar1(&outs[4]).unwrap(),
            resid_nsq: scalar1(&outs[5]).unwrap(),
        }
    }

    fn apply_step(&mut self, x: &[f32], g: &[f32], mu: f32, s: usize) -> (Vec<f32>, f32, f32) {
        assert_eq!(s, self.s);
        let xl = lit_f32(x, &[self.n]).expect("literal");
        let gl = lit_f32(g, &[self.n]).expect("literal");
        let mul = lit_f32(&[mu], &[1]).expect("literal");
        let outs = self
            .rt
            .borrow_mut()
            .execute(&self.apply_name.clone(), &[&self.phi, &xl, &gl, &mul])
            .expect("PJRT apply_step_f32 failed");
        (
            to_vec_f32(&outs[0]).unwrap(),
            scalar1(&outs[1]).unwrap(),
            scalar1(&outs[2]).unwrap(),
        )
    }
}
