//! PJRT runtime (S12): loads the JAX/Pallas AOT artifacts and executes them
//! from the rust hot path. Python never runs at request time.
//!
//! Flow (see /opt/xla-example/load_hlo/): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file(artifacts/<name>.hlo.txt)` →
//! `client.compile` → `execute`. HLO **text** is the interchange format —
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids.

pub mod engine;
pub mod manifest;

pub use engine::{Runtime, XlaDenseKernel, XlaQuantKernel};
pub use manifest::{ArtifactEntry, Manifest};
