//! Shepp–Logan-style head phantom — the ground-truth image of the MRI
//! workload (the paper evaluates MRI recovery on brain images; the
//! standard synthetic stand-in is the Shepp–Logan phantom, fully
//! determined by ten ellipses, so every experiment is reproducible from
//! the grid size alone).
//!
//! The intensities are the "modified" (Toft) contrast variant — the
//! classical values differ by ~1e-2 between tissues, which vanishes under
//! 8-bit quantization and PGM dumps.

use crate::algorithms::support::hard_threshold;

/// One ellipse: (additive intensity, semi-axis a, semi-axis b, centre x₀,
/// centre y₀, rotation φ in degrees). Coordinates live in `[-1, 1]²`.
const ELLIPSES: [(f32, f32, f32, f32, f32, f32); 10] = [
    (1.0, 0.69, 0.92, 0.0, 0.0, 0.0),
    (-0.8, 0.6624, 0.874, 0.0, -0.0184, 0.0),
    (-0.2, 0.11, 0.31, 0.22, 0.0, -18.0),
    (-0.2, 0.16, 0.41, -0.22, 0.0, 18.0),
    (0.1, 0.21, 0.25, 0.0, 0.35, 0.0),
    (0.1, 0.046, 0.046, 0.0, 0.1, 0.0),
    (0.1, 0.046, 0.046, 0.0, -0.1, 0.0),
    (0.1, 0.046, 0.023, -0.08, -0.605, 0.0),
    (0.1, 0.023, 0.023, 0.0, -0.606, 0.0),
    (0.1, 0.023, 0.046, 0.06, -0.605, 0.0),
];

/// Rasterize the phantom onto an `r × r` row-major grid (row 0 is the top
/// of the head). Values are sums of ellipse intensities, in `[0, 1]`-ish
/// range (the skull ring is 1.0, tissue ~0.1–0.4, background 0).
pub fn shepp_logan(r: usize) -> Vec<f32> {
    assert!(r >= 2, "phantom needs at least a 2x2 grid");
    let mut img = vec![0.0f32; r * r];
    for i in 0..r {
        // Pixel centres; image row 0 maps to y = +1 (top).
        let y = -(2.0 * (i as f32 + 0.5) / r as f32 - 1.0);
        for j in 0..r {
            let x = 2.0 * (j as f32 + 0.5) / r as f32 - 1.0;
            let mut v = 0.0f32;
            for &(a, ax, ay, x0, y0, phi_deg) in ELLIPSES.iter() {
                let th = phi_deg.to_radians();
                let (st, ct) = th.sin_cos();
                let xr = (x - x0) * ct + (y - y0) * st;
                let yr = -(x - x0) * st + (y - y0) * ct;
                if (xr / ax) * (xr / ax) + (yr / ay) * (yr / ay) <= 1.0 {
                    v += a;
                }
            }
            img[i * r + j] = v;
        }
    }
    img
}

/// The `s`-sparse recovery target: keep the `s` largest-magnitude pixels
/// (IHT recovers s-sparse signals; the phantom's bright structure — skull
/// ring and interior features — survives, the flat tissue floor does
/// not). This is [`hard_threshold`], i.e. exactly the H_s the solvers
/// apply.
pub fn sparse_phantom(r: usize, s: usize) -> Vec<f32> {
    hard_threshold(&shepp_logan(r), s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::support::support_of;

    #[test]
    fn phantom_shape_and_range() {
        let img = shepp_logan(32);
        assert_eq!(img.len(), 32 * 32);
        let max = img.iter().cloned().fold(f32::MIN, f32::max);
        let min = img.iter().cloned().fold(f32::MAX, f32::min);
        assert!(max <= 1.0 + 1e-6 && max > 0.5, "skull ring present: max={max}");
        assert!(min >= -1e-6, "intensities are non-negative: min={min}");
        // Corners are background.
        assert_eq!(img[0], 0.0);
        assert_eq!(img[32 * 32 - 1], 0.0);
        // Centre is inside the head (brain tissue, not background).
        assert!(img[16 * 32 + 16] > 0.0);
    }

    #[test]
    fn phantom_is_deterministic() {
        assert_eq!(shepp_logan(16), shepp_logan(16));
    }

    #[test]
    fn sparse_phantom_is_s_sparse_and_keeps_the_bright_ring() {
        let r = 32;
        let s = 80;
        let sp = sparse_phantom(r, s);
        let supp = support_of(&sp);
        assert!(supp.len() <= s);
        assert!(!supp.is_empty());
        // Every kept pixel matches the full phantom.
        let full = shepp_logan(r);
        for &i in &supp {
            assert_eq!(sp[i], full[i]);
        }
        // The kept set is the brightest: min kept >= max dropped.
        let min_kept = supp.iter().map(|&i| sp[i].abs()).fold(f32::MAX, f32::min);
        let max_dropped = full
            .iter()
            .enumerate()
            .filter(|&(i, _)| !supp.contains(&i))
            .map(|(_, v)| v.abs())
            .fold(0.0f32, f32::max);
        assert!(min_kept >= max_dropped - 1e-6);
    }
}
