//! MRI workload — recovery of brain images from undersampled Fourier
//! measurements (the paper's second application, §10).
//!
//! This is the crate's first **structured-operator** workload: the
//! measurement matrix is never materialized. An MRI scanner acquires a
//! subset of the image's 2-D Fourier coefficients (k-space); recovery
//! solves `y ≈ S F_u x` for an s-sparse image `x`, with `S` the
//! undersampling mask and `F_u` the unitary 2-D DFT. The pieces:
//!
//! * [`phantom`] — the Shepp–Logan ground-truth image and its s-sparse
//!   recovery target ([`phantom::sparse_phantom`]).
//! * [`mask`] — Cartesian variable-density and radial undersampling
//!   patterns ([`SamplingMask`]), parameter-gated by
//!   [`MaskConfig::validate`] at config parse *and* job submission.
//! * [`op`] — [`PartialFourierOp`], the matrix-free
//!   [`crate::solver::MeasurementOp`] (FFT forward, exact-adjoint
//!   inverse FFT backward), its dense materialization
//!   ([`PartialFourierOp::to_mat`]) for parity and baselines, and the
//!   low-precision sampling path ([`LowPrecFourierOp`] +
//!   [`lowprec_problem`]): observation and per-iteration k-space traffic
//!   stochastically quantized to b ∈ {2, 4, 8} bits with per-readout
//!   block scales. The [`op`] module docs spell out exactly what is
//!   quantized when Φ is implicit.
//!
//! Matrix-free problems run under `SolverKind::Niht` on the dense-f32
//! native engine via the facade's generic `OpKernel` driver — and they
//! are servable: `coordinator::OperatorSpec::PartialFourier` carries the
//! shared operator (and optional bit width) through `JobSpec`,
//! `BatchKey` and submit-time validation, pinned bit-for-bit against the
//! facade by `tests/mri_serving.rs`.

pub mod mask;
pub mod op;
pub mod phantom;

pub use mask::{MaskConfig, MaskKind, SamplingMask};
pub use op::{lowprec_problem, quantize_blocked, LowPrecFourierOp, PartialFourierOp, QUANT_BLOCK};

use crate::solver::MeasurementOp;
use anyhow::Result;
use std::sync::Arc;

/// MRI experiment parameters (the `mri.*` config keys).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MriConfig {
    /// Image resolution r (pixels per axis, power of two ≥ 8).
    pub resolution: usize,
    /// Undersampling pattern parameters.
    pub mask: MaskConfig,
    /// Bit width of the low-precision sampling path (2 | 4 | 8), or 0 to
    /// run the f32 path only.
    pub bits: u8,
    /// Recovery sparsity s, or 0 for the auto default `max(8, n/12)`.
    pub sparsity: usize,
}

impl Default for MriConfig {
    fn default() -> Self {
        Self { resolution: 64, mask: MaskConfig::default(), bits: 8, sparsity: 0 }
    }
}

impl MriConfig {
    /// The resolved sparsity target.
    pub fn effective_sparsity(&self) -> usize {
        if self.sparsity == 0 {
            (self.resolution * self.resolution / 12).max(8)
        } else {
            self.sparsity
        }
    }

    /// Cross-field gate (config file / CLI parse): mask parameters via
    /// the shared [`MaskConfig::validate`], grid and bit-width sanity.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.resolution.is_power_of_two() && self.resolution >= 8,
            "mri.resolution {} must be a power of two >= 8 (radix-2 FFT grid)",
            self.resolution
        );
        self.mask.validate()?;
        anyhow::ensure!(
            matches!(self.bits, 0 | 2 | 4 | 8),
            "mri.bits {} must be 0 (f32) or a packed width (2|4|8)",
            self.bits
        );
        anyhow::ensure!(
            self.effective_sparsity() <= self.resolution * self.resolution,
            "mri.sparsity {} exceeds the image dimension",
            self.sparsity
        );
        Ok(())
    }
}

/// A fully synthesized MRI recovery problem: the shared operator, the
/// (noiseless, f32) observations, and the ground truth.
#[derive(Debug, Clone)]
pub struct MriProblem {
    /// The matrix-free operator, shareable across jobs (batch identity).
    pub op: Arc<PartialFourierOp>,
    /// f32 observations `Φ x_true` (quantize via [`lowprec_problem`]).
    pub y: Vec<f32>,
    /// The s-sparse phantom the recovery targets.
    pub x_true: Vec<f32>,
    pub s: usize,
    pub r: usize,
}

impl MriProblem {
    /// Build from validated configuration; `seed` drives the mask draw.
    pub fn build(cfg: &MriConfig, seed: u64) -> Result<Self> {
        cfg.validate()?;
        let r = cfg.resolution;
        let s = cfg.effective_sparsity();
        let x_true = phantom::sparse_phantom(r, s);
        let mask = SamplingMask::generate(&cfg.mask, r, seed)?;
        let op = Arc::new(PartialFourierOp::new(mask));
        let y = op.apply(&x_true);
        Ok(Self { op, y, x_true, s, r })
    }

    pub fn n(&self) -> usize {
        self.r * self.r
    }

    pub fn m(&self) -> usize {
        self.y.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_validate_and_resolve_sparsity() {
        let cfg = MriConfig::default();
        cfg.validate().unwrap();
        assert_eq!(cfg.effective_sparsity(), 64 * 64 / 12);
        let explicit = MriConfig { sparsity: 100, ..cfg };
        assert_eq!(explicit.effective_sparsity(), 100);
    }

    #[test]
    fn config_rejects_bad_parameters() {
        let ok = MriConfig::default();
        assert!(MriConfig { resolution: 48, ..ok }.validate().is_err());
        assert!(MriConfig { resolution: 4, ..ok }.validate().is_err());
        assert!(MriConfig { bits: 3, ..ok }.validate().is_err());
        assert!(MriConfig { bits: 16, ..ok }.validate().is_err());
        MriConfig { bits: 0, ..ok }.validate().unwrap();
        let bad_mask =
            MriConfig { mask: MaskConfig { fraction: 0.0, ..ok.mask }, ..ok };
        assert!(bad_mask.validate().is_err());
        assert!(MriConfig { sparsity: 5000, resolution: 8, ..ok }.validate().is_err());
    }

    #[test]
    fn problem_build_is_consistent() {
        let cfg = MriConfig { resolution: 16, sparsity: 20, ..Default::default() };
        let p = MriProblem::build(&cfg, 3).unwrap();
        assert_eq!(p.n(), 256);
        assert_eq!(p.m(), 2 * p.op.mask().len());
        assert_eq!(p.y.len(), p.m());
        assert_eq!(p.s, 20);
        assert!(p.x_true.iter().filter(|&&v| v != 0.0).count() <= 20);
        // Same seed, same problem.
        let q = MriProblem::build(&cfg, 3).unwrap();
        assert_eq!(p.y, q.y);
    }

    #[test]
    fn build_rejects_invalid_config() {
        let cfg = MriConfig {
            mask: MaskConfig { fraction: 1.5, ..Default::default() },
            ..Default::default()
        };
        assert!(MriProblem::build(&cfg, 0).is_err());
    }
}
