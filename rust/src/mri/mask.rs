//! k-space undersampling masks — which Fourier coefficients the scanner
//! acquires.
//!
//! Two families, both on the unshifted `r × r` DFT grid (DC at index
//! `(0, 0)`; distances are computed on *wrapped* frequencies, so "low
//! frequency" means close to DC modulo `r`):
//!
//! * [`MaskKind::Cartesian`] — variable-density phase-encode sampling:
//!   whole `kx` readout lines, every line within `center_band` of DC plus
//!   randomly drawn outer lines with density `∝ 1/(1+|ky|)²` until
//!   `fraction · r` lines are acquired. This is the standard Cartesian
//!   CS-MRI protocol (dense centre, sparse periphery).
//! * [`MaskKind::Radial`] — `round(fraction · r)` equally-spaced spokes
//!   through DC (rasterized lines), plus a fully-sampled
//!   `center_band`-wide block around DC.
//!
//! Mask *generation* is total: degenerate parameters produce degenerate
//! masks rather than panicking, and [`MaskConfig::validate`] is the single
//! gate both the config/CLI layer and [`crate::coordinator::JobSpec`]
//! submission call — an out-of-range fraction or a zero centre band is
//! rejected with a clear error before any job is queued (counted in
//! `ServiceMetrics.invalid`).

use crate::rng::XorShift128Plus;
use anyhow::{bail, Result};
use std::collections::BTreeSet;

/// Mask family selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MaskKind {
    /// Variable-density Cartesian phase-encode lines.
    Cartesian,
    /// Equally-spaced radial spokes through DC.
    Radial,
}

impl MaskKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "cartesian" => Self::Cartesian,
            "radial" => Self::Radial,
            other => bail!("unknown mask kind '{other}' (cartesian|radial)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Cartesian => "cartesian",
            Self::Radial => "radial",
        }
    }
}

/// Undersampling-mask parameters. `fraction` is the target fraction of
/// acquired lines/spokes relative to a full acquisition (`r` of either);
/// `center_band` is the half-width of the always-acquired low-frequency
/// region around DC.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MaskConfig {
    pub kind: MaskKind,
    pub fraction: f32,
    pub center_band: usize,
}

impl Default for MaskConfig {
    fn default() -> Self {
        Self { kind: MaskKind::Cartesian, fraction: 0.4, center_band: 4 }
    }
}

impl MaskConfig {
    /// The one shared parameter gate (config/CLI parse AND job submit):
    /// the undersampling fraction must lie in `(0, 1]` and the centre
    /// band must keep at least the DC line.
    pub fn validate(&self) -> Result<()> {
        if !(self.fraction > 0.0 && self.fraction <= 1.0) {
            bail!(
                "mri mask: undersampling fraction {} is not in (0, 1]",
                self.fraction
            );
        }
        if self.center_band == 0 {
            bail!("mri mask: center_band must be >= 1 (the DC region is always acquired)");
        }
        Ok(())
    }

    /// Hashable fingerprint (`f32` bit-cast) — folded into the
    /// coordinator's batch key via the operator pointer; kept for tests
    /// and diagnostics.
    pub fn key(&self) -> (MaskKind, u32, usize) {
        (self.kind, self.fraction.to_bits(), self.center_band)
    }
}

/// Wrapped frequency distance from DC: `min(k, r − k)`.
fn wrapped(k: usize, r: usize) -> usize {
    k.min(r - k)
}

/// A generated sampling pattern: the acquired k-space indices (flattened
/// `ky · r + kx`, ascending) plus the parameters that produced it.
#[derive(Clone)]
pub struct SamplingMask {
    r: usize,
    cfg: MaskConfig,
    points: Vec<usize>,
}

impl std::fmt::Debug for SamplingMask {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SamplingMask")
            .field("r", &self.r)
            .field("kind", &self.cfg.kind.name())
            .field("samples", &self.points.len())
            .field("undersampling", &self.undersampling())
            .finish()
    }
}

impl SamplingMask {
    /// Generate a mask. Deterministic in `(cfg, r, seed)`; `r` must be a
    /// power of two ≥ 4 (the FFT grid). Does NOT validate `cfg` — see the
    /// module docs; callers gate parameters through
    /// [`MaskConfig::validate`].
    pub fn generate(cfg: &MaskConfig, r: usize, seed: u64) -> Result<Self> {
        anyhow::ensure!(
            r.is_power_of_two() && r >= 4,
            "mask grid size {r} must be a power of two >= 4"
        );
        let points = match cfg.kind {
            MaskKind::Cartesian => cartesian_points(cfg, r, seed),
            MaskKind::Radial => radial_points(cfg, r),
        };
        Ok(Self { r, cfg: *cfg, points })
    }

    /// Rebuild a mask from its acquired points. This is how the wire
    /// protocol ships masks — by content, not by generation seed — so a
    /// server-side reconstruction is exactly the client's operator.
    /// Points must be strictly ascending (the [`SamplingMask::points`]
    /// invariant) and in range; `r` must be a power of two in
    /// `4..=8192`. The upper bound exists because these values arrive
    /// from the network: without it a tiny frame naming an astronomical
    /// grid would drive an unbounded FFT-plan allocation (and `r * r`
    /// below must not overflow).
    pub fn from_points(cfg: &MaskConfig, r: usize, points: Vec<usize>) -> Result<Self> {
        anyhow::ensure!(
            r.is_power_of_two() && (4..=8192).contains(&r),
            "mask grid size {r} must be a power of two in 4..=8192"
        );
        anyhow::ensure!(!points.is_empty(), "mask must acquire at least one k-space point");
        for w in points.windows(2) {
            anyhow::ensure!(w[0] < w[1], "mask points must be strictly ascending");
        }
        anyhow::ensure!(
            *points.last().unwrap() < r * r,
            "mask point {} outside the {r}x{r} grid",
            points.last().unwrap()
        );
        Ok(Self { r, cfg: *cfg, points })
    }

    pub fn r(&self) -> usize {
        self.r
    }

    pub fn config(&self) -> &MaskConfig {
        &self.cfg
    }

    /// Acquired k-space indices, flattened `ky · r + kx`, ascending.
    pub fn points(&self) -> &[usize] {
        &self.points
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Fraction of the full grid actually acquired.
    pub fn undersampling(&self) -> f64 {
        self.points.len() as f64 / (self.r * self.r) as f64
    }

    /// 0/1 occupancy image (row-major r×r) — mask figures.
    pub fn to_image(&self) -> Vec<f32> {
        let mut img = vec![0.0f32; self.r * self.r];
        for &p in &self.points {
            img[p] = 1.0;
        }
        img
    }
}

fn cartesian_points(cfg: &MaskConfig, r: usize, seed: u64) -> Vec<usize> {
    let mut lines: BTreeSet<usize> = (0..r).filter(|&k| wrapped(k, r) < cfg.center_band).collect();
    let target = ((cfg.fraction as f64 * r as f64).round() as usize).max(1);

    // Variable-density draws over the remaining lines: weight ∝ 1/(1+d)²
    // where d is the wrapped distance from DC. CDF inversion per draw,
    // sampling WITHOUT replacement (the picked line leaves the candidate
    // set), so exactly min(target, r) lines come out after at most r
    // draws — no collision retries, no attempt bound.
    let mut rest: Vec<usize> = (0..r).filter(|k| !lines.contains(k)).collect();
    let mut weights: Vec<f64> =
        rest.iter().map(|&k| 1.0 / ((1 + wrapped(k, r)) as f64).powi(2)).collect();
    let mut total: f64 = weights.iter().sum();
    let mut rng = XorShift128Plus::new(seed ^ 0x4D52_4931); // "MRI1"
    while lines.len() < target && !rest.is_empty() {
        let mut u = rng.uniform() * total;
        let mut pick = rest.len() - 1;
        for (idx, &w) in weights.iter().enumerate() {
            if u < w {
                pick = idx;
                break;
            }
            u -= w;
        }
        lines.insert(rest.swap_remove(pick));
        total -= weights.swap_remove(pick);
    }
    lines.iter().flat_map(|&ky| (0..r).map(move |kx| ky * r + kx)).collect()
}

fn radial_points(cfg: &MaskConfig, r: usize) -> Vec<usize> {
    let spokes = ((cfg.fraction as f64 * r as f64).round() as usize).max(1);
    let mut pts: BTreeSet<usize> = BTreeSet::new();
    for si in 0..spokes {
        let theta = std::f64::consts::PI * si as f64 / spokes as f64;
        let (sin_t, cos_t) = theta.sin_cos();
        for t in -(r as i64) / 2..(r as i64) / 2 {
            let ky = (t as f64 * sin_t).round() as i64;
            let kx = (t as f64 * cos_t).round() as i64;
            let ky = ky.rem_euclid(r as i64) as usize;
            let kx = kx.rem_euclid(r as i64) as usize;
            pts.insert(ky * r + kx);
        }
    }
    // Fully-sampled centre block (wrapped in both axes).
    for ky in 0..r {
        if wrapped(ky, r) >= cfg.center_band {
            continue;
        }
        for kx in 0..r {
            if wrapped(kx, r) < cfg.center_band {
                pts.insert(ky * r + kx);
            }
        }
    }
    pts.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_points_round_trips_generated_masks() {
        for kind in [MaskKind::Cartesian, MaskKind::Radial] {
            let cfg = MaskConfig { kind, ..Default::default() };
            let mask = SamplingMask::generate(&cfg, 16, 5).unwrap();
            let rebuilt =
                SamplingMask::from_points(&cfg, 16, mask.points().to_vec()).unwrap();
            assert_eq!(rebuilt.points(), mask.points());
            assert_eq!(rebuilt.r(), mask.r());
        }
        let cfg = MaskConfig::default();
        assert!(SamplingMask::from_points(&cfg, 12, vec![0]).is_err(), "non-pow2 grid");
        assert!(
            SamplingMask::from_points(&cfg, 1 << 31, vec![0]).is_err(),
            "wire-controlled grid sizes are bounded"
        );
        assert!(SamplingMask::from_points(&cfg, 16, vec![]).is_err(), "empty mask");
        assert!(SamplingMask::from_points(&cfg, 16, vec![3, 3]).is_err(), "not ascending");
        assert!(SamplingMask::from_points(&cfg, 16, vec![5, 4]).is_err(), "not ascending");
        assert!(SamplingMask::from_points(&cfg, 16, vec![256]).is_err(), "out of range");
    }

    #[test]
    fn validate_gates_parameters() {
        let ok = MaskConfig::default();
        ok.validate().unwrap();
        for bad_fraction in [0.0f32, -0.1, 1.5, f32::NAN] {
            let cfg = MaskConfig { fraction: bad_fraction, ..ok };
            let err = cfg.validate().unwrap_err().to_string();
            assert!(err.contains("fraction"), "{bad_fraction}: {err}");
        }
        let cfg = MaskConfig { center_band: 0, ..ok };
        assert!(cfg.validate().unwrap_err().to_string().contains("center_band"));
        // Full sampling is legal (fraction = 1).
        MaskConfig { fraction: 1.0, ..ok }.validate().unwrap();
    }

    #[test]
    fn generation_is_deterministic_and_sorted() {
        let cfg = MaskConfig { fraction: 0.5, ..Default::default() };
        let a = SamplingMask::generate(&cfg, 64, 7).unwrap();
        let b = SamplingMask::generate(&cfg, 64, 7).unwrap();
        assert_eq!(a.points(), b.points());
        assert!(a.points().windows(2).all(|w| w[0] < w[1]), "ascending, deduped");
        let c = SamplingMask::generate(&cfg, 64, 8).unwrap();
        assert_ne!(a.points(), c.points(), "seed changes the drawn lines");
    }

    #[test]
    fn cartesian_keeps_dc_and_hits_the_target_fraction() {
        for (r, fraction) in [(32usize, 0.4f32), (64, 0.3), (64, 1.0)] {
            let cfg = MaskConfig { fraction, ..Default::default() };
            let m = SamplingMask::generate(&cfg, r, 3).unwrap();
            assert!(m.points().contains(&0), "DC acquired (r={r})");
            let lines = m.len() / r;
            assert_eq!(m.len() % r, 0, "whole lines only");
            let target = ((fraction as f64 * r as f64).round() as usize)
                .max((2 * cfg.center_band).saturating_sub(1));
            assert_eq!(lines, target.min(r), "r={r} fraction={fraction}");
        }
    }

    #[test]
    fn radial_covers_center_and_undersamples() {
        let cfg =
            MaskConfig { kind: MaskKind::Radial, fraction: 0.4, center_band: 3 };
        let m = SamplingMask::generate(&cfg, 64, 0).unwrap();
        assert!(m.points().contains(&0), "DC acquired");
        // Centre block fully present (wrapped coordinates).
        for ky in [0usize, 1, 2, 62, 63] {
            for kx in [0usize, 1, 2, 62, 63] {
                assert!(m.points().contains(&(ky * 64 + kx)), "({ky},{kx})");
            }
        }
        assert!(m.undersampling() < 0.6, "radial at 0.4 undersamples: {}", m.undersampling());
        assert!(m.undersampling() > 0.05);
    }

    #[test]
    fn degenerate_configs_generate_without_panicking() {
        // Generation is total; validation is the gate.
        let zero = MaskConfig { fraction: 0.0, ..Default::default() };
        let m = SamplingMask::generate(&zero, 16, 1).unwrap();
        assert!(!m.is_empty(), "centre band still acquired");
        let no_band =
            MaskConfig { center_band: 0, fraction: 0.25, ..Default::default() };
        SamplingMask::generate(&no_band, 16, 1).unwrap();
    }

    #[test]
    fn non_power_of_two_grid_rejected() {
        let err = SamplingMask::generate(&MaskConfig::default(), 48, 0).unwrap_err();
        assert!(err.to_string().contains("power of two"));
    }

    #[test]
    fn mask_image_marks_points() {
        let m = SamplingMask::generate(&MaskConfig::default(), 16, 2).unwrap();
        let img = m.to_image();
        assert_eq!(img.iter().filter(|&&v| v == 1.0).count(), m.len());
    }
}
