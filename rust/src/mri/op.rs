//! The partial-Fourier measurement operator and its low-precision
//! sampling variant.
//!
//! [`PartialFourierOp`] is the crate's first matrix-free
//! [`MeasurementOp`]: `Φ = S F_u`, where `F_u` is the unitary 2-D DFT
//! (`1/√n` scaling) and `S` gathers the masked k-space coefficients as
//! interleaved `(re, im)` pairs — the stacked-real embedding keeps every
//! solver in f32 real arithmetic, exactly like the telescope workload.
//! `apply` runs an FFT instead of an `m × n` matvec (`O(n log n)` vs
//! `O(n²)` work and **zero** operator storage), and `apply_t` is the
//! *exact* adjoint `F_uᴴ Sᵀ` (pinned by the inner-product property test
//! in `tests/mri_parity.rs`), so NIHT's descent math holds unchanged.
//! [`PartialFourierOp::to_mat`] materializes the same operator as an
//! explicit [`Mat`] from the closed-form DFT entries — the parity
//! reference and the "dense baseline" the MRI bench compares against.
//!
//! ## What is quantized when Φ is implicit
//!
//! The dense workloads quantize the *entries of Φ*. A Fourier operator
//! has no entries worth storing — its "matrix" is the FFT butterfly
//! structure — so the paper's low-precision representation maps onto the
//! **data streams** instead ([`LowPrecFourierOp`]):
//!
//! * the observation ŷ = Q_b(y), quantized once at acquisition
//!   ([`lowprec_problem`]) — the scanner's ADC output at `b` bits;
//! * the per-iteration k-space residual `r = ŷ − Φx` entering the
//!   adjoint, re-quantized stochastically every gradient step — the
//!   measurement-domain traffic between the reconstruction host and the
//!   transform accelerator.
//!
//! Both use the crate's stochastic [`Quantizer`] with a **per-block
//! scale** ([`QUANT_BLOCK`] samples — the per-readout ADC gain): k-space
//! has orders-of-magnitude dynamic range between DC and the periphery, so
//! one global scale (the dense-Φ setting) would drown the high-frequency
//! detail in rounding noise at any practical bit width. Image-domain
//! iterates stay f32 — they are solver state, not operator traffic.
//! Dequantization streams the int8 codes through the runtime-dispatched
//! SIMD backend ([`crate::simd::Kernels::scale_add_i8`]), the same
//! mixed-precision kernel the packed dense path uses.

use crate::fft::FftPlan;
use crate::linalg::Mat;
use crate::quant::Quantizer;
use crate::rng::XorShift128Plus;
use crate::solver::{MeasurementOp, Problem};
use anyhow::Result;
use std::sync::{Arc, Mutex};

use super::mask::SamplingMask;

/// Matrix-free partial-Fourier operator `Φ = S F_u` (see module docs).
#[derive(Clone)]
pub struct PartialFourierOp {
    mask: SamplingMask,
    r: usize,
    n: usize,
    /// Unitary DFT scaling `1/√n`.
    scale: f32,
    /// Prepared twiddles for the `r × r` grid — built once so the
    /// per-iteration transforms run trig-free.
    plan: FftPlan,
}

impl std::fmt::Debug for PartialFourierOp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PartialFourierOp")
            .field("r", &self.r)
            .field("mask", &self.mask)
            .field("m", &MeasurementOp::m(self))
            .finish()
    }
}

impl PartialFourierOp {
    pub fn new(mask: SamplingMask) -> Self {
        let r = mask.r();
        let n = r * r;
        Self { mask, r, n, scale: 1.0 / (n as f32).sqrt(), plan: FftPlan::new(r) }
    }

    pub fn r(&self) -> usize {
        self.r
    }

    pub fn mask(&self) -> &SamplingMask {
        &self.mask
    }

    /// Submit-time gate: re-checks the mask parameters (the coordinator
    /// calls this from `JobSpec::validate`, so a job built around an
    /// invalid mask fails at submission, not inside a worker).
    pub fn validate(&self) -> Result<()> {
        self.mask.config().validate()?;
        anyhow::ensure!(!self.mask.is_empty(), "mri mask acquires no samples");
        Ok(())
    }

    /// Materialize `Φ` as an explicit dense matrix from the closed-form
    /// DFT entries (independent of the FFT code path — the parity
    /// reference, and the dense-baseline operand of the MRI bench).
    /// Row `2i` is `Re`, row `2i+1` is `Im` of mask point `i`:
    /// `Φ[2i, p·r+q] = cos(−2π(ky·p + kx·q)/r)/√n`.
    pub fn to_mat(&self) -> Mat {
        let r = self.r;
        let mut mat = Mat::zeros(MeasurementOp::m(self), self.n);
        for (i, &point) in self.mask.points().iter().enumerate() {
            let (ky, kx) = (point / r, point % r);
            for p in 0..r {
                for q in 0..r {
                    let ang = -2.0 * std::f64::consts::PI
                        * ((ky * p) as f64 + (kx * q) as f64)
                        / r as f64;
                    let col = p * r + q;
                    *mat.at_mut(2 * i, col) = (ang.cos() as f32) * self.scale;
                    *mat.at_mut(2 * i + 1, col) = (ang.sin() as f32) * self.scale;
                }
            }
        }
        mat
    }

    /// The classical zero-filled reconstruction `Φᵀ y` (the baseline
    /// image the demo and figures show next to the recovered one).
    pub fn zero_filled(&self, y: &[f32]) -> Vec<f32> {
        self.apply_t(y)
    }
}

impl MeasurementOp for PartialFourierOp {
    fn m(&self) -> usize {
        2 * self.mask.len()
    }

    fn n(&self) -> usize {
        self.n
    }

    fn apply(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n);
        let mut re = x.to_vec();
        let mut im = vec![0.0f32; self.n];
        self.plan.run_2d_square(&mut re, &mut im, false);
        let mut out = Vec::with_capacity(2 * self.mask.len());
        for &p in self.mask.points() {
            out.push(re[p] * self.scale);
            out.push(im[p] * self.scale);
        }
        out
    }

    fn apply_t(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), 2 * self.mask.len());
        let mut re = vec![0.0f32; self.n];
        let mut im = vec![0.0f32; self.n];
        for (i, &p) in self.mask.points().iter().enumerate() {
            re[p] = v[2 * i];
            im[p] = v[2 * i + 1];
        }
        self.plan.run_2d_square(&mut re, &mut im, true);
        // Adjoint of the unitary forward: F_uᴴ = √n · ifft2. The image
        // domain is real, so the imaginary part is dropped.
        let s = (self.n as f32).sqrt();
        for val in re.iter_mut() {
            *val *= s;
        }
        re
    }
}

/// Samples per quantization block (interleaved re/im f32 values sharing
/// one scale): the per-readout ADC gain granularity. Validated against
/// the global-scale alternative, which loses > 2 dB at 8 bits on the
/// 64×64 phantom from k-space dynamic range alone.
pub const QUANT_BLOCK: usize = 32;

/// Stochastically quantize `v` to `bits` with one scale per
/// [`QUANT_BLOCK`]-value block and dequantize back to f32, streaming the
/// codes through the dispatched SIMD backend.
pub fn quantize_blocked(v: &[f32], bits: u8, rng: &mut XorShift128Plus) -> Vec<f32> {
    let q = Quantizer::new(bits);
    let kernels = crate::simd::active();
    let mut out = vec![0.0f32; v.len()];
    for (seg, dst) in v.chunks(QUANT_BLOCK).zip(out.chunks_mut(QUANT_BLOCK)) {
        let scale =
            seg.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(f32::MIN_POSITIVE);
        let codes = q.quantize_slice(seg, scale, rng);
        // dst is zero-initialized: y += mult · codes dequantizes in one
        // pass of the mixed int8·f32 kernel.
        kernels.scale_add_i8(dst, &codes, scale / q.half() as f32);
    }
    out
}

/// Low-precision sampling variant of [`PartialFourierOp`]: the same
/// transform, with the per-iteration measurement-domain traffic (the
/// k-space residual entering the adjoint) stochastically quantized to
/// `bits` per [`QUANT_BLOCK`]-sample block. See the module docs for what
/// is (and is not) quantized when Φ is implicit.
///
/// The RNG driving the stochastic rounding lives behind a `Mutex`: calls
/// consume draws in sequence, so two solves issuing the same call
/// sequence from the same seed are bit-identical — which is exactly how
/// the serving conformance test pins the service against the facade.
pub struct LowPrecFourierOp {
    inner: Arc<PartialFourierOp>,
    bits: u8,
    rng: Mutex<XorShift128Plus>,
}

impl std::fmt::Debug for LowPrecFourierOp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LowPrecFourierOp")
            .field("bits", &self.bits)
            .field("inner", &self.inner)
            .finish()
    }
}

impl LowPrecFourierOp {
    pub fn new(inner: Arc<PartialFourierOp>, bits: u8, rng: XorShift128Plus) -> Self {
        assert!(matches!(bits, 2 | 4 | 8), "packed widths only, got {bits}");
        Self { inner, bits, rng: Mutex::new(rng) }
    }

    pub fn bits(&self) -> u8 {
        self.bits
    }
}

impl MeasurementOp for LowPrecFourierOp {
    fn m(&self) -> usize {
        self.inner.m()
    }

    fn n(&self) -> usize {
        self.inner.n()
    }

    fn apply(&self, x: &[f32]) -> Vec<f32> {
        // Image-domain input: solver state, streamed at full precision.
        self.inner.apply(x)
    }

    fn apply_t(&self, v: &[f32]) -> Vec<f32> {
        let vq = quantize_blocked(v, self.bits, &mut self.rng.lock().unwrap());
        self.inner.apply_t(&vq)
    }
}

/// Lower an MRI problem onto the low-precision sampling path: quantize
/// the observation to `bits` (per-block stochastic rounding seeded by
/// `seed`) and wrap the operator so per-iteration k-space traffic is
/// quantized with the same RNG stream.
///
/// This is the single lowering both
/// [`crate::coordinator::JobSpec::into_request`] and direct facade
/// callers use, so a served job and a local `Recovery` run of the same
/// spec produce bit-identical iterates.
pub fn lowprec_problem(
    op: Arc<PartialFourierOp>,
    y: &[f32],
    s: usize,
    bits: u8,
    seed: u64,
) -> Problem {
    let mut rng = XorShift128Plus::new(seed ^ 0x4C50_4653); // "LPFS"
    let y_hat = quantize_blocked(y, bits, &mut rng);
    Problem::with_op(Arc::new(LowPrecFourierOp::new(op, bits, rng)), y_hat, s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg;
    use crate::mri::mask::{MaskConfig, MaskKind};

    fn op(r: usize, seed: u64) -> PartialFourierOp {
        let mask = SamplingMask::generate(&MaskConfig::default(), r, seed).unwrap();
        PartialFourierOp::new(mask)
    }

    #[test]
    fn shapes_and_interleaving() {
        let op = op(16, 1);
        assert_eq!(op.n(), 256);
        assert_eq!(op.m(), 2 * op.mask().len());
        let ones = vec![1.0f32; 256];
        let y = op.apply(&ones);
        assert_eq!(y.len(), op.m());
        // A constant image is a pure DC spike: every non-DC sample ~0.
        let dc = op.mask().points().iter().position(|&p| p == 0).unwrap();
        assert!((y[2 * dc] - 16.0).abs() < 1e-4, "DC = n/sqrt(n) = r");
        let energy: f32 = y.iter().map(|v| v * v).sum();
        assert!((energy - 256.0).abs() < 1e-2, "all energy at DC");
    }

    #[test]
    fn adjoint_inner_product_property() {
        // <Φx, v> == <x, Φᵀv> for random x, v — the exact-adjoint
        // requirement NIHT's convergence rests on.
        let mut rng = XorShift128Plus::new(5);
        for kind in [MaskKind::Cartesian, MaskKind::Radial] {
            let cfg = MaskConfig { kind, ..Default::default() };
            let mask = SamplingMask::generate(&cfg, 16, 3).unwrap();
            let op = PartialFourierOp::new(mask);
            let x = rng.gaussian_vec(op.n());
            let v = rng.gaussian_vec(MeasurementOp::m(&op));
            let lhs = linalg::dot(&op.apply(&x), &v);
            let rhs = linalg::dot(&x, &op.apply_t(&v));
            assert!(
                (lhs - rhs).abs() <= 1e-3 * (1.0 + lhs.abs()),
                "{kind:?}: {lhs} vs {rhs}"
            );
        }
    }

    #[test]
    fn unitary_when_fully_sampled() {
        // fraction = 1 acquires every line: ΦᵀΦ = I.
        let cfg = MaskConfig { fraction: 1.0, ..Default::default() };
        let mask = SamplingMask::generate(&cfg, 8, 0).unwrap();
        assert_eq!(mask.len(), 64);
        let op = PartialFourierOp::new(mask);
        let mut rng = XorShift128Plus::new(6);
        let x = rng.gaussian_vec(64);
        let back = op.apply_t(&op.apply(&x));
        for i in 0..64 {
            assert!((back[i] - x[i]).abs() <= 1e-4, "i={i}");
        }
    }

    #[test]
    fn quantize_blocked_bounds_error_and_uses_block_scales() {
        let mut rng = XorShift128Plus::new(7);
        // Two blocks with wildly different magnitude: per-block scales
        // keep the small block's relative error at the b-bit level.
        let mut v = vec![0.0f32; 2 * QUANT_BLOCK];
        for (i, val) in v.iter_mut().enumerate() {
            *val = if i < QUANT_BLOCK { 1000.0 } else { 1.0 } * (0.3 + 0.7 * ((i % 5) as f32) / 5.0);
        }
        let dq = quantize_blocked(&v, 8, &mut rng);
        let half = 64.0f32;
        for i in 0..v.len() {
            let block_max = if i < QUANT_BLOCK { 1000.0 } else { 1.0 };
            assert!(
                (dq[i] - v[i]).abs() <= block_max / half + 1e-3,
                "i={i}: {} vs {}",
                dq[i],
                v[i]
            );
        }
    }

    #[test]
    fn lowprec_op_quantizes_adjoint_traffic_only() {
        let inner = Arc::new(op(16, 2));
        let lp = LowPrecFourierOp::new(inner.clone(), 8, XorShift128Plus::new(1));
        let mut rng = XorShift128Plus::new(8);
        let x = rng.gaussian_vec(inner.n());
        assert_eq!(lp.apply(&x), inner.apply(&x), "forward path is exact");
        let v = rng.gaussian_vec(inner.m());
        let exact = inner.apply_t(&v);
        let noisy = lp.apply_t(&v);
        assert_ne!(noisy, exact, "adjoint input is quantized");
        let rel = linalg::norm2(&linalg::sub(&noisy, &exact)) / linalg::norm2(&exact);
        assert!(rel < 0.05, "8-bit noise is small: rel={rel}");
    }

    #[test]
    fn lowprec_problem_is_deterministic_in_seed() {
        let inner = Arc::new(op(16, 2));
        let mut rng = XorShift128Plus::new(9);
        let x = rng.gaussian_vec(inner.n());
        let y = inner.apply(&x);
        let run = |seed: u64| {
            let p = lowprec_problem(inner.clone(), &y, 8, 8, seed);
            // Same call sequence → identical draws.
            let a = p.op().apply_t(p.y());
            (p.y().to_vec(), a)
        };
        assert_eq!(run(3), run(3), "same seed reproduces");
        assert_ne!(run(3), run(4), "seed matters");
    }

    #[test]
    fn validate_flags_bad_mask_parameters() {
        let mask = SamplingMask::generate(
            &MaskConfig { fraction: 2.0, ..Default::default() },
            16,
            0,
        )
        .unwrap();
        let op = PartialFourierOp::new(mask);
        assert!(op.validate().unwrap_err().to_string().contains("fraction"));
        op.to_mat(); // materialization itself is still well-defined
    }
}
