//! Stochastic quantization (S2) — the paper's `Q_b(·)` operator.
//!
//! Scheme (§3 "Quantization" + Remark 3): an odd number of levels,
//! `2^{b-1}+1`, equally spaced on `[-scale, +scale]`. Codes are signed
//! integers `k ∈ {-half, …, +half}` with `half = 2^{b-2}`, dequantizing as
//! `value = scale · k / half`. Stochastic rounding assigns the two
//! neighbouring levels with probabilities proportional to proximity, so the
//! quantizer is **unbiased** (`E[Q(v)] = v`) and the per-element error is at
//! most `scale/2^{b-1}` in expectation — the constant of Lemma 4.
//!
//! This module is the rust twin of `python/compile/kernels/quantize.py`
//! (same grid, same rounding rule) so codes produced here feed the AOT
//! artifacts directly.

pub mod packed;

use crate::linalg::Mat;
use crate::rng::XorShift128Plus;

/// A b-bit stochastic quantizer (2 ≤ b ≤ 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Quantizer {
    pub bits: u8,
}

impl Quantizer {
    pub fn new(bits: u8) -> Self {
        assert!((2..=8).contains(&bits), "bits must be in 2..=8, got {bits}");
        Self { bits }
    }

    /// Codes live in `[-half, +half]`.
    #[inline]
    pub fn half(&self) -> i32 {
        1 << (self.bits - 2)
    }

    /// Number of levels (odd): 2^{b-1} + 1.
    #[inline]
    pub fn levels(&self) -> u32 {
        (1u32 << (self.bits - 1)) + 1
    }

    /// Quantize one value given a uniform(0,1) draw.
    #[inline]
    pub fn quantize_one(&self, v: f32, u: f32, scale: f32) -> i8 {
        let half = self.half() as f32;
        let t = v / scale * half;
        let lo = t.floor();
        let code = lo + if u < t - lo { 1.0 } else { 0.0 };
        code.clamp(-half, half) as i8
    }

    #[inline]
    pub fn dequantize_one(&self, code: i8, scale: f32) -> f32 {
        code as f32 * (scale / self.half() as f32)
    }

    /// Quantize a slice with the given scale. Returns codes.
    pub fn quantize_slice(&self, v: &[f32], scale: f32, rng: &mut XorShift128Plus) -> Vec<i8> {
        v.iter().map(|&x| self.quantize_one(x, rng.uniform_f32(), scale)).collect()
    }

    /// Quantize with auto scale = max|v| (the paper's setting: data is
    /// normalized to [-1, 1] a priori). Returns (codes, scale).
    pub fn quantize_auto(&self, v: &[f32], rng: &mut XorShift128Plus) -> (Vec<i8>, f32) {
        let scale = v.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(f32::MIN_POSITIVE);
        (self.quantize_slice(v, scale, rng), scale)
    }

    pub fn dequantize_slice(&self, codes: &[i8], scale: f32) -> Vec<f32> {
        let mult = scale / self.half() as f32;
        codes.iter().map(|&c| c as f32 * mult).collect()
    }
}

/// A quantized matrix: int8 codes + scale + bit width (row-major, `m×n`).
#[derive(Debug, Clone)]
pub struct QuantizedMatrix {
    pub m: usize,
    pub n: usize,
    pub bits: u8,
    pub scale: f32,
    pub codes: Vec<i8>,
}

impl QuantizedMatrix {
    /// Quantize a dense matrix (scale = max|Φ|, per the paper).
    pub fn from_mat(a: &Mat, bits: u8, rng: &mut XorShift128Plus) -> Self {
        let q = Quantizer::new(bits);
        let (codes, scale) = q.quantize_auto(&a.data, rng);
        Self { m: a.rows, n: a.cols, bits, scale, codes }
    }

    /// Quantize with an explicit scale (for paired quantizations that must
    /// share the grid).
    pub fn from_mat_with_scale(a: &Mat, bits: u8, scale: f32, rng: &mut XorShift128Plus) -> Self {
        let q = Quantizer::new(bits);
        let codes = q.quantize_slice(&a.data, scale, rng);
        Self { m: a.rows, n: a.cols, bits, scale, codes }
    }

    /// Dequantization multiplier `scale / half` (what the kernels consume).
    #[inline]
    pub fn multiplier(&self) -> f32 {
        self.scale / Quantizer::new(self.bits).half() as f32
    }

    /// Dense reconstruction Q(Φ) as f32 (for diagnostics / RIP probes).
    pub fn to_mat(&self) -> Mat {
        let mult = self.multiplier();
        Mat::from_vec(self.m, self.n, self.codes.iter().map(|&c| c as f32 * mult).collect())
    }

    /// Transposed copy (codes^T), used for the Φᵀ-oriented buffer.
    pub fn transposed(&self) -> QuantizedMatrix {
        let mut codes = vec![0i8; self.codes.len()];
        for i in 0..self.m {
            for j in 0..self.n {
                codes[j * self.m + i] = self.codes[i * self.n + j];
            }
        }
        QuantizedMatrix { m: self.n, n: self.m, bits: self.bits, scale: self.scale, codes }
    }

    /// Ideal packed size in bytes at this bit width (the traffic metric
    /// driving Figs 5/6: bytes = m·n·b/8).
    pub fn bytes_ideal(&self) -> usize {
        (self.m * self.n * self.bits as usize).div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn half_and_levels() {
        assert_eq!(Quantizer::new(2).half(), 1);
        assert_eq!(Quantizer::new(2).levels(), 3);
        assert_eq!(Quantizer::new(4).half(), 4);
        assert_eq!(Quantizer::new(4).levels(), 9);
        assert_eq!(Quantizer::new(8).half(), 64);
        assert_eq!(Quantizer::new(8).levels(), 129);
    }

    #[test]
    #[should_panic]
    fn bits_out_of_range_panics() {
        Quantizer::new(1);
    }

    #[test]
    fn codes_in_range() {
        let mut rng = XorShift128Plus::new(1);
        for bits in 2..=8u8 {
            let q = Quantizer::new(bits);
            let v = rng.gaussian_vec(512);
            let (codes, _) = q.quantize_auto(&v, &mut rng);
            let half = q.half() as i32;
            assert!(codes.iter().all(|&c| (c as i32).abs() <= half), "bits={bits}");
        }
    }

    #[test]
    fn grid_points_are_fixed() {
        // Values exactly on the grid quantize deterministically.
        let q = Quantizer::new(4);
        let mut rng = XorShift128Plus::new(2);
        for k in -4i32..=4 {
            let v = k as f32 / 4.0;
            let c = q.quantize_one(v, rng.uniform_f32(), 1.0);
            assert_eq!(c as i32, k);
        }
    }

    #[test]
    fn clamps_out_of_range() {
        let q = Quantizer::new(2);
        assert_eq!(q.quantize_one(5.0, 0.5, 1.0), 1);
        assert_eq!(q.quantize_one(-5.0, 0.5, 1.0), -1);
    }

    #[test]
    fn unbiased_in_expectation() {
        let q = Quantizer::new(2);
        let mut rng = XorShift128Plus::new(3);
        let v = 0.3f32;
        let reps = 60_000;
        let mean: f64 = (0..reps)
            .map(|_| q.dequantize_one(q.quantize_one(v, rng.uniform_f32(), 1.0), 1.0) as f64)
            .sum::<f64>()
            / reps as f64;
        assert!((mean - v as f64).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn lemma4_expected_error_bound() {
        // E‖Q(v)−v‖₂ ≤ scale·√M / 2^{b−1}
        let mut rng = XorShift128Plus::new(4);
        let m = 256usize;
        let v: Vec<f32> = (0..m).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
        for bits in [2u8, 4, 8] {
            let q = Quantizer::new(bits);
            let mut acc = 0.0f64;
            let reps = 60;
            for _ in 0..reps {
                let codes = q.quantize_slice(&v, 1.0, &mut rng);
                let dq = q.dequantize_slice(&codes, 1.0);
                acc += crate::linalg::norm2(&crate::linalg::sub(&dq, &v)) as f64;
            }
            let bound = (m as f64).sqrt() / (1u64 << (bits - 1)) as f64;
            assert!(acc / reps as f64 <= bound, "bits={bits}");
        }
    }

    #[test]
    fn roundtrip_error_within_half_spacing() {
        let mut rng = XorShift128Plus::new(5);
        for bits in [2u8, 4, 8] {
            let q = Quantizer::new(bits);
            let spacing = 1.0 / q.half() as f32;
            for _ in 0..200 {
                let v = rng.uniform_in(-1.0, 1.0) as f32;
                let dq = q.dequantize_one(q.quantize_one(v, rng.uniform_f32(), 1.0), 1.0);
                assert!((dq - v).abs() <= spacing + 1e-6, "bits={bits} v={v} dq={dq}");
            }
        }
    }

    #[test]
    fn matrix_quantization_dims_and_scale() {
        let mut rng = XorShift128Plus::new(6);
        let a = Mat::from_fn(10, 20, |_, _| rng.gaussian_f32());
        let qm = QuantizedMatrix::from_mat(&a, 4, &mut rng);
        assert_eq!((qm.m, qm.n), (10, 20));
        assert!((qm.scale - a.max_abs()).abs() < 1e-6);
        assert_eq!(qm.bytes_ideal(), 10 * 20 * 4 / 8);
    }

    #[test]
    fn transposed_codes_match() {
        let mut rng = XorShift128Plus::new(7);
        let a = Mat::from_fn(5, 8, |_, _| rng.gaussian_f32());
        let qm = QuantizedMatrix::from_mat(&a, 8, &mut rng);
        let qt = qm.transposed();
        for i in 0..5 {
            for j in 0..8 {
                assert_eq!(qm.codes[i * 8 + j], qt.codes[j * 5 + i]);
            }
        }
    }

    #[test]
    fn quantization_error_decreases_with_bits() {
        let mut rng = XorShift128Plus::new(8);
        let a = Mat::from_fn(40, 40, |_, _| rng.gaussian_f32());
        let mut errs = vec![];
        for bits in [2u8, 4, 8] {
            let qm = QuantizedMatrix::from_mat(&a, bits, &mut rng);
            let diff: Vec<f32> = a.data.iter().zip(&qm.to_mat().data).map(|(x, y)| x - y).collect();
            errs.push(crate::linalg::norm2(&diff));
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }
}
