//! Bit-packed code storage (S3) — the memory layout behind the speedups.
//!
//! The FPGA/CPU speedups in the paper (Figs 5–6) come from moving
//! `m·n·b/8` bytes instead of `4·m·n`: quantized values are *packed*, b bits
//! each, into machine words. This module implements that layout for
//! b ∈ {2, 4, 8}: codes are biased by `half` into unsigned b-bit fields
//! (`field = code + half`, so b=2 fields hold {0,1,2}), packed little-endian
//! into `u64` words, each **row padded to a word boundary** so rows can be
//! streamed independently (the paper's FPGA gradient unit consumes whole
//! cache lines per row segment).

use super::{QuantizedMatrix, Quantizer};

/// Bit-packed quantized matrix (row-major, row-aligned to u64 words).
#[derive(Debug, Clone)]
pub struct PackedMatrix {
    pub m: usize,
    pub n: usize,
    pub bits: u8,
    pub scale: f32,
    /// Words per row (row stride).
    pub words_per_row: usize,
    pub words: Vec<u64>,
}

impl PackedMatrix {
    /// Codes per 64-bit word at this width.
    #[inline]
    pub fn lanes(bits: u8) -> usize {
        64 / bits as usize
    }

    pub fn pack(qm: &QuantizedMatrix) -> Self {
        let bits = qm.bits;
        assert!(
            matches!(bits, 2 | 4 | 8),
            "packed storage supports b ∈ {{2,4,8}}, got {bits}"
        );
        let half = Quantizer::new(bits).half();
        let lanes = Self::lanes(bits);
        let words_per_row = qm.n.div_ceil(lanes);
        // Assemble each word in a register and store it once — the previous
        // per-element read-modify-write of `words[w]` forced a load+or+store
        // round trip through memory for every code.
        let mut words = Vec::with_capacity(qm.m * words_per_row);
        let mask = (1u64 << bits) - 1;
        for i in 0..qm.m {
            let row = &qm.codes[i * qm.n..(i + 1) * qm.n];
            for chunk in row.chunks(lanes) {
                let mut w = 0u64;
                let mut off = 0u32;
                for &code in chunk {
                    w |= (((code as i32 + half) as u64) & mask) << off;
                    off += bits as u32;
                }
                words.push(w);
            }
        }
        debug_assert_eq!(words.len(), qm.m * words_per_row);
        Self { m: qm.m, n: qm.n, bits, scale: qm.scale, words_per_row, words }
    }

    /// Unpack back to int8 codes (round-trip must be exact).
    pub fn unpack(&self) -> QuantizedMatrix {
        let half = Quantizer::new(self.bits).half();
        let lanes = Self::lanes(self.bits);
        let mask = (1u64 << self.bits) - 1;
        let mut codes = vec![0i8; self.m * self.n];
        for i in 0..self.m {
            for j in 0..self.n {
                let w = self.words[i * self.words_per_row + j / lanes];
                let field = (w >> ((j % lanes) * self.bits as usize)) & mask;
                codes[i * self.n + j] = (field as i32 - half) as i8;
            }
        }
        QuantizedMatrix {
            m: self.m,
            n: self.n,
            bits: self.bits,
            scale: self.scale,
            codes,
        }
    }

    /// Actual storage footprint in bytes — the paper's traffic metric with
    /// row-padding included.
    pub fn bytes(&self) -> usize {
        self.words.len() * 8
    }

    /// Dequantization multiplier scale/half.
    #[inline]
    pub fn multiplier(&self) -> f32 {
        self.scale / Quantizer::new(self.bits).half() as f32
    }

    #[inline]
    pub fn row_words(&self, i: usize) -> &[u64] {
        &self.words[i * self.words_per_row..(i + 1) * self.words_per_row]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::XorShift128Plus;

    fn random_qm(m: usize, n: usize, bits: u8, seed: u64) -> QuantizedMatrix {
        let mut rng = XorShift128Plus::new(seed);
        let a = Mat::from_fn(m, n, |_, _| rng.gaussian_f32());
        QuantizedMatrix::from_mat(&a, bits, &mut rng)
    }

    #[test]
    fn roundtrip_exact_all_widths() {
        for bits in [2u8, 4, 8] {
            for (m, n) in [(1, 1), (3, 7), (16, 64), (10, 33)] {
                let qm = random_qm(m, n, bits, (bits as u64) << 8 | m as u64);
                let packed = PackedMatrix::pack(&qm);
                let back = packed.unpack();
                assert_eq!(qm.codes, back.codes, "bits={bits} m={m} n={n}");
                assert_eq!(qm.scale, back.scale);
            }
        }
    }

    #[test]
    fn lanes_per_word() {
        assert_eq!(PackedMatrix::lanes(2), 32);
        assert_eq!(PackedMatrix::lanes(4), 16);
        assert_eq!(PackedMatrix::lanes(8), 8);
    }

    #[test]
    fn footprint_shrinks_with_bits() {
        let (m, n) = (32, 256);
        let b2 = PackedMatrix::pack(&random_qm(m, n, 2, 1)).bytes();
        let b4 = PackedMatrix::pack(&random_qm(m, n, 4, 2)).bytes();
        let b8 = PackedMatrix::pack(&random_qm(m, n, 8, 3)).bytes();
        assert_eq!(b4, 2 * b2);
        assert_eq!(b8, 2 * b4);
        // vs f32: 16x / 8x / 4x smaller
        assert_eq!(m * n * 4 / b2, 16);
    }

    #[test]
    fn row_padding_word_aligned() {
        // n=5 at 2 bits -> 1 word per row despite 32 lanes.
        let qm = random_qm(4, 5, 2, 4);
        let p = PackedMatrix::pack(&qm);
        assert_eq!(p.words_per_row, 1);
        assert_eq!(p.words.len(), 4);
        // n=33 at 2 bits -> 2 words per row.
        let qm = random_qm(4, 33, 2, 5);
        assert_eq!(PackedMatrix::pack(&qm).words_per_row, 2);
    }

    #[test]
    #[should_panic]
    fn pack_rejects_odd_widths() {
        let qm = random_qm(2, 2, 3, 6);
        PackedMatrix::pack(&qm);
    }

    #[test]
    fn extreme_codes_roundtrip() {
        // Explicit max/min codes at every width.
        for bits in [2u8, 4, 8] {
            let half = Quantizer::new(bits).half() as i8;
            let qm = QuantizedMatrix {
                m: 1,
                n: 3,
                bits,
                scale: 1.0,
                codes: vec![-half, 0, half],
            };
            let back = PackedMatrix::pack(&qm).unpack();
            assert_eq!(back.codes, vec![-half, 0, half]);
        }
    }
}
