//! PGM (portable graymap) image I/O — the Fig 1 / Fig 9 sky maps and the
//! MRI phantom panels.
//!
//! Writing: binary P5, 8-bit, with linear scaling from [min, max] of the
//! data (or a caller-fixed range so panels of a figure share a colour
//! scale). Reading ([`read_pgm`]): both ASCII `P2` and binary `P5`, any
//! maxval ≤ 65535 (two-byte big-endian samples above 255, per the Netpbm
//! spec), `#` comments between header tokens (and inside `P2` rasters) —
//! matching the reference implementation, which delimits a binary raster
//! with exactly one whitespace byte after maxval, so a leading raster
//! byte of 0x23 is data, never a comment. Enough to feed recovered
//! images (or external ground truths) back into the pipeline.

use std::io::{Error, ErrorKind, Write as _};
use std::path::Path;

/// Write an r×r (row-major) image to `path` as binary PGM.
/// `range` fixes the scaling; `None` auto-scales to the data extremes.
pub fn write_pgm(
    path: &Path,
    data: &[f32],
    width: usize,
    height: usize,
    range: Option<(f32, f32)>,
) -> std::io::Result<()> {
    assert_eq!(data.len(), width * height);
    let (lo, hi) = range.unwrap_or_else(|| {
        let lo = data.iter().cloned().fold(f32::MAX, f32::min);
        let hi = data.iter().cloned().fold(f32::MIN, f32::max);
        (lo, hi)
    });
    let span = (hi - lo).max(f32::MIN_POSITIVE);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    write!(f, "P5\n{width} {height}\n255\n")?;
    let bytes: Vec<u8> = data
        .iter()
        .map(|&v| (((v - lo) / span).clamp(0.0, 1.0) * 255.0) as u8)
        .collect();
    f.write_all(&bytes)
}

/// A decoded PGM image: raw sample values as `f32` (0..=maxval).
#[derive(Debug, Clone, PartialEq)]
pub struct PgmImage {
    pub width: usize,
    pub height: usize,
    pub maxval: u32,
    /// Row-major samples, `width * height` of them, in `0..=maxval`.
    pub data: Vec<f32>,
}

impl PgmImage {
    /// Samples rescaled to `[0, 1]` (what the recovery pipeline consumes).
    pub fn normalized(&self) -> Vec<f32> {
        let inv = 1.0 / self.maxval as f32;
        self.data.iter().map(|&v| v * inv).collect()
    }
}

fn bad(msg: impl Into<String>) -> Error {
    Error::new(ErrorKind::InvalidData, msg.into())
}

/// Header tokenizer: skips whitespace and `#`-to-end-of-line comments,
/// returns the next token and the index just past it.
fn next_token(bytes: &[u8], mut i: usize) -> std::io::Result<(&[u8], usize)> {
    loop {
        while i < bytes.len() && bytes[i].is_ascii_whitespace() {
            i += 1;
        }
        if i < bytes.len() && bytes[i] == b'#' {
            while i < bytes.len() && bytes[i] != b'\n' {
                i += 1;
            }
            continue;
        }
        break;
    }
    let start = i;
    while i < bytes.len() && !bytes[i].is_ascii_whitespace() && bytes[i] != b'#' {
        i += 1;
    }
    if start == i {
        return Err(bad("pgm: truncated header"));
    }
    Ok((&bytes[start..i], i))
}

fn parse_usize(tok: &[u8], what: &str) -> std::io::Result<usize> {
    std::str::from_utf8(tok)
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .ok_or_else(|| bad(format!("pgm: invalid {what} '{}'", String::from_utf8_lossy(tok))))
}

/// Read a PGM file (ASCII `P2` or binary `P5`, maxval ≤ 65535). The
/// round-trip partner of [`write_pgm`].
pub fn read_pgm(path: &Path) -> std::io::Result<PgmImage> {
    let bytes = std::fs::read(path)?;
    let (magic, mut i) = next_token(&bytes, 0)?;
    let binary = match magic {
        b"P5" => true,
        b"P2" => false,
        other => {
            return Err(bad(format!(
                "pgm: unsupported magic '{}' (P2|P5)",
                String::from_utf8_lossy(other)
            )))
        }
    };
    let (tok, j) = next_token(&bytes, i)?;
    let width = parse_usize(tok, "width")?;
    let (tok, j) = next_token(&bytes, j)?;
    let height = parse_usize(tok, "height")?;
    let (tok, j) = next_token(&bytes, j)?;
    let maxval = parse_usize(tok, "maxval")?;
    i = j;
    if maxval == 0 || maxval > 65535 {
        return Err(bad(format!("pgm: maxval {maxval} out of range 1..=65535")));
    }
    let count = width
        .checked_mul(height)
        .ok_or_else(|| bad("pgm: image dimensions overflow"))?;

    let mut data = Vec::with_capacity(count);
    if binary {
        // Exactly one whitespace byte separates the maxval token from
        // the raster — the reference implementation's rule. No comment
        // handling here: a '#' after the delimiter is raster DATA (byte
        // 0x23), and treating it as a comment would corrupt round-trips
        // of our own writer. Comments belong between header tokens
        // (where `next_token` strips them).
        if i >= bytes.len() || !bytes[i].is_ascii_whitespace() {
            return Err(bad("pgm: missing raster separator"));
        }
        i += 1;
        let wide = maxval > 255;
        let sample_bytes = if wide { 2 } else { 1 };
        let need = count * sample_bytes;
        let raster = &bytes[i.min(bytes.len())..];
        if raster.len() < need {
            return Err(bad(format!(
                "pgm: raster truncated ({} of {need} bytes)",
                raster.len()
            )));
        }
        for k in 0..count {
            let v = if wide {
                u16::from_be_bytes([raster[2 * k], raster[2 * k + 1]]) as u32
            } else {
                raster[k] as u32
            };
            data.push(v as f32);
        }
    } else {
        for _ in 0..count {
            let (tok, j) = next_token(&bytes, i).map_err(|_| bad("pgm: raster truncated"))?;
            data.push(parse_usize(tok, "sample")? as f32);
            i = j;
        }
    }
    if data.iter().any(|&v| v > maxval as f32) {
        return Err(bad("pgm: sample exceeds maxval"));
    }
    Ok(PgmImage { width, height, maxval: maxval as u32, data })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_valid_header_and_size() {
        let dir = std::env::temp_dir().join("lpcs_pgm_test");
        let path = dir.join("t.pgm");
        let data = vec![0.0f32, 0.5, 1.0, 0.25];
        write_pgm(&path, &data, 2, 2, None).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.starts_with(b"P5\n2 2\n255\n"));
        assert_eq!(bytes.len(), 11 + 4);
        // Max value maps to 255, min to 0.
        assert_eq!(bytes[11], 0);
        assert_eq!(bytes[13], 255);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_read_roundtrip() {
        let dir = std::env::temp_dir().join("lpcs_pgm_rt");
        let path = dir.join("rt.pgm");
        // Values spanning the scale; write normalizes [lo, hi] → 0..=255.
        let data = vec![0.0f32, 0.25, 0.5, 0.75, 1.0, 0.1];
        write_pgm(&path, &data, 3, 2, Some((0.0, 1.0))).unwrap();
        let img = read_pgm(&path).unwrap();
        assert_eq!((img.width, img.height, img.maxval), (3, 2, 255));
        assert_eq!(img.data.len(), 6);
        let norm = img.normalized();
        for (got, want) in norm.iter().zip(&data) {
            // One 8-bit quantization step of tolerance.
            assert!((got - want).abs() <= 1.0 / 255.0 + 1e-6, "{got} vs {want}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reads_ascii_p2_with_comments() {
        let dir = std::env::temp_dir().join("lpcs_pgm_p2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.pgm");
        std::fs::write(
            &path,
            "P2 # ascii graymap\n# a comment line\n3 2\n15\n0 1 2\n13 14 15\n",
        )
        .unwrap();
        let img = read_pgm(&path).unwrap();
        assert_eq!((img.width, img.height, img.maxval), (3, 2, 15));
        assert_eq!(img.data, vec![0.0, 1.0, 2.0, 13.0, 14.0, 15.0]);
        assert!((img.normalized()[5] - 1.0).abs() < 1e-6);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reads_p5_with_header_comments_and_hash_valued_raster() {
        let dir = std::env::temp_dir().join("lpcs_pgm_p5c");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.pgm");
        // Comments between header tokens; the raster's FIRST byte is
        // 0x23 ('#') and whitespace-valued bytes follow — all must be
        // read as data (one-whitespace delimiter rule).
        let mut bytes = b"P5 # binary graymap\n# scanner gain 1.0\n2 2\n255\n".to_vec();
        bytes.extend_from_slice(&[b'#', b'\n', 30, 40]);
        std::fs::write(&path, bytes).unwrap();
        let img = read_pgm(&path).unwrap();
        assert_eq!((img.width, img.height, img.maxval), (2, 2, 255));
        assert_eq!(img.data, vec![35.0, 10.0, 30.0, 40.0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reads_16bit_p5_big_endian() {
        let dir = std::env::temp_dir().join("lpcs_pgm_p5w");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.pgm");
        let mut bytes = b"P5\n2 1\n65535\n".to_vec();
        bytes.extend_from_slice(&300u16.to_be_bytes());
        bytes.extend_from_slice(&65535u16.to_be_bytes());
        std::fs::write(&path, bytes).unwrap();
        let img = read_pgm(&path).unwrap();
        assert_eq!(img.maxval, 65535);
        assert_eq!(img.data, vec![300.0, 65535.0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn read_rejects_malformed_files() {
        let dir = std::env::temp_dir().join("lpcs_pgm_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let write = |name: &str, content: &[u8]| {
            let p = dir.join(name);
            std::fs::write(&p, content).unwrap();
            p
        };
        // Wrong magic (PBM bitmap).
        let p = write("m.pgm", b"P1\n2 2\n0 1 1 0\n");
        assert!(read_pgm(&p).unwrap_err().to_string().contains("magic"));
        // Truncated binary raster.
        let p = write("t.pgm", b"P5\n4 4\n255\nab");
        assert!(read_pgm(&p).unwrap_err().to_string().contains("truncated"));
        // Maxval out of range.
        let p = write("x.pgm", b"P2\n1 1\n70000\n5\n");
        assert!(read_pgm(&p).unwrap_err().to_string().contains("maxval"));
        // ASCII sample above maxval.
        let p = write("s.pgm", b"P2\n1 1\n10\n11\n");
        assert!(read_pgm(&p).unwrap_err().to_string().contains("exceeds"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fixed_range_clamps() {
        let dir = std::env::temp_dir().join("lpcs_pgm_test2");
        let path = dir.join("t.pgm");
        write_pgm(&path, &[-1.0, 2.0], 2, 1, Some((0.0, 1.0))).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let px = &bytes[bytes.len() - 2..];
        assert_eq!(px, &[0u8, 255]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
