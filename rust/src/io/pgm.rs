//! PGM (portable graymap) image dumps — the Fig 1 / Fig 9 sky maps.
//!
//! Binary P5, 8-bit, with linear scaling from [min, max] of the data (or a
//! caller-fixed range so panels of a figure share a colour scale).

use std::io::Write as _;
use std::path::Path;

/// Write an r×r (row-major) image to `path` as binary PGM.
/// `range` fixes the scaling; `None` auto-scales to the data extremes.
pub fn write_pgm(
    path: &Path,
    data: &[f32],
    width: usize,
    height: usize,
    range: Option<(f32, f32)>,
) -> std::io::Result<()> {
    assert_eq!(data.len(), width * height);
    let (lo, hi) = range.unwrap_or_else(|| {
        let lo = data.iter().cloned().fold(f32::MAX, f32::min);
        let hi = data.iter().cloned().fold(f32::MIN, f32::max);
        (lo, hi)
    });
    let span = (hi - lo).max(f32::MIN_POSITIVE);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    write!(f, "P5\n{width} {height}\n255\n")?;
    let bytes: Vec<u8> = data
        .iter()
        .map(|&v| (((v - lo) / span).clamp(0.0, 1.0) * 255.0) as u8)
        .collect();
    f.write_all(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_valid_header_and_size() {
        let dir = std::env::temp_dir().join("lpcs_pgm_test");
        let path = dir.join("t.pgm");
        let data = vec![0.0f32, 0.5, 1.0, 0.25];
        write_pgm(&path, &data, 2, 2, None).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.starts_with(b"P5\n2 2\n255\n"));
        assert_eq!(bytes.len(), 11 + 4);
        // Max value maps to 255, min to 0.
        assert_eq!(bytes[11], 0);
        assert_eq!(bytes[13], 255);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fixed_range_clamps() {
        let dir = std::env::temp_dir().join("lpcs_pgm_test2");
        let path = dir.join("t.pgm");
        write_pgm(&path, &[-1.0, 2.0], 2, 1, Some((0.0, 1.0))).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let px = &bytes[bytes.len() - 2..];
        assert_eq!(px, &[0u8, 255]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
