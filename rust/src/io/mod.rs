//! I/O substrate (S16): minimal JSON parser (the build environment vendors
//! no serde), CSV emission, and PGM image dumps for sky maps.

pub mod csv;
pub mod json;
pub mod pgm;
