//! CSV emission for the figure-regeneration harness (results/ *.csv).

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// A simple CSV table: header + f64 rows, with optional string columns.
#[derive(Debug, Clone)]
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Convenience: all-numeric row.
    pub fn row_f64(&mut self, cells: &[f64]) {
        self.row(&cells.iter().map(|v| format_num(*v)).collect::<Vec<_>>());
    }

    /// Mixed row: leading label + numbers.
    pub fn row_labeled(&mut self, label: &str, cells: &[f64]) {
        let mut v = vec![label.to_string()];
        v.extend(cells.iter().map(|c| format_num(*c)));
        self.row(&v);
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        writeln!(out, "{}", self.header.join(",")).unwrap();
        for r in &self.rows {
            writeln!(out, "{}", r.join(",")).unwrap();
        }
        out
    }

    pub fn write_to(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())
    }

    /// Render as an aligned markdown-ish table for stdout.
    pub fn pretty(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        writeln!(out, "{}", fmt_row(&self.header, &widths)).unwrap();
        writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1))).unwrap();
        for r in &self.rows {
            writeln!(out, "{}", fmt_row(r, &widths)).unwrap();
        }
        out
    }
}

fn format_num(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e12 {
        format!("{}", v as i64)
    } else {
        format!("{v:.6}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_and_rows() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.row_f64(&[1.0, 2.5]);
        t.row_labeled("x", &[3.0]);
        let s = t.to_string();
        assert!(s.starts_with("a,b\n"));
        assert!(s.contains("1,2.500000"));
        assert!(s.contains("x,3"));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.row_f64(&[1.0]);
    }

    #[test]
    fn writes_file() {
        let mut t = CsvTable::new(&["v"]);
        t.row_f64(&[9.0]);
        let dir = std::env::temp_dir().join("lpcs_csv_test");
        let path = dir.join("t.csv");
        t.write_to(&path).unwrap();
        let read = std::fs::read_to_string(&path).unwrap();
        assert_eq!(read, "v\n9\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pretty_aligns() {
        let mut t = CsvTable::new(&["name", "val"]);
        t.row_labeled("long-name", &[1.0]);
        let p = t.pretty();
        assert!(p.contains("long-name"));
    }
}
