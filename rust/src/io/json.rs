//! Minimal recursive-descent JSON parser — enough for `artifacts/manifest.json`
//! and the config files. No external crates are available offline, so this
//! is part of the substrate (DESIGN.md §6).
//!
//! Supports the full JSON value grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null). Numbers are stored as f64.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize back to compact JSON (used by the service API and tests).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode the UTF-8 sequence starting at c.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    let end = (start + len).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": null}, "x"], "c": true}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Bool(true)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0], Json::Num(1.0));
        assert_eq!(arr[1].get("b"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(j, Json::Str("a\n\t\"\\A".into()));
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"αβγ\"").unwrap();
        assert_eq!(j, Json::Str("αβγ".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_dump() {
        let src = r#"{"entries":[{"m":256,"n":512,"name":"x"}],"fmt":"hlo-text"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
          "format": "hlo-text",
          "entries": [
            {"name": "qniht_step_tiny", "file": "a.hlo.txt", "m": 64, "n": 128, "s": 8,
             "inputs": [{"name": "codes1_t", "dtype": "int8", "shape": [128, 64]}]}
          ]
        }"#;
        let j = Json::parse(text).unwrap();
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("m").unwrap().as_usize(), Some(64));
        assert_eq!(
            e.get("inputs").unwrap().as_arr().unwrap()[0].get("dtype").unwrap().as_str(),
            Some("int8")
        );
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
